//! Regenerate the paper's Table 1.
//!
//! Small trained models (LeNet-300-100, LeNet5, Small-VGG16, FCAE) get
//! the full treatment: S-sweep → compress → PJRT accuracy before/after.
//! With `--large`, the VGG16 / ResNet50 / MobileNet-v1 rows are added
//! using synthetic weights at true layer shapes (DESIGN.md §5) at 1/8
//! channel scale (pass `--scale 1` for the true 553 MB VGG16 — slow).
//!
//! ```bash
//! cargo run --release --offline --example table1 -- --large
//! ```

use deepcabac::app;
use deepcabac::coordinator::{sweep::default_s_grid, CompressionSpec};
use deepcabac::report::{human_bytes, Table};
use deepcabac::synth::Arch;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let large = args.iter().any(|a| a == "--large");
    let no_eval = args.iter().any(|a| a == "--no-eval");
    let scale = args
        .iter()
        .position(|a| a == "--scale")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(8);

    let spec = CompressionSpec::default();
    let s_grid = default_s_grid(17);

    println!("Table 1 — compression ratios with DeepCABAC (this reproduction)");
    println!("paper reference values in brackets; datasets are synthetic substitutes\n");

    let mut table = Table::new(&[
        "Model", "Dataset", "Org.acc", "Org.size", "Spars.[%]",
        "Comp.ratio[%]", "Acc.after", "[paper spars/ratio]",
    ]);
    let paper_ref = |m: &str| match m {
        "lenet300" => "9.05 / 1.82",
        "lenet5" => "1.90 / 0.72",
        "smallvgg" => "7.57 / 1.6",
        "fcae" => "55.69 / 16.15",
        "vgg16" => "9.85 / 1.57",
        "resnet50" => "25.40 / 5.95",
        "mobilenet-v1" => "50.73 / 12.7",
        _ => "-",
    };

    for name in app::SMALL_MODELS {
        eprintln!("[table1] {name} ...");
        let row = app::table1_small_row(name, &s_grid, &spec, 1, !no_eval)?;
        table.row(vec![
            row.model.clone(),
            row.dataset.clone(),
            fmt_metric(&row.model, row.org_metric),
            human_bytes(row.org_bytes),
            format!("{:.2}", row.sparsity_pct),
            format!("{:.2}", row.ratio_pct),
            row.metric_after
                .map(|m| fmt_metric(&row.model, m))
                .unwrap_or_else(|| "n/a".into()),
            paper_ref(&row.model).into(),
        ]);
    }

    if large {
        for arch in [Arch::Vgg16, Arch::ResNet50, Arch::MobileNetV1] {
            eprintln!("[table1] {} (synthetic, 1/{scale}) ...", arch.name());
            let row = app::table1_large_row(arch, scale, &s_grid, &spec, 1, 42)?;
            table.row(vec![
                format!("{}*", row.model),
                row.dataset.clone(),
                "n/a".into(),
                human_bytes(row.org_bytes),
                format!("{:.2}", row.sparsity_pct),
                format!("{:.2}", row.ratio_pct),
                "n/a".into(),
                paper_ref(&row.model).into(),
            ]);
        }
    }

    println!("{}", table.render());
    if large {
        println!("* synthetic weights at true layer shapes (1/{scale} channel scale);");
        println!("  accuracy requires ImageNet — see DESIGN.md §5 substitutions.");
    }
    Ok(())
}

fn fmt_metric(model: &str, m: f64) -> String {
    if model == "fcae" {
        format!("{m:.2} dB")
    } else {
        format!("{:.2}%", m * 100.0)
    }
}
