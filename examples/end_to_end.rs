//! End-to-end driver (the system-prompt-required validation run):
//!
//! 1. Layer 2/1 (build time, cached): `make artifacts` trained
//!    LeNet-300-100 (~267k params) and LeNet5 on synth-MNIST with
//!    variational-dropout sparsification and lowered their Pallas
//!    forward passes to HLO.
//! 2. This binary (pure Rust, no Python):
//!    a. loads the sparse weights + posterior sigmas,
//!    b. evaluates the *original* accuracy through the PJRT runtime,
//!    c. sweeps S, compresses with the coupled RD quantizer + CABAC,
//!    d. decompresses, re-evaluates accuracy,
//!    e. prints the Table-1-style row and asserts the contract:
//!       big compression, tiny accuracy delta, bit-exact container.
//!
//! ```bash
//! cargo run --release --offline --example end_to_end
//! ```

use deepcabac::app;
use deepcabac::coordinator::{sweep::default_s_grid, sweep_s, CompressionSpec};
use deepcabac::model::CompressedModel;
use deepcabac::report::human_bytes;
use deepcabac::runtime::Runtime;
use deepcabac::util::Timer;

fn main() -> anyhow::Result<()> {
    let model_name =
        std::env::args().nth(1).unwrap_or_else(|| "lenet300".to_string());
    println!("=== DeepCABAC end-to-end: {model_name} ===\n");

    let t_all = Timer::new();
    let model = app::load_model(&model_name)?;
    println!(
        "loaded {}: {} weights in {} layers, density {:.2}% (VD-sparsified), raw {}",
        model.manifest.name,
        model.weight_count(),
        model.weights.len(),
        model.density() * 100.0,
        human_bytes(model.raw_bytes()),
    );

    // -- original accuracy through PJRT (Python is NOT involved) --------
    let rt = Runtime::cpu()?;
    let t = Timer::new();
    let before = app::evaluate_original(&rt, &model)?;
    println!(
        "\n[1] original eval : {:.4} ({} samples, {:.2}s, platform={})",
        before.metric,
        before.n_samples,
        t.elapsed_s(),
        rt.platform()
    );

    // -- S sweep + coupled RD quantization + CABAC ----------------------
    let spec = CompressionSpec::default();
    let grid = default_s_grid(17);
    let t = Timer::new();
    let sweep = sweep_s(&model, &grid, &spec, 1)?;
    let (compressed, report) = sweep.best;
    println!(
        "\n[2] compression   : {} -> {} ({:.2}% of original, x{:.1}) in {:.2}s",
        human_bytes(report.raw_bytes),
        human_bytes(report.compressed_bytes),
        report.ratio_percent(),
        report.factor(),
        t.elapsed_s(),
    );
    println!("    sweep probed {} S values; best S = {}", sweep.points.len(),
             compressed.layers[0].s_param);
    for l in &report.layers {
        println!(
            "      {:<8} {:>9} weights  {:>8}  {:.3} bpw",
            l.name,
            l.n_weights,
            human_bytes(l.payload_bytes),
            l.bits_per_weight()
        );
    }

    // -- container round trip -------------------------------------------
    let bytes = compressed.serialize();
    let reloaded = CompressedModel::deserialize(&bytes)?;
    assert_eq!(reloaded.serialize(), bytes, "container not bit-exact");
    println!("\n[3] container     : {} serialized, bit-exact reload OK", human_bytes(bytes.len()));

    // -- decompressed accuracy through PJRT ------------------------------
    let t = Timer::new();
    let after = app::evaluate_compressed(&rt, &model, &reloaded)?;
    println!(
        "[4] compressed eval: {:.4} ({:.2}s)",
        after.metric,
        t.elapsed_s()
    );

    let delta = before.metric - after.metric;
    println!("\n=== Table-1 row ===");
    println!(
        "{:<10} {:<12} org {:.4} | size {} | spars {:.2}% | ratio {:.2}% | after {:.4} (Δ {:+.4})",
        model.manifest.name,
        app::dataset_of(&model.manifest.name),
        before.metric,
        human_bytes(report.raw_bytes),
        model.density() * 100.0,
        report.ratio_percent(),
        after.metric,
        -delta,
    );
    println!("total wall time: {:.1}s", t_all.elapsed_s());

    // Contract asserts (loose enough for any healthy run).
    assert!(report.factor() > 5.0, "compression factor suspiciously low");
    let tolerance = if model.manifest.task == "classify" { 0.02 } else { 1.5 };
    assert!(
        delta.abs() < tolerance || after.metric > before.metric,
        "accuracy drop {delta} exceeds tolerance {tolerance}"
    );
    println!("\nEND-TO-END OK");
    Ok(())
}
