//! L1 kernel offload: the Rust coordinator executes the *Pallas
//! rd_quantize kernel itself* through PJRT (artifacts/kernels/...),
//! using it for batched candidate pre-selection, then compares against
//! the exact sequential RD scan.
//!
//! This is the third way the three layers compose (besides model
//! forwards and the codec): L3 calls L1 compute directly.
//!
//! ```bash
//! cargo run --release --offline --example kernel_offload
//! ```

use deepcabac::app;
use deepcabac::quant::QuantGrid;
use deepcabac::runtime::{RdQuantizeKernel, Runtime};
use deepcabac::util::{SplitMix64, Timer};

fn main() -> anyhow::Result<()> {
    let rt = Runtime::cpu()?;
    let kernel = RdQuantizeKernel::load(&rt, &app::artifacts_dir())?;
    println!(
        "loaded rd_quantize HLO kernel: block {} weights x {} grid points\n",
        kernel.block_n, kernel.k
    );

    // a sparse tensor + grid, like one VGG conv layer
    let n = 200_000;
    let mut rng = SplitMix64::new(99);
    let mut w = vec![0.0f32; n];
    let mut eta = vec![1.0f32; n];
    for i in 0..n {
        if rng.next_f64() < 0.1 {
            w[i] = rng.laplace(0.08) as f32;
        }
        eta[i] = 1.0 / (0.02 + 0.05 * rng.next_f32()).powi(2);
    }
    let grid = QuantGrid::from_stats(
        w.iter().fold(0.0f32, |m, &v| m.max(v.abs())),
        0.02,
        40,
    );
    // explicit grid + a frozen rate snapshot (fresh-context estimate)
    let levels: Vec<i32> = (-grid.max_level..=grid.max_level).collect();
    let q: Vec<f32> = levels.iter().map(|&l| grid.value(l)).collect();
    let cfg = deepcabac::codec::CodecConfig::default();
    let ctxs = deepcabac::codec::ContextSet::new(&cfg);
    let rate: Vec<f32> = levels
        .iter()
        .map(|&l| {
            deepcabac::codec::RateEstimator::level_bits(&cfg, &ctxs, (false, false), l)
        })
        .collect();
    let lambda = 0.02f32;

    let t = Timer::new();
    let idx = kernel.run(&w, &eta, &q, &rate, lambda)?;
    let kernel_s = t.elapsed_s();

    // native exact argmin over the same frozen snapshot
    let t = Timer::new();
    let mut agree = 0usize;
    for i in 0..n {
        let mut best = (0usize, f32::INFINITY);
        for (j, (&qq, &rr)) in q.iter().zip(&rate).enumerate() {
            let d = w[i] - qq;
            let cost = eta[i] * d * d + lambda * rr;
            if cost < best.1 {
                best = (j, cost);
            }
        }
        if best.0 == idx[i] as usize {
            agree += 1;
        }
    }
    let native_s = t.elapsed_s();

    println!("kernel (PJRT, blocked)   : {:.3}s for {n} weights", kernel_s);
    println!("native (exact, per-weight): {:.3}s", native_s);
    println!(
        "agreement: {agree}/{n} ({:.4}%)",
        agree as f64 / n as f64 * 100.0
    );
    assert_eq!(agree, n, "blocked kernel must match the frozen-rate argmin");
    println!("\nL1-from-L3 kernel offload OK");
    Ok(())
}
