//! Rate–distortion sweep (the paper's §3/§4 trade-off surface).
//!
//! Sweeps the Lagrangian λ and the grid coarseness S on a trained model
//! and prints CSV series: bytes vs weighted distortion vs accuracy.
//! This regenerates the implicit "figure" behind the paper's statement
//! that compression is sensitive to S (they probed all S ∈ {0..256}).
//!
//! ```bash
//! cargo run --release --offline --example rd_sweep -- lenet300 > rd_sweep.csv
//! ```

use deepcabac::app;
use deepcabac::coordinator::{compress_model, CompressionSpec};
use deepcabac::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let model_name =
        std::env::args().nth(1).unwrap_or_else(|| "lenet300".to_string());
    let with_eval = std::env::args().any(|a| a == "--eval");
    let model = app::load_model(&model_name)?;
    let rt = if with_eval { Some(Runtime::cpu()?) } else { None };

    println!("model,lambda_scale,S,bytes,bits_per_weight,distortion,density,accuracy");
    for &lambda_scale in &[0.0f32, 0.01, 0.05, 0.2, 1.0] {
        for &s in &[0u32, 16, 32, 64, 96, 128, 192, 256] {
            let spec = CompressionSpec { s, lambda_scale, ..Default::default() };
            let (compressed, report) = compress_model(&model, &spec, 1);
            let distortion: f64 = report.layers.iter().map(|l| l.distortion).sum();
            let acc = match &rt {
                Some(rt) => {
                    format!("{:.4}", app::evaluate_compressed(rt, &model, &compressed)?.metric)
                }
                None => "".to_string(),
            };
            println!(
                "{model_name},{lambda_scale},{s},{},{:.4},{:.6e},{:.4},{acc}",
                report.compressed_bytes,
                report.bits_per_weight(),
                distortion,
                report.density,
            );
        }
    }
    Ok(())
}
