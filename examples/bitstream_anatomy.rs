//! Figure 1 reproduction: the DeepCABAC binarization, bin by bin.
//!
//! Walks a short level sequence through the encoder and prints, for each
//! weight: the bins emitted (sigflag / signflag / AbsGr(i) / remainder),
//! which are regular (context-coded) vs bypass, and how the sigflag
//! context's probability estimate adapts — exactly the structure of the
//! paper's figure 1.
//!
//! ```bash
//! cargo run --release --offline --example bitstream_anatomy
//! ```

use deepcabac::codec::{decode_levels, CodecConfig, ContextSet, LevelEncoder, RateEstimator};

fn main() {
    let levels: Vec<i32> = vec![0, 3, 0, 0, -1, 14, 0, 1, 0, 0, 0, 2, -2, 0, 1];
    let cfg = CodecConfig::default();

    println!("DeepCABAC binarization (paper figure 1)");
    println!("regular bins = context-coded (grey in the paper), bypass = fixed-point\n");
    println!(
        "{:<7} {:<44} {:>10} {:>12}",
        "level", "bins", "p(sig=1)", "est. bits"
    );

    let mut enc = LevelEncoder::new(cfg);
    for &l in &levels {
        let sig_idx = ContextSet::sig_ctx_index(&cfg, enc.prev_sig());
        let p_sig = enc.ctxs.sig[sig_idx].p_one();
        let bits = RateEstimator::level_bits(&cfg, &enc.ctxs, enc.prev_sig(), l);
        println!("{:<7} {:<44} {:>10.3} {:>12.3}", l, bins_of(l, &cfg), p_sig, bits);
        enc.encode_level(l);
    }

    let n = levels.len();
    let payload = enc.finish();
    println!(
        "\npayload: {} bytes for {} weights ({:.2} bits/weight; raw f32 = {} bytes)",
        payload.len(),
        n,
        payload.len() as f64 * 8.0 / n as f64,
        4 * n
    );
    assert_eq!(decode_levels(&payload, n, cfg), levels);
    println!("decoder reproduces all levels: OK");
}

fn bins_of(level: i32, cfg: &CodecConfig) -> String {
    if level == 0 {
        return "sigflag=0".into();
    }
    let mut s = format!("sigflag=1 signflag={}", (level < 0) as u8);
    let abs = level.unsigned_abs();
    for i in 1..=cfg.n_abs_flags {
        if abs > i {
            s.push_str(&format!(" absGr{i}=1"));
        } else {
            s.push_str(&format!(" absGr{i}=0"));
            return s;
        }
    }
    s.push_str(&format!(" rem={} [bypass]", abs - cfg.n_abs_flags - 1));
    s
}
