//! Quickstart: compress one sparse weight tensor with DeepCABAC and
//! verify the round trip — the 30-second tour of the public API.
//!
//! ```bash
//! cargo run --release --offline --example quickstart
//! ```

use deepcabac::codec::{decode_levels, CodecConfig};
use deepcabac::coordinator::{compress_tensor, CompressionSpec};
use deepcabac::quant::QuantGrid;
use deepcabac::report::human_bytes;
use deepcabac::util::SplitMix64;

fn main() {
    // 1. A synthetic pre-sparsified layer: 90% zeros, Laplacian nonzeros,
    //    and a per-weight "robustness" sigma as variational dropout would
    //    estimate it (paper §3).
    let n = 200_000;
    let mut rng = SplitMix64::new(7);
    let mut weights = vec![0.0f32; n];
    let mut sigmas = vec![0.0f32; n];
    for i in 0..n {
        if rng.next_f64() > 0.9 {
            weights[i] = rng.laplace(0.08) as f32;
        }
        sigmas[i] = 0.02 + 0.05 * rng.next_f32();
    }

    // 2. One call: grid from eq. 2, coupled RD quantization (eq. 1),
    //    CABAC entropy coding.
    let spec = CompressionSpec { s: 48, lambda_scale: 0.05, ..Default::default() };
    let (layer, report) =
        compress_tensor("demo", &[n], &weights, &sigmas, &[], &spec);

    println!("DeepCABAC quickstart");
    println!("  weights            : {n} ({:.1}% nonzero)", report.density() * 100.0);
    println!("  raw f32            : {}", human_bytes(n * 4));
    println!(
        "  compressed payload : {} ({:.3} bits/weight, x{:.1})",
        human_bytes(report.payload_bytes),
        report.bits_per_weight(),
        (n * 4) as f64 / report.payload_bytes as f64
    );
    println!("  grid               : Δ = {:.6}, S = {}", layer.grid.delta, layer.s_param);

    // 3. Decode and verify.
    let decoded = decode_levels(&layer.payload, n, CodecConfig::default());
    let recon: Vec<f32> = decoded.iter().map(|&l| layer.grid.value(l)).collect();
    let max_err = weights
        .iter()
        .zip(&recon)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!(
        "  max |w - ŵ|        : {max_err:.6} (Δ/2 = {:.6}; λ > 0 trades a few \
         weights past Δ/2 for rate — that is eq. 1 working)",
        layer.grid.delta / 2.0
    );

    // The decode must be bit-exact on the levels (lossless entropy stage).
    let grid = QuantGrid { delta: layer.grid.delta, max_level: layer.grid.max_level };
    assert_eq!(decoded.len(), n);
    assert!(
        max_err <= grid.delta * 8.0,
        "reconstruction error {max_err} far outside the RD regime"
    );
    println!("  roundtrip          : OK (levels decode bit-exact)");
}
