//! Federated-learning scenario (the paper's stated future-work target:
//! "apply it in the context of distributed learning scenarios where
//! memory complexity is critical (e.g. in federated learning)").
//!
//! Simulates `K` clients fine-tuning LeNet-300-100 locally: each round,
//! every client uploads a *sparse weight delta* (only a fraction of
//! weights changed, magnitudes small). We compress each upload with
//! DeepCABAC and compare against scalar Huffman and raw f32, reporting
//! per-round upload bytes — the metric federated deployments care about.
//!
//! ```bash
//! cargo run --release --offline --example federated
//! ```

use deepcabac::baselines::huffman;
use deepcabac::codec::{decode_levels, CodecConfig};
use deepcabac::coordinator::{compress_tensor, CompressionSpec};
use deepcabac::report::{human_bytes, Table};
use deepcabac::util::SplitMix64;

fn main() -> anyhow::Result<()> {
    let n_weights = 266_610; // LeNet-300-100
    let clients = 8;
    let rounds = 5;
    let update_density = 0.02; // 2% of weights touched per round

    println!(
        "federated upload compression: {clients} clients x {rounds} rounds, \
         {n_weights} weights, {:.0}% touched/round\n",
        update_density * 100.0
    );

    let mut rng = SplitMix64::new(0xFED);
    let spec = CompressionSpec { s: 40, lambda_scale: 0.02, ..Default::default() };

    let mut table = Table::new(&[
        "round", "raw f32 (all clients)", "huffman", "deepcabac", "x vs raw",
    ]);
    let mut total_dcbc = 0usize;
    for round in 0..rounds {
        let mut raw = 0usize;
        let mut huff = 0usize;
        let mut dcbc = 0usize;
        for client in 0..clients {
            // sparse delta: later rounds shrink (convergence)
            let scale = 0.02 / (1.0 + round as f64);
            let mut delta = vec![0.0f32; n_weights];
            let mut sigma = vec![0.0f32; n_weights];
            for i in 0..n_weights {
                if rng.next_f64() < update_density {
                    delta[i] = (rng.laplace(scale)) as f32;
                }
                sigma[i] = (scale * 0.5) as f32 + 0.01 * rng.next_f32();
            }
            let _ = client;
            raw += n_weights * 4;

            let (layer, rep) =
                compress_tensor("delta", &[n_weights], &delta, &sigma, &[], &spec);
            dcbc += rep.payload_bytes;
            // huffman baseline codes the same quantized levels
            let levels = decode_levels(&layer.payload, n_weights, CodecConfig::default());
            huff += huffman::encode(&levels)?.len();
        }
        total_dcbc += dcbc;
        table.row(vec![
            round.to_string(),
            human_bytes(raw),
            human_bytes(huff),
            human_bytes(dcbc),
            format!("x{:.0}", raw as f64 / dcbc as f64),
        ]);
    }
    println!("{}", table.render());
    println!(
        "total DeepCABAC upload over {rounds} rounds: {}",
        human_bytes(total_dcbc)
    );
    Ok(())
}
