//! Federated-learning scenario (the paper's stated future-work target:
//! "apply it in the context of distributed learning scenarios where
//! memory complexity is critical (e.g. in federated learning)"), on the
//! real delta engine.
//!
//! Simulates `K` clients fine-tuning LeNet-300-100 from a shared global
//! model: each round, every client perturbs a sparse subset of weights
//! (the local fine-tune), compresses the result through the standard
//! pipeline, and uploads a `.dcbc` v3 **delta segment** against the
//! server's current global container instead of the full container.
//! Every upload is verified end to end — `delta::apply` must rebuild
//! the client's container byte-for-byte — and the server then adopts
//! one client's model as the next round's global (a stand-in for
//! aggregation), growing the version chain the serve path exposes via
//! `GET /models/{m}/delta?from=<fingerprint>`.
//!
//! ```bash
//! cargo run --release --offline --example federated
//! ```

use deepcabac::coordinator::{compress_model, CompressionSpec};
use deepcabac::delta::{apply, encode_from_model};
use deepcabac::model::manifest::{LayerInfo, LayerKind, ModelManifest};
use deepcabac::model::{fingerprint, Model};
use deepcabac::report::{human_bytes, Table};
use deepcabac::tensor::Tensor;
use deepcabac::util::SplitMix64;

/// LeNet-300-100 (784×300, 300×100, 100×10 = 266 610 weights) with a
/// sparse Laplacian initialization, the shape Table 1 compresses.
fn lenet_model(rng: &mut SplitMix64) -> Model {
    let dims: [(usize, usize); 3] = [(784, 300), (300, 100), (100, 10)];
    let mut layers = Vec::new();
    let (mut weights, mut biases, mut sigmas) = (Vec::new(), Vec::new(), Vec::new());
    for (li, (rows, cols)) in dims.iter().enumerate() {
        let n = rows * cols;
        let mut w = vec![0.0f32; n];
        let mut s = vec![0.0f32; n];
        for i in 0..n {
            if rng.next_f64() < 0.1 {
                w[i] = rng.laplace(0.05) as f32;
            }
            s[i] = 0.01 + 0.05 * rng.next_f32();
        }
        weights.push(Tensor::new(vec![*rows, *cols], w));
        sigmas.push(Tensor::new(vec![*rows, *cols], s));
        biases.push(Tensor::new(vec![*cols], vec![0.0; *cols]));
        layers.push(LayerInfo {
            name: format!("fc{}", li + 1),
            kind: LayerKind::Fc,
            shape: vec![*rows, *cols],
            activation: None,
            stride: 1,
            padding: 0,
            nonzero: 0,
            size: n,
        });
    }
    Model {
        manifest: ModelManifest {
            name: "lenet300".into(),
            task: "classify".into(),
            input_shape: vec![784],
            eval_batch: 1,
            n_classes: 10,
            param_count: 266_610,
            density: 0.1,
            dense_metric: 1.0,
            sparse_metric: 1.0,
            layers,
            hlo: String::new(),
            arg_order: Vec::new(),
        },
        weights,
        biases,
        sigmas,
    }
}

/// One client's local fine-tune: nudge `density` of the weights by a
/// small Laplacian step (later rounds shrink — convergence).
fn local_finetune(global: &Model, density: f64, scale: f64, rng: &mut SplitMix64) -> Model {
    let mut local = global.clone();
    for t in &mut local.weights {
        for v in &mut t.data {
            if rng.next_f64() < density {
                *v += rng.laplace(scale) as f32;
            }
        }
    }
    local
}

fn main() -> anyhow::Result<()> {
    let clients = 4;
    let rounds = 3;
    let update_density = 0.02; // 2% of weights touched per round
    let workers = 4;

    // λ = 0 keeps the quantizer nearest-neighbour, so a sparse weight
    // update stays sparse in level space and the residual coder sees
    // mostly zeros — the regime the delta format is built for.
    let spec = CompressionSpec { s: 40, lambda_scale: 0.0, ..Default::default() };
    let mut rng = SplitMix64::new(0xFED);

    let global = lenet_model(&mut rng);
    let (mut parent, _) = compress_model(&global, &spec, workers);
    println!(
        "federated delta uploads: {clients} clients x {rounds} rounds, \
         {} weights, {:.0}% touched/round",
        global.weight_count(),
        update_density * 100.0
    );
    println!(
        "global v0: full container {} (fingerprint {:016x})\n",
        human_bytes(parent.serialize().len()),
        fingerprint(&parent)
    );

    let mut table = Table::new(&[
        "round", "raw f32 (all clients)", "full containers", "delta uploads", "x vs full",
    ]);
    let (mut total_delta, mut total_full) = (0usize, 0usize);
    let mut global = global;
    for round in 0..rounds {
        let scale = 0.02 / (1.0 + round as f64);
        let (mut raw, mut full_sum, mut delta_sum) = (0usize, 0usize, 0usize);
        let mut adopted = None;
        for client in 0..clients {
            let local = local_finetune(&global, update_density, scale, &mut rng);
            raw += local.raw_bytes();
            let (full, delta, report) = encode_from_model(&parent, &local, &spec, workers)?;
            // the integrity contract of every upload: the server can
            // rebuild the client's exact container from base + delta
            let rebuilt = apply(&parent, &delta, workers)?;
            assert_eq!(
                rebuilt.serialize(),
                full.serialize(),
                "round {round} client {client}: delta did not reproduce the container"
            );
            full_sum += full.serialize().len();
            delta_sum += delta.total_bytes();
            if client == 0 {
                println!(
                    "  round {round} client 0: residual density {:.3}%, \
                     delta {} vs full {}",
                    report.residual_density() * 100.0,
                    human_bytes(delta.total_bytes()),
                    human_bytes(full.serialize().len()),
                );
                adopted = Some((local, full));
            }
        }
        total_delta += delta_sum;
        total_full += full_sum;
        table.row(vec![
            round.to_string(),
            human_bytes(raw),
            human_bytes(full_sum),
            human_bytes(delta_sum),
            format!("x{:.1}", full_sum as f64 / delta_sum.max(1) as f64),
        ]);
        // the server adopts client 0's model as the new global — the
        // next round's deltas chain off this fingerprint
        let (g, p) = adopted.expect("at least one client per round");
        global = g;
        parent = p;
        println!(
            "  round {round}: global advanced to fingerprint {:016x}",
            fingerprint(&parent)
        );
    }
    println!("\n{}", table.render());
    println!(
        "total upload over {rounds} rounds: {} as deltas vs {} as full containers \
         (x{:.1} saved)",
        human_bytes(total_delta),
        human_bytes(total_full),
        total_full as f64 / total_delta.max(1) as f64
    );
    Ok(())
}
