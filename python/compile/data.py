"""Deterministic synthetic datasets.

The paper evaluates on MNIST / CIFAR10 / ImageNet, none of which are
available in this offline environment. We substitute deterministic
synthetic datasets that exercise the identical train -> sparsify ->
compress -> evaluate code path (see DESIGN.md §5):

* ``synth_mnist``  — 1x28x28, 10 classes. Each class has a smooth random
  prototype (low-frequency Gaussian field); samples are prototype + noise
  + small random shift, so the task is learnable but not trivial.
* ``synth_cifar``  — 3x32x32, 10 classes, same construction.
* ``fcae_images``  — 3x32x32 natural-ish images (sums of random oriented
  sinusoids + Gaussian fields) for the autoencoder's PSNR task.

Everything is generated with ``numpy.random.Generator(PCG64(seed))`` so the
*same* bytes are produced on every run; the Rust side regenerates eval
batches with its own mirror of the label stream when needed.
"""

from __future__ import annotations

import numpy as np

# Fixed master seeds — also recorded in artifact manifests.
MNIST_SEED = 0xC0FFEE
CIFAR_SEED = 0xBEEF
FCAE_SEED = 0xFACADE


def _smooth_field(rng: np.random.Generator, c: int, h: int, w: int, cutoff: int) -> np.ndarray:
    """Low-frequency random field in [-1, 1], shape (c, h, w)."""
    spec = np.zeros((c, h, w), dtype=np.complex128)
    k = cutoff
    re = rng.standard_normal((c, k, k))
    im = rng.standard_normal((c, k, k))
    spec[:, :k, :k] = re + 1j * im
    field = np.fft.ifft2(spec, axes=(-2, -1)).real
    field /= np.abs(field).max(axis=(-2, -1), keepdims=True) + 1e-9
    return field.astype(np.float32)


def _prototype_dataset(
    n: int,
    seed: int,
    channels: int,
    size: int,
    n_classes: int = 10,
    noise: float = 0.35,
    cutoff: int = 4,
) -> tuple[np.ndarray, np.ndarray]:
    """Class-prototype + noise classification set.

    Returns (x, y) with x of shape (n, channels, size, size) in roughly
    [-1.5, 1.5] and int32 labels y of shape (n,).
    """
    rng = np.random.default_rng(seed)
    protos = _smooth_field(rng, n_classes * channels, size, size, cutoff)
    protos = protos.reshape(n_classes, channels, size, size)
    y = rng.integers(0, n_classes, size=n, dtype=np.int32)
    x = protos[y].copy()
    # Per-sample smooth distortion + white noise.
    distort = _smooth_field(rng, n * channels, size, size, cutoff=3).reshape(
        n, channels, size, size
    )
    x += 0.25 * distort
    x += noise * rng.standard_normal(x.shape).astype(np.float32)
    # Random small translation (+-2 px) via roll, per-sample.
    shifts = rng.integers(-2, 3, size=(n, 2))
    for i in range(n):
        x[i] = np.roll(x[i], (shifts[i, 0], shifts[i, 1]), axis=(-2, -1))
    return x.astype(np.float32), y


def synth_mnist(n: int, seed: int = MNIST_SEED) -> tuple[np.ndarray, np.ndarray]:
    """(n,1,28,28) images + 10-class labels."""
    return _prototype_dataset(n, seed, channels=1, size=28)


def synth_cifar(n: int, seed: int = CIFAR_SEED) -> tuple[np.ndarray, np.ndarray]:
    """(n,3,32,32) images + 10-class labels."""
    return _prototype_dataset(n, seed, channels=3, size=32)


def fcae_images(n: int, seed: int = FCAE_SEED) -> np.ndarray:
    """(n,3,32,32) images in [0,1] for the autoencoder task."""
    rng = np.random.default_rng(seed)
    h = w = 32
    imgs = np.zeros((n, 3, h, w), dtype=np.float32)
    yy, xx = np.mgrid[0:h, 0:w].astype(np.float32)
    for i in range(n):
        img = np.zeros((3, h, w), dtype=np.float32)
        # 4 random oriented sinusoids shared across channels w/ random gains.
        for _ in range(4):
            fx, fy = rng.uniform(-0.5, 0.5, size=2)
            phase = rng.uniform(0, 2 * np.pi)
            wave = np.sin(2 * np.pi * (fx * xx + fy * yy) / 8.0 + phase)
            gains = rng.uniform(0.1, 0.6, size=3).astype(np.float32)
            img += gains[:, None, None] * wave[None]
        img += 0.6 * _smooth_field(rng, 3, h, w, cutoff=5)
        lo, hi = img.min(), img.max()
        imgs[i] = (img - lo) / (hi - lo + 1e-9)
    return imgs


def train_eval_split(
    x: np.ndarray, y: np.ndarray | None, n_eval: int
) -> tuple[np.ndarray, np.ndarray | None, np.ndarray, np.ndarray | None]:
    """Deterministic head/tail split: last ``n_eval`` samples are eval."""
    xe, xt = x[-n_eval:], x[:-n_eval]
    if y is None:
        return xt, None, xe, None
    return xt, y[:-n_eval], xe, y[-n_eval:]
