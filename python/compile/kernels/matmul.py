"""Pallas tiled matmul + bias + activation (Layer 1).

TPU-shaped even though we execute under ``interpret=True`` on CPU (the
Mosaic custom-call emitted for real TPUs cannot run on the CPU PJRT
plugin — see DESIGN.md §Hardware-Adaptation):

* the grid is (M/bm, N/bn, K/bk); each (i, j) output tile accumulates
  over the k axis in VMEM, the canonical MXU-feeding schedule
  (bm = bn = 128 matches the 128x128 systolic array; bk = 128 keeps each
  operand tile at 64 KiB f32, comfortably inside the ~16 MiB VMEM budget
  with double buffering),
* accumulation is f32 (MXU accumulator width); outputs are f32,
* bias-add + activation are fused into the last k step so each output
  tile leaves VMEM exactly once.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Preferred tile sizes; shrunk to divisors for small operands.
#
# Two targets (env PALLAS_TARGET):
#   "tpu"            — 128x128x128: the MXU systolic shape, ~192 KiB of
#                      f32 operand tiles, double-buffers comfortably in
#                      the ~16 MiB VMEM. What a real TPU build uses.
#   "cpu-interpret"  — 2048x512x512 (default here): interpret mode pays
#                      ~ms *per grid step*, so on CPU we trade VMEM
#                      realism for a ~30x smaller grid. Numerics are
#                      identical (same kernel body, same f32 accumulate).
import os

if os.environ.get("PALLAS_TARGET", "cpu-interpret") == "tpu":
    BM, BN, BK = 128, 128, 128
else:
    BM, BN, BK = 2048, 512, 512


def _apply_act(y, activation):
    if activation == "relu":
        return jnp.maximum(y, 0.0)
    if activation == "sigmoid":
        return 1.0 / (1.0 + jnp.exp(-y))
    if activation in (None, "none"):
        return y
    raise ValueError(f"unknown activation {activation!r}")


def _kernel_bias(x_ref, w_ref, b_ref, o_ref, *, nk: int, activation):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _finish():
        o_ref[...] = _apply_act(o_ref[...] + b_ref[...][None, :], activation)


def _kernel_nobias(x_ref, w_ref, o_ref, *, nk: int, activation):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _finish():
        o_ref[...] = _apply_act(o_ref[...], activation)


def _tile(dim: int, pref: int) -> int:
    """Largest divisor of ``dim`` that is <= pref (keeps the grid exact)."""
    t = min(dim, pref)
    while dim % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("activation", "interpret"))
def matmul(x, w, b=None, activation: str | None = None, interpret: bool = True):
    """act(x @ w + b) with a Pallas tiled kernel.

    x: (M, K) f32, w: (K, N) f32, optional b: (N,) f32. Returns (M, N) f32.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {x.shape} @ {w.shape}"
    bm, bn, bk = _tile(m, BM), _tile(n, BN), _tile(k, BK)
    nk = k // bk

    if b is not None:
        kern = functools.partial(_kernel_bias, nk=nk, activation=activation)
        in_specs = [
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ]
        args = (x, w, b)
    else:
        kern = functools.partial(_kernel_nobias, nk=nk, activation=activation)
        in_specs = [
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ]
        args = (x, w)

    return pl.pallas_call(
        kern,
        grid=(m // bm, n // bn, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(*args)
