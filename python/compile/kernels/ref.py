"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness ground truth: pytest asserts
``assert_allclose(kernel(...), ref(...))`` over hypothesis-generated
shapes/dtypes. They are also the fast path used during training (the
Pallas kernels run under ``interpret=True`` on CPU, which is orders of
magnitude slower, so the trainer uses the oracles and the AOT artifacts
use the kernels — both are verified equal).
"""

from __future__ import annotations

import jax.numpy as jnp


def matmul_ref(x, w, b=None, activation: str | None = None):
    """y = act(x @ w + b). x: (M,K), w: (K,N), b: (N,) or None."""
    y = jnp.matmul(x, w, preferred_element_type=jnp.float32)
    if b is not None:
        y = y + b
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation == "sigmoid":
        y = 1.0 / (1.0 + jnp.exp(-y))
    elif activation not in (None, "none"):
        raise ValueError(f"unknown activation {activation!r}")
    return y.astype(x.dtype)


def rd_quantize_ref(w, eta, grid, rate, lam):
    """Blocked weighted rate-distortion argmin (paper eq. 1, frozen rates).

    w:    (n,) float32 weights
    eta:  (n,) float32 robustness weights (1/sigma^2)
    grid: (k,) float32 quantization points q_k
    rate: (k,) float32 bit-cost estimate R_k of each grid point (frozen
          context snapshot; the exact sequential coupling lives in Rust)
    lam:  scalar float lagrangian

    Returns (n,) int32 indices into grid.
    """
    cost = eta[:, None] * (w[:, None] - grid[None, :]) ** 2 + lam * rate[None, :]
    return jnp.argmin(cost, axis=1).astype(jnp.int32)


def conv2d_ref(x, w, b=None, stride: int = 1, padding: int = 0, activation=None):
    """NCHW conv. x: (N,C,H,W), w: (O,C,kh,kw), b: (O,)."""
    import jax

    y = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    if b is not None:
        y = y + b[None, :, None, None]
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation == "sigmoid":
        y = 1.0 / (1.0 + jnp.exp(-y))
    return y.astype(x.dtype)
