"""NCHW convolution = im2col (L2, plain jnp layout ops) + the Pallas
matmul kernel (L1). This mirrors the paper's footnote 1: conv tensors are
compressed in their cuDNN/im2col matrix form (Chetlur et al. 2014), and
on TPU the same im2col + MXU matmul is the natural schedule.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .matmul import matmul


def _im2col(x, kh: int, kw: int, stride: int, padding: int):
    """x: (N,C,H,W) -> patches (N*OH*OW, C*kh*kw), plus (OH, OW).

    Uses ``lax.conv_general_dilated_patches`` (an identity-kernel conv),
    which XLA lowers to an efficient extraction — hand-rolled nested
    gathers lowered catastrophically on CPU (30s+ per LeNet5 batch).
    """
    n, c, h, w = x.shape
    oh = (h + 2 * padding - kh) // stride + 1
    ow = (w + 2 * padding - kw) // stride + 1
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )  # (n, c*kh*kw, oh, ow)
    patches = patches.transpose(0, 2, 3, 1).reshape(n * oh * ow, c * kh * kw)
    return patches, oh, ow


@functools.partial(
    jax.jit, static_argnames=("stride", "padding", "activation", "interpret")
)
def conv2d(
    x,
    w,
    b=None,
    stride: int = 1,
    padding: int = 0,
    activation: str | None = None,
    interpret: bool = True,
):
    """NCHW conv via im2col + Pallas matmul.

    x: (N,C,H,W) f32, w: (O,C,kh,kw) f32, b: (O,) f32 or None.
    Returns (N,O,OH,OW) f32.
    """
    n, c, h, wdim = x.shape
    o, c2, kh, kw = w.shape
    assert c == c2, f"channel mismatch: {x.shape} vs {w.shape}"
    patches, oh, ow = _im2col(x, kh, kw, stride, padding)
    wmat = w.reshape(o, c * kh * kw).T  # (c*kh*kw, o)
    y = matmul(patches, wmat, b, activation=activation, interpret=interpret)
    return y.reshape(n, oh, ow, o).transpose(0, 3, 1, 2)
