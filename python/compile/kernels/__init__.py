"""Layer 1 — Pallas kernels (executed under ``interpret=True`` on CPU).

Exports:
    matmul       — tiled matmul + bias + activation (MXU-shaped)
    conv2d       — im2col + matmul kernel (NCHW)
    rd_quantize  — blocked weighted rate-distortion argmin (paper eq. 1)
    ref          — pure-jnp oracles for all of the above
"""

from . import ref  # noqa: F401
from .conv2d import conv2d  # noqa: F401
from .matmul import matmul  # noqa: F401
from .rd_quantize import rd_quantize  # noqa: F401
