"""Pallas blocked weighted rate-distortion argmin (Layer 1).

The paper's eq. 1 assigns each weight w_i the grid index

    k*(i) = argmin_k  eta_i (w_i - q_k)^2 + lambda * R_ik.

The *exact* DeepCABAC coupling updates the context models after every
weight, making R_ik position-dependent and the scan inherently
sequential — that exact version is the Rust hot path. This kernel is the
*blocked* variant used for candidate pre-selection and for the L1/L2
artifact path: the rate table is a frozen snapshot R_k of the context
states at block entry, so every weight in the block can be quantized in
parallel. With per-block snapshots the result differs from the exact scan
only where context drift within one block flips an argmin, which the
Rust pipeline corrects in its sequential pass.

TPU shaping: weights stream through VMEM in (8, 128)-multiple tiles
(VPU lanes — this kernel is element-wise + a K-reduction, no MXU); the
grid/rate tables (K <= 1024 entries, <8 KiB) are replicated into VMEM for
every block. The cost matrix tile is (BW, K) f32 = 1 MiB at BW=256,
K=1024 — three such tiles (cost, w, broadcast grid) fit VMEM with double
buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BW = 256  # weights per block


def _rdq_kernel(w_ref, eta_ref, grid_ref, rate_ref, lam_ref, o_ref):
    w = w_ref[...]  # (bw,)
    eta = eta_ref[...]  # (bw,)
    q = grid_ref[...]  # (k,)
    r = rate_ref[...]  # (k,)
    lam = lam_ref[0]
    d = w[:, None] - q[None, :]
    cost = eta[:, None] * (d * d) + lam * r[None, :]
    o_ref[...] = jnp.argmin(cost, axis=1).astype(jnp.int32)


def _tile(dim: int, pref: int) -> int:
    t = min(dim, pref)
    while dim % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("interpret",))
def rd_quantize(w, eta, grid, rate, lam, interpret: bool = True):
    """Blocked RD argmin.

    w, eta: (n,) f32; grid, rate: (k,) f32; lam: () or python float.
    Returns (n,) int32 grid indices.
    """
    (n,) = w.shape
    (k,) = grid.shape
    assert eta.shape == (n,) and rate.shape == (k,)
    bw = _tile(n, BW)
    lam_arr = jnp.asarray(lam, dtype=jnp.float32).reshape(1)

    return pl.pallas_call(
        _rdq_kernel,
        grid=(n // bw,),
        in_specs=[
            pl.BlockSpec((bw,), lambda i: (i,)),
            pl.BlockSpec((bw,), lambda i: (i,)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bw,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(w, eta, grid, rate, lam_arr)
