"""Layer 2 — JAX model zoo (the paper's small-model suite).

Each model is a pure function of an ordered parameter list, so the same
forward pass can be (a) trained fast with the pure-jnp oracle kernels,
(b) AOT-lowered with the Pallas kernels into an HLO artifact whose
*weights are runtime inputs* — the Rust coordinator feeds decompressed
weights plus a data batch and reads back logits, which is how the
accuracy columns of Table 1 are measured without Python on the hot path.

Models (paper §4):
    lenet300  — LeNet-300-100 MLP              (MNIST row)
    lenet5    — LeNet5 (Caffe variant)          (MNIST row)
    smallvgg  — Small-VGG16, channel-scaled 1/4 (CIFAR10 row; see DESIGN.md §5)
    fcae      — fully-convolutional autoencoder (CIFAR10 PSNR row)
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import conv2d as pallas_conv2d
from .kernels import matmul as pallas_matmul
from .kernels import ref

# ---------------------------------------------------------------------------
# Layer descriptors
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LayerSpec:
    """One parameterized layer. ``kind`` in {fc, conv}; pooling/reshape are
    captured by ``post`` ops so the spec list fully determines the net."""

    name: str
    kind: str  # "fc" | "conv"
    shape: tuple  # fc: (in, out); conv: (out, in, kh, kw)
    activation: str | None = None
    stride: int = 1
    padding: int = 0
    post: tuple = ()  # sequence of ("maxpool2",) / ("flatten",) / ("upsample2",)


@dataclass(frozen=True)
class ModelSpec:
    name: str
    input_shape: tuple  # per-sample, e.g. (1, 28, 28) or (784,)
    layers: tuple = field(default_factory=tuple)
    task: str = "classify"  # "classify" | "autoencode"
    n_classes: int = 10


def _vgg_cfg(scale: int = 4):
    """VGG16 conv plan (channel-scaled by 1/scale) for 32x32 inputs."""
    plan = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
            512, 512, 512, "M", 512, 512, 512, "M"]
    return [c if c == "M" else max(8, c // scale) for c in plan]


def _smallvgg_spec() -> ModelSpec:
    layers: list[LayerSpec] = []
    in_c = 3
    i = 0
    for c in _vgg_cfg():
        if c == "M":
            prev = layers[-1]
            layers[-1] = LayerSpec(
                prev.name, prev.kind, prev.shape, prev.activation, prev.stride,
                prev.padding, prev.post + (("maxpool2",),),
            )
            continue
        i += 1
        layers.append(LayerSpec(f"conv{i}", "conv", (c, in_c, 3, 3), "relu", 1, 1))
        in_c = c
    last_c = in_c
    prev = layers[-1]
    layers[-1] = LayerSpec(
        prev.name, prev.kind, prev.shape, prev.activation, prev.stride,
        prev.padding, prev.post + (("flatten",),),
    )
    layers.append(LayerSpec("fc1", "fc", (last_c, last_c), "relu"))
    layers.append(LayerSpec("fc2", "fc", (last_c, 10), None))
    return ModelSpec("smallvgg", (3, 32, 32), tuple(layers))


MODELS: dict[str, ModelSpec] = {
    "lenet300": ModelSpec(
        "lenet300",
        (784,),
        (
            LayerSpec("fc1", "fc", (784, 300), "relu"),
            LayerSpec("fc2", "fc", (300, 100), "relu"),
            LayerSpec("fc3", "fc", (100, 10), None),
        ),
    ),
    "lenet5": ModelSpec(
        "lenet5",
        (1, 28, 28),
        (
            LayerSpec("conv1", "conv", (20, 1, 5, 5), "relu", 1, 0, (("maxpool2",),)),
            LayerSpec("conv2", "conv", (50, 20, 5, 5), "relu", 1, 0,
                      (("maxpool2",), ("flatten",))),
            LayerSpec("fc1", "fc", (800, 500), "relu"),
            LayerSpec("fc2", "fc", (500, 10), None),
        ),
    ),
    "smallvgg": _smallvgg_spec(),
    "fcae": ModelSpec(
        "fcae",
        (3, 32, 32),
        (
            LayerSpec("enc1", "conv", (16, 3, 3, 3), "relu", 2, 1),
            LayerSpec("enc2", "conv", (32, 16, 3, 3), "relu", 2, 1),
            LayerSpec("bott", "conv", (32, 32, 3, 3), "relu", 1, 1, (("upsample2",),)),
            LayerSpec("dec1", "conv", (16, 32, 3, 3), "relu", 1, 1, (("upsample2",),)),
            LayerSpec("dec2", "conv", (3, 16, 3, 3), "sigmoid", 1, 1),
        ),
        task="autoencode",
    ),
}

# ---------------------------------------------------------------------------
# Parameter init / flattening
# ---------------------------------------------------------------------------


def init_params(spec: ModelSpec, seed: int = 0) -> dict[str, dict[str, jnp.ndarray]]:
    """He-initialised {layer: {"w": ..., "b": ...}} parameter dict."""
    rng = np.random.default_rng(seed)
    params = {}
    for layer in spec.layers:
        if layer.kind == "fc":
            fan_in = layer.shape[0]
            w = rng.standard_normal(layer.shape) * np.sqrt(2.0 / fan_in)
            b = np.zeros(layer.shape[1])
        else:
            o, c, kh, kw = layer.shape
            fan_in = c * kh * kw
            w = rng.standard_normal(layer.shape) * np.sqrt(2.0 / fan_in)
            b = np.zeros(o)
        params[layer.name] = {
            "w": jnp.asarray(w, dtype=jnp.float32),
            "b": jnp.asarray(b, dtype=jnp.float32),
        }
    return params


def flatten_params(spec: ModelSpec, params) -> list[jnp.ndarray]:
    """Deterministic (w, b) * layers ordering — the HLO argument order."""
    flat = []
    for layer in spec.layers:
        flat.append(params[layer.name]["w"])
        flat.append(params[layer.name]["b"])
    return flat


def unflatten_params(spec: ModelSpec, flat) -> dict:
    params = {}
    it = iter(flat)
    for layer in spec.layers:
        params[layer.name] = {"w": next(it), "b": next(it)}
    return params


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID"
    )


def _upsample2(x):
    return jnp.repeat(jnp.repeat(x, 2, axis=-2), 2, axis=-1)


def _post(x, ops):
    for op in ops:
        if op[0] == "maxpool2":
            x = _maxpool2(x)
        elif op[0] == "upsample2":
            x = _upsample2(x)
        elif op[0] == "flatten":
            x = x.reshape(x.shape[0], -1)
        else:
            raise ValueError(f"unknown post op {op}")
    return x


def forward(spec: ModelSpec, params, x, impl: str = "jnp"):
    """Run the model. ``impl`` selects the kernel implementation:
    "jnp" (training-speed oracle) or "pallas" (AOT artifact path)."""
    if impl == "pallas":
        mm = lambda x, w, b, act: pallas_matmul(x, w, b, activation=act)
        cv = lambda x, w, b, s, p, act: pallas_conv2d(
            x, w, b, stride=s, padding=p, activation=act
        )
    else:
        mm = lambda x, w, b, act: ref.matmul_ref(x, w, b, act)
        cv = lambda x, w, b, s, p, act: ref.conv2d_ref(x, w, b, s, p, act)

    for layer in spec.layers:
        p = params[layer.name]
        if layer.kind == "fc":
            if x.ndim > 2:
                x = x.reshape(x.shape[0], -1)
            x = mm(x, p["w"], p["b"], layer.activation)
        else:
            x = cv(x, p["w"], p["b"], layer.stride, layer.padding, layer.activation)
        x = _post(x, layer.post)
    return x


def forward_flat(spec: ModelSpec, flat_params, x, impl: str = "pallas"):
    """Forward with positional parameters — the AOT entry point."""
    return forward(spec, unflatten_params(spec, flat_params), x, impl=impl)


def param_count(spec: ModelSpec) -> int:
    n = 0
    for layer in spec.layers:
        n += int(np.prod(layer.shape))
        n += layer.shape[1] if layer.kind == "fc" else layer.shape[0]
    return n
