"""AOT artifact builder (the only Python entry point; runs at build time).

For every model in the suite this script:
  1. trains + sparsifies on the synthetic dataset (``train.run_recipe``),
  2. exports the sparse weights, per-weight posterior sigmas, and a held
     out eval set as ``.npy`` files + a JSON manifest,
  3. lowers the *Pallas* forward pass (weights as runtime inputs) to HLO
     **text** — not ``.serialize()``: jax >= 0.5 emits protos with 64-bit
     instruction ids that xla_extension 0.5.1 rejects; the text parser
     reassigns ids (see /opt/xla-example/README.md),
  4. lowers the blocked RD-quantize Pallas kernel to its own HLO artifact.

The Rust coordinator consumes ``artifacts/`` and never imports Python.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts [--quick]
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .kernels.rd_quantize import rd_quantize
from .model import MODELS, flatten_params, forward_flat, param_count
from .train import TrainConfig, run_recipe

EVAL_BATCH = 256

# Per-model training budgets (1-core CPU; --quick shrinks these for tests).
# kl_weight is tuned so the post-VD density lands near the paper's Table 1
# sparsity column (LeNet-300-100 9.05%, LeNet5 1.90%, Small-VGG16 7.57%,
# FCAE 55.69% — the FCAE row is barely sparse, hence the light KL).
CONFIGS: dict[str, TrainConfig] = {
    "lenet300": TrainConfig(steps_dense=400, steps_sparse=1000, batch=128,
                            kl_weight=4e-4),
    "lenet5": TrainConfig(steps_dense=300, steps_sparse=1100, batch=64,
                          kl_weight=2e-3),
    "smallvgg": TrainConfig(steps_dense=300, steps_sparse=900, batch=64,
                            kl_weight=5e-3, n_train=2048, n_eval=1024),
    "fcae": TrainConfig(steps_dense=400, steps_sparse=500, batch=64,
                        kl_weight=5e-5, n_train=2048, n_eval=1024),
}

RD_QUANT_N = 4096
RD_QUANT_K = 257


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_model(name: str, out_dir: Path, cfg: TrainConfig, log=print) -> dict:
    t0 = time.time()
    result = run_recipe(name, cfg, log=log)
    spec = result["spec"]
    mdir = out_dir / "models" / name
    mdir.mkdir(parents=True, exist_ok=True)

    layers_meta = []
    for layer in spec.layers:
        w = np.asarray(result["params"][layer.name]["w"], dtype=np.float32)
        b = np.asarray(result["params"][layer.name]["b"], dtype=np.float32)
        sig = np.asarray(result["sigmas"][layer.name], dtype=np.float32)
        np.save(mdir / f"{layer.name}.w.npy", w)
        np.save(mdir / f"{layer.name}.b.npy", b)
        np.save(mdir / f"{layer.name}.sigma.npy", sig)
        layers_meta.append(
            {
                "name": layer.name,
                "kind": layer.kind,
                "shape": list(layer.shape),
                "activation": layer.activation,
                "stride": layer.stride,
                "padding": layer.padding,
                "post": [list(p) for p in layer.post],
                "nonzero": int((w != 0).sum()),
                "size": int(w.size),
            }
        )

    xe = np.asarray(result["eval_x"], dtype=np.float32)
    np.save(mdir / "eval_x.npy", xe[: EVAL_BATCH * (len(xe) // EVAL_BATCH)])
    if result["eval_y"] is not None:
        ye = np.asarray(result["eval_y"], dtype=np.int32)
        np.save(mdir / "eval_y.npy", ye[: EVAL_BATCH * (len(ye) // EVAL_BATCH)])

    # --- HLO artifact: forward pass with weights as runtime inputs -------
    hdir = out_dir / "hlo"
    hdir.mkdir(parents=True, exist_ok=True)
    flat = flatten_params(spec, result["params"])
    arg_specs = [jax.ShapeDtypeStruct(p.shape, jnp.float32) for p in flat]
    x_spec = jax.ShapeDtypeStruct((EVAL_BATCH,) + spec.input_shape, jnp.float32)

    def fwd(*args):
        *params, x = args
        return (forward_flat(spec, list(params), x, impl="pallas"),)

    lowered = jax.jit(fwd).lower(*arg_specs, x_spec)
    hlo_path = hdir / f"{name}.fwd.hlo.txt"
    hlo_path.write_text(to_hlo_text(lowered))
    log(f"  [aot {name}] wrote {hlo_path} ({time.time() - t0:.1f}s total)")

    manifest = {
        "name": name,
        "task": spec.task,
        "input_shape": list(spec.input_shape),
        "eval_batch": EVAL_BATCH,
        "n_classes": spec.n_classes,
        "param_count": param_count(spec),
        "density": result["density"],
        "dense_metric": result["dense_metric"],
        "sparse_metric": result["sparse_metric"],
        "sparsifier": cfg.sparsifier,
        "layers": layers_meta,
        "hlo": f"hlo/{name}.fwd.hlo.txt",
        "arg_order": [f"{l.name}.{p}" for l in spec.layers for p in ("w", "b")]
        + ["eval_x"],
    }
    (mdir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    return manifest


def relower_hlo(name: str, out_dir: Path, log=print) -> None:
    """Regenerate only the HLO artifact for an already-trained model
    (used when the kernels/lowering change but weights are cached)."""
    spec = MODELS[name]
    hdir = out_dir / "hlo"
    hdir.mkdir(parents=True, exist_ok=True)
    arg_specs = []
    for layer in spec.layers:
        arg_specs.append(jax.ShapeDtypeStruct(tuple(layer.shape), jnp.float32))
        bdim = layer.shape[1] if layer.kind == "fc" else layer.shape[0]
        arg_specs.append(jax.ShapeDtypeStruct((bdim,), jnp.float32))
    x_spec = jax.ShapeDtypeStruct((EVAL_BATCH,) + spec.input_shape, jnp.float32)

    def fwd(*args):
        *params, x = args
        return (forward_flat(spec, list(params), x, impl="pallas"),)

    lowered = jax.jit(fwd).lower(*arg_specs, x_spec)
    path = hdir / f"{name}.fwd.hlo.txt"
    path.write_text(to_hlo_text(lowered))
    log(f"  [aot {name}] re-lowered {path}")


def export_rd_quantize_kernel(out_dir: Path, log=print):
    """Standalone HLO artifact of the L1 blocked RD-argmin kernel."""
    hdir = out_dir / "kernels"
    hdir.mkdir(parents=True, exist_ok=True)
    n, k = RD_QUANT_N, RD_QUANT_K

    def fn(w, eta, grid, rate, lam):
        return (rd_quantize(w, eta, grid, rate, lam),)

    specs = [
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
        jax.ShapeDtypeStruct((k,), jnp.float32),
        jax.ShapeDtypeStruct((k,), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
    ]
    lowered = jax.jit(fn).lower(*specs)
    path = hdir / f"rd_quantize_n{n}_k{k}.hlo.txt"
    path.write_text(to_hlo_text(lowered))
    meta = {"n": n, "k": k, "hlo": f"kernels/{path.name}",
            "args": ["w", "eta", "grid", "rate", "lam"]}
    (hdir / "rd_quantize.json").write_text(json.dumps(meta, indent=2))
    log(f"  [aot] wrote {path}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", nargs="*", default=list(CONFIGS))
    ap.add_argument("--quick", action="store_true",
                    help="tiny training budgets (CI / pytest)")
    ap.add_argument("--force", action="store_true",
                    help="retrain even if the manifest already exists")
    ap.add_argument("--relower", action="store_true",
                    help="regenerate HLO artifacts for cached models")
    args = ap.parse_args()

    out_dir = Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    summaries = []
    for name in args.models:
        cfg = CONFIGS[name]
        if args.quick:
            cfg = TrainConfig(
                steps_dense=40, steps_sparse=40, batch=32,
                n_train=512, n_eval=256, sparsifier=cfg.sparsifier,
            )
        mpath = out_dir / "models" / name / "manifest.json"
        if mpath.exists() and not args.force:
            print(f"[aot] {name}: cached ({mpath})")
            if args.relower:
                relower_hlo(name, out_dir)
            summaries.append(json.loads(mpath.read_text()))
            continue
        print(f"[aot] building {name} ...")
        summaries.append(export_model(name, out_dir, cfg))

    export_rd_quantize_kernel(out_dir)
    (out_dir / "manifest.json").write_text(
        json.dumps({"models": [s["name"] for s in summaries],
                    "eval_batch": EVAL_BATCH}, indent=2)
    )
    print("[aot] done:", ", ".join(s["name"] for s in summaries))


if __name__ == "__main__":
    main()
