"""Build-time training + sparsification (paper §4 recipes).

Two-phase recipe per model:

  Phase 1 — dense training (Adam, cross-entropy or MSE).
  Phase 2 — sparsification, one of:
    * ``vd``  — sparse variational dropout (Molchanov et al., 2017): each
      weight tensor gets (theta, log_sigma2); training adds the Molchanov
      KL approximation; weights with log_alpha > TAU are pruned; the
      posterior std sigma_i = exp(0.5 * log_sigma2_i) becomes the paper's
      robustness parameter (eta_i = 1/sigma_i^2 in eq. 1).
    * ``magnitude`` — iterative magnitude pruning (Han et al., 2015b)
      followed by variance-only VD (means frozen) to estimate sigma —
      the paper's recipe for VGG16/ResNet50.

Runs once at artifact build time on the synthetic datasets; never on the
Rust request path.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import data as datamod
from .model import MODELS, ModelSpec, forward, init_params

# Molchanov et al. (2017) KL approximation constants.
_K1, _K2, _K3 = 0.63576, 1.87320, 1.48695
LOG_ALPHA_THRESH = 3.0


@dataclass
class TrainConfig:
    steps_dense: int = 400
    steps_sparse: int = 400
    batch: int = 96
    lr: float = 1e-3
    kl_weight: float = 1e-4  # scaled by 1/n_train implicitly via loss mean
    seed: int = 0
    n_train: int = 4096
    n_eval: int = 1024
    sparsifier: str = "vd"  # "vd" | "magnitude"
    prune_fraction: float = 0.9  # for magnitude pruning


# ---------------------------------------------------------------------------
# Adam (hand-rolled; no optax in the offline env)
# ---------------------------------------------------------------------------


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_step(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree_util.tree_map(lambda m: m / (1 - b1**t), m)
    vh = jax.tree_util.tree_map(lambda v: v / (1 - b2**t), v)
    params = jax.tree_util.tree_map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mh, vh
    )
    return params, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# Losses / metrics
# ---------------------------------------------------------------------------


def cross_entropy(logits, y):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def accuracy(logits, y):
    return jnp.mean((jnp.argmax(logits, axis=1) == y).astype(jnp.float32))


def psnr(x_hat, x):
    mse = jnp.mean((x_hat - x) ** 2)
    return -10.0 * jnp.log10(mse + 1e-12)


def task_loss(spec: ModelSpec, params, xb, yb):
    out = forward(spec, params, xb, impl="jnp")
    if spec.task == "classify":
        return cross_entropy(out, yb)
    return jnp.mean((out - xb) ** 2)


# ---------------------------------------------------------------------------
# Data plumbing
# ---------------------------------------------------------------------------


def load_dataset(spec: ModelSpec, cfg: TrainConfig):
    n = cfg.n_train + cfg.n_eval
    if spec.name in ("lenet300", "lenet5"):
        x, y = datamod.synth_mnist(n)
        if spec.name == "lenet300":
            x = x.reshape(n, -1)
    elif spec.name == "smallvgg":
        x, y = datamod.synth_cifar(n)
    elif spec.name == "fcae":
        x, y = datamod.fcae_images(n), None
    else:
        raise ValueError(spec.name)
    return datamod.train_eval_split(x, y, cfg.n_eval)


def _batches(rng: np.random.Generator, n: int, batch: int):
    while True:
        idx = rng.permutation(n)
        for i in range(0, n - batch + 1, batch):
            yield idx[i : i + batch]


# ---------------------------------------------------------------------------
# Phase 1 — dense training
# ---------------------------------------------------------------------------


def train_dense(spec: ModelSpec, cfg: TrainConfig, xt, yt, log=print):
    params = init_params(spec, seed=cfg.seed)
    opt = adam_init(params)
    loss_grad = jax.jit(jax.value_and_grad(lambda p, xb, yb: task_loss(spec, p, xb, yb)))
    rng = np.random.default_rng(cfg.seed + 1)
    bgen = _batches(rng, xt.shape[0], cfg.batch)
    losses = []
    for step in range(cfg.steps_dense):
        idx = next(bgen)
        xb = jnp.asarray(xt[idx])
        yb = jnp.asarray(yt[idx]) if yt is not None else None
        loss, grads = loss_grad(params, xb, yb)
        params, opt = adam_step(params, grads, opt, cfg.lr)
        losses.append(float(loss))
        if step % 100 == 0 or step == cfg.steps_dense - 1:
            log(f"  [dense {spec.name}] step {step:4d} loss {loss:.4f}")
    return params, losses


# ---------------------------------------------------------------------------
# Phase 2a — sparse variational dropout (Molchanov et al. 2017)
# ---------------------------------------------------------------------------


def _kl_vd(log_alpha):
    """Negative ELBO KL term per weight (to *minimize*)."""
    neg_kl = (
        _K1 * jax.nn.sigmoid(_K2 + _K3 * log_alpha)
        - 0.5 * jnp.log1p(jnp.exp(-log_alpha))
        - _K1
    )
    return -neg_kl


def vd_init(params, init_log_sigma2: float = -8.0):
    vd = {}
    for lname, p in params.items():
        vd[lname] = {
            "theta": p["w"],
            "log_sigma2": jnp.full_like(p["w"], init_log_sigma2),
            "b": p["b"],
        }
    return vd


def vd_log_alpha(vd_layer):
    theta = vd_layer["theta"]
    return jnp.clip(
        vd_layer["log_sigma2"] - jnp.log(theta * theta + 1e-12), -10.0, 10.0
    )


def vd_forward_params(vd, key, sample: bool):
    """Reparameterized sample w = theta + sigma * eps (additive noise)."""
    params = {}
    for i, (lname, layer) in enumerate(vd.items()):
        theta = layer["theta"]
        if sample:
            sigma = jnp.exp(0.5 * layer["log_sigma2"])
            eps = jax.random.normal(jax.random.fold_in(key, i), theta.shape)
            w = theta + sigma * eps
        else:
            w = theta
        params[lname] = {"w": w, "b": layer["b"]}
    return params


def train_vd(spec: ModelSpec, cfg: TrainConfig, params, xt, yt, log=print,
             freeze_means: bool = False):
    """Phase 2: VD fine-tuning. ``freeze_means=True`` is the paper's
    variance-only recipe used after magnitude pruning (VGG16/ResNet50)."""
    vd = vd_init(params)
    opt = adam_init(vd)

    def loss_fn(vd, key, xb, yb):
        p = vd_forward_params(vd, key, sample=True)
        tloss = task_loss(spec, p, xb, yb)
        kl = 0.0
        nw = 0
        for lname in vd:
            la = vd_log_alpha(vd[lname])
            kl = kl + jnp.sum(_kl_vd(la))
            nw += la.size
        return tloss + cfg.kl_weight * kl / nw * 1000.0

    loss_grad = jax.jit(jax.value_and_grad(loss_fn))
    rng = np.random.default_rng(cfg.seed + 2)
    bgen = _batches(rng, xt.shape[0], cfg.batch)
    key = jax.random.PRNGKey(cfg.seed)
    losses = []
    mask_frozen = None
    if freeze_means:
        mask_frozen = {ln: vd[ln]["theta"] for ln in vd}
    for step in range(cfg.steps_sparse):
        idx = next(bgen)
        xb = jnp.asarray(xt[idx])
        yb = jnp.asarray(yt[idx]) if yt is not None else None
        key, sub = jax.random.split(key)
        loss, grads = loss_grad(vd, sub, xb, yb)
        vd, opt = adam_step(vd, grads, opt, cfg.lr * 0.5)
        if freeze_means:
            for ln in vd:
                vd[ln]["theta"] = mask_frozen[ln]
        losses.append(float(loss))
        if step % 100 == 0 or step == cfg.steps_sparse - 1:
            log(f"  [vd {spec.name}] step {step:4d} loss {loss:.4f}")
    return vd, losses


def vd_extract(vd, thresh: float = LOG_ALPHA_THRESH):
    """Prune by log_alpha and return (params, sigmas, sparsity).

    sigma for pruned weights is set to the posterior std as well — the
    quantizer uses sigma_min over *kept* weights for the grid (eq. 2) and
    eta = 1/sigma^2 everywhere.
    """
    params, sigmas = {}, {}
    kept = 0
    total = 0
    for lname, layer in vd.items():
        la = vd_log_alpha(layer)
        mask = (la < thresh).astype(jnp.float32)
        w = layer["theta"] * mask
        sigma = jnp.exp(0.5 * layer["log_sigma2"])
        params[lname] = {"w": w, "b": layer["b"]}
        sigmas[lname] = sigma
        kept += int(jnp.sum(mask))
        total += mask.size
    return params, sigmas, kept / max(total, 1)


# ---------------------------------------------------------------------------
# Phase 2b — magnitude pruning (Han et al. 2015b)
# ---------------------------------------------------------------------------


def magnitude_prune(params, fraction: float):
    """Zero the smallest-|w| ``fraction`` of weights, globally per layer."""
    pruned = {}
    for lname, p in params.items():
        w = p["w"]
        k = int(np.floor(fraction * w.size))
        if k > 0:
            thresh = jnp.sort(jnp.abs(w).ravel())[k - 1]
            mask = (jnp.abs(w) > thresh).astype(jnp.float32)
        else:
            mask = jnp.ones_like(w)
        pruned[lname] = {"w": w * mask, "b": p["b"]}
    return pruned


def retrain_masked(spec: ModelSpec, cfg: TrainConfig, params, xt, yt, steps, log=print):
    """Fine-tune surviving weights with the zero mask held fixed."""
    masks = {ln: (params[ln]["w"] != 0).astype(jnp.float32) for ln in params}
    opt = adam_init(params)
    loss_grad = jax.jit(jax.value_and_grad(lambda p, xb, yb: task_loss(spec, p, xb, yb)))
    rng = np.random.default_rng(cfg.seed + 3)
    bgen = _batches(rng, xt.shape[0], cfg.batch)
    for step in range(steps):
        idx = next(bgen)
        xb = jnp.asarray(xt[idx])
        yb = jnp.asarray(yt[idx]) if yt is not None else None
        loss, grads = loss_grad(params, xb, yb)
        params, opt = adam_step(params, grads, opt, cfg.lr * 0.3)
        for ln in params:
            params[ln]["w"] = params[ln]["w"] * masks[ln]
        if step % 100 == 0 or step == steps - 1:
            log(f"  [mag-retrain {spec.name}] step {step:4d} loss {loss:.4f}")
    return params


# ---------------------------------------------------------------------------
# Full recipe
# ---------------------------------------------------------------------------


def evaluate(spec: ModelSpec, params, xe, ye, batch: int = 256):
    outs = []
    for i in range(0, xe.shape[0], batch):
        outs.append(forward(spec, params, jnp.asarray(xe[i : i + batch]), impl="jnp"))
    out = jnp.concatenate(outs)
    if spec.task == "classify":
        return float(accuracy(out, jnp.asarray(ye)))
    return float(psnr(out, jnp.asarray(xe)))


def run_recipe(name: str, cfg: TrainConfig, log=print):
    """Train + sparsify one model; returns everything aot.py exports."""
    spec = MODELS[name]
    xt, yt, xe, ye = load_dataset(spec, cfg)
    params, dense_losses = train_dense(spec, cfg, xt, yt, log=log)
    dense_metric = evaluate(spec, params, xe, ye)
    log(f"  [dense {name}] eval metric {dense_metric:.4f}")

    if cfg.sparsifier == "vd":
        vd, sparse_losses = train_vd(spec, cfg, params, xt, yt, log=log)
        sparams, sigmas, density = vd_extract(vd)
    else:
        params = magnitude_prune(params, cfg.prune_fraction)
        params = retrain_masked(spec, cfg, params, xt, yt, cfg.steps_sparse // 2, log=log)
        vd, sparse_losses = train_vd(
            spec, cfg, params, xt, yt, log=log, freeze_means=True
        )
        sparams, sigmas, _ = vd_extract(vd, thresh=np.inf)  # keep mask from pruning
        for ln in sparams:  # re-apply the magnitude mask (means were frozen)
            mask = (params[ln]["w"] != 0).astype(jnp.float32)
            sparams[ln]["w"] = sparams[ln]["w"] * mask
        total = sum(int(sparams[ln]["w"].size) for ln in sparams)
        kept = sum(int(jnp.sum(sparams[ln]["w"] != 0)) for ln in sparams)
        density = kept / total

    sparse_metric = evaluate(spec, sparams, xe, ye)
    log(f"  [{cfg.sparsifier} {name}] density {density:.4f} eval {sparse_metric:.4f}")
    return {
        "spec": spec,
        "params": sparams,
        "sigmas": sigmas,
        "density": density,
        "dense_metric": dense_metric,
        "sparse_metric": sparse_metric,
        "dense_losses": dense_losses,
        "sparse_losses": sparse_losses,
        "eval_x": xe,
        "eval_y": ye,
    }
