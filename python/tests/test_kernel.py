"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (and the rd-quantize parameter space); each
kernel must match the oracle to float tolerance — this is the CORE
correctness signal for the AOT artifacts the Rust side executes.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv2d, matmul, rd_quantize, ref

RNG = np.random.default_rng(1234)


def _randf(*shape):
    return RNG.standard_normal(shape).astype(np.float32)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    bias=st.booleans(),
    act=st.sampled_from([None, "relu", "sigmoid"]),
)
def test_matmul_matches_ref(m, k, n, bias, act):
    x, w = _randf(m, k), _randf(k, n)
    b = _randf(n) if bias else None
    got = np.asarray(matmul(x, w, b, activation=act))
    want = np.asarray(ref.matmul_ref(x, w, b, act))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_matmul_tiled_path_exact_blocks():
    # shapes that are exact multiples of the 128 tiles
    x, w, b = _randf(256, 128), _randf(128, 256), _randf(256)
    np.testing.assert_allclose(
        np.asarray(matmul(x, w, b, activation="relu")),
        np.asarray(ref.matmul_ref(x, w, b, "relu")),
        rtol=1e-4,
        atol=1e-5,
    )


def test_matmul_rejects_mismatched_inner_dim():
    with pytest.raises(AssertionError):
        matmul(_randf(4, 5), _randf(6, 7))


# ---------------------------------------------------------------------------
# conv2d
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 3),
    c=st.integers(1, 4),
    o=st.integers(1, 6),
    hw=st.integers(5, 14),
    kk=st.sampled_from([1, 3, 5]),
    stride=st.sampled_from([1, 2]),
    padding=st.sampled_from([0, 1, 2]),
)
def test_conv2d_matches_ref(n, c, o, hw, kk, stride, padding):
    if hw + 2 * padding < kk:
        return
    x, w, b = _randf(n, c, hw, hw), _randf(o, c, kk, kk), _randf(o)
    got = np.asarray(conv2d(x, w, b, stride=stride, padding=padding, activation="relu"))
    want = np.asarray(ref.conv2d_ref(x, w, b, stride, padding, "relu"))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


def test_conv2d_shape():
    y = conv2d(_randf(2, 3, 8, 8), _randf(5, 3, 3, 3), None, stride=2, padding=1)
    assert y.shape == (2, 5, 4, 4)


# ---------------------------------------------------------------------------
# rd_quantize
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 600),
    k=st.integers(2, 80),
    lam=st.floats(0.0, 5.0),
)
def test_rd_quantize_matches_ref(n, k, lam):
    w = _randf(n)
    eta = np.abs(_randf(n)) + 0.05
    grid = np.sort(_randf(k))
    rate = np.abs(_randf(k)) * 8.0
    got = np.asarray(rd_quantize(w, eta, grid, rate, lam))
    want = np.asarray(ref.rd_quantize_ref(w, eta, grid, rate, lam))
    np.testing.assert_array_equal(got, want)


def test_rd_quantize_zero_lambda_is_weighted_nearest():
    """With lam=0 the argmin is pure weighted distortion = nearest point."""
    w = _randf(512)
    eta = np.abs(_randf(512)) + 0.1
    grid = np.linspace(-3, 3, 33).astype(np.float32)
    rate = np.abs(_randf(33)).astype(np.float32)
    idx = np.asarray(rd_quantize(w, eta, grid, rate, 0.0))
    nearest = np.argmin((w[:, None] - grid[None, :]) ** 2, axis=1)
    np.testing.assert_array_equal(idx, nearest)


def test_rd_quantize_huge_lambda_picks_cheapest():
    """lam -> inf forces every weight to the cheapest grid point."""
    w = _randf(256)
    eta = np.ones(256, dtype=np.float32)
    grid = np.linspace(-1, 1, 17).astype(np.float32)
    rate = np.abs(_randf(17)) + 0.1
    rate[5] = 0.001
    idx = np.asarray(rd_quantize(w, eta, grid, rate.astype(np.float32), 1e9))
    assert (idx == 5).all()
