"""AOT artifact invariants: HLO text is loadable-shaped, manifests are
consistent, and the lowering path (Pallas kernels under jit) is stable."""

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import to_hlo_text, RD_QUANT_K, RD_QUANT_N
from compile.kernels.rd_quantize import rd_quantize
from compile.model import MODELS, flatten_params, forward_flat, init_params

ARTIFACTS = Path(__file__).resolve().parents[2] / "artifacts"


def test_to_hlo_text_emits_entry():
    def fn(x):
        return (x * 2.0 + 1.0,)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((4,), jnp.float32))
    text = to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "f32[4]" in text


def test_forward_flat_lowers_with_pallas():
    """The exact lowering path aot.py uses must trace cleanly."""
    spec = MODELS["lenet300"]
    params = init_params(spec, seed=0)
    flat = flatten_params(spec, params)
    arg_specs = [jax.ShapeDtypeStruct(p.shape, jnp.float32) for p in flat]
    x_spec = jax.ShapeDtypeStruct((8,) + spec.input_shape, jnp.float32)

    def fwd(*args):
        *ps, x = args
        return (forward_flat(spec, list(ps), x, impl="pallas"),)

    text = to_hlo_text(jax.jit(fwd).lower(*arg_specs, x_spec))
    assert "ENTRY" in text


def test_rd_quantize_kernel_lowering_shape():
    def fn(w, eta, grid, rate, lam):
        return (rd_quantize(w, eta, grid, rate, lam),)

    specs = [
        jax.ShapeDtypeStruct((RD_QUANT_N,), jnp.float32),
        jax.ShapeDtypeStruct((RD_QUANT_N,), jnp.float32),
        jax.ShapeDtypeStruct((RD_QUANT_K,), jnp.float32),
        jax.ShapeDtypeStruct((RD_QUANT_K,), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
    ]
    text = to_hlo_text(jax.jit(fn).lower(*specs))
    assert f"s32[{RD_QUANT_N}]" in text


@pytest.mark.skipif(not ARTIFACTS.exists(), reason="run `make artifacts` first")
def test_built_artifacts_are_consistent():
    manifest = json.loads((ARTIFACTS / "manifest.json").read_text())
    for name in manifest["models"]:
        mdir = ARTIFACTS / "models" / name
        m = json.loads((mdir / "manifest.json").read_text())
        assert m["name"] == name
        # every layer's files exist and shapes match the manifest
        for layer in m["layers"]:
            w = np.load(mdir / f"{layer['name']}.w.npy")
            s = np.load(mdir / f"{layer['name']}.sigma.npy")
            assert list(w.shape) == layer["shape"]
            assert w.shape == s.shape
            assert w.dtype == np.float32
            assert int((w != 0).sum()) == layer["nonzero"]
        # HLO exists and is text
        hlo = (ARTIFACTS / m["hlo"]).read_text()
        assert "ENTRY" in hlo
        # eval set is batch-aligned
        x = np.load(mdir / "eval_x.npy")
        assert x.shape[0] % m["eval_batch"] == 0
        # density column is reproducible from the tensors
        nz = sum(l["nonzero"] for l in m["layers"])
        tot = sum(l["size"] for l in m["layers"])
        assert abs(nz / tot - m["density"]) < 1e-6
