"""L2 model invariants: shapes, jnp-vs-pallas equivalence, flattening."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    MODELS,
    flatten_params,
    forward,
    forward_flat,
    init_params,
    param_count,
    unflatten_params,
)

RNG = np.random.default_rng(7)


def _batch(spec, n=2):
    return jnp.asarray(RNG.standard_normal((n,) + spec.input_shape).astype(np.float32))


@pytest.mark.parametrize("name", list(MODELS))
def test_forward_shapes(name):
    spec = MODELS[name]
    params = init_params(spec, seed=1)
    out = forward(spec, params, _batch(spec), impl="jnp")
    if spec.task == "classify":
        assert out.shape == (2, spec.n_classes)
    else:
        assert out.shape == (2,) + spec.input_shape


@pytest.mark.parametrize("name", list(MODELS))
def test_pallas_equals_jnp(name):
    """The AOT (Pallas) path must match the training (jnp) path — this is
    what makes the Rust-side accuracy measurements valid."""
    spec = MODELS[name]
    params = init_params(spec, seed=2)
    x = _batch(spec)
    got = np.asarray(forward(spec, params, x, impl="pallas"))
    want = np.asarray(forward(spec, params, x, impl="jnp"))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("name", list(MODELS))
def test_flatten_roundtrip(name):
    spec = MODELS[name]
    params = init_params(spec, seed=3)
    flat = flatten_params(spec, params)
    assert len(flat) == 2 * len(spec.layers)
    rec = unflatten_params(spec, flat)
    for lname in params:
        np.testing.assert_array_equal(params[lname]["w"], rec[lname]["w"])


@pytest.mark.parametrize("name", list(MODELS))
def test_forward_flat_matches_dict(name):
    spec = MODELS[name]
    params = init_params(spec, seed=4)
    x = _batch(spec)
    a = np.asarray(forward_flat(spec, flatten_params(spec, params), x, impl="jnp"))
    b = np.asarray(forward(spec, params, x, impl="jnp"))
    np.testing.assert_array_equal(a, b)


def test_param_counts():
    # LeNet-300-100: 784*300+300 + 300*100+100 + 100*10+10 = 266610
    assert param_count(MODELS["lenet300"]) == 266_610
    # LeNet5-Caffe: conv 20*1*25+20, 50*20*25+50, fc 800*500+500, 500*10+10
    assert param_count(MODELS["lenet5"]) == 20 * 25 + 20 + 50 * 20 * 25 + 50 + 800 * 500 + 500 + 500 * 10 + 10
