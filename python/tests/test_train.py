"""Training/sparsification sanity (small budgets — CI-sized)."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import MODELS, init_params
from compile.train import (
    TrainConfig,
    adam_init,
    adam_step,
    cross_entropy,
    load_dataset,
    magnitude_prune,
    run_recipe,
    train_dense,
    vd_extract,
    vd_init,
    vd_log_alpha,
)


def _quick_cfg(**kw):
    base = dict(steps_dense=30, steps_sparse=30, batch=32, n_train=256, n_eval=128)
    base.update(kw)
    return TrainConfig(**base)


def test_adam_decreases_quadratic():
    params = {"x": jnp.array([5.0, -3.0])}
    state = adam_init(params)
    for _ in range(200):
        grads = {"x": 2 * params["x"]}
        params, state = adam_step(params, grads, state, 0.1)
    assert float(jnp.abs(params["x"]).max()) < 0.2


def test_cross_entropy_perfect_prediction():
    logits = jnp.array([[10.0, -10.0], [-10.0, 10.0]])
    y = jnp.array([0, 1])
    assert float(cross_entropy(logits, y)) < 1e-3


def test_dense_training_reduces_loss():
    cfg = _quick_cfg()
    spec = MODELS["lenet300"]
    xt, yt, _, _ = load_dataset(spec, cfg)
    _, losses = train_dense(spec, cfg, xt, yt, log=lambda *a: None)
    early = np.mean(losses[:5])
    late = np.mean(losses[-5:])
    assert late < early * 0.7, f"{early} -> {late}"


def test_magnitude_prune_fraction():
    params = init_params(MODELS["lenet300"], seed=0)
    pruned = magnitude_prune(params, 0.9)
    for lname in pruned:
        w = np.asarray(pruned[lname]["w"])
        assert (w == 0).mean() >= 0.89


def test_vd_log_alpha_shapes_and_extract():
    params = init_params(MODELS["lenet300"], seed=1)
    vd = vd_init(params)
    la = vd_log_alpha(vd["fc1"])
    assert la.shape == params["fc1"]["w"].shape
    # log_sigma2 = -8 with typical theta ~ 0.05 gives log_alpha << 3, but
    # near-zero He-init weights already exceed the threshold — only a few
    # percent should be pruned at init
    sparams, sigmas, density = vd_extract(vd)
    assert density > 0.9
    assert sigmas["fc1"].shape == params["fc1"]["w"].shape
    assert float(sigmas["fc1"].min()) > 0


@pytest.mark.slow
def test_run_recipe_vd_sparsifies():
    r = run_recipe("lenet300", _quick_cfg(steps_sparse=120, kl_weight=1e-3),
                   log=lambda *a: None)
    assert r["density"] < 0.95
    assert r["sparse_metric"] > 0.5  # still classifies synthetic digits
    assert set(r["sigmas"]) == set(r["params"])
