"""Synthetic dataset invariants: determinism, shape, learnability proxy."""

import numpy as np

from compile import data


def test_mnist_shapes_and_determinism():
    x1, y1 = data.synth_mnist(64)
    x2, y2 = data.synth_mnist(64)
    assert x1.shape == (64, 1, 28, 28) and y1.shape == (64,)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    assert x1.dtype == np.float32 and y1.dtype == np.int32


def test_cifar_shapes():
    x, y = data.synth_cifar(16)
    assert x.shape == (16, 3, 32, 32)
    assert set(np.unique(y)).issubset(set(range(10)))


def test_fcae_images_range():
    x = data.fcae_images(8)
    assert x.shape == (8, 3, 32, 32)
    assert x.min() >= 0.0 and x.max() <= 1.0


def test_classes_are_separable():
    """Nearest-prototype classification must beat chance by a wide margin —
    otherwise the accuracy columns of Table 1 are meaningless."""
    x, y = data.synth_mnist(512)
    protos = np.stack([x[y == c].mean(axis=0) for c in range(10)])
    d = ((x[:, None] - protos[None]) ** 2).sum(axis=(2, 3, 4))
    acc = (d.argmin(axis=1) == y).mean()
    assert acc > 0.8, f"prototype accuracy only {acc:.2f}"


def test_split_is_head_tail():
    x, y = data.synth_mnist(100)
    xt, yt, xe, ye = data.train_eval_split(x, y, 30)
    assert xt.shape[0] == 70 and xe.shape[0] == 30
    np.testing.assert_array_equal(xe, x[-30:])
