#!/usr/bin/env bash
# Progressive-container smoke test, run by CI from the rust/ directory:
#   1. sweep --progressive chains frontier points into one .dcbc v4
#      container, writing the standalone per-tier containers next to it
#   2. `materialize` at every tier must be byte-identical to the
#      standalone container the encoder was given for that tier
#   3. serve the progressive container; `fetch --tier 0` must yield a
#      decodable model from a strict byte prefix, and `fetch --upgrade`
#      must extend that prefix to the full container byte-for-byte
#   4. size gate: the progressive container (sum of tiers) must be
#      <= 115% of the finest standalone container
#   5. BENCH_progressive.json is left for upload
set -euo pipefail

BIN=${BIN:-target/release/deepcabac}
WORK=$(mktemp -d)
mkdir -p "$WORK/models" "$WORK/tiers"

cleanup() {
  [ -n "${SERVER_PID:-}" ] && kill "$SERVER_PID" 2>/dev/null || true
  rm -rf "$WORK"
}
trap cleanup EXIT

echo "== progressive sweep: chain frontier points into a v4 container =="
"$BIN" sweep --arch mobilenet --scale 16 --points 9 --workers 4 --chunks 4 \
  --progressive --tiers 3 \
  --out "$WORK/models/mobilenet.dcbc" --out-tiers "$WORK/tiers"
N_TIERS=$(ls "$WORK/tiers" | wc -l)
echo "sweep produced $N_TIERS tiers"
[ "$N_TIERS" -ge 2 ] || { echo "expected >= 2 tiers from the frontier"; exit 1; }

echo "== materialize each tier vs its standalone container =="
for t in $(seq 0 $((N_TIERS - 1))); do
  "$BIN" materialize --in "$WORK/models/mobilenet.dcbc" --tier "$t" \
    --out "$WORK/mat_$t.dcbc" --workers 4
  cmp "$WORK/mat_$t.dcbc" "$WORK/tiers/tier_$t.dcbc"
done
echo "all $N_TIERS tiers materialize byte-identical to the standalone containers"

echo "== size gate: sum of tiers <= 115% of the finest standalone =="
python3 - <<'EOF'
import json
j = json.load(open("BENCH_progressive.json"))
ratio = j["overhead_ratio"]
assert ratio <= 1.15, (
    f"progressive container is {ratio:.1%} of the finest standalone (want <= 115%)"
)
print(f"progressive overhead {ratio:.1%} of the finest standalone "
      f"({int(j['progressive_bytes'])} vs {int(j['finest_standalone_bytes'])} bytes)")
EOF

echo "== start server on an ephemeral port =="
"$BIN" serve --dir "$WORK/models" --addr 127.0.0.1:0 --cache-mb 32 --workers 4 \
  > "$WORK/serve.log" 2>&1 &
SERVER_PID=$!

ADDR=""
for _ in $(seq 1 100); do
  ADDR=$(sed -n 's#^listening on http://##p' "$WORK/serve.log" | head -n1)
  [ -n "$ADDR" ] && break
  kill -0 "$SERVER_PID" 2>/dev/null || { cat "$WORK/serve.log"; exit 1; }
  sleep 0.1
done
[ -n "$ADDR" ] || { echo "server never announced its port"; cat "$WORK/serve.log"; exit 1; }
echo "server at $ADDR"

echo "== fetch --tier 0: usable model from a strict byte prefix =="
"$BIN" fetch --url "http://$ADDR/models/mobilenet" --tier 0 \
  --out "$WORK/prefix.dcbc" --out-dir "$WORK/tier0_npy"
PREFIX_LEN=$(wc -c < "$WORK/prefix.dcbc")
FULL_LEN=$(wc -c < "$WORK/models/mobilenet.dcbc")
[ "$PREFIX_LEN" -lt "$FULL_LEN" ] || { echo "tier-0 prefix is not a strict prefix"; exit 1; }
head -c "$PREFIX_LEN" "$WORK/models/mobilenet.dcbc" | cmp - "$WORK/prefix.dcbc"
echo "tier 0 served as an exact $PREFIX_LEN-byte prefix of the $FULL_LEN-byte container"
# the prefix is itself a decodable v4 container at tier 0: materializing
# it must reproduce the standalone base-tier container byte-for-byte
"$BIN" materialize --in "$WORK/prefix.dcbc" --out "$WORK/prefix_mat.dcbc" --workers 4
cmp "$WORK/prefix_mat.dcbc" "$WORK/tiers/tier_0.dcbc"
echo "tier-0 prefix decodes to the standalone base container"

echo "== fetch --upgrade: extend the prefix to the full container =="
"$BIN" fetch --url "http://$ADDR/models/mobilenet" --upgrade "$WORK/prefix.dcbc" \
  --out "$WORK/upgraded.dcbc"
cmp "$WORK/upgraded.dcbc" "$WORK/models/mobilenet.dcbc"
echo "upgrade reassembled the full container byte-for-byte"
# upgrading an already-complete container is a clean no-op (416 tail)
"$BIN" fetch --url "http://$ADDR/models/mobilenet" --upgrade "$WORK/upgraded.dcbc" \
  --out "$WORK/upgraded2.dcbc" | grep -q "already complete"
echo "re-upgrade of a complete container is a clean no-op"

echo "== decoded-layer cache: per-tier LRU hit rate under repeat load =="
# loadgen alternates compressed-bytes and decoded-weights requests; the
# weights repeats must land in the (model, layer, tier)-keyed LRU
"$BIN" loadgen --url "http://$ADDR" --clients 8 --requests 16 \
  --out "$WORK/BENCH_progressive_serve.json"
python3 - "http://$ADDR/stats" <<'PYEOF'
import json, sys, urllib.request

stats = json.load(urllib.request.urlopen(sys.argv[1], timeout=10))
cache = stats["cache"]
hits, misses = cache["hits"], cache["misses"]
assert hits + misses > 0, f"no decode traffic reached the cache: {cache}"
rate = hits / (hits + misses)
# every distinct (layer, tier) misses once, every repeat must hit
assert rate >= 0.5, f"cache hit rate {rate:.1%} (hits {hits}, misses {misses})"
print(f"decoded-layer cache hit rate {rate:.1%} ({hits} hits / {misses} misses, "
      f"{cache['entries']} entries resident)")
PYEOF
