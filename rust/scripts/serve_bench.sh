#!/usr/bin/env bash
# Connection-scaling serve benchmark, run by CI from the rust/ directory:
#   1. sweep --progressive builds a tiered .dcbc v4 container so the
#      sweep's time-to-first-usable-tier probe has a ?tier=0 prefix to hit
#   2. start the event-loop server and run the fixed 32-client loadgen
#      plus a 1..1024 connection-scaling sweep into BENCH_serve.json
#   3. regression gate: p99 at the smoke point (64 connections) must not
#      worsen by more than 25% against the committed baseline
#      (BENCH_serve_baseline.json); re-baseline by copying a trusted
#      BENCH_serve.json over it
set -euo pipefail

BIN=${BIN:-target/release/deepcabac}
WORK=$(mktemp -d)
mkdir -p "$WORK/models"

# 1024 concurrent sockets on each side needs headroom over the default
# 1024 fd soft limit
ulimit -n 4096 || true

cleanup() {
  [ -n "${SERVER_PID:-}" ] && kill "$SERVER_PID" 2>/dev/null || true
  rm -rf "$WORK"
}
trap cleanup EXIT

echo "== build a progressive container for the ttfut probe =="
"$BIN" sweep --arch mobilenet --scale 16 --points 5 --workers 4 --chunks 4 \
  --progressive --tiers 2 \
  --out "$WORK/models/mobilenet.dcbc"

echo "== start event-loop server on an ephemeral port =="
"$BIN" serve --dir "$WORK/models" --addr 127.0.0.1:0 --cache-mb 32 --workers 4 \
  --event-loop \
  > "$WORK/serve.log" 2>&1 &
SERVER_PID=$!

ADDR=""
for _ in $(seq 1 100); do
  ADDR=$(sed -n 's#^listening on http://##p' "$WORK/serve.log" | head -n1)
  [ -n "$ADDR" ] && break
  kill -0 "$SERVER_PID" 2>/dev/null || { cat "$WORK/serve.log"; exit 1; }
  sleep 0.1
done
[ -n "$ADDR" ] || { echo "server never announced its port"; cat "$WORK/serve.log"; exit 1; }
echo "server at $ADDR"

echo "== fixed loadgen + connection-scaling sweep =="
"$BIN" loadgen --url "http://$ADDR" --clients 32 --requests 8 \
  --connections-sweep 1,64,256,1024 --sweep-requests 3 --out BENCH_serve.json
cat BENCH_serve.json

echo "== regression gate: p99 at 64 connections vs committed baseline =="
python3 - <<'PYEOF'
import json
import os
import sys

SMOKE_CONNS = 64
ALLOWED_WORSENING = 1.25
BASELINE = "BENCH_serve_baseline.json"

cur = json.load(open("BENCH_serve.json"))
points = {p["connections"]: p for p in cur["connection_scaling"]}
assert sorted(points) == [1, 64, 256, 1024], f"sweep points: {sorted(points)}"
for p in points.values():
    # the container is progressive, so every point must carry the
    # time-to-first-usable-tier probe
    assert "ttfut_ms" in p, f"sweep point lacks ttfut_ms: {p}"
    assert p["ttfut_ms"] >= 0.0, p

if not os.path.exists(BASELINE):
    print(f"no {BASELINE} committed — bootstrap by copying BENCH_serve.json "
          "over it; gate skipped")
    sys.exit(0)

base = json.load(open(BASELINE))
base_points = {p["connections"]: p for p in base.get("connection_scaling", [])}
if SMOKE_CONNS not in base_points:
    print(f"{BASELINE} has no {SMOKE_CONNS}-connection point — re-baseline; "
          "gate skipped")
    sys.exit(0)

base_p99 = base_points[SMOKE_CONNS]["p99_ms"]
cur_p99 = points[SMOKE_CONNS]["p99_ms"]
ceiling = base_p99 * ALLOWED_WORSENING
if cur_p99 > ceiling:
    sys.exit(
        f"p99 regression at {SMOKE_CONNS} connections: {cur_p99:.2f} ms vs "
        f"baseline {base_p99:.2f} ms (ceiling {ceiling:.2f} ms, "
        f"+{ALLOWED_WORSENING - 1:.0%} allowed)"
    )
print(f"p99 at {SMOKE_CONNS} connections: {cur_p99:.2f} ms vs baseline "
      f"{base_p99:.2f} ms (ceiling {ceiling:.2f} ms) — ok")
PYEOF
