#!/usr/bin/env bash
# Budgeted fuzzing smoke, run by CI from the rust/ directory:
#   1. replay the checked-in crasher corpus (regression gate)
#   2. fixed-seed structure-aware fuzzing over every parser target,
#      enforcing the never-panic / alloc-budget / time-budget /
#      roundtrip-idempotence invariants and the >= 50% prelude-survival
#      coverage proxy
#   3. a second, different seed for extra coverage at ~the same cost
#
# Fails on any new crasher; minimized reproducers land in
# fuzz_artifacts/ (uploaded by CI even on failure) ready to be promoted
# into fuzz_corpus/.
set -euo pipefail

BIN=${BIN:-target/release/deepcabac}
CASES=${CASES:-2000}
ARTIFACTS=${ARTIFACTS:-fuzz_artifacts}

rm -rf "$ARTIFACTS"

echo "== corpus replay + seed 42 =="
"$BIN" fuzz --target all --cases "$CASES" --seed 42 \
  --corpus fuzz_corpus --artifacts "$ARTIFACTS"

echo "== seed 1337 =="
"$BIN" fuzz --target all --cases "$CASES" --seed 1337 \
  --corpus fuzz_corpus --artifacts "$ARTIFACTS"

echo "fuzz smoke clean: $((2 * CASES)) cases/target across 2 seeds + corpus replay"
