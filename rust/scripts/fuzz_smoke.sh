#!/usr/bin/env bash
# Budgeted fuzzing smoke, run by CI from the rust/ directory:
#   1. replay the checked-in crasher corpus (regression gate)
#   2. fixed-seed structure-aware fuzzing over every parser target,
#      enforcing the never-panic / alloc-budget / time-budget /
#      roundtrip-idempotence invariants and the >= 50% prelude-survival
#      coverage proxy
#   3. a second, different seed for extra coverage at ~the same cost
#   4. (EVOLVE=1, default) the coverage-guided evolve loop per target,
#      wall-capped by EVOLVE_TIME seconds each, writing BENCH_fuzz.json
#      and promoted finds — then gate per-target unique edges against
#      the committed floors in BENCH_fuzz_baseline.json, and require
#      evolve to beat the same-budget batch on the container and
#      delta-apply targets. The edge gates only bite when the binary was
#      built with --features fuzz-cov (CI's fuzz-smoke job does); an
#      uninstrumented build still runs evolve as a crash hunt.
#
# Fails on any new crasher; minimized reproducers and promoted finds
# land in fuzz_artifacts/ (uploaded by CI even on failure) ready to be
# promoted into fuzz_corpus/.
set -euo pipefail

BIN=${BIN:-target/release/deepcabac}
CASES=${CASES:-2000}
ARTIFACTS=${ARTIFACTS:-fuzz_artifacts}
EVOLVE=${EVOLVE:-1}
EVOLVE_TIME=${EVOLVE_TIME:-60}

rm -rf "$ARTIFACTS"

echo "== corpus replay + seed 42 =="
"$BIN" fuzz --target all --cases "$CASES" --seed 42 \
  --corpus fuzz_corpus --artifacts "$ARTIFACTS"

echo "== seed 1337 =="
"$BIN" fuzz --target all --cases "$CASES" --seed 1337 \
  --corpus fuzz_corpus --artifacts "$ARTIFACTS"

if [ "$EVOLVE" = "1" ]; then
  echo "== coverage-guided evolve (--max-time ${EVOLVE_TIME}s per target) =="
  "$BIN" fuzz --target all --cases "$CASES" --seed 42 \
    --corpus fuzz_corpus --artifacts "$ARTIFACTS" \
    --evolve --max-time "$EVOLVE_TIME" --json BENCH_fuzz.json

  echo "== coverage gate vs BENCH_fuzz_baseline.json =="
  python3 - <<'PYGATE'
import json, sys

bench = json.load(open("BENCH_fuzz.json"))
floors = json.load(open("BENCH_fuzz_baseline.json"))["floors"]
if not bench.get("cov_enabled"):
    print("coverage gate skipped: binary built without --features fuzz-cov")
    sys.exit(0)
failed = []
for t in bench["targets"]:
    name, edges = t["target"], t["unique_edges"]
    floor = floors.get(name)
    if floor is None:
        failed.append(f"{name}: no committed floor in BENCH_fuzz_baseline.json")
    elif edges < floor:
        failed.append(f"{name}: {edges} unique edges < committed floor {floor}")
    else:
        print(f"{name}: {edges} unique edges >= floor {floor}")
    if name in ("container", "delta_apply") and edges <= t["batch_unique_edges"]:
        failed.append(
            f"{name}: evolve ({edges}) must beat same-budget batch "
            f"({t['batch_unique_edges']})"
        )
for msg in failed:
    print("GATE FAIL:", msg)
sys.exit(1 if failed else 0)
PYGATE
fi

echo "fuzz smoke clean: $((2 * CASES)) cases/target across 2 seeds + corpus replay"
