#!/usr/bin/env python3
"""Regenerate the checked-in fuzz corpus (rust/fuzz_corpus/).

Each file is a reviewable, hand-specified parser edge case from the wire
spec in docs/FORMAT.md. Filename conventions (see fuzz::driver):

  accept_*  must parse Ok (container: deserialize; http: request head)
  reject_*  must parse Err
  other     only has to uphold the crash invariants

Container files (v1/v2 full containers, v3 delta segments and v4
progressive containers alike) are replayed against both the batch and
the streaming decoder; range files
are raw `Range:` header values; encoder files are hostile-model recipes
for fuzz::gen::hostile_model_pair (accept_* must delta-encode, reject_*
must be rejected by the finite-value boundary); delta_apply files are
framed (parent, delta) pairs — 4-byte LE parent length, parent bytes,
delta bytes, mirroring fuzz::gen::frame_delta_pair — whose parent was
mutated AFTER the delta captured its fingerprint (accept_* must apply
byte-exactly, reject_* must come back as a structured error). The
corpus is committed — this script exists so the bytes have a
reproducible, documented provenance, not because regeneration is
routine.
"""

import os
import struct

ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "fuzz_corpus")


def varint(v: int) -> bytes:
    out = bytearray()
    while True:
        b = v & 0x7F
        v >>= 7
        if v:
            out.append(b | 0x80)
        else:
            out.append(b)
            return bytes(out)


def s(name: str) -> bytes:
    b = name.encode()
    return varint(len(b)) + b


def f32(x: float) -> bytes:
    return struct.pack("<f", x)


CFG = bytes([1, 1, 0, 0])  # n_abs_flags=1, ExpGolomb(0), no sig neighbors


def layer_v1(name, n_weights, payload, dims=(4,), bias=(), max_level=3, s_param=7):
    out = s(name) + varint(len(dims))
    for d in dims:
        out += varint(d)
    out += f32(0.05) + varint(max_level) + varint(s_param) + CFG
    out += varint(n_weights) + varint(len(payload)) + payload
    out += varint(len(bias))
    for b in bias:
        out += f32(b)
    return out


def layer_v2(name, chunks, n_weights, payload, bias=()):
    """chunks: list of (chunk_weights, chunk_bytes) varint pairs."""
    out = s(name) + varint(1) + varint(4)
    out += f32(0.05) + varint(3) + varint(7) + CFG
    out += varint(len(chunks))
    for w, b in chunks:
        out += varint(w) + varint(b)
    out += varint(n_weights) + varint(len(payload)) + payload
    out += varint(len(bias))
    for b in bias:
        out += f32(b)
    return out


def container(version, name, layer_blobs):
    return b"DCBC" + bytes([version]) + s(name) + varint(len(layer_blobs)) + b"".join(
        layer_blobs
    )


def delta_container(parent_fp, name, layer_blobs):
    """A v3 delta segment: the parent fingerprint rides raw LE after the
    version byte, then the same name/count prelude as v1/v2."""
    return (
        b"DCBC\x03"
        + struct.pack("<Q", parent_fp)
        + s(name)
        + varint(len(layer_blobs))
        + b"".join(layer_blobs)
    )


def dlayer_skip(name):
    """Skip record: flag 1 + layer name, nothing else."""
    return b"\x01" + s(name)


def dlayer_coded(name, chunks, n_weights, payload, bias=()):
    """Coded record: flag 0 + a v2-shaped layer (the chunk table is
    always present in v3, single-entry tables canonicalize away)."""
    out = b"\x00" + s(name) + varint(1) + varint(4)
    out += f32(0.05) + varint(3) + varint(7) + CFG
    out += varint(len(chunks))
    for w, b in chunks:
        out += varint(w) + varint(b)
    out += varint(n_weights) + varint(len(payload)) + payload
    out += varint(len(bias))
    for b in bias:
        out += f32(b)
    return out


def progressive_container(name, n_layers, tier_bodies, declared_lens=None):
    """A v4 progressive container: name/layer-count prelude, tier count,
    the tier byte-length table, then the concatenated tier bodies. Tier 0
    holds v2-shaped layer records, tiers >= 1 hold v3 dlayer records.
    `declared_lens` overrides the table so cases can lie about spans."""
    lens = declared_lens if declared_lens is not None else [len(b) for b in tier_bodies]
    out = b"DCBC\x04" + s(name) + varint(n_layers) + varint(len(lens))
    for ln in lens:
        out += varint(ln)
    return out + b"".join(tier_bodies)


# deterministic "garbage" CABAC payload: parse never validates payload
# content, and the decoder treats any bits as a (possibly nonsense) stream
def junk(n: int, seed: int = 0xA5) -> bytes:
    return bytes((seed * (i + 3) * 2654435761) >> 7 & 0xFF for i in range(n))


def write(sub, name, data):
    d = os.path.join(ROOT, sub)
    os.makedirs(d, exist_ok=True)
    with open(os.path.join(d, name), "wb") as f:
        f.write(data)
    print(f"  {sub}/{name}: {len(data)} bytes")


def containers():
    # -- accepted ----------------------------------------------------------
    write("container", "accept_minimal_v1", container(1, "m", []))
    write(
        "container",
        "accept_zero_weight_layer",
        container(1, "m", [layer_v1("z", 0, b"", dims=(0,))]),
    )
    # single-entry chunk table: legal on the wire, canonicalizes to the
    # monolithic form on reserialize (the idempotence invariant's x != y case)
    write(
        "container",
        "accept_v2_single_chunk",
        container(2, "m", [layer_v2("a", [(4, 2)], 4, junk(2))]),
    )
    write(
        "container",
        "accept_v2_multichunk",
        container(
            2,
            "m",
            [layer_v2("a", [(3, 2), (5, 4)], 8, junk(6), bias=(0.5,))],
        ),
    )
    write(
        "container",
        "accept_two_layers_v1",
        container(
            1,
            "mm",
            [
                layer_v1("conv", 6, junk(5), dims=(3, 2), bias=(1.0, -1.0)),
                layer_v1("fc", 2, junk(3), dims=(2,)),
            ],
        ),
    )

    # -- v3 delta segments -------------------------------------------------
    write("container", "accept_v3_minimal", delta_container(0xDEADBEEF, "m", []))
    write(
        "container",
        "accept_v3_skip_only",
        delta_container(7, "m", [dlayer_skip("a"), dlayer_skip("b")]),
    )
    # single-entry chunk table on the coded record: canonicalizes on
    # reserialize, the v3 instance of the idempotence x != y case
    write(
        "container",
        "accept_v3_coded_single_chunk",
        delta_container(7, "m", [dlayer_coded("a", [(4, 2)], 4, junk(2))]),
    )
    write(
        "container",
        "accept_v3_mixed",
        delta_container(
            99,
            "mm",
            [
                dlayer_skip("conv"),
                dlayer_coded("fc", [(3, 2), (5, 4)], 8, junk(6), bias=(0.5,)),
            ],
        ),
    )
    # the only legal skip flags are 0 and 1
    write(
        "container",
        "reject_v3_bad_skip_flag",
        delta_container(7, "m", [b"\x02" + s("a")]),
    )
    # prelude cut mid-fingerprint: batch says truncated, stream NeedMore
    # then finish() rejects
    write("container", "reject_v3_truncated_parent_fp", b"DCBC\x03" + b"\xAB" * 4)
    # residual chunk-table lies: weights sum disagrees with the header
    write(
        "container",
        "reject_v3_chunk_sum_mismatch",
        delta_container(7, "m", [dlayer_coded("a", [(1, 1), (1, 1)], 5, junk(2))]),
    )
    # residual chunk weight counts that overflow the u64 sum
    write(
        "container",
        "reject_v3_chunk_sum_overflow",
        delta_container(
            7,
            "m",
            [
                b"\x00" + s("a") + varint(1) + varint(4) + f32(0.05) + varint(3)
                + varint(7) + CFG
                + varint(2)
                + varint((1 << 64) - 1) + varint(1)
                + varint(1) + varint(1)
                + varint(4) + varint(2) + junk(2) + varint(0)
            ],
        ),
    )
    write(
        "container",
        "reject_v3_trailing_bytes",
        delta_container(0xDEADBEEF, "m", []) + b"\x00",
    )

    # -- v4 progressive containers -----------------------------------------
    base_a = layer_v2("a", [(3, 2), (5, 4)], 8, junk(6), bias=(0.5,))
    base_b = layer_v2("b", [(4, 2)], 4, junk(2))
    write(
        "container",
        "accept_v4_single_tier",
        progressive_container("m", 1, [base_a]),
    )
    # refinement records are positional: skip "a", re-code "b" with a
    # matching weight count so the tier applies cleanly
    refinement = dlayer_skip("a") + dlayer_coded("b", [(4, 2)], 4, junk(2, seed=0x3C))
    two_tier = progressive_container("mm", 2, [base_a + base_b, refinement])
    write("container", "accept_v4_two_tiers", two_tier)
    write(
        "container",
        "accept_v4_zero_layers",
        progressive_container("m", 0, [b""]),
    )
    # the truncation rule: EOF exactly at a tier-body boundary is a
    # complete container at the preceding tier (reserialize shrinks the
    # tier table — canonicalization, same idempotence story as v2
    # single-chunk forms)
    write(
        "container",
        "accept_v4_truncated_at_tier_boundary",
        two_tier[: len(two_tier) - len(refinement)],
    )
    # a mid-tier EOF is NOT a boundary: one byte into the refinement
    write(
        "container",
        "reject_v4_truncated_tier_header",
        two_tier[: len(two_tier) - len(refinement) + 1],
    )
    # tier counts outside 1..=MAX_TIERS (64)
    write(
        "container",
        "reject_v4_zero_tiers",
        b"DCBC\x04" + s("m") + varint(0) + varint(0),
    )
    write(
        "container",
        "reject_v4_too_many_tiers",
        b"DCBC\x04" + s("m") + varint(0) + varint(65),
    )
    # tier table lies about the span: declared length disagrees with the
    # bytes the tier's records actually occupy
    write(
        "container",
        "reject_v4_tier_span_mismatch",
        progressive_container("m", 1, [base_a], declared_lens=[len(base_a) + 1]),
    )
    # tier byte-lengths whose sum overflows u64
    write(
        "container",
        "reject_v4_tier_table_overflow",
        b"DCBC\x04" + s("m") + varint(0) + varint(2)
        + varint((1 << 64) - 1) + varint(1),
    )
    write(
        "container",
        "reject_v4_trailing_bytes",
        progressive_container("m", 0, [b""]) + b"\x00",
    )

    # -- rejected ----------------------------------------------------------
    write("container", "reject_bad_magic", b"DCBX\x01" + s("m") + varint(0))
    # version 4 became the progressive container; 5 is the first unknown
    write("container", "reject_bad_version", b"DCBC\x05" + s("m") + varint(0))
    # 11 continuation bytes: >= 10 undecided bytes = malformed varint,
    # not a short buffer
    write("container", "reject_overlong_varint", b"DCBC\x01" + b"\x80" * 11)
    write(
        "container",
        "reject_nonutf8_name",
        b"DCBC\x01" + varint(2) + b"\xff\xfe" + varint(0),
    )
    write(
        "container",
        "reject_trailing_bytes",
        container(1, "m", []) + b"\xff",
    )
    # density guard: 2^20 claimed weights against a 1-byte payload
    write(
        "container",
        "reject_giant_nweights_small_payload",
        container(1, "m", [layer_v1("z", 1 << 20, b"\x00")]),
    )
    # reverse cap: 4097 payload bytes claimed for 0 weights (cap is
    # n_weights*512 + 4096); header-only file — the parser bails before
    # ever needing the payload bytes
    write(
        "container",
        "reject_huge_payload_claim",
        container(1, "m", [s("z") + varint(1) + varint(4) + f32(0.05) + varint(3) + varint(7) + CFG + varint(0) + varint(4097)]),
    )
    write(
        "container",
        "reject_zero_chunks",
        container(2, "m", [s("a") + varint(1) + varint(4) + f32(0.05) + varint(3) + varint(7) + CFG + varint(0)]),
    )
    # chunk table sums disagree with the layer header
    write(
        "container",
        "reject_chunk_sum_mismatch",
        container(2, "m", [layer_v2("a", [(1, 1), (1, 1)], 5, junk(2))]),
    )
    # chunk weight counts that overflow a u64 sum
    write(
        "container",
        "reject_chunk_sum_overflow",
        container(
            2,
            "m",
            [
                s("a") + varint(1) + varint(4) + f32(0.05) + varint(3) + varint(7) + CFG
                + varint(2)
                + varint((1 << 64) - 1) + varint(1)
                + varint(1) + varint(1)
                + varint(4) + varint(2) + junk(2) + varint(0)
            ],
        ),
    )
    write(
        "container",
        "reject_bad_remainder_tag",
        container(1, "m", [s("z") + varint(1) + varint(4) + f32(0.05) + varint(3) + varint(7) + bytes([1, 7, 0, 0]) + varint(0) + varint(0) + varint(0)]),
    )
    # payload claimed but not present: batch says truncated, stream's
    # finish() says incomplete — both reject
    write(
        "container",
        "reject_truncated_payload",
        container(1, "m", [s("z") + varint(1) + varint(4) + f32(0.05) + varint(3) + varint(7) + CFG + varint(64) + varint(100) + junk(5)]),
    )


def https():
    # parse_request_head takes the head without the terminating blank line
    write("http", "accept_basic_get", b"GET /models HTTP/1.1\r\nHost: x\r\n")
    write(
        "http",
        "accept_range_request",
        b"GET /models/m/layers/0 HTTP/1.1\r\nRange: bytes=0-99\r\nAccept: */*\r\n",
    )
    write("http", "reject_empty", b"")
    write("http", "reject_non_utf8", b"GET /\xff\xfe HTTP/1.1\r\n")
    write("http", "reject_method_only", b"GET\r\n")
    # crash-invariant-only cases (no accept/reject prefix)
    write("http", "slowloris_partial_head", b"GET /models HTTP/1.1\r\nHost: victim\r\nX-Slow: ")
    write("http", "nul_in_path", b"GET /\x00models HTTP/1.1\r\nHost: a\x00b\r\n")
    write(
        "http",
        "giant_header_line",
        b"GET / HTTP/1.1\r\nX-Big: " + b"A" * 20000 + b"\r\n",
    )
    write("http", "lf_only_lines", b"GET /stats HTTP/1.0\nHost: x\nRange: bytes=0-1\n")


def ranges():
    # raw Range header values; exec_range only asserts the in-bounds
    # invariant on Satisfiable outcomes, so no accept/reject prefixes
    cases = {
        "u64_max_end": b"bytes=0-18446744073709551615",
        "u64_max_suffix": b"bytes=-18446744073709551615",
        "overflow_26_digits": b"bytes=0-99999999999999999999999999",
        "suffix_zero": b"bytes=-0",
        "open_end": b"bytes=100-",
        "reversed": b"bytes=5-2",
        "multipart": b"bytes=0-5,10-20",
        "double_dash": b"bytes=0--5",
        "bad_unit": b"bytez=0-5",
        "spaces": b"bytes = 0 - 5",
        "empty_value": b"",
        "just_unit": b"bytes=",
        "boundary_127_128": b"bytes=127-128",
        "boundary_16384": b"bytes=16383-16384",
    }
    for name, v in cases.items():
        write("range", name, v)


def encoders():
    # hostile-model recipes for fuzz::gen::hostile_model_pair: byte 0 is
    # the layer count (mod 4), then per layer a size selector and
    # (parent, target, sigma) value-table triples; exhausted recipes
    # read as zeros. accepted = the pair delta-encodes end to end.
    # target selector ≡ 0 mod 4 re-draws from HOSTILE_ANY, where
    # indices 12/13/14 (selectors 48/52/56) are NaN/+Inf/-Inf.
    write(
        "encoder",
        "reject_nan_inf_target",
        bytes([1, 2, 2]) + bytes([6, 48, 8, 6, 52, 8, 6, 56, 8]) + bytes([0]),
    )
    # finite-but-nasty: subnormals, signed zeros, f32::MAX magnitudes,
    # a zero-dim second layer — must encode, apply back byte-for-byte
    write(
        "encoder",
        "accept_finite_hostile",
        bytes([2, 2, 4, 0, 1, 2, 1, 8, 10, 6, 8, 4, 2, 0, 0, 0]),
    )
    # the empty recipe: a zero-layer model pair, the degenerate accept
    write("encoder", "accept_empty_recipe", b"")


def fnv1a(data: bytes) -> int:
    """Mirror of util::fnv1a — fingerprint(model) = fnv1a(serialize)."""
    h = 0xCBF29CE484222325
    for x in data:
        h ^= x
        h = (h * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


def frame_pair(parent: bytes, delta: bytes) -> bytes:
    """Mirror of fuzz::gen::frame_delta_pair: 4-byte LE parent length,
    parent bytes, delta bytes."""
    return struct.pack("<I", len(parent)) + parent + delta


def delta_applies():
    # framed (parent, delta) pairs for the delta_apply target. The trust
    # boundary under test: the delta names its parent by fingerprint, and
    # every mutation below happens to the parent AFTER that fingerprint
    # was taken — apply must reject with a structured error (or, for the
    # pristine accept_* pairs, reproduce the parent byte-exactly through
    # both the batch and the streaming applier), never panic or blow the
    # allocation budget.
    parent = container(
        1,
        "mm",
        [
            layer_v1("conv", 6, junk(5), dims=(3, 2), bias=(1.0, -1.0)),
            layer_v1("fc", 2, junk(3), dims=(2,)),
        ],
    )
    skip_all = delta_container(
        fnv1a(parent), "mm", [dlayer_skip("conv"), dlayer_skip("fc")]
    )
    # pristine pair: all-skip delta against its true parent — applies to
    # a byte-identical copy of the parent
    write("delta_apply", "accept_pristine_all_skip", frame_pair(parent, skip_all))
    # the degenerate pristine pair: zero layers on both sides
    empty_parent = container(1, "m", [])
    write(
        "delta_apply",
        "accept_empty_model_pair",
        frame_pair(empty_parent, delta_container(fnv1a(empty_parent), "m", [])),
    )
    # byte noise in a CABAC payload: the parent still parses, but its
    # fingerprint no longer matches — apply must say so, not reconstruct
    noisy = bytearray(parent)
    noisy[parent.index(junk(5))] ^= 0xFF
    write("delta_apply", "reject_fp_byte_noise", frame_pair(bytes(noisy), skip_all))
    # chunk-table lie that still parses: same weight/byte sums split
    # differently, so the parent is accepted by the parser yet
    # fingerprint-rejected by apply
    parent_v2 = container(2, "m", [layer_v2("a", [(3, 2), (5, 4)], 8, junk(6))])
    skip_v2 = delta_container(fnv1a(parent_v2), "m", [dlayer_skip("a")])
    lying_v2 = container(2, "m", [layer_v2("a", [(4, 3), (4, 3)], 8, junk(6))])
    write("delta_apply", "reject_chunk_table_lie", frame_pair(lying_v2, skip_v2))
    # truncation: the parent ends mid-layer-record
    write(
        "delta_apply",
        "reject_truncated_parent",
        frame_pair(parent[: len(parent) // 2], skip_all),
    )
    # the parent replaced with garbage entirely (no DCBC magic)
    write("delta_apply", "reject_garbage_parent", frame_pair(junk(40), skip_all))
    # version-byte lie: 9 is no container version
    wrong_version = parent[:4] + bytes([9]) + parent[5:]
    write(
        "delta_apply",
        "reject_wrong_version_parent",
        frame_pair(wrong_version, skip_all),
    )
    # pristine parent, zeroed fingerprint in the delta: the mismatch is
    # on the delta side this time
    write(
        "delta_apply",
        "reject_zeroed_delta_fp",
        frame_pair(parent, delta_container(0, "mm", [dlayer_skip("conv"), dlayer_skip("fc")])),
    )
    # crash-invariant-only: the length prefix claims more parent bytes
    # than the frame holds; split_delta_pair clamps, the delta side is
    # empty, and nothing may panic
    lying_frame = bytearray(frame_pair(parent, skip_all))
    struct.pack_into("<I", lying_frame, 0, len(lying_frame) * 2)
    write("delta_apply", "lying_length_prefix", bytes(lying_frame))


if __name__ == "__main__":
    containers()
    https()
    ranges()
    encoders()
    delta_applies()
    print("corpus regenerated at", os.path.normpath(ROOT))
