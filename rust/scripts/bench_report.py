#!/usr/bin/env python3
"""Render a BENCH_*.json artifact as a paste-ready markdown fragment.

Usage:  python3 scripts/bench_report.py BENCH_sweep.json > BENCH_sweep.md

The fragment is what EXPERIMENTS.md's fill-in procedure pastes under the
matching section (§Perf / §Serving / §Sweep): CI runs this after every
smoke job and uploads the .md next to the .json, so the numbers in the
docs always have a machine-generated source that names the commit that
produced them. Stdlib only — the CI image has no extra packages.
"""

import json
import os
import sys


def row(cells):
    return "| " + " | ".join(str(c) for c in cells) + " |"


def table(headers, rows):
    out = [row(headers), row(["---"] * len(headers))]
    out += [row(r) for r in rows]
    return "\n".join(out)


def fmt(x, nd=2):
    if isinstance(x, float):
        return f"{x:.{nd}f}"
    return str(x)


def throughput_md(b):
    rows = []
    for name, r in sorted(b.get("results", {}).items()):
        bpw = r.get("bits_per_weight")
        rows.append([name, fmt(r["mweights_per_s"]), fmt(bpw, 3) if bpw is not None else "—"])
    return "\n".join([
        f"**§Perf** — {int(b['n_weights'])} weights, density {b['density']:.2f}:",
        "",
        table(["path", "Mweights/s", "bits/weight"], rows),
    ])


def sweep_md(b):
    head = [
        f"**§Sweep** — model `{b['model']}`, {b['workers']} workers, "
        f"{b['points_per_round']} S-points/round × {b['lambda_columns']} λ-columns, "
        f"abandon mode `{b['abandon_mode']}`, warm start `{b['warm_start']}`:",
        "",
        table(
            ["probes", "rounds", "abandoned", "mid-layer", "boundary",
             "seed hit-rate", "best (S, λ)", "best bytes", "engine wall s",
             "serial wall s"],
            [[
                b["probes_total"], b["rounds"], b["probes_abandoned"],
                b["abandoned_mid_layer"], b["abandoned_boundary"],
                fmt(b["seed_hit_rate"], 3),
                f"({b['best_s']}, {b['best_lambda']})", b["best_bytes"],
                fmt(b["wall_s"]), fmt(b.get("wall_s_serial", float("nan"))),
            ]],
        ),
        "",
        "Per-λ-column argmins:",
        "",
        table(
            ["λ (lambda_scale)", "best S", "best bytes", "probes", "abandoned"]
            + (["metric"] if any("metric" in c for c in b["columns"]) else []),
            [
                [c["lambda_scale"], c["best_s"], c["best_bytes"], c["probes"],
                 c["abandoned"]]
                + ([fmt(c.get("metric", float("nan")), 4)]
                   if any("metric" in x for x in b["columns"]) else [])
                for c in b["columns"]
            ],
        ),
        "",
        f"Pareto frontier ({len(b['frontier'])} points, bytes ↑ / distortion ↓):",
        "",
        table(
            ["S", "λ", "bytes", "distortion"],
            [[q["s"], q["lambda_scale"], q["bytes"], f"{q['distortion']:.4e}"]
             for q in b["frontier"]],
        ),
    ]
    return "\n".join(head)


def serve_md(b):
    mode = b.get("mode", "closed")
    mode_note = f", open loop @ {b['rate_rps']:g} req/s" if mode == "open" else ""
    out = [
        f"**§Serving** — {int(b['clients'])} clients × "
        f"{int(b['requests_per_client'])} requests against `{b['url']}`"
        f"{mode_note}:",
        "",
        table(
            ["requests", "failures", "p50 ms", "p99 ms", "p999 ms", "mean ms",
             "req/s", "bytes moved"],
            [[
                int(b["total_requests"]), int(b["failures"]), fmt(b["p50_ms"]),
                fmt(b["p99_ms"]), fmt(b.get("p999_ms", b["p99_ms"])),
                fmt(b["mean_ms"]), fmt(b["throughput_rps"], 0),
                int(b["bytes_transferred"]),
            ]],
        ),
    ]
    scaling = b.get("connection_scaling")
    if scaling:
        out += [
            "",
            f"Connection scaling ({len(scaling)} points, keep-alive sockets, "
            "reuse = responses served on an already-used socket):",
            "",
            table(
                ["conns", "established", "ok", "failures", "shed", "reused",
                 "reconnects", "p50 ms", "p99 ms", "p999 ms", "req/s",
                 "ttfut ms"],
                [[
                    int(p["connections"]), int(p["established"]), int(p["ok"]),
                    int(p["failures"]), int(p["shed"]), int(p["reused"]),
                    int(p["reconnects"]), fmt(p["p50_ms"]), fmt(p["p99_ms"]),
                    fmt(p["p999_ms"]), fmt(p["throughput_rps"], 0),
                    fmt(p["ttfut_ms"]) if "ttfut_ms" in p else "—",
                ] for p in scaling],
            ),
        ]
    p = b.get("progressive")
    if p:
        out += [
            "",
            f"Time to first usable tier ({int(p['models'])} progressive "
            f"models × {int(p['probes'])} probes, idle server):",
            "",
            table(
                ["base p50 ms", "base p99 ms", "full p50 ms", "full p99 ms",
                 "base bytes", "full bytes"],
                [[
                    fmt(p["base_tier_p50_ms"]), fmt(p["base_tier_p99_ms"]),
                    fmt(p["full_p50_ms"]), fmt(p["full_p99_ms"]),
                    int(p["base_tier_bytes"]), int(p["full_bytes"]),
                ]],
            ),
        ]
    return "\n".join(out)


def delta_md(b):
    head = [
        f"**§Delta** — model `{b['model']}`, parent `{b['parent_fingerprint']}`, "
        f"{int(b['workers'])} workers:",
        "",
        table(
            ["full bytes", "delta bytes", "ratio", "coded/total layers",
             "residual density", "encode s", "apply p50 ms", "apply p99 ms"],
            [[
                int(b["full_bytes"]), int(b["delta_bytes"]),
                f"{b['delta_ratio']:.1%}",
                f"{int(b['coded_layers'])}/{int(b['total_layers'])}",
                f"{b['residual_density']:.3%}", fmt(b["encode_wall_s"]),
                fmt(b["apply_p50_ms"]), fmt(b["apply_p99_ms"]),
            ]],
        ),
        "",
        "Per-layer residuals:",
        "",
        table(
            ["layer", "skipped", "weights", "nonzero residuals",
             "delta payload", "target payload"],
            [[l["name"], l["skipped"], int(l["n_weights"]),
              int(l["residual_nonzero"]), int(l["delta_payload"]),
              int(l["target_payload"])]
             for l in b["layers"]],
        ),
    ]
    return "\n".join(head)


def progressive_md(b):
    rows = []
    for t in b["tiers"]:
        dist = t.get("distortion")
        dens = t.get("residual_density")
        rows.append([
            int(t["tier"]), t["s"], t["lambda_scale"],
            int(t["standalone_bytes"]), int(t["tier_body_bytes"]),
            f"{dist:.4e}" if dist is not None else "—",
            f"{dens:.3%}" if dens is not None else "—",
        ])
    return "\n".join([
        f"**§Progressive** — model `{b['model']}`, {int(b['n_tiers'])} tiers "
        f"({int(b['requested_tiers'])} requested), {int(b['workers'])} workers: "
        f"{int(b['progressive_bytes'])} bytes vs {int(b['finest_standalone_bytes'])} "
        f"standalone ({b['overhead_ratio']:.1%}):",
        "",
        table(
            ["tier", "S", "λ", "standalone bytes", "tier body bytes",
             "distortion", "residual density"],
            rows,
        ),
    ])


def fuzz_md(b):
    cov = "on" if b.get("cov_enabled") else "off (build with --features fuzz-cov)"
    rows = []
    for t in b["targets"]:
        disc = t.get("discovery") or []
        curve = " ".join(f"{i}:{e}" for i, e in disc)
        rows.append([
            t["target"], int(t["cases"]), fmt(float(t["execs_per_s"]), 0),
            int(t["unique_edges"]), int(t["batch_unique_edges"]),
            int(t["corpus_size"]), int(t["promoted"]), int(t["crashes"]),
            curve or "—",
        ])
    return "\n".join([
        f"**§Fuzzing** — seed {int(b['seed'])}, edge instrumentation {cov}, "
        f"alloc metering {'on' if b.get('alloc_metered') else 'off'} "
        "(edges = unique coverage-map slots; batch = same-budget "
        "generate-and-mutate run for comparison):",
        "",
        table(
            ["target", "execs", "execs/s", "unique edges", "batch edges",
             "corpus", "promoted", "crashes", "discovery (exec:edges)"],
            rows,
        ),
    ])


RENDERERS = {
    "throughput": throughput_md,
    "sweep": sweep_md,
    "serve": serve_md,
    "delta": delta_md,
    "progressive": progressive_md,
    "fuzz": fuzz_md,
}


def main():
    if len(sys.argv) != 2:
        sys.exit(__doc__)
    path = sys.argv[1]
    b = json.load(open(path))
    kind = b.get("bench")
    render = RENDERERS.get(kind)
    if render is None:
        sys.exit(f"unknown bench kind {kind!r} in {path}")
    sha = os.environ.get("GITHUB_SHA", "local")[:12]
    print(f"<!-- generated by scripts/bench_report.py from {os.path.basename(path)} "
          f"@ {sha} — paste into EXPERIMENTS.md -->")
    print(render(b))


if __name__ == "__main__":
    main()
