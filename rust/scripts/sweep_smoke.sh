#!/usr/bin/env bash
# S-sweep engine smoke test, run by CI from the rust/ directory:
#   1. coarse-to-fine sweep on a synthetic model — parallel with early
#      abandonment — plus the serial no-abandon reference (the binary
#      itself asserts both select a byte-identical container)
#   2. assert BENCH_sweep.json is well-formed and that the refinement
#      path actually abandoned probes (the fan-out + budget engaged)
#   3. roundtrip the best-S container through `decompress`
set -euo pipefail

BIN=${BIN:-target/release/deepcabac}
WORK=$(mktemp -d)
trap 'rm -rf "$WORK"' EXIT

echo "== parallel sweep (+ serial reference) =="
"$BIN" sweep --arch mobilenet --scale 8 --points 9 --workers 4 \
  --compare-serial --out "$WORK/best.dcbc" --json BENCH_sweep.json
cat BENCH_sweep.json

echo "== BENCH_sweep.json well-formed =="
python3 - <<'EOF'
import json

b = json.load(open("BENCH_sweep.json"))
assert b["bench"] == "sweep", b
for key in ("model", "workers", "points_per_round", "rounds", "probes_total",
            "probes_abandoned", "best_s", "best_bytes", "wall_s",
            "wall_s_serial", "points"):
    assert key in b, f"missing {key}"
assert b["workers"] == 4 and b["points_per_round"] == 9
assert b["probes_total"] == len(b["points"]) > 9, "refinement never ran"
assert b["rounds"] > 1, "refinement never ran"
assert b["probes_abandoned"] > 0, "refinement abandoned no probes"
assert sum(p["abandoned"] for p in b["points"]) == b["probes_abandoned"]
completed = [p["bytes"] for p in b["points"] if not p["abandoned"]]
assert completed and min(completed) == b["best_bytes"], "best != min(points)"
assert 0 <= b["best_s"] <= 256
print(f"BENCH_sweep.json OK: {b['probes_total']} probes / {b['rounds']} rounds, "
      f"{b['probes_abandoned']} abandoned, best S = {b['best_s']} "
      f"({b['best_bytes']} bytes), wall {b['wall_s']:.2f}s "
      f"vs serial {b['wall_s_serial']:.2f}s")
EOF

echo "== best-S container roundtrips =="
"$BIN" decompress --in "$WORK/best.dcbc" --out-dir "$WORK/out"
N=$(ls "$WORK/out"/*.npy | wc -l)
[ "$N" -gt 0 ] || { echo "no tensors decoded"; exit 1; }
echo "decoded $N tensors from the best-S container"
