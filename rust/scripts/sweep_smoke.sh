#!/usr/bin/env bash
# (S × λ) sweep engine smoke test, run by CI from the rust/ directory:
#   1. warm, frontier-preserving 2-D sweep (5 S points per round × 3
#      λ-columns) on a synthetic model with --compare-serial (the binary
#      recompresses every completed grid point serially and asserts
#      byte-identity against the engine's per-point fingerprints)
#   2. cold --no-abandon reference run: the Pareto frontier, every
#      per-column argmin, and the winning container must be IDENTICAL —
#      the warm-start + dominance-abandonment acceptance check
#   3. --abandon-argmin run: the aggressive byte-budget mode still
#      abandons probes (>0) and still lands on the same argmins
#   4. assert BENCH_sweep.json carries a well-formed Pareto frontier
#      (non-dominated, covers the min-bytes and min-distortion completed
#      points), per-column argmins, seed hit-rate + abandonment-reason
#      stats, and near-monotone (0.5% slack) container size along λ at
#      fixed S
#   5. roundtrip the frontier-argmin container through `decompress`
#   6. frontier output selection: --select-lambda writes a λ-column's
#      argmin (and rejects λ values outside the grid / empty λ grids /
#      contradictory switch pairs)
#   7. emit BENCH_sweep.md (markdown fragment for EXPERIMENTS.md §Sweep)
set -euo pipefail

BIN=${BIN:-target/release/deepcabac}
WORK=$(mktemp -d)
trap 'rm -rf "$WORK"' EXIT

SWEEP_ARGS=(--arch mobilenet --scale 8 --points 5 --workers 4 --lambdas 0.01,0.05,0.2)

echo "== warm frontier-preserving sweep (+ per-point serial byte-identity) =="
"$BIN" sweep "${SWEEP_ARGS[@]}" \
  --compare-serial --out "$WORK/best.dcbc" --json BENCH_sweep.json
cat BENCH_sweep.json

echo "== cold --no-abandon reference (same surface) =="
"$BIN" sweep "${SWEEP_ARGS[@]}" \
  --no-abandon --cold --out "$WORK/cold.dcbc" --json "$WORK/noab.json"

echo "== argmin-mode (byte-budget-only) run =="
"$BIN" sweep "${SWEEP_ARGS[@]}" \
  --abandon-argmin --json "$WORK/argmin.json"

echo "== warm/cold winning containers byte-identical =="
cmp "$WORK/best.dcbc" "$WORK/cold.dcbc"
echo "identical"

echo "== BENCH_sweep.json well-formed + frontier equality across modes =="
python3 - "$WORK" <<'EOF'
import json, sys

work = sys.argv[1]
b = json.load(open("BENCH_sweep.json"))
noab = json.load(open(f"{work}/noab.json"))
argmin = json.load(open(f"{work}/argmin.json"))

assert b["bench"] == "sweep", b
for key in ("model", "workers", "points_per_round", "rounds", "probes_total",
            "probes_abandoned", "abandoned_mid_layer", "abandoned_boundary",
            "abandon_mode", "warm_start", "seeded_weights", "seed_hits",
            "seed_hit_rate", "lambdas", "lambda_columns", "best_s",
            "best_lambda", "best_bytes", "wall_s", "wall_s_serial", "points",
            "frontier", "columns"):
    assert key in b, f"missing {key}"
assert b["workers"] == 4 and b["points_per_round"] == 5
assert b["abandon_mode"] == "frontier" and b["warm_start"] is True
assert noab["abandon_mode"] == "off" and noab["warm_start"] is False
assert argmin["abandon_mode"] == "argmin"
assert b["lambda_columns"] == 3 and len(b["lambdas"]) == 3
assert b["probes_total"] == len(b["points"]) > 15, "refinement never ran"
assert b["rounds"] > 1, "refinement never ran"

# warm start really seeded the refinement rounds, and the hit rate on
# neighbouring-Δ seeds must be high; the cold reference never seeds
assert b["seeded_weights"] > 0, "warm run never seeded a probe"
assert 0.5 < b["seed_hit_rate"] <= 1.0, b["seed_hit_rate"]
assert b["seed_hits"] <= b["seeded_weights"]
assert noab["seeded_weights"] == 0 and noab["seed_hit_rate"] == 0.0

# abandonment bookkeeping: reasons partition the abandoned set
assert sum(p["abandoned"] for p in b["points"]) == b["probes_abandoned"]
assert b["abandoned_mid_layer"] + b["abandoned_boundary"] == b["probes_abandoned"]
for p in b["points"]:
    assert p["abandoned"] == (p["abandon_reason"] is not None), p
    if p["abandon_reason"] is not None:
        assert p["abandon_reason"] in ("mid-layer", "layer-boundary"), p
assert noab["probes_abandoned"] == 0

# the aggressive argmin mode must still abandon probes on this surface
assert argmin["probes_abandoned"] > 0, "argmin mode abandoned nothing"

completed = [p for p in b["points"] if not p["abandoned"]]
assert completed and min(p["bytes"] for p in completed) == b["best_bytes"]
assert 0 <= b["best_s"] <= 256

# per-column argmins: each column's best is the min over its completed
# points, and all three modes agree on every argmin + the winner
assert len(b["columns"]) == 3
for col in b["columns"]:
    col_completed = [p["bytes"] for p in completed
                     if p["lambda_scale"] == col["lambda_scale"]]
    assert col_completed and min(col_completed) == col["best_bytes"], col
    assert col["probes"] >= 5, col
for other in (noab, argmin):
    assert other["best_bytes"] == b["best_bytes"]
    assert other["best_s"] == b["best_s"] and other["best_lambda"] == b["best_lambda"]
    for ca, cb in zip(b["columns"], other["columns"]):
        assert (ca["lambda_scale"], ca["best_s"], ca["best_bytes"]) == \
               (cb["lambda_scale"], cb["best_s"], cb["best_bytes"]), (ca, cb)

# ACCEPTANCE: the frontier under dominance-based abandonment equals the
# --no-abandon frontier exactly (same points, same order)
fr = [(q["s"], q["lambda_scale"], q["bytes"], q["distortion"]) for q in b["frontier"]]
fr_noab = [(q["s"], q["lambda_scale"], q["bytes"], q["distortion"])
           for q in noab["frontier"]]
assert fr == fr_noab, f"frontier changed under abandonment:\n{fr}\nvs\n{fr_noab}"

# near-monotone container size along λ at fixed S (the coarse grid is
# probed in every column and never abandoned; adaptive contexts give no
# strict pointwise guarantee, so allow 0.5% + 2 bytes of slack like the
# bytes_near_monotone_along_lambda_at_fixed_s unit test)
by_s = {}
for p in completed:
    by_s.setdefault(p["s"], []).append((p["lambda_scale"], p["bytes"]))
checked = 0
lo_total = hi_total = 0
for s, pts in sorted(by_s.items()):
    pts.sort()
    for (_, a), (_, bb) in zip(pts, pts[1:]):
        assert bb <= a + a // 200 + 2, f"S={s}: bytes grew with lambda: {pts}"
    if len(pts) >= 3:
        checked += 1
        lo_total += pts[0][1]
        hi_total += pts[-1][1]
assert checked >= 5, f"only {checked} S values probed across all 3 columns"
# across the whole lambda range the rate saving must be real in aggregate
assert hi_total < lo_total, f"lambda=0.2 not smaller than 0.01 in aggregate: {hi_total} vs {lo_total}"

# frontier: non-empty, non-dominated vs every completed point, sorted by
# bytes, and covering both extreme points of the completed grid
f = b["frontier"]
assert len(f) >= 2, f
fb = [q["bytes"] for q in f]
assert fb == sorted(fb)
fd = [q["distortion"] for q in f]
assert fd == sorted(fd, reverse=True), "frontier distortion not monotone"
for q in f:
    for p in completed:
        dominates = (p["bytes"] <= q["bytes"] and p["distortion"] <= q["distortion"]
                     and (p["bytes"] < q["bytes"] or p["distortion"] < q["distortion"]))
        assert not dominates, f"frontier point {q} dominated by {p}"
min_bytes = min(p["bytes"] for p in completed)
min_dist = min(p["distortion"] for p in completed)
assert any(q["bytes"] == min_bytes for q in f), "min-bytes point not on frontier"
assert any(q["distortion"] == min_dist for q in f), "min-distortion point not on frontier"
assert b["best_bytes"] == min_bytes

print(f"BENCH_sweep.json OK: {b['probes_total']} probes / {b['rounds']} rounds "
      f"across {b['lambda_columns']} lambda-columns, "
      f"{b['probes_abandoned']} abandoned (frontier mode; argmin mode "
      f"{argmin['probes_abandoned']}), seed hit-rate {b['seed_hit_rate']:.3f}, "
      f"frontier {len(f)} points == no-abandon frontier, "
      f"best (S={b['best_s']}, lambda={b['best_lambda']}) = {b['best_bytes']} bytes, "
      f"wall {b['wall_s']:.2f}s vs serial {b['wall_s_serial']:.2f}s")
EOF

echo "== frontier-argmin container roundtrips =="
"$BIN" decompress --in "$WORK/best.dcbc" --out-dir "$WORK/out"
N=$(ls "$WORK/out"/*.npy | wc -l)
[ "$N" -gt 0 ] || { echo "no tensors decoded"; exit 1; }
echo "decoded $N tensors from the frontier-argmin container"

echo "== frontier output selection (--select-lambda) =="
"$BIN" sweep --arch mobilenet --scale 8 --points 3 --workers 2 \
  --lambdas 0.05,0.2 --select-lambda 0.2 \
  --out "$WORK/col.dcbc" --json "$WORK/col.json"
"$BIN" decompress --in "$WORK/col.dcbc" --out-dir "$WORK/colout"
M=$(ls "$WORK/colout"/*.npy | wc -l)
[ "$M" -gt 0 ] || { echo "no tensors decoded from the lambda-column argmin"; exit 1; }

echo "== lambda-grid / switch error paths =="
if "$BIN" sweep --arch mobilenet --scale 8 --points 3 --lambdas "," \
     --json "$WORK/x.json" 2>/dev/null; then
  echo "empty lambda grid must fail"; exit 1
fi
if "$BIN" sweep --arch mobilenet --scale 8 --points 3 --lambdas 0.05 \
     --select-lambda 0.9 --out "$WORK/y.dcbc" --json "$WORK/y.json" 2>/dev/null; then
  echo "select-lambda outside the grid must fail"; exit 1
fi
if "$BIN" sweep --arch mobilenet --scale 8 --points 3 \
     --no-abandon --abandon-argmin --json "$WORK/z.json" 2>/dev/null; then
  echo "--no-abandon with --abandon-argmin must fail"; exit 1
fi
if "$BIN" sweep --arch mobilenet --scale 8 --points 3 \
     --cold --warm-start --json "$WORK/z.json" 2>/dev/null; then
  echo "--cold with --warm-start must fail"; exit 1
fi
echo "sweep misuse rejected as expected"

echo "== markdown fragment for EXPERIMENTS.md =="
python3 scripts/bench_report.py BENCH_sweep.json > BENCH_sweep.md
cat BENCH_sweep.md
