#!/usr/bin/env bash
# Delta-delivery smoke test, run by CI from the rust/ directory:
#   1. synthesize a base container and a 2%-perturbed target through the
#      identical compression path (--perturb-density 0 vs 0.02)
#   2. delta-encode and verify the offline apply reconstructs the target
#      container byte-for-byte
#   3. serve the target + its delta segment; `fetch --from base` must
#      reconstruct the same tensors as batch decompress of the target
#   4. hostile ?from=: a known full-container fingerprint with no delta
#      is 409, garbage is 404, a missing param is 400 — never a hang
#   5. `delta bench` leaves BENCH_delta.json for upload; the delta must
#      be <= 25% of the full container at 2% update density
set -euo pipefail

BIN=${BIN:-target/release/deepcabac}
WORK=$(mktemp -d)
mkdir -p "$WORK/models"

cleanup() {
  [ -n "${SERVER_PID:-}" ] && kill "$SERVER_PID" 2>/dev/null || true
  rm -rf "$WORK"
}
trap cleanup EXIT

echo "== synth base + 2%-perturbed target =="
# --lambda-scale 0: pure nearest-neighbour quantization, so the sparse
# perturbation stays sparse in level space and the delta stays small
"$BIN" synth --arch mobilenet --scale 32 --s 40 --chunks 4 --lambda-scale 0 \
  --perturb-density 0 --out "$WORK/base.dcbc"
"$BIN" synth --arch mobilenet --scale 32 --s 40 --chunks 4 --lambda-scale 0 \
  --perturb-density 0.02 --perturb-scale 0.02 --out "$WORK/models/mobilenet.dcbc"

echo "== delta encode + offline apply round trip =="
"$BIN" delta encode --parent "$WORK/base.dcbc" \
  --target "$WORK/models/mobilenet.dcbc" \
  --out "$WORK/models/mobilenet_update.dcbc" --workers 4
"$BIN" delta apply --parent "$WORK/base.dcbc" \
  --delta "$WORK/models/mobilenet_update.dcbc" \
  --out "$WORK/applied.dcbc" --workers 4
cmp "$WORK/applied.dcbc" "$WORK/models/mobilenet.dcbc"
echo "offline apply is byte-identical to the target container"

echo "== start server on an ephemeral port =="
"$BIN" serve --dir "$WORK/models" --addr 127.0.0.1:0 --cache-mb 32 --workers 4 \
  > "$WORK/serve.log" 2>&1 &
SERVER_PID=$!

ADDR=""
for _ in $(seq 1 100); do
  ADDR=$(sed -n 's#^listening on http://##p' "$WORK/serve.log" | head -n1)
  [ -n "$ADDR" ] && break
  kill -0 "$SERVER_PID" 2>/dev/null || { cat "$WORK/serve.log"; exit 1; }
  sleep 0.1
done
[ -n "$ADDR" ] || { echo "server never announced its port"; cat "$WORK/serve.log"; exit 1; }
echo "server at $ADDR"

echo "== incremental fetch (--from) vs batch decompress =="
"$BIN" fetch --url "http://$ADDR/models/mobilenet" --from "$WORK/base.dcbc" \
  --out-dir "$WORK/fetched"
"$BIN" decompress --in "$WORK/models/mobilenet.dcbc" --out-dir "$WORK/batch"
for f in "$WORK/batch"/*.npy; do
  cmp "$f" "$WORK/fetched/$(basename "$f")"
done
echo "all tensors byte-identical through the delta path"

echo "== hostile ?from= =="
# the target's own fingerprint is a known full container with no delta
# from it: the server must answer 409 Conflict, and fetch must surface it
if "$BIN" fetch --url "http://$ADDR/models/mobilenet" \
    --from "$WORK/models/mobilenet.dcbc" --out-dir "$WORK/conflict" \
    2> "$WORK/err409"; then
  echo "expected fetch --from <no-delta base> to fail with 409"; exit 1
fi
grep -q "409" "$WORK/err409"
echo "stale-but-known base correctly answered 409"
python3 - "$ADDR" <<'EOF'
import http.client, sys
addr = sys.argv[1]
host, port = addr.rsplit(":", 1)
for path, want in [
    ("/models/mobilenet/delta?from=0000000000000000", 404),  # unknown fp
    ("/models/mobilenet/delta?from=zzzz", 404),              # not hex
    ("/models/mobilenet/delta", 404),                        # missing param
    ("/models/nosuch/delta?from=0000000000000000", 404),     # unknown model
]:
    c = http.client.HTTPConnection(host, int(port), timeout=10)
    c.request("GET", path)
    r = c.getresponse()
    r.read()
    assert r.status == want, f"{path}: got {r.status}, want {want}"
    c.close()
print("hostile ?from= requests answered with clean 4xx, no hangs")
EOF

echo "== delta bench =="
"$BIN" delta bench --parent "$WORK/base.dcbc" \
  --target "$WORK/models/mobilenet.dcbc" --iters 48 --workers 4 \
  --json BENCH_delta.json
python3 - <<'EOF'
import json
j = json.load(open("BENCH_delta.json"))
ratio = j["delta_ratio"]
assert ratio <= 0.25, f"delta is {ratio:.1%} of the full container (want <= 25%)"
print(f"delta ratio {ratio:.1%} of full, apply p50 {j['apply_p50_ms']:.2f} ms, "
      f"p99 {j['apply_p99_ms']:.2f} ms over {j['apply_iters']:.0f} iters")
EOF
