#!/usr/bin/env bash
# Serve-path smoke test, run by CI from the rust/ directory:
#   1. synthesize a chunked .dcbc container
#   2. start `deepcabac serve --event-loop` on an ephemeral port
#   3. `deepcabac fetch` the container through the streaming decoder and
#      diff every reconstructed tensor against the batch `decompress` path
#   4. run a 32-client loadgen with a 1..1024 connection-scaling sweep
#      and leave BENCH_serve.json for upload
#   5. prove the scaling claim: the event loop holds all 1024 keep-alive
#      sockets (reuse > 0); a --threaded server on the same directory
#      cannot (its sweep shows zero reuse and fewer established sockets)
set -euo pipefail

BIN=${BIN:-target/release/deepcabac}
WORK=$(mktemp -d)
mkdir -p "$WORK/models"

# 1024 concurrent sockets on each side needs headroom over the default
# 1024 fd soft limit
ulimit -n 4096 || true

cleanup() {
  [ -n "${SERVER_PID:-}" ] && kill "$SERVER_PID" 2>/dev/null || true
  [ -n "${THREADED_PID:-}" ] && kill "$THREADED_PID" 2>/dev/null || true
  rm -rf "$WORK"
}
trap cleanup EXIT

echo "== synth a chunked container =="
"$BIN" synth --arch mobilenet --scale 32 --s 40 --chunks 4 \
  --out "$WORK/models/mobilenet.dcbc"

echo "== start event-loop server on an ephemeral port =="
"$BIN" serve --dir "$WORK/models" --addr 127.0.0.1:0 --cache-mb 32 --workers 4 \
  --event-loop \
  > "$WORK/serve.log" 2>&1 &
SERVER_PID=$!

ADDR=""
for _ in $(seq 1 100); do
  ADDR=$(sed -n 's#^listening on http://##p' "$WORK/serve.log" | head -n1)
  [ -n "$ADDR" ] && break
  kill -0 "$SERVER_PID" 2>/dev/null || { cat "$WORK/serve.log"; exit 1; }
  sleep 0.1
done
[ -n "$ADDR" ] || { echo "server never announced its port"; cat "$WORK/serve.log"; exit 1; }
echo "server at $ADDR"

echo "== streaming fetch vs batch decompress =="
"$BIN" fetch --url "http://$ADDR/models/mobilenet" --out-dir "$WORK/fetched"
"$BIN" decompress --in "$WORK/models/mobilenet.dcbc" --out-dir "$WORK/batch"
for f in "$WORK/batch"/*.npy; do
  cmp "$f" "$WORK/fetched/$(basename "$f")"
done
echo "all tensors byte-identical"

echo "== single-layer random-access fetch =="
"$BIN" fetch --url "http://$ADDR/models/mobilenet" --layer 0 --out-dir "$WORK/single"

echo "== 32-client loadgen + connection-scaling sweep (event loop) =="
"$BIN" loadgen --url "http://$ADDR" --clients 32 --requests 8 \
  --connections-sweep 1,64,256,1024 --sweep-requests 3 --out BENCH_serve.json
cat BENCH_serve.json

echo "== threaded comparison server (same directory, same sweep point) =="
"$BIN" serve --dir "$WORK/models" --addr 127.0.0.1:0 --cache-mb 32 --workers 4 \
  --threaded --read-timeout 500 --write-timeout 1000 \
  > "$WORK/serve_threaded.log" 2>&1 &
THREADED_PID=$!
TADDR=""
for _ in $(seq 1 100); do
  TADDR=$(sed -n 's#^listening on http://##p' "$WORK/serve_threaded.log" | head -n1)
  [ -n "$TADDR" ] && break
  kill -0 "$THREADED_PID" 2>/dev/null || { cat "$WORK/serve_threaded.log"; exit 1; }
  sleep 0.1
done
[ -n "$TADDR" ] || { echo "threaded server never announced its port"; cat "$WORK/serve_threaded.log"; exit 1; }
echo "threaded server at $TADDR"
"$BIN" loadgen --url "http://$TADDR" --clients 4 --requests 4 \
  --connections-sweep 1024 --sweep-requests 1 --out "$WORK/threaded_sweep.json"

echo "== scaling assertions: keep-alive is real, and only the event loop scales =="
python3 - "$WORK/threaded_sweep.json" <<'PYEOF'
import json, sys

event = json.load(open("BENCH_serve.json"))
threaded = json.load(open(sys.argv[1]))

points = {p["connections"]: p for p in event["connection_scaling"]}
assert sorted(points) == [1, 64, 256, 1024], f"sweep points: {sorted(points)}"
top = points[1024]
assert top["established"] == 1024, (
    f"event loop must hold all 1024 sockets, established {top['established']}"
)
assert top["reused"] > 0 and top["reconnects"] == 0, (
    f"event keep-alive must be real: reused {top['reused']}, "
    f"reconnects {top['reconnects']}"
)
for p in points.values():
    assert p["p999_ms"] >= p["p99_ms"] >= p["p50_ms"] >= 0.0, p

t = threaded["connection_scaling"][0]
assert t["reused"] == 0, (
    f"threaded closes every connection, yet reused {t['reused']}"
)
assert t["established"] < 1024, (
    f"threaded should not hold 1024 concurrent sockets "
    f"(established {t['established']}) — if it does, the backlog "
    f"assumption changed and this gate needs a rethink"
)
print(
    f"event: 1024/1024 established, {top['reused']} reused; "
    f"threaded: {t['established']}/1024 established, 0 reused"
)
PYEOF
