#!/usr/bin/env bash
# Serve-path smoke test, run by CI from the rust/ directory:
#   1. synthesize a chunked .dcbc container
#   2. start `deepcabac serve` on an ephemeral port
#   3. `deepcabac fetch` the container through the streaming decoder and
#      diff every reconstructed tensor against the batch `decompress` path
#   4. run a 32-client loadgen and leave BENCH_serve.json for upload
set -euo pipefail

BIN=${BIN:-target/release/deepcabac}
WORK=$(mktemp -d)
mkdir -p "$WORK/models"

cleanup() {
  [ -n "${SERVER_PID:-}" ] && kill "$SERVER_PID" 2>/dev/null || true
  rm -rf "$WORK"
}
trap cleanup EXIT

echo "== synth a chunked container =="
"$BIN" synth --arch mobilenet --scale 32 --s 40 --chunks 4 \
  --out "$WORK/models/mobilenet.dcbc"

echo "== start server on an ephemeral port =="
"$BIN" serve --dir "$WORK/models" --addr 127.0.0.1:0 --cache-mb 32 --workers 4 \
  > "$WORK/serve.log" 2>&1 &
SERVER_PID=$!

ADDR=""
for _ in $(seq 1 100); do
  ADDR=$(sed -n 's#^listening on http://##p' "$WORK/serve.log" | head -n1)
  [ -n "$ADDR" ] && break
  kill -0 "$SERVER_PID" 2>/dev/null || { cat "$WORK/serve.log"; exit 1; }
  sleep 0.1
done
[ -n "$ADDR" ] || { echo "server never announced its port"; cat "$WORK/serve.log"; exit 1; }
echo "server at $ADDR"

echo "== streaming fetch vs batch decompress =="
"$BIN" fetch --url "http://$ADDR/models/mobilenet" --out-dir "$WORK/fetched"
"$BIN" decompress --in "$WORK/models/mobilenet.dcbc" --out-dir "$WORK/batch"
for f in "$WORK/batch"/*.npy; do
  cmp "$f" "$WORK/fetched/$(basename "$f")"
done
echo "all tensors byte-identical"

echo "== single-layer random-access fetch =="
"$BIN" fetch --url "http://$ADDR/models/mobilenet" --layer 0 --out-dir "$WORK/single"

echo "== 32-client loadgen =="
"$BIN" loadgen --url "http://$ADDR" --clients 32 --requests 8 --out BENCH_serve.json
cat BENCH_serve.json
