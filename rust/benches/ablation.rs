//! Ablation benches (DESIGN.md experiment index Abl-ctx, Abl-eta):
//!
//! 1. **Coding stage** on identical quantized tensors: DeepCABAC
//!    (adaptive contexts) vs static arithmetic vs scalar Huffman vs
//!    CSR+Huffman vs fixed-length vs the scalar-entropy bound — the
//!    paper's §2 claim that CABAC produces "a bitstream with minimal
//!    redundancies".
//! 2. **RD coupling / η weighting** on a trained model: nearest
//!    neighbour (decoupled) vs RD λ>0 unweighted vs RD λ>0 with
//!    η = 1/σ² (paper eq. 1), with PJRT accuracy when artifacts exist.
//!
//! ```bash
//! cargo bench --offline --bench ablation
//! ```

use deepcabac::app;
use deepcabac::baselines::{csr, entropy_bits, fixed, huffman, static_arith};
use deepcabac::codec::{encode_levels, CodecConfig};
use deepcabac::coordinator::{compress_model, CompressionSpec};
use deepcabac::quant::QuantGrid;
use deepcabac::report::Table;
use deepcabac::runtime::Runtime;
use deepcabac::util::SplitMix64;

fn main() -> anyhow::Result<()> {
    coding_stage_ablation();
    if let Err(e) = eta_ablation() {
        eprintln!("(η ablation skipped: {e}; run `make artifacts` first)");
    }
    Ok(())
}

fn coding_stage_ablation() {
    println!("== ablation 1: coding stage (identical quantized levels) ==\n");
    let n = 500_000;
    let mut rng = SplitMix64::new(77);
    let mut w = vec![0.0f32; n];
    let mut s = vec![0.0f32; n];
    for i in 0..n {
        if rng.next_f64() < 0.1 {
            w[i] = rng.laplace(0.08) as f32;
        }
        s[i] = 0.02 + 0.05 * rng.next_f32();
    }
    let grid = QuantGrid::from_tensor(&w, &s, 64);
    let levels: Vec<i32> = w.iter().map(|&x| grid.nearest_level(x)).collect();

    let cfg = CodecConfig::default();
    let cfg_noctx = CodecConfig { sig_ctx_neighbors: false, ..cfg };

    let deepcabac = encode_levels(&levels, cfg).len();
    let deepcabac_1ctx = encode_levels(&levels, cfg_noctx).len();
    let stat = static_arith::encode(&levels, cfg_noctx).unwrap().len();
    let huff = huffman::encode(&levels).unwrap().len();
    let csr_h = csr::encode(&levels, csr::CsrConfig::default()).unwrap().len();
    let fixedlen = fixed::encode(&levels).len();
    let bound = (entropy_bits(&levels) / 8.0).ceil() as usize;

    let mut t = Table::new(&["coder", "bytes", "bits/weight", "vs entropy bound"]);
    for (name, bytes) in [
        ("scalar entropy bound (H0)", bound),
        ("DeepCABAC (adaptive + neighbor ctx)", deepcabac),
        ("DeepCABAC (adaptive, single sig ctx)", deepcabac_1ctx),
        ("static binary arithmetic (frozen p)", stat),
        ("scalar Huffman (Deep Compression)", huff),
        ("CSR(4-bit runs)+Huffman (Han fmt)", csr_h),
        ("fixed-length", fixedlen),
    ] {
        t.row(vec![
            name.to_string(),
            bytes.to_string(),
            format!("{:.4}", bytes as f64 * 8.0 / n as f64),
            format!("{:.3}x", bytes as f64 / bound as f64),
        ]);
    }
    println!("{}", t.render());
    println!(
        "note: DeepCABAC beats the *scalar* bound H0 when conditional\n\
         statistics (runs of zeros) carry information the scalar bound ignores.\n"
    );
}

fn eta_ablation() -> anyhow::Result<()> {
    println!("== ablation 2: RD coupling + η weighting (paper eq. 1) ==\n");
    let model = app::load_model("lenet300")?;
    let rt = Runtime::cpu()?;
    let mut t = Table::new(&["variant", "S", "bytes", "accuracy", "Δacc vs orig"]);
    let before = app::evaluate_original(&rt, &model)?.metric;

    for (name, lambda_scale, weighted, s) in [
        ("nearest-neighbour (decoupled)", 0.0f32, true, 64u32),
        ("RD coupled, uniform η", 0.25, false, 64),
        ("RD coupled, η = 1/σ² (paper)", 0.25, true, 64),
        ("RD coupled, η = 1/σ², coarse S", 0.25, true, 8),
    ] {
        let spec = CompressionSpec {
            s,
            lambda_scale,
            weighted,
            ..Default::default()
        };
        let (compressed, report) = compress_model(&model, &spec, 1);
        let acc = app::evaluate_compressed(&rt, &model, &compressed)?.metric;
        t.row(vec![
            name.to_string(),
            s.to_string(),
            report.compressed_bytes.to_string(),
            format!("{acc:.4}"),
            format!("{:+.4}", acc - before),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}
