//! Bench: PJRT runtime latency — HLO compile time and per-batch forward
//! latency for every model artifact. This is the L2/L3 boundary the
//! accuracy evaluations pay for; it must not dominate the pipeline.
//!
//! ```bash
//! cargo bench --offline --bench runtime
//! ```

use deepcabac::app;
use deepcabac::report::Table;
use deepcabac::runtime::Runtime;
use deepcabac::tensor::Tensor;
use deepcabac::util::bench::bench;
use deepcabac::util::Timer;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::cpu()?;
    println!("PJRT runtime benches (platform = {})\n", rt.platform());
    let mut t = Table::new(&[
        "model", "compile[s]", "fwd/batch median[ms]", "samples/s", "batch",
    ]);

    for name in app::SMALL_MODELS {
        let model = match app::load_model(name) {
            Ok(m) => m,
            Err(e) => {
                eprintln!("{name}: skipped ({e})");
                continue;
            }
        };
        let timer = Timer::new();
        let hlo = app::artifacts_dir().join(&model.manifest.hlo);
        let exe = rt.load_hlo_text(&hlo)?;
        let compile_s = timer.elapsed_s();

        let (x, _) = app::load_eval_set(name)?;
        let batch = model.manifest.eval_batch;
        let sample: usize = x.shape[1..].iter().product();
        let mut shape = x.shape.clone();
        shape[0] = batch;
        let xb = Tensor::new(shape, x.data[..batch * sample].to_vec());
        let mut args: Vec<Tensor> = Vec::new();
        for (w, b) in model.weights.iter().zip(&model.biases) {
            args.push(w.clone());
            args.push(b.clone());
        }
        args.push(xb);

        let stats = bench(1, 5, || exe.run_f32(&args).unwrap());
        t.row(vec![
            name.to_string(),
            format!("{compile_s:.2}"),
            format!("{:.1}", stats.median_s * 1e3),
            format!("{:.0}", batch as f64 / stats.median_s),
            batch.to_string(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}
