//! Bench: regenerate Table 1 (compression side) and time each stage —
//! the per-model end-to-end target the paper's evaluation is built on.
//! Accuracy columns come from `examples/table1.rs` (PJRT eval); this
//! bench focuses on sizes + pipeline wall time so it stays fast enough
//! to run under `cargo bench`.
//!
//! ```bash
//! cargo bench --offline --bench table1
//! ```

use deepcabac::app;
use deepcabac::coordinator::{sweep::default_s_grid, CompressionSpec};
use deepcabac::report::{human_bytes, Table};
use deepcabac::synth::Arch;
use deepcabac::util::Timer;

fn main() -> anyhow::Result<()> {
    let spec = CompressionSpec::default();
    let s_grid = default_s_grid(9); // coarser than the example: bench speed

    let mut t = Table::new(&[
        "row", "org size", "spars[%]", "ratio[%]", "x", "paper ratio[%]", "time[s]",
    ]);

    for name in app::SMALL_MODELS {
        let timer = Timer::new();
        match app::table1_small_row(name, &s_grid, &spec, 1, false) {
            Ok(row) => {
                t.row(vec![
                    name.to_string(),
                    human_bytes(row.org_bytes),
                    format!("{:.2}", row.sparsity_pct),
                    format!("{:.2}", row.ratio_pct),
                    format!("x{:.1}", row.report.factor()),
                    paper_ratio(name).to_string(),
                    format!("{:.2}", timer.elapsed_s()),
                ]);
            }
            Err(e) => {
                eprintln!("{name}: skipped ({e}); run `make artifacts`");
            }
        }
    }

    for arch in [Arch::Vgg16, Arch::ResNet50, Arch::MobileNetV1] {
        let timer = Timer::new();
        let row = app::table1_large_row(arch, 8, &s_grid, &spec, 1, 42)?;
        t.row(vec![
            format!("{}*", arch.name()),
            human_bytes(row.org_bytes),
            format!("{:.2}", row.sparsity_pct),
            format!("{:.2}", row.ratio_pct),
            format!("x{:.1}", row.report.factor()),
            paper_ratio(arch.name()).to_string(),
            format!("{:.2}", timer.elapsed_s()),
        ]);
    }

    println!("{}", t.render());
    println!("* synthetic weights at true layer shapes, 1/8 channel scale (DESIGN.md §5)");
    Ok(())
}

fn paper_ratio(name: &str) -> &'static str {
    match name {
        "lenet300" => "1.82",
        "lenet5" => "0.72",
        "smallvgg" => "1.6",
        "fcae" => "16.15",
        "vgg16" => "1.57",
        "resnet50" => "5.95",
        "mobilenet-v1" => "12.7",
        _ => "-",
    }
}
