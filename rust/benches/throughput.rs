//! §Perf throughput benches — the L3 hot paths.
//!
//! Measures: CABAC encode/decode (Mbins/s and Mweights/s on realistic
//! sparse tensors), the coupled RD quantizer (Mweights/s), chunked
//! intra-layer parallel encode/decode, and the baselines for context.
//! These are the before/after numbers tracked in EXPERIMENTS.md §Perf.
//!
//! ```bash
//! cargo bench --offline --bench throughput             # human output
//! cargo bench --offline --bench throughput -- --json   # + BENCH_throughput.json
//! cargo bench --offline --bench throughput -- --n 100000   # CI smoke size
//! ```
//!
//! `--json [PATH]` writes machine-readable results (name → Mweights/s,
//! bits/weight) so the perf trajectory is tracked across PRs.

use std::collections::BTreeMap;

use deepcabac::baselines::{csr, fixed, huffman};
use deepcabac::codec::{decode_levels, encode_levels, CodecConfig};
use deepcabac::coordinator::{compress_tensor, compress_tensor_chunked, CompressionSpec};
use deepcabac::quant::{QuantGrid, RdParams, RdQuantizer};
use deepcabac::util::bench::{bench, black_box, report_line};
use deepcabac::util::json::Json;
use deepcabac::util::SplitMix64;

fn sparse_tensor(n: usize, density: f64, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = SplitMix64::new(seed);
    let mut w = vec![0.0f32; n];
    let mut s = vec![0.0f32; n];
    for i in 0..n {
        if rng.next_f64() < density {
            w[i] = rng.laplace(0.08) as f32;
        }
        s[i] = 0.02 + 0.05 * rng.next_f32();
    }
    (w, s)
}

/// Collects (name, mweights_per_s, bits_per_weight) rows for --json.
struct Results {
    rows: Vec<(String, f64, Option<f64>)>,
}

impl Results {
    fn push(&mut self, name: &str, mws: f64, bpw: Option<f64>) {
        self.rows.push((name.to_string(), mws, bpw));
    }

    fn to_json(&self, n: usize) -> Json {
        let mut results = BTreeMap::new();
        for (name, mws, bpw) in &self.rows {
            let mut entry = BTreeMap::new();
            entry.insert("mweights_per_s".to_string(), Json::Num(*mws));
            if let Some(b) = bpw {
                entry.insert("bits_per_weight".to_string(), Json::Num(*b));
            }
            results.insert(name.clone(), Json::Obj(entry));
        }
        let mut top = BTreeMap::new();
        top.insert("bench".to_string(), Json::Str("throughput".to_string()));
        top.insert("n_weights".to_string(), Json::Num(n as f64));
        top.insert("density".to_string(), Json::Num(0.10));
        top.insert("results".to_string(), Json::Obj(results));
        Json::Obj(top)
    }
}

fn main() {
    // hand-rolled flags (clap is not in the offline registry):
    //   --n N          fixture size in weights (default 1M)
    //   --json [PATH]  write machine-readable results (default
    //                  BENCH_throughput.json in the workspace root)
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut n = 1_000_000usize;
    let mut json_path: Option<String> = None;
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "--n" => {
                n = argv.get(i + 1).and_then(|v| v.parse().ok()).unwrap_or_else(|| {
                    eprintln!("--n expects an integer");
                    std::process::exit(2);
                });
                i += 2;
            }
            "--json" => {
                let next = argv.get(i + 1).filter(|v| !v.starts_with("--"));
                json_path = Some(
                    next.cloned().unwrap_or_else(|| "BENCH_throughput.json".to_string()),
                );
                i += if next.is_some() { 2 } else { 1 };
            }
            "--bench" => i += 1, // passed through by `cargo bench`
            other => {
                eprintln!("ignoring unknown flag {other:?}");
                i += 1;
            }
        }
    }
    let mut out = Results { rows: Vec::new() };

    println!("== throughput (n = {n} weights, 10% dense) ==\n");
    let (w, s) = sparse_tensor(n, 0.10, 3);
    let grid = QuantGrid::from_tensor(&w, &s, 64);
    let levels: Vec<i32> = w.iter().map(|&x| grid.nearest_level(x)).collect();
    let cfg = CodecConfig::default();

    // ---- entropy coding ----------------------------------------------
    let st = bench(1, 7, || encode_levels(black_box(&levels), cfg));
    report_line("cabac encode (levels→payload)", &st, n as f64, "Mweights/s");
    let payload = encode_levels(&levels, cfg);
    let bpw = payload.len() as f64 * 8.0 / n as f64;
    out.push("cabac_encode", st.throughput(n as f64) / 1e6, Some(bpw));
    println!(
        "{:<44}         {:>8} bytes  ({:.3} bits/weight)",
        "  payload", payload.len(), bpw
    );
    let st = bench(1, 7, || decode_levels(black_box(&payload), n, cfg));
    report_line("cabac decode (payload→levels)", &st, n as f64, "Mweights/s");
    out.push("cabac_decode", st.throughput(n as f64) / 1e6, Some(bpw));

    let st = bench(1, 7, || huffman::encode(black_box(&levels)).unwrap());
    report_line("huffman encode (baseline)", &st, n as f64, "Mweights/s");
    out.push("huffman_encode", st.throughput(n as f64) / 1e6, None);
    let hpayload = huffman::encode(&levels).unwrap();
    let st = bench(1, 7, || huffman::decode(black_box(&hpayload)).unwrap());
    report_line("huffman decode (baseline)", &st, n as f64, "Mweights/s");
    out.push("huffman_decode", st.throughput(n as f64) / 1e6, None);
    let st = bench(1, 7, || csr::encode(black_box(&levels), csr::CsrConfig::default()).unwrap());
    report_line("csr encode (baseline)", &st, n as f64, "Mweights/s");
    out.push("csr_encode", st.throughput(n as f64) / 1e6, None);
    let st = bench(1, 7, || fixed::encode(black_box(&levels)));
    report_line("fixed-length encode (floor)", &st, n as f64, "Mweights/s");
    out.push("fixed_encode", st.throughput(n as f64) / 1e6, None);

    // ---- coupled RD quantization ---------------------------------------
    println!();
    let q = RdQuantizer::new(cfg);
    let mean_eta = {
        let etas: f64 = s.iter().map(|&x| 1.0 / (x as f64 * x as f64)).sum();
        (etas / n as f64) as f32
    };
    let etas: Vec<f32> = s.iter().map(|&x| 1.0 / (x * x)).collect();
    for lambda_scale in [0.0f32, 0.05] {
        let lambda = lambda_scale * grid.delta * grid.delta * mean_eta;
        let st = bench(1, 5, || {
            q.quantize_encode(
                black_box(&w),
                black_box(&etas),
                &grid,
                RdParams { lambda },
            )
        });
        report_line(
            &format!("rd quantize+encode (λscale={lambda_scale})"),
            &st,
            n as f64,
            "Mweights/s",
        );
        out.push(
            &format!("rd_quantize_encode_lambda{lambda_scale}"),
            st.throughput(n as f64) / 1e6,
            None,
        );
    }

    // ---- full pipeline (grid + η + RD + CABAC) -------------------------
    println!();
    let spec = CompressionSpec { s: 64, lambda_scale: 0.05, ..Default::default() };
    let st = bench(1, 5, || {
        compress_tensor("bench", &[n], black_box(&w), black_box(&s), &[], &spec)
    });
    report_line("compress_tensor (full pipeline)", &st, n as f64, "Mweights/s");
    out.push("compress_tensor", st.throughput(n as f64) / 1e6, None);

    // ---- chunked intra-layer parallelism -------------------------------
    println!();
    let workers = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let mono_payload = compress_tensor("bench", &[n], &w, &s, &[], &spec).0.payload;
    // bench N=4 plus N=cores, skipping degenerate/duplicate counts
    let mut chunk_counts = vec![4u32];
    if workers > 1 && workers != 4 {
        chunk_counts.push(workers as u32);
    }
    for chunks in chunk_counts {
        let cspec = CompressionSpec { chunks, ..spec };
        let st = bench(1, 5, || {
            compress_tensor_chunked(
                "bench",
                &[n],
                black_box(&w),
                black_box(&s),
                &[],
                &cspec,
                workers,
            )
        });
        report_line(
            &format!("chunked encode (N={chunks}, {workers} workers)"),
            &st,
            n as f64,
            "Mweights/s",
        );
        let (layer, _) = compress_tensor_chunked("bench", &[n], &w, &s, &[], &cspec, workers);
        let overhead =
            (layer.payload.len() as f64 / mono_payload.len() as f64 - 1.0) * 100.0;
        println!(
            "{:<44}         {:>8} bytes  ({overhead:+.3}% vs monolithic)",
            "  chunked payload", layer.payload.len()
        );
        out.push(
            &format!("chunked_encode_n{chunks}"),
            st.throughput(n as f64) / 1e6,
            Some(layer.payload.len() as f64 * 8.0 / n as f64),
        );
        let st = bench(1, 5, || black_box(&layer).decode_levels());
        report_line(
            &format!("chunked decode (N={chunks}, parallel)"),
            &st,
            n as f64,
            "Mweights/s",
        );
        out.push(&format!("chunked_decode_n{chunks}"), st.throughput(n as f64) / 1e6, None);
    }

    // ---- bins/s view ----------------------------------------------------
    let bins_per_weight = {
        // sig bin per weight + extra bins for nonzeros (~sign + ~1.5 gr)
        1.0 + 0.10 * 2.5
    };
    let st = bench(1, 7, || encode_levels(black_box(&levels), cfg));
    println!(
        "\ncabac engine ≈ {:.1} Mbins/s (at ~{:.2} bins/weight)",
        st.throughput(n as f64 * bins_per_weight) / 1e6,
        bins_per_weight
    );

    if let Some(path) = json_path {
        let doc = out.to_json(n);
        std::fs::write(&path, doc.to_string_pretty() + "\n").expect("writing bench json");
        println!("wrote {path}");
    }
}
