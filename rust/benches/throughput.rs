//! §Perf throughput benches — the L3 hot paths.
//!
//! Measures: CABAC encode/decode (Mbins/s and Mweights/s on realistic
//! sparse tensors), the coupled RD quantizer (Mweights/s), and the
//! baselines for context. These are the before/after numbers tracked in
//! EXPERIMENTS.md §Perf.
//!
//! ```bash
//! cargo bench --offline --bench throughput
//! ```

use deepcabac::baselines::{csr, fixed, huffman};
use deepcabac::codec::{decode_levels, encode_levels, CodecConfig};
use deepcabac::coordinator::{compress_tensor, CompressionSpec};
use deepcabac::quant::{QuantGrid, RdParams, RdQuantizer};
use deepcabac::util::bench::{bench, black_box, report_line};
use deepcabac::util::SplitMix64;

fn sparse_tensor(n: usize, density: f64, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = SplitMix64::new(seed);
    let mut w = vec![0.0f32; n];
    let mut s = vec![0.0f32; n];
    for i in 0..n {
        if rng.next_f64() < density {
            w[i] = rng.laplace(0.08) as f32;
        }
        s[i] = 0.02 + 0.05 * rng.next_f32();
    }
    (w, s)
}

fn main() {
    let n = 1_000_000;
    println!("== throughput (n = {n} weights, 10% dense) ==\n");
    let (w, s) = sparse_tensor(n, 0.10, 3);
    let grid = QuantGrid::from_tensor(&w, &s, 64);
    let levels: Vec<i32> = w.iter().map(|&x| grid.nearest_level(x)).collect();
    let cfg = CodecConfig::default();

    // ---- entropy coding ----------------------------------------------
    let st = bench(1, 7, || encode_levels(black_box(&levels), cfg));
    report_line("cabac encode (levels→payload)", &st, n as f64, "Mweights/s");
    let payload = encode_levels(&levels, cfg);
    println!(
        "{:<44}         {:>8} bytes  ({:.3} bits/weight)",
        "  payload", payload.len(),
        payload.len() as f64 * 8.0 / n as f64
    );
    let st = bench(1, 7, || decode_levels(black_box(&payload), n, cfg));
    report_line("cabac decode (payload→levels)", &st, n as f64, "Mweights/s");

    let st = bench(1, 7, || huffman::encode(black_box(&levels)).unwrap());
    report_line("huffman encode (baseline)", &st, n as f64, "Mweights/s");
    let hpayload = huffman::encode(&levels).unwrap();
    let st = bench(1, 7, || huffman::decode(black_box(&hpayload)).unwrap());
    report_line("huffman decode (baseline)", &st, n as f64, "Mweights/s");
    let st = bench(1, 7, || csr::encode(black_box(&levels), csr::CsrConfig::default()).unwrap());
    report_line("csr encode (baseline)", &st, n as f64, "Mweights/s");
    let st = bench(1, 7, || fixed::encode(black_box(&levels)));
    report_line("fixed-length encode (floor)", &st, n as f64, "Mweights/s");

    // ---- coupled RD quantization ---------------------------------------
    println!();
    let q = RdQuantizer::new(cfg);
    for lambda_scale in [0.0f32, 0.05] {
        let mean_eta = {
            let etas: f64 = s.iter().map(|&x| 1.0 / (x as f64 * x as f64)).sum();
            (etas / n as f64) as f32
        };
        let lambda = lambda_scale * grid.delta * grid.delta * mean_eta;
        let etas: Vec<f32> = s.iter().map(|&x| 1.0 / (x * x)).collect();
        let st = bench(1, 5, || {
            q.quantize_encode(
                black_box(&w),
                black_box(&etas),
                &grid,
                RdParams { lambda, window: 4 },
            )
        });
        report_line(
            &format!("rd quantize+encode (λscale={lambda_scale})"),
            &st,
            n as f64,
            "Mweights/s",
        );
    }

    // ---- full pipeline (grid + η + RD + CABAC) -------------------------
    println!();
    let spec = CompressionSpec { s: 64, lambda_scale: 0.05, ..Default::default() };
    let st = bench(1, 5, || {
        compress_tensor("bench", &[n], black_box(&w), black_box(&s), &[], &spec)
    });
    report_line("compress_tensor (full pipeline)", &st, n as f64, "Mweights/s");

    // ---- bins/s view ----------------------------------------------------
    let bins_per_weight = {
        // sig bin per weight + extra bins for nonzeros (~sign + ~1.5 gr)
        1.0 + 0.10 * 2.5
    };
    let st = bench(1, 7, || encode_levels(black_box(&levels), cfg));
    println!(
        "\ncabac engine ≈ {:.1} Mbins/s (at ~{:.2} bins/weight)",
        st.throughput(n as f64 * bins_per_weight) / 1e6,
        bins_per_weight
    );
}
