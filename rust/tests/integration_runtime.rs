//! Integration tests over the PJRT runtime + accuracy evaluation — the
//! L3 ↔ L2/L1 boundary. Skip gracefully without artifacts.

use deepcabac::app;
use deepcabac::coordinator::{compress_model, CompressionSpec};
use deepcabac::runtime::Runtime;

fn have_artifacts() -> bool {
    app::artifacts_dir().join("models/lenet300/manifest.json").exists()
}

/// Bound eval cost: the conv models' interpret-mode forwards are slow on
/// 1 CPU core; one 256-sample batch is plenty for an integration signal.
fn bound_eval() {
    std::env::set_var("DEEPCABAC_MAX_EVAL_BATCHES", "1");
}

#[test]
fn pjrt_loads_and_reproduces_training_metric() {
    if !have_artifacts() {
        eprintln!("skipped: no artifacts");
        return;
    }
    let model = app::load_model("lenet300").unwrap();
    bound_eval();
    let rt = Runtime::cpu().unwrap();
    let res = app::evaluate_original(&rt, &model).unwrap();
    // The Python trainer recorded sparse_metric on the same eval set; the
    // rust-side PJRT evaluation of the same weights must agree closely
    // (identical graph lowered once; only eval-set truncation differs).
    let py = model.manifest.sparse_metric;
    assert!(
        (res.metric - py).abs() < 0.02,
        "rust PJRT {} vs python {}",
        res.metric,
        py
    );
}

#[test]
fn compressed_accuracy_within_tolerance() {
    if !have_artifacts() {
        eprintln!("skipped: no artifacts");
        return;
    }
    bound_eval();
    let rt = Runtime::cpu().unwrap();
    for name in ["lenet300", "lenet5"] {
        let Ok(model) = app::load_model(name) else { continue };
        let before = app::evaluate_original(&rt, &model).unwrap().metric;
        let (compressed, report) =
            compress_model(&model, &CompressionSpec::default(), 1);
        let after = app::evaluate_compressed(&rt, &model, &compressed).unwrap().metric;
        assert!(report.factor() > 5.0);
        assert!(
            before - after < 0.02,
            "{name}: accuracy {before} -> {after} (factor x{:.1})",
            report.factor()
        );
    }
}

#[test]
fn autoencoder_psnr_path() {
    if !have_artifacts() || !app::artifacts_dir().join("models/fcae").exists() {
        eprintln!("skipped: no fcae artifacts");
        return;
    }
    let model = app::load_model("fcae").unwrap();
    bound_eval();
    let rt = Runtime::cpu().unwrap();
    let before = app::evaluate_original(&rt, &model).unwrap();
    assert!(before.metric > 10.0, "PSNR {} suspiciously low", before.metric);
    let (compressed, _) = compress_model(&model, &CompressionSpec::default(), 1);
    let after = app::evaluate_compressed(&rt, &model, &compressed).unwrap();
    assert!(
        before.metric - after.metric < 3.0,
        "PSNR dropped {} -> {}",
        before.metric,
        after.metric
    );
}
