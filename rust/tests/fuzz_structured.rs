//! Structure-aware fuzzing integration tests: the metered counterpart
//! to the `fuzz` module's unit tests. This binary installs the
//! [`CountingAlloc`] global allocator (the library deliberately never
//! does), so allocation budgets are *enforced* here, and replays the
//! checked-in crasher corpus exactly like the CI `fuzz-smoke` job.

use deepcabac::fuzz::alloc::{self, CountingAlloc};
use deepcabac::fuzz::{fuzz_target, replay_corpus, Budgets, TargetKind};
use std::path::PathBuf;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn corpus_root() -> PathBuf {
    // tests run with CWD = the crate root (rust/)
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("fuzz_corpus")
}

#[test]
fn metering_allocator_is_live() {
    assert!(
        alloc::probe(),
        "CountingAlloc is installed in this binary; the probe must see it"
    );
}

/// The acceptance gate: fixed-seed fuzzing over every target with
/// metered allocation budgets — zero invariant violations, and the
/// structure-aware mutator keeps ≥ 50 % of container cases alive past
/// the prelude (the coverage proxy: they reach layer/chunk handling,
/// which dumb-random inputs essentially never do).
#[test]
fn fixed_seed_fuzz_is_clean_and_penetrates_the_prelude() {
    let budgets = Budgets::default();
    for target in TargetKind::all() {
        let (stats, crashes) = fuzz_target(target, 300, 0xD5EE9CABAC, &budgets);
        assert_eq!(stats.cases, 300);
        assert!(stats.alloc_metered, "{}: alloc budget must be enforced", target.as_str());
        assert!(
            crashes.is_empty(),
            "{}: {} invariant violations, first: {} ({} bytes)",
            target.as_str(),
            crashes.len(),
            crashes[0].kind,
            crashes[0].input.len()
        );
        if matches!(target, TargetKind::Container | TargetKind::Stream) {
            assert!(
                stats.survival_ratio() >= 0.5,
                "{}: only {:.0}% of mutants survived the prelude (want >= 50%)",
                target.as_str(),
                stats.survival_ratio() * 100.0
            );
            // and some cases must be fully accepted (pristine + benign
            // mutants), or the roundtrip invariants went unexercised
            assert!(stats.accepted > 0, "{}: nothing accepted", target.as_str());
        }
    }
}

/// The checked-in corpus replays with zero crashes and every
/// `accept_`/`reject_` expectation holding — the regression gate that
/// keeps yesterday's crashers fixed.
#[test]
fn corpus_replays_clean() {
    let budgets = Budgets::default();
    let (stats, crashes) = replay_corpus(&corpus_root(), &budgets).unwrap();
    assert!(
        stats.cases > 0,
        "corpus at {:?} is missing — it is part of the repo",
        corpus_root()
    );
    assert!(
        crashes.is_empty(),
        "{} corpus regressions, first: [{}] {}",
        crashes.len(),
        crashes[0].target.as_str(),
        crashes[0].kind
    );
}

/// Same corpus, twice: identical counters. Replay is deterministic
/// (sorted paths, no randomness), so CI failures are reproducible.
#[test]
fn corpus_replay_is_deterministic() {
    let budgets = Budgets::default();
    let (s1, c1) = replay_corpus(&corpus_root(), &budgets).unwrap();
    let (s2, c2) = replay_corpus(&corpus_root(), &budgets).unwrap();
    assert_eq!(s1.cases, s2.cases);
    assert_eq!(s1.crashes, s2.crashes);
    assert_eq!(s1.survived_prefix, s2.survived_prefix);
    assert_eq!(s1.accepted, s2.accepted);
    assert_eq!(c1.len(), c2.len());
}

/// A pathological-but-parseable container (one layer claiming many
/// weights from a tiny payload, within the density guard) must stay
/// inside the per-case allocation budget — the guard caps decode-side
/// allocation, and the meter proves it.
#[test]
fn decode_allocation_stays_budgeted() {
    use deepcabac::model::CompressedModel;

    let mut rng = deepcabac::util::SplitMix64::new(9);
    let bytes = deepcabac::fuzz::gen::container(&mut rng);
    alloc::reset();
    let _ = CompressedModel::deserialize(&bytes);
    let peak = alloc::peak();
    assert!(
        peak < Budgets::default().alloc_bytes,
        "decoding a generated container peaked at {peak} bytes"
    );
}

/// `ddmin` must reset the allocation meter before every probe: this
/// predicate holds only while the candidate still allocates ≥ 32 KiB in
/// one go, and — crucially — it never resets the meter itself. Without
/// the per-probe reset every candidate would inherit the previous
/// probe's peak, every deletion would "hold", and the input would shrink
/// to nothing; with it, ddmin converges to exactly the 32-byte core.
#[test]
fn ddmin_resets_the_meter_between_probes() {
    let input = vec![7u8; 100];
    let holds = |buf: &[u8]| {
        // allocate 1 KiB per input byte, then ask the meter — a stand-in
        // for an alloc-budget crasher whose allocation scales with input
        let v = vec![0u8; buf.len() * 1024];
        std::hint::black_box(&v);
        alloc::peak() >= 32 * 1024
    };
    assert!(holds(&input), "the unminimized input must hold");
    let min = deepcabac::fuzz::ddmin(&input, holds, 4000);
    assert_eq!(
        min.len(),
        32,
        "meter-sensitive ddmin must converge to the 32-byte core, got {} bytes",
        min.len()
    );
}

// ---------------------------------------------------------------------------
// Coverage-guided layer (needs --features fuzz-cov to record edges)
// ---------------------------------------------------------------------------

/// Per-target unique-edge floors for replaying the checked-in corpus,
/// parsed from the committed `BENCH_fuzz_baseline.json` (the same file
/// the CI gate reads) — one source of truth for "the corpus exercises
/// at least this much of the parsers".
#[cfg(feature = "fuzz-cov")]
fn committed_floors() -> std::collections::BTreeMap<String, usize> {
    let raw = include_str!("../BENCH_fuzz_baseline.json");
    let j = deepcabac::util::json::Json::parse(raw).expect("baseline JSON parses");
    let obj = j.get("floors").expect("baseline has a floors object");
    let mut floors = std::collections::BTreeMap::new();
    for t in TargetKind::all() {
        let v = obj
            .get(t.as_str())
            .and_then(|v| v.as_usize())
            .unwrap_or_else(|| panic!("baseline floors missing target {}", t.as_str()));
        floors.insert(t.as_str().to_string(), v);
    }
    floors
}

/// The coverage-floor regression gate: replaying the full corpus with
/// instrumentation must light up at least the committed number of
/// unique edges per target. A refactor that quietly stops a corpus case
/// short of the deep parsing code fails here, not in production.
#[cfg(feature = "fuzz-cov")]
#[test]
fn corpus_coverage_meets_committed_floors() {
    let budgets = Budgets::default();
    let cov = deepcabac::fuzz::replay_corpus_coverage(&corpus_root(), &budgets).unwrap();
    let floors = committed_floors();
    for (target, edges) in &cov {
        let floor = floors[target.as_str()];
        assert!(
            edges.len() >= floor,
            "{}: corpus replay hit {} unique edges, committed floor is {}",
            target.as_str(),
            edges.len(),
            floor
        );
    }
}

/// Two instrumented replays of the same corpus produce the identical
/// edge map per target — coverage capture is deterministic, so floor
/// failures in CI reproduce locally.
#[cfg(feature = "fuzz-cov")]
#[test]
fn corpus_coverage_is_deterministic_across_replays() {
    let budgets = Budgets::default();
    let a = deepcabac::fuzz::replay_corpus_coverage(&corpus_root(), &budgets).unwrap();
    let b = deepcabac::fuzz::replay_corpus_coverage(&corpus_root(), &budgets).unwrap();
    assert_eq!(a.len(), b.len());
    for ((t1, e1), (t2, e2)) in a.iter().zip(&b) {
        assert_eq!(t1, t2);
        assert_eq!(e1, e2, "{}: edge sets differ between replays", t1.as_str());
    }
}

/// The tentpole acceptance criterion: at an equal execution budget, the
/// corpus-seeded evolve loop must discover strictly more unique edges
/// than the fixed-seed generate-and-mutate batch — on the container and
/// the delta-apply targets. The corpus seeds carry hand-built reject
/// cases (overlong varints, bad magic, hostile tier tables) the
/// generators essentially never produce, so evolution starts from
/// coverage the batch cannot reach and grows from there.
#[cfg(feature = "fuzz-cov")]
#[test]
fn evolve_beats_same_budget_batch_on_container_and_delta_apply() {
    use deepcabac::fuzz::{batch_coverage, corpus_groups, evolve_target, EvolveCfg};

    let budgets = Budgets::default();
    for target in [TargetKind::Container, TargetKind::DeltaApply] {
        let mut initial = Vec::new();
        for (sub, group) in corpus_groups() {
            if !group.contains(&target) {
                continue;
            }
            let dir = corpus_root().join(sub);
            let mut paths: Vec<_> = std::fs::read_dir(&dir)
                .unwrap()
                .filter_map(|e| e.ok().map(|e| e.path()))
                .filter(|p| p.is_file())
                .collect();
            paths.sort();
            for p in paths {
                initial.push(std::fs::read(&p).unwrap());
            }
        }
        assert!(!initial.is_empty(), "{}: no corpus seeds", target.as_str());
        let cfg = EvolveCfg { seed: 0xD5EE9CABAC, cases: 160, max_millis: 0, budgets, ..EvolveCfg::default() };
        let report = evolve_target(target, &cfg, &initial);
        let batch = batch_coverage(target, 160, 0xD5EE9CABAC, &budgets);
        assert!(
            report.crashes.is_empty(),
            "{}: evolve found {} crashes",
            target.as_str(),
            report.crashes.len()
        );
        assert!(
            report.unique_edges > batch,
            "{}: evolve hit {} unique edges, batch hit {} — evolution must win",
            target.as_str(),
            report.unique_edges,
            batch
        );
    }
}

/// Instrumented evolve is byte-reproducible under a fixed seed with the
/// metering allocator installed — the CI artifact (promoted finds +
/// BENCH_fuzz.json) is stable run to run.
#[cfg(feature = "fuzz-cov")]
#[test]
fn evolve_is_reproducible_with_instrumentation_and_metering() {
    use deepcabac::fuzz::{evolve_target, EvolveCfg};

    let cfg = EvolveCfg { seed: 99, cases: 80, ..EvolveCfg::default() };
    let a = evolve_target(TargetKind::Container, &cfg, &[]);
    let b = evolve_target(TargetKind::Container, &cfg, &[]);
    assert_eq!(a.unique_edges, b.unique_edges);
    assert_eq!(a.promoted, b.promoted);
    assert_eq!(a.discovery, b.discovery);
    assert_eq!(a.promoted_inputs, b.promoted_inputs);
}
