//! Structure-aware fuzzing integration tests: the metered counterpart
//! to the `fuzz` module's unit tests. This binary installs the
//! [`CountingAlloc`] global allocator (the library deliberately never
//! does), so allocation budgets are *enforced* here, and replays the
//! checked-in crasher corpus exactly like the CI `fuzz-smoke` job.

use deepcabac::fuzz::alloc::{self, CountingAlloc};
use deepcabac::fuzz::{fuzz_target, replay_corpus, Budgets, TargetKind};
use std::path::PathBuf;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn corpus_root() -> PathBuf {
    // tests run with CWD = the crate root (rust/)
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("fuzz_corpus")
}

#[test]
fn metering_allocator_is_live() {
    assert!(
        alloc::probe(),
        "CountingAlloc is installed in this binary; the probe must see it"
    );
}

/// The acceptance gate: fixed-seed fuzzing over every target with
/// metered allocation budgets — zero invariant violations, and the
/// structure-aware mutator keeps ≥ 50 % of container cases alive past
/// the prelude (the coverage proxy: they reach layer/chunk handling,
/// which dumb-random inputs essentially never do).
#[test]
fn fixed_seed_fuzz_is_clean_and_penetrates_the_prelude() {
    let budgets = Budgets::default();
    for target in TargetKind::all() {
        let (stats, crashes) = fuzz_target(target, 300, 0xD5EE9CABAC, &budgets);
        assert_eq!(stats.cases, 300);
        assert!(stats.alloc_metered, "{}: alloc budget must be enforced", target.as_str());
        assert!(
            crashes.is_empty(),
            "{}: {} invariant violations, first: {} ({} bytes)",
            target.as_str(),
            crashes.len(),
            crashes[0].kind,
            crashes[0].input.len()
        );
        if matches!(target, TargetKind::Container | TargetKind::Stream) {
            assert!(
                stats.survival_ratio() >= 0.5,
                "{}: only {:.0}% of mutants survived the prelude (want >= 50%)",
                target.as_str(),
                stats.survival_ratio() * 100.0
            );
            // and some cases must be fully accepted (pristine + benign
            // mutants), or the roundtrip invariants went unexercised
            assert!(stats.accepted > 0, "{}: nothing accepted", target.as_str());
        }
    }
}

/// The checked-in corpus replays with zero crashes and every
/// `accept_`/`reject_` expectation holding — the regression gate that
/// keeps yesterday's crashers fixed.
#[test]
fn corpus_replays_clean() {
    let budgets = Budgets::default();
    let (stats, crashes) = replay_corpus(&corpus_root(), &budgets).unwrap();
    assert!(
        stats.cases > 0,
        "corpus at {:?} is missing — it is part of the repo",
        corpus_root()
    );
    assert!(
        crashes.is_empty(),
        "{} corpus regressions, first: [{}] {}",
        crashes.len(),
        crashes[0].target.as_str(),
        crashes[0].kind
    );
}

/// Same corpus, twice: identical counters. Replay is deterministic
/// (sorted paths, no randomness), so CI failures are reproducible.
#[test]
fn corpus_replay_is_deterministic() {
    let budgets = Budgets::default();
    let (s1, c1) = replay_corpus(&corpus_root(), &budgets).unwrap();
    let (s2, c2) = replay_corpus(&corpus_root(), &budgets).unwrap();
    assert_eq!(s1.cases, s2.cases);
    assert_eq!(s1.crashes, s2.crashes);
    assert_eq!(s1.survived_prefix, s2.survived_prefix);
    assert_eq!(s1.accepted, s2.accepted);
    assert_eq!(c1.len(), c2.len());
}

/// A pathological-but-parseable container (one layer claiming many
/// weights from a tiny payload, within the density guard) must stay
/// inside the per-case allocation budget — the guard caps decode-side
/// allocation, and the meter proves it.
#[test]
fn decode_allocation_stays_budgeted() {
    use deepcabac::model::CompressedModel;

    let mut rng = deepcabac::util::SplitMix64::new(9);
    let bytes = deepcabac::fuzz::gen::container(&mut rng);
    alloc::reset();
    let _ = CompressedModel::deserialize(&bytes);
    let peak = alloc::peak();
    assert!(
        peak < Budgets::default().alloc_bytes,
        "decoding a generated container peaked at {peak} bytes"
    );
}
