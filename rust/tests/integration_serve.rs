//! End-to-end serving tests: a real `TcpListener` server over a temp
//! directory of `.dcbc` containers, exercised by the HTTP client, the
//! streaming decoder, and a ≥32-client loadgen run.

use deepcabac::codec::{encode_levels, CodecConfig, RemainderMode};
use deepcabac::delta;
use deepcabac::model::{fingerprint, ChunkInfo, CompressedLayer, CompressedModel, DeltaModel};
use deepcabac::quant::QuantGrid;
use deepcabac::serve::http;
use deepcabac::serve::loadgen::{self, LoadgenOptions};
use deepcabac::serve::server::{start, ServeOptions};
use deepcabac::serve::stream::{StreamDecoder, StreamEvent};
use deepcabac::util::json::Json;
use deepcabac::util::{fnv1a, SplitMix64};
use std::path::PathBuf;

fn make_layer(name: &str, n: usize, n_chunks: usize, seed: u64, cfg: CodecConfig) -> CompressedLayer {
    let mut rng = SplitMix64::new(seed);
    let levels: Vec<i32> = (0..n)
        .map(|_| {
            if rng.next_f64() < 0.75 {
                0
            } else {
                (1 + rng.below(25) as i32) * if rng.next_u64() & 1 == 0 { 1 } else { -1 }
            }
        })
        .collect();
    let n_chunks = n_chunks.max(1);
    let per = ((levels.len() + n_chunks - 1) / n_chunks).max(1);
    let mut payload = Vec::new();
    let mut chunks = Vec::new();
    for part in levels.chunks(per) {
        let bytes = encode_levels(part, cfg);
        chunks.push(ChunkInfo { n_weights: part.len(), bytes: bytes.len() });
        payload.extend_from_slice(&bytes);
    }
    if chunks.len() <= 1 {
        chunks.clear();
    }
    CompressedLayer {
        name: name.into(),
        dims: vec![n.max(4) / 4, 4],
        grid: QuantGrid { delta: 0.05, max_level: 30 },
        s_param: 12,
        cfg,
        n_weights: levels.len(),
        payload,
        chunks,
        bias: vec![0.5, -0.5],
    }
}

/// Two models on disk: one v1 (monolithic) and one v2 (chunked).
/// `tag` keeps the two tests (threads of one process) in separate dirs.
fn write_model_dir(tag: &str) -> (PathBuf, Vec<CompressedModel>) {
    let dir =
        std::env::temp_dir().join(format!("dcbc_serve_{}_{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = CodecConfig::default();
    let cfg2 = CodecConfig {
        n_abs_flags: 2,
        remainder: RemainderMode::ExpGolomb(1),
        sig_ctx_neighbors: false,
    };
    let alpha = CompressedModel {
        name: "alpha".into(),
        layers: vec![
            make_layer("conv1", 2000, 1, 1, cfg),
            make_layer("fc1", 400, 1, 2, cfg2),
        ],
    };
    let beta = CompressedModel {
        name: "beta".into(),
        layers: vec![
            make_layer("conv1", 3000, 4, 3, cfg),
            make_layer("conv2", 1500, 3, 4, cfg),
            make_layer("fc", 100, 1, 5, cfg),
        ],
    };
    std::fs::write(dir.join("alpha.dcbc"), alpha.serialize()).unwrap();
    std::fs::write(dir.join("beta.dcbc"), beta.serialize()).unwrap();
    (dir, vec![alpha, beta])
}

fn f32_le_bytes(w: &[f32]) -> Vec<u8> {
    w.iter().flat_map(|v| v.to_le_bytes()).collect()
}

#[test]
fn server_end_to_end() {
    let (dir, models) = write_model_dir("e2e");
    let handle = start(ServeOptions {
        dir: dir.clone(),
        addr: "127.0.0.1:0".into(),
        cache_bytes: 1 << 20,
        workers: 8,
        ..Default::default()
    })
    .unwrap();
    let addr = handle.addr().to_string();

    // -- listing + health -------------------------------------------------
    let resp = http::get(&addr, "/healthz", None).unwrap();
    assert_eq!(resp.status, 200);
    let resp = http::get(&addr, "/models", None).unwrap();
    assert_eq!(resp.status, 200);
    let listing = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
    let listed = listing.get("models").unwrap().as_arr().unwrap();
    assert_eq!(listed.len(), 2);
    assert_eq!(listed[0].get("name").unwrap().as_str().unwrap(), "alpha");
    assert_eq!(listed[1].get("layers").unwrap().as_usize().unwrap(), 3);

    // -- manifest ---------------------------------------------------------
    let resp = http::get(&addr, "/models/beta/manifest", None).unwrap();
    assert_eq!(resp.status, 200);
    let manifest = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
    assert_eq!(manifest.get("version").unwrap().as_usize().unwrap(), 2);
    let mlayers = manifest.get("layers").unwrap().as_arr().unwrap();
    assert_eq!(mlayers.len(), 3);
    assert_eq!(
        mlayers[0].get("chunks").unwrap().as_arr().unwrap().len(),
        models[1].layers[0].n_chunks()
    );

    // -- compressed layer bytes, by index and by name, with Range ---------
    let want_payload = &models[1].layers[1].payload;
    let by_index = http::get(&addr, "/models/beta/layers/1", None).unwrap();
    assert_eq!(by_index.status, 200);
    assert_eq!(&by_index.body, want_payload);
    let by_name = http::get(&addr, "/models/beta/layers/conv2", None).unwrap();
    assert_eq!(&by_name.body, want_payload);
    let ranged = http::get(&addr, "/models/beta/layers/1", Some((4, 11))).unwrap();
    assert_eq!(ranged.status, 206);
    assert_eq!(&ranged.body, &want_payload[4..12]);
    assert!(ranged.header("content-range").unwrap().starts_with("bytes 4-11/"));
    let bad_range =
        http::get(&addr, "/models/beta/layers/1", Some((1 << 30, 1 << 30))).unwrap();
    assert_eq!(bad_range.status, 416);

    // -- whole container + streaming decode over the wire -----------------
    let mut dec = StreamDecoder::new();
    let mut streamed: Vec<(String, Vec<f32>)> = Vec::new();
    let (status, _, _) = http::get_streaming(&addr, "/models/beta", None, &mut |chunk| {
        for ev in dec.feed(chunk)? {
            if let StreamEvent::Layer(l) = ev {
                streamed.push((l.name.clone(), l.weights));
            }
        }
        Ok(())
    })
    .unwrap();
    assert_eq!(status, 200);
    dec.finish().unwrap();
    assert_eq!(streamed.len(), 3);
    for ((name, weights), layer) in streamed.iter().zip(&models[1].layers) {
        assert_eq!(name, &layer.name);
        assert_eq!(f32_le_bytes(weights), f32_le_bytes(&layer.decode_weights()));
    }

    // -- decoded weights endpoint + LRU cache hit on repeat ---------------
    let first = http::get(&addr, "/models/alpha/layers/0/weights", None).unwrap();
    assert_eq!(first.status, 200);
    assert_eq!(first.header("x-cache"), Some("miss"));
    assert_eq!(first.body, f32_le_bytes(&models[0].layers[0].decode_weights()));
    let hits_before = handle.cache_stats().hits;
    let second = http::get(&addr, "/models/alpha/layers/0/weights", None).unwrap();
    assert_eq!(second.header("x-cache"), Some("hit"));
    assert_eq!(second.body, first.body);
    assert!(handle.cache_stats().hits > hits_before, "repeat fetch must hit the LRU");

    // -- ?tier= on a non-progressive container is a 409 -------------------
    let resp = http::get(&addr, "/models/alpha?tier=0", None).unwrap();
    assert_eq!(resp.status, 409);
    assert!(String::from_utf8_lossy(&resp.body).contains("not a progressive"));

    // -- unknown resources ------------------------------------------------
    assert_eq!(http::get(&addr, "/models/nope", None).unwrap().status, 404);
    assert_eq!(http::get(&addr, "/models/alpha/layers/99", None).unwrap().status, 404);
    assert_eq!(http::get(&addr, "/nope", None).unwrap().status, 404);

    handle.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// The delta endpoint under legitimate and hostile `?from=` values: a
/// registered (model, parent-fingerprint) pair serves the v3 segment
/// byte-for-byte and the segment applies back to the target container;
/// a fingerprint the server recognises with no delta from it is a 409;
/// everything else — garbage hex, unknown fingerprints, a missing
/// param, an unknown model — is a plain 404, never a panic or a hang.
#[test]
fn delta_endpoint_serves_and_sheds_hostile_from() {
    let dir =
        std::env::temp_dir().join(format!("dcbc_serve_{}_delta", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = CodecConfig::default();

    // parent and target share architecture (names + weight counts) but
    // differ in payload — a real update, so the delta codes residuals
    let parent = CompressedModel {
        name: "gamma".into(),
        layers: vec![make_layer("conv1", 1200, 2, 7, cfg), make_layer("fc", 300, 1, 8, cfg)],
    };
    let target = CompressedModel {
        name: "gamma".into(),
        layers: vec![make_layer("conv1", 1200, 2, 9, cfg), make_layer("fc", 300, 1, 10, cfg)],
    };
    let (delta, _report) = delta::encode(&parent, &target, 2).unwrap();
    let target_bytes = target.serialize();
    std::fs::write(dir.join("gamma.dcbc"), &target_bytes).unwrap();
    std::fs::write(dir.join("gamma_update.dcbc"), delta.serialize()).unwrap();

    let handle = start(ServeOptions {
        dir: dir.clone(),
        addr: "127.0.0.1:0".into(),
        cache_bytes: 1 << 20,
        workers: 4,
        ..Default::default()
    })
    .unwrap();
    let addr = handle.addr().to_string();

    // -- the happy path: known parent fingerprint → 200, applies back --
    let parent_fp = fingerprint(&parent);
    let resp = http::get(&addr, &format!("/models/gamma/delta?from={parent_fp:016x}"), None)
        .unwrap();
    assert_eq!(resp.status, 200);
    assert_eq!(resp.body, delta.serialize(), "served segment must be byte-identical");
    let wire = DeltaModel::deserialize(&resp.body).unwrap();
    let rebuilt = delta::apply(&parent, &wire, 2).unwrap();
    assert_eq!(
        rebuilt.serialize(),
        target_bytes,
        "served delta must rebuild the target container byte-for-byte"
    );

    // -- known full container with no delta from it → 409 Conflict ----
    // (the fall-back-to-full-fetch signal; full-container fingerprints
    // are FNV-1a of the file bytes, valid because serialization is
    // canonical)
    let target_fp = fnv1a(&target_bytes);
    let resp = http::get(&addr, &format!("/models/gamma/delta?from={target_fp:016x}"), None)
        .unwrap();
    assert_eq!(resp.status, 409, "known base with no delta must be a 409");

    // -- hostile ?from= values are all shed with a 404 -----------------
    for path in [
        "/models/gamma/delta?from=0000000000000000", // unknown fingerprint
        "/models/gamma/delta?from=zzzz",             // not hex
        "/models/gamma/delta?from=",                 // empty value
        "/models/gamma/delta",                       // missing param
        "/models/nosuch/delta?from=0000000000000000", // unknown model
    ] {
        let resp = http::get(&addr, path, None).unwrap();
        assert_eq!(resp.status, 404, "{path}: hostile ?from= must be a plain 404");
    }

    // the server is still healthy after the hostile batch
    assert_eq!(http::get(&addr, "/healthz", None).unwrap().status, 200);

    handle.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// A served v4 progressive container: `?tier=t` returns the exact byte
/// prefix through tier t, which is itself a complete container that
/// materializes to the standalone tier-t model byte-for-byte; hostile
/// tier values are shed; the delta 409 advertises the progressive
/// fallback.
#[test]
fn progressive_tier_endpoint_serves_exact_prefixes() {
    use deepcabac::delta::{encode_progressive, materialize};
    use deepcabac::model::{deserialize_any, Container};

    let dir =
        std::env::temp_dir().join(format!("dcbc_serve_{}_prog", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = CodecConfig::default();

    // two quality tiers over the same architecture (the second layer is
    // unchanged, so the refinement skips it)
    let coarse = CompressedModel {
        name: "prog".into(),
        layers: vec![make_layer("conv1", 1200, 2, 17, cfg), make_layer("fc", 300, 1, 18, cfg)],
    };
    let fine = CompressedModel {
        name: "prog".into(),
        layers: vec![make_layer("conv1", 1200, 2, 19, cfg), make_layer("fc", 300, 1, 18, cfg)],
    };
    let (prog, _) = encode_progressive(&[coarse.clone(), fine.clone()], 2).unwrap();
    let prog_bytes = prog.serialize();
    std::fs::write(dir.join("prog.dcbc"), &prog_bytes).unwrap();

    let handle = start(ServeOptions {
        dir: dir.clone(),
        addr: "127.0.0.1:0".into(),
        cache_bytes: 1 << 20,
        workers: 4,
        ..Default::default()
    })
    .unwrap();
    let addr = handle.addr().to_string();

    // listing reports the tier count
    let resp = http::get(&addr, "/models", None).unwrap();
    let listing = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
    let listed = listing.get("models").unwrap().as_arr().unwrap();
    assert_eq!(listed[0].get("tiers").unwrap().as_usize().unwrap(), 2);

    // manifest carries tier_ends and per-layer tiers
    let resp = http::get(&addr, "/models/prog/manifest", None).unwrap();
    let manifest = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
    let tier_ends = manifest.get("tier_ends").unwrap().as_arr().unwrap();
    assert_eq!(tier_ends.len(), 2);
    assert_eq!(tier_ends[1].as_usize().unwrap(), prog_bytes.len());
    let mlayers = manifest.get("layers").unwrap().as_arr().unwrap();
    assert_eq!(mlayers.len(), 4);
    assert_eq!(mlayers[3].get("tier").unwrap().as_usize().unwrap(), 1);

    // tier 0 is a strict byte prefix of the full container...
    let t0 = http::get(&addr, "/models/prog?tier=0", None).unwrap();
    assert_eq!(t0.status, 200);
    assert_eq!(t0.header("x-tier"), Some("0"));
    assert_eq!(t0.header("x-tiers-total"), Some("2"));
    let full = http::get(&addr, "/models/prog", None).unwrap();
    assert_eq!(full.body, prog_bytes);
    assert!(t0.body.len() < full.body.len());
    assert_eq!(&full.body[..t0.body.len()], &t0.body[..]);
    // ...that is itself a complete container materializing to the
    // standalone coarse model byte-for-byte
    let p0 = match deserialize_any(&t0.body).unwrap() {
        Container::Progressive(p) => p,
        other => panic!("expected progressive, got {other:?}"),
    };
    assert_eq!(p0.n_tiers(), 1);
    assert_eq!(materialize(&p0, 0, 2).unwrap().serialize(), coarse.serialize());

    // the final tier equals the whole file, and materializes to `fine`
    let t1 = http::get(&addr, "/models/prog?tier=1", None).unwrap();
    assert_eq!(t1.body, prog_bytes);
    let p1 = match deserialize_any(&t1.body).unwrap() {
        Container::Progressive(p) => p,
        other => panic!("expected progressive, got {other:?}"),
    };
    assert_eq!(materialize(&p1, 1, 2).unwrap().serialize(), fine.serialize());

    // tier prefixes stay Range-compatible (the --upgrade path fetches
    // only the bytes between two tier ends)
    let ranged = http::get(&addr, "/models/prog?tier=0", Some((4, 11))).unwrap();
    assert_eq!(ranged.status, 206);
    assert_eq!(&ranged.body, &prog_bytes[4..12]);

    // hostile tier values are shed with structured errors
    assert_eq!(http::get(&addr, "/models/prog?tier=2", None).unwrap().status, 404);
    assert_eq!(http::get(&addr, "/models/prog?tier=x", None).unwrap().status, 404);

    // the delta 409 advertises the progressive fallback
    let fp = fnv1a(&prog_bytes);
    let resp =
        http::get(&addr, &format!("/models/prog/delta?from={fp:016x}"), None).unwrap();
    assert_eq!(resp.status, 409);
    let body = String::from_utf8_lossy(&resp.body);
    assert!(body.contains("progressive container is available"), "{body}");
    assert!(body.contains("?tier=0"), "{body}");

    handle.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn loadgen_32_clients_zero_failures() {
    let (dir, _models) = write_model_dir("loadgen");
    let handle = start(ServeOptions {
        dir: dir.clone(),
        addr: "127.0.0.1:0".into(),
        cache_bytes: 8 << 20,
        workers: 8,
        ..Default::default()
    })
    .unwrap();
    let out = dir.join("BENCH_serve.json");
    let report = loadgen::run(&LoadgenOptions {
        url: format!("http://{}", handle.addr()),
        clients: 32,
        requests: 6,
        hostile: 0,
        rate: None,
        sweep: None,
        sweep_requests: 3,
        out: Some(out.clone()),
    })
    .unwrap();

    // ≥ 32 concurrent clients over mixed endpoints, zero failed requests
    assert_eq!(report.total_requests, 32 * 6);
    assert_eq!(report.failures, 0, "no request may fail");
    assert!(report.bytes_requests > 0 && report.weights_requests > 0, "mix must cover both endpoints");
    assert!(report.p50_ms > 0.0 && report.p99_ms >= report.p50_ms);

    // repeat weight fetches across clients must have hit the LRU
    assert!(handle.cache_stats().hits > 0, "expected cache hits under load");

    // the machine-readable report landed with the latency percentiles
    let json = Json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
    assert_eq!(json.get("failures").unwrap().as_usize().unwrap(), 0);
    assert!(json.get("p50_ms").unwrap().as_f64().unwrap() > 0.0);
    assert!(json.get("p99_ms").unwrap().as_f64().unwrap() > 0.0);
    assert_eq!(json.get("clients").unwrap().as_usize().unwrap(), 32);

    handle.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}
