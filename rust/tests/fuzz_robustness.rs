//! Failure injection: every deserializer must reject malformed input
//! with an `Err` — never panic, never loop, never allocate absurdly.
//! Inputs are (a) random bytes, (b) random truncations of valid streams,
//! (c) single-byte corruptions of valid streams.
//!
//! The hostile-input driver itself lives in `util::ptest` so unit
//! tests, these integration tests, and the structure-aware fuzzer
//! (`deepcabac::fuzz`, exercised by `tests/fuzz_structured.rs`) all
//! share one battery; this file is a thin per-decoder caller.

use deepcabac::baselines::{csr, fixed, huffman, static_arith};
use deepcabac::codec::{encode_levels, CodecConfig};
use deepcabac::model::{CompressedLayer, CompressedModel};
use deepcabac::quant::QuantGrid;
use deepcabac::util::ptest::hostile_inputs;
use deepcabac::util::SplitMix64;

fn random_levels(rng: &mut SplitMix64, n: usize) -> Vec<i32> {
    (0..n)
        .map(|_| {
            if rng.next_f64() < 0.8 {
                0
            } else {
                (1 + rng.below(40) as i32) * if rng.next_u64() & 1 == 0 { 1 } else { -1 }
            }
        })
        .collect()
}

#[test]
fn huffman_decoder_never_panics() {
    let mut rng = SplitMix64::new(1);
    let levels = random_levels(&mut rng, 2000);
    let valid = huffman::encode(&levels).unwrap();
    hostile_inputs(&valid, &mut rng, |buf| {
        let _ = huffman::decode(buf);
    });
}

#[test]
fn fixed_decoder_never_panics() {
    let mut rng = SplitMix64::new(2);
    let levels = random_levels(&mut rng, 2000);
    let valid = fixed::encode(&levels);
    hostile_inputs(&valid, &mut rng, |buf| {
        let _ = fixed::decode(buf);
    });
}

#[test]
fn csr_decoder_never_panics() {
    let mut rng = SplitMix64::new(3);
    let levels = random_levels(&mut rng, 2000);
    for cfg in [
        csr::CsrConfig::default(),
        csr::CsrConfig { run_bits: 4, huffman: false },
    ] {
        let valid = csr::encode(&levels, cfg).unwrap();
        hostile_inputs(&valid, &mut rng, |buf| {
            let _ = csr::decode(buf);
        });
    }
}

#[test]
fn static_arith_decoder_never_panics() {
    let mut rng = SplitMix64::new(4);
    let levels = random_levels(&mut rng, 2000);
    let cfg = CodecConfig::default();
    let valid = static_arith::encode(&levels, cfg).unwrap();
    hostile_inputs(&valid, &mut rng, |buf| {
        let _ = static_arith::decode(buf);
    });
}

#[test]
fn container_deserializer_never_panics() {
    let mut rng = SplitMix64::new(5);
    let cfg = CodecConfig::default();
    let levels = random_levels(&mut rng, 500);
    let model = CompressedModel {
        name: "fuzz".into(),
        layers: vec![CompressedLayer {
            name: "l0".into(),
            dims: vec![levels.len()],
            grid: QuantGrid { delta: 0.1, max_level: 41 },
            s_param: 7,
            cfg,
            n_weights: levels.len(),
            payload: encode_levels(&levels, cfg),
            chunks: vec![],
            bias: vec![1.0, 2.0],
        }],
    };
    let valid = model.serialize();
    hostile_inputs(&valid, &mut rng, |buf| {
        let _ = CompressedModel::deserialize(buf);
    });
}

#[test]
fn chunked_container_deserializer_never_panics() {
    // same hostile battery against the v2 (chunk-table) layout
    let mut rng = SplitMix64::new(15);
    let cfg = CodecConfig::default();
    let levels = random_levels(&mut rng, 600);
    let half = levels.len() / 2;
    let (p0, p1) = (encode_levels(&levels[..half], cfg), encode_levels(&levels[half..], cfg));
    let mut payload = p0.clone();
    payload.extend_from_slice(&p1);
    let model = CompressedModel {
        name: "fuzz2".into(),
        layers: vec![CompressedLayer {
            name: "l0".into(),
            dims: vec![levels.len()],
            grid: QuantGrid { delta: 0.1, max_level: 41 },
            s_param: 7,
            cfg,
            n_weights: levels.len(),
            payload,
            chunks: vec![
                deepcabac::model::ChunkInfo { n_weights: half, bytes: p0.len() },
                deepcabac::model::ChunkInfo { n_weights: levels.len() - half, bytes: p1.len() },
            ],
            bias: vec![0.5],
        }],
    };
    let valid = model.serialize();
    assert_eq!(
        CompressedModel::deserialize(&valid).unwrap().layers[0].decode_levels(),
        levels
    );
    hostile_inputs(&valid, &mut rng, |buf| {
        let _ = CompressedModel::deserialize(buf);
    });
}

#[test]
fn cabac_decoder_tolerates_any_payload() {
    // The CABAC decoder is length-driven: decoding N levels from garbage
    // must terminate and give N levels (values arbitrary but in-range
    // per the binarization), because past-the-end reads return 0s.
    let mut rng = SplitMix64::new(6);
    let cfg = CodecConfig::default();
    for _ in 0..32 {
        let n = 1 + rng.below(500) as usize;
        let len = rng.below(200) as usize;
        let buf: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let levels = deepcabac::codec::decode_levels(&buf, n, cfg);
        assert_eq!(levels.len(), n);
    }
}

#[test]
fn npy_reader_never_panics() {
    let mut rng = SplitMix64::new(7);
    let dir = std::env::temp_dir().join("dcbc_fuzz_npy");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("fuzz.npy");
    // valid file to corrupt
    deepcabac::tensor::npy::write_npy_f32(&path, &[8], &[0.0; 8]).unwrap();
    let valid = std::fs::read(&path).unwrap();
    hostile_inputs(&valid, &mut rng, |buf| {
        std::fs::write(&path, buf).unwrap();
        let _ = deepcabac::tensor::npy::read_npy_f32(&path);
        let _ = deepcabac::tensor::npy::read_npy_i32(&path);
    });
}
