//! Live fault injection against a real server: a storm of hostile
//! client sessions (byte dribble, slowloris, mid-request disconnects,
//! stalled readers) must not panic the server, wedge a worker slot, or
//! break service for healthy clients — and the client side must survive
//! a hostile *server* with a fast error instead of a hang.

use deepcabac::codec::{encode_levels, CodecConfig};
use deepcabac::fuzz::fault;
use deepcabac::model::{CompressedLayer, CompressedModel};
use deepcabac::quant::QuantGrid;
use deepcabac::serve::http;
use deepcabac::serve::loadgen::{self, LoadgenOptions};
use deepcabac::serve::server::{start, start_with, Backend, ServeOptions, ServerHandle};
use deepcabac::util::json::Json;
use deepcabac::util::SplitMix64;
use std::io::{Read, Write};
use std::path::PathBuf;
use std::time::Duration;

fn make_model_dir(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("dcbc_fault_{}_{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = CodecConfig::default();
    let mut rng = SplitMix64::new(7);
    let levels: Vec<i32> = (0..1200)
        .map(|_| if rng.next_f64() < 0.7 { 0 } else { 1 + rng.below(20) as i32 })
        .collect();
    let payload = encode_levels(&levels, cfg);
    let model = CompressedModel {
        name: "victim".into(),
        layers: vec![CompressedLayer {
            name: "fc".into(),
            dims: vec![300, 4],
            grid: QuantGrid { delta: 0.05, max_level: 30 },
            s_param: 12,
            cfg,
            n_weights: levels.len(),
            payload,
            chunks: Vec::new(),
            bias: vec![0.1, -0.1],
        }],
    };
    std::fs::write(dir.join("victim.dcbc"), model.serialize()).unwrap();
    dir
}

/// Short-deadline server for fault tests: hostile sessions resolve in
/// ~300 ms instead of the production 10 s default.
fn short_deadline_opts(dir: PathBuf, workers: usize) -> ServeOptions {
    ServeOptions {
        dir,
        addr: "127.0.0.1:0".into(),
        cache_bytes: 1 << 20,
        workers,
        read_timeout: Duration::from_millis(300),
        write_timeout: Duration::from_millis(500),
        max_connections: usize::MAX,
    }
}

fn start_short_deadline(dir: PathBuf, workers: usize) -> ServerHandle {
    start(short_deadline_opts(dir, workers)).unwrap()
}

fn run_fault_storm(tag: &str, backend: Backend) {
    let dir = make_model_dir(tag);
    let workers = 4;
    let handle = start_with(backend, short_deadline_opts(dir.clone(), workers)).unwrap();
    let addr = handle.addr().to_string();
    let deadline = Duration::from_secs(5);
    let path = "/models/victim/layers/0";

    // the storm: every pathology, some sessions concurrent
    std::thread::scope(|scope| {
        for _ in 0..3 {
            let addr = addr.clone();
            scope.spawn(move || {
                let out = fault::slowloris(&addr, deadline).unwrap();
                // a read-deadline server answers 408 or sheds the
                // connection; it must never leave us waiting forever
                assert!(
                    matches!(
                        out,
                        fault::FaultOutcome::Status(408)
                            | fault::FaultOutcome::Closed
                            | fault::FaultOutcome::IoError(_)
                    ),
                    "slowloris got {out:?}"
                );
            });
        }
        for _ in 0..3 {
            let addr = addr.clone();
            scope.spawn(move || {
                fault::disconnect_mid_request(&addr, deadline).unwrap();
            });
        }
        for _ in 0..2 {
            let addr = addr.clone();
            scope.spawn(move || {
                fault::stalled_reader(&addr, path, Duration::from_millis(700), deadline)
                    .unwrap();
            });
        }
        // a slow-but-complete request must still be answered: the read
        // deadline applies per read, and bytes keep arriving
        let addr2 = addr.clone();
        scope.spawn(move || {
            let out = fault::dribble_request(
                &addr2,
                "/healthz",
                Duration::from_millis(2),
                deadline,
            )
            .unwrap();
            assert_eq!(out, fault::FaultOutcome::Status(200), "dribbled request");
        });
    });

    // zero wedged slots: more sequential healthy requests than worker
    // threads, all served after the storm
    for i in 0..(workers * 2 + 2) {
        let resp = http::get(&addr, path, None).unwrap();
        assert_eq!(resp.status, 200, "healthy request {i} after the storm");
        assert!(!resp.body.is_empty());
    }

    // the storm left its fingerprints in the stats, not in the error log
    assert!(handle.timeout_count() > 0, "slowloris must trip the read deadline");
    let stats = http::get(&addr, "/stats", None).unwrap();
    assert_eq!(stats.status, 200);
    let json = Json::parse(std::str::from_utf8(&stats.body).unwrap()).unwrap();
    assert!(json.get("timeouts").unwrap().as_usize().unwrap() > 0);
    assert_eq!(json.get("read_timeout_ms").unwrap().as_usize().unwrap(), 300);
    assert_eq!(json.get("write_timeout_ms").unwrap().as_usize().unwrap(), 500);

    handle.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn server_survives_fault_storm_and_keeps_serving() {
    run_fault_storm("storm", Backend::Threaded);
}

/// The same storm against the epoll/kqueue readiness loop: the
/// timer-wheel deadlines must give hostile sessions the same contract
/// the per-socket deadlines give them on the threaded path (slowloris
/// -> 408/close, dribble -> 200, storms never wedge healthy service).
#[test]
fn event_server_survives_fault_storm_and_keeps_serving() {
    if !deepcabac::util::poll::supported() {
        eprintln!("skipping: readiness polling unsupported on this platform");
        return;
    }
    run_fault_storm("storm_event", Backend::Event);
}

#[test]
fn loadgen_hostile_mode_reports_clean_taxonomy() {
    let dir = make_model_dir("loadgen");
    let handle = start_short_deadline(dir.clone(), 6);
    let out = dir.join("BENCH_serve.json");

    let report = loadgen::run(&LoadgenOptions {
        url: format!("http://{}", handle.addr()),
        clients: 6,
        requests: 8,
        hostile: 2,
        rate: None,
        sweep: None,
        sweep_requests: 3,
        out: Some(out.clone()),
    })
    .unwrap();

    // healthy clients ride through the injected faults untouched: zero
    // failures, so the taxonomy shows only injected failure classes
    // (reported under `injected`), none leaking into the client buckets
    assert_eq!(report.failures, 0, "taxonomy: {:?}", report.failure_taxonomy);
    assert_eq!(report.failure_taxonomy.total(), 0);
    let i = &report.injected;
    assert_eq!(i.dribble + i.slowloris + i.disconnect + i.stalled_reader, 2 * 8);
    assert_eq!(i.unexpected, 0, "injected sessions outside contract: {i:?}");

    // machine-readable report carries both new objects
    let json = Json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
    assert_eq!(json.path("failure_taxonomy.timeout").unwrap().as_usize().unwrap(), 0);
    assert_eq!(json.path("injected.unexpected").unwrap().as_usize().unwrap(), 0);
    assert_eq!(json.path("injected.hostile_threads").unwrap().as_usize().unwrap(), 2);

    handle.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// The other direction: a hostile *server*. `get_streaming_with` must
/// surface a stalled or trickling peer as a fast error, never a hang.
#[test]
fn client_survives_hostile_server() {
    use std::net::TcpListener;

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();

    // hostile server: reads the request, then sends half a status line
    // and goes silent (socket stays open)
    let srv = std::thread::spawn(move || {
        let (mut conn, _) = listener.accept().unwrap();
        let mut buf = [0u8; 1024];
        let _ = conn.read(&mut buf);
        conn.write_all(b"HTTP/1.1 20").unwrap();
        conn.flush().unwrap();
        std::thread::sleep(Duration::from_millis(1200));
        drop(conn);
    });

    let t0 = std::time::Instant::now();
    let err = http::get_streaming_with(
        &addr,
        "/models/x",
        None,
        Duration::from_millis(400),
        &mut |_| Ok(()),
    )
    .unwrap_err();
    let waited = t0.elapsed();
    assert!(
        waited < Duration::from_secs(3),
        "client hung {waited:?} on a stalled server"
    );
    // the deadline shows up as a tagged IO error the taxonomy can bucket
    let msg = format!("{err:#}");
    assert!(
        msg.contains("[kind=WouldBlock]") || msg.contains("[kind=TimedOut]"),
        "untagged error: {msg}"
    );
    let mut tax = loadgen::FailureTaxonomy::default();
    tax.record_error(&msg);
    assert_eq!(tax.timeout, 1, "classified as {tax:?}");

    srv.join().unwrap();
}
