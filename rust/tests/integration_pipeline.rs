//! Integration tests over the full compression pipeline, including the
//! real artifacts when they exist (`make artifacts`). Artifact-dependent
//! tests skip gracefully so `cargo test` passes on a fresh checkout.

use deepcabac::app;
use deepcabac::baselines::{csr, huffman, static_arith};
use deepcabac::codec::{decode_levels, encode_levels, CodecConfig};
use deepcabac::coordinator::{compress_model, sweep_s, CompressionSpec};
use deepcabac::model::CompressedModel;
use deepcabac::synth::{self, Arch};

fn have_artifacts() -> bool {
    app::artifacts_dir().join("models/lenet300/manifest.json").exists()
}

#[test]
fn trained_model_roundtrips_bit_exact() {
    if !have_artifacts() {
        eprintln!("skipped: no artifacts");
        return;
    }
    let model = app::load_model("lenet300").unwrap();
    let spec = CompressionSpec::default();
    let (compressed, report) = compress_model(&model, &spec, 1);
    assert!(report.factor() > 10.0, "factor {}", report.factor());

    // container serialize/deserialize must be byte-stable
    let bytes = compressed.serialize();
    let re = CompressedModel::deserialize(&bytes).unwrap();
    assert_eq!(re.serialize(), bytes);

    // every layer decodes to exactly n_weights levels within grid range
    for layer in &re.layers {
        let levels = layer.decode_levels();
        assert_eq!(levels.len(), layer.n_weights);
        for &l in &levels {
            assert!(l.abs() <= layer.grid.max_level, "level {l} outside grid");
        }
    }
}

#[test]
fn deepcabac_beats_scalar_huffman_on_all_trained_layers() {
    // The paper's core claim: CABAC's adaptive contexts beat scalar
    // Huffman on every pre-sparsified layer.
    if !have_artifacts() {
        eprintln!("skipped: no artifacts");
        return;
    }
    for name in app::SMALL_MODELS {
        let Ok(model) = app::load_model(name) else { continue };
        let spec = CompressionSpec { lambda_scale: 0.0, ..Default::default() };
        let (compressed, _) = compress_model(&model, &spec, 1);
        for layer in &compressed.layers {
            let levels = layer.decode_levels();
            if levels.len() < 20_000 {
                // on tiny layers the adaptive models are still warming up
                // and header amortization favors scalar codes; the paper's
                // claim is about real (large) weight tensors
                continue;
            }
            let h = huffman::encode(&levels).unwrap().len();
            assert!(
                layer.payload.len() < h,
                "{name}/{}: cabac {} >= huffman {h}",
                layer.name,
                layer.payload.len()
            );
        }
    }
}

#[test]
fn adaptive_beats_baselines_on_swept_synthetic_vgg_layer() {
    // fc6 (~400k weights at 1/16 scale), compressed the way the real
    // pipeline does it: S swept so the grid matches the tensor (at a
    // fixed overly-fine grid, level magnitudes explode and the
    // Deep-Compression CSR format can win — the sweep is part of the
    // paper's method, §4).
    let m = synth::generate(Arch::Vgg16, 16, 9);
    let l = &m.layers[13];
    assert_eq!(l.name, "fc6");
    let mut best: Option<(usize, Vec<i32>)> = None;
    for s in [0u32, 16, 64, 128, 256] {
        let spec = deepcabac::coordinator::CompressionSpec {
            s,
            lambda_scale: 0.05,
            ..Default::default()
        };
        let (layer, rep) = deepcabac::coordinator::compress_tensor(
            &l.name, &l.dims, &l.weights, &l.sigmas, &[], &spec,
        );
        if best.as_ref().map(|(b, _)| rep.payload_bytes < *b).unwrap_or(true) {
            best = Some((rep.payload_bytes, layer.decode_levels()));
        }
    }
    let (cabac, levels) = best.unwrap();
    let cfg = CodecConfig::default();
    let stat = static_arith::encode(&levels, cfg).unwrap().len();
    let csr_b = csr::encode(&levels, csr::CsrConfig::default()).unwrap().len();
    let huf = huffman::encode(&levels).unwrap().len();
    // Static two-pass coding can tie on stationary data (see
    // `static_arith::tests::adaptive_beats_static_on_nonstationary_data`
    // for the adaptive win); require within 3% here.
    assert!(
        (cabac as f64) <= stat as f64 * 1.03,
        "cabac {cabac} vs static {stat}"
    );
    assert!(cabac < csr_b, "cabac {cabac} vs csr {csr_b}");
    assert!(cabac < huf, "cabac {cabac} vs huffman {huf}");
}

#[test]
fn sweep_improves_or_matches_default_s() {
    if !have_artifacts() {
        eprintln!("skipped: no artifacts");
        return;
    }
    let model = app::load_model("lenet300").unwrap();
    let spec = CompressionSpec::default();
    let (_, fixed) = compress_model(&model, &spec, 1);
    let sweep = sweep_s(&model, &[0, 32, 64, 128, 256], &spec, 1).unwrap();
    assert!(sweep.best.1.compressed_bytes <= fixed.compressed_bytes);
}

#[test]
fn lambda_monotonicity_on_trained_weights() {
    if !have_artifacts() {
        eprintln!("skipped: no artifacts");
        return;
    }
    let model = app::load_model("lenet300").unwrap();
    let mut prev = usize::MAX;
    for ls in [0.0f32, 0.05, 0.5, 2.0] {
        let spec = CompressionSpec { lambda_scale: ls, s: 64, ..Default::default() };
        let (_, report) = compress_model(&model, &spec, 1);
        assert!(
            report.compressed_bytes <= prev,
            "λscale={ls}: {} > {prev}",
            report.compressed_bytes
        );
        prev = report.compressed_bytes;
    }
}

#[test]
fn full_levels_decode_equals_multiple_configs() {
    // cross-config determinism: decoding twice yields identical levels
    let m = synth::generate(Arch::MobileNetV1, 16, 4);
    let l = &m.layers[2];
    let grid = deepcabac::quant::QuantGrid::from_tensor(&l.weights, &l.sigmas, 40);
    let levels: Vec<i32> = l.weights.iter().map(|&w| grid.nearest_level(w)).collect();
    for cfg in [
        CodecConfig::default(),
        CodecConfig { sig_ctx_neighbors: false, ..Default::default() },
        CodecConfig::with_fixed_length_for(
            levels.iter().map(|l| l.unsigned_abs()).max().unwrap_or(1),
            6,
        ),
    ] {
        let payload = encode_levels(&levels, cfg);
        assert_eq!(decode_levels(&payload, levels.len(), cfg), levels);
        assert_eq!(decode_levels(&payload, levels.len(), cfg), levels);
    }
}
