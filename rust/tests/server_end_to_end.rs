//! Transport-level conformance for the two serve backends.
//!
//! The byte-level contract of the server (status lines, headers, bodies,
//! Range/tier/delta semantics, hostile-session handling) is defined by
//! the pure router + shared framing, not by the transport. This file
//! enforces that:
//!
//! * **differential corpus replay** — every endpoint class (plus Range
//!   variants and hostile fingerprints) is replayed against a threaded
//!   and an event-loop server over the same model directory; responses
//!   must be byte-identical (`/stats` compares the status line only —
//!   its body is live counters).
//! * **hostile sessions** — slowloris gets the same 408 bytes from both
//!   backends; a dribbled-but-complete request gets the same 200.
//! * **keep-alive + pipelining** — N pipelined requests on one socket
//!   are answered in order; a malformed request mid-pipeline gets a 400
//!   and a clean close with nothing after it.
//! * **max-connections shedding** — connections beyond the cap get a
//!   503 and show up in the `shed` counter.

use deepcabac::codec::{encode_levels, CodecConfig};
use deepcabac::delta;
use deepcabac::model::{fingerprint, ChunkInfo, CompressedLayer, CompressedModel};
use deepcabac::quant::QuantGrid;
use deepcabac::serve::http;
use deepcabac::serve::server::{start_with, Backend, ServeOptions, ServerHandle};
use deepcabac::util::json::Json;
use deepcabac::util::{fnv1a, SplitMix64};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::time::Duration;

fn make_layer(name: &str, n: usize, n_chunks: usize, seed: u64) -> CompressedLayer {
    let cfg = CodecConfig::default();
    let mut rng = SplitMix64::new(seed);
    let levels: Vec<i32> = (0..n)
        .map(|_| {
            if rng.next_f64() < 0.75 {
                0
            } else {
                (1 + rng.below(25) as i32) * if rng.next_u64() & 1 == 0 { 1 } else { -1 }
            }
        })
        .collect();
    let n_chunks = n_chunks.max(1);
    let per = ((levels.len() + n_chunks - 1) / n_chunks).max(1);
    let mut payload = Vec::new();
    let mut chunks = Vec::new();
    for part in levels.chunks(per) {
        let bytes = encode_levels(part, cfg);
        chunks.push(ChunkInfo { n_weights: part.len(), bytes: bytes.len() });
        payload.extend_from_slice(&bytes);
    }
    if chunks.len() <= 1 {
        chunks.clear();
    }
    CompressedLayer {
        name: name.into(),
        dims: vec![n.max(4) / 4, 4],
        grid: QuantGrid { delta: 0.05, max_level: 30 },
        s_param: 12,
        cfg,
        n_weights: levels.len(),
        payload,
        chunks,
        bias: vec![0.5, -0.5],
    }
}

/// A model directory covering every endpoint class: a plain container
/// (`alpha`), a v4 progressive (`prog`), and a v3 delta segment for
/// `gamma` (whose full container is also present, so the 409 stale-base
/// path is reachable). Returns (dir, delta parent fp, gamma full fp).
fn write_corpus_dir(tag: &str) -> (PathBuf, u64, u64) {
    let dir = std::env::temp_dir().join(format!("dcbc_e2e_{}_{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    let alpha = CompressedModel {
        name: "alpha".into(),
        layers: vec![make_layer("conv1", 2000, 1, 1), make_layer("fc1", 400, 3, 2)],
    };
    std::fs::write(dir.join("alpha.dcbc"), alpha.serialize()).unwrap();

    let coarse = CompressedModel {
        name: "prog".into(),
        layers: vec![make_layer("conv1", 1200, 2, 17), make_layer("fc", 300, 1, 18)],
    };
    let fine = CompressedModel {
        name: "prog".into(),
        layers: vec![make_layer("conv1", 1200, 2, 19), make_layer("fc", 300, 1, 18)],
    };
    let (prog, _) = delta::encode_progressive(&[coarse, fine], 2).unwrap();
    std::fs::write(dir.join("prog.dcbc"), prog.serialize()).unwrap();

    let parent = CompressedModel {
        name: "gamma".into(),
        layers: vec![make_layer("conv1", 1200, 2, 7), make_layer("fc", 300, 1, 8)],
    };
    let target = CompressedModel {
        name: "gamma".into(),
        layers: vec![make_layer("conv1", 1200, 2, 9), make_layer("fc", 300, 1, 10)],
    };
    let (seg, _) = delta::encode(&parent, &target, 2).unwrap();
    let target_bytes = target.serialize();
    std::fs::write(dir.join("gamma.dcbc"), &target_bytes).unwrap();
    std::fs::write(dir.join("gamma_update.dcbc"), seg.serialize()).unwrap();

    (dir, fingerprint(&parent), fnv1a(&target_bytes))
}

fn start_backend(dir: PathBuf, backend: Backend) -> ServerHandle {
    start_with(
        backend,
        ServeOptions {
            dir,
            addr: "127.0.0.1:0".into(),
            cache_bytes: 1 << 20,
            workers: 4,
            read_timeout: Duration::from_millis(400),
            write_timeout: Duration::from_millis(800),
            max_connections: usize::MAX,
        },
    )
    .unwrap()
}

/// Write `raw` on a fresh connection, read until the server closes.
fn exchange(addr: &str, raw: &[u8]) -> Vec<u8> {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    s.write_all(raw).unwrap();
    s.flush().unwrap();
    let mut buf = Vec::new();
    let _ = s.read_to_end(&mut buf);
    buf
}

fn get_request(path: &str) -> Vec<u8> {
    format!("GET {path} HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n").into_bytes()
}

fn first_line(resp: &[u8]) -> &[u8] {
    match resp.windows(2).position(|w| w == b"\r\n") {
        Some(i) => &resp[..i],
        None => resp,
    }
}

/// Read exactly `n` HTTP/1.1 responses off one socket (Content-Length
/// framing), returning (status, body) per response plus whether the
/// last-seen head asked for `Connection: close`.
fn read_n_responses(s: &mut TcpStream, n: usize) -> (Vec<(u16, Vec<u8>)>, bool) {
    let mut buf: Vec<u8> = Vec::new();
    let mut out = Vec::new();
    let mut last_close = false;
    let mut chunk = [0u8; 4096];
    while out.len() < n {
        let head_end = loop {
            if let Some(i) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
                break i + 4;
            }
            let got = s.read(&mut chunk).expect("reading pipelined response");
            assert!(got > 0, "server closed mid-response ({} of {n} read)", out.len());
            buf.extend_from_slice(&chunk[..got]);
        };
        let head = String::from_utf8(buf[..head_end].to_vec()).unwrap();
        let status: u16 = head
            .split_whitespace()
            .nth(1)
            .and_then(|t| t.parse().ok())
            .unwrap_or_else(|| panic!("bad status line in {head:?}"));
        let mut content_length = 0usize;
        for line in head.lines() {
            let lower = line.to_ascii_lowercase();
            if let Some(v) = lower.strip_prefix("content-length:") {
                content_length = v.trim().parse().unwrap();
            }
            if let Some(v) = lower.strip_prefix("connection:") {
                last_close = v.trim() == "close";
            }
        }
        while buf.len() < head_end + content_length {
            let got = s.read(&mut chunk).expect("reading pipelined body");
            assert!(got > 0, "server closed mid-body");
            buf.extend_from_slice(&chunk[..got]);
        }
        let body = buf[head_end..head_end + content_length].to_vec();
        buf.drain(..head_end + content_length);
        out.push((status, body));
    }
    (out, last_close)
}

/// Every endpoint class replayed against both transports: responses
/// must be byte-identical (status line, headers, body). `/stats` is the
/// one body exemption — it reports live per-server counters.
#[test]
fn differential_corpus_replay_threaded_vs_event() {
    if !deepcabac::util::poll::supported() {
        eprintln!("skipping: readiness polling unsupported on this platform");
        return;
    }
    let (dir, parent_fp, stale_fp) = write_corpus_dir("diff");
    let threaded = start_backend(dir.clone(), Backend::Threaded);
    let event = start_backend(dir.clone(), Backend::Event);
    let (ta, ea) = (threaded.addr().to_string(), event.addr().to_string());

    let paths = [
        "/healthz",
        "/models",
        "/models/alpha",
        "/models/alpha/manifest",
        "/models/alpha/layers/0",
        "/models/alpha/layers/conv1",
        "/models/alpha/layers/fc1",
        // twice on purpose: the second decode must be a cache hit on
        // both servers, so X-Cache headers stay identical
        "/models/alpha/layers/0/weights",
        "/models/alpha/layers/0/weights",
        "/models/alpha/layers/9",
        "/models/nosuch",
        "/models/prog",
        "/models/prog?tier=0",
        "/models/prog?tier=1",
        "/models/prog?tier=2",
        "/models/prog?tier=x",
        "/models/prog/manifest",
        "/models/nosuch/delta?from=0000000000000000",
        "/models/gamma/delta?from=zzzz",
        "/models/gamma/delta",
        "/also/not/a/route",
    ];
    let mut corpus: Vec<Vec<u8>> = paths.iter().map(|p| get_request(p)).collect();
    // the delta 200 (served segment) and 409 (stale base) paths
    corpus.push(get_request(&format!("/models/gamma/delta?from={parent_fp:016x}")));
    corpus.push(get_request(&format!("/models/gamma/delta?from={stale_fp:016x}")));
    // Range variants over zero-copy windows: satisfiable, unsatisfiable,
    // malformed (served whole), and a ranged tier prefix
    for (path, range) in [
        ("/models/alpha/layers/0", "bytes=4-11"),
        ("/models/alpha", "bytes=0-0"),
        ("/models/alpha", "bytes=999999999-"),
        ("/models/alpha", "bytes=frobnicate"),
        ("/models/prog?tier=0", "bytes=4-11"),
    ] {
        corpus.push(
            format!(
                "GET {path} HTTP/1.1\r\nHost: x\r\nRange: {range}\r\nConnection: close\r\n\r\n"
            )
            .into_bytes(),
        );
    }
    // non-GET is a 405 on both
    corpus.push(b"POST /healthz HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n".to_vec());

    for (i, raw) in corpus.iter().enumerate() {
        let a = exchange(&ta, raw);
        let b = exchange(&ea, raw);
        let req = String::from_utf8_lossy(raw);
        let req = req.lines().next().unwrap_or("");
        assert!(!a.is_empty(), "[{i}] {req}: threaded sent nothing");
        assert_eq!(
            a,
            b,
            "[{i}] {req}: transports disagree\n threaded: {:?}\n event:    {:?}",
            String::from_utf8_lossy(&a),
            String::from_utf8_lossy(&b),
        );
    }

    // /stats bodies are live counters; the status line must still match
    let stats_req = get_request("/stats");
    let a = exchange(&ta, &stats_req);
    let b = exchange(&ea, &stats_req);
    assert_eq!(first_line(&a), b"HTTP/1.1 200 OK");
    assert_eq!(first_line(&a), first_line(&b));

    threaded.shutdown();
    event.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Hostile sessions get byte-identical verdicts from both transports:
/// slowloris (partial head, then silence) is a 408 from the threaded
/// per-socket deadline and from the event loop's timer wheel; a
/// dribbled-but-complete request is a 200 from both.
#[test]
fn differential_hostile_sessions() {
    if !deepcabac::util::poll::supported() {
        eprintln!("skipping: readiness polling unsupported on this platform");
        return;
    }
    let (dir, _, _) = write_corpus_dir("hostile");
    let threaded = start_backend(dir.clone(), Backend::Threaded);
    let event = start_backend(dir.clone(), Backend::Event);
    let (ta, ea) = (threaded.addr().to_string(), event.addr().to_string());

    let slowloris = |addr: &str| -> Vec<u8> {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        s.write_all(b"GET /models HTTP/1.1\r\nHost: victim\r\nX-Slow: ").unwrap();
        s.flush().unwrap();
        let mut buf = Vec::new();
        let _ = s.read_to_end(&mut buf);
        buf
    };
    let a = slowloris(&ta);
    let b = slowloris(&ea);
    assert!(a.starts_with(b"HTTP/1.1 408 "), "threaded: {:?}", String::from_utf8_lossy(&a));
    assert_eq!(a, b, "slowloris 408s must be byte-identical");
    assert!(threaded.timeout_count() > 0);
    assert!(event.timeout_count() > 0);

    let dribble = |addr: &str| -> Vec<u8> {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        // one byte at a time, each well inside the 400 ms read deadline:
        // the deadline applies to *stalls*, not to total request time
        for b in get_request("/healthz") {
            s.write_all(&[b]).unwrap();
            s.flush().unwrap();
            std::thread::sleep(Duration::from_millis(2));
        }
        let mut buf = Vec::new();
        let _ = s.read_to_end(&mut buf);
        buf
    };
    let a = dribble(&ta);
    let b = dribble(&ea);
    assert!(a.starts_with(b"HTTP/1.1 200 OK"), "threaded: {:?}", String::from_utf8_lossy(&a));
    assert_eq!(a, b, "dribbled 200s must be byte-identical");

    threaded.shutdown();
    event.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// N pipelined requests on one keep-alive socket are answered in order,
/// the socket survives for another batch, and `Connection: close` is
/// honored when the client finally asks for it.
#[test]
fn event_keepalive_pipelining_answers_in_order() {
    if !deepcabac::util::poll::supported() {
        eprintln!("skipping: readiness polling unsupported on this platform");
        return;
    }
    let (dir, _, _) = write_corpus_dir("pipeline");
    let handle = start_backend(dir.clone(), Backend::Event);
    let addr = handle.addr().to_string();

    // expected bodies via independent one-shot fetches
    let layer0 = http::get(&addr, "/models/alpha/layers/0", None).unwrap();
    assert_eq!(layer0.status, 200);

    let keep = |path: &str| format!("GET {path} HTTP/1.1\r\nHost: x\r\n\r\n");
    let mut s = TcpStream::connect(&addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();

    // batch 1: three requests written back-to-back before reading
    // anything — distinct bodies prove ordering
    let batch = format!(
        "{}{}{}",
        keep("/healthz"),
        keep("/models/alpha/layers/0"),
        keep("/models/alpha/manifest"),
    );
    s.write_all(batch.as_bytes()).unwrap();
    s.flush().unwrap();
    let (resps, closed) = read_n_responses(&mut s, 3);
    assert_eq!(resps[0].0, 200);
    assert_eq!(resps[0].1, b"ok");
    assert_eq!(resps[1].0, 200);
    assert_eq!(resps[1].1, layer0.body, "pipelined responses out of order");
    assert_eq!(resps[2].0, 200);
    assert!(resps[2].1.starts_with(b"{"), "manifest must be JSON");
    assert!(!closed, "keep-alive batch must not advertise Connection: close");

    // batch 2 on the SAME socket: the connection survived
    s.write_all(keep("/healthz").as_bytes()).unwrap();
    let (resps, _) = read_n_responses(&mut s, 1);
    assert_eq!((resps[0].0, resps[0].1.as_slice()), (200, b"ok".as_slice()));

    // explicit close honored: response, then EOF
    s.write_all(get_request("/healthz").as_slice()).unwrap();
    let (resps, closed) = read_n_responses(&mut s, 1);
    assert_eq!(resps[0].0, 200);
    assert!(closed, "Connection: close must be echoed");
    let mut rest = Vec::new();
    s.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty(), "bytes after Connection: close: {rest:?}");

    handle.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// A malformed request mid-pipeline: everything before it is answered
/// normally, the bad request gets a 400, the connection closes cleanly,
/// and the request after it is never parsed (no framing desync).
#[test]
fn event_malformed_mid_pipeline_closes_cleanly() {
    if !deepcabac::util::poll::supported() {
        eprintln!("skipping: readiness polling unsupported on this platform");
        return;
    }
    let (dir, _, _) = write_corpus_dir("malformed");
    let handle = start_backend(dir.clone(), Backend::Event);
    let addr = handle.addr().to_string();
    let before = handle.request_count();

    let mut s = TcpStream::connect(&addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let batch = "GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n\
                 THIS IS NOT HTTP\r\n\r\n\
                 GET /models/alpha/layers/0 HTTP/1.1\r\nHost: x\r\n\r\n";
    s.write_all(batch.as_bytes()).unwrap();
    s.flush().unwrap();

    let (resps, closed) = read_n_responses(&mut s, 2);
    assert_eq!(resps[0].0, 200, "request before the malformed one must succeed");
    assert_eq!(resps[0].1, b"ok");
    assert_eq!(resps[1].0, 400, "malformed request must get a 400");
    assert!(closed, "a 400 must close the connection (framing is not trustworthy)");
    let mut rest = Vec::new();
    s.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty(), "no third response after the close: {rest:?}");

    // the third request was never parsed: 2 requests counted, not 3
    assert_eq!(handle.request_count() - before, 2);

    handle.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// `max_connections`: the event loop holds N keep-alive connections and
/// sheds connection N+1 with a 503 + the `shed` counter in /stats.
#[test]
fn event_max_connections_sheds_with_503() {
    if !deepcabac::util::poll::supported() {
        eprintln!("skipping: readiness polling unsupported on this platform");
        return;
    }
    let (dir, _, _) = write_corpus_dir("shed");
    let handle = start_with(
        Backend::Event,
        ServeOptions {
            dir: dir.clone(),
            addr: "127.0.0.1:0".into(),
            cache_bytes: 1 << 20,
            workers: 2,
            read_timeout: Duration::from_secs(5),
            write_timeout: Duration::from_secs(5),
            max_connections: 2,
        },
    )
    .unwrap();
    let addr = handle.addr().to_string();

    // two keep-alive connections, each proven live by a served request
    let mut held = Vec::new();
    for _ in 0..2 {
        let mut s = TcpStream::connect(&addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        s.write_all(b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let (resps, _) = read_n_responses(&mut s, 1);
        assert_eq!(resps[0].0, 200);
        held.push(s);
    }

    // the third connection is shed with a 503 and a close
    let resp = exchange(&addr, &get_request("/models/alpha"));
    assert!(
        resp.starts_with(b"HTTP/1.1 503 "),
        "expected shed 503, got {:?}",
        String::from_utf8_lossy(&resp)
    );
    assert!(handle.shed_count() >= 1);

    // a held (under-cap) connection still works and reports the shed
    let mut s = held.pop().unwrap();
    s.write_all(b"GET /stats HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
    let (resps, _) = read_n_responses(&mut s, 1);
    assert_eq!(resps[0].0, 200);
    let stats = Json::parse(std::str::from_utf8(&resps[0].1).unwrap()).unwrap();
    assert!(stats.get("shed").unwrap().as_usize().unwrap() >= 1);
    assert_eq!(stats.get("max_connections").unwrap().as_usize().unwrap(), 2);
    assert_eq!(stats.get("backend").unwrap().as_str().unwrap(), "event");

    handle.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// The threaded accept guard sheds the same way (same 503 bytes, same
/// counter), differentially pinning the shed contract across transports.
#[test]
fn threaded_max_connections_sheds_with_503() {
    let (dir, _, _) = write_corpus_dir("shed_threaded");
    let handle = start_with(
        Backend::Threaded,
        ServeOptions {
            dir: dir.clone(),
            addr: "127.0.0.1:0".into(),
            cache_bytes: 1 << 20,
            workers: 2,
            read_timeout: Duration::from_secs(5),
            write_timeout: Duration::from_secs(5),
            // a zero cap sheds every connection — deterministic without
            // needing to wedge handlers to hold `open` up
            max_connections: 0,
        },
    )
    .unwrap();
    let addr = handle.addr().to_string();

    let resp = exchange(&addr, &get_request("/healthz"));
    assert!(
        resp.starts_with(b"HTTP/1.1 503 "),
        "expected shed 503, got {:?}",
        String::from_utf8_lossy(&resp)
    );
    assert!(handle.shed_count() >= 1);

    handle.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// 64 concurrent keep-alive clients against the event loop: every one
/// of them holds its socket across requests (`reused` > 0, zero
/// reconnects) — the in-test slice of the connection-scaling story the
/// smoke benchmark measures at 1k+.
#[test]
fn event_holds_concurrent_keepalive_connections() {
    if !deepcabac::util::poll::supported() {
        eprintln!("skipping: readiness polling unsupported on this platform");
        return;
    }
    let (dir, _, _) = write_corpus_dir("ka64");
    let handle = start_backend(dir.clone(), Backend::Event);
    let addr = handle.addr().to_string();

    let mut clients: Vec<http::KeepAliveClient> = (0..64)
        .map(|_| http::KeepAliveClient::connect(&addr, Duration::from_secs(5)).unwrap())
        .collect();
    // all 64 sockets are open concurrently; three requests each
    for round in 0..3 {
        for c in clients.iter_mut() {
            let (status, len) = c.get("/models/alpha/layers/0").unwrap();
            assert_eq!(status, 200, "round {round}");
            assert!(len > 0);
        }
    }
    for (i, c) in clients.iter().enumerate() {
        assert_eq!(c.reconnects, 0, "client {i} lost its socket");
        assert!(c.reused >= 2, "client {i} never reused its socket");
    }

    handle.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}
