//! Offline **stub** of the PJRT/XLA bindings (vendored shim).
//!
//! The real bindings need a native xla_extension install that does not
//! exist in this environment. This stub keeps the crate compiling:
//! [`PjRtClient::cpu`] returns an "unavailable" error, so every eval
//! path fails fast at runtime with a clear message instead of at build
//! time. Integration tests skip before constructing a client when model
//! artifacts are absent, so `cargo test` stays green.

use std::fmt;

#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn unavailable() -> Self {
        Self("xla backend unavailable: built against the offline stub (vendor/xla)".into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self, Error> {
        Err(Error::unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".into()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error::unavailable())
    }
}

pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self, Error> {
        Err(Error::unavailable())
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self { _private: () }
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::unavailable())
    }
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error::unavailable())
    }
}

#[derive(Clone)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn scalar(_v: f32) -> Self {
        Self { _private: () }
    }

    pub fn vec1(_v: &[f32]) -> Self {
        Self { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Ok(Self { _private: () })
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>, Error> {
        Err(Error::unavailable())
    }

    pub fn array_shape(&self) -> Result<ArrayShape, Error> {
        Err(Error::unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(Error::unavailable())
    }
}

pub struct ArrayShape {
    _private: (),
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &[]
    }
}
