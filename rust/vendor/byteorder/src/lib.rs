//! Offline API-compatible subset of the `byteorder` crate (vendored
//! shim): the `LittleEndian` reads/writes `tensor/npy.rs` and
//! `model/container.rs` use. Panics on short buffers, like the original.

/// Byte-order trait carrying the slice conversion methods.
pub trait ByteOrder {
    fn read_u16(buf: &[u8]) -> u16;
    fn read_u32(buf: &[u8]) -> u32;
    fn read_f32_into(src: &[u8], dst: &mut [f32]);
    fn read_f64_into(src: &[u8], dst: &mut [f64]);
    fn read_i32_into(src: &[u8], dst: &mut [i32]);
    fn read_i64_into(src: &[u8], dst: &mut [i64]);
    fn write_f32_into(src: &[f32], dst: &mut [u8]);
}

pub enum LittleEndian {}

macro_rules! read_into {
    ($src:ident, $dst:ident, $ty:ty, $w:expr) => {{
        assert!(
            $src.len() >= $dst.len() * $w,
            "source too short: {} bytes for {} elems",
            $src.len(),
            $dst.len()
        );
        for (i, out) in $dst.iter_mut().enumerate() {
            *out = <$ty>::from_le_bytes($src[i * $w..(i + 1) * $w].try_into().unwrap());
        }
    }};
}

impl ByteOrder for LittleEndian {
    fn read_u16(buf: &[u8]) -> u16 {
        u16::from_le_bytes(buf[..2].try_into().unwrap())
    }

    fn read_u32(buf: &[u8]) -> u32 {
        u32::from_le_bytes(buf[..4].try_into().unwrap())
    }

    fn read_f32_into(src: &[u8], dst: &mut [f32]) {
        read_into!(src, dst, f32, 4)
    }

    fn read_f64_into(src: &[u8], dst: &mut [f64]) {
        read_into!(src, dst, f64, 8)
    }

    fn read_i32_into(src: &[u8], dst: &mut [i32]) {
        read_into!(src, dst, i32, 4)
    }

    fn read_i64_into(src: &[u8], dst: &mut [i64]) {
        read_into!(src, dst, i64, 8)
    }

    fn write_f32_into(src: &[f32], dst: &mut [u8]) {
        assert!(dst.len() >= src.len() * 4, "destination too short");
        for (i, v) in src.iter().enumerate() {
            dst[i * 4..(i + 1) * 4].copy_from_slice(&v.to_le_bytes());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let vals = [1.5f32, -0.25, 3.0e10, f32::MIN_POSITIVE];
        let mut bytes = vec![0u8; 16];
        LittleEndian::write_f32_into(&vals, &mut bytes);
        let mut back = [0f32; 4];
        LittleEndian::read_f32_into(&bytes, &mut back);
        assert_eq!(vals, back);
    }

    #[test]
    fn scalar_reads() {
        assert_eq!(LittleEndian::read_u16(&[0x34, 0x12]), 0x1234);
        assert_eq!(LittleEndian::read_u32(&[0x78, 0x56, 0x34, 0x12]), 0x12345678);
    }
}
