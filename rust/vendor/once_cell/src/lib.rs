//! Offline API-compatible subset of `once_cell` (vendored shim):
//! `sync::Lazy` implemented on `std::sync::OnceLock`.

pub mod sync {
    use std::ops::Deref;
    use std::sync::OnceLock;

    /// Lazily-initialized static value; the initializer runs at most once.
    pub struct Lazy<T, F = fn() -> T> {
        cell: OnceLock<T>,
        init: F,
    }

    impl<T, F> Lazy<T, F> {
        pub const fn new(init: F) -> Self {
            Self { cell: OnceLock::new(), init }
        }
    }

    impl<T, F: Fn() -> T> Lazy<T, F> {
        pub fn force(this: &Self) -> &T {
            this.cell.get_or_init(&this.init)
        }
    }

    impl<T, F: Fn() -> T> Deref for Lazy<T, F> {
        type Target = T;

        fn deref(&self) -> &T {
            Lazy::force(self)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::sync::Lazy;

    static N: Lazy<u32> = Lazy::new(|| 40 + 2);

    #[test]
    fn static_lazy_initializes_once() {
        assert_eq!(*N, 42);
        assert_eq!(*N, 42);
    }
}
