//! Offline API-compatible subset of the `anyhow` crate (vendored shim).
//!
//! Provides the pieces this workspace uses: [`Error`], [`Result`],
//! [`Context`], and the `anyhow!` / `bail!` / `ensure!` macros. Context
//! chains print with `{:#}` like the real crate.

use std::fmt;

/// A string-backed error with an optional chain of underlying causes.
///
/// Deliberately does **not** implement `std::error::Error`, mirroring the
/// real crate — that is what makes the blanket
/// `impl<E: std::error::Error> From<E> for Error` coherent.
pub struct Error {
    msg: String,
    chain: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Self { msg: m.to_string(), chain: Vec::new() }
    }

    /// Wrap with an outer context message (the real crate's `Error::context`).
    pub fn context<C: fmt::Display>(self, c: C) -> Self {
        let mut chain = vec![self.msg];
        chain.extend(self.chain);
        Self { msg: c.to_string(), chain }
    }

    /// Iterate the context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        std::iter::once(self.msg.as_str()).chain(self.chain.iter().map(|s| s.as_str()))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            for c in &self.chain {
                write!(f, ": {c}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        for c in &self.chain {
            write!(f, "\n\nCaused by:\n    {c}")?;
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = Vec::new();
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Self { msg: e.to_string(), chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(..)` on `Result` and `Option`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(c)
        })
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(::std::format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(::std::concat!("condition failed: ", ::std::stringify!($cond)));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into())
    }

    #[test]
    fn context_chains_render_alternate() {
        let e = io_err().context("loading config").unwrap_err();
        assert_eq!(format!("{e}"), "loading config");
        assert_eq!(format!("{e:#}"), "loading config: gone");
    }

    #[test]
    fn option_context_and_macros() {
        let e: Error = None::<u8>.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
        let e = anyhow!("x = {}", 3);
        assert_eq!(e.to_string(), "x = 3");
        fn f(v: u8) -> Result<u8> {
            ensure!(v < 10, "v too big: {v}");
            if v == 9 {
                bail!("nine");
            }
            Ok(v)
        }
        assert!(f(3).is_ok());
        assert_eq!(f(12).unwrap_err().to_string(), "v too big: 12");
    }
}
