//! Robustness weighting (paper §3): η_i = 1/σ_i², where σ_i is the
//! posterior standard deviation estimated by variational dropout at
//! training time (Layer 2 exports it per weight). This module converts
//! σ tensors into η tensors with the numerical guards the quantizer
//! needs, and provides the uniform-η fallback used by the ablation.

/// Convert posterior sigmas to etas (η = 1/σ²), clamping σ into
/// [sigma_floor, ∞) so frozen weights don't produce infinite stiffness.
pub fn etas_from_sigmas(sigmas: &[f32], sigma_floor: f32) -> Vec<f32> {
    let floor = sigma_floor.max(1e-12);
    sigmas
        .iter()
        .map(|&s| {
            let s = s.abs().max(floor);
            1.0 / (s * s)
        })
        .collect()
}

/// Uniform η = 1 (the unweighted ablation — plain rate-distortion).
pub fn etas_uniform(n: usize) -> Vec<f32> {
    vec![1.0; n]
}

/// A sensible σ floor for a tensor: 1e-3 × the RMS of the nonzero σs
/// (guards against collapsed posteriors without distorting the scale).
pub fn sigma_floor(sigmas: &[f32]) -> f32 {
    let (mut sum, mut n) = (0.0f64, 0usize);
    for &s in sigmas {
        if s > 0.0 {
            sum += (s as f64) * (s as f64);
            n += 1;
        }
    }
    if n == 0 {
        return 1e-6;
    }
    ((sum / n as f64).sqrt() as f32) * 1e-3
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eta_is_inverse_variance() {
        let etas = etas_from_sigmas(&[0.5, 2.0], 1e-6);
        assert!((etas[0] - 4.0).abs() < 1e-6);
        assert!((etas[1] - 0.25).abs() < 1e-6);
    }

    #[test]
    fn zero_sigma_clamped() {
        let etas = etas_from_sigmas(&[0.0, 1.0], 1e-3);
        assert!(etas[0].is_finite());
        assert!((etas[0] - 1e6).abs() / 1e6 < 1e-3);
    }

    #[test]
    fn floor_scales_with_rms() {
        let f = sigma_floor(&[0.1, 0.1, 0.0]);
        assert!((f - 1e-4).abs() < 1e-6);
        assert_eq!(sigma_floor(&[0.0, 0.0]), 1e-6);
    }
}
