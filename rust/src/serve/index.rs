//! Random-access reader over a serialized `.dcbc` container.
//!
//! [`ContainerIndex::build`] walks the v1–v4 headers once (skipping every
//! payload byte) and records absolute byte ranges for each layer's
//! payload, each chunk inside it, and the raw bias bytes. A client can
//! then fetch and decode a single layer — or a single chunk — without
//! touching the rest of the file; the server's `Range` support and the
//! decoded-layer cache are both built on this. The index exists because
//! the `.dcbc` format guarantees header-only locatability — invariant 1
//! of `docs/FORMAT.md` §"Invariants the serving stack relies on".
//! For version-4 progressive containers the index additionally records
//! where each tier's body ends ([`ContainerIndex::tier_ends`]), so
//! `GET /models/{m}?tier=t` can serve an exact byte prefix.

use crate::codec::{decode_levels, CodecConfig};
use crate::model::container::{
    parse_container_prefix, parse_layer_header, parse_varint_prefix, Parsed, VERSION_CHUNKED,
    VERSION_DELTA, VERSION_PROGRESSIVE,
};
use crate::quant::QuantGrid;
use crate::util::par;
use anyhow::{anyhow, bail, Result};
use byteorder::{ByteOrder, LittleEndian};
use std::ops::Range;

/// One chunk's absolute position in the container file.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct IndexedChunk {
    /// Levels coded in this chunk.
    pub n_weights: usize,
    /// Absolute byte range of the chunk's CABAC stream.
    pub bytes: Range<usize>,
}

/// One layer's metadata + absolute byte ranges.
#[derive(Debug, Clone)]
pub struct IndexedLayer {
    pub name: String,
    pub dims: Vec<usize>,
    pub grid: QuantGrid,
    pub s_param: u32,
    pub cfg: CodecConfig,
    pub n_weights: usize,
    /// Absolute byte range of the whole CABAC payload.
    pub payload: Range<usize>,
    /// Per-chunk ranges tiling `payload` (≥ 1 entry).
    pub chunks: Vec<IndexedChunk>,
    /// Absolute byte range of the raw little-endian f32 bias bytes.
    pub bias: Range<usize>,
    /// True for a v3 skip record: the layer is carried over from the
    /// parent unchanged and owns no payload or bias bytes (all ranges
    /// are empty).
    pub skipped: bool,
    /// Tier this record belongs to: always 0 for v1–v3; for v4, layers
    /// of all tiers appear in `layers` in file order (base first).
    pub tier: usize,
}

impl IndexedLayer {
    pub fn bias_count(&self) -> usize {
        self.bias.len() / 4
    }
}

/// Byte-level map of one container: everything needed for random access.
#[derive(Debug, Clone)]
pub struct ContainerIndex {
    pub model: String,
    pub version: u8,
    /// `Some` for v3 delta segments: the parent container fingerprint.
    pub parent_fp: Option<u64>,
    pub container_len: usize,
    pub layers: Vec<IndexedLayer>,
    /// Version-4 only (empty otherwise): absolute offset at which each
    /// tier's body ends. `buf[..tier_ends[t]]` is a complete, decodable
    /// container at tier t (the progressive truncation rule);
    /// `tier_ends.last() == container_len`.
    pub tier_ends: Vec<usize>,
}

impl ContainerIndex {
    /// Build the index by scanning headers only — O(header bytes), no
    /// payload is read or decoded.
    pub fn build(buf: &[u8]) -> Result<Self> {
        let (prefix, mut pos) = match parse_container_prefix(buf)? {
            Parsed::Complete(p, n) => (p, n),
            Parsed::NeedMore => bail!("truncated container prelude"),
        };
        let mut layers = Vec::with_capacity(prefix.n_layers.min(1 << 16));
        let mut tier_ends = Vec::new();
        if prefix.version == VERSION_PROGRESSIVE {
            for (t, &tlen) in prefix.tier_lens.iter().enumerate() {
                if t > 0 && pos == buf.len() {
                    // progressive truncation rule: EOF at a tier-body
                    // boundary is a complete container at tier t−1
                    break;
                }
                let tier_start = pos;
                let hv = if t == 0 { VERSION_CHUNKED } else { VERSION_DELTA };
                for _ in 0..prefix.n_layers {
                    pos = index_layer(buf, pos, hv, t, &mut layers)?;
                }
                if (pos - tier_start) as u64 != tlen {
                    bail!(
                        "tier {t} body is {} bytes but the tier table declares {tlen}",
                        pos - tier_start
                    );
                }
                tier_ends.push(pos);
            }
        } else {
            for _ in 0..prefix.n_layers {
                pos = index_layer(buf, pos, prefix.version, 0, &mut layers)?;
            }
        }
        if pos != buf.len() {
            bail!("trailing bytes in container");
        }
        Ok(Self {
            model: prefix.name,
            version: prefix.version,
            parent_fp: prefix.parent_fp,
            container_len: buf.len(),
            layers,
            tier_ends,
        })
    }

    /// Number of tiers the indexed container holds: 1 for v1–v3.
    pub fn n_tiers(&self) -> usize {
        self.tier_ends.len().max(1)
    }

    /// Resolve a layer by name (`"conv1"`) or by index (`"3"`). An exact
    /// name match wins over the numeric interpretation, so a model whose
    /// layers are *named* with digits never silently serves a different
    /// layer than the one asked for.
    pub fn resolve(&self, id: &str) -> Option<usize> {
        if let Some(i) = self.layers.iter().position(|l| l.name == id) {
            return Some(i);
        }
        match id.parse::<usize>() {
            Ok(i) if i < self.layers.len() => Some(i),
            _ => None,
        }
    }

    /// The compressed payload bytes of one layer.
    pub fn layer_payload<'a>(&self, buf: &'a [u8], layer: usize) -> Result<&'a [u8]> {
        let l = self.layer(layer)?;
        buf.get(l.payload.clone())
            .ok_or_else(|| anyhow!("container buffer shorter than index"))
    }

    /// Decode one layer's integer levels straight out of the container
    /// buffer, fanning chunks over up to `workers` threads. Identical to
    /// [`crate::model::CompressedLayer::decode_levels_with`].
    pub fn decode_layer_levels(
        &self,
        buf: &[u8],
        layer: usize,
        workers: usize,
    ) -> Result<Vec<i32>> {
        let l = self.layer(layer)?;
        if self.container_len != buf.len() {
            bail!("container buffer shorter than index");
        }
        let decoded = par::map_indexed(l.chunks.len(), workers, |i| {
            let c = &l.chunks[i];
            decode_levels(&buf[c.bytes.clone()], c.n_weights, l.cfg)
        });
        let mut levels = Vec::with_capacity(l.n_weights);
        for s in decoded {
            levels.extend_from_slice(&s);
        }
        Ok(levels)
    }

    /// Decode one layer's reconstructed weights (levels × Δ).
    pub fn decode_layer_weights(
        &self,
        buf: &[u8],
        layer: usize,
        workers: usize,
    ) -> Result<Vec<f32>> {
        let l = self.layer(layer)?;
        let levels = self.decode_layer_levels(buf, layer, workers)?;
        Ok(l.grid.dequantize(&levels))
    }

    /// One layer's raw bias values.
    pub fn layer_bias(&self, buf: &[u8], layer: usize) -> Result<Vec<f32>> {
        let l = self.layer(layer)?;
        let bytes = buf
            .get(l.bias.clone())
            .ok_or_else(|| anyhow!("container buffer shorter than index"))?;
        let mut bias = vec![0f32; bytes.len() / 4];
        LittleEndian::read_f32_into(bytes, &mut bias);
        Ok(bias)
    }

    fn layer(&self, i: usize) -> Result<&IndexedLayer> {
        self.layers.get(i).ok_or_else(|| {
            anyhow!("layer {i} out of range (container has {})", self.layers.len())
        })
    }
}

/// Index one layer record at `pos`, parsed with `hdr_version` semantics
/// (v2-shaped for a v4 base tier, v3-shaped for refinement tiers), and
/// return the position after it.
fn index_layer(
    buf: &[u8],
    mut pos: usize,
    hdr_version: u8,
    tier: usize,
    layers: &mut Vec<IndexedLayer>,
) -> Result<usize> {
    let hdr = match parse_layer_header(&buf[pos..], hdr_version)? {
        Parsed::Complete(h, n) => {
            pos += n;
            h
        }
        Parsed::NeedMore => bail!("truncated layer header"),
    };
    if hdr.skipped {
        // skip record: name only, no payload or bias bytes
        layers.push(IndexedLayer {
            name: hdr.name,
            dims: hdr.dims,
            grid: hdr.grid,
            s_param: hdr.s_param,
            cfg: hdr.cfg,
            n_weights: 0,
            payload: pos..pos,
            chunks: vec![IndexedChunk { n_weights: 0, bytes: pos..pos }],
            bias: pos..pos,
            skipped: true,
            tier,
        });
        return Ok(pos);
    }
    if hdr.payload_len > buf.len() - pos {
        bail!("truncated payload");
    }
    let payload = pos..pos + hdr.payload_len;
    let chunks = hdr
        .chunk_spans()
        .into_iter()
        .map(|s| IndexedChunk {
            n_weights: s.n_weights,
            bytes: pos + s.offset..pos + s.offset + s.bytes,
        })
        .collect();
    pos += hdr.payload_len;
    let blen = match parse_varint_prefix(&buf[pos..])? {
        Parsed::Complete(v, n) => {
            pos += n;
            v as usize
        }
        Parsed::NeedMore => bail!("truncated bias"),
    };
    if blen > crate::baselines::MAX_DECODE_ELEMS || blen * 4 > buf.len() - pos {
        bail!("truncated bias");
    }
    let bias = pos..pos + blen * 4;
    pos += blen * 4;
    layers.push(IndexedLayer {
        name: hdr.name,
        dims: hdr.dims,
        grid: hdr.grid,
        s_param: hdr.s_param,
        cfg: hdr.cfg,
        n_weights: hdr.n_weights,
        payload,
        chunks,
        bias,
        skipped: false,
        tier,
    });
    Ok(pos)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::{encode_levels, CodecConfig};
    use crate::model::{ChunkInfo, CompressedLayer, CompressedModel};
    use crate::util::SplitMix64;

    fn build_model(chunked: bool) -> CompressedModel {
        let cfg = CodecConfig::default();
        let mut rng = SplitMix64::new(21);
        let mut layers = Vec::new();
        for (li, n) in [900usize, 333, 80].iter().enumerate() {
            let levels: Vec<i32> = (0..*n)
                .map(|_| {
                    if rng.next_f64() < 0.7 {
                        0
                    } else {
                        (1 + rng.below(20) as i32)
                            * if rng.next_u64() & 1 == 0 { 1 } else { -1 }
                    }
                })
                .collect();
            let n_chunks = if chunked && li != 2 { 3 } else { 1 };
            let per = ((levels.len() + n_chunks - 1) / n_chunks).max(1);
            let mut payload = Vec::new();
            let mut chunks = Vec::new();
            for part in levels.chunks(per) {
                let bytes = encode_levels(part, cfg);
                chunks.push(ChunkInfo { n_weights: part.len(), bytes: bytes.len() });
                payload.extend_from_slice(&bytes);
            }
            if chunks.len() <= 1 {
                chunks.clear();
            }
            layers.push(CompressedLayer {
                name: format!("l{li}"),
                dims: vec![levels.len()],
                grid: crate::quant::QuantGrid { delta: 0.0625, max_level: 25 },
                s_param: 9,
                cfg,
                n_weights: levels.len(),
                payload,
                chunks,
                bias: (0..li).map(|b| b as f32 * 0.5).collect(),
            });
        }
        CompressedModel { name: "indexed".into(), layers }
    }

    #[test]
    fn index_matches_batch_decode() {
        for chunked in [false, true] {
            let model = build_model(chunked);
            let bytes = model.serialize();
            let idx = ContainerIndex::build(&bytes).unwrap();
            assert_eq!(idx.model, "indexed");
            assert_eq!(idx.layers.len(), model.layers.len());
            assert_eq!(idx.container_len, bytes.len());
            for (i, l) in model.layers.iter().enumerate() {
                // payload range points at the exact stored payload bytes
                assert_eq!(idx.layer_payload(&bytes, i).unwrap(), &l.payload[..]);
                // chunk ranges tile the payload range
                let il = &idx.layers[i];
                assert_eq!(il.chunks.len(), l.n_chunks());
                assert_eq!(il.chunks.first().unwrap().bytes.start, il.payload.start);
                assert_eq!(il.chunks.last().unwrap().bytes.end, il.payload.end);
                // random-access decode == batch decode, serial and parallel
                for workers in [1usize, 4] {
                    assert_eq!(
                        idx.decode_layer_levels(&bytes, i, workers).unwrap(),
                        l.decode_levels(),
                        "layer {i} workers {workers}"
                    );
                }
                let got: Vec<u32> = idx
                    .decode_layer_weights(&bytes, i, 2)
                    .unwrap()
                    .iter()
                    .map(|w| w.to_bits())
                    .collect();
                let want: Vec<u32> =
                    l.decode_weights().iter().map(|w| w.to_bits()).collect();
                assert_eq!(got, want);
                assert_eq!(idx.layer_bias(&bytes, i).unwrap(), l.bias);
            }
        }
    }

    #[test]
    fn resolve_by_index_and_name() {
        let bytes = build_model(true).serialize();
        let idx = ContainerIndex::build(&bytes).unwrap();
        assert_eq!(idx.resolve("0"), Some(0));
        assert_eq!(idx.resolve("l2"), Some(2));
        assert_eq!(idx.resolve("7"), None);
        assert_eq!(idx.resolve("nope"), None);
        assert!(idx.decode_layer_levels(&bytes, 99, 1).is_err());
    }

    #[test]
    fn indexes_v3_delta_segments() {
        use crate::model::{DeltaLayer, DeltaModel};
        let full = build_model(true);
        let delta = DeltaModel {
            parent_fp: 0xFEED_FACE_0123_4567,
            name: "indexed".into(),
            layers: vec![
                DeltaLayer::Skipped("l0".into()),
                DeltaLayer::Coded(full.layers[1].clone()),
                DeltaLayer::Skipped("l2".into()),
            ],
        };
        let bytes = delta.serialize();
        let idx = ContainerIndex::build(&bytes).unwrap();
        assert_eq!(idx.version, 3);
        assert_eq!(idx.parent_fp, Some(0xFEED_FACE_0123_4567));
        assert_eq!(idx.layers.len(), 3);
        assert!(idx.layers[0].skipped && idx.layers[2].skipped);
        assert!(idx.layers[0].payload.is_empty() && idx.layers[0].bias.is_empty());
        // skip records decode to nothing without error
        assert_eq!(idx.decode_layer_levels(&bytes, 0, 2).unwrap(), Vec::<i32>::new());
        // the coded record random-accesses exactly like a full layer
        let l = &full.layers[1];
        assert!(!idx.layers[1].skipped);
        assert_eq!(idx.layer_payload(&bytes, 1).unwrap(), &l.payload[..]);
        assert_eq!(idx.decode_layer_levels(&bytes, 1, 4).unwrap(), l.decode_levels());
        assert_eq!(idx.layer_bias(&bytes, 1).unwrap(), l.bias);
        // full containers index with no parent fingerprint
        let fidx = ContainerIndex::build(&full.serialize()).unwrap();
        assert_eq!(fidx.parent_fp, None);
        assert!(fidx.layers.iter().all(|l| !l.skipped));
    }

    #[test]
    fn rejects_corrupt_containers() {
        let bytes = build_model(true).serialize();
        assert!(ContainerIndex::build(&bytes[..bytes.len() - 2]).is_err());
        assert!(ContainerIndex::build(&bytes[1..]).is_err());
        let mut bad = bytes.clone();
        bad[4] = 42;
        assert!(ContainerIndex::build(&bad).is_err());
    }

    #[test]
    fn indexes_v4_progressive_tiers() {
        use crate::model::{DeltaLayer, ProgressiveModel};
        let full = build_model(true);
        let prog = ProgressiveModel {
            name: "indexed".into(),
            base: full.layers.clone(),
            refinements: vec![vec![
                DeltaLayer::Coded(full.layers[0].clone()),
                DeltaLayer::Skipped("l1".into()),
                DeltaLayer::Skipped("l2".into()),
            ]],
        };
        let bytes = prog.serialize();
        let idx = ContainerIndex::build(&bytes).unwrap();
        assert_eq!(idx.version, 4);
        assert_eq!(idx.n_tiers(), 2);
        assert_eq!(idx.layers.len(), 6);
        assert!(idx.layers[..3].iter().all(|l| l.tier == 0 && !l.skipped));
        assert!(idx.layers[3..].iter().all(|l| l.tier == 1));
        assert!(idx.layers[4].skipped && idx.layers[5].skipped);
        // tier end offsets match the serializer's tier table, and the
        // last one covers the whole file
        let lens = prog.tier_body_lens();
        let prelude = bytes.len() - lens.iter().sum::<usize>();
        assert_eq!(idx.tier_ends, vec![prelude + lens[0], bytes.len()]);
        // the tier-0 prefix is itself a complete, indexable container
        let prefix_idx = ContainerIndex::build(&bytes[..idx.tier_ends[0]]).unwrap();
        assert_eq!(prefix_idx.n_tiers(), 1);
        assert_eq!(prefix_idx.layers.len(), 3);
        // random access into a refinement record decodes its residuals
        let l = &full.layers[0];
        assert_eq!(idx.decode_layer_levels(&bytes, 3, 2).unwrap(), l.decode_levels());
        assert_eq!(idx.layer_bias(&bytes, 3).unwrap(), l.bias);
        // a v1/v2 container reports a single tier and no tier table
        let fidx = ContainerIndex::build(&full.serialize()).unwrap();
        assert!(fidx.tier_ends.is_empty());
        assert_eq!(fidx.n_tiers(), 1);
        // mid-tier truncation still rejects
        assert!(ContainerIndex::build(&bytes[..idx.tier_ends[0] + 1]).is_err());
        assert!(ContainerIndex::build(&bytes[..idx.tier_ends[0] - 1]).is_err());
    }
}
