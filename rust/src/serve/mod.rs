//! Model-delivery serving over `.dcbc` containers.
//!
//! DeepCABAC's deployment target is transmitting compressed networks to
//! many resource-constrained clients (paper §1; arXiv:1907.11900 frames
//! it explicitly as a transmission codec). This subsystem turns the
//! batch codec into that delivery path, dependency-free (`std::net` +
//! [`crate::util::par`]). Everything here is built on the `.dcbc` wire
//! invariants specified in `docs/FORMAT.md` (header-only locatability,
//! prefix monotonicity, chunk independence):
//!
//! * [`stream`] — a push-based incremental decoder: `feed()` bytes as
//!   they arrive off the wire, get fully decoded layers (and, within a
//!   layer, completed v2 chunks) as soon as their bytes are complete,
//!   without ever buffering the whole container.
//! * [`index`] — [`index::ContainerIndex`]: per-layer / per-chunk byte
//!   ranges built from the v1/v2 headers alone, so one layer can be
//!   fetched and decoded without touching the rest of the file.
//! * [`cache`] — byte-budgeted LRU over decoded layers, shared by every
//!   connection handler.
//! * [`http`] — minimal HTTP/1.1 framing (server + client side) with
//!   `Range` support.
//! * [`mmap`] — [`mmap::ModelBytes`]: read-only `mmap` of a container
//!   (heap fallback), so Range/tier/delta responses are zero-copy.
//! * [`server`] — shared routing/state ([`server::ServeOptions`],
//!   `respond`) behind two transports: the thread-per-connection accept
//!   loop bounded by a [`crate::util::par::WorkerPool`], and —
//! * [`event`] — the epoll/kqueue readiness loop
//!   ([`crate::util::poll`]) with HTTP/1.1 keep-alive, bounded
//!   pipelining, and poll-driven read/write deadlines, holding
//!   thousands of mostly-idle connections on one thread. Both serve
//!   byte-identical responses (differentially tested).
//! * [`loadgen`] — closed- and open-loop (Poisson) load generator
//!   reporting p50/p99/p999 latency, throughput, and a
//!   connection-scaling sweep to `BENCH_serve.json`.

pub mod cache;
pub mod event;
pub mod http;
pub mod index;
pub mod loadgen;
pub mod mmap;
pub mod server;
pub mod stream;

pub use cache::{CacheStats, DecodedCache};
pub use index::ContainerIndex;
pub use mmap::ModelBytes;
pub use server::{Backend, ServeOptions, ServerHandle};
pub use stream::{DecodedLayer, StreamDecoder, StreamEvent};
