//! Zero-copy container bytes: a read-only `mmap` of the `.dcbc` file
//! with a buffered-read fallback.
//!
//! The server indexes containers once and then serves byte ranges out of
//! them for the life of the process. Mapping the file means Range / tier
//! / delta responses are written straight from the page cache — no heap
//! copy per request, and cold pages fault in lazily instead of the whole
//! multi-GB container being resident up front. Like
//! [`crate::util::poll`], the binding is a pair of `extern "C"`
//! declarations against symbols `std` already links; on non-Unix
//! platforms (or when `mmap` fails, e.g. on an empty file or an exotic
//! filesystem) [`ModelBytes::load`] silently falls back to an ordinary
//! heap read — behavior is identical either way, only the copy count
//! differs.

use anyhow::{Context, Result};
use std::ops::Deref;
use std::path::Path;

/// Immutable container bytes, either mapped or heap-resident. Derefs to
/// `&[u8]`; shared across connections behind an `Arc`.
pub enum ModelBytes {
    #[cfg(unix)]
    Mapped {
        ptr: *const u8,
        len: usize,
    },
    Heap(Vec<u8>),
}

// A PROT_READ private mapping is immutable shared memory; the raw
// pointer is only ever read through &self.
unsafe impl Send for ModelBytes {}
unsafe impl Sync for ModelBytes {}

#[cfg(unix)]
mod sys {
    pub const PROT_READ: i32 = 0x1;
    pub const MAP_PRIVATE: i32 = 0x2;

    extern "C" {
        pub fn mmap(
            addr: *mut u8,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut u8;
        pub fn munmap(addr: *mut u8, len: usize) -> i32;
    }
}

impl ModelBytes {
    /// Map `path` read-only, falling back to a heap read when mapping is
    /// unavailable. The fallback is also taken for empty files (a
    /// zero-length `mmap` is an error by spec).
    pub fn load(path: &Path) -> Result<ModelBytes> {
        #[cfg(unix)]
        {
            if let Some(mapped) = Self::try_map(path) {
                return Ok(mapped);
            }
        }
        let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
        Ok(ModelBytes::Heap(bytes))
    }

    /// Force the heap representation (tests, synthetic containers).
    pub fn from_vec(bytes: Vec<u8>) -> ModelBytes {
        ModelBytes::Heap(bytes)
    }

    /// True when the bytes are served from a mapping (diagnostics).
    pub fn is_mapped(&self) -> bool {
        #[cfg(unix)]
        {
            matches!(self, ModelBytes::Mapped { .. })
        }
        #[cfg(not(unix))]
        {
            false
        }
    }

    #[cfg(unix)]
    fn try_map(path: &Path) -> Option<ModelBytes> {
        use std::os::fd::AsRawFd;
        let file = std::fs::File::open(path).ok()?;
        let len = file.metadata().ok()?.len();
        if len == 0 || len > usize::MAX as u64 {
            return None;
        }
        let len = len as usize;
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        // MAP_FAILED is (void*)-1
        if ptr.is_null() || ptr as isize == -1 {
            return None;
        }
        Some(ModelBytes::Mapped { ptr, len })
    }
}

impl Deref for ModelBytes {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        match self {
            #[cfg(unix)]
            ModelBytes::Mapped { ptr, len } => unsafe {
                std::slice::from_raw_parts(*ptr, *len)
            },
            ModelBytes::Heap(v) => v,
        }
    }
}

impl Drop for ModelBytes {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let ModelBytes::Mapped { ptr, len } = self {
            unsafe {
                sys::munmap(*ptr as *mut u8, *len);
            }
        }
    }
}

impl std::fmt::Debug for ModelBytes {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "ModelBytes({} bytes, {})",
            self.len(),
            if self.is_mapped() { "mapped" } else { "heap" }
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mapped_bytes_match_fs_read() {
        let dir = std::env::temp_dir().join(format!("dcbc_mmap_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("sample.bin");
        let payload: Vec<u8> = (0..10_000u32).flat_map(|i| i.to_le_bytes()).collect();
        std::fs::write(&path, &payload).unwrap();

        let loaded = ModelBytes::load(&path).unwrap();
        assert_eq!(&loaded[..], &payload[..]);
        #[cfg(unix)]
        assert!(loaded.is_mapped());

        // slicing works through Deref like any &[u8]
        assert_eq!(&loaded[4..8], &payload[4..8]);
        drop(loaded); // munmap must not invalidate other state
        std::fs::remove_file(&path).ok();
        std::fs::remove_dir(&dir).ok();
    }

    #[test]
    fn empty_file_falls_back_to_heap() {
        let dir = std::env::temp_dir().join(format!("dcbc_mmap_e_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("empty.bin");
        std::fs::write(&path, b"").unwrap();
        let loaded = ModelBytes::load(&path).unwrap();
        assert!(!loaded.is_mapped());
        assert!(loaded.is_empty());
        std::fs::remove_file(&path).ok();
        std::fs::remove_dir(&dir).ok();
    }

    #[test]
    fn from_vec_is_heap() {
        let b = ModelBytes::from_vec(vec![1, 2, 3]);
        assert!(!b.is_mapped());
        assert_eq!(&b[..], &[1, 2, 3]);
    }
}
