//! Minimal HTTP/1.1 framing over `std::net` — just enough for the model
//! delivery server and its clients: GET requests, `Content-Length`
//! bodies, `Range: bytes=…` on both sides, `Connection: close`
//! semantics. Deliberately not a general HTTP implementation.

use anyhow::{anyhow, bail, Context, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;

/// Cap on request head size (hostile-client guard). Shared with the
/// event loop's incremental head scanner so both backends reject at the
/// same bound.
pub(crate) const MAX_HEAD_BYTES: usize = 16 * 1024;

/// A parsed request head (the server never needs bodies).
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    headers: Vec<(String, String)>,
}

impl Request {
    /// Case-insensitive header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    /// Parse `Range: bytes=a-b` against a body of `len` bytes, with RFC
    /// 7233 semantics: `Ignored` when the header is absent, malformed,
    /// uses an unknown unit, or asks for multipart ranges (the server
    /// must then answer 200 with the full body), `Satisfiable` with the
    /// half-open range when it can be honored (→ 206), `Unsatisfiable`
    /// only for a syntactically valid single bytes-range that lies
    /// outside the body (→ 416).
    pub fn byte_range(&self, len: usize) -> RangeOutcome {
        let Some(spec) = self.header("range") else {
            crate::fuzz::cov::edge!("range_absent");
            return RangeOutcome::Ignored;
        };
        let spec = spec.trim();
        let Some(spec) = spec.strip_prefix("bytes=") else {
            crate::fuzz::cov::edge!("range_not_bytes");
            return RangeOutcome::Ignored; // unknown unit: MUST ignore
        };
        if spec.contains(',') {
            crate::fuzz::cov::edge!("range_multi");
            return RangeOutcome::Ignored; // multipart unsupported: serve full
        }
        let Some((a, b)) = spec.split_once('-') else {
            crate::fuzz::cov::edge!("range_no_dash");
            return RangeOutcome::Ignored;
        };
        let (start, end) = match (a.trim(), b.trim()) {
            ("", "") => {
                crate::fuzz::cov::edge!("range_empty_pair");
                return RangeOutcome::Ignored;
            }
            // suffix range: last N bytes
            ("", n) => {
                let Ok(n) = n.parse::<usize>() else {
                    crate::fuzz::cov::edge!("range_suffix_bad");
                    return RangeOutcome::Ignored;
                };
                if n == 0 {
                    crate::fuzz::cov::edge!("range_suffix_zero");
                    return RangeOutcome::Unsatisfiable;
                }
                crate::fuzz::cov::edge!("range_suffix_ok");
                (len.saturating_sub(n), len)
            }
            (s, "") => {
                let Ok(s) = s.parse::<usize>() else {
                    crate::fuzz::cov::edge!("range_open_bad");
                    return RangeOutcome::Ignored;
                };
                crate::fuzz::cov::edge!("range_open_ok");
                (s, len)
            }
            (s, e) => {
                let (Ok(s), Ok(e)) = (s.parse::<usize>(), e.parse::<usize>()) else {
                    crate::fuzz::cov::edge!("range_closed_bad");
                    return RangeOutcome::Ignored;
                };
                crate::fuzz::cov::edge!("range_closed_ok");
                (s, e.saturating_add(1).min(len))
            }
        };
        if start >= len || start >= end {
            crate::fuzz::cov::edge!("range_unsat");
            return RangeOutcome::Unsatisfiable;
        }
        crate::fuzz::cov::edge!("range_sat");
        RangeOutcome::Satisfiable(start..end)
    }
}

/// Outcome of [`Request::byte_range`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RangeOutcome {
    /// No usable Range header — serve the full body with 200.
    Ignored,
    /// Serve this slice with 206.
    Satisfiable(std::ops::Range<usize>),
    /// Answer 416 with `Content-Range: bytes */len`.
    Unsatisfiable,
}

/// First value of `key` in the request path's query string (`?a=1&b=2`),
/// or `None` if absent. No percent-decoding — the delta endpoint's
/// fingerprints are plain hex and anything else should fail the
/// downstream parse, not get creatively decoded.
pub fn query_param(path: &str, key: &str) -> Option<String> {
    let (_, query) = path.split_once('?')?;
    query
        .split('&')
        .filter_map(|kv| kv.split_once('='))
        .find(|(k, _)| *k == key)
        .map(|(_, v)| v.to_string())
}

/// Make a container/user-supplied string safe to embed in a response
/// header: control characters (notably CR/LF — response splitting) are
/// replaced with `_`.
pub fn sanitize_header_value(s: &str) -> String {
    s.chars().map(|c| if c.is_control() { '_' } else { c }).collect()
}

/// Read one request head off the stream (up to the blank line), then
/// parse it with [`parse_request_head`].
pub fn read_request(stream: &mut TcpStream) -> Result<Request> {
    // hard-cap everything read while parsing the head, so a hostile
    // client cannot grow a single header line without bound
    let mut reader = BufReader::new(Read::take(&mut *stream, MAX_HEAD_BYTES as u64));
    let mut head = Vec::new();
    loop {
        let mut line = Vec::new();
        // tag_io: the server's 408 path keys off the [kind=…] tag to tell
        // a read-deadline expiry apart from genuinely malformed bytes
        let n = reader.read_until(b'\n', &mut line).map_err(tag_io)?;
        if n == 0 {
            if head.len() + line.len() >= MAX_HEAD_BYTES {
                bail!("request head too large");
            }
            bail!("connection closed mid-request");
        }
        if line == b"\r\n" || line == b"\n" {
            break;
        }
        head.extend_from_slice(&line);
        if head.len() >= MAX_HEAD_BYTES {
            bail!("request head too large");
        }
    }
    parse_request_head(&head)
}

/// Parse a request head (request line + header lines, the terminating
/// blank line already stripped) out of raw bytes. Factored out of
/// [`read_request`] so the structure-aware fuzzer (`crate::fuzz`) can
/// drive the parser directly, without a socket; every hostile byte
/// sequence must come back as `Ok` or `Err`, never a panic.
pub fn parse_request_head(head: &[u8]) -> Result<Request> {
    if head.len() > MAX_HEAD_BYTES {
        crate::fuzz::cov::edge!("head_too_large");
        bail!("request head too large");
    }
    let head = std::str::from_utf8(head)
        .map_err(|e| {
            crate::fuzz::cov::edge!("head_not_utf8");
            e
        })
        .context("non-utf8 request head")?;
    let mut lines = head.lines();
    let request_line = lines.next().ok_or_else(|| {
        crate::fuzz::cov::edge!("head_empty");
        anyhow!("empty request")
    })?;
    let mut parts = request_line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| {
            crate::fuzz::cov::edge!("head_bad_request_line");
            anyhow!("bad request line")
        })?
        .to_string();
    let path = parts
        .next()
        .ok_or_else(|| {
            crate::fuzz::cov::edge!("head_bad_request_line");
            anyhow!("bad request line")
        })?
        .to_string();
    let mut headers = Vec::new();
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            crate::fuzz::cov::edge!("head_header_line");
            headers.push((k.trim().to_string(), v.trim().to_string()));
        }
    }
    crate::fuzz::cov::edge!("head_ok");
    Ok(Request { method, path, headers })
}

/// Render a response head. Every response from both server backends
/// goes through this one function so header names, order and formatting
/// are byte-identical regardless of transport: status line, then
/// `Content-Type`, `Content-Length`, `Connection` (the only
/// backend-dependent value — the threaded server always closes, the
/// event loop honors keep-alive), then any extra headers.
pub fn render_head(
    status: u16,
    reason: &str,
    content_type: &str,
    body_len: usize,
    connection: &str,
    extra_headers: &[(&str, String)],
) -> String {
    let mut head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {body_len}\r\nConnection: {connection}\r\n"
    );
    for (k, v) in extra_headers {
        head.push_str(k);
        head.push_str(": ");
        head.push_str(v);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    head
}

/// Write a full response (status line, standard headers, body) with
/// `Connection: close` semantics.
pub fn write_response(
    stream: &mut TcpStream,
    status: u16,
    reason: &str,
    content_type: &str,
    extra_headers: &[(&str, String)],
    body: &[u8],
) -> Result<()> {
    let head = render_head(status, reason, content_type, body.len(), "close", extra_headers);
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()?;
    Ok(())
}

/// Convenience error response (plain-text body).
pub fn write_error(stream: &mut TcpStream, status: u16, reason: &str, msg: &str) -> Result<()> {
    write_response(stream, status, reason, "text/plain", &[], msg.as_bytes())
}

// ---------------------------------------------------------------------------
// Client side
// ---------------------------------------------------------------------------

/// Convert a client-side I/O error into an `anyhow` error whose message
/// carries the [`std::io::ErrorKind`] as a machine-readable `[kind=…]`
/// tag. The vendored `anyhow` shim is string-backed (no `downcast_ref`),
/// so the loadgen failure taxonomy classifies on this tag instead of on
/// platform-dependent `strerror` text.
pub fn tag_io(e: std::io::Error) -> anyhow::Error {
    anyhow!("{e} [kind={:?}]", e.kind())
}

/// Split `http://host:port/path` into (`host:port`, `/path`).
pub fn parse_url(url: &str) -> Result<(String, String)> {
    let rest = url
        .strip_prefix("http://")
        .ok_or_else(|| anyhow!("only http:// URLs are supported: {url}"))?;
    let (addr, path) = match rest.find('/') {
        Some(i) => (&rest[..i], &rest[i..]),
        None => (rest, "/"),
    };
    if addr.is_empty() {
        bail!("empty host in {url}");
    }
    let addr =
        if addr.contains(':') { addr.to_string() } else { format!("{addr}:80") };
    Ok((addr, path.to_string()))
}

/// A client-side response.
#[derive(Debug)]
pub struct ClientResponse {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl ClientResponse {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }
}

/// Blocking GET, whole body in memory.
pub fn get(addr: &str, path: &str, range: Option<(u64, u64)>) -> Result<ClientResponse> {
    let mut body = Vec::new();
    let (status, headers, err_body) = get_streaming(addr, path, range, &mut |chunk| {
        body.extend_from_slice(chunk);
        Ok(())
    })?;
    // non-2xx bodies bypass the sink; splice them back for the caller
    if body.is_empty() {
        body = err_body;
    }
    Ok(ClientResponse { status, headers, body })
}

/// Blocking GET that hands body bytes to `sink` as they arrive off the
/// socket — this is what drives [`super::stream::StreamDecoder`] for
/// over-the-wire incremental decode. The sink only ever sees **2xx**
/// bodies; a non-2xx body (an error page, not payload) is collected and
/// returned as the third tuple element instead, so callers can report
/// the status without feeding garbage into a decoder.
pub fn get_streaming(
    addr: &str,
    path: &str,
    range: Option<(u64, u64)>,
    sink: &mut dyn FnMut(&[u8]) -> Result<()>,
) -> Result<(u16, Vec<(String, String)>, Vec<u8>)> {
    get_streaming_with(addr, path, range, std::time::Duration::from_secs(30), sink)
}

/// [`get_streaming`] with an explicit per-socket-operation deadline —
/// fault-injection tests drive hostile/stalling servers with sub-second
/// timeouts so a wedged peer surfaces as a fast `Err`, not a 30 s hang.
pub fn get_streaming_with(
    addr: &str,
    path: &str,
    range: Option<(u64, u64)>,
    timeout: std::time::Duration,
    sink: &mut dyn FnMut(&[u8]) -> Result<()>,
) -> Result<(u16, Vec<(String, String)>, Vec<u8>)> {
    let mut stream = TcpStream::connect(addr)
        .map_err(tag_io)
        .with_context(|| format!("connecting to {addr}"))?;
    // a stalled/saturated server must surface as an error, not a hang
    let _ = stream.set_read_timeout(Some(timeout));
    let _ = stream.set_write_timeout(Some(timeout));
    let range_hdr = match range {
        Some((a, b)) => format!("Range: bytes={a}-{b}\r\n"),
        None => String::new(),
    };
    let req = format!(
        "GET {path} HTTP/1.1\r\nHost: {addr}\r\nAccept: */*\r\n{range_hdr}Connection: close\r\n\r\n"
    );
    stream.write_all(req.as_bytes()).map_err(tag_io)?;
    stream.flush().map_err(tag_io)?;

    let mut reader = BufReader::new(stream);
    // status line
    let mut line = String::new();
    reader.read_line(&mut line).map_err(tag_io)?;
    let mut parts = line.split_whitespace();
    let proto = parts.next().unwrap_or("");
    if !proto.starts_with("HTTP/1.") {
        bail!("not an HTTP response: {line:?}");
    }
    let status: u16 = parts.next().unwrap_or("").parse().context("bad status")?;
    // headers
    let mut headers = Vec::new();
    loop {
        let mut line = String::new();
        let n = reader.read_line(&mut line).map_err(tag_io)?;
        if n == 0 {
            bail!("connection closed in response head");
        }
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        if let Some((k, v)) = line.split_once(':') {
            headers.push((k.trim().to_string(), v.trim().to_string()));
        }
    }
    let content_length: Option<usize> = headers
        .iter()
        .find(|(k, _)| k.eq_ignore_ascii_case("content-length"))
        .and_then(|(_, v)| v.parse().ok());
    // body: stream until Content-Length is satisfied (or EOF without one)
    let ok = (200..300).contains(&status);
    let mut err_body = Vec::new();
    let mut remaining = content_length;
    let mut chunk = [0u8; 64 * 1024];
    loop {
        if remaining == Some(0) {
            break;
        }
        let want = match remaining {
            Some(r) => r.min(chunk.len()),
            None => chunk.len(),
        };
        let n = reader.read(&mut chunk[..want]).map_err(tag_io)?;
        if n == 0 {
            if let Some(r) = remaining {
                if r > 0 {
                    bail!("connection closed {r} bytes early");
                }
            }
            break;
        }
        if ok {
            sink(&chunk[..n])?;
        } else if err_body.len() < 64 * 1024 {
            err_body.extend_from_slice(&chunk[..n]);
        }
        if let Some(r) = remaining.as_mut() {
            *r -= n;
        }
    }
    Ok((status, headers, err_body))
}

/// A persistent HTTP/1.1 connection for the connection-scaling
/// harness: requests are sent *without* `Connection: close`, so a
/// keep-alive-capable server answers many requests on one socket. The
/// client transparently reconnects when the server closes (the
/// thread-per-connection backend always does) and counts both events —
/// `reused` vs `reconnects` is how the loadgen sweep proves which
/// backend actually holds connections open.
pub struct KeepAliveClient {
    addr: std::net::SocketAddr,
    timeout: std::time::Duration,
    reader: Option<BufReader<TcpStream>>,
    /// Responses served on an already-used socket.
    pub reused: u64,
    /// Fresh sockets dialed after the first (server closed or errored).
    pub reconnects: u64,
    /// Responses completed on the current socket.
    served_on_socket: u64,
}

impl KeepAliveClient {
    /// Resolve and dial `addr` ("host:port") within `timeout`. The
    /// initial connect is part of construction so the sweep can count
    /// how many concurrent sockets were actually established.
    pub fn connect(addr: &str, timeout: std::time::Duration) -> Result<Self> {
        use std::net::ToSocketAddrs;
        let sockaddr = addr
            .to_socket_addrs()
            .map_err(tag_io)?
            .next()
            .ok_or_else(|| anyhow!("no address for {addr}"))?;
        let mut c = Self {
            addr: sockaddr,
            timeout,
            reader: None,
            reused: 0,
            reconnects: 0,
            served_on_socket: 0,
        };
        c.dial()?;
        Ok(c)
    }

    fn dial(&mut self) -> Result<()> {
        let stream =
            TcpStream::connect_timeout(&self.addr, self.timeout).map_err(tag_io)?;
        let _ = stream.set_read_timeout(Some(self.timeout));
        let _ = stream.set_write_timeout(Some(self.timeout));
        let _ = stream.set_nodelay(true);
        self.reader = Some(BufReader::new(stream));
        self.served_on_socket = 0;
        Ok(())
    }

    /// True while the underlying socket is open.
    pub fn connected(&self) -> bool {
        self.reader.is_some()
    }

    /// GET `path`, reusing the open socket when possible (one reconnect
    /// attempt when it has gone away). Returns (status, body length) —
    /// the sweep only needs sizes, not bodies.
    pub fn get(&mut self, path: &str) -> Result<(u16, usize)> {
        if self.reader.is_none() {
            self.reconnects += 1;
            self.dial()?;
        }
        match self.request(path) {
            Ok(r) => Ok(r),
            Err(e) => {
                // socket died (stale keep-alive, peer restart): one retry
                // on a fresh connection, then give up
                self.reader = None;
                self.reconnects += 1;
                self.dial().map_err(|_| e)?;
                self.request(path)
            }
        }
    }

    fn request(&mut self, path: &str) -> Result<(u16, usize)> {
        let reader = self.reader.as_mut().ok_or_else(|| anyhow!("not connected"))?;
        let req = format!("GET {path} HTTP/1.1\r\nHost: sweep\r\nAccept: */*\r\n\r\n");
        reader.get_mut().write_all(req.as_bytes()).map_err(tag_io)?;
        reader.get_mut().flush().map_err(tag_io)?;

        let mut line = String::new();
        let n = reader.read_line(&mut line).map_err(tag_io)?;
        if n == 0 {
            bail!("connection closed before status line");
        }
        let mut parts = line.split_whitespace();
        if !parts.next().unwrap_or("").starts_with("HTTP/1.") {
            bail!("not an HTTP response: {line:?}");
        }
        let status: u16 = parts.next().unwrap_or("").parse().context("bad status")?;
        let mut content_length = 0usize;
        let mut server_closes = false;
        loop {
            let mut line = String::new();
            let n = reader.read_line(&mut line).map_err(tag_io)?;
            if n == 0 {
                bail!("connection closed in response head");
            }
            let line = line.trim_end();
            if line.is_empty() {
                break;
            }
            if let Some((k, v)) = line.split_once(':') {
                let (k, v) = (k.trim(), v.trim());
                if k.eq_ignore_ascii_case("content-length") {
                    content_length = v.parse().context("bad content-length")?;
                } else if k.eq_ignore_ascii_case("connection")
                    && v.eq_ignore_ascii_case("close")
                {
                    server_closes = true;
                }
            }
        }
        // drain the body so the socket is request-aligned for reuse
        let mut remaining = content_length;
        let mut chunk = [0u8; 16 * 1024];
        while remaining > 0 {
            let want = remaining.min(chunk.len());
            let n = reader.read(&mut chunk[..want]).map_err(tag_io)?;
            if n == 0 {
                bail!("connection closed {remaining} bytes early");
            }
            remaining -= n;
        }
        if self.served_on_socket > 0 {
            self.reused += 1;
        }
        self.served_on_socket += 1;
        if server_closes {
            self.reader = None;
        }
        Ok((status, content_length))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req_with_range(spec: Option<&str>) -> Request {
        let mut headers = vec![("Host".to_string(), "x".to_string())];
        if let Some(s) = spec {
            headers.push(("Range".to_string(), s.to_string()));
        }
        Request { method: "GET".into(), path: "/".into(), headers }
    }

    #[test]
    fn range_parsing() {
        use RangeOutcome::*;
        let r = |spec| req_with_range(spec).byte_range(100);
        assert_eq!(r(None), Ignored);
        assert_eq!(r(Some("bytes=0-9")), Satisfiable(0..10));
        assert_eq!(r(Some("bytes=90-")), Satisfiable(90..100));
        assert_eq!(r(Some("bytes=-10")), Satisfiable(90..100));
        // end clamps to len
        assert_eq!(r(Some("bytes=50-500")), Satisfiable(50..100));
        // syntactically valid but outside the body → 416
        assert_eq!(r(Some("bytes=100-")), Unsatisfiable);
        assert_eq!(r(Some("bytes=9-3")), Unsatisfiable);
        assert_eq!(r(Some("bytes=-0")), Unsatisfiable);
        // malformed / unknown unit / multipart → RFC 7233 says ignore
        assert_eq!(r(Some("bytes=")), Ignored);
        assert_eq!(r(Some("bytes=x-y")), Ignored);
        assert_eq!(r(Some("items=0-4")), Ignored);
        assert_eq!(r(Some("bytes=0-4,10-12")), Ignored);
    }

    #[test]
    fn range_integer_boundaries() {
        use RangeOutcome::*;
        let r = |spec, len| req_with_range(Some(spec)).byte_range(len);
        // suffix range asking for exactly the file length: whole body, 206
        assert_eq!(r("bytes=-100", 100), Satisfiable(0..100));
        // suffix larger than the body clamps to the whole body
        assert_eq!(r("bytes=-101", 100), Satisfiable(0..100));
        // bytes=N-M with M = u64::MAX: end saturates then clamps to len
        assert_eq!(r("bytes=0-18446744073709551615", 100), Satisfiable(0..100));
        assert_eq!(r("bytes=99-18446744073709551615", 100), Satisfiable(99..100));
        // start = u64::MAX is syntactically valid but outside any body
        assert_eq!(r("bytes=18446744073709551615-", 100), Unsatisfiable);
        // suffix of u64::MAX bytes clamps to the whole body
        assert_eq!(r("bytes=-18446744073709551615", 100), Satisfiable(0..100));
        // 2^64 and beyond no longer parse as u64 → ignored per RFC 7233
        assert_eq!(r("bytes=0-18446744073709551616", 100), Ignored);
        assert_eq!(r("bytes=99999999999999999999999999-", 100), Ignored);
        // zero-length body: every concrete range is unsatisfiable
        assert_eq!(r("bytes=0-0", 0), Unsatisfiable);
        assert_eq!(r("bytes=-1", 0), Unsatisfiable);
    }

    #[test]
    fn request_head_parser_handles_hostile_bytes() {
        // the extracted parser must accept/reject, never panic
        let ok = parse_request_head(b"GET /x HTTP/1.1\r\nHost: h\r\nRange: bytes=0-1\r\n").unwrap();
        assert_eq!(ok.method, "GET");
        assert_eq!(ok.path, "/x");
        assert_eq!(ok.header("range"), Some("bytes=0-1"));
        // bare LF line endings parse too (str::lines splits on \n)
        assert!(parse_request_head(b"GET / HTTP/1.1\nHost: h\n").is_ok());
        // missing path, empty head, non-utf8, oversized: structured errors
        assert!(parse_request_head(b"").is_err());
        assert!(parse_request_head(b"GET").is_err());
        assert!(parse_request_head(b"\xff\xfe\r\n").is_err());
        assert!(parse_request_head(&vec![b'a'; MAX_HEAD_BYTES + 1]).is_err());
        // header lines without a colon are skipped, not fatal
        let r = parse_request_head(b"GET / HTTP/1.1\r\ngarbage line\r\nHost: h\r\n").unwrap();
        assert_eq!(r.header("host"), Some("h"));
    }

    #[test]
    fn header_value_sanitization() {
        assert_eq!(sanitize_header_value("conv1"), "conv1");
        assert_eq!(
            sanitize_header_value("x\r\nSet-Cookie: evil=1"),
            "x__Set-Cookie: evil=1"
        );
        assert_eq!(sanitize_header_value("a\tb\u{7f}c"), "a_b_c");
    }

    #[test]
    fn header_lookup_is_case_insensitive() {
        let r = req_with_range(Some("bytes=0-0"));
        assert!(r.header("RANGE").is_some());
        assert!(r.header("host").is_some());
        assert!(r.header("cookie").is_none());
    }

    #[test]
    fn url_parsing() {
        assert_eq!(
            parse_url("http://127.0.0.1:8080/models/x").unwrap(),
            ("127.0.0.1:8080".to_string(), "/models/x".to_string())
        );
        assert_eq!(
            parse_url("http://example.com").unwrap(),
            ("example.com:80".to_string(), "/".to_string())
        );
        assert!(parse_url("https://x/y").is_err());
        assert!(parse_url("ftp://x").is_err());
        assert!(parse_url("http:///path").is_err());
    }
}
