//! The readiness-polling serve transport: one thread, an
//! epoll/kqueue [`crate::util::poll::Poller`], and a per-connection
//! state machine (reading-head → dispatching → writing-response) over
//! non-blocking sockets.
//!
//! What it adds over the threaded backend:
//!
//! * **Scale** — a connection costs a [`Conn`] struct, not a thread.
//!   Tens of thousands of mostly-idle keep-alive connections (the
//!   federated-fleet shape from arXiv:1907.11900) sit in the poller for
//!   free.
//! * **Keep-alive + pipelining** — HTTP/1.1 connections persist by
//!   default (`Connection: close` honored, and always answered after
//!   parse failures / 405 / 408 so framing can never desync). Up to
//!   [`MAX_PIPELINE`] pipelined requests are queued per connection and
//!   answered strictly in order; past the bound the loop simply stops
//!   reading that socket, which is TCP backpressure, not an error.
//! * **Poll-driven deadlines** — the threaded backend's per-socket
//!   read/write timeouts are re-expressed as a coarse timer wheel
//!   ([`WHEEL_SLOTS`] slots × 25 ms). A slowloris still gets its
//!   graceful 408, a stalled reader still gets dropped at the write
//!   deadline, and a dribbling-but-live client still completes, because
//!   read deadlines reset on every byte of progress — the same
//!   semantics as a per-`read(2)` socket timeout.
//! * **Zero-copy bodies** — responses carry
//!   [`super::server::Body::Slice`] ranges into the mmap'd container
//!   and are written straight from the page cache.
//!
//! Routing is the same pure [`super::server::respond`] the threaded
//! backend uses; only decoded-weights requests (CPU-bound CABAC
//! decodes) leave the loop, offloaded to the [`WorkerPool`] which posts
//! the finished response back through an `mpsc` channel plus a
//! [`crate::util::poll::Waker`] nudge. While a connection's decode is
//! in flight, its later pipelined requests stay buffered so responses
//! never reorder.

use super::http::MAX_HEAD_BYTES;

/// Maximum queued (accepted-but-unwritten) responses per connection;
/// beyond this the loop stops reading the socket until writes drain.
pub(crate) const MAX_PIPELINE: usize = 32;

/// Timer-wheel size; with 25 ms ticks this is a ~12.8 s horizon.
/// Deadlines beyond the horizon wrap and simply fire early — every
/// expiry re-checks the connection's true deadline before acting.
pub(crate) const WHEEL_SLOTS: usize = 512;

/// Outcome of scanning a receive buffer for one complete request head.
#[derive(Debug, PartialEq, Eq)]
enum HeadScan {
    /// No terminating blank line yet — keep reading.
    Partial,
    /// A complete head: `head_end` is the byte length of the head
    /// (request line + header lines, blank line excluded), `consumed`
    /// the total bytes to drain including the blank line.
    Complete { head_end: usize, consumed: usize },
    /// The head exceeded [`MAX_HEAD_BYTES`] — answer 400 and close,
    /// mirroring the threaded backend's capped reader.
    TooLarge,
}

/// Incremental equivalent of `http::read_request`'s line loop: walk
/// `\n`-terminated lines until the blank line (`\r\n` or bare `\n`),
/// enforcing the same head-size cap.
fn head_scan(buf: &[u8]) -> HeadScan {
    let mut i = 0usize;
    loop {
        match buf[i..].iter().position(|&b| b == b'\n') {
            None => {
                return if buf.len() >= MAX_HEAD_BYTES {
                    HeadScan::TooLarge
                } else {
                    HeadScan::Partial
                };
            }
            Some(j) => {
                let line_start = i;
                let nl = i + j;
                let line = &buf[line_start..=nl];
                if line == b"\r\n" || line == b"\n" {
                    return HeadScan::Complete { head_end: line_start, consumed: nl + 1 };
                }
                i = nl + 1;
                if i >= MAX_HEAD_BYTES {
                    return HeadScan::TooLarge;
                }
            }
        }
    }
}

#[cfg(unix)]
pub(crate) use imp::run;

/// Stub for platforms without a readiness backend (the CLI falls back
/// to the threaded transport there).
#[cfg(not(unix))]
pub(crate) fn run(
    _listener: std::net::TcpListener,
    _state: std::sync::Arc<super::server::ServerState>,
    _stop: std::sync::Arc<std::sync::atomic::AtomicBool>,
    _waker: std::sync::Arc<crate::util::poll::Waker>,
    _workers: usize,
) -> anyhow::Result<()> {
    anyhow::bail!("event backend is unix-only — use the threaded backend")
}

#[cfg(unix)]
mod imp {
    use super::super::http::parse_request_head;
    use super::super::server::{
        respond, shed_response, timeout_response, Body, Response, ServerState,
    };
    use super::{head_scan, HeadScan, MAX_PIPELINE, WHEEL_SLOTS};
    use crate::util::par::WorkerPool;
    use crate::util::poll::{Interest, Poller, Waker};
    use anyhow::{Context, Result};
    use std::collections::{HashMap, VecDeque};
    use std::io::{ErrorKind, Read, Write};
    use std::net::{TcpListener, TcpStream};
    use std::os::fd::AsRawFd;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::{mpsc, Arc};
    use std::time::{Duration, Instant};

    const TOKEN_LISTENER: u64 = 0;
    const TOKEN_WAKER: u64 = 1;
    const FIRST_CONN_TOKEN: u64 = 2;
    const TICK: Duration = Duration::from_millis(25);
    /// Per-event read budget: once this much unparsed data is buffered,
    /// parsing catches up before the socket is read again.
    const READ_BUDGET: usize = 64 * 1024;

    /// One response queued for writing: pre-rendered head + body, with
    /// a single write cursor across both.
    struct OutResp {
        head: Vec<u8>,
        body: Body,
        written: usize,
        close_after: bool,
    }

    impl OutResp {
        fn total(&self) -> usize {
            self.head.len() + self.body.len()
        }
    }

    /// Which deadline class a connection is currently governed by; used
    /// to avoid flooding the wheel with one entry per byte of progress
    /// (entries are only added on class transitions, and every expiry
    /// re-derives the true deadline before acting).
    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    enum DeadlineKind {
        /// Unwritten response bytes: client must keep reading.
        Write,
        /// Partial request head buffered: client must keep sending.
        Read,
        /// Nothing in flight: generous keep-alive idle window.
        Idle,
    }

    /// Per-connection state machine.
    struct Conn {
        stream: TcpStream,
        /// Received-but-unparsed bytes.
        buf: Vec<u8>,
        /// Responses awaiting (partial) write, strictly in request order.
        out: VecDeque<OutResp>,
        /// A weights decode is in flight on the pool; no further
        /// requests are parsed until it completes (ordering).
        pending_decode: bool,
        /// The offloaded request asked `Connection: close`.
        pending_close: bool,
        /// No further requests will be parsed; close once `out` drains.
        closing: bool,
        /// Peer sent EOF (half-close): drain what's buffered, then go.
        peer_closed: bool,
        /// Last read or write progress (deadline base).
        last_activity: Instant,
        interest: Interest,
        scheduled_kind: Option<DeadlineKind>,
    }

    impl Conn {
        fn wants_read(&self) -> bool {
            !self.closing
                && !self.peer_closed
                && !self.pending_decode
                && self.out.len() < MAX_PIPELINE
        }

        fn desired_interest(&self) -> Interest {
            Interest { readable: self.wants_read(), writable: !self.out.is_empty() }
        }
    }

    /// Coarse hashed timer wheel: `WHEEL_SLOTS` slots × `TICK`. Entries
    /// are lazy — an expiry is a hint to re-check the connection, not a
    /// verdict — so duplicates and early (wrapped) firings are harmless.
    struct TimerWheel {
        slots: Vec<Vec<u64>>,
        start: Instant,
        cursor: u64,
    }

    impl TimerWheel {
        fn new(start: Instant) -> Self {
            Self { slots: vec![Vec::new(); WHEEL_SLOTS], start, cursor: 0 }
        }

        fn tick_of(&self, t: Instant) -> u64 {
            (t.saturating_duration_since(self.start).as_millis() / TICK.as_millis()) as u64
        }

        fn schedule(&mut self, token: u64, deadline: Instant) {
            let tick = self.tick_of(deadline).max(self.cursor + 1);
            self.slots[(tick % WHEEL_SLOTS as u64) as usize].push(token);
        }

        /// Advance the cursor to `now`, draining every slot that came
        /// due. Returned tokens must be re-checked against real state.
        fn advance(&mut self, now: Instant) -> Vec<u64> {
            let mut due = Vec::new();
            let now_tick = self.tick_of(now);
            while self.cursor < now_tick {
                self.cursor += 1;
                let slot = (self.cursor % WHEEL_SLOTS as u64) as usize;
                due.append(&mut self.slots[slot]);
            }
            due
        }
    }

    fn deadline_of(
        conn: &Conn,
        read_t: Duration,
        write_t: Duration,
    ) -> Option<(Instant, DeadlineKind)> {
        if !conn.out.is_empty() {
            Some((conn.last_activity + write_t, DeadlineKind::Write))
        } else if conn.pending_decode {
            // the pool always completes; no wall-clock verdict here
            None
        } else if !conn.buf.is_empty() {
            Some((conn.last_activity + read_t, DeadlineKind::Read))
        } else {
            Some((conn.last_activity + read_t * 4, DeadlineKind::Idle))
        }
    }

    fn is_decode_heavy(req: &super::super::http::Request) -> bool {
        if req.method != "GET" {
            return false;
        }
        let path = req.path.split('?').next().unwrap_or("");
        let parts: Vec<&str> = path.split('/').filter(|p| !p.is_empty()).collect();
        matches!(parts.as_slice(), ["models", _, "layers", _, "weights"])
    }

    fn enqueue(conn: &mut Conn, resp: Response, close: bool) {
        let head = resp.render(if close { "close" } else { "keep-alive" }).into_bytes();
        conn.out.push_back(OutResp { head, body: resp.body, written: 0, close_after: close });
    }

    fn enqueue_error(conn: &mut Conn, status: u16, reason: &'static str, msg: String) {
        enqueue(conn, Response::error(status, reason, msg), true);
        conn.closing = true;
    }

    /// Drain the socket into `buf` (bounded per event). Returns `true`
    /// when the connection is unusable and must be dropped.
    fn read_into_buf(conn: &mut Conn, now: Instant) -> bool {
        let mut chunk = [0u8; 16 * 1024];
        loop {
            if !conn.wants_read() || conn.buf.len() >= READ_BUDGET {
                return false;
            }
            match conn.stream.read(&mut chunk) {
                Ok(0) => {
                    conn.peer_closed = true;
                    return false;
                }
                Ok(n) => {
                    conn.buf.extend_from_slice(&chunk[..n]);
                    // read deadline resets on any progress — a slow but
                    // live client (dribble) is not a slowloris
                    conn.last_activity = now;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return false,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => return true,
            }
        }
    }

    /// Parse every complete pipelined head the gates allow, dispatching
    /// each through [`respond`] (inline) or the decode pool (offload).
    fn process_conn(
        conn: &mut Conn,
        token: u64,
        state: &Arc<ServerState>,
        pool: &WorkerPool,
        tx: &mpsc::Sender<(u64, Result<Response>)>,
        waker: &Arc<Waker>,
    ) {
        while !conn.closing && !conn.pending_decode && conn.out.len() < MAX_PIPELINE {
            match head_scan(&conn.buf) {
                HeadScan::Partial => break,
                HeadScan::TooLarge => {
                    state.requests.fetch_add(1, Ordering::Relaxed);
                    state.errors.fetch_add(1, Ordering::Relaxed);
                    conn.buf.clear();
                    enqueue_error(conn, 400, "Bad Request", "request head too large".into());
                    break;
                }
                HeadScan::Complete { head_end, consumed } => {
                    let head: Vec<u8> = conn.buf[..head_end].to_vec();
                    conn.buf.drain(..consumed);
                    state.requests.fetch_add(1, Ordering::Relaxed);
                    let req = match parse_request_head(&head) {
                        Ok(r) => r,
                        Err(e) => {
                            // same body text as the threaded backend's
                            // 400 (Display prints the top message only)
                            state.errors.fetch_add(1, Ordering::Relaxed);
                            enqueue_error(conn, 400, "Bad Request", format!("{e}"));
                            break;
                        }
                    };
                    let wants_close = req
                        .header("connection")
                        .map_or(false, |v| v.eq_ignore_ascii_case("close"));
                    if is_decode_heavy(&req) {
                        conn.pending_decode = true;
                        conn.pending_close = wants_close;
                        let (state2, tx2, waker2) = (state.clone(), tx.clone(), waker.clone());
                        pool.execute(move || {
                            let res = respond(&req, &state2);
                            let _ = tx2.send((token, res));
                            waker2.wake();
                        });
                        break;
                    }
                    match respond(&req, state) {
                        Ok(resp) => {
                            // 405 closes: we never read request bodies,
                            // so an unframed non-GET would desync the
                            // next pipelined parse
                            let close = wants_close || resp.status == 405;
                            enqueue(conn, resp, close);
                            if close {
                                conn.closing = true;
                                break;
                            }
                        }
                        Err(e) => {
                            state.errors.fetch_add(1, Ordering::Relaxed);
                            enqueue_error(
                                conn,
                                500,
                                "Internal Server Error",
                                format!("{e:#}"),
                            );
                            break;
                        }
                    }
                }
            }
        }
        // EOF epilogue: the peer is done sending. Whatever complete
        // heads were buffered got parsed above; a leftover partial head
        // mirrors the threaded "connection closed mid-request" 400.
        if conn.peer_closed && !conn.pending_decode && !conn.closing {
            if conn.buf.is_empty() {
                conn.closing = true;
            } else if conn.out.len() < MAX_PIPELINE
                && matches!(head_scan(&conn.buf), HeadScan::Partial)
            {
                state.requests.fetch_add(1, Ordering::Relaxed);
                state.errors.fetch_add(1, Ordering::Relaxed);
                conn.buf.clear();
                enqueue_error(conn, 400, "Bad Request", "connection closed mid-request".into());
            }
        }
    }

    /// Write as much of the queued responses as the socket accepts.
    /// Returns `true` when the connection died mid-write.
    fn write_ready(conn: &mut Conn, now: Instant) -> bool {
        while let Some(front) = conn.out.front_mut() {
            while front.written < front.total() {
                let head_len = front.head.len();
                let res = if front.written < head_len {
                    conn.stream.write(&front.head[front.written..])
                } else {
                    let off = front.written - head_len;
                    conn.stream.write(&front.body.as_slice()[off..])
                };
                match res {
                    Ok(0) => return true,
                    Ok(n) => {
                        front.written += n;
                        conn.last_activity = now;
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => return false,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => return true,
                }
            }
            let close = front.close_after;
            conn.out.pop_front();
            if close {
                conn.closing = true;
            }
        }
        false
    }

    fn teardown(
        poller: &Poller,
        conns: &mut HashMap<u64, Conn>,
        state: &ServerState,
        token: u64,
    ) {
        if let Some(conn) = conns.remove(&token) {
            let _ = poller.deregister(conn.stream.as_raw_fd());
            state.open.fetch_sub(1, Ordering::Relaxed);
        }
    }

    /// Post-event reconciliation for one connection: close it if it is
    /// finished (or broke), otherwise refresh poller interest and make
    /// sure a timer-wheel entry covers its current deadline class.
    #[allow(clippy::too_many_arguments)]
    fn sync_conn(
        poller: &Poller,
        conns: &mut HashMap<u64, Conn>,
        wheel: &mut TimerWheel,
        state: &ServerState,
        token: u64,
        dead: bool,
        read_t: Duration,
        write_t: Duration,
    ) {
        let done = {
            let Some(conn) = conns.get_mut(&token) else { return };
            let done = conn.out.is_empty()
                && !conn.pending_decode
                && (conn.closing || (conn.peer_closed && conn.buf.is_empty()));
            if !dead && !done {
                let want = conn.desired_interest();
                if want != conn.interest {
                    let _ = poller.modify(conn.stream.as_raw_fd(), token, want);
                    conn.interest = want;
                }
                match deadline_of(conn, read_t, write_t) {
                    None => conn.scheduled_kind = None,
                    Some((deadline, kind)) => {
                        if conn.scheduled_kind != Some(kind) {
                            wheel.schedule(token, deadline);
                            conn.scheduled_kind = Some(kind);
                        }
                    }
                }
            }
            done
        };
        if dead || done {
            teardown(poller, conns, state, token);
        }
    }

    fn accept_ready(
        listener: &TcpListener,
        poller: &Poller,
        state: &Arc<ServerState>,
        conns: &mut HashMap<u64, Conn>,
        next_token: &mut u64,
        wheel: &mut TimerWheel,
        now: Instant,
        read_t: Duration,
    ) {
        loop {
            match listener.accept() {
                Ok((stream, _peer)) => {
                    if conns.len() >= state.max_connections {
                        // shed at the door: bounded best-effort 503 on
                        // the still-blocking socket, then drop
                        state.shed.fetch_add(1, Ordering::Relaxed);
                        let mut stream = stream;
                        let _ = stream.set_write_timeout(Some(Duration::from_millis(200)));
                        let _ = shed_response().write_close(&mut stream);
                        continue;
                    }
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    let token = *next_token;
                    *next_token += 1;
                    if poller.register(stream.as_raw_fd(), token, Interest::READ).is_err() {
                        continue;
                    }
                    state.open.fetch_add(1, Ordering::Relaxed);
                    wheel.schedule(token, now + read_t * 4);
                    conns.insert(
                        token,
                        Conn {
                            stream,
                            buf: Vec::new(),
                            out: VecDeque::new(),
                            pending_decode: false,
                            pending_close: false,
                            closing: false,
                            peer_closed: false,
                            last_activity: now,
                            interest: Interest::READ,
                            scheduled_kind: Some(DeadlineKind::Idle),
                        },
                    );
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => {
                    eprintln!("[serve] accept error: {e}");
                    break;
                }
            }
        }
    }

    /// The event loop proper. Runs until `stop` is set (the
    /// [`Waker`] interrupts a parked wait).
    pub(crate) fn run(
        listener: TcpListener,
        state: Arc<ServerState>,
        stop: Arc<AtomicBool>,
        waker: Arc<Waker>,
        workers: usize,
    ) -> Result<()> {
        let read_t = state.read_timeout;
        let write_t = state.write_timeout;
        let poller = Poller::new()?;
        poller
            .register(listener.as_raw_fd(), TOKEN_LISTENER, Interest::READ)
            .context("registering listener")?;
        poller
            .register(waker.fd(), TOKEN_WAKER, Interest::READ)
            .context("registering waker")?;
        let pool = WorkerPool::new(workers);
        let (tx, rx) = mpsc::channel::<(u64, Result<Response>)>();

        let mut conns: HashMap<u64, Conn> = HashMap::new();
        let mut next_token = FIRST_CONN_TOKEN;
        let mut wheel = TimerWheel::new(Instant::now());
        let mut events = Vec::with_capacity(256);

        while !stop.load(Ordering::SeqCst) {
            events.clear();
            let timeout = if conns.is_empty() { Duration::from_millis(500) } else { TICK };
            poller.wait(&mut events, Some(timeout))?;
            if stop.load(Ordering::SeqCst) {
                break;
            }
            let now = Instant::now();

            for ev in events.iter().copied().collect::<Vec<_>>() {
                match ev.token {
                    TOKEN_LISTENER => accept_ready(
                        &listener,
                        &poller,
                        &state,
                        &mut conns,
                        &mut next_token,
                        &mut wheel,
                        now,
                        read_t,
                    ),
                    TOKEN_WAKER => waker.drain(),
                    token => {
                        let dead = {
                            let Some(conn) = conns.get_mut(&token) else { continue };
                            let mut dead = false;
                            if ev.readable || ev.hangup {
                                dead = read_into_buf(conn, now);
                                if !dead {
                                    process_conn(conn, token, &state, &pool, &tx, &waker);
                                }
                            }
                            if !dead && (ev.writable || !conn.out.is_empty()) {
                                dead = write_ready(conn, now);
                                if !dead {
                                    // write progress frees pipeline slots
                                    process_conn(conn, token, &state, &pool, &tx, &waker);
                                }
                            }
                            if !dead && ev.hangup && conn.out.is_empty() && !conn.pending_decode
                            {
                                // peer gone and nothing left to flush
                                conn.peer_closed = true;
                            }
                            dead
                        };
                        sync_conn(
                            &poller, &mut conns, &mut wheel, &state, token, dead, read_t,
                            write_t,
                        );
                    }
                }
            }

            // decode completions posted by the pool
            while let Ok((token, res)) = rx.try_recv() {
                let dead = {
                    let Some(conn) = conns.get_mut(&token) else { continue };
                    conn.pending_decode = false;
                    match res {
                        Ok(resp) => {
                            let close = conn.pending_close;
                            enqueue(conn, resp, close);
                            if close {
                                conn.closing = true;
                            }
                        }
                        Err(e) => {
                            state.errors.fetch_add(1, Ordering::Relaxed);
                            enqueue_error(
                                conn,
                                500,
                                "Internal Server Error",
                                format!("{e:#}"),
                            );
                        }
                    }
                    let dead = write_ready(conn, now);
                    if !dead {
                        process_conn(conn, token, &state, &pool, &tx, &waker);
                    }
                    dead
                };
                sync_conn(&poller, &mut conns, &mut wheel, &state, token, dead, read_t, write_t);
            }

            // timer expiries (lazy: re-derive the true deadline first)
            for token in wheel.advance(now) {
                enum Act {
                    Keep,
                    Drop { count_error: bool },
                    Timeout,
                }
                let act = {
                    let Some(conn) = conns.get_mut(&token) else { continue };
                    match deadline_of(conn, read_t, write_t) {
                        None => {
                            conn.scheduled_kind = None;
                            Act::Keep
                        }
                        Some((deadline, kind)) => {
                            if now < deadline {
                                wheel.schedule(token, deadline);
                                conn.scheduled_kind = Some(kind);
                                Act::Keep
                            } else {
                                match kind {
                                    DeadlineKind::Write => Act::Drop { count_error: true },
                                    DeadlineKind::Idle => Act::Drop { count_error: false },
                                    DeadlineKind::Read => Act::Timeout,
                                }
                            }
                        }
                    }
                };
                match act {
                    Act::Keep => {}
                    Act::Drop { count_error } => {
                        if count_error {
                            // stalled reader blew the write deadline —
                            // the threaded backend counts this as an
                            // error too (its write_close fails)
                            state.errors.fetch_add(1, Ordering::Relaxed);
                        }
                        teardown(&poller, &mut conns, &state, token);
                    }
                    Act::Timeout => {
                        let dead = {
                            let conn = conns.get_mut(&token).expect("checked above");
                            state.timeouts.fetch_add(1, Ordering::Relaxed);
                            state.requests.fetch_add(1, Ordering::Relaxed);
                            conn.buf.clear();
                            enqueue(conn, timeout_response(), true);
                            conn.closing = true;
                            write_ready(conn, now)
                        };
                        sync_conn(
                            &poller, &mut conns, &mut wheel, &state, token, dead, read_t,
                            write_t,
                        );
                    }
                }
            }
        }
        // dropping the pool drains in-flight decodes; their completions
        // land in a closed channel and are discarded
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_scan_finds_crlf_terminated_head() {
        let buf = b"GET / HTTP/1.1\r\nHost: h\r\n\r\ntrailing";
        match head_scan(buf) {
            HeadScan::Complete { head_end, consumed } => {
                assert_eq!(&buf[..head_end], b"GET / HTTP/1.1\r\nHost: h\r\n");
                assert_eq!(&buf[consumed..], b"trailing");
            }
            other => panic!("expected Complete, got {other:?}"),
        }
    }

    #[test]
    fn head_scan_accepts_bare_lf() {
        let buf = b"GET / HTTP/1.1\nHost: h\n\nX";
        match head_scan(buf) {
            HeadScan::Complete { head_end, consumed } => {
                assert_eq!(&buf[..head_end], b"GET / HTTP/1.1\nHost: h\n");
                assert_eq!(consumed, buf.len() - 1);
            }
            other => panic!("expected Complete, got {other:?}"),
        }
    }

    #[test]
    fn head_scan_partial_until_blank_line() {
        assert_eq!(head_scan(b""), HeadScan::Partial);
        assert_eq!(head_scan(b"GET / HTTP/1.1\r\n"), HeadScan::Partial);
        assert_eq!(head_scan(b"GET / HTTP/1.1\r\nHost: h\r\n"), HeadScan::Partial);
    }

    #[test]
    fn head_scan_caps_hostile_heads() {
        // one endless header line
        let long = vec![b'a'; MAX_HEAD_BYTES + 1];
        assert_eq!(head_scan(&long), HeadScan::TooLarge);
        // many small lines adding up past the cap, no blank line
        let mut many = Vec::new();
        while many.len() <= MAX_HEAD_BYTES {
            many.extend_from_slice(b"X-Pad: yyyyyyyyyyyyyyyy\r\n");
        }
        assert_eq!(head_scan(&many), HeadScan::TooLarge);
        // a complete head just under the cap still parses
        let mut ok = b"GET / HTTP/1.1\r\n".to_vec();
        ok.extend_from_slice(b"\r\n");
        assert!(matches!(head_scan(&ok), HeadScan::Complete { .. }));
    }

    #[test]
    fn head_scan_pipelined_requests_split_cleanly() {
        let two = b"GET /a HTTP/1.1\r\n\r\nGET /b HTTP/1.1\r\n\r\n";
        let HeadScan::Complete { consumed, .. } = head_scan(two) else {
            panic!("first head");
        };
        let rest = &two[consumed..];
        let HeadScan::Complete { head_end, .. } = head_scan(rest) else {
            panic!("second head");
        };
        assert_eq!(&rest[..head_end], b"GET /b HTTP/1.1\r\n");
    }
}
