//! Closed- and open-loop load generator for the model-delivery server.
//!
//! Spawns `clients` threads, each issuing `requests` GETs against a mix
//! of the compressed-bytes and decoded-weights endpoints (layers picked
//! round-robin across every model the server lists), and reports
//! p50/p99/p999/mean latency + throughput, machine-readable to
//! `BENCH_serve.json`. Two arrival disciplines:
//!
//! * **closed loop** (default): each client fires its next request the
//!   moment the previous one completes — measures capacity.
//! * **open loop** (`rate` set): arrivals are a Poisson process at the
//!   target aggregate rate, split across clients, and latency is
//!   measured from the *scheduled* arrival time — so a server that
//!   falls behind accrues queueing delay in its percentiles instead of
//!   silently slowing the offered load (coordinated omission).
//!
//! Failures are classified into a [`FailureTaxonomy`] (connect-refused
//! / timeout / reset / malformed-response / http-error / shed) so a red
//! run says *what* broke, not just how much. `hostile > 0` adds that
//! many attacker threads running the fault-injection sessions from
//! [`crate::fuzz::fault`] alongside the healthy clients; their outcomes
//! are reported separately under `injected` and never count as load
//! failures.
//!
//! `sweep` turns on the connection-scaling harness: for each requested
//! connection count N it establishes N concurrent keep-alive sockets
//! ([`http::KeepAliveClient`]), drives a fixed number of requests per
//! connection, and reports per-point latency percentiles plus the
//! `reused` vs `reconnects` split — the direct evidence of which server
//! backend actually holds N connections open.

use super::http;
use crate::fuzz::fault;
use crate::util::json::{self, Json};
use crate::util::SplitMix64;
use anyhow::{bail, Context, Result};
use std::path::PathBuf;
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct LoadgenOptions {
    /// Server base URL, e.g. `http://127.0.0.1:8080`.
    pub url: String,
    /// Concurrent client threads.
    pub clients: usize,
    /// Requests per client.
    pub requests: usize,
    /// Hostile (fault-injecting) threads to run alongside the clients.
    pub hostile: usize,
    /// Open-loop mode: target aggregate arrival rate in requests/sec
    /// (Poisson arrivals split evenly across clients). `None` = closed
    /// loop.
    pub rate: Option<f64>,
    /// Connection-scaling sweep: counts of concurrent keep-alive
    /// connections to establish and drive (e.g. `[1, 64, 1000]`).
    /// Empty/None = no sweep.
    pub sweep: Option<Vec<usize>>,
    /// Requests per connection at each sweep point.
    pub sweep_requests: usize,
    /// Where to write the JSON report (None = don't write).
    pub out: Option<PathBuf>,
}

/// Healthy-client failures, split by root cause. Classification keys off
/// the `[kind=…]` tags [`http::tag_io`] attaches (the vendored anyhow
/// shim is string-backed, so `ErrorKind` can't travel any other way),
/// with message-keyword fallbacks for the client's own `bail!` errors.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FailureTaxonomy {
    /// TCP connect refused (server down / not listening).
    pub connect_refused: usize,
    /// Socket deadline expired (read or write).
    pub timeout: usize,
    /// Peer reset/aborted the connection mid-exchange.
    pub reset: usize,
    /// Bytes arrived but didn't parse as the expected HTTP response.
    pub malformed_response: usize,
    /// A well-formed response with a non-200 status.
    pub http_error: usize,
    /// HTTP 409 from the delta endpoint: the server recognises the
    /// client's base fingerprint but has no delta from it. A stale-base
    /// signal, not a server fault — bucketed apart from `http_error` so
    /// a run against a drifting model fleet reads as "clients need full
    /// fetches", not "server is erroring".
    pub delta_mismatch: usize,
    /// HTTP 503 from the `max_connections` accept guard: the server is
    /// load-shedding by design, not failing — its own bucket so
    /// saturation reads as "offered load exceeded the cap".
    pub shed: usize,
    /// Anything else.
    pub other: usize,
}

impl FailureTaxonomy {
    /// Classify one client-side error message.
    pub fn record_error(&mut self, msg: &str) {
        if msg.contains("[kind=ConnectionRefused]") {
            self.connect_refused += 1;
        } else if msg.contains("[kind=WouldBlock]") || msg.contains("[kind=TimedOut]") {
            self.timeout += 1;
        } else if msg.contains("[kind=ConnectionReset]")
            || msg.contains("[kind=BrokenPipe]")
            || msg.contains("[kind=ConnectionAborted]")
        {
            self.reset += 1;
        } else if msg.contains("not an HTTP response")
            || msg.contains("bad status")
            || msg.contains("connection closed")
        {
            self.malformed_response += 1;
        } else {
            self.other += 1;
        }
    }

    pub fn record_status(&mut self, status: u16) {
        if status == 409 {
            self.delta_mismatch += 1;
        } else if status == 503 {
            self.shed += 1;
        } else {
            self.http_error += 1;
        }
    }

    pub fn total(&self) -> usize {
        self.connect_refused
            + self.timeout
            + self.reset
            + self.malformed_response
            + self.http_error
            + self.delta_mismatch
            + self.shed
            + self.other
    }

    fn merge(&mut self, o: &FailureTaxonomy) {
        self.connect_refused += o.connect_refused;
        self.timeout += o.timeout;
        self.reset += o.reset;
        self.malformed_response += o.malformed_response;
        self.http_error += o.http_error;
        self.delta_mismatch += o.delta_mismatch;
        self.shed += o.shed;
        self.other += o.other;
    }
}

/// What the hostile threads did and how the server reacted. Sessions are
/// *supposed* to fail — only `unexpected` (a reaction outside the
/// session's contract, e.g. a dribbled-but-complete request not getting
/// its 200) indicates a server bug.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct InjectedReport {
    pub dribble: usize,
    pub slowloris: usize,
    pub disconnect: usize,
    pub stalled_reader: usize,
    pub unexpected: usize,
}

#[derive(Debug, Clone)]
pub struct LoadgenReport {
    pub total_requests: usize,
    pub failures: usize,
    pub failure_taxonomy: FailureTaxonomy,
    pub injected: InjectedReport,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub p999_ms: f64,
    pub mean_ms: f64,
    pub min_ms: f64,
    pub max_ms: f64,
    pub throughput_rps: f64,
    pub wall_s: f64,
    pub bytes_transferred: u64,
    pub bytes_requests: usize,
    pub weights_requests: usize,
    /// Time-to-first-usable-tier probes — `None` when the server hosts
    /// no progressive (v4) containers.
    pub progressive: Option<ProgressiveLatency>,
    /// One entry per requested sweep connection count; empty when the
    /// sweep was not requested.
    pub connection_scaling: Vec<SweepPoint>,
}

/// One point on the connection-scaling curve: N concurrent keep-alive
/// connections, a fixed number of requests each.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    /// Requested concurrent connections.
    pub connections: usize,
    /// Connections actually established within the dial timeout — the
    /// headline scaling number (a backend that cannot hold N
    /// connections shows `established < connections` here).
    pub established: usize,
    /// Requests attempted across all established connections.
    pub requests: usize,
    pub ok: usize,
    pub failures: usize,
    /// 503s from the accept guard.
    pub shed: usize,
    /// Re-dials forced by the server closing (threaded backend: every
    /// request; event backend: ~0).
    pub reconnects: u64,
    /// Responses served on an already-used socket (keep-alive working).
    pub reused: u64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub p999_ms: f64,
    pub throughput_rps: f64,
    /// Time-to-first-usable-tier (`GET /models/{m}?tier=0`, best of 3)
    /// probed right after the point — `None` without progressive models.
    pub ttfut_ms: Option<f64>,
}

/// The progressive-delivery headline numbers: how fast a client gets a
/// *usable* model (the base-tier prefix, `GET /models/{m}?tier=0`)
/// versus the full container. Measured sequentially after the
/// concurrent load phase so the two distributions see the same idle
/// server.
#[derive(Debug, Clone, Default)]
pub struct ProgressiveLatency {
    /// Progressive models probed.
    pub models: usize,
    /// Probes per endpoint (base and full each).
    pub probes: usize,
    pub base_p50_ms: f64,
    pub base_p99_ms: f64,
    pub full_p50_ms: f64,
    pub full_p99_ms: f64,
    /// Summed across the probed models (one count per model).
    pub base_bytes: u64,
    pub full_bytes: u64,
}

/// One target the mix rotates over.
#[derive(Debug, Clone)]
struct Target {
    model: String,
    layer: usize,
}

/// Discover every (model, layer) pair the server offers, plus the
/// models served as progressive (v4) containers (the listing carries a
/// `tiers` count for those).
fn discover(addr: &str, base_path: &str) -> Result<(Vec<Target>, Vec<String>)> {
    let resp = http::get(addr, &format!("{base_path}/models"), None)?;
    if resp.status != 200 {
        bail!("GET {base_path}/models returned {}", resp.status);
    }
    let listing = Json::parse(std::str::from_utf8(&resp.body)?)
        .map_err(|e| anyhow::anyhow!("bad /models JSON: {e}"))?;
    let mut targets = Vec::new();
    let mut progressives = Vec::new();
    for m in listing.get("models").and_then(|m| m.as_arr()).unwrap_or(&[]) {
        let Some(name) = m.get("name").and_then(|n| n.as_str()) else { continue };
        let layers = m.get("layers").and_then(|l| l.as_usize()).unwrap_or(0);
        for layer in 0..layers {
            targets.push(Target { model: name.to_string(), layer });
        }
        if m.get("tiers").and_then(|t| t.as_usize()).unwrap_or(0) > 0 {
            progressives.push(name.to_string());
        }
    }
    if targets.is_empty() {
        bail!("server lists no layers to fetch");
    }
    Ok((targets, progressives))
}

/// The time-to-first-usable-tier measurement: sequential GETs of the
/// base-tier prefix (`?tier=0`) and the full container for every
/// progressive model, `probes` rounds each.
fn probe_progressive(
    addr: &str,
    base_path: &str,
    progressives: &[String],
    probes: usize,
) -> Result<Option<ProgressiveLatency>> {
    if progressives.is_empty() {
        return Ok(None);
    }
    let mut base_lat: Vec<f64> = Vec::new();
    let mut full_lat: Vec<f64> = Vec::new();
    let (mut base_bytes, mut full_bytes) = (0u64, 0u64);
    for m in progressives {
        for i in 0..probes.max(1) {
            let t = Instant::now();
            let r = http::get(addr, &format!("{base_path}/models/{m}?tier=0"), None)?;
            if r.status != 200 {
                bail!("GET /models/{m}?tier=0 returned {}", r.status);
            }
            base_lat.push(t.elapsed().as_secs_f64() * 1e3);
            let t = Instant::now();
            let full = http::get(addr, &format!("{base_path}/models/{m}"), None)?;
            if full.status != 200 {
                bail!("GET /models/{m} returned {}", full.status);
            }
            full_lat.push(t.elapsed().as_secs_f64() * 1e3);
            if i == 0 {
                base_bytes += r.body.len() as u64;
                full_bytes += full.body.len() as u64;
            }
        }
    }
    base_lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    full_lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Ok(Some(ProgressiveLatency {
        models: progressives.len(),
        probes: probes.max(1),
        base_p50_ms: percentile(&base_lat, 50.0),
        base_p99_ms: percentile(&base_lat, 99.0),
        full_p50_ms: percentile(&full_lat, 50.0),
        full_p99_ms: percentile(&full_lat, 99.0),
        base_bytes,
        full_bytes,
    }))
}

/// Run the load; returns the aggregate report (and writes `out` if set).
pub fn run(opts: &LoadgenOptions) -> Result<LoadgenReport> {
    let (addr, base_path) = http::parse_url(&opts.url)?;
    let base_path = base_path.trim_end_matches('/').to_string();
    let (targets, progressives) = discover(&addr, &base_path)?;

    struct ClientResult {
        latencies_ms: Vec<f64>,
        failures: usize,
        taxonomy: FailureTaxonomy,
        bytes: u64,
        bytes_requests: usize,
        weights_requests: usize,
    }

    let t0 = Instant::now();
    let (results, injected): (Vec<ClientResult>, InjectedReport) = std::thread::scope(|scope| {
        let hostile_handles: Vec<_> = (0..opts.hostile)
            .map(|h| {
                let addr = &addr;
                let base_path = &base_path;
                let targets = &targets;
                scope.spawn(move || {
                    hostile_session_loop(addr, base_path, targets, h, opts.requests)
                })
            })
            .collect();
        let handles: Vec<_> = (0..opts.clients)
            .map(|c| {
                let addr = &addr;
                let base_path = &base_path;
                let targets = &targets;
                scope.spawn(move || {
                    let mut r = ClientResult {
                        latencies_ms: Vec::with_capacity(opts.requests),
                        failures: 0,
                        taxonomy: FailureTaxonomy::default(),
                        bytes: 0,
                        bytes_requests: 0,
                        weights_requests: 0,
                    };
                    // Open loop: this client's share of the aggregate
                    // Poisson rate. Deterministic per-client RNG so two
                    // runs offer the same arrival sequence.
                    let lambda =
                        opts.rate.map(|rt| (rt / opts.clients.max(1) as f64).max(1e-6));
                    let mut rng = SplitMix64::new(
                        0x9e37_79b9_7f4a_7c15 ^ (c as u64).wrapping_mul(0x100_0000_01b3),
                    );
                    let mut next_at = Instant::now();
                    for i in 0..opts.requests {
                        let t = &targets[(c + i * 7) % targets.len()];
                        // alternate compressed-bytes and decoded-weights
                        let weights = (c + i) % 2 == 1;
                        let path = if weights {
                            r.weights_requests += 1;
                            format!(
                                "{base_path}/models/{}/layers/{}/weights",
                                t.model, t.layer
                            )
                        } else {
                            r.bytes_requests += 1;
                            format!("{base_path}/models/{}/layers/{}", t.model, t.layer)
                        };
                        // In open-loop mode latency is measured from
                        // the *scheduled* arrival, so server slowdowns
                        // show up as queueing delay instead of being
                        // absorbed by the client (coordinated omission).
                        let rt0 = match lambda {
                            Some(l) => {
                                let dt = -(1.0 - rng.next_f64()).ln() / l;
                                next_at += Duration::from_secs_f64(dt);
                                if let Some(wait) =
                                    next_at.checked_duration_since(Instant::now())
                                {
                                    std::thread::sleep(wait);
                                }
                                next_at
                            }
                            None => Instant::now(),
                        };
                        match http::get(addr, &path, None) {
                            Ok(resp) if resp.status == 200 => {
                                r.latencies_ms
                                    .push(rt0.elapsed().as_secs_f64() * 1e3);
                                r.bytes += resp.body.len() as u64;
                            }
                            Ok(resp) => {
                                eprintln!(
                                    "[loadgen] {} -> HTTP {}",
                                    path, resp.status
                                );
                                r.failures += 1;
                                r.taxonomy.record_status(resp.status);
                            }
                            Err(e) => {
                                eprintln!("[loadgen] {path} -> {e}");
                                r.failures += 1;
                                r.taxonomy.record_error(&format!("{e:#}"));
                            }
                        }
                    }
                    r
                })
            })
            .collect();
        let results =
            handles.into_iter().map(|h| h.join().expect("client thread")).collect();
        let mut injected = InjectedReport::default();
        for h in hostile_handles {
            let part = h.join().expect("hostile thread");
            injected.dribble += part.dribble;
            injected.slowloris += part.slowloris;
            injected.disconnect += part.disconnect;
            injected.stalled_reader += part.stalled_reader;
            injected.unexpected += part.unexpected;
        }
        (results, injected)
    });
    let wall_s = t0.elapsed().as_secs_f64();

    let mut latencies: Vec<f64> = Vec::new();
    let mut failures = 0usize;
    let mut taxonomy = FailureTaxonomy::default();
    let mut bytes = 0u64;
    let (mut breq, mut wreq) = (0usize, 0usize);
    for r in results {
        latencies.extend_from_slice(&r.latencies_ms);
        failures += r.failures;
        taxonomy.merge(&r.taxonomy);
        bytes += r.bytes;
        breq += r.bytes_requests;
        wreq += r.weights_requests;
    }
    if latencies.is_empty() {
        bail!("all {} requests failed", opts.clients * opts.requests);
    }
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    // sequential and outside wall_s on purpose: time-to-first-usable-tier
    // compares base-prefix vs full-container latency on an idle server,
    // not under the concurrent mix above
    let progressive = probe_progressive(&addr, &base_path, &progressives, opts.requests)?;
    let connection_scaling = match &opts.sweep {
        Some(counts) if !counts.is_empty() => connection_sweep(
            &addr,
            &base_path,
            &targets,
            &progressives,
            counts,
            opts.sweep_requests.max(1),
        ),
        _ => Vec::new(),
    };
    let report = LoadgenReport {
        total_requests: opts.clients * opts.requests,
        failures,
        failure_taxonomy: taxonomy,
        injected,
        p50_ms: percentile(&latencies, 50.0),
        p99_ms: percentile(&latencies, 99.0),
        p999_ms: percentile(&latencies, 99.9),
        mean_ms: latencies.iter().sum::<f64>() / latencies.len() as f64,
        min_ms: latencies[0],
        max_ms: latencies[latencies.len() - 1],
        throughput_rps: latencies.len() as f64 / wall_s.max(1e-9),
        wall_s,
        bytes_transferred: bytes,
        bytes_requests: breq,
        weights_requests: wreq,
        progressive,
        connection_scaling,
    };
    if let Some(path) = &opts.out {
        std::fs::write(path, to_json(opts, &report).to_string_pretty())
            .with_context(|| format!("writing {path:?}"))?;
    }
    Ok(report)
}

/// Connection-scaling sweep: for each count N, establish N concurrent
/// keep-alive sockets (spread over at most 64 threads), then drive
/// `rounds` cheap zero-copy requests per connection and report latency
/// percentiles, throughput, and the keep-alive reuse split. Failures
/// here are recorded per point, never folded into the main run's
/// ensure-zero failure count — a backend that cannot hold N connections
/// is exactly what this sweep exists to show, not an error.
fn connection_sweep(
    addr: &str,
    base_path: &str,
    targets: &[Target],
    progressives: &[String],
    counts: &[usize],
    rounds: usize,
) -> Vec<SweepPoint> {
    struct ThreadResult {
        established: usize,
        latencies: Vec<f64>,
        ok: usize,
        failures: usize,
        shed: usize,
        reconnects: u64,
        reused: u64,
        wall_s: f64,
    }

    let mut points = Vec::with_capacity(counts.len());
    for &requested in counts {
        let n = requested.max(1);
        let threads = n.min(64);
        // Short dial timeout on purpose: a backend whose backlog is full
        // should show up as `established < connections` within seconds,
        // not stall the sweep.
        let dial_timeout = Duration::from_millis(1000);
        let barrier = std::sync::Barrier::new(threads);
        let results: Vec<ThreadResult> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..threads)
                .map(|ti| {
                    let barrier = &barrier;
                    scope.spawn(move || {
                        // split N across threads; the first (N % threads)
                        // threads own one extra connection
                        let owned = n / threads + usize::from(ti < n % threads);
                        let mut clients = Vec::with_capacity(owned);
                        for _ in 0..owned {
                            if let Ok(c) = http::KeepAliveClient::connect(addr, dial_timeout)
                            {
                                clients.push(c);
                            }
                        }
                        let established = clients.len();
                        // all threads finish dialing before anyone sends,
                        // so the point measures N *concurrent* sockets
                        barrier.wait();
                        let mut r = ThreadResult {
                            established,
                            latencies: Vec::with_capacity(established * rounds),
                            ok: 0,
                            failures: 0,
                            shed: 0,
                            reconnects: 0,
                            reused: 0,
                            wall_s: 0.0,
                        };
                        let start = Instant::now();
                        for round in 0..rounds {
                            for ci in 0..clients.len() {
                                let t = &targets
                                    [(ti * 31 + ci * 7 + round) % targets.len()];
                                let path = format!(
                                    "{base_path}/models/{}/layers/{}",
                                    t.model, t.layer
                                );
                                let q0 = Instant::now();
                                match clients[ci].get(&path) {
                                    Ok((200, _)) => {
                                        r.ok += 1;
                                        r.latencies
                                            .push(q0.elapsed().as_secs_f64() * 1e3);
                                    }
                                    Ok((503, _)) => r.shed += 1,
                                    Ok(_) | Err(_) => r.failures += 1,
                                }
                            }
                        }
                        r.wall_s = start.elapsed().as_secs_f64();
                        for c in &clients {
                            r.reconnects += c.reconnects;
                            r.reused += c.reused;
                        }
                        r
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("sweep thread"))
                .collect()
        });

        let mut latencies: Vec<f64> = Vec::new();
        let (mut established, mut ok, mut failures, mut shed) = (0, 0, 0, 0);
        let (mut reconnects, mut reused) = (0u64, 0u64);
        let mut wall_s = 0.0f64;
        for r in results {
            established += r.established;
            ok += r.ok;
            failures += r.failures;
            shed += r.shed;
            reconnects += r.reconnects;
            reused += r.reused;
            wall_s = wall_s.max(r.wall_s);
            latencies.extend_from_slice(&r.latencies);
        }
        latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // time-to-first-usable-tier right after the point, while the
        // server has just carried N connections
        let ttfut_ms = progressives.first().and_then(|m| {
            let mut best = f64::INFINITY;
            for _ in 0..3 {
                let q0 = Instant::now();
                if let Ok(resp) =
                    http::get(addr, &format!("{base_path}/models/{m}?tier=0"), None)
                {
                    if resp.status == 200 {
                        best = best.min(q0.elapsed().as_secs_f64() * 1e3);
                    }
                }
            }
            best.is_finite().then_some(best)
        });
        let (p50, p99, p999) = if latencies.is_empty() {
            (0.0, 0.0, 0.0)
        } else {
            (
                percentile(&latencies, 50.0),
                percentile(&latencies, 99.0),
                percentile(&latencies, 99.9),
            )
        };
        eprintln!(
            "[loadgen] sweep {requested}: established {established}, ok {ok}, \
reused {reused}, reconnects {reconnects}, shed {shed}, p99 {p99:.2}ms"
        );
        points.push(SweepPoint {
            connections: requested,
            established,
            requests: ok + failures + shed,
            ok,
            failures,
            shed,
            reconnects,
            reused,
            p50_ms: p50,
            p99_ms: p99,
            p999_ms: p999,
            throughput_rps: ok as f64 / wall_s.max(1e-9),
            ttfut_ms,
        });
    }
    points
}

/// One hostile thread: `rounds` fault-injection sessions cycling over
/// the four pathologies. `unexpected` counts server reactions outside
/// each session's contract — a dribbled-but-complete request must get
/// its 200, and a slowloris must get 408 or a close (any socket error
/// also means the server shed the connection, which is fine).
fn hostile_session_loop(
    addr: &str,
    base_path: &str,
    targets: &[Target],
    thread_idx: usize,
    rounds: usize,
) -> InjectedReport {
    let mut r = InjectedReport::default();
    let deadline = Duration::from_secs(30);
    for i in 0..rounds {
        let t = &targets[(thread_idx + i) % targets.len()];
        let path = format!("{base_path}/models/{}/layers/{}", t.model, t.layer);
        match (thread_idx + i) % 4 {
            0 => {
                r.dribble += 1;
                match fault::dribble_request(addr, &path, Duration::from_millis(1), deadline) {
                    Ok(fault::FaultOutcome::Status(200)) => {}
                    other => {
                        eprintln!("[loadgen] hostile dribble -> {other:?}");
                        r.unexpected += 1;
                    }
                }
            }
            1 => {
                r.slowloris += 1;
                match fault::slowloris(addr, deadline) {
                    Ok(fault::FaultOutcome::Status(408))
                    | Ok(fault::FaultOutcome::Closed)
                    | Ok(fault::FaultOutcome::IoError(_)) => {}
                    other => {
                        eprintln!("[loadgen] hostile slowloris -> {other:?}");
                        r.unexpected += 1;
                    }
                }
            }
            2 => {
                r.disconnect += 1;
                if let Err(e) = fault::disconnect_mid_request(addr, deadline) {
                    eprintln!("[loadgen] hostile disconnect -> {e:#}");
                    r.unexpected += 1;
                }
            }
            _ => {
                r.stalled_reader += 1;
                if let Err(e) =
                    fault::stalled_reader(addr, &path, Duration::from_millis(100), deadline)
                {
                    eprintln!("[loadgen] hostile stalled-reader -> {e:#}");
                    r.unexpected += 1;
                }
            }
        }
    }
    r
}

/// Nearest-rank percentile over an ascending-sorted slice.
fn percentile(sorted: &[f64], p: f64) -> f64 {
    debug_assert!(!sorted.is_empty());
    let rank = (p / 100.0 * (sorted.len() - 1) as f64).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

fn to_json(opts: &LoadgenOptions, r: &LoadgenReport) -> Json {
    let mut fields = vec![
        ("bench", json::s("serve")),
        ("url", json::s(&opts.url)),
        ("clients", json::num(opts.clients as f64)),
        ("requests_per_client", json::num(opts.requests as f64)),
        ("total_requests", json::num(r.total_requests as f64)),
        (
            "mode",
            json::s(if opts.rate.is_some() { "open" } else { "closed" }),
        ),
        ("failures", json::num(r.failures as f64)),
        (
            "failure_taxonomy",
            json::obj(vec![
                ("connect_refused", json::num(r.failure_taxonomy.connect_refused as f64)),
                ("timeout", json::num(r.failure_taxonomy.timeout as f64)),
                ("reset", json::num(r.failure_taxonomy.reset as f64)),
                (
                    "malformed_response",
                    json::num(r.failure_taxonomy.malformed_response as f64),
                ),
                ("http_error", json::num(r.failure_taxonomy.http_error as f64)),
                ("delta_mismatch", json::num(r.failure_taxonomy.delta_mismatch as f64)),
                ("shed", json::num(r.failure_taxonomy.shed as f64)),
                ("other", json::num(r.failure_taxonomy.other as f64)),
            ]),
        ),
        (
            "injected",
            json::obj(vec![
                ("hostile_threads", json::num(opts.hostile as f64)),
                ("dribble", json::num(r.injected.dribble as f64)),
                ("slowloris", json::num(r.injected.slowloris as f64)),
                ("disconnect", json::num(r.injected.disconnect as f64)),
                ("stalled_reader", json::num(r.injected.stalled_reader as f64)),
                ("unexpected", json::num(r.injected.unexpected as f64)),
            ]),
        ),
        ("p50_ms", json::num(r.p50_ms)),
        ("p99_ms", json::num(r.p99_ms)),
        ("p999_ms", json::num(r.p999_ms)),
        ("mean_ms", json::num(r.mean_ms)),
        ("min_ms", json::num(r.min_ms)),
        ("max_ms", json::num(r.max_ms)),
        ("throughput_rps", json::num(r.throughput_rps)),
        ("wall_s", json::num(r.wall_s)),
        ("bytes_transferred", json::num(r.bytes_transferred as f64)),
        (
            "mix",
            json::obj(vec![
                ("layer_bytes", json::num(r.bytes_requests as f64)),
                ("layer_weights", json::num(r.weights_requests as f64)),
            ]),
        ),
    ];
    if let Some(rate) = opts.rate {
        fields.push(("rate_rps", json::num(rate)));
    }
    if let Some(p) = &r.progressive {
        fields.push((
            "progressive",
            json::obj(vec![
                ("models", json::num(p.models as f64)),
                ("probes", json::num(p.probes as f64)),
                ("base_tier_p50_ms", json::num(p.base_p50_ms)),
                ("base_tier_p99_ms", json::num(p.base_p99_ms)),
                ("full_p50_ms", json::num(p.full_p50_ms)),
                ("full_p99_ms", json::num(p.full_p99_ms)),
                ("base_tier_bytes", json::num(p.base_bytes as f64)),
                ("full_bytes", json::num(p.full_bytes as f64)),
            ]),
        ));
    }
    if !r.connection_scaling.is_empty() {
        fields.push((
            "connection_scaling",
            json::arr(
                r.connection_scaling
                    .iter()
                    .map(|p| {
                        let mut f = vec![
                            ("connections", json::num(p.connections as f64)),
                            ("established", json::num(p.established as f64)),
                            ("requests", json::num(p.requests as f64)),
                            ("ok", json::num(p.ok as f64)),
                            ("failures", json::num(p.failures as f64)),
                            ("shed", json::num(p.shed as f64)),
                            ("reconnects", json::num(p.reconnects as f64)),
                            ("reused", json::num(p.reused as f64)),
                            (
                                "reuse_ratio",
                                json::num(if p.ok > 0 {
                                    p.reused as f64 / p.ok as f64
                                } else {
                                    0.0
                                }),
                            ),
                            ("p50_ms", json::num(p.p50_ms)),
                            ("p99_ms", json::num(p.p99_ms)),
                            ("p999_ms", json::num(p.p999_ms)),
                            ("throughput_rps", json::num(p.throughput_rps)),
                        ];
                        if let Some(t) = p.ttfut_ms {
                            f.push(("ttfut_ms", json::num(t)));
                        }
                        json::obj(f)
                    })
                    .collect(),
            ),
        ));
    }
    json::obj(fields)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 50.0), 51.0);
        assert_eq!(percentile(&v, 99.0), 99.0);
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 100.0);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn failure_classifier_buckets() {
        let mut t = FailureTaxonomy::default();
        t.record_error("connecting to 127.0.0.1:1: refused [kind=ConnectionRefused]");
        t.record_error("read head: timed out [kind=TimedOut]");
        t.record_error("read head: would block [kind=WouldBlock]");
        t.record_error("peer went away [kind=ConnectionReset]");
        t.record_error("write body: pipe [kind=BrokenPipe]");
        t.record_error("not an HTTP response");
        t.record_error("bad status line");
        t.record_error("connection closed before full body");
        t.record_status(500);
        // 409 is the delta endpoint's stale-base signal, its own bucket
        t.record_status(409);
        // 503 is the accept guard shedding by design, its own bucket
        t.record_status(503);
        t.record_error("some novel explosion");
        assert_eq!(
            t,
            FailureTaxonomy {
                connect_refused: 1,
                timeout: 2,
                reset: 2,
                malformed_response: 3,
                http_error: 1,
                delta_mismatch: 1,
                shed: 1,
                other: 1,
            }
        );
        assert_eq!(t.total(), 12);
        let mut sum = FailureTaxonomy::default();
        sum.merge(&t);
        sum.merge(&t);
        assert_eq!(sum.total(), 24);
    }

    #[test]
    fn report_json_shape() {
        let opts = LoadgenOptions {
            url: "http://x:1".into(),
            clients: 2,
            requests: 3,
            hostile: 1,
            rate: None,
            sweep: None,
            sweep_requests: 3,
            out: None,
        };
        let r = LoadgenReport {
            total_requests: 6,
            failures: 0,
            failure_taxonomy: FailureTaxonomy {
                timeout: 2,
                delta_mismatch: 1,
                ..Default::default()
            },
            injected: InjectedReport { slowloris: 3, unexpected: 0, ..Default::default() },
            p50_ms: 1.0,
            p99_ms: 2.0,
            p999_ms: 2.5,
            mean_ms: 1.2,
            min_ms: 0.8,
            max_ms: 2.0,
            throughput_rps: 100.0,
            wall_s: 0.06,
            bytes_transferred: 1234,
            bytes_requests: 3,
            weights_requests: 3,
            progressive: Some(ProgressiveLatency {
                models: 1,
                probes: 3,
                base_p50_ms: 0.4,
                base_p99_ms: 0.9,
                full_p50_ms: 1.1,
                full_p99_ms: 2.2,
                base_bytes: 100,
                full_bytes: 300,
            }),
            connection_scaling: vec![SweepPoint {
                connections: 64,
                established: 64,
                requests: 192,
                ok: 192,
                failures: 0,
                shed: 0,
                reconnects: 0,
                reused: 128,
                p50_ms: 0.5,
                p99_ms: 1.5,
                p999_ms: 1.9,
                throughput_rps: 5000.0,
                ttfut_ms: Some(0.7),
            }],
        };
        let j = to_json(&opts, &r);
        let parsed = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_str().unwrap(), "serve");
        assert_eq!(parsed.get("failures").unwrap().as_usize().unwrap(), 0);
        assert!(parsed.get("p50_ms").is_some());
        assert!(parsed.get("p99_ms").is_some());
        assert_eq!(parsed.path("mix.layer_bytes").unwrap().as_usize().unwrap(), 3);
        assert_eq!(
            parsed.path("failure_taxonomy.timeout").unwrap().as_usize().unwrap(),
            2
        );
        assert_eq!(
            parsed.path("failure_taxonomy.delta_mismatch").unwrap().as_usize().unwrap(),
            1
        );
        assert_eq!(parsed.path("injected.slowloris").unwrap().as_usize().unwrap(), 3);
        assert_eq!(parsed.path("injected.hostile_threads").unwrap().as_usize().unwrap(), 1);
        assert_eq!(parsed.path("injected.unexpected").unwrap().as_usize().unwrap(), 0);
        // time-to-first-usable-tier section, present only when the
        // server hosts progressive containers
        assert_eq!(parsed.path("progressive.models").unwrap().as_usize().unwrap(), 1);
        assert!(parsed.path("progressive.base_tier_p50_ms").is_some());
        assert!(parsed.path("progressive.full_p99_ms").is_some());
        assert_eq!(
            parsed.path("progressive.base_tier_bytes").unwrap().as_usize().unwrap(),
            100
        );
        assert_eq!(parsed.get("mode").unwrap().as_str().unwrap(), "closed");
        assert!(parsed.get("rate_rps").is_none());
        assert!(parsed.get("p999_ms").is_some());
        assert!(parsed.path("failure_taxonomy.shed").is_some());
        // connection-scaling block: one object per sweep point
        let scaling = parsed.get("connection_scaling").unwrap();
        let point = match scaling {
            Json::Arr(a) => &a[0],
            _ => panic!("connection_scaling must be an array"),
        };
        assert_eq!(point.get("connections").unwrap().as_usize().unwrap(), 64);
        assert_eq!(point.get("established").unwrap().as_usize().unwrap(), 64);
        assert_eq!(point.get("reused").unwrap().as_usize().unwrap(), 128);
        assert!(point.get("reuse_ratio").is_some());
        assert!(point.get("p999_ms").is_some());
        assert!(point.get("ttfut_ms").is_some());

        let open_opts = LoadgenOptions { rate: Some(250.0), ..opts.clone() };
        let parsed_open =
            Json::parse(&to_json(&open_opts, &r).to_string_pretty()).unwrap();
        assert_eq!(parsed_open.get("mode").unwrap().as_str().unwrap(), "open");
        assert_eq!(parsed_open.get("rate_rps").unwrap().as_usize().unwrap(), 250);

        let r2 = LoadgenReport {
            progressive: None,
            connection_scaling: Vec::new(),
            ..r
        };
        let parsed2 = Json::parse(&to_json(&opts, &r2).to_string_pretty()).unwrap();
        assert!(parsed2.get("progressive").is_none());
        assert!(parsed2.get("connection_scaling").is_none());
    }
}
