//! Concurrent-client load generator for the model-delivery server.
//!
//! Spawns `clients` threads, each issuing `requests` GETs against a mix
//! of the compressed-bytes and decoded-weights endpoints (layers picked
//! round-robin across every model the server lists), and reports
//! p50/p99/mean latency + throughput, machine-readable to
//! `BENCH_serve.json`.

use super::http;
use crate::util::json::{self, Json};
use anyhow::{bail, Context, Result};
use std::path::PathBuf;
use std::time::Instant;

#[derive(Debug, Clone)]
pub struct LoadgenOptions {
    /// Server base URL, e.g. `http://127.0.0.1:8080`.
    pub url: String,
    /// Concurrent client threads.
    pub clients: usize,
    /// Requests per client.
    pub requests: usize,
    /// Where to write the JSON report (None = don't write).
    pub out: Option<PathBuf>,
}

#[derive(Debug, Clone)]
pub struct LoadgenReport {
    pub total_requests: usize,
    pub failures: usize,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    pub min_ms: f64,
    pub max_ms: f64,
    pub throughput_rps: f64,
    pub wall_s: f64,
    pub bytes_transferred: u64,
    pub bytes_requests: usize,
    pub weights_requests: usize,
}

/// One target the mix rotates over.
#[derive(Debug, Clone)]
struct Target {
    model: String,
    layer: usize,
}

/// Discover every (model, layer) pair the server offers.
fn discover(addr: &str, base_path: &str) -> Result<Vec<Target>> {
    let resp = http::get(addr, &format!("{base_path}/models"), None)?;
    if resp.status != 200 {
        bail!("GET {base_path}/models returned {}", resp.status);
    }
    let listing = Json::parse(std::str::from_utf8(&resp.body)?)
        .map_err(|e| anyhow::anyhow!("bad /models JSON: {e}"))?;
    let mut targets = Vec::new();
    for m in listing.get("models").and_then(|m| m.as_arr()).unwrap_or(&[]) {
        let Some(name) = m.get("name").and_then(|n| n.as_str()) else { continue };
        let layers = m.get("layers").and_then(|l| l.as_usize()).unwrap_or(0);
        for layer in 0..layers {
            targets.push(Target { model: name.to_string(), layer });
        }
    }
    if targets.is_empty() {
        bail!("server lists no layers to fetch");
    }
    Ok(targets)
}

/// Run the load; returns the aggregate report (and writes `out` if set).
pub fn run(opts: &LoadgenOptions) -> Result<LoadgenReport> {
    let (addr, base_path) = http::parse_url(&opts.url)?;
    let base_path = base_path.trim_end_matches('/').to_string();
    let targets = discover(&addr, &base_path)?;

    struct ClientResult {
        latencies_ms: Vec<f64>,
        failures: usize,
        bytes: u64,
        bytes_requests: usize,
        weights_requests: usize,
    }

    let t0 = Instant::now();
    let results: Vec<ClientResult> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..opts.clients)
            .map(|c| {
                let addr = &addr;
                let base_path = &base_path;
                let targets = &targets;
                scope.spawn(move || {
                    let mut r = ClientResult {
                        latencies_ms: Vec::with_capacity(opts.requests),
                        failures: 0,
                        bytes: 0,
                        bytes_requests: 0,
                        weights_requests: 0,
                    };
                    for i in 0..opts.requests {
                        let t = &targets[(c + i * 7) % targets.len()];
                        // alternate compressed-bytes and decoded-weights
                        let weights = (c + i) % 2 == 1;
                        let path = if weights {
                            r.weights_requests += 1;
                            format!(
                                "{base_path}/models/{}/layers/{}/weights",
                                t.model, t.layer
                            )
                        } else {
                            r.bytes_requests += 1;
                            format!("{base_path}/models/{}/layers/{}", t.model, t.layer)
                        };
                        let rt0 = Instant::now();
                        match http::get(addr, &path, None) {
                            Ok(resp) if resp.status == 200 => {
                                r.latencies_ms
                                    .push(rt0.elapsed().as_secs_f64() * 1e3);
                                r.bytes += resp.body.len() as u64;
                            }
                            Ok(resp) => {
                                eprintln!(
                                    "[loadgen] {} -> HTTP {}",
                                    path, resp.status
                                );
                                r.failures += 1;
                            }
                            Err(e) => {
                                eprintln!("[loadgen] {path} -> {e}");
                                r.failures += 1;
                            }
                        }
                    }
                    r
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    });
    let wall_s = t0.elapsed().as_secs_f64();

    let mut latencies: Vec<f64> = Vec::new();
    let mut failures = 0usize;
    let mut bytes = 0u64;
    let (mut breq, mut wreq) = (0usize, 0usize);
    for r in results {
        latencies.extend_from_slice(&r.latencies_ms);
        failures += r.failures;
        bytes += r.bytes;
        breq += r.bytes_requests;
        wreq += r.weights_requests;
    }
    if latencies.is_empty() {
        bail!("all {} requests failed", opts.clients * opts.requests);
    }
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let report = LoadgenReport {
        total_requests: opts.clients * opts.requests,
        failures,
        p50_ms: percentile(&latencies, 50.0),
        p99_ms: percentile(&latencies, 99.0),
        mean_ms: latencies.iter().sum::<f64>() / latencies.len() as f64,
        min_ms: latencies[0],
        max_ms: latencies[latencies.len() - 1],
        throughput_rps: latencies.len() as f64 / wall_s.max(1e-9),
        wall_s,
        bytes_transferred: bytes,
        bytes_requests: breq,
        weights_requests: wreq,
    };
    if let Some(path) = &opts.out {
        std::fs::write(path, to_json(opts, &report).to_string_pretty())
            .with_context(|| format!("writing {path:?}"))?;
    }
    Ok(report)
}

/// Nearest-rank percentile over an ascending-sorted slice.
fn percentile(sorted: &[f64], p: f64) -> f64 {
    debug_assert!(!sorted.is_empty());
    let rank = (p / 100.0 * (sorted.len() - 1) as f64).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

fn to_json(opts: &LoadgenOptions, r: &LoadgenReport) -> Json {
    json::obj(vec![
        ("bench", json::s("serve")),
        ("url", json::s(&opts.url)),
        ("clients", json::num(opts.clients as f64)),
        ("requests_per_client", json::num(opts.requests as f64)),
        ("total_requests", json::num(r.total_requests as f64)),
        ("failures", json::num(r.failures as f64)),
        ("p50_ms", json::num(r.p50_ms)),
        ("p99_ms", json::num(r.p99_ms)),
        ("mean_ms", json::num(r.mean_ms)),
        ("min_ms", json::num(r.min_ms)),
        ("max_ms", json::num(r.max_ms)),
        ("throughput_rps", json::num(r.throughput_rps)),
        ("wall_s", json::num(r.wall_s)),
        ("bytes_transferred", json::num(r.bytes_transferred as f64)),
        (
            "mix",
            json::obj(vec![
                ("layer_bytes", json::num(r.bytes_requests as f64)),
                ("layer_weights", json::num(r.weights_requests as f64)),
            ]),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 50.0), 51.0);
        assert_eq!(percentile(&v, 99.0), 99.0);
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 100.0);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn report_json_shape() {
        let opts = LoadgenOptions {
            url: "http://x:1".into(),
            clients: 2,
            requests: 3,
            out: None,
        };
        let r = LoadgenReport {
            total_requests: 6,
            failures: 0,
            p50_ms: 1.0,
            p99_ms: 2.0,
            mean_ms: 1.2,
            min_ms: 0.8,
            max_ms: 2.0,
            throughput_rps: 100.0,
            wall_s: 0.06,
            bytes_transferred: 1234,
            bytes_requests: 3,
            weights_requests: 3,
        };
        let j = to_json(&opts, &r);
        let parsed = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_str().unwrap(), "serve");
        assert_eq!(parsed.get("failures").unwrap().as_usize().unwrap(), 0);
        assert!(parsed.get("p50_ms").is_some());
        assert!(parsed.get("p99_ms").is_some());
        assert_eq!(parsed.path("mix.layer_bytes").unwrap().as_usize().unwrap(), 3);
    }
}
