//! Byte-budgeted LRU cache for server-side-decoded layers.
//!
//! Keyed by `(model, layer index, tier)`, value is the dequantized
//! weight vector behind an `Arc` so eviction never invalidates an
//! in-flight response. The tier component keeps decodes of the same
//! layer at different progressive qualities (a v4 container flattens
//! every tier's records into one layer list) from aliasing: a `?tier=t`
//! client that re-requests a layer it already forced a decode of hits
//! the cache instead of re-materializing the tier. The decode itself
//! runs *outside* the lock — concurrent misses on the same layer may
//! decode twice, but a slow decode never blocks hits on other layers
//! (first writer wins; the loser adopts the resident entry).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::Result;

type Key = (String, usize, usize);

struct Entry {
    weights: Arc<Vec<f32>>,
    bytes: usize,
    last_used: u64,
}

struct Inner {
    map: HashMap<Key, Entry>,
    /// Monotone recency clock.
    tick: u64,
    resident_bytes: usize,
    budget_bytes: usize,
    hits: u64,
    misses: u64,
    evictions: u64,
}

/// Point-in-time cache counters (served at `GET /stats`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub entries: usize,
    pub resident_bytes: usize,
    pub budget_bytes: usize,
}

pub struct DecodedCache {
    inner: Mutex<Inner>,
}

impl DecodedCache {
    pub fn new(budget_bytes: usize) -> Self {
        Self {
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                tick: 0,
                resident_bytes: 0,
                budget_bytes,
                hits: 0,
                misses: 0,
                evictions: 0,
            }),
        }
    }

    /// Fetch `key`, decoding via `decode` on a miss. Returns the weights
    /// plus whether this call was served from cache (the authoritative
    /// `X-Cache` signal — computed under the same lock as the lookup, so
    /// it cannot race with concurrent evictions). An entry larger than
    /// the whole budget is returned but not retained. `tier` is 0 for
    /// non-progressive containers and the layer's tier index in a v4
    /// container (`IndexedLayer::tier`).
    pub fn get_or_decode(
        &self,
        model: &str,
        layer: usize,
        tier: usize,
        decode: impl FnOnce() -> Result<Vec<f32>>,
    ) -> Result<(Arc<Vec<f32>>, bool)> {
        {
            let mut g = self.inner.lock().expect("cache lock");
            g.tick += 1;
            let tick = g.tick;
            if let Some(e) = g.map.get_mut(&(model.to_string(), layer, tier)) {
                e.last_used = tick;
                let weights = e.weights.clone();
                g.hits += 1;
                return Ok((weights, true));
            }
            g.misses += 1;
        }
        // decode outside the lock
        let weights = Arc::new(decode()?);
        let bytes = weights.len() * 4;
        let mut g = self.inner.lock().expect("cache lock");
        g.tick += 1;
        let tick = g.tick;
        if let Some(e) = g.map.get_mut(&(model.to_string(), layer, tier)) {
            // another thread decoded the same layer meanwhile — adopt its
            // entry so all handlers share one allocation (still a miss
            // from this caller's perspective: we did decode)
            e.last_used = tick;
            return Ok((e.weights.clone(), false));
        }
        if bytes > g.budget_bytes {
            return Ok((weights, false)); // too big to ever cache
        }
        g.resident_bytes += bytes;
        g.map.insert(
            (model.to_string(), layer, tier),
            Entry { weights: weights.clone(), bytes, last_used: tick },
        );
        // evict least-recently-used entries (never the one just inserted)
        while g.resident_bytes > g.budget_bytes {
            let victim = g
                .map
                .iter()
                .filter(|(k, _)| !(k.0 == model && k.1 == layer && k.2 == tier))
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone());
            match victim {
                Some(k) => {
                    if let Some(e) = g.map.remove(&k) {
                        g.resident_bytes -= e.bytes;
                        g.evictions += 1;
                    }
                }
                None => break,
            }
        }
        Ok((weights, false))
    }

    /// True if the key is currently resident (test/diagnostic helper —
    /// does not touch recency or counters).
    pub fn contains(&self, model: &str, layer: usize, tier: usize) -> bool {
        let g = self.inner.lock().expect("cache lock");
        g.map.contains_key(&(model.to_string(), layer, tier))
    }

    pub fn stats(&self) -> CacheStats {
        let g = self.inner.lock().expect("cache lock");
        CacheStats {
            hits: g.hits,
            misses: g.misses,
            evictions: g.evictions,
            entries: g.map.len(),
            resident_bytes: g.resident_bytes,
            budget_bytes: g.budget_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill(cache: &DecodedCache, model: &str, layer: usize, n: usize) -> Arc<Vec<f32>> {
        cache.get_or_decode(model, layer, 0, || Ok(vec![layer as f32; n])).unwrap().0
    }

    #[test]
    fn hit_after_miss() {
        let c = DecodedCache::new(1 << 20);
        let (a, was_hit) = c.get_or_decode("m", 0, 0, || Ok(vec![0.0; 100])).unwrap();
        assert!(!was_hit);
        let (b, was_hit) = c.get_or_decode("m", 0, 0, || Ok(vec![0.0; 100])).unwrap();
        assert!(was_hit);
        assert!(Arc::ptr_eq(&a, &b));
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert_eq!(s.resident_bytes, 400);
    }

    #[test]
    fn lru_eviction_order_and_budget() {
        // budget fits two 100-element layers (800 B), not three
        let c = DecodedCache::new(800);
        fill(&c, "m", 0, 100);
        fill(&c, "m", 1, 100);
        fill(&c, "m", 0, 100); // touch 0 → 1 becomes LRU
        fill(&c, "m", 2, 100); // evicts 1
        assert!(c.contains("m", 0, 0));
        assert!(!c.contains("m", 1, 0));
        assert!(c.contains("m", 2, 0));
        let s = c.stats();
        assert_eq!(s.evictions, 1);
        assert!(s.resident_bytes <= 800);
    }

    #[test]
    fn oversized_entry_not_retained() {
        let c = DecodedCache::new(100);
        let w = fill(&c, "m", 0, 1000); // 4000 B > 100 B budget
        assert_eq!(w.len(), 1000);
        assert!(!c.contains("m", 0, 0));
        assert_eq!(c.stats().resident_bytes, 0);
    }

    #[test]
    fn distinct_tiers_do_not_collide() {
        // same model + layer index at two tiers = two entries (a v4
        // container's flattened layer list reuses names across tiers)
        let c = DecodedCache::new(1 << 20);
        let (_, hit) = c.get_or_decode("m", 0, 0, || Ok(vec![1.0; 8])).unwrap();
        assert!(!hit);
        let (coarse, hit) = c.get_or_decode("m", 0, 1, || Ok(vec![2.0; 8])).unwrap();
        assert!(!hit);
        assert_eq!(coarse[0], 2.0);
        let (base, hit) = c.get_or_decode("m", 0, 0, || unreachable!()).unwrap();
        assert!(hit);
        assert_eq!(base[0], 1.0);
        assert_eq!(c.stats().entries, 2);
        assert!(c.contains("m", 0, 0) && c.contains("m", 0, 1));
    }

    #[test]
    fn distinct_models_do_not_collide() {
        let c = DecodedCache::new(1 << 20);
        fill(&c, "a", 0, 10);
        fill(&c, "b", 0, 20);
        assert_eq!(c.stats().entries, 2);
        assert_eq!(fill(&c, "a", 0, 10).len(), 10);
        assert_eq!(fill(&c, "b", 0, 20).len(), 20);
    }

    #[test]
    fn decode_error_propagates_and_is_not_cached() {
        let c = DecodedCache::new(1 << 20);
        let r = c.get_or_decode("m", 3, 0, || anyhow::bail!("corrupt layer"));
        assert!(r.is_err());
        assert!(!c.contains("m", 3, 0));
        // a later good decode succeeds
        assert_eq!(fill(&c, "m", 3, 5).len(), 5);
    }
}
