//! The model-delivery server: a `TcpListener` accept loop whose
//! connection handlers run on a bounded [`WorkerPool`].
//!
//! Endpoints (all GET, `Connection: close`):
//!
//! ```text
//! /healthz                           liveness probe
//! /stats                             cache + traffic counters (JSON)
//! /models                            model listing (JSON)
//! /models/{m}                        whole .dcbc container  [Range OK]
//! /models/{m}?tier={t}               exact byte prefix of a v4
//!                                    progressive container through
//!                                    tier t [Range OK]
//! /models/{m}/manifest               layer/chunk byte map (JSON)
//! /models/{m}/layers/{l}             compressed layer payload [Range OK]
//! /models/{m}/layers/{l}/weights     decoded f32 LE weights (cached)
//! /models/{m}/delta?from={fp}        v3 delta segment upgrading the
//!                                    base with fingerprint {fp} [Range OK]
//! ```
//!
//! `{l}` is a layer index or a layer name. Weights decodes go through a
//! byte-budgeted LRU ([`super::cache::DecodedCache`]); `X-Cache:
//! hit|miss` reports what happened. Containers are mmap-free
//! whole-file loads — the index keeps per-layer byte ranges so `Range`
//! requests and layer fetches never copy more than they serve.

use super::cache::{CacheStats, DecodedCache};
use super::http::{self, Request};
use super::index::ContainerIndex;
use crate::util::json::{self, Json};
use crate::util::par::WorkerPool;
use anyhow::{bail, Context, Result};
use byteorder::{ByteOrder, LittleEndian};
use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Directory scanned (non-recursively) for `*.dcbc` containers.
    pub dir: PathBuf,
    /// Bind address, e.g. `127.0.0.1:8080` (port 0 = ephemeral).
    pub addr: String,
    /// Decoded-layer cache budget in bytes.
    pub cache_bytes: usize,
    /// Concurrent connection handlers (and per-layer decode fan-out cap).
    pub workers: usize,
    /// Per-socket read deadline: a client that goes quiet mid-request
    /// (slowloris) gets a 408 and frees its worker slot after this long.
    pub read_timeout: Duration,
    /// Per-socket write deadline: a client that stops reading the
    /// response can only wedge a handler for this long.
    pub write_timeout: Duration,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            dir: PathBuf::from("."),
            addr: "127.0.0.1:8080".into(),
            cache_bytes: 64 << 20,
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            read_timeout: Duration::from_secs(10),
            write_timeout: Duration::from_secs(30),
        }
    }
}

/// One loaded container.
pub struct ModelEntry {
    pub bytes: Arc<Vec<u8>>,
    pub index: Arc<ContainerIndex>,
}

struct ServerState {
    models: BTreeMap<String, ModelEntry>,
    /// (model name, parent fingerprint) → key in `models` of the v3
    /// delta segment upgrading that base. Model name is the delta
    /// container's own `name` field, not its file stem.
    deltas: BTreeMap<(String, u64), String>,
    /// Fingerprint → key for every loaded **full** container: how the
    /// delta endpoint tells a stale-but-legitimate base (409) from a
    /// fingerprint it has never heard of (404).
    known_fps: BTreeMap<u64, String>,
    /// Container model name → key in `models` of a v4 progressive
    /// container for it, so the delta 409 can advertise the fallback.
    progressives: BTreeMap<String, String>,
    cache: DecodedCache,
    /// Worker cap for intra-layer (chunk) decode fan-out.
    decode_workers: usize,
    requests: AtomicU64,
    errors: AtomicU64,
    /// Connections dropped for blowing a read deadline (408s issued).
    timeouts: AtomicU64,
    read_timeout: Duration,
    write_timeout: Duration,
}

/// Handle to a running server; dropping it does NOT stop the server —
/// call [`ServerHandle::shutdown`].
pub struct ServerHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    state: Arc<ServerState>,
}

impl ServerHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.state.cache.stats()
    }

    pub fn request_count(&self) -> u64 {
        self.state.requests.load(Ordering::Relaxed)
    }

    /// Connections that blew the read deadline (and got a 408).
    pub fn timeout_count(&self) -> u64 {
        self.state.timeouts.load(Ordering::Relaxed)
    }

    /// Stop accepting, drain in-flight handlers, join the accept thread.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // unblock the accept() call
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

/// Scan `dir` for `*.dcbc` files, index each one. The model name is the
/// file stem (`lenet5.dcbc` → `lenet5`).
pub fn load_model_dir(dir: &PathBuf) -> Result<BTreeMap<String, ModelEntry>> {
    let mut models = BTreeMap::new();
    let entries = std::fs::read_dir(dir).with_context(|| format!("reading {dir:?}"))?;
    for entry in entries {
        let path = entry?.path();
        if path.extension().and_then(|e| e.to_str()) != Some("dcbc") {
            continue;
        }
        let Some(stem) = path.file_stem().and_then(|s| s.to_str()) else { continue };
        let bytes = std::fs::read(&path).with_context(|| format!("reading {path:?}"))?;
        let index =
            ContainerIndex::build(&bytes).with_context(|| format!("indexing {path:?}"))?;
        models.insert(
            stem.to_string(),
            ModelEntry { bytes: Arc::new(bytes), index: Arc::new(index) },
        );
    }
    if models.is_empty() {
        bail!("no .dcbc containers found in {dir:?}");
    }
    Ok(models)
}

/// Split the loaded entries into the delta registry: v3 segments keyed
/// by (target model name, parent fingerprint), and the fingerprint of
/// every full container. Full-container fingerprints are FNV-1a of the
/// file bytes — valid because serialization is canonical (byte-stable
/// round trip, invariant 2 of `docs/FORMAT.md`), so a file written by
/// this toolchain hashes identically to `model::fingerprint` of its
/// deserialization.
pub fn build_delta_registry(
    models: &BTreeMap<String, ModelEntry>,
) -> (
    BTreeMap<(String, u64), String>,
    BTreeMap<u64, String>,
    BTreeMap<String, String>,
) {
    let mut deltas = BTreeMap::new();
    let mut known_fps = BTreeMap::new();
    let mut progressives = BTreeMap::new();
    for (key, m) in models {
        match m.index.parent_fp {
            Some(fp) => {
                deltas.insert((m.index.model.clone(), fp), key.clone());
            }
            None => {
                known_fps.insert(crate::util::fnv1a(&m.bytes), key.clone());
                if !m.index.tier_ends.is_empty() {
                    progressives.insert(m.index.model.clone(), key.clone());
                }
            }
        }
    }
    (deltas, known_fps, progressives)
}

/// Bind, spawn the accept loop, and return immediately.
pub fn start(opts: ServeOptions) -> Result<ServerHandle> {
    let models = load_model_dir(&opts.dir)?;
    let listener =
        TcpListener::bind(&opts.addr).with_context(|| format!("binding {}", opts.addr))?;
    let addr = listener.local_addr()?;
    let (deltas, known_fps, progressives) = build_delta_registry(&models);
    let state = Arc::new(ServerState {
        models,
        deltas,
        known_fps,
        progressives,
        cache: DecodedCache::new(opts.cache_bytes),
        decode_workers: opts.workers,
        requests: AtomicU64::new(0),
        errors: AtomicU64::new(0),
        timeouts: AtomicU64::new(0),
        read_timeout: opts.read_timeout,
        write_timeout: opts.write_timeout,
    });
    let stop = Arc::new(AtomicBool::new(false));
    let accept_state = state.clone();
    let accept_stop = stop.clone();
    let workers = opts.workers;
    let accept_thread = std::thread::Builder::new()
        .name("serve-accept".into())
        .spawn(move || {
            let pool = WorkerPool::new(workers);
            for conn in listener.incoming() {
                if accept_stop.load(Ordering::SeqCst) {
                    break;
                }
                match conn {
                    Ok(stream) => {
                        let state = accept_state.clone();
                        pool.execute(move || handle_connection(stream, &state));
                    }
                    Err(e) => {
                        eprintln!("[serve] accept error: {e}");
                    }
                }
            }
            // pool drop drains in-flight handlers
        })
        .context("spawning accept thread")?;
    Ok(ServerHandle { addr, stop, accept_thread: Some(accept_thread), state })
}

fn handle_connection(mut stream: TcpStream, state: &ServerState) {
    let _ = stream.set_read_timeout(Some(state.read_timeout));
    let _ = stream.set_write_timeout(Some(state.write_timeout));
    state.requests.fetch_add(1, Ordering::Relaxed);
    let req = match http::read_request(&mut stream) {
        Ok(r) => r,
        Err(e) => {
            // a read deadline expiring mid-head is a slow client, not a
            // malformed request: answer 408 and free the worker slot.
            // The vendored anyhow shim is string-backed, so the io
            // ErrorKind travels as a `[kind=…]` tag (http::tag_io).
            let msg = format!("{e}");
            if msg.contains("[kind=WouldBlock]") || msg.contains("[kind=TimedOut]") {
                state.timeouts.fetch_add(1, Ordering::Relaxed);
                let _ = http::write_error(
                    &mut stream,
                    408,
                    "Request Timeout",
                    "client sent no complete request head in time",
                );
            } else {
                state.errors.fetch_add(1, Ordering::Relaxed);
                let _ = http::write_error(&mut stream, 400, "Bad Request", &msg);
            }
            return;
        }
    };
    if let Err(e) = route(&mut stream, &req, state) {
        state.errors.fetch_add(1, Ordering::Relaxed);
        let _ = http::write_error(&mut stream, 500, "Internal Server Error", &format!("{e:#}"));
    }
}

fn route(stream: &mut TcpStream, req: &Request, state: &ServerState) -> Result<()> {
    if req.method != "GET" {
        return http::write_error(stream, 405, "Method Not Allowed", "GET only");
    }
    let path = req.path.split('?').next().unwrap_or("");
    let parts: Vec<&str> = path.split('/').filter(|p| !p.is_empty()).collect();
    match parts.as_slice() {
        ["healthz"] => http::write_response(stream, 200, "OK", "text/plain", &[], b"ok"),
        ["stats"] => {
            let s = state.cache.stats();
            let body = json::obj(vec![
                ("requests", json::num(state.requests.load(Ordering::Relaxed) as f64)),
                ("errors", json::num(state.errors.load(Ordering::Relaxed) as f64)),
                ("timeouts", json::num(state.timeouts.load(Ordering::Relaxed) as f64)),
                (
                    "read_timeout_ms",
                    json::num(state.read_timeout.as_millis() as f64),
                ),
                (
                    "write_timeout_ms",
                    json::num(state.write_timeout.as_millis() as f64),
                ),
                (
                    "cache",
                    json::obj(vec![
                        ("hits", json::num(s.hits as f64)),
                        ("misses", json::num(s.misses as f64)),
                        ("evictions", json::num(s.evictions as f64)),
                        ("entries", json::num(s.entries as f64)),
                        ("resident_bytes", json::num(s.resident_bytes as f64)),
                        ("budget_bytes", json::num(s.budget_bytes as f64)),
                    ]),
                ),
            ]);
            write_json(stream, 200, "OK", &body)
        }
        ["models"] => {
            let list = state
                .models
                .iter()
                .map(|(name, m)| {
                    let mut fields = vec![
                        ("name", json::s(name)),
                        ("layers", json::num(m.index.layers.len() as f64)),
                        ("bytes", json::num(m.bytes.len() as f64)),
                        ("version", json::num(m.index.version as f64)),
                    ];
                    if let Some(fp) = m.index.parent_fp {
                        fields.push(("parent_fingerprint", json::s(&format!("{fp:016x}"))));
                    }
                    if !m.index.tier_ends.is_empty() {
                        fields.push(("tiers", json::num(m.index.tier_ends.len() as f64)));
                    }
                    json::obj(fields)
                })
                .collect();
            write_json(stream, 200, "OK", &json::obj(vec![("models", json::arr(list))]))
        }
        ["models", name] => {
            let Some(m) = state.models.get(*name) else {
                return not_found(stream, name);
            };
            // ?tier=t on a v4 progressive container serves the exact
            // byte prefix through tier t — a complete container in its
            // own right (progressive truncation rule). Hostile values
            // are shed with structured errors, never a panic.
            if let Some(t) = http::query_param(&req.path, "tier") {
                let Ok(t) = t.parse::<usize>() else {
                    return http::write_error(
                        stream,
                        404,
                        "Not Found",
                        "unparseable ?tier= (want a decimal tier index)",
                    );
                };
                if m.index.tier_ends.is_empty() {
                    return http::write_error(
                        stream,
                        409,
                        "Conflict",
                        &format!(
                            "model {name} is not a progressive container \
                             (version {}) — fetch it without ?tier=",
                            m.index.version
                        ),
                    );
                }
                let Some(&end) = m.index.tier_ends.get(t) else {
                    return http::write_error(
                        stream,
                        404,
                        "Not Found",
                        &format!(
                            "tier {t} out of range (container has {} tiers)",
                            m.index.tier_ends.len()
                        ),
                    );
                };
                let headers = [
                    ("X-Tier", t.to_string()),
                    ("X-Tiers-Total", m.index.tier_ends.len().to_string()),
                ];
                return write_bytes_ranged_with(
                    stream,
                    req,
                    &m.bytes[..end],
                    "application/octet-stream",
                    &headers,
                );
            }
            write_bytes_ranged(stream, req, &m.bytes, "application/octet-stream")
        }
        ["models", name, "delta"] => {
            // Hostile ?from= values are shed, never served and never a
            // panic: unknown or unparseable fingerprints are a plain 404;
            // a fingerprint we recognise (the client holds a container
            // this server also has) with no delta from it is a 409, the
            // signal to fall back to a full fetch. Loadgen buckets the
            // 409s separately (`delta_mismatch`).
            let Some(from) = http::query_param(&req.path, "from") else {
                return http::write_error(
                    stream,
                    404,
                    "Not Found",
                    "delta endpoint needs ?from=<16-hex-digit parent fingerprint>",
                );
            };
            let Ok(fp) = u64::from_str_radix(from.trim_start_matches("0x"), 16) else {
                return http::write_error(
                    stream,
                    404,
                    "Not Found",
                    "unparseable ?from= fingerprint (want 16 hex digits)",
                );
            };
            if let Some(key) = state.deltas.get(&(name.to_string(), fp)) {
                let m = &state.models[key];
                return write_bytes_ranged(stream, req, &m.bytes, "application/octet-stream");
            }
            if state.known_fps.contains_key(&fp) {
                // advertise a progressive fallback when one is loaded:
                // upgrading tier-by-tier beats refetching whole files
                let fallback = match state.progressives.get(*name) {
                    Some(key) => format!(
                        "a progressive container is available: \
                         GET /models/{key}?tier=0 and upgrade from there"
                    ),
                    None => "no progressive container is available for this model".into(),
                };
                return http::write_error(
                    stream,
                    409,
                    "Conflict",
                    &format!(
                        "no delta from base {fp:016x} for model {name} — \
                         fetch the full container instead ({fallback})"
                    ),
                );
            }
            http::write_error(
                stream,
                404,
                "Not Found",
                &format!("unknown base fingerprint {fp:016x}"),
            )
        }
        ["models", name, "manifest"] => {
            let Some(m) = state.models.get(*name) else {
                return not_found(stream, name);
            };
            write_json(stream, 200, "OK", &manifest_json(name, &m.index))
        }
        ["models", name, "layers", layer] => {
            let Some(m) = state.models.get(*name) else {
                return not_found(stream, name);
            };
            let Some(li) = m.index.resolve(layer) else {
                return not_found(stream, layer);
            };
            let payload = m.index.layer_payload(&m.bytes, li)?;
            write_bytes_ranged(stream, req, payload, "application/octet-stream")
        }
        ["models", name, "layers", layer, "weights"] => {
            let Some(m) = state.models.get(*name) else {
                return not_found(stream, name);
            };
            let Some(li) = m.index.resolve(layer) else {
                return not_found(stream, layer);
            };
            let (weights, was_hit) = state.cache.get_or_decode(name, li, || {
                m.index.decode_layer_weights(&m.bytes, li, state.decode_workers)
            })?;
            let mut body = vec![0u8; weights.len() * 4];
            LittleEndian::write_f32_into(&weights, &mut body);
            let dims = m.index.layers[li]
                .dims
                .iter()
                .map(|d| d.to_string())
                .collect::<Vec<_>>()
                .join(",");
            let headers = [
                ("X-Cache", if was_hit { "hit" } else { "miss" }.to_string()),
                ("X-Dims", dims),
                // container-supplied name: strip CR/LF/controls so a
                // hostile layer name cannot inject response headers
                ("X-Layer-Name", http::sanitize_header_value(&m.index.layers[li].name)),
            ];
            http::write_response(
                stream,
                200,
                "OK",
                "application/octet-stream",
                &headers,
                &body,
            )
        }
        _ => not_found(stream, path),
    }
}

fn not_found(stream: &mut TcpStream, what: &str) -> Result<()> {
    http::write_error(stream, 404, "Not Found", &format!("no such resource: {what}"))
}

fn write_json(stream: &mut TcpStream, status: u16, reason: &str, body: &Json) -> Result<()> {
    http::write_response(
        stream,
        status,
        reason,
        "application/json",
        &[],
        body.to_string_compact().as_bytes(),
    )
}

/// Serve `bytes` honoring an optional single `Range` header (RFC 7233:
/// ignored/malformed ranges get the full 200, satisfiable ones 206,
/// out-of-bounds ones 416).
fn write_bytes_ranged(
    stream: &mut TcpStream,
    req: &Request,
    bytes: &[u8],
    content_type: &str,
) -> Result<()> {
    write_bytes_ranged_with(stream, req, bytes, content_type, &[])
}

/// [`write_bytes_ranged`] with extra response headers (e.g. `X-Tier`).
fn write_bytes_ranged_with(
    stream: &mut TcpStream,
    req: &Request,
    bytes: &[u8],
    content_type: &str,
    extra: &[(&str, String)],
) -> Result<()> {
    match req.byte_range(bytes.len()) {
        http::RangeOutcome::Ignored => {
            let mut headers = vec![("Accept-Ranges", "bytes".to_string())];
            headers.extend(extra.iter().cloned());
            http::write_response(stream, 200, "OK", content_type, &headers, bytes)
        }
        http::RangeOutcome::Satisfiable(r) => {
            let mut headers = vec![
                ("Accept-Ranges", "bytes".to_string()),
                (
                    "Content-Range",
                    format!("bytes {}-{}/{}", r.start, r.end - 1, bytes.len()),
                ),
            ];
            headers.extend(extra.iter().cloned());
            http::write_response(
                stream,
                206,
                "Partial Content",
                content_type,
                &headers,
                &bytes[r],
            )
        }
        http::RangeOutcome::Unsatisfiable => {
            let headers = [("Content-Range", format!("bytes */{}", bytes.len()))];
            http::write_response(
                stream,
                416,
                "Range Not Satisfiable",
                "text/plain",
                &headers,
                b"unsatisfiable range",
            )
        }
    }
}

/// The manifest the server publishes per model: layer metadata + the
/// byte map that enables client-side random access.
fn manifest_json(name: &str, index: &ContainerIndex) -> Json {
    let layers = index
        .layers
        .iter()
        .enumerate()
        .map(|(i, l)| {
            let chunks = l
                .chunks
                .iter()
                .map(|c| {
                    json::obj(vec![
                        ("offset", json::num(c.bytes.start as f64)),
                        ("bytes", json::num(c.bytes.len() as f64)),
                        ("n_weights", json::num(c.n_weights as f64)),
                    ])
                })
                .collect();
            json::obj(vec![
                ("index", json::num(i as f64)),
                ("name", json::s(&l.name)),
                ("tier", json::num(l.tier as f64)),
                (
                    "dims",
                    json::arr(l.dims.iter().map(|&d| json::num(d as f64)).collect()),
                ),
                ("n_weights", json::num(l.n_weights as f64)),
                ("delta", json::num(l.grid.delta as f64)),
                ("s_param", json::num(l.s_param as f64)),
                ("payload_offset", json::num(l.payload.start as f64)),
                ("payload_bytes", json::num(l.payload.len() as f64)),
                ("bias_count", json::num(l.bias_count() as f64)),
                ("chunks", json::arr(chunks)),
            ])
        })
        .collect();
    let mut fields = vec![
        ("model", json::s(name)),
        ("container_name", json::s(&index.model)),
        ("version", json::num(index.version as f64)),
        ("container_bytes", json::num(index.container_len as f64)),
    ];
    if let Some(fp) = index.parent_fp {
        fields.push(("parent_fingerprint", json::s(&format!("{fp:016x}"))));
    }
    if !index.tier_ends.is_empty() {
        fields.push((
            "tier_ends",
            json::arr(index.tier_ends.iter().map(|&e| json::num(e as f64)).collect()),
        ));
    }
    fields.push(("layers", json::arr(layers)));
    json::obj(fields)
}
