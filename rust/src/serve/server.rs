//! The model-delivery server: shared routing + state behind two
//! transports — the thread-per-connection [`Backend::Threaded`] accept
//! loop (a bounded [`WorkerPool`]) and the readiness-polling
//! [`Backend::Event`] loop ([`super::event`]) that holds thousands of
//! keep-alive connections on a handful of threads.
//!
//! Endpoints (all GET):
//!
//! ```text
//! /healthz                           liveness probe
//! /stats                             cache + traffic counters (JSON)
//! /models                            model listing (JSON)
//! /models/{m}                        whole .dcbc container  [Range OK]
//! /models/{m}?tier={t}               exact byte prefix of a v4
//!                                    progressive container through
//!                                    tier t [Range OK]
//! /models/{m}/manifest               layer/chunk byte map (JSON)
//! /models/{m}/layers/{l}             compressed layer payload [Range OK]
//! /models/{m}/layers/{l}/weights     decoded f32 LE weights (cached)
//! /models/{m}/delta?from={fp}        v3 delta segment upgrading the
//!                                    base with fingerprint {fp} [Range OK]
//! ```
//!
//! `{l}` is a layer index or a layer name. Weights decodes go through a
//! byte-budgeted LRU ([`super::cache::DecodedCache`]) keyed by (model,
//! layer, tier); `X-Cache: hit|miss` reports what happened. Containers
//! are served from a read-only `mmap` ([`super::mmap::ModelBytes`])
//! where available, so container/tier/layer/delta byte ranges are
//! written zero-copy out of the page cache.
//!
//! Routing is the pure function [`respond`]: request in, [`Response`]
//! out, no socket in sight — both transports render its output through
//! [`http::render_head`], which is what makes the byte-level contract
//! transport-independent (and differentially testable; see
//! `tests/server_end_to_end.rs`).

use super::cache::{CacheStats, DecodedCache};
use super::http::{self, Request};
use super::index::ContainerIndex;
use super::mmap::ModelBytes;
use crate::util::json::{self, Json};
use crate::util::par::WorkerPool;
use crate::util::poll;
use anyhow::{bail, Context, Result};
use byteorder::{ByteOrder, LittleEndian};
use std::collections::BTreeMap;
use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::ops::Range;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Directory scanned (non-recursively) for `*.dcbc` containers.
    pub dir: PathBuf,
    /// Bind address, e.g. `127.0.0.1:8080` (port 0 = ephemeral).
    pub addr: String,
    /// Decoded-layer cache budget in bytes.
    pub cache_bytes: usize,
    /// Concurrent connection handlers (threaded backend) / decode
    /// offload pool size (event backend), and per-layer chunk fan-out.
    pub workers: usize,
    /// Read deadline: a client that goes quiet mid-request (slowloris)
    /// gets a 408 and frees its slot after this long.
    pub read_timeout: Duration,
    /// Write deadline: a client that stops reading the response can
    /// only wedge a handler/connection for this long.
    pub write_timeout: Duration,
    /// Accept guard: connections beyond this many concurrently open are
    /// shed with a 503 (counted in `/stats` as `shed`) instead of
    /// queueing unboundedly. `usize::MAX` = no limit.
    pub max_connections: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            dir: PathBuf::from("."),
            addr: "127.0.0.1:8080".into(),
            cache_bytes: 64 << 20,
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            read_timeout: Duration::from_secs(10),
            write_timeout: Duration::from_secs(30),
            max_connections: usize::MAX,
        }
    }
}

/// Which transport serves the shared routing logic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// One blocking handler per connection on a bounded [`WorkerPool`];
    /// always answers `Connection: close`. The original transport, kept
    /// as the differential-testing oracle.
    Threaded,
    /// epoll/kqueue readiness loop with keep-alive + pipelining
    /// ([`super::event`]); requires [`poll::supported`].
    Event,
}

/// One loaded container.
pub struct ModelEntry {
    pub bytes: Arc<ModelBytes>,
    pub index: Arc<ContainerIndex>,
}

pub(crate) struct ServerState {
    pub(crate) models: BTreeMap<String, ModelEntry>,
    /// (model name, parent fingerprint) → key in `models` of the v3
    /// delta segment upgrading that base. Model name is the delta
    /// container's own `name` field, not its file stem.
    pub(crate) deltas: BTreeMap<(String, u64), String>,
    /// Fingerprint → key for every loaded **full** container: how the
    /// delta endpoint tells a stale-but-legitimate base (409) from a
    /// fingerprint it has never heard of (404).
    pub(crate) known_fps: BTreeMap<u64, String>,
    /// Container model name → key in `models` of a v4 progressive
    /// container for it, so the delta 409 can advertise the fallback.
    pub(crate) progressives: BTreeMap<String, String>,
    pub(crate) cache: DecodedCache,
    /// Worker cap for intra-layer (chunk) decode fan-out.
    pub(crate) decode_workers: usize,
    pub(crate) requests: AtomicU64,
    pub(crate) errors: AtomicU64,
    /// Connections dropped for blowing a read deadline (408s issued).
    pub(crate) timeouts: AtomicU64,
    /// Connections shed with a 503 at the `max_connections` guard.
    pub(crate) shed: AtomicU64,
    /// Currently open (accepted, not yet closed) connections.
    pub(crate) open: AtomicUsize,
    pub(crate) read_timeout: Duration,
    pub(crate) write_timeout: Duration,
    pub(crate) max_connections: usize,
    /// `"threaded"` or `"event"`, surfaced in `/stats`.
    pub(crate) backend: &'static str,
}

impl ServerState {
    /// Load the model directory and assemble the shared state both
    /// backends serve from.
    pub(crate) fn build(opts: &ServeOptions, backend: &'static str) -> Result<Arc<ServerState>> {
        let models = load_model_dir(&opts.dir)?;
        let (deltas, known_fps, progressives) = build_delta_registry(&models);
        Ok(Arc::new(ServerState {
            models,
            deltas,
            known_fps,
            progressives,
            cache: DecodedCache::new(opts.cache_bytes),
            decode_workers: opts.workers,
            requests: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            timeouts: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            open: AtomicUsize::new(0),
            read_timeout: opts.read_timeout,
            write_timeout: opts.write_timeout,
            max_connections: opts.max_connections,
            backend,
        }))
    }
}

/// Handle to a running server; dropping it does NOT stop the server —
/// call [`ServerHandle::shutdown`].
pub struct ServerHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    state: Arc<ServerState>,
    /// Present on the event backend: how `shutdown` interrupts a parked
    /// poll loop without a TCP self-connect.
    waker: Option<Arc<poll::Waker>>,
}

impl ServerHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.state.cache.stats()
    }

    pub fn request_count(&self) -> u64 {
        self.state.requests.load(Ordering::Relaxed)
    }

    /// Connections that blew the read deadline (and got a 408).
    pub fn timeout_count(&self) -> u64 {
        self.state.timeouts.load(Ordering::Relaxed)
    }

    /// Connections shed with a 503 at the `max_connections` guard.
    pub fn shed_count(&self) -> u64 {
        self.state.shed.load(Ordering::Relaxed)
    }

    /// Stop accepting, drain in-flight handlers, join the serve thread.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        match &self.waker {
            // event loop: parked in poll — nudge it
            Some(w) => w.wake(),
            // threaded loop: parked in accept() — unblock it
            None => {
                let _ = TcpStream::connect(self.addr);
            }
        }
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

/// Scan `dir` for `*.dcbc` files, index each one. The model name is the
/// file stem (`lenet5.dcbc` → `lenet5`). Container bytes are mmap'd
/// where the platform allows so big models cost address space, not RSS.
pub fn load_model_dir(dir: &PathBuf) -> Result<BTreeMap<String, ModelEntry>> {
    let mut models = BTreeMap::new();
    let entries = std::fs::read_dir(dir).with_context(|| format!("reading {dir:?}"))?;
    for entry in entries {
        let path = entry?.path();
        if path.extension().and_then(|e| e.to_str()) != Some("dcbc") {
            continue;
        }
        let Some(stem) = path.file_stem().and_then(|s| s.to_str()) else { continue };
        let bytes = ModelBytes::load(&path)?;
        let index =
            ContainerIndex::build(&bytes).with_context(|| format!("indexing {path:?}"))?;
        models.insert(
            stem.to_string(),
            ModelEntry { bytes: Arc::new(bytes), index: Arc::new(index) },
        );
    }
    if models.is_empty() {
        bail!("no .dcbc containers found in {dir:?}");
    }
    Ok(models)
}

/// Split the loaded entries into the delta registry: v3 segments keyed
/// by (target model name, parent fingerprint), and the fingerprint of
/// every full container. Full-container fingerprints are FNV-1a of the
/// file bytes — valid because serialization is canonical (byte-stable
/// round trip, invariant 2 of `docs/FORMAT.md`), so a file written by
/// this toolchain hashes identically to `model::fingerprint` of its
/// deserialization.
pub fn build_delta_registry(
    models: &BTreeMap<String, ModelEntry>,
) -> (
    BTreeMap<(String, u64), String>,
    BTreeMap<u64, String>,
    BTreeMap<String, String>,
) {
    let mut deltas = BTreeMap::new();
    let mut known_fps = BTreeMap::new();
    let mut progressives = BTreeMap::new();
    for (key, m) in models {
        match m.index.parent_fp {
            Some(fp) => {
                deltas.insert((m.index.model.clone(), fp), key.clone());
            }
            None => {
                known_fps.insert(crate::util::fnv1a(&m.bytes[..]), key.clone());
                if !m.index.tier_ends.is_empty() {
                    progressives.insert(m.index.model.clone(), key.clone());
                }
            }
        }
    }
    (deltas, known_fps, progressives)
}

/// Bind and spawn the **threaded** backend (the historical default for
/// embedders/tests); see [`start_with`] to choose.
pub fn start(opts: ServeOptions) -> Result<ServerHandle> {
    start_with(Backend::Threaded, opts)
}

/// Bind, spawn the chosen backend's serve loop, and return immediately.
pub fn start_with(backend: Backend, opts: ServeOptions) -> Result<ServerHandle> {
    match backend {
        Backend::Threaded => start_threaded(opts),
        Backend::Event => start_event(opts),
    }
}

fn start_threaded(opts: ServeOptions) -> Result<ServerHandle> {
    let state = ServerState::build(&opts, "threaded")?;
    let listener =
        TcpListener::bind(&opts.addr).with_context(|| format!("binding {}", opts.addr))?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let accept_state = state.clone();
    let accept_stop = stop.clone();
    let workers = opts.workers;
    let accept_thread = std::thread::Builder::new()
        .name("serve-accept".into())
        .spawn(move || {
            let pool = WorkerPool::new(workers);
            for conn in listener.incoming() {
                if accept_stop.load(Ordering::SeqCst) {
                    break;
                }
                match conn {
                    Ok(mut stream) => {
                        // accept guard: beyond the cap, shed cheaply in
                        // the accept thread (a bounded write, then drop)
                        if accept_state.open.load(Ordering::Relaxed)
                            >= accept_state.max_connections
                        {
                            accept_state.shed.fetch_add(1, Ordering::Relaxed);
                            let _ = stream.set_write_timeout(Some(Duration::from_millis(500)));
                            let _ = shed_response().write_close(&mut stream);
                            continue;
                        }
                        accept_state.open.fetch_add(1, Ordering::Relaxed);
                        let state = accept_state.clone();
                        pool.execute(move || {
                            handle_connection(stream, &state);
                            state.open.fetch_sub(1, Ordering::Relaxed);
                        });
                    }
                    Err(e) => {
                        eprintln!("[serve] accept error: {e}");
                    }
                }
            }
            // pool drop drains in-flight handlers
        })
        .context("spawning accept thread")?;
    Ok(ServerHandle { addr, stop, accept_thread: Some(accept_thread), state, waker: None })
}

fn start_event(opts: ServeOptions) -> Result<ServerHandle> {
    if !poll::supported() {
        bail!("event backend needs epoll/kqueue — rerun with the threaded backend");
    }
    let state = ServerState::build(&opts, "event")?;
    let listener =
        TcpListener::bind(&opts.addr).with_context(|| format!("binding {}", opts.addr))?;
    listener.set_nonblocking(true).context("nonblocking listener")?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let waker = Arc::new(poll::Waker::new()?);
    let (loop_state, loop_stop, loop_waker) = (state.clone(), stop.clone(), waker.clone());
    let workers = opts.workers;
    let accept_thread = std::thread::Builder::new()
        .name("serve-event".into())
        .spawn(move || {
            if let Err(e) = super::event::run(listener, loop_state, loop_stop, loop_waker, workers)
            {
                eprintln!("[serve] event loop failed: {e:#}");
            }
        })
        .context("spawning event loop thread")?;
    Ok(ServerHandle { addr, stop, accept_thread: Some(accept_thread), state, waker: Some(waker) })
}

// ---------------------------------------------------------------------------
// Responses
// ---------------------------------------------------------------------------

/// A fully-routed response, not yet framed onto a socket. The body may
/// borrow the mmap'd container ([`Body::Slice`]) — zero copies between
/// the page cache and the socket for Range/tier/delta traffic.
pub(crate) struct Response {
    pub(crate) status: u16,
    pub(crate) reason: &'static str,
    pub(crate) content_type: &'static str,
    pub(crate) headers: Vec<(&'static str, String)>,
    pub(crate) body: Body,
}

/// Response body: owned bytes (JSON, decoded weights, error text) or a
/// shared slice of a loaded container.
pub(crate) enum Body {
    Owned(Vec<u8>),
    Slice { bytes: Arc<ModelBytes>, range: Range<usize> },
}

impl Body {
    pub(crate) fn len(&self) -> usize {
        match self {
            Body::Owned(v) => v.len(),
            Body::Slice { range, .. } => range.len(),
        }
    }

    pub(crate) fn as_slice(&self) -> &[u8] {
        match self {
            Body::Owned(v) => v,
            Body::Slice { bytes, range } => &bytes[range.clone()],
        }
    }
}

impl Response {
    pub(crate) fn new(
        status: u16,
        reason: &'static str,
        content_type: &'static str,
        headers: Vec<(&'static str, String)>,
        body: Body,
    ) -> Response {
        Response { status, reason, content_type, headers, body }
    }

    /// Plain-text error response (the shape `http::write_error` framed).
    pub(crate) fn error(status: u16, reason: &'static str, msg: String) -> Response {
        Response::new(status, reason, "text/plain", Vec::new(), Body::Owned(msg.into_bytes()))
    }

    fn json(status: u16, reason: &'static str, body: &Json) -> Response {
        Response::new(
            status,
            reason,
            "application/json",
            Vec::new(),
            Body::Owned(body.to_string_compact().into_bytes()),
        )
    }

    /// Render head + body with `Connection: <connection>` — the single
    /// framing path shared by both backends.
    pub(crate) fn render(&self, connection: &str) -> String {
        http::render_head(
            self.status,
            self.reason,
            self.content_type,
            self.body.len(),
            connection,
            &self.headers,
        )
    }

    /// Blocking write with `Connection: close` (threaded backend).
    pub(crate) fn write_close(&self, stream: &mut TcpStream) -> Result<()> {
        stream.write_all(self.render("close").as_bytes())?;
        stream.write_all(self.body.as_slice())?;
        stream.flush()?;
        Ok(())
    }
}

/// The 503 issued at the `max_connections` accept guard (both backends).
pub(crate) fn shed_response() -> Response {
    Response::error(
        503,
        "Service Unavailable",
        "connection limit reached, retry shortly".into(),
    )
}

/// The 408 issued when a read deadline expires mid-request-head.
pub(crate) fn timeout_response() -> Response {
    Response::error(
        408,
        "Request Timeout",
        "client sent no complete request head in time".into(),
    )
}

fn handle_connection(mut stream: TcpStream, state: &ServerState) {
    let _ = stream.set_read_timeout(Some(state.read_timeout));
    let _ = stream.set_write_timeout(Some(state.write_timeout));
    state.requests.fetch_add(1, Ordering::Relaxed);
    match http::read_request(&mut stream) {
        Ok(req) => match respond(&req, state) {
            Ok(resp) => {
                if resp.write_close(&mut stream).is_err() {
                    // client stopped reading (stalled reader) or died —
                    // the write deadline freed the handler
                    state.errors.fetch_add(1, Ordering::Relaxed);
                }
            }
            Err(e) => {
                state.errors.fetch_add(1, Ordering::Relaxed);
                let resp =
                    Response::error(500, "Internal Server Error", format!("{e:#}"));
                let _ = resp.write_close(&mut stream);
            }
        },
        Err(e) => {
            // a read deadline expiring mid-head is a slow client, not a
            // malformed request: answer 408 and free the worker slot.
            // The vendored anyhow shim is string-backed, so the io
            // ErrorKind travels as a `[kind=…]` tag (http::tag_io).
            let msg = format!("{e}");
            if msg.contains("[kind=WouldBlock]") || msg.contains("[kind=TimedOut]") {
                state.timeouts.fetch_add(1, Ordering::Relaxed);
                let _ = timeout_response().write_close(&mut stream);
            } else {
                state.errors.fetch_add(1, Ordering::Relaxed);
                let _ = Response::error(400, "Bad Request", msg).write_close(&mut stream);
            }
        }
    }
}

/// Route one parsed request to its response. Pure with respect to the
/// transport: no socket, no deadline, no `Connection` header — both
/// backends call this and frame the result themselves, which is what the
/// differential replay in `tests/server_end_to_end.rs` relies on.
pub(crate) fn respond(req: &Request, state: &ServerState) -> Result<Response> {
    if req.method != "GET" {
        return Ok(Response::error(405, "Method Not Allowed", "GET only".into()));
    }
    let path = req.path.split('?').next().unwrap_or("");
    let parts: Vec<&str> = path.split('/').filter(|p| !p.is_empty()).collect();
    match parts.as_slice() {
        ["healthz"] => Ok(Response::new(
            200,
            "OK",
            "text/plain",
            Vec::new(),
            Body::Owned(b"ok".to_vec()),
        )),
        ["stats"] => {
            let s = state.cache.stats();
            let mut fields = vec![
                ("requests", json::num(state.requests.load(Ordering::Relaxed) as f64)),
                ("errors", json::num(state.errors.load(Ordering::Relaxed) as f64)),
                ("timeouts", json::num(state.timeouts.load(Ordering::Relaxed) as f64)),
                ("shed", json::num(state.shed.load(Ordering::Relaxed) as f64)),
                (
                    "open_connections",
                    json::num(state.open.load(Ordering::Relaxed) as f64),
                ),
                ("backend", json::s(state.backend)),
                (
                    "read_timeout_ms",
                    json::num(state.read_timeout.as_millis() as f64),
                ),
                (
                    "write_timeout_ms",
                    json::num(state.write_timeout.as_millis() as f64),
                ),
            ];
            if state.max_connections != usize::MAX {
                fields.push((
                    "max_connections",
                    json::num(state.max_connections as f64),
                ));
            }
            fields.push((
                "cache",
                json::obj(vec![
                    ("hits", json::num(s.hits as f64)),
                    ("misses", json::num(s.misses as f64)),
                    ("evictions", json::num(s.evictions as f64)),
                    ("entries", json::num(s.entries as f64)),
                    ("resident_bytes", json::num(s.resident_bytes as f64)),
                    ("budget_bytes", json::num(s.budget_bytes as f64)),
                ]),
            ));
            Ok(Response::json(200, "OK", &json::obj(fields)))
        }
        ["models"] => {
            let list = state
                .models
                .iter()
                .map(|(name, m)| {
                    let mut fields = vec![
                        ("name", json::s(name)),
                        ("layers", json::num(m.index.layers.len() as f64)),
                        ("bytes", json::num(m.bytes.len() as f64)),
                        ("version", json::num(m.index.version as f64)),
                    ];
                    if let Some(fp) = m.index.parent_fp {
                        fields.push(("parent_fingerprint", json::s(&format!("{fp:016x}"))));
                    }
                    if !m.index.tier_ends.is_empty() {
                        fields.push(("tiers", json::num(m.index.tier_ends.len() as f64)));
                    }
                    json::obj(fields)
                })
                .collect();
            Ok(Response::json(200, "OK", &json::obj(vec![("models", json::arr(list))])))
        }
        ["models", name] => {
            let Some(m) = state.models.get(*name) else {
                return Ok(not_found(name));
            };
            // ?tier=t on a v4 progressive container serves the exact
            // byte prefix through tier t — a complete container in its
            // own right (progressive truncation rule). Hostile values
            // are shed with structured errors, never a panic.
            if let Some(t) = http::query_param(&req.path, "tier") {
                let Ok(t) = t.parse::<usize>() else {
                    return Ok(Response::error(
                        404,
                        "Not Found",
                        "unparseable ?tier= (want a decimal tier index)".into(),
                    ));
                };
                if m.index.tier_ends.is_empty() {
                    return Ok(Response::error(
                        409,
                        "Conflict",
                        format!(
                            "model {name} is not a progressive container \
                             (version {}) — fetch it without ?tier=",
                            m.index.version
                        ),
                    ));
                }
                let Some(&end) = m.index.tier_ends.get(t) else {
                    return Ok(Response::error(
                        404,
                        "Not Found",
                        format!(
                            "tier {t} out of range (container has {} tiers)",
                            m.index.tier_ends.len()
                        ),
                    ));
                };
                let headers = vec![
                    ("X-Tier", t.to_string()),
                    ("X-Tiers-Total", m.index.tier_ends.len().to_string()),
                ];
                return Ok(ranged_response(req, &m.bytes, 0..end, headers));
            }
            let len = m.bytes.len();
            Ok(ranged_response(req, &m.bytes, 0..len, Vec::new()))
        }
        ["models", name, "delta"] => {
            // Hostile ?from= values are shed, never served and never a
            // panic: unknown or unparseable fingerprints are a plain 404;
            // a fingerprint we recognise (the client holds a container
            // this server also has) with no delta from it is a 409, the
            // signal to fall back to a full fetch. Loadgen buckets the
            // 409s separately (`delta_mismatch`).
            let Some(from) = http::query_param(&req.path, "from") else {
                return Ok(Response::error(
                    404,
                    "Not Found",
                    "delta endpoint needs ?from=<16-hex-digit parent fingerprint>".into(),
                ));
            };
            let Ok(fp) = u64::from_str_radix(from.trim_start_matches("0x"), 16) else {
                return Ok(Response::error(
                    404,
                    "Not Found",
                    "unparseable ?from= fingerprint (want 16 hex digits)".into(),
                ));
            };
            if let Some(key) = state.deltas.get(&(name.to_string(), fp)) {
                let m = &state.models[key];
                let len = m.bytes.len();
                return Ok(ranged_response(req, &m.bytes, 0..len, Vec::new()));
            }
            if state.known_fps.contains_key(&fp) {
                // advertise a progressive fallback when one is loaded:
                // upgrading tier-by-tier beats refetching whole files
                let fallback = match state.progressives.get(*name) {
                    Some(key) => format!(
                        "a progressive container is available: \
                         GET /models/{key}?tier=0 and upgrade from there"
                    ),
                    None => "no progressive container is available for this model".into(),
                };
                return Ok(Response::error(
                    409,
                    "Conflict",
                    format!(
                        "no delta from base {fp:016x} for model {name} — \
                         fetch the full container instead ({fallback})"
                    ),
                ));
            }
            Ok(Response::error(
                404,
                "Not Found",
                format!("unknown base fingerprint {fp:016x}"),
            ))
        }
        ["models", name, "manifest"] => {
            let Some(m) = state.models.get(*name) else {
                return Ok(not_found(name));
            };
            Ok(Response::json(200, "OK", &manifest_json(name, &m.index)))
        }
        ["models", name, "layers", layer] => {
            let Some(m) = state.models.get(*name) else {
                return Ok(not_found(name));
            };
            let Some(li) = m.index.resolve(layer) else {
                return Ok(not_found(layer));
            };
            // validates the payload range against the container bytes
            m.index.layer_payload(&m.bytes, li)?;
            let range = m.index.layers[li].payload.clone();
            Ok(ranged_response(req, &m.bytes, range, Vec::new()))
        }
        ["models", name, "layers", layer, "weights"] => {
            let Some(m) = state.models.get(*name) else {
                return Ok(not_found(name));
            };
            let Some(li) = m.index.resolve(layer) else {
                return Ok(not_found(layer));
            };
            let tier = m.index.layers[li].tier;
            let (weights, was_hit) = state.cache.get_or_decode(name, li, tier, || {
                m.index.decode_layer_weights(&m.bytes, li, state.decode_workers)
            })?;
            let mut body = vec![0u8; weights.len() * 4];
            LittleEndian::write_f32_into(&weights, &mut body);
            let dims = m.index.layers[li]
                .dims
                .iter()
                .map(|d| d.to_string())
                .collect::<Vec<_>>()
                .join(",");
            let headers = vec![
                ("X-Cache", if was_hit { "hit" } else { "miss" }.to_string()),
                ("X-Dims", dims),
                // container-supplied name: strip CR/LF/controls so a
                // hostile layer name cannot inject response headers
                ("X-Layer-Name", http::sanitize_header_value(&m.index.layers[li].name)),
            ];
            Ok(Response::new(
                200,
                "OK",
                "application/octet-stream",
                headers,
                Body::Owned(body),
            ))
        }
        _ => Ok(not_found(path)),
    }
}

fn not_found(what: &str) -> Response {
    Response::error(404, "Not Found", format!("no such resource: {what}"))
}

/// Serve `bytes[window]` honoring an optional single `Range` header (RFC
/// 7233: ignored/malformed ranges get the full 200, satisfiable ones
/// 206, out-of-bounds ones 416). The 200/206 body is a [`Body::Slice`]
/// into the shared container bytes — zero-copy straight to the socket.
fn ranged_response(
    req: &Request,
    bytes: &Arc<ModelBytes>,
    window: Range<usize>,
    extra: Vec<(&'static str, String)>,
) -> Response {
    let len = window.len();
    match req.byte_range(len) {
        http::RangeOutcome::Ignored => {
            let mut headers = vec![("Accept-Ranges", "bytes".to_string())];
            headers.extend(extra);
            Response::new(
                200,
                "OK",
                "application/octet-stream",
                headers,
                Body::Slice { bytes: bytes.clone(), range: window },
            )
        }
        http::RangeOutcome::Satisfiable(r) => {
            let mut headers = vec![
                ("Accept-Ranges", "bytes".to_string()),
                (
                    "Content-Range",
                    format!("bytes {}-{}/{}", r.start, r.end - 1, len),
                ),
            ];
            headers.extend(extra);
            let abs = (window.start + r.start)..(window.start + r.end);
            Response::new(
                206,
                "Partial Content",
                "application/octet-stream",
                headers,
                Body::Slice { bytes: bytes.clone(), range: abs },
            )
        }
        http::RangeOutcome::Unsatisfiable => {
            let headers = vec![("Content-Range", format!("bytes */{len}"))];
            Response::new(
                416,
                "Range Not Satisfiable",
                "text/plain",
                headers,
                Body::Owned(b"unsatisfiable range".to_vec()),
            )
        }
    }
}

/// The manifest the server publishes per model: layer metadata + the
/// byte map that enables client-side random access.
fn manifest_json(name: &str, index: &ContainerIndex) -> Json {
    let layers = index
        .layers
        .iter()
        .enumerate()
        .map(|(i, l)| {
            let chunks = l
                .chunks
                .iter()
                .map(|c| {
                    json::obj(vec![
                        ("offset", json::num(c.bytes.start as f64)),
                        ("bytes", json::num(c.bytes.len() as f64)),
                        ("n_weights", json::num(c.n_weights as f64)),
                    ])
                })
                .collect();
            json::obj(vec![
                ("index", json::num(i as f64)),
                ("name", json::s(&l.name)),
                ("tier", json::num(l.tier as f64)),
                (
                    "dims",
                    json::arr(l.dims.iter().map(|&d| json::num(d as f64)).collect()),
                ),
                ("n_weights", json::num(l.n_weights as f64)),
                ("delta", json::num(l.grid.delta as f64)),
                ("s_param", json::num(l.s_param as f64)),
                ("payload_offset", json::num(l.payload.start as f64)),
                ("payload_bytes", json::num(l.payload.len() as f64)),
                ("bias_count", json::num(l.bias_count() as f64)),
                ("chunks", json::arr(chunks)),
            ])
        })
        .collect();
    let mut fields = vec![
        ("model", json::s(name)),
        ("container_name", json::s(&index.model)),
        ("version", json::num(index.version as f64)),
        ("container_bytes", json::num(index.container_len as f64)),
    ];
    if let Some(fp) = index.parent_fp {
        fields.push(("parent_fingerprint", json::s(&format!("{fp:016x}"))));
    }
    if !index.tier_ends.is_empty() {
        fields.push((
            "tier_ends",
            json::arr(index.tier_ends.iter().map(|&e| json::num(e as f64)).collect()),
        ));
    }
    fields.push(("layers", json::arr(layers)));
    json::obj(fields)
}
