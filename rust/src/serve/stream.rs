//! Push-based incremental container decoder.
//!
//! [`StreamDecoder::feed`] accepts bytes in whatever pieces the wire
//! delivers them and emits [`StreamEvent`]s the moment enough input has
//! arrived: the container prelude, every completed chunk (decoded
//! immediately — CABAC contexts reset at chunk boundaries, so a chunk is
//! decodable as soon as its last byte lands), and every completed layer
//! with fully reconstructed weights. Memory stays bounded by the largest
//! single chunk plus undecoded slack, never the whole container.
//!
//! The produced weights are byte-for-byte identical to the batch
//! [`CompressedModel::decode_weights`][crate::model::CompressedLayer::decode_weights]
//! path — both decode the same spans with the same engine and dequantize
//! on the same grid (see `property_stream_matches_batch`). Correctness
//! under arbitrary packetization rests on the `.dcbc` prefix-
//! monotonicity and chunk-independence invariants — `docs/FORMAT.md`
//! §"Invariants the serving stack relies on".

use crate::codec::decode_levels;
use crate::model::container::{
    parse_container_prefix, parse_layer_header, parse_varint_prefix, ChunkSpan, LayerHeader,
    Parsed, VERSION_CHUNKED, VERSION_DELTA, VERSION_PROGRESSIVE,
};
use crate::quant::QuantGrid;
use anyhow::{bail, Result};
use byteorder::{ByteOrder, LittleEndian};

/// A fully reconstructed layer, emitted as soon as its bytes completed.
#[derive(Debug, Clone)]
pub struct DecodedLayer {
    /// Position of this layer in the container.
    pub index: usize,
    pub name: String,
    pub dims: Vec<usize>,
    pub grid: QuantGrid,
    pub s_param: u32,
    pub n_weights: usize,
    /// Decoded integer levels — for a v3 delta segment these are the
    /// residual levels `R` that [`crate::delta::StreamApplier`] combines
    /// with the parent.
    pub levels: Vec<i32>,
    /// Dequantized weights (levels × Δ), identical to the batch decoder's.
    pub weights: Vec<f32>,
    pub bias: Vec<f32>,
    /// Version-3 skip record: the layer is untouched by the delta.
    /// `levels`/`weights`/`bias` are empty; only `name`/`index` matter.
    pub skipped: bool,
}

/// Everything a [`StreamDecoder`] can announce while bytes arrive.
#[derive(Debug)]
pub enum StreamEvent {
    /// Container prelude parsed. `parent_fp` is `Some` for version-3
    /// delta segments (the parent container fingerprint).
    Start { model: String, version: u8, n_layers: usize, parent_fp: Option<u64> },
    /// One independently coded CABAC stream finished decoding. Monolithic
    /// layers emit exactly one of these (chunk 0 of 1).
    Chunk { layer: usize, chunk: usize, n_chunks: usize, n_weights: usize },
    /// A layer's payload and bias are complete: reconstructed weights.
    /// In a version-4 container, layers of refinement tiers carry the
    /// **residual** levels `R` (like a v3 delta) that
    /// [`crate::delta::ProgressiveApplier`] folds into the running model.
    Layer(Box<DecodedLayer>),
    /// A version-4 tier body completed: the container is usable at this
    /// quality right now, even if the transfer stops here.
    Tier { tier: usize, n_tiers: usize },
    /// The container ended cleanly (all layers delivered).
    End,
}

enum State {
    /// Waiting for magic/version/name/layer count.
    Prelude,
    /// Waiting for the next layer's header.
    LayerHeader,
    /// Draining the current layer's chunks as their bytes complete.
    Chunks { hdr: LayerHeader, spans: Vec<ChunkSpan>, next: usize, levels: Vec<i32> },
    /// Payload done; waiting for the bias length + bytes.
    Bias { hdr: LayerHeader, levels: Vec<i32>, bias_len: Option<usize> },
    /// Version-4 only: at a tier-body boundary, waiting for the next
    /// refinement tier's first byte. End-of-input here is a *clean*
    /// finish — the progressive truncation rule accepts EOF exactly at
    /// a tier boundary as a complete container at that tier.
    TierBoundary,
    /// Clean end of container.
    Done,
    /// A structural error was reported; all further input is rejected.
    Failed,
}

/// Push-based streaming `.dcbc` decoder. See the module docs.
pub struct StreamDecoder {
    state: State,
    buf: Vec<u8>,
    /// Consumed prefix of `buf` (compacted away after every feed).
    pos: usize,
    /// Total bytes consumed over the decoder's lifetime.
    consumed: u64,
    version: u8,
    n_layers: usize,
    layer_idx: usize,
    /// Version passed to [`parse_layer_header`]: equal to `version` for
    /// v1–v3; for v4 it is [`VERSION_CHUNKED`] during the base tier and
    /// [`VERSION_DELTA`] in refinement tiers.
    hdr_version: u8,
    /// Version-4 declared tier byte lengths (empty otherwise).
    tier_lens: Vec<u64>,
    /// Tier currently being decoded.
    tier_idx: usize,
    /// Absolute offset at which the current tier's body must end.
    tier_end_abs: u64,
}

impl Default for StreamDecoder {
    fn default() -> Self {
        Self::new()
    }
}

impl StreamDecoder {
    pub fn new() -> Self {
        Self {
            state: State::Prelude,
            buf: Vec::new(),
            pos: 0,
            consumed: 0,
            version: 0,
            n_layers: 0,
            layer_idx: 0,
            hdr_version: 0,
            tier_lens: Vec::new(),
            tier_idx: 0,
            tier_end_abs: 0,
        }
    }

    /// Total container bytes consumed so far.
    pub fn bytes_consumed(&self) -> u64 {
        self.consumed
    }

    /// True once the container has been fully decoded.
    pub fn is_done(&self) -> bool {
        matches!(self.state, State::Done)
    }

    /// Push the next bytes off the wire; returns every event they
    /// completed. A structural error poisons the decoder: the error is
    /// returned and every later call fails too.
    pub fn feed(&mut self, bytes: &[u8]) -> Result<Vec<StreamEvent>> {
        if matches!(self.state, State::Failed) {
            bail!("stream decoder already failed");
        }
        self.buf.extend_from_slice(bytes);
        let mut events = Vec::new();
        let res = self.advance(&mut events);
        // compact the consumed prefix so memory tracks undecoded slack
        self.consumed += self.pos as u64;
        self.buf.drain(..self.pos);
        self.pos = 0;
        if let Err(e) = res {
            self.state = State::Failed;
            return Err(e);
        }
        Ok(events)
    }

    /// Signal end-of-input: succeeds only if the container ended cleanly
    /// with no bytes left over.
    pub fn finish(&self) -> Result<()> {
        match &self.state {
            State::Done if self.pos == self.buf.len() => Ok(()),
            State::Done => bail!("trailing bytes after container end"),
            // progressive truncation rule: EOF at a tier-body boundary
            // is a complete container at that tier
            State::TierBoundary => Ok(()),
            State::Failed => bail!("stream decoder already failed"),
            State::Prelude => bail!("truncated container: prelude incomplete"),
            State::LayerHeader => bail!(
                "truncated container: layer {}/{} header incomplete",
                self.layer_idx,
                self.n_layers
            ),
            State::Chunks { next, spans, .. } => bail!(
                "truncated container: layer {}/{} stopped at chunk {}/{}",
                self.layer_idx,
                self.n_layers,
                next,
                spans.len()
            ),
            State::Bias { .. } => bail!(
                "truncated container: layer {}/{} bias incomplete",
                self.layer_idx,
                self.n_layers
            ),
        }
    }

    fn rest(&self) -> &[u8] {
        &self.buf[self.pos..]
    }

    /// A layer finished: advance within the container, and for version 4
    /// handle the tier boundary (tiling check, `Tier` event, switch to
    /// v3-shaped refinement records).
    fn layer_done(&mut self, events: &mut Vec<StreamEvent>) -> Result<()> {
        self.layer_idx += 1;
        if self.layer_idx < self.n_layers {
            self.state = State::LayerHeader;
            return Ok(());
        }
        if self.version != VERSION_PROGRESSIVE {
            events.push(StreamEvent::End);
            self.state = State::Done;
            return Ok(());
        }
        self.check_tier_tiling()?;
        events.push(StreamEvent::Tier {
            tier: self.tier_idx,
            n_tiers: self.tier_lens.len(),
        });
        if self.tier_idx + 1 == self.tier_lens.len() {
            events.push(StreamEvent::End);
            self.state = State::Done;
        } else {
            self.tier_idx += 1;
            self.tier_end_abs += self.tier_lens[self.tier_idx];
            self.layer_idx = 0;
            self.hdr_version = VERSION_DELTA;
            self.state = State::TierBoundary;
        }
        Ok(())
    }

    /// The just-finished tier body must end exactly where the tier table
    /// declared (`docs/FORMAT.md` §"Progressive tiers").
    fn check_tier_tiling(&self) -> Result<()> {
        let abs = self.consumed + self.pos as u64;
        if abs != self.tier_end_abs {
            bail!(
                "tier {} body does not tile its declared byte length \
                 (body ends at offset {abs}, tier table says {})",
                self.tier_idx,
                self.tier_end_abs
            );
        }
        Ok(())
    }

    /// Run the state machine until it stalls on missing input.
    fn advance(&mut self, events: &mut Vec<StreamEvent>) -> Result<()> {
        loop {
            match std::mem::replace(&mut self.state, State::Failed) {
                State::Prelude => match parse_container_prefix(self.rest())? {
                    Parsed::Complete(p, used) => {
                        self.pos += used;
                        self.version = p.version;
                        self.n_layers = p.n_layers;
                        self.hdr_version = if p.version == VERSION_PROGRESSIVE {
                            VERSION_CHUNKED
                        } else {
                            p.version
                        };
                        self.tier_lens = p.tier_lens;
                        events.push(StreamEvent::Start {
                            model: p.name,
                            version: p.version,
                            n_layers: p.n_layers,
                            parent_fp: p.parent_fp,
                        });
                        if self.version == VERSION_PROGRESSIVE {
                            self.tier_end_abs =
                                self.consumed + self.pos as u64 + self.tier_lens[0];
                            if self.n_layers == 0 {
                                // a zero-layer container collapses to its
                                // (empty) base tier, like the batch reader
                                self.check_tier_tiling()?;
                                events.push(StreamEvent::Tier {
                                    tier: 0,
                                    n_tiers: self.tier_lens.len(),
                                });
                                events.push(StreamEvent::End);
                                self.state = State::Done;
                            } else {
                                self.state = State::LayerHeader;
                            }
                        } else if self.n_layers == 0 {
                            events.push(StreamEvent::End);
                            self.state = State::Done;
                        } else {
                            self.state = State::LayerHeader;
                        }
                    }
                    Parsed::NeedMore => {
                        self.state = State::Prelude;
                        return Ok(());
                    }
                },
                State::LayerHeader => match parse_layer_header(self.rest(), self.hdr_version)? {
                    Parsed::Complete(hdr, used) => {
                        self.pos += used;
                        if hdr.skipped {
                            // v3 skip record: no payload, no bias — the
                            // layer completes the moment its header does
                            events.push(StreamEvent::Layer(Box::new(DecodedLayer {
                                index: self.layer_idx,
                                name: hdr.name,
                                dims: Vec::new(),
                                grid: hdr.grid,
                                s_param: 0,
                                n_weights: 0,
                                levels: Vec::new(),
                                weights: Vec::new(),
                                bias: Vec::new(),
                                skipped: true,
                            })));
                            self.layer_done(events)?;
                            continue;
                        }
                        let spans = hdr.chunk_spans();
                        // cap the pre-allocation: n_weights is attacker
                        // controlled until the payload actually decodes
                        let levels = Vec::with_capacity(hdr.n_weights.min(1 << 16));
                        self.state = State::Chunks { hdr, spans, next: 0, levels };
                    }
                    Parsed::NeedMore => {
                        self.state = State::LayerHeader;
                        return Ok(());
                    }
                },
                State::Chunks { hdr, spans, mut next, mut levels } => {
                    // decode every chunk whose bytes are fully buffered
                    while next < spans.len() && self.rest().len() >= spans[next].bytes {
                        let span = spans[next];
                        let chunk = &self.rest()[..span.bytes];
                        levels.extend_from_slice(&decode_levels(
                            chunk,
                            span.n_weights,
                            hdr.cfg,
                        ));
                        self.pos += span.bytes;
                        events.push(StreamEvent::Chunk {
                            layer: self.layer_idx,
                            chunk: next,
                            n_chunks: spans.len(),
                            n_weights: span.n_weights,
                        });
                        next += 1;
                    }
                    if next < spans.len() {
                        self.state = State::Chunks { hdr, spans, next, levels };
                        return Ok(());
                    }
                    self.state = State::Bias { hdr, levels, bias_len: None };
                }
                State::Bias { hdr, levels, mut bias_len } => {
                    if bias_len.is_none() {
                        match parse_varint_prefix(self.rest())? {
                            Parsed::Complete(v, used) => {
                                let blen = v as usize;
                                if blen > crate::baselines::MAX_DECODE_ELEMS {
                                    bail!("layer claims {blen} biases (hostile header?)");
                                }
                                self.pos += used;
                                bias_len = Some(blen);
                            }
                            Parsed::NeedMore => {
                                self.state = State::Bias { hdr, levels, bias_len };
                                return Ok(());
                            }
                        }
                    }
                    let blen = bias_len.expect("set above");
                    if self.rest().len() < blen * 4 {
                        self.state = State::Bias { hdr, levels, bias_len };
                        return Ok(());
                    }
                    let mut bias = vec![0f32; blen];
                    LittleEndian::read_f32_into(&self.rest()[..blen * 4], &mut bias);
                    self.pos += blen * 4;
                    let weights = hdr.grid.dequantize(&levels);
                    events.push(StreamEvent::Layer(Box::new(DecodedLayer {
                        index: self.layer_idx,
                        name: hdr.name,
                        dims: hdr.dims,
                        grid: hdr.grid,
                        s_param: hdr.s_param,
                        n_weights: hdr.n_weights,
                        levels,
                        weights,
                        bias,
                        skipped: false,
                    })));
                    self.layer_done(events)?;
                }
                State::TierBoundary => {
                    if self.rest().is_empty() {
                        self.state = State::TierBoundary;
                        return Ok(());
                    }
                    self.state = State::LayerHeader;
                }
                State::Done => {
                    self.state = State::Done;
                    if self.pos < self.buf.len() {
                        bail!("trailing bytes after container end");
                    }
                    return Ok(());
                }
                State::Failed => unreachable!("feed rejects a failed decoder"),
            }
        }
    }
}

/// Decode a whole in-memory container through the streaming path —
/// convenience for tests and the `fetch` CLI fallback.
pub fn decode_all(bytes: &[u8]) -> Result<Vec<DecodedLayer>> {
    let mut dec = StreamDecoder::new();
    let events = dec.feed(bytes)?;
    dec.finish()?;
    Ok(events
        .into_iter()
        .filter_map(|e| match e {
            StreamEvent::Layer(l) => Some(*l),
            _ => None,
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::{encode_levels, CodecConfig, RemainderMode};
    use crate::model::{ChunkInfo, CompressedLayer, CompressedModel};
    use crate::util::{ptest, SplitMix64};

    fn rand_levels(rng: &mut SplitMix64, n: usize, p_zero: f64, spread: u64) -> Vec<i32> {
        (0..n)
            .map(|_| {
                if rng.next_f64() < p_zero {
                    0
                } else {
                    (1 + rng.below(spread) as i32)
                        * if rng.next_u64() & 1 == 0 { 1 } else { -1 }
                }
            })
            .collect()
    }

    fn layer_from_levels(
        name: &str,
        levels: &[i32],
        n_chunks: usize,
        cfg: CodecConfig,
        bias: Vec<f32>,
    ) -> CompressedLayer {
        let n_chunks = n_chunks.max(1);
        let per = ((levels.len() + n_chunks - 1) / n_chunks).max(1);
        let mut payload = Vec::new();
        let mut chunks = Vec::new();
        for part in levels.chunks(per) {
            let bytes = encode_levels(part, cfg);
            chunks.push(ChunkInfo { n_weights: part.len(), bytes: bytes.len() });
            payload.extend_from_slice(&bytes);
        }
        if chunks.len() <= 1 {
            chunks.clear();
        }
        let max_abs = levels.iter().map(|l| l.unsigned_abs()).max().unwrap_or(0);
        CompressedLayer {
            name: name.into(),
            dims: vec![levels.len().max(1)],
            grid: crate::quant::QuantGrid { delta: 0.03125, max_level: max_abs as i32 },
            s_param: 17,
            cfg,
            n_weights: levels.len(),
            payload,
            chunks,
            bias,
        }
    }

    fn sample_container(seed: u64, chunked: bool) -> CompressedModel {
        let mut rng = SplitMix64::new(seed);
        let cfg = CodecConfig::default();
        let cfg2 = CodecConfig {
            n_abs_flags: 3,
            remainder: RemainderMode::ExpGolomb(1),
            sig_ctx_neighbors: false,
        };
        let l0 = rand_levels(&mut rng, 700, 0.85, 40);
        let l1 = rand_levels(&mut rng, 1200, 0.6, 12);
        let l2 = rand_levels(&mut rng, 64, 0.3, 5);
        CompressedModel {
            name: "streamtest".into(),
            layers: vec![
                layer_from_levels("conv1", &l0, if chunked { 4 } else { 1 }, cfg, vec![1.0, -2.5]),
                layer_from_levels("conv2", &l1, if chunked { 3 } else { 1 }, cfg2, vec![]),
                layer_from_levels("fc", &l2, 1, cfg, vec![0.25; 8]),
            ],
        }
    }

    /// Feed `bytes` split at the given granularity and collect all events.
    fn feed_in_splits(
        bytes: &[u8],
        splits: impl Iterator<Item = usize>,
    ) -> Result<Vec<StreamEvent>> {
        let mut dec = StreamDecoder::new();
        let mut events = Vec::new();
        let mut pos = 0usize;
        for sz in splits {
            if pos >= bytes.len() {
                break;
            }
            let end = (pos + sz.max(1)).min(bytes.len());
            events.extend(dec.feed(&bytes[pos..end])?);
            pos = end;
        }
        if pos < bytes.len() {
            events.extend(dec.feed(&bytes[pos..])?);
        }
        dec.finish()?;
        Ok(events)
    }

    fn layers_of(events: Vec<StreamEvent>) -> Vec<DecodedLayer> {
        events
            .into_iter()
            .filter_map(|e| match e {
                StreamEvent::Layer(l) => Some(*l),
                _ => None,
            })
            .collect()
    }

    fn assert_matches_batch(model: &CompressedModel, decoded: &[DecodedLayer]) {
        assert_eq!(decoded.len(), model.layers.len());
        for (got, want) in decoded.iter().zip(&model.layers) {
            assert_eq!(got.name, want.name);
            assert_eq!(got.dims, want.dims);
            assert_eq!(got.n_weights, want.n_weights);
            // byte-for-byte: compare the f32 bit patterns
            let gw: Vec<u32> = got.weights.iter().map(|w| w.to_bits()).collect();
            let ww: Vec<u32> = want.decode_weights().iter().map(|w| w.to_bits()).collect();
            assert_eq!(gw, ww, "layer {}", want.name);
            assert_eq!(got.bias, want.bias);
        }
    }

    #[test]
    fn one_byte_dribble_matches_batch_v1_and_v2() {
        for chunked in [false, true] {
            let model = sample_container(3, chunked);
            let bytes = model.serialize();
            let events = feed_in_splits(&bytes, std::iter::repeat(1)).unwrap();
            assert_matches_batch(&model, &layers_of(events));
        }
    }

    #[test]
    fn whole_buffer_matches_batch() {
        for chunked in [false, true] {
            let model = sample_container(4, chunked);
            let bytes = model.serialize();
            let events = feed_in_splits(&bytes, std::iter::once(bytes.len())).unwrap();
            let mut saw_start = false;
            let mut saw_end = false;
            let mut chunk_events = 0usize;
            for e in &events {
                match e {
                    StreamEvent::Start { model: m, n_layers, .. } => {
                        saw_start = true;
                        assert_eq!(m, "streamtest");
                        assert_eq!(*n_layers, 3);
                    }
                    StreamEvent::Chunk { .. } => chunk_events += 1,
                    StreamEvent::End => saw_end = true,
                    StreamEvent::Layer(_) | StreamEvent::Tier { .. } => {}
                }
            }
            assert!(saw_start && saw_end);
            let expected_chunks: usize =
                model.layers.iter().map(|l| l.n_chunks()).sum();
            assert_eq!(chunk_events, expected_chunks);
            assert_matches_batch(&model, &layers_of(events));
        }
    }

    #[test]
    fn empty_container_streams() {
        let model = CompressedModel { name: "empty".into(), layers: vec![] };
        let bytes = model.serialize();
        let events = feed_in_splits(&bytes, std::iter::repeat(1)).unwrap();
        assert!(matches!(events.last(), Some(StreamEvent::End)));
        assert!(layers_of(events).is_empty());
    }

    #[test]
    fn property_stream_matches_batch_randomized_splits() {
        ptest::check(
            ptest::Config { cases: 40, max_size: 600, ..Default::default() },
            "stream-matches-batch",
            |g| {
                let n_layers = g.usize_in(1, 3);
                let mut layers = Vec::new();
                for li in 0..n_layers {
                    let levels = g.levels();
                    let cfg = CodecConfig {
                        n_abs_flags: 1 + g.usize_in(0, 8) as u32,
                        remainder: RemainderMode::ExpGolomb(g.usize_in(0, 2) as u32),
                        sig_ctx_neighbors: g.bool(),
                    };
                    let n_chunks = if g.bool() { 1 } else { 1 + g.usize_in(0, 4) };
                    let bias = (0..g.usize_in(0, 6)).map(|_| g.f32_normal(1.0)).collect();
                    layers.push(layer_from_levels(
                        &format!("l{li}"),
                        &levels,
                        n_chunks,
                        cfg,
                        bias,
                    ));
                }
                let model = CompressedModel { name: "p".into(), layers };
                let bytes = model.serialize();
                // randomized split sizes, 1 byte .. whole buffer
                let mut dec = StreamDecoder::new();
                let mut events = Vec::new();
                let mut pos = 0usize;
                while pos < bytes.len() {
                    let sz = g.usize_in(1, bytes.len().min(257));
                    let end = (pos + sz).min(bytes.len());
                    events.extend(
                        dec.feed(&bytes[pos..end]).map_err(|e| format!("feed: {e}"))?,
                    );
                    pos = end;
                }
                dec.finish().map_err(|e| format!("finish: {e}"))?;
                if dec.bytes_consumed() != bytes.len() as u64 {
                    return Err("consumed != container length".into());
                }
                let decoded = layers_of(events);
                if decoded.len() != model.layers.len() {
                    return Err("missing layers".into());
                }
                for (got, want) in decoded.iter().zip(&model.layers) {
                    let gw: Vec<u32> = got.weights.iter().map(|w| w.to_bits()).collect();
                    let ww: Vec<u32> =
                        want.decode_weights().iter().map(|w| w.to_bits()).collect();
                    if gw != ww {
                        return Err(format!("weight mismatch in {}", want.name));
                    }
                    if got.bias != want.bias {
                        return Err("bias mismatch".into());
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn truncation_reports_structured_error_no_panic() {
        for chunked in [false, true] {
            let model = sample_container(9, chunked);
            let bytes = model.serialize();
            for cut in [0usize, 1, 4, 5, 9, bytes.len() / 3, bytes.len() / 2, bytes.len() - 1]
            {
                let mut dec = StreamDecoder::new();
                // feeding a valid prefix must never error...
                dec.feed(&bytes[..cut]).unwrap();
                // ...but finishing early must, with a structured message
                let err = dec.finish().unwrap_err().to_string();
                assert!(
                    err.contains("truncated") || err.contains("incomplete"),
                    "cut={cut}: {err}"
                );
            }
        }
    }

    #[test]
    fn garbage_and_trailing_bytes_are_rejected() {
        // wrong magic fails fast
        let mut dec = StreamDecoder::new();
        assert!(dec.feed(b"NOPE....").is_err());
        // a failed decoder stays failed
        assert!(dec.feed(b"DCBC").is_err());

        // trailing bytes after a clean end
        let bytes = sample_container(2, true).serialize();
        let mut dec = StreamDecoder::new();
        dec.feed(&bytes).unwrap();
        assert!(dec.feed(b"x").is_err());

        // corrupted version byte
        let mut bad = bytes.clone();
        bad[4] = 77;
        let mut dec = StreamDecoder::new();
        assert!(dec.feed(&bad).is_err());

        // random garbage after the magic must error, not panic
        let mut rng = SplitMix64::new(33);
        for _ in 0..32 {
            let mut buf = b"DCBC".to_vec();
            buf.push(if rng.next_u64() & 1 == 0 { 1 } else { 2 });
            buf.extend((0..200).map(|_| rng.next_u64() as u8));
            let mut dec = StreamDecoder::new();
            match dec.feed(&buf) {
                Ok(_) => {
                    // structurally plausible prefix — must still refuse to finish
                    assert!(dec.finish().is_err() || dec.is_done());
                }
                Err(_) => {}
            }
        }
    }

    #[test]
    fn v3_delta_segment_streams_at_every_granularity() {
        use crate::model::{DeltaLayer, DeltaModel};
        let cfg = CodecConfig::default();
        let residual = vec![0, 0, 3, 0, -1, 0, 0, 0];
        let delta = DeltaModel {
            parent_fp: 0x1234_5678_9ABC_DEF0,
            name: "d".into(),
            layers: vec![
                DeltaLayer::Skipped("conv1".into()),
                DeltaLayer::Coded(layer_from_levels("conv2", &residual, 2, cfg, vec![0.5])),
                DeltaLayer::Skipped("fc".into()),
            ],
        };
        let bytes = delta.serialize();
        for split in [1usize, 3, 7, bytes.len()] {
            let events = feed_in_splits(&bytes, std::iter::repeat(split)).unwrap();
            let mut fp = None;
            for e in &events {
                if let StreamEvent::Start { parent_fp, version, .. } = e {
                    fp = *parent_fp;
                    assert_eq!(*version, 3);
                }
            }
            assert_eq!(fp, Some(0x1234_5678_9ABC_DEF0), "split={split}");
            let layers = layers_of(events);
            assert_eq!(layers.len(), 3);
            assert!(layers[0].skipped && layers[2].skipped && !layers[1].skipped);
            assert_eq!(layers[0].name, "conv1");
            assert_eq!(layers[2].name, "fc");
            assert_eq!(layers[1].levels, residual);
            assert_eq!(layers[1].bias, vec![0.5]);
        }
    }

    #[test]
    fn decode_all_convenience() {
        let model = sample_container(5, true);
        let layers = decode_all(&model.serialize()).unwrap();
        assert_matches_batch(&model, &layers);
    }

    use crate::model::{DeltaLayer, ProgressiveModel};

    /// A 2-layer, 3-tier progressive container with chunked payloads and
    /// a skip record in tier 1 — built record-by-record so the stream
    /// shape is exercised independently of the residual algebra.
    fn sample_progressive(seed: u64) -> ProgressiveModel {
        let mut rng = SplitMix64::new(seed);
        let cfg = CodecConfig::default();
        let base = vec![
            layer_from_levels("conv1", &rand_levels(&mut rng, 400, 0.8, 9), 3, cfg, vec![1.0]),
            layer_from_levels("fc", &rand_levels(&mut rng, 120, 0.5, 4), 1, cfg, vec![]),
        ];
        let r1 = vec![
            DeltaLayer::Coded(layer_from_levels(
                "conv1",
                &rand_levels(&mut rng, 400, 0.95, 2),
                2,
                cfg,
                vec![1.0],
            )),
            DeltaLayer::Skipped("fc".into()),
        ];
        let r2 = vec![
            DeltaLayer::Coded(layer_from_levels(
                "conv1",
                &rand_levels(&mut rng, 400, 0.9, 3),
                1,
                cfg,
                vec![1.0],
            )),
            DeltaLayer::Coded(layer_from_levels(
                "fc",
                &rand_levels(&mut rng, 120, 0.9, 2),
                1,
                cfg,
                vec![],
            )),
        ];
        ProgressiveModel { name: "prog".into(), base, refinements: vec![r1, r2] }
    }

    #[test]
    fn v4_progressive_streams_match_batch_at_every_granularity() {
        let prog = sample_progressive(61);
        let bytes = prog.serialize();
        // batch reference: levels of every record in file order
        let want: Vec<(String, bool, Vec<i32>)> = prog
            .base
            .iter()
            .map(|l| (l.name.clone(), false, l.decode_levels_with(1)))
            .chain(prog.refinements.iter().flatten().map(|d| match d {
                DeltaLayer::Skipped(n) => (n.clone(), true, Vec::new()),
                DeltaLayer::Coded(c) => (c.name.clone(), false, c.decode_levels_with(1)),
            }))
            .collect();

        for split in [1usize, 5, 13, bytes.len()] {
            let events = feed_in_splits(&bytes, std::iter::repeat(split)).unwrap();
            let mut tiers = Vec::new();
            let mut layers_seen_at_tier = Vec::new();
            let mut n_layer_events = 0usize;
            for e in &events {
                match e {
                    StreamEvent::Start { version, n_layers, parent_fp, .. } => {
                        assert_eq!(*version, 4);
                        assert_eq!(*n_layers, 2);
                        assert_eq!(*parent_fp, None);
                    }
                    StreamEvent::Layer(_) => n_layer_events += 1,
                    StreamEvent::Tier { tier, n_tiers } => {
                        assert_eq!(*n_tiers, 3);
                        tiers.push(*tier);
                        layers_seen_at_tier.push(n_layer_events);
                    }
                    _ => {}
                }
            }
            // one Tier event per tier, in order, each after its 2 layers
            assert_eq!(tiers, vec![0, 1, 2], "split={split}");
            assert_eq!(layers_seen_at_tier, vec![2, 4, 6], "split={split}");
            let got = layers_of(events);
            assert_eq!(got.len(), want.len(), "split={split}");
            for (g, (name, skipped, levels)) in got.iter().zip(&want) {
                assert_eq!(&g.name, name, "split={split}");
                assert_eq!(g.skipped, *skipped, "split={split}");
                assert_eq!(&g.levels, levels, "split={split} layer={name}");
            }
        }
    }

    #[test]
    fn v4_truncation_at_tier_boundary_is_a_clean_finish() {
        let prog = sample_progressive(62);
        let bytes = prog.serialize();
        let lens = prog.tier_body_lens();
        let prelude = bytes.len() - lens.iter().sum::<usize>();
        let ends: Vec<usize> = lens
            .iter()
            .scan(prelude, |acc, &l| {
                *acc += l;
                Some(*acc)
            })
            .collect();
        for (t, &end) in ends.iter().enumerate() {
            // exactly at the boundary: complete container at tier t
            let mut dec = StreamDecoder::new();
            let events = dec.feed(&bytes[..end]).unwrap();
            dec.finish().unwrap();
            let tiers = events
                .iter()
                .filter(|e| matches!(e, StreamEvent::Tier { .. }))
                .count();
            assert_eq!(tiers, t + 1, "boundary {t}");
            // one byte short / one byte past: incomplete
            for cut in [end - 1, (end + 1).min(bytes.len())] {
                if cut == end || cut == bytes.len() {
                    continue;
                }
                let mut dec = StreamDecoder::new();
                dec.feed(&bytes[..cut]).unwrap();
                assert!(dec.finish().is_err(), "cut={cut}");
            }
        }
        // trailing garbage after the last declared tier
        let mut dec = StreamDecoder::new();
        let mut all = bytes.clone();
        all.push(0);
        assert!(dec.feed(&all).is_err());
    }
}
