//! Synthetic ImageNet-scale models at true layer shapes.
//!
//! Statistics model (per layer):
//! * a spike-and-slab weight distribution — `density` of the entries are
//!   nonzero, drawn Laplace(0, b) with b set from the He-init scale of
//!   the layer (empirical DNN weights are zero-mean and heavier-tailed
//!   than Gaussian; magnitude pruning keeps the tails, which is why the
//!   slab is truncated away from 0 by the pruning threshold),
//! * per-weight posterior σ ~ |N(0.12·b, 0.04·b)| + floor — the shape VD
//!   posteriors take after variance-only fine-tuning (narrow for large
//!   weights, wide for small ones: we add a mild |w|-dependent tilt).
//!
//! Layer-type modulation matches the pruning literature: fc layers prune
//! much harder than convs (Han et al. report 96%+ fc sparsity vs ~60-70%
//! conv sparsity on VGG16); we solve a per-type density split that hits
//! the paper's global density exactly.

use crate::util::SplitMix64;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arch {
    Vgg16,
    ResNet50,
    MobileNetV1,
}

impl Arch {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "vgg16" => Some(Arch::Vgg16),
            "resnet50" => Some(Arch::ResNet50),
            "mobilenetv1" | "mobilenet-v1" | "mobilenet" => Some(Arch::MobileNetV1),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Arch::Vgg16 => "vgg16",
            Arch::ResNet50 => "resnet50",
            Arch::MobileNetV1 => "mobilenet-v1",
        }
    }

    /// The paper's Table 1 sparsity (|w ≠ 0| / |w|, as a fraction).
    pub fn paper_density(&self) -> f64 {
        match self {
            Arch::Vgg16 => 0.0985,
            Arch::ResNet50 => 0.2540,
            Arch::MobileNetV1 => 0.5073,
        }
    }

    /// Table 1 "Org. size" in MB (sanity anchor for the shape tables).
    pub fn paper_size_mb(&self) -> f64 {
        match self {
            Arch::Vgg16 => 553.43,
            Arch::ResNet50 => 102.23,
            Arch::MobileNetV1 => 16.93,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerType {
    Conv,
    Fc,
}

/// (name, type, shape) — weight tensors only (biases/BN excluded, as the
/// paper excludes them from DeepCABAC).
fn layer_table(arch: Arch) -> Vec<(String, LayerType, Vec<usize>)> {
    use LayerType::*;
    match arch {
        Arch::Vgg16 => {
            let convs: [(usize, usize); 13] = [
                (64, 3),
                (64, 64),
                (128, 64),
                (128, 128),
                (256, 128),
                (256, 256),
                (256, 256),
                (512, 256),
                (512, 512),
                (512, 512),
                (512, 512),
                (512, 512),
                (512, 512),
            ];
            let mut out: Vec<(String, LayerType, Vec<usize>)> = convs
                .iter()
                .enumerate()
                .map(|(i, &(o, c))| (format!("conv{}", i + 1), Conv, vec![o, c, 3, 3]))
                .collect();
            out.push(("fc6".into(), Fc, vec![25088, 4096]));
            out.push(("fc7".into(), Fc, vec![4096, 4096]));
            out.push(("fc8".into(), Fc, vec![4096, 1000]));
            out
        }
        Arch::ResNet50 => {
            let mut out = vec![("conv1".into(), Conv, vec![64usize, 3, 7, 7])];
            // bottleneck stages: (n_blocks, in, mid, out)
            let stages = [
                (3usize, 64usize, 64usize, 256usize),
                (4, 256, 128, 512),
                (6, 512, 256, 1024),
                (3, 1024, 512, 2048),
            ];
            for (si, &(blocks, stage_in, mid, stage_out)) in stages.iter().enumerate() {
                let mut cin = stage_in;
                for b in 0..blocks {
                    let p = format!("layer{}.{}", si + 1, b);
                    out.push((format!("{p}.conv1"), Conv, vec![mid, cin, 1, 1]));
                    out.push((format!("{p}.conv2"), Conv, vec![mid, mid, 3, 3]));
                    out.push((format!("{p}.conv3"), Conv, vec![stage_out, mid, 1, 1]));
                    if b == 0 {
                        out.push((
                            format!("{p}.downsample"),
                            Conv,
                            vec![stage_out, cin, 1, 1],
                        ));
                    }
                    cin = stage_out;
                }
            }
            out.push(("fc".into(), Fc, vec![2048, 1000]));
            out
        }
        Arch::MobileNetV1 => {
            let mut out = vec![("conv0".into(), Conv, vec![32usize, 3, 3, 3])];
            // (in, out, stride) depthwise-separable plan
            let plan: [(usize, usize); 13] = [
                (32, 64),
                (64, 128),
                (128, 128),
                (128, 256),
                (256, 256),
                (256, 512),
                (512, 512),
                (512, 512),
                (512, 512),
                (512, 512),
                (512, 512),
                (512, 1024),
                (1024, 1024),
            ];
            for (i, &(cin, cout)) in plan.iter().enumerate() {
                out.push((format!("dw{}", i + 1), Conv, vec![cin, 1, 3, 3]));
                out.push((format!("pw{}", i + 1), Conv, vec![cout, cin, 1, 1]));
            }
            out.push(("fc".into(), Fc, vec![1024, 1000]));
            out
        }
    }
}

#[derive(Debug)]
pub struct SynthLayer {
    pub name: String,
    pub dims: Vec<usize>,
    pub weights: Vec<f32>,
    pub sigmas: Vec<f32>,
}

#[derive(Debug)]
pub struct SynthModel {
    pub arch: Arch,
    pub layers: Vec<SynthLayer>,
}

impl SynthModel {
    pub fn weight_count(&self) -> usize {
        self.layers.iter().map(|l| l.weights.len()).sum()
    }

    pub fn raw_bytes(&self) -> usize {
        self.weight_count() * 4
    }

    pub fn density(&self) -> f64 {
        let nz: usize = self
            .layers
            .iter()
            .map(|l| l.weights.iter().filter(|&&w| w != 0.0).count())
            .sum();
        nz as f64 / self.weight_count().max(1) as f64
    }

    /// Wrap the synthetic tensors into a [`crate::model::Model`]
    /// (synthetic manifest, empty biases — the paper excludes biases
    /// from DeepCABAC anyway) so the sweep engine and the whole-model
    /// pipeline APIs can drive synthetic architectures directly. This
    /// is the *only* compression route for synthetic rows: the `sweep`
    /// CLI's `--arch` mode and `app::table1_large_row` both go through
    /// here onto the (S × λ) engine instead of ad-hoc per-layer loops.
    pub fn to_model(&self) -> crate::model::Model {
        use crate::model::manifest::{LayerInfo, LayerKind, ModelManifest};
        use crate::tensor::Tensor;
        let mut weights = Vec::with_capacity(self.layers.len());
        let mut sigmas = Vec::with_capacity(self.layers.len());
        let mut biases = Vec::with_capacity(self.layers.len());
        let mut layers = Vec::with_capacity(self.layers.len());
        for l in &self.layers {
            let n = l.weights.len();
            layers.push(LayerInfo {
                name: l.name.clone(),
                kind: if l.dims.len() == 4 { LayerKind::Conv } else { LayerKind::Fc },
                shape: l.dims.clone(),
                activation: None,
                stride: 1,
                padding: 0,
                nonzero: l.weights.iter().filter(|&&w| w != 0.0).count(),
                size: n,
            });
            weights.push(Tensor::new(l.dims.clone(), l.weights.clone()));
            sigmas.push(Tensor::new(l.dims.clone(), l.sigmas.clone()));
            biases.push(Tensor::new(vec![0], vec![]));
        }
        crate::model::Model {
            manifest: ModelManifest {
                name: self.arch.name().to_string(),
                task: "synthetic".to_string(),
                input_shape: vec![3, 224, 224],
                eval_batch: 1,
                n_classes: 1000,
                param_count: self.weight_count(),
                density: self.density(),
                dense_metric: 0.0,
                sparse_metric: 0.0,
                layers,
                hlo: "none".to_string(),
                arg_order: vec![],
            },
            weights,
            biases,
            sigmas,
        }
    }
}

/// Generate a synthetic model. `scale ≥ 1` divides every channel/feature
/// dimension (param count shrinks ~ scale²) so the full sweep stays
/// tractable on small machines; `scale = 1` is the true size.
pub fn generate(arch: Arch, scale: usize, seed: u64) -> SynthModel {
    let scale = scale.max(1);
    let table = layer_table(arch);
    // Solve per-type densities: fc prunes ~5x harder than conv, subject to
    // hitting the paper's global density exactly.
    let (mut n_conv, mut n_fc) = (0usize, 0usize);
    for (_, t, dims) in &table {
        let n: usize = scaled_dims(dims, scale, *t).iter().product();
        match t {
            LayerType::Conv => n_conv += n,
            LayerType::Fc => n_fc += n,
        }
    }
    let target = arch.paper_density();
    // d_fc = d_conv / 5  (Han-style fc-heavy pruning), global constraint:
    // (n_conv·d_conv + n_fc·d_conv/5) / (n_conv + n_fc) = target
    let total = (n_conv + n_fc) as f64;
    let mut d_conv = target * total / (n_conv as f64 + n_fc as f64 / 5.0);
    let mut d_fc = d_conv / 5.0;
    // guard: clamp into (0, 1]
    if d_conv > 1.0 {
        // dominate-fc case (mobilenet has tiny fc): push excess into fc
        d_conv = 1.0f64.min(d_conv);
        d_fc = ((target * total) - n_conv as f64 * d_conv) / n_fc as f64;
        d_fc = d_fc.clamp(0.0, 1.0);
    }

    let mut rng = SplitMix64::new(seed ^ 0xD5EEB);
    let mut layers = Vec::with_capacity(table.len());
    for (name, ty, dims) in table {
        let dims = scaled_dims(&dims, scale, ty);
        let n: usize = dims.iter().product();
        let fan_in: usize = match ty {
            LayerType::Conv => dims[1..].iter().product(),
            LayerType::Fc => dims[0],
        };
        let b = (2.0 / fan_in as f64).sqrt() / std::f64::consts::SQRT_2; // Laplace b with He variance
        let density = match ty {
            LayerType::Conv => d_conv,
            LayerType::Fc => d_fc,
        };
        // magnitude pruning keeps the tails: threshold at the density
        // quantile of |Laplace| = -b·ln(density)
        let thresh = -b * density.max(1e-9).ln();
        let mut weights = vec![0.0f32; n];
        let mut sigmas = vec![0.0f32; n];
        for i in 0..n {
            let keep = rng.next_f64() < density;
            if keep {
                // Laplace tail beyond `thresh`: memorylessness of the
                // exponential makes this exact.
                let mag = thresh + rng.laplace(b).abs();
                let sign = if rng.next_u64() & 1 == 0 { 1.0 } else { -1.0 };
                weights[i] = (sign * mag) as f32;
                // VD posterior width scales with the weight magnitude
                // (log-uniform prior ⇒ roughly constant relative width);
                // survivors of pruning sit at 5–20% relative uncertainty.
                let rel = 0.05 + 0.15 * rng.next_f64();
                sigmas[i] = (rel * mag) as f32;
            } else {
                // Pruned weights have *wide* posteriors (that is exactly
                // why VD/pruning decided they were expendable): order of
                // the pruning threshold, not orders below it.
                let rel = 0.5 + 0.5 * rng.next_f64();
                sigmas[i] = (rel * thresh.max(0.1 * b)) as f32;
            }
        }
        layers.push(SynthLayer { name, dims, weights, sigmas });
    }
    SynthModel { arch, layers }
}

fn scaled_dims(dims: &[usize], scale: usize, ty: LayerType) -> Vec<usize> {
    if scale == 1 {
        return dims.to_vec();
    }
    match ty {
        LayerType::Conv => {
            // scale channel dims (first two), keep kernel dims; never
            // shrink the RGB input channel.
            let mut d = dims.to_vec();
            d[0] = (d[0] / scale).max(1);
            if d[1] > 3 {
                d[1] = (d[1] / scale).max(1);
            }
            d
        }
        LayerType::Fc => {
            let mut d = dims.to_vec();
            d[0] = (d[0] / scale).max(1);
            d[1] = (d[1] / scale).max(1);
            d
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn true_shapes_match_paper_sizes() {
        // param count × 4 bytes ≈ Table 1 "Org. size" (±2% — the paper
        // includes biases/BN we exclude)
        for arch in [Arch::Vgg16, Arch::ResNet50, Arch::MobileNetV1] {
            let n: usize = layer_table(arch)
                .iter()
                .map(|(_, _, d)| d.iter().product::<usize>())
                .sum();
            let mb = n as f64 * 4.0 / 1e6;
            let paper = arch.paper_size_mb();
            let rel = (mb - paper).abs() / paper;
            assert!(rel < 0.02, "{}: {mb:.2} MB vs paper {paper} MB", arch.name());
        }
    }

    #[test]
    fn density_hits_paper_target() {
        for arch in [Arch::Vgg16, Arch::ResNet50, Arch::MobileNetV1] {
            let m = generate(arch, 8, 42);
            let got = m.density();
            let want = arch.paper_density();
            assert!(
                (got - want).abs() < 0.02,
                "{}: density {got:.4} vs target {want:.4}",
                arch.name()
            );
        }
    }

    #[test]
    fn deterministic() {
        let a = generate(Arch::MobileNetV1, 8, 7);
        let b = generate(Arch::MobileNetV1, 8, 7);
        assert_eq!(a.layers[3].weights, b.layers[3].weights);
    }

    #[test]
    fn scaling_shrinks_quadratically() {
        let full: usize = layer_table(Arch::Vgg16)
            .iter()
            .map(|(_, _, d)| d.iter().product::<usize>())
            .sum();
        let scaled = generate(Arch::Vgg16, 4, 1).weight_count();
        let ratio = full as f64 / scaled as f64;
        assert!(ratio > 8.0 && ratio < 32.0, "ratio {ratio}");
    }

    #[test]
    fn sigmas_positive() {
        let m = generate(Arch::ResNet50, 16, 3);
        for l in &m.layers {
            assert!(l.sigmas.iter().all(|&s| s > 0.0));
        }
    }

    #[test]
    fn to_model_preserves_tensors() {
        let synth = generate(Arch::MobileNetV1, 32, 7);
        let model = synth.to_model();
        assert_eq!(model.weights.len(), synth.layers.len());
        assert_eq!(model.weight_count(), synth.weight_count());
        assert!((model.density() - synth.density()).abs() < 1e-12);
        for (t, l) in model.weights.iter().zip(&synth.layers) {
            assert_eq!(t.data, l.weights);
            assert_eq!(t.shape, l.dims);
        }
        // raw size excludes biases (they are empty), matching SynthModel
        assert_eq!(model.raw_bytes(), synth.raw_bytes());
    }
}
