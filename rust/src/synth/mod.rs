//! Synthetic substitutes for assets this environment cannot provide
//! (DESIGN.md §5):
//!
//! * [`bigmodel`] — ImageNet-scale weight tensors (VGG16, ResNet50,
//!   MobileNet-v1) at their **true layer shapes**, with spike-and-slab
//!   statistics calibrated to the paper's reported sparsities. The
//!   compression-ratio columns of Table 1 depend only on the statistics
//!   of the quantized levels, which these match; accuracy columns for
//!   these rows are N/A (no ImageNet).

pub mod bigmodel;

pub use bigmodel::{generate, Arch, SynthLayer, SynthModel};
