//! Han-style sparse format (Deep Compression §3): nonzero values plus
//! relative zero-run indices capped at 2^run_bits − 1 (longer gaps insert
//! a filler zero), optionally Huffman-coding both streams.

use super::huffman;
use crate::bitstream::{read_varint, write_varint, BitReader, BitWriter};
use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, Copy)]
pub struct CsrConfig {
    /// Bits per relative index (Deep Compression uses 4-8).
    pub run_bits: u32,
    /// Huffman-code the value & run streams (vs raw fixed-length).
    pub huffman: bool,
}

impl Default for CsrConfig {
    fn default() -> Self {
        Self { run_bits: 4, huffman: true }
    }
}

/// Split levels into (runs, values) with capped runs + filler zeros.
/// An entry (r, v) decodes to r zeros followed by v, so a filler entry
/// (max_run, 0) covers max_run + 1 zeros.
fn split(levels: &[i32], max_run: u32) -> (Vec<i32>, Vec<i32>) {
    let mut runs = Vec::new();
    let mut vals = Vec::new();
    let mut gap = 0u32;
    for &l in levels {
        if l == 0 {
            gap += 1;
            if gap == max_run + 1 {
                runs.push(max_run as i32);
                vals.push(0); // filler zero (counts as the +1)
                gap = 0;
            }
        } else {
            runs.push(gap as i32);
            vals.push(l);
            gap = 0;
        }
    }
    (runs, vals)
}

pub fn encode(levels: &[i32], cfg: CsrConfig) -> Result<Vec<u8>> {
    let max_run = (1u32 << cfg.run_bits) - 1;
    let (runs, vals) = split(levels, max_run);
    let mut out = Vec::new();
    write_varint(&mut out, levels.len() as u64);
    out.push(cfg.run_bits as u8);
    out.push(cfg.huffman as u8);
    write_varint(&mut out, vals.len() as u64);
    if cfg.huffman {
        let rb = huffman::encode(&runs)?;
        let vb = huffman::encode(&vals)?;
        write_varint(&mut out, rb.len() as u64);
        out.extend_from_slice(&rb);
        write_varint(&mut out, vb.len() as u64);
        out.extend_from_slice(&vb);
    } else {
        let mut w = BitWriter::new();
        for &r in &runs {
            w.put_bits(r as u32, cfg.run_bits);
        }
        let max_abs = vals.iter().map(|v| v.unsigned_abs()).max().unwrap_or(0);
        let vbits = super::fixed::bits_per_symbol(max_abs);
        write_varint(&mut out, max_abs as u64);
        for &v in &vals {
            w.put_bits((v + max_abs as i32) as u32, vbits);
        }
        let payload = w.finish();
        write_varint(&mut out, payload.len() as u64);
        out.extend_from_slice(&payload);
    }
    Ok(out)
}

pub fn decode(buf: &[u8]) -> Result<Vec<i32>> {
    let mut pos = 0usize;
    let rd = |buf: &[u8], pos: &mut usize| -> Result<u64> {
        let (v, n) = read_varint(&buf[*pos..]).ok_or_else(|| anyhow!("varint"))?;
        *pos += n;
        Ok(v)
    };
    let n = rd(buf, &mut pos)? as usize;
    if n > super::MAX_DECODE_ELEMS {
        bail!("csr header claims {n} levels (limit {})", super::MAX_DECODE_ELEMS);
    }
    if pos + 2 > buf.len() {
        bail!("truncated csr header");
    }
    let run_bits = buf[pos] as u32;
    if run_bits == 0 || run_bits > 16 {
        bail!("csr run_bits {run_bits} out of range");
    }
    let use_huffman = buf[pos + 1] != 0;
    pos += 2;
    let n_vals = rd(buf, &mut pos)? as usize;
    if n_vals > n.max(1) {
        bail!("csr claims more entries ({n_vals}) than levels ({n})");
    }
    let (runs, vals) = if use_huffman {
        let rl = rd(buf, &mut pos)? as usize;
        if pos + rl > buf.len() {
            bail!("truncated csr run stream");
        }
        let runs = huffman::decode(&buf[pos..pos + rl])?;
        pos += rl;
        let vl = rd(buf, &mut pos)? as usize;
        if pos + vl > buf.len() {
            bail!("truncated csr value stream");
        }
        let vals = huffman::decode(&buf[pos..pos + vl])?;
        (runs, vals)
    } else {
        let max_abs = rd(buf, &mut pos)? as u32;
        let plen = rd(buf, &mut pos)? as usize;
        if pos + plen > buf.len() {
            bail!("truncated csr raw payload");
        }
        let vbits = super::fixed::bits_per_symbol(max_abs);
        let mut r = BitReader::new(&buf[pos..pos + plen]);
        let runs: Vec<i32> = (0..n_vals).map(|_| r.get_bits(run_bits) as i32).collect();
        let vals: Vec<i32> =
            (0..n_vals).map(|_| r.get_bits(vbits) as i32 - max_abs as i32).collect();
        (runs, vals)
    };
    if runs.len() != vals.len() {
        bail!("runs/vals length mismatch");
    }
    let mut out = Vec::with_capacity(n);
    for (&r, &v) in runs.iter().zip(&vals) {
        if !(0..=(1 << run_bits) - 1).contains(&r) {
            bail!("csr run {r} outside {run_bits}-bit range");
        }
        for _ in 0..r {
            out.push(0);
        }
        if out.len() < n {
            out.push(v);
        } else if v != 0 {
            bail!("csr overrun with nonzero value");
        }
    }
    while out.len() < n {
        out.push(0);
    }
    if out.len() != n {
        bail!("csr length mismatch");
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ptest;

    #[test]
    fn split_caps_runs() {
        let levels = vec![0; 40];
        let (runs, vals) = split(&levels, 15);
        assert_eq!(runs, vec![15, 15]); // 2 fillers cover 32; tail 8 zeros implicit
        assert_eq!(vals, vec![0, 0]);
    }

    #[test]
    fn roundtrip_hand() {
        for cfg in [
            CsrConfig::default(),
            CsrConfig { run_bits: 2, huffman: false },
            CsrConfig { run_bits: 8, huffman: true },
        ] {
            for levels in [
                vec![],
                vec![0; 100],
                vec![1, 0, 0, -2, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 3],
                vec![5, 5, 5],
            ] {
                let bytes = encode(&levels, cfg).unwrap();
                assert_eq!(decode(&bytes).unwrap(), levels, "cfg {cfg:?}");
            }
        }
    }

    #[test]
    fn property_roundtrip() {
        ptest::quick("csr-roundtrip", |g| {
            let levels = g.levels();
            let cfg = CsrConfig {
                run_bits: 1 + g.usize_in(0, 7) as u32,
                huffman: g.bool(),
            };
            let bytes = encode(&levels, cfg).map_err(|e| e.to_string())?;
            let got = decode(&bytes).map_err(|e| e.to_string())?;
            if got != levels {
                return Err(format!("mismatch cfg {cfg:?} n={}", levels.len()));
            }
            Ok(())
        });
    }

    #[test]
    fn beats_dense_fixed_on_sparse_data() {
        let mut rng = crate::util::SplitMix64::new(19);
        let levels: Vec<i32> = (0..50_000)
            .map(|_| if rng.next_f64() < 0.95 { 0 } else { 1 + rng.below(15) as i32 })
            .collect();
        let csr = encode(&levels, CsrConfig::default()).unwrap();
        let dense = super::super::fixed::encode(&levels);
        assert!(csr.len() < dense.len() / 2, "csr {} dense {}", csr.len(), dense.len());
    }
}
