//! Canonical scalar Huffman coder over i32 level symbols — the entropy
//! coding stage of Deep Compression (Han et al. 2015a), used as the
//! primary baseline in Table 1's parenthesised comparisons.

use crate::bitstream::{read_varint, write_varint, BitReader, BitWriter};
use anyhow::{anyhow, bail, Result};
use std::collections::{BinaryHeap, HashMap};

/// Code length assignment via the standard two-queue/heap Huffman build,
/// then canonicalization (lengths → lexicographic codes).
#[derive(Debug, Clone)]
pub struct HuffmanCode {
    /// (symbol, code length) sorted canonical order.
    pub lengths: Vec<(i32, u8)>,
    enc: HashMap<i32, (u32, u8)>, // symbol -> (code, len)
}

impl HuffmanCode {
    pub fn from_levels(levels: &[i32]) -> Result<Self> {
        let mut counts: HashMap<i32, u64> = HashMap::new();
        for &l in levels {
            *counts.entry(l).or_insert(0) += 1;
        }
        Self::from_counts(&counts)
    }

    pub fn from_counts(counts: &HashMap<i32, u64>) -> Result<Self> {
        if counts.is_empty() {
            return Ok(Self { lengths: Vec::new(), enc: HashMap::new() });
        }
        if counts.len() == 1 {
            let sym = *counts.keys().next().unwrap();
            let lengths = vec![(sym, 1u8)];
            return Ok(Self { enc: build_canonical(&lengths)?, lengths });
        }
        // node arena + heap of (Reverse(count), tie, node index)
        enum Node {
            Leaf(i32),
            Internal(usize, usize),
        }
        let mut arena: Vec<Node> = Vec::new();
        let mut heap: BinaryHeap<(std::cmp::Reverse<u64>, u64, usize)> = BinaryHeap::new();
        let mut tie = 0u64;
        let mut sorted: Vec<_> = counts.iter().collect();
        sorted.sort(); // determinism
        for (&sym, &c) in sorted {
            arena.push(Node::Leaf(sym));
            heap.push((std::cmp::Reverse(c), tie, arena.len() - 1));
            tie += 1;
        }
        while heap.len() > 1 {
            let (std::cmp::Reverse(c1), _, n1) = heap.pop().unwrap();
            let (std::cmp::Reverse(c2), _, n2) = heap.pop().unwrap();
            arena.push(Node::Internal(n1, n2));
            heap.push((std::cmp::Reverse(c1 + c2), tie, arena.len() - 1));
            tie += 1;
        }
        let (_, _, root) = heap.pop().unwrap();
        let mut lengths = Vec::new();
        fn walk(arena: &[Node], n: usize, depth: u8, out: &mut Vec<(i32, u8)>) {
            match arena[n] {
                Node::Leaf(s) => out.push((s, depth.max(1))),
                Node::Internal(a, b) => {
                    walk(arena, a, depth + 1, out);
                    walk(arena, b, depth + 1, out);
                }
            }
        }
        walk(&arena, root, 0, &mut lengths);
        // canonical ordering: by (length, symbol)
        lengths.sort_by_key(|&(s, l)| (l, s));
        Ok(Self { enc: build_canonical(&lengths)?, lengths })
    }

    pub fn code_for(&self, sym: i32) -> Option<(u32, u8)> {
        self.enc.get(&sym).copied()
    }

    /// Average code length under the given counts (bits/symbol).
    pub fn avg_bits(&self, counts: &HashMap<i32, u64>) -> f64 {
        let total: u64 = counts.values().sum();
        if total == 0 {
            return 0.0;
        }
        counts
            .iter()
            .map(|(s, &c)| c as f64 * self.enc.get(s).map(|&(_, l)| l as f64).unwrap_or(0.0))
            .sum::<f64>()
            / total as f64
    }
}

fn build_canonical(lengths: &[(i32, u8)]) -> Result<HashMap<i32, (u32, u8)>> {
    let mut enc = HashMap::new();
    let mut code = 0u32;
    let mut prev_len = 0u8;
    for &(sym, len) in lengths {
        // canonical order requires nondecreasing, nonzero, bounded lengths
        if len == 0 || len > 32 || len < prev_len {
            bail!("invalid canonical code length {len} (prev {prev_len})");
        }
        code = code
            .checked_shl((len - prev_len) as u32)
            .ok_or_else(|| anyhow!("code space overflow"))?;
        enc.insert(sym, (code, len));
        code = code.checked_add(1).ok_or_else(|| anyhow!("code space overflow"))?;
        prev_len = len;
    }
    Ok(enc)
}

/// Encode levels: header (symbol table) + canonical Huffman payload.
pub fn encode(levels: &[i32]) -> Result<Vec<u8>> {
    let code = HuffmanCode::from_levels(levels)?;
    let mut out = Vec::new();
    // header: n_symbols | (zigzag sym varint, len byte)* | n_levels
    write_varint(&mut out, code.lengths.len() as u64);
    for &(sym, len) in &code.lengths {
        write_varint(&mut out, zigzag(sym));
        out.push(len);
    }
    write_varint(&mut out, levels.len() as u64);
    let mut w = BitWriter::new();
    for &l in levels {
        let (c, n) = code
            .code_for(l)
            .ok_or_else(|| anyhow!("symbol {l} missing from code"))?;
        w.put_bits(c, n as u32);
    }
    let payload = w.finish();
    write_varint(&mut out, payload.len() as u64);
    out.extend_from_slice(&payload);
    Ok(out)
}

/// Decode an [`encode`] stream.
pub fn decode(buf: &[u8]) -> Result<Vec<i32>> {
    let mut pos = 0usize;
    let rd = |buf: &[u8], pos: &mut usize| -> Result<u64> {
        let (v, n) = read_varint(&buf[*pos..]).ok_or_else(|| anyhow!("varint"))?;
        *pos += n;
        Ok(v)
    };
    let n_sym = rd(buf, &mut pos)? as usize;
    if n_sym > buf.len() {
        bail!("huffman header claims {n_sym} symbols in {} bytes", buf.len());
    }
    let mut lengths = Vec::with_capacity(n_sym);
    for _ in 0..n_sym {
        let sym = unzigzag(rd(buf, &mut pos)?);
        if pos >= buf.len() {
            bail!("truncated header");
        }
        let len = buf[pos];
        pos += 1;
        lengths.push((sym, len));
    }
    let n_levels = rd(buf, &mut pos)? as usize;
    let plen = rd(buf, &mut pos)? as usize;
    if pos + plen > buf.len() {
        bail!("truncated payload");
    }
    // every symbol consumes >= 1 bit; reject impossible level counts
    // before allocating (hostile headers)
    if n_levels > plen * 8 || n_levels > super::MAX_DECODE_ELEMS {
        bail!("huffman header claims {n_levels} levels from {plen} bytes");
    }
    let enc = build_canonical(&lengths)?;
    // decode table: (code, len) -> sym
    let dec: HashMap<(u32, u8), i32> =
        enc.iter().map(|(&s, &(c, l))| ((c, l), s)).collect();
    let max_len = lengths.iter().map(|&(_, l)| l).max().unwrap_or(0);
    let mut r = BitReader::new(&buf[pos..pos + plen]);
    let mut out = Vec::with_capacity(n_levels);
    for _ in 0..n_levels {
        let mut c = 0u32;
        let mut l = 0u8;
        loop {
            c = (c << 1) | r.get_bit();
            l += 1;
            if let Some(&sym) = dec.get(&(c, l)) {
                out.push(sym);
                break;
            }
            if l > max_len {
                bail!("invalid huffman stream");
            }
        }
    }
    Ok(out)
}

fn zigzag(v: i32) -> u64 {
    ((v << 1) ^ (v >> 31)) as u32 as u64
}

fn unzigzag(v: u64) -> i32 {
    let v = v as u32;
    ((v >> 1) as i32) ^ -((v & 1) as i32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ptest;

    #[test]
    fn roundtrip_simple() {
        for levels in [
            vec![],
            vec![0],
            vec![0, 0, 0],
            vec![1, -1, 2, -2, 0, 0, 0, 5],
            (-20..20).collect(),
        ] {
            let bytes = encode(&levels).unwrap();
            assert_eq!(decode(&bytes).unwrap(), levels);
        }
    }

    #[test]
    fn near_entropy_on_skewed_data() {
        let mut rng = crate::util::SplitMix64::new(13);
        let levels: Vec<i32> = (0..50_000)
            .map(|_| {
                if rng.next_f64() < 0.9 {
                    0
                } else {
                    1 + rng.below(7) as i32
                }
            })
            .collect();
        let bytes = encode(&levels).unwrap();
        let ent = super::super::entropy_bits(&levels) / 8.0;
        // Scalar Huffman pays the ≥1 bit/symbol floor: must be within the
        // floor but above entropy.
        let payload = bytes.len() as f64;
        assert!(payload >= ent * 0.99);
        // avg code length here ≈ 0.9·1 + 0.1·(3..4) bits ≈ 1.2–1.3 bits/sym
        assert!(payload < levels.len() as f64 * 1.6 / 8.0 + 128.0);
    }

    #[test]
    fn property_roundtrip() {
        ptest::quick("huffman-roundtrip", |g| {
            let levels = g.levels();
            let bytes = encode(&levels).map_err(|e| e.to_string())?;
            let got = decode(&bytes).map_err(|e| e.to_string())?;
            if got != levels {
                return Err(format!("mismatch on {} levels", levels.len()));
            }
            Ok(())
        });
    }

    #[test]
    fn zigzag_roundtrip() {
        for v in [0, 1, -1, i32::MAX, i32::MIN, 12345, -98765] {
            assert_eq!(unzigzag(zigzag(v)), v);
        }
    }
}
