//! Baseline coders for the Table 1 comparisons and the context-adaptivity
//! ablation:
//!
//! * [`huffman`] — canonical scalar Huffman over quantized levels (the
//!   coding stage of Deep Compression, Han et al. 2015a).
//! * [`fixed`] — fixed-length binary code (the naive floor).
//! * [`csr`] — Han-style relative-index sparse format (nonzeros + 4/8-bit
//!   zero-run codes) with optional Huffman on top.
//! * [`static_arith`] — binary arithmetic coding with *frozen* per-bin
//!   probabilities (two-pass): isolates what context adaptivity buys.
//! * [`entropy`] — empirical entropy, the scalar-coding lower bound.

pub mod csr;
pub mod fixed;
pub mod huffman;
pub mod static_arith;

use std::collections::HashMap;

/// Upper bound on decoded element counts accepted from stream headers —
/// rejects hostile varints before any allocation (268M levels ≈ 1 GiB,
/// comfortably above VGG16's 138M weights).
pub const MAX_DECODE_ELEMS: usize = 1 << 28;

/// Empirical zeroth-order entropy of a level stream, in bits/symbol.
pub fn entropy(levels: &[i32]) -> f64 {
    if levels.is_empty() {
        return 0.0;
    }
    let mut counts: HashMap<i32, u64> = HashMap::new();
    for &l in levels {
        *counts.entry(l).or_insert(0) += 1;
    }
    let n = levels.len() as f64;
    counts
        .values()
        .map(|&c| {
            let p = c as f64 / n;
            -p * p.log2()
        })
        .sum()
}

/// Total bits of the scalar-entropy lower bound.
pub fn entropy_bits(levels: &[i32]) -> f64 {
    entropy(levels) * levels.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entropy_uniform_and_constant() {
        assert_eq!(entropy(&[]), 0.0);
        assert_eq!(entropy(&[3, 3, 3, 3]), 0.0);
        let e = entropy(&[0, 1, 2, 3]);
        assert!((e - 2.0).abs() < 1e-12);
    }

    #[test]
    fn entropy_binary_skew() {
        let mut v = vec![0i32; 95];
        v.extend(vec![1i32; 5]);
        let e = entropy(&v);
        assert!((e - 0.2864).abs() < 1e-3);
    }
}
