//! Static (non-adaptive) binary arithmetic coding — the ablation that
//! isolates the value of context *adaptivity*: identical binarization to
//! DeepCABAC, but every bin type's probability is frozen to its empirical
//! frequency measured in a first pass, signalled in the header, and never
//! updated. Comparing its payload against DeepCABAC's on the same levels
//! measures what the adaptive models buy (paper §2's motivation).

use crate::bitstream::{read_varint, write_varint};
use crate::cabac::{CabacDecoder, CabacEncoder, ContextModel};
use crate::codec::{CodecConfig, RemainderMode};
use anyhow::{anyhow, bail, Result};

/// Nearest M-coder state to a target probability-of-one; the encoder and
/// decoder both clamp the context there and never transition (we emulate
/// "no adaptation" by resetting the state after every bin).
fn state_for_p_one(p1: f64) -> ContextModel {
    let (mps, p_lps) = if p1 <= 0.5 { (0u8, p1) } else { (1u8, 1.0 - p1) };
    // p_lps = 0.5 * alpha^s  =>  s = log(p_lps / 0.5) / log(alpha)
    let mut best = 0u8;
    let mut best_err = f64::INFINITY;
    for s in 0..63u8 {
        let err = (crate::cabac::tables::p_lps(s) - p_lps).abs();
        if err < best_err {
            best_err = err;
            best = s;
        }
    }
    ContextModel { state: best, mps }
}

struct BinCounter {
    ones: u64,
    total: u64,
}

impl BinCounter {
    fn p_one(&self) -> f64 {
        if self.total == 0 {
            0.5
        } else {
            (self.ones as f64 + 0.5) / (self.total as f64 + 1.0)
        }
    }
}

/// First pass: count each bin type's ones under the DeepCABAC binarization.
fn count_bins(levels: &[i32], cfg: &CodecConfig) -> Vec<BinCounter> {
    // bins: [sig, sign, gr1..grN]
    let n = cfg.n_abs_flags as usize;
    let mut counters: Vec<BinCounter> =
        (0..2 + n).map(|_| BinCounter { ones: 0, total: 0 }).collect();
    for &l in levels {
        let sig = l != 0;
        counters[0].total += 1;
        counters[0].ones += sig as u64;
        if sig {
            counters[1].total += 1;
            counters[1].ones += (l < 0) as u64;
            let abs = l.unsigned_abs();
            let mut i = 1u32;
            while i <= cfg.n_abs_flags {
                let greater = abs > i;
                counters[1 + i as usize].total += 1;
                counters[1 + i as usize].ones += greater as u64;
                if !greater {
                    break;
                }
                i += 1;
            }
        }
    }
    counters
}

pub fn encode(levels: &[i32], cfg: CodecConfig) -> Result<Vec<u8>> {
    let counters = count_bins(levels, &cfg);
    let models: Vec<ContextModel> =
        counters.iter().map(|c| state_for_p_one(c.p_one())).collect();
    let mut out = Vec::new();
    write_varint(&mut out, levels.len() as u64);
    out.push(cfg.n_abs_flags as u8);
    out.push(cfg.remainder.tag());
    out.push(cfg.remainder.param() as u8);
    write_varint(&mut out, models.len() as u64);
    for m in &models {
        out.push(m.state);
        out.push(m.mps);
    }
    let mut enc = CabacEncoder::new();
    for &l in levels {
        encode_one(&mut enc, &models, &cfg, l);
    }
    let payload = enc.finish();
    write_varint(&mut out, payload.len() as u64);
    out.extend_from_slice(&payload);
    Ok(out)
}

fn encode_one(enc: &mut CabacEncoder, models: &[ContextModel], cfg: &CodecConfig, l: i32) {
    let sig = l != 0;
    let mut m = models[0];
    enc.encode(&mut m, sig as u8); // m is a copy: state never persists
    if sig {
        let mut m = models[1];
        enc.encode(&mut m, (l < 0) as u8);
        let abs = l.unsigned_abs();
        let mut i = 1u32;
        while i <= cfg.n_abs_flags {
            let greater = abs > i;
            let mut m = models[1 + i as usize];
            enc.encode(&mut m, greater as u8);
            if !greater {
                return;
            }
            i += 1;
        }
        let rem = abs - cfg.n_abs_flags - 1;
        match cfg.remainder {
            RemainderMode::FixedLength(w) => enc.encode_bypass_bits(rem, w),
            RemainderMode::ExpGolomb(k) => enc.encode_bypass_eg(rem, k),
        }
    }
}

pub fn decode(buf: &[u8]) -> Result<Vec<i32>> {
    let mut pos = 0usize;
    let rd = |buf: &[u8], pos: &mut usize| -> Result<u64> {
        let (v, n) = read_varint(&buf[*pos..]).ok_or_else(|| anyhow!("varint"))?;
        *pos += n;
        Ok(v)
    };
    let n = rd(buf, &mut pos)? as usize;
    if n > super::MAX_DECODE_ELEMS {
        bail!("header claims {n} levels (limit {})", super::MAX_DECODE_ELEMS);
    }
    if pos + 3 > buf.len() {
        bail!("truncated header");
    }
    let n_abs = buf[pos] as u32;
    let remainder = RemainderMode::from_tag(buf[pos + 1], buf[pos + 2] as u32)
        .ok_or_else(|| anyhow!("bad remainder"))?;
    pos += 3;
    let n_models = rd(buf, &mut pos)? as usize;
    if pos + 2 * n_models > buf.len() {
        bail!("truncated models");
    }
    let models: Vec<ContextModel> = (0..n_models)
        .map(|i| ContextModel { state: buf[pos + 2 * i], mps: buf[pos + 2 * i + 1] })
        .collect();
    pos += 2 * n_models;
    let plen = rd(buf, &mut pos)? as usize;
    if pos + plen > buf.len() {
        bail!("truncated payload");
    }
    let mut dec = CabacDecoder::new(&buf[pos..pos + plen]);
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let mut m = models[0];
        let sig = dec.decode(&mut m) != 0;
        if !sig {
            out.push(0);
            continue;
        }
        let mut m = models[1];
        let neg = dec.decode(&mut m) != 0;
        let mut abs = 1u32;
        let mut i = 1u32;
        while i <= n_abs {
            let mut m = models[1 + i as usize];
            if dec.decode(&mut m) == 0 {
                break;
            }
            abs += 1;
            i += 1;
        }
        if i > n_abs {
            let rem = match remainder {
                RemainderMode::FixedLength(w) => dec.decode_bypass_bits(w),
                RemainderMode::ExpGolomb(k) => dec.decode_bypass_eg(k),
            };
            abs = n_abs + 1 + rem;
        }
        out.push(if neg { -(abs as i32) } else { abs as i32 });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::encode_levels;
    use crate::util::ptest;

    #[test]
    fn property_roundtrip() {
        ptest::quick("static-arith-roundtrip", |g| {
            let levels = g.levels();
            let cfg = CodecConfig {
                n_abs_flags: 1 + g.usize_in(0, 10) as u32,
                remainder: RemainderMode::ExpGolomb(g.usize_in(0, 2) as u32),
                sig_ctx_neighbors: false,
            };
            let bytes = encode(&levels, cfg).map_err(|e| e.to_string())?;
            let got = decode(&bytes).map_err(|e| e.to_string())?;
            if got != levels {
                return Err("mismatch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn adaptive_beats_static_on_nonstationary_data() {
        // First half dense, second half sparse: the adaptive coder tracks
        // the shift, the static one pays the average.
        let mut rng = crate::util::SplitMix64::new(31);
        let mut levels = Vec::new();
        for i in 0..60_000 {
            let p = if i < 30_000 { 0.5 } else { 0.02 };
            levels.push(if rng.next_f64() < p {
                1 + rng.below(3) as i32
            } else {
                0
            });
        }
        let cfg = CodecConfig { sig_ctx_neighbors: false, ..Default::default() };
        let adaptive = encode_levels(&levels, cfg).len();
        let static_ = encode(&levels, cfg).unwrap().len();
        assert!(
            adaptive < static_,
            "adaptive {adaptive} should beat static {static_}"
        );
    }
}
