//! Fixed-length binary coding of levels — the naive floor every entropy
//! coder must beat.

use crate::bitstream::{read_varint, write_varint, BitReader, BitWriter};
use anyhow::{anyhow, bail, Result};

/// Bits needed for a symbol alphabet spanning [-max_abs, max_abs].
pub fn bits_per_symbol(max_abs: u32) -> u32 {
    if max_abs == 0 {
        return 0;
    }
    let n_symbols = 2 * max_abs as u64 + 1;
    64 - (n_symbols - 1).leading_zeros()
}

pub fn encode(levels: &[i32]) -> Vec<u8> {
    let max_abs = levels.iter().map(|l| l.unsigned_abs()).max().unwrap_or(0);
    let bps = bits_per_symbol(max_abs);
    let mut out = Vec::new();
    write_varint(&mut out, levels.len() as u64);
    write_varint(&mut out, max_abs as u64);
    let mut w = BitWriter::new();
    for &l in levels {
        w.put_bits((l + max_abs as i32) as u32, bps);
    }
    let payload = w.finish();
    out.extend_from_slice(&payload);
    out
}

pub fn decode(buf: &[u8]) -> Result<Vec<i32>> {
    let (n, used1) = read_varint(buf).ok_or_else(|| anyhow!("varint"))?;
    let (max_abs, used2) =
        read_varint(&buf[used1..]).ok_or_else(|| anyhow!("varint"))?;
    let bps = bits_per_symbol(max_abs as u32);
    let need = (n as usize * bps as usize).div_ceil(8);
    let body = &buf[used1 + used2..];
    if body.len() < need {
        bail!("truncated fixed-length payload");
    }
    let mut r = BitReader::new(body);
    Ok((0..n)
        .map(|_| r.get_bits(bps) as i32 - max_abs as i32)
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ptest;

    #[test]
    fn bps_values() {
        assert_eq!(bits_per_symbol(0), 0);
        assert_eq!(bits_per_symbol(1), 2); // {-1,0,1} -> 2 bits
        assert_eq!(bits_per_symbol(3), 3); // 7 symbols -> 3 bits
        assert_eq!(bits_per_symbol(127), 8); // 255 symbols -> 8 bits
        assert_eq!(bits_per_symbol(128), 9);
    }

    #[test]
    fn property_roundtrip() {
        ptest::quick("fixed-roundtrip", |g| {
            let levels = g.levels();
            let got = decode(&encode(&levels)).map_err(|e| e.to_string())?;
            if got != levels {
                return Err("mismatch".into());
            }
            Ok(())
        });
    }
}
