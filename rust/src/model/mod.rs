//! Model container: artifact manifests (JSON, written by
//! `python/compile/aot.py`), in-memory models, and the compressed `DCBC`
//! bitstream container.

pub mod container;
pub mod manifest;

pub use container::{
    deserialize_any, fingerprint, ChunkInfo, CompressedLayer, CompressedModel, Container,
    DeltaLayer, DeltaModel, ProgressiveModel, MAX_TIERS,
};
pub use manifest::{LayerInfo, LayerKind, ModelManifest};

use crate::tensor::{npy, Tensor};
use anyhow::{Context, Result};
use std::path::Path;

/// A loaded model: weights + biases + per-weight posterior sigmas.
#[derive(Debug, Clone)]
pub struct Model {
    pub manifest: ModelManifest,
    /// Per layer, in manifest order.
    pub weights: Vec<Tensor>,
    pub biases: Vec<Tensor>,
    pub sigmas: Vec<Tensor>,
}

impl Model {
    /// Load `artifacts/models/<name>/` as written by aot.py.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest_src = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading manifest in {dir:?}"))?;
        let manifest = ModelManifest::parse(&manifest_src)?;
        let mut weights = Vec::new();
        let mut biases = Vec::new();
        let mut sigmas = Vec::new();
        for layer in &manifest.layers {
            let (ws, wd) = npy::read_npy_f32(&dir.join(format!("{}.w.npy", layer.name)))?;
            let (bs, bd) = npy::read_npy_f32(&dir.join(format!("{}.b.npy", layer.name)))?;
            let (ss, sd) =
                npy::read_npy_f32(&dir.join(format!("{}.sigma.npy", layer.name)))?;
            // A NaN weight would silently quantize to level 0 with zero
            // recorded distortion, and NaN/Inf σ poisons the eq. 2 grid
            // statistics: fail loudly at load, naming layer and index.
            crate::tensor::validate_finite(&format!("layer {:?} weights", layer.name), &wd)?;
            crate::tensor::validate_finite(&format!("layer {:?} bias", layer.name), &bd)?;
            crate::tensor::validate_finite(&format!("layer {:?} sigma", layer.name), &sd)?;
            weights.push(Tensor::new(ws, wd));
            biases.push(Tensor::new(bs, bd));
            sigmas.push(Tensor::new(ss, sd));
        }
        Ok(Self { manifest, weights, biases, sigmas })
    }

    /// Total number of weight parameters (excluding biases).
    pub fn weight_count(&self) -> usize {
        self.weights.iter().map(|t| t.len()).sum()
    }

    /// Original (uncompressed f32) size in bytes, weights + biases — the
    /// "Org. size" column of Table 1.
    pub fn raw_bytes(&self) -> usize {
        self.weights.iter().map(|t| t.raw_bytes()).sum::<usize>()
            + self.biases.iter().map(|t| t.raw_bytes()).sum::<usize>()
    }

    /// Overall weight density |w≠0|/|w| — the "Spars." column.
    pub fn density(&self) -> f64 {
        let nz: usize = self
            .weights
            .iter()
            .map(|t| t.data.iter().filter(|&&v| v != 0.0).count())
            .sum();
        nz as f64 / self.weight_count().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_roundtrip_synthetic_dir() {
        let dir = std::env::temp_dir().join("dcbc_model_test");
        std::fs::create_dir_all(&dir).unwrap();
        let manifest = r#"{
            "name": "tiny", "task": "classify", "input_shape": [4],
            "eval_batch": 2, "n_classes": 2, "param_count": 10,
            "density": 0.5, "dense_metric": 1.0, "sparse_metric": 1.0,
            "sparsifier": "vd",
            "layers": [{"name": "fc1", "kind": "fc", "shape": [4, 2],
                        "activation": null, "stride": 1, "padding": 0,
                        "post": [], "nonzero": 4, "size": 8}],
            "hlo": "hlo/tiny.fwd.hlo.txt",
            "arg_order": ["fc1.w", "fc1.b", "eval_x"]
        }"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        npy::write_npy_f32(&dir.join("fc1.w.npy"), &[4, 2],
                           &[0.0, 1.0, -1.0, 0.0, 0.5, 0.0, 0.0, 2.0]).unwrap();
        npy::write_npy_f32(&dir.join("fc1.b.npy"), &[2], &[0.1, -0.1]).unwrap();
        npy::write_npy_f32(&dir.join("fc1.sigma.npy"), &[4, 2], &[0.1; 8]).unwrap();

        let m = Model::load(&dir).unwrap();
        assert_eq!(m.manifest.name, "tiny");
        assert_eq!(m.weight_count(), 8);
        assert!((m.density() - 0.5).abs() < 1e-12);
        assert_eq!(m.raw_bytes(), 8 * 4 + 2 * 4);
        assert_eq!(m.manifest.layers[0].kind, LayerKind::Fc);

        // regression: a NaN weight or an Inf sigma must fail the load
        // with an error naming the layer and the flat index — not load
        // silently and encode level 0 with distortion 0.0
        npy::write_npy_f32(
            &dir.join("fc1.w.npy"),
            &[4, 2],
            &[0.0, 1.0, -1.0, f32::NAN, 0.5, 0.0, 0.0, 2.0],
        )
        .unwrap();
        let err = Model::load(&dir).unwrap_err().to_string();
        assert!(err.contains("fc1"), "{err}");
        assert!(err.contains("weights[3]"), "{err}");
        assert!(err.contains("NaN"), "{err}");
        npy::write_npy_f32(&dir.join("fc1.w.npy"), &[4, 2],
                           &[0.0, 1.0, -1.0, 0.0, 0.5, 0.0, 0.0, 2.0]).unwrap();
        npy::write_npy_f32(&dir.join("fc1.sigma.npy"), &[4, 2],
                           &[0.1, 0.1, f32::INFINITY, 0.1, 0.1, 0.1, 0.1, 0.1]).unwrap();
        let err = Model::load(&dir).unwrap_err().to_string();
        assert!(err.contains("sigma[2]"), "{err}");
        npy::write_npy_f32(&dir.join("fc1.sigma.npy"), &[4, 2], &[0.1; 8]).unwrap();
        assert!(Model::load(&dir).is_ok());
    }
}
