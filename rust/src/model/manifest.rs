//! Parsing of the artifact manifests written by `python/compile/aot.py`.

use crate::util::json::Json;
use anyhow::{anyhow, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    Fc,
    Conv,
}

impl LayerKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "fc" => Ok(LayerKind::Fc),
            "conv" => Ok(LayerKind::Conv),
            other => Err(anyhow!("unknown layer kind {other}")),
        }
    }
}

#[derive(Debug, Clone)]
pub struct LayerInfo {
    pub name: String,
    pub kind: LayerKind,
    pub shape: Vec<usize>,
    pub activation: Option<String>,
    pub stride: usize,
    pub padding: usize,
    pub nonzero: usize,
    pub size: usize,
}

#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub name: String,
    pub task: String,
    pub input_shape: Vec<usize>,
    pub eval_batch: usize,
    pub n_classes: usize,
    pub param_count: usize,
    pub density: f64,
    pub dense_metric: f64,
    pub sparse_metric: f64,
    pub layers: Vec<LayerInfo>,
    /// HLO path relative to the artifacts root.
    pub hlo: String,
    pub arg_order: Vec<String>,
}

impl ModelManifest {
    pub fn parse(src: &str) -> Result<Self> {
        let j = Json::parse(src).map_err(|e| anyhow!("manifest json: {e}"))?;
        let str_field = |k: &str| -> Result<String> {
            Ok(j.get(k)
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("missing {k}"))?
                .to_string())
        };
        let num_field = |k: &str| -> Result<f64> {
            j.get(k).and_then(Json::as_f64).ok_or_else(|| anyhow!("missing {k}"))
        };
        let mut layers = Vec::new();
        for l in j
            .get("layers")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing layers"))?
        {
            layers.push(LayerInfo {
                name: l
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("layer name"))?
                    .to_string(),
                kind: LayerKind::parse(
                    l.get("kind").and_then(Json::as_str).unwrap_or("fc"),
                )?,
                shape: l
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("layer shape"))?
                    .iter()
                    .filter_map(Json::as_usize)
                    .collect(),
                activation: l
                    .get("activation")
                    .and_then(Json::as_str)
                    .map(str::to_string),
                stride: l.get("stride").and_then(Json::as_usize).unwrap_or(1),
                padding: l.get("padding").and_then(Json::as_usize).unwrap_or(0),
                nonzero: l.get("nonzero").and_then(Json::as_usize).unwrap_or(0),
                size: l.get("size").and_then(Json::as_usize).unwrap_or(0),
            });
        }
        Ok(Self {
            name: str_field("name")?,
            task: str_field("task")?,
            input_shape: j
                .get("input_shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("input_shape"))?
                .iter()
                .filter_map(Json::as_usize)
                .collect(),
            eval_batch: num_field("eval_batch")? as usize,
            n_classes: num_field("n_classes").unwrap_or(0.0) as usize,
            param_count: num_field("param_count").unwrap_or(0.0) as usize,
            density: num_field("density").unwrap_or(0.0),
            dense_metric: num_field("dense_metric").unwrap_or(0.0),
            sparse_metric: num_field("sparse_metric").unwrap_or(0.0),
            layers,
            hlo: str_field("hlo")?,
            arg_order: j
                .get("arg_order")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_str).map(str::to_string).collect())
                .unwrap_or_default(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_minimal() {
        let src = r#"{"name":"m","task":"classify","input_shape":[1,28,28],
            "eval_batch":256,"n_classes":10,"param_count":100,"density":0.1,
            "dense_metric":0.99,"sparse_metric":0.98,
            "layers":[{"name":"conv1","kind":"conv","shape":[20,1,5,5],
                       "activation":"relu","stride":1,"padding":0,"post":[],
                       "nonzero":50,"size":500}],
            "hlo":"hlo/m.fwd.hlo.txt","arg_order":["conv1.w","conv1.b","eval_x"]}"#;
        let m = ModelManifest::parse(src).unwrap();
        assert_eq!(m.layers.len(), 1);
        assert_eq!(m.layers[0].kind, LayerKind::Conv);
        assert_eq!(m.layers[0].shape, vec![20, 1, 5, 5]);
        assert_eq!(m.eval_batch, 256);
        assert_eq!(m.arg_order.len(), 3);
    }

    #[test]
    fn rejects_bad_kind() {
        let src = r#"{"name":"m","task":"c","input_shape":[1],"eval_batch":1,
            "n_classes":2,"param_count":1,"density":1,"dense_metric":1,
            "sparse_metric":1,"layers":[{"name":"x","kind":"wat","shape":[1]}],
            "hlo":"h","arg_order":[]}"#;
        assert!(ModelManifest::parse(src).is_err());
    }
}
