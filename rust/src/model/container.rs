//! The `DCBC` compressed-model container format.
//!
//! The normative wire specification — field-by-field layout, hostile
//! input guards, and the invariants the serving stack relies on — is
//! `docs/FORMAT.md` at the repository root; this module is its single
//! implementation. Layout summary:
//!
//! ```text
//! file   := "DCBC" u8 version | str name | varint n_layers | layer*
//! layer  := str name | varint ndims, dims* | f32 delta | varint S
//!           | u8 n_abs_flags | u8 rem_tag | u8 rem_param | u8 flags
//!           | chunk_table(v2 only)
//!           | varint n_weights | varint payload_len | payload bytes
//!           | varint bias_len | raw f32 bias bytes
//! chunk_table := varint n_chunks | (varint n_weights, varint bytes)*
//! ```
//!
//! Version 1 is the original single-stream layout. Version 2 adds a
//! per-layer **chunk table**: a tensor may be split into N independently
//! decodable CABAC streams (contexts reset at each chunk boundary, byte
//! offsets derivable from the table) so encode *and* decode of one giant
//! layer fan out across threads. Serialization emits v1 whenever no
//! layer is chunked, so unchunked containers are byte-identical to the
//! old format; the reader accepts both versions.
//!
//! Version 3 is a **delta segment** ([`DeltaModel`]): a residual against
//! a fingerprinted parent container, with a per-layer skip byte for
//! layers the update left untouched and version-2 layer records (chunk
//! table always present) for residual-coded layers. Full containers
//! still serialize as v1/v2, byte-for-byte unchanged; [`deserialize_any`]
//! dispatches on the version byte.
//!
//! Version 4 is a **progressive container** ([`ProgressiveModel`]): a
//! coarse base tier (version-2 layer records) followed by refinement
//! tiers of version-3 dlayer records, each tier's residuals coded
//! against the previous tier of the *same file*. A tier table in the
//! prelude gives every tier body's byte length, so a strict byte prefix
//! ending at a tier boundary is itself a complete container at that
//! tier (the "progressive truncation rule", `docs/FORMAT.md`
//! §"Progressive tiers").
//!
//! Biases (and any normalization parameters) are stored raw, as the
//! paper compresses weight tensors only.

use crate::bitstream::{read_varint, write_varint};
use crate::codec::{decode_levels, CodecConfig, RemainderMode};
use crate::quant::QuantGrid;
use anyhow::{anyhow, bail, Result};
use byteorder::{ByteOrder, LittleEndian};

pub const MAGIC: &[u8; 4] = b"DCBC";
/// Original single-stream layout.
pub const VERSION: u8 = 1;
/// Chunked layout (only emitted when some layer has > 1 chunk).
pub const VERSION_CHUNKED: u8 = 2;
/// Delta-segment layout: parent fingerprint + skip/residual layer records.
pub const VERSION_DELTA: u8 = 3;
/// Progressive layout: base tier + residual refinement tiers in one file.
pub const VERSION_PROGRESSIVE: u8 = 4;
/// Highest version byte this reader understands (named in the
/// unknown-version error so clients of newer archives get an actionable
/// message).
pub const MAX_SUPPORTED_VERSION: u8 = VERSION_PROGRESSIVE;

const FLAG_SIG_NEIGHBORS: u8 = 1;

/// Sanity cap on a progressive container's tier count (hostile-header
/// guard; normative in `docs/FORMAT.md` §"Progressive tiers").
pub const MAX_TIERS: usize = 64;

/// Sanity cap on the per-layer chunk count (hostile-header guard).
pub const MAX_CHUNKS: usize = 1 << 16;

/// Hostile-header guard on embedded strings (model/layer names).
pub const MAX_NAME_BYTES: usize = 1 << 20;

/// Hostile-header guard on tensor rank.
pub const MAX_DIMS: usize = 1 << 16;

/// One independently decodable slice of a chunked layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkInfo {
    /// Levels coded in this chunk.
    pub n_weights: usize,
    /// Payload bytes of this chunk's CABAC stream.
    pub bytes: usize,
}

/// A [`ChunkInfo`] resolved to its byte position inside a layer payload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkSpan {
    /// Byte offset of this chunk's CABAC stream within the layer payload.
    pub offset: usize,
    /// Byte length of the stream.
    pub bytes: usize,
    /// Levels coded in this chunk.
    pub n_weights: usize,
}

#[derive(Debug, Clone)]
pub struct CompressedLayer {
    pub name: String,
    pub dims: Vec<usize>,
    pub grid: QuantGrid,
    pub s_param: u32,
    pub cfg: CodecConfig,
    pub n_weights: usize,
    /// Concatenated CABAC payload (all chunks back to back).
    pub payload: Vec<u8>,
    /// Chunk table; empty or single-entry means one monolithic stream
    /// (the payload is then bit-identical to the v1 format's).
    pub chunks: Vec<ChunkInfo>,
    pub bias: Vec<f32>,
}

impl CompressedLayer {
    /// Number of independently decodable streams in this layer.
    pub fn n_chunks(&self) -> usize {
        self.chunks.len().max(1)
    }

    /// Decode the CABAC payload back into integer levels. Chunked
    /// layers decode their chunks in parallel (contexts reset per
    /// chunk, exactly as the encoder coded them).
    pub fn decode_levels(&self) -> Vec<i32> {
        let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        self.decode_levels_with(workers)
    }

    /// [`Self::decode_levels`] with an explicit worker cap.
    pub fn decode_levels_with(&self, workers: usize) -> Vec<i32> {
        let spans = self.chunk_spans();
        if spans.len() <= 1 {
            return decode_levels(&self.payload, self.n_weights, self.cfg);
        }
        let decoded = crate::util::par::map_indexed(spans.len(), workers, |i| {
            let s = spans[i];
            decode_levels(&self.payload[s.offset..s.offset + s.bytes], s.n_weights, self.cfg)
        });
        let mut levels = Vec::with_capacity(self.n_weights);
        for s in decoded {
            levels.extend_from_slice(&s);
        }
        levels
    }

    /// Byte extent of every independently decodable CABAC stream inside
    /// [`Self::payload`], in scan order — a single whole-payload span for
    /// monolithic layers. This is the random-access map the streaming
    /// decoder and the serving index are built on: each span can be
    /// handed to [`decode_levels`] on its own (contexts reset at every
    /// chunk boundary, exactly as the encoder coded them).
    pub fn chunk_spans(&self) -> Vec<ChunkSpan> {
        resolve_spans(&self.chunks, self.n_weights, self.payload.len())
    }

    /// Full reconstruction: levels × Δ.
    pub fn decode_weights(&self) -> Vec<f32> {
        self.grid.dequantize(&self.decode_levels())
    }

    /// On-disk footprint of this layer (payload + bias + header approx).
    pub fn stored_bytes(&self) -> usize {
        self.payload.len() + self.bias.len() * 4
    }
}

#[derive(Debug, Clone, Default)]
pub struct CompressedModel {
    pub name: String,
    pub layers: Vec<CompressedLayer>,
}

impl CompressedModel {
    pub fn total_bytes(&self) -> usize {
        // serialized size (exact): build lazily
        self.serialize().len()
    }

    pub fn payload_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.payload.len()).sum()
    }

    /// True if any layer carries a multi-chunk table (forces version 2).
    pub fn is_chunked(&self) -> bool {
        self.layers.iter().any(|l| l.chunks.len() > 1)
    }

    pub fn serialize(&self) -> Vec<u8> {
        let version = if self.is_chunked() { VERSION_CHUNKED } else { VERSION };
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.push(version);
        write_str(&mut out, &self.name);
        write_varint(&mut out, self.layers.len() as u64);
        for l in &self.layers {
            write_layer_body(&mut out, l, version == VERSION_CHUNKED);
        }
        out
    }

    pub fn deserialize(buf: &[u8]) -> Result<Self> {
        let (prefix, mut pos) = match parse_container_prefix(buf)? {
            Parsed::Complete(p, n) => (p, n),
            Parsed::NeedMore => bail!("truncated container prelude"),
        };
        if prefix.version == VERSION_DELTA {
            crate::fuzz::cov::edge!("batch_v3_redirect");
            bail!(
                "container is a version-3 delta segment; use deserialize_any \
                 or DeltaModel::deserialize"
            );
        }
        if prefix.version == VERSION_PROGRESSIVE {
            crate::fuzz::cov::edge!("batch_v4_redirect");
            bail!(
                "container is a version-4 progressive container; use \
                 deserialize_any or ProgressiveModel::deserialize"
            );
        }
        // cap the pre-allocation: n_layers is attacker-controlled, and a
        // 20-byte hostile prelude must not reserve megabytes up front
        let mut layers = Vec::with_capacity(prefix.n_layers.min(1 << 10));
        for _ in 0..prefix.n_layers {
            let hdr = match parse_layer_header(&buf[pos..], prefix.version)? {
                Parsed::Complete(h, n) => {
                    pos += n;
                    h
                }
                Parsed::NeedMore => bail!("truncated layer header"),
            };
            let (layer, used) = read_layer_tail(&buf[pos..], hdr)?;
            pos += used;
            layers.push(layer);
        }
        if pos != buf.len() {
            crate::fuzz::cov::edge!("batch_trailing");
            bail!("trailing bytes in container");
        }
        crate::fuzz::cov::edge!("batch_ok");
        Ok(Self { name: prefix.name, layers })
    }
}

/// Canonical container fingerprint: FNV-1a-64 over the canonical
/// serialization. This is the `parent_fp` a delta segment records and
/// the identity the serve layer's version-chain manifest is keyed on.
pub fn fingerprint(model: &CompressedModel) -> u64 {
    crate::util::fnv1a(&model.serialize())
}

/// Serialize one layer record body (everything from the layer name to the
/// bias bytes). `chunk_table` controls whether the v2/v3 chunk table is
/// emitted; v1 layers omit it.
fn write_layer_body(out: &mut Vec<u8>, l: &CompressedLayer, chunk_table: bool) {
    write_str(out, &l.name);
    write_varint(out, l.dims.len() as u64);
    for &d in &l.dims {
        write_varint(out, d as u64);
    }
    out.extend_from_slice(&l.grid.delta.to_le_bytes());
    write_varint(out, l.grid.max_level as u64);
    write_varint(out, l.s_param as u64);
    out.push(l.cfg.n_abs_flags as u8);
    out.push(l.cfg.remainder.tag());
    out.push(l.cfg.remainder.param() as u8);
    out.push(if l.cfg.sig_ctx_neighbors { FLAG_SIG_NEIGHBORS } else { 0 });
    if chunk_table {
        if l.chunks.len() > 1 {
            write_varint(out, l.chunks.len() as u64);
            for c in &l.chunks {
                write_varint(out, c.n_weights as u64);
                write_varint(out, c.bytes as u64);
            }
        } else {
            // monolithic layer inside a chunk-table-bearing container
            write_varint(out, 1);
            write_varint(out, l.n_weights as u64);
            write_varint(out, l.payload.len() as u64);
        }
    }
    write_varint(out, l.n_weights as u64);
    write_varint(out, l.payload.len() as u64);
    out.extend_from_slice(&l.payload);
    write_varint(out, l.bias.len() as u64);
    let mut bias_bytes = vec![0u8; l.bias.len() * 4];
    LittleEndian::write_f32_into(&l.bias, &mut bias_bytes);
    out.extend_from_slice(&bias_bytes);
}

/// Batch-read a layer's payload + bias given its parsed header. Returns
/// the assembled layer and the bytes consumed after the header.
fn read_layer_tail(buf: &[u8], hdr: LayerHeader) -> Result<(CompressedLayer, usize)> {
    let mut pos = 0usize;
    if hdr.payload_len > buf.len() {
        crate::fuzz::cov::edge!("tail_truncated_payload");
        bail!("truncated payload");
    }
    let payload = buf[..hdr.payload_len].to_vec();
    pos += hdr.payload_len;
    let blen = match parse_varint_prefix(&buf[pos..])? {
        Parsed::Complete(v, n) => {
            pos += n;
            v as usize
        }
        Parsed::NeedMore => {
            crate::fuzz::cov::edge!("tail_truncated_bias");
            bail!("truncated bias")
        }
    };
    if blen > crate::baselines::MAX_DECODE_ELEMS || blen * 4 > buf.len() - pos {
        crate::fuzz::cov::edge!("tail_bias_too_big");
        bail!("truncated bias");
    }
    let mut bias = vec![0f32; blen];
    LittleEndian::read_f32_into(&buf[pos..pos + blen * 4], &mut bias);
    pos += blen * 4;
    Ok((
        CompressedLayer {
            name: hdr.name,
            dims: hdr.dims,
            grid: hdr.grid,
            s_param: hdr.s_param,
            cfg: hdr.cfg,
            n_weights: hdr.n_weights,
            payload,
            chunks: hdr.chunks,
            bias,
        },
        pos,
    ))
}

/// One layer of a [`DeltaModel`].
#[derive(Debug, Clone)]
pub enum DeltaLayer {
    /// The target layer is byte-identical to the parent layer at this
    /// position; only the (matching) name is recorded on the wire.
    Skipped(String),
    /// Residual-coded layer. The header fields (dims, grid, codec config,
    /// bias) are the *target* layer's; the payload codes the residual
    /// levels `R = L_target − P` against the parent quantized onto the
    /// target grid (see `docs/FORMAT.md` §"Delta segments").
    Coded(CompressedLayer),
}

impl DeltaLayer {
    /// Layer name (skipped or coded).
    pub fn name(&self) -> &str {
        match self {
            DeltaLayer::Skipped(n) => n,
            DeltaLayer::Coded(l) => &l.name,
        }
    }
}

/// A version-3 `.dcbc` delta segment: the difference between a
/// fingerprinted parent container and a target container, applied with
/// [`crate::delta::apply`].
#[derive(Debug, Clone)]
pub struct DeltaModel {
    /// [`fingerprint`] of the parent container this delta applies to.
    pub parent_fp: u64,
    /// Target model name.
    pub name: String,
    pub layers: Vec<DeltaLayer>,
}

impl DeltaModel {
    /// Serialized size of the delta segment.
    pub fn total_bytes(&self) -> usize {
        self.serialize().len()
    }

    /// Residual payload bytes across coded layers.
    pub fn payload_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| match l {
                DeltaLayer::Skipped(_) => 0,
                DeltaLayer::Coded(c) => c.payload.len(),
            })
            .sum()
    }

    /// Number of layers the delta re-codes (non-skipped).
    pub fn coded_layers(&self) -> usize {
        self.layers.iter().filter(|l| matches!(l, DeltaLayer::Coded(_))).count()
    }

    pub fn serialize(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.push(VERSION_DELTA);
        out.extend_from_slice(&self.parent_fp.to_le_bytes());
        write_str(&mut out, &self.name);
        write_varint(&mut out, self.layers.len() as u64);
        for l in &self.layers {
            match l {
                DeltaLayer::Skipped(name) => {
                    out.push(1);
                    write_str(&mut out, name);
                }
                DeltaLayer::Coded(layer) => {
                    out.push(0);
                    write_layer_body(&mut out, layer, true);
                }
            }
        }
        out
    }

    pub fn deserialize(buf: &[u8]) -> Result<Self> {
        let (prefix, mut pos) = match parse_container_prefix(buf)? {
            Parsed::Complete(p, n) => (p, n),
            Parsed::NeedMore => bail!("truncated container prelude"),
        };
        if prefix.version != VERSION_DELTA {
            crate::fuzz::cov::edge!("v3_wrong_version");
            bail!("not a delta segment (version {})", prefix.version);
        }
        let parent_fp = prefix.parent_fp.expect("v3 prelude carries a fingerprint");
        let mut layers = Vec::with_capacity(prefix.n_layers.min(1 << 10));
        for _ in 0..prefix.n_layers {
            let hdr = match parse_layer_header(&buf[pos..], VERSION_DELTA)? {
                Parsed::Complete(h, n) => {
                    pos += n;
                    h
                }
                Parsed::NeedMore => bail!("truncated layer header"),
            };
            if hdr.skipped {
                layers.push(DeltaLayer::Skipped(hdr.name));
                continue;
            }
            let (layer, used) = read_layer_tail(&buf[pos..], hdr)?;
            pos += used;
            layers.push(DeltaLayer::Coded(layer));
        }
        if pos != buf.len() {
            crate::fuzz::cov::edge!("v3_trailing");
            bail!("trailing bytes in container");
        }
        crate::fuzz::cov::edge!("v3_ok");
        Ok(Self { parent_fp, name: prefix.name, layers })
    }
}

/// A version-4 `.dcbc` progressive container: a coarse base tier plus
/// refinement tiers, each refining the previous tier of the same file
/// with the v3 residual algebra. `refinements.len() + 1` is the tier
/// count; every refinement holds exactly `base.len()` dlayers.
///
/// Deliberately does NOT record the tier count the file *declared*:
/// a prefix accepted under the progressive truncation rule
/// canonicalizes to a smaller complete container (the documented
/// exception to the byte round-trip invariant — serialization stays
/// idempotent).
#[derive(Debug, Clone)]
pub struct ProgressiveModel {
    pub name: String,
    /// Tier 0: a complete coarse model (version-2 layer records).
    pub base: Vec<CompressedLayer>,
    /// Tiers 1..: per-layer residuals against the previous tier.
    pub refinements: Vec<Vec<DeltaLayer>>,
}

impl ProgressiveModel {
    /// Number of tiers in the file (base included).
    pub fn n_tiers(&self) -> usize {
        1 + self.refinements.len()
    }

    /// Serialized size of the whole progressive container.
    pub fn total_bytes(&self) -> usize {
        self.serialize().len()
    }

    /// Serialized byte length of every tier body, in tier order. The
    /// absolute end of tier `t`'s byte prefix is
    /// `prelude_len + Σ tier_body_lens[0..=t]`.
    pub fn tier_body_lens(&self) -> Vec<usize> {
        self.tier_bodies().iter().map(|b| b.len()).collect()
    }

    fn tier_bodies(&self) -> Vec<Vec<u8>> {
        let mut bodies = Vec::with_capacity(self.n_tiers());
        let mut body = Vec::new();
        for l in &self.base {
            write_layer_body(&mut body, l, true);
        }
        bodies.push(std::mem::take(&mut body));
        for tier in &self.refinements {
            for l in tier {
                match l {
                    DeltaLayer::Skipped(name) => {
                        body.push(1);
                        write_str(&mut body, name);
                    }
                    DeltaLayer::Coded(layer) => {
                        body.push(0);
                        write_layer_body(&mut body, layer, true);
                    }
                }
            }
            bodies.push(std::mem::take(&mut body));
        }
        bodies
    }

    pub fn serialize(&self) -> Vec<u8> {
        let bodies = self.tier_bodies();
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.push(VERSION_PROGRESSIVE);
        write_str(&mut out, &self.name);
        write_varint(&mut out, self.base.len() as u64);
        write_varint(&mut out, bodies.len() as u64);
        for b in &bodies {
            write_varint(&mut out, b.len() as u64);
        }
        for b in &bodies {
            out.extend_from_slice(b);
        }
        out
    }

    pub fn deserialize(buf: &[u8]) -> Result<Self> {
        let (prefix, mut pos) = match parse_container_prefix(buf)? {
            Parsed::Complete(p, n) => (p, n),
            Parsed::NeedMore => bail!("truncated container prelude"),
        };
        if prefix.version != VERSION_PROGRESSIVE {
            crate::fuzz::cov::edge!("v4_wrong_version");
            bail!("not a progressive container (version {})", prefix.version);
        }
        let tier_lens = &prefix.tier_lens;
        let mut base = Vec::with_capacity(prefix.n_layers.min(1 << 10));
        let tier_start = pos;
        for _ in 0..prefix.n_layers {
            let hdr = match parse_layer_header(&buf[pos..], VERSION_CHUNKED)? {
                Parsed::Complete(h, n) => {
                    pos += n;
                    h
                }
                Parsed::NeedMore => bail!("truncated layer header"),
            };
            let (layer, used) = read_layer_tail(&buf[pos..], hdr)?;
            pos += used;
            base.push(layer);
        }
        if (pos - tier_start) as u64 != tier_lens[0] {
            crate::fuzz::cov::edge!("v4_tier0_span");
            bail!(
                "tier 0 body is {} bytes but the tier table declares {}",
                pos - tier_start,
                tier_lens[0]
            );
        }
        let mut refinements = Vec::new();
        for (t, &tlen) in tier_lens.iter().enumerate().skip(1) {
            if pos == buf.len() {
                // progressive truncation rule: EOF exactly at a tier-body
                // boundary is a complete container at the preceding tier
                crate::fuzz::cov::edge!("v4_truncated_tier");
                break;
            }
            let tier_start = pos;
            let mut layers = Vec::with_capacity(prefix.n_layers.min(1 << 10));
            for _ in 0..prefix.n_layers {
                let hdr = match parse_layer_header(&buf[pos..], VERSION_DELTA)? {
                    Parsed::Complete(h, n) => {
                        pos += n;
                        h
                    }
                    Parsed::NeedMore => bail!("truncated layer header"),
                };
                if hdr.skipped {
                    layers.push(DeltaLayer::Skipped(hdr.name));
                    continue;
                }
                let (layer, used) = read_layer_tail(&buf[pos..], hdr)?;
                pos += used;
                layers.push(DeltaLayer::Coded(layer));
            }
            if (pos - tier_start) as u64 != tlen {
                crate::fuzz::cov::edge!("v4_tier_span");
                bail!(
                    "tier {t} body is {} bytes but the tier table declares {tlen}",
                    pos - tier_start
                );
            }
            refinements.push(layers);
        }
        if pos != buf.len() {
            crate::fuzz::cov::edge!("v4_trailing");
            bail!("trailing bytes in container");
        }
        crate::fuzz::cov::edge!("v4_ok");
        Ok(Self { name: prefix.name, base, refinements })
    }
}

/// Any `.dcbc` file: a full container (v1/v2), a delta segment (v3) or
/// a progressive container (v4).
#[derive(Debug, Clone)]
pub enum Container {
    Full(CompressedModel),
    Delta(DeltaModel),
    Progressive(ProgressiveModel),
}

/// Deserialize any `.dcbc` version, dispatching on the version byte.
pub fn deserialize_any(buf: &[u8]) -> Result<Container> {
    if buf.len() >= 5 && &buf[..4] == MAGIC && buf[4] == VERSION_DELTA {
        DeltaModel::deserialize(buf).map(Container::Delta)
    } else if buf.len() >= 5 && &buf[..4] == MAGIC && buf[4] == VERSION_PROGRESSIVE {
        ProgressiveModel::deserialize(buf).map(Container::Progressive)
    } else {
        CompressedModel::deserialize(buf).map(Container::Full)
    }
}

// ---------------------------------------------------------------------------
// Incremental (resumable) container parsing
//
// Everything below parses container structures out of a byte *prefix*, so
// both the batch [`CompressedModel::deserialize`] above and the push-based
// streaming decoder (`serve::stream`) and random-access index
// (`serve::index`) share one definition of the format. `NeedMore` always
// means "this is a valid start of a container — feed more bytes";
// structural corruption is an `Err`.
// ---------------------------------------------------------------------------

/// Outcome of parsing a structure from a byte prefix.
#[derive(Debug)]
pub enum Parsed<T> {
    /// Parsed successfully; `.1` is the number of bytes consumed.
    Complete(T, usize),
    /// Valid so far, but the structure is not complete yet.
    NeedMore,
}

/// Container prelude: magic, version, model name and layer count —
/// plus the parent fingerprint for version-3 delta segments and the
/// tier table for version-4 progressive containers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ContainerPrefix {
    pub version: u8,
    pub name: String,
    pub n_layers: usize,
    /// `Some` iff `version == VERSION_DELTA`.
    pub parent_fp: Option<u64>,
    /// Declared tier-body byte lengths; non-empty iff
    /// `version == VERSION_PROGRESSIVE` (then `1 ≤ len ≤ MAX_TIERS`).
    pub tier_lens: Vec<u64>,
}

/// Everything in a layer record before the payload bytes, plus the payload
/// length — enough to locate and independently decode every chunk without
/// touching the rest of the container.
#[derive(Debug, Clone)]
pub struct LayerHeader {
    pub name: String,
    pub dims: Vec<usize>,
    pub grid: QuantGrid,
    pub s_param: u32,
    pub cfg: CodecConfig,
    /// Canonicalized like [`CompressedLayer::chunks`]: empty = monolithic.
    pub chunks: Vec<ChunkInfo>,
    pub n_weights: usize,
    pub payload_len: usize,
    /// Version-3 skip record: the layer is untouched by the delta. Only
    /// `name` is meaningful; there is no payload and no bias on the wire.
    pub skipped: bool,
}

impl LayerHeader {
    /// Chunk extents relative to the start of this layer's payload
    /// (mirror of [`CompressedLayer::chunk_spans`]; always ≥ 1 span).
    pub fn chunk_spans(&self) -> Vec<ChunkSpan> {
        resolve_spans(&self.chunks, self.n_weights, self.payload_len)
    }
}

fn resolve_spans(chunks: &[ChunkInfo], n_weights: usize, payload_len: usize) -> Vec<ChunkSpan> {
    if chunks.len() <= 1 {
        return vec![ChunkSpan { offset: 0, bytes: payload_len, n_weights }];
    }
    let mut spans = Vec::with_capacity(chunks.len());
    let mut off = 0usize;
    for c in chunks {
        spans.push(ChunkSpan { offset: off, bytes: c.bytes, n_weights: c.n_weights });
        off += c.bytes;
    }
    debug_assert_eq!(off, payload_len);
    spans
}

/// Prefix-parsing cursor: every accessor returns `Ok(None)` when it runs
/// out of bytes (resume later with a longer prefix) and `Err` only on
/// structurally invalid input.
struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn varint(&mut self) -> Result<Option<u64>> {
        match read_varint(&self.buf[self.pos..]) {
            Some((v, n)) => {
                self.pos += n;
                Ok(Some(v))
            }
            // 10 bytes always decide a u64 varint — still undecided means
            // an overlong encoding, not a short buffer
            None if self.buf.len() - self.pos >= 10 => {
                crate::fuzz::cov::edge!("varint_overlong");
                bail!("malformed varint")
            }
            None => Ok(None),
        }
    }

    fn take(&mut self, n: usize) -> Option<&'a [u8]> {
        if self.buf.len() - self.pos < n {
            return None;
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Some(s)
    }

    fn string(&mut self, what: &str) -> Result<Option<String>> {
        let Some(len) = self.varint()? else { return Ok(None) };
        if len as usize > MAX_NAME_BYTES {
            crate::fuzz::cov::edge!("string_too_long");
            bail!("{what} claims {len} bytes (hostile header?)");
        }
        let Some(bytes) = self.take(len as usize) else { return Ok(None) };
        Ok(Some(std::str::from_utf8(bytes)?.to_string()))
    }
}

/// Grabs a cursor accessor's value or reports `NeedMore` to the caller.
macro_rules! need {
    ($e:expr) => {
        match $e {
            Some(v) => v,
            None => return Ok(Parsed::NeedMore),
        }
    };
}

/// Parse the container prelude from a byte prefix.
pub fn parse_container_prefix(buf: &[u8]) -> Result<Parsed<ContainerPrefix>> {
    // reject a wrong magic as early as the bytes allow
    let probe = buf.len().min(4);
    if buf[..probe] != MAGIC[..probe] {
        crate::fuzz::cov::edge!("prefix_bad_magic");
        bail!("not a DCBC container");
    }
    if buf.len() < 5 {
        crate::fuzz::cov::edge!("prefix_short");
        return Ok(Parsed::NeedMore);
    }
    let version = buf[4];
    if version < VERSION || version > MAX_SUPPORTED_VERSION {
        crate::fuzz::cov::edge!("prefix_bad_version");
        bail!(
            "unsupported DCBC version {version} (this reader supports \
             versions {VERSION}..={MAX_SUPPORTED_VERSION})"
        );
    }
    let mut cur = Cur { buf, pos: 5 };
    let parent_fp = if version == VERSION_DELTA {
        crate::fuzz::cov::edge!("prefix_v3_fp");
        Some(u64::from_le_bytes(need!(cur.take(8)).try_into().unwrap()))
    } else {
        None
    };
    let name = need!(cur.string("model name")?);
    let n_layers = need!(cur.varint()?) as usize;
    let mut tier_lens = Vec::new();
    if version == VERSION_PROGRESSIVE {
        let n_tiers = need!(cur.varint()?) as usize;
        if n_tiers == 0 || n_tiers > MAX_TIERS {
            crate::fuzz::cov::edge!("prefix_bad_tiers");
            bail!("progressive container claims {n_tiers} tiers (hostile header?)");
        }
        tier_lens.reserve(n_tiers);
        let mut total = 0u64;
        for _ in 0..n_tiers {
            let len = need!(cur.varint()?);
            crate::fuzz::cov::edge!("prefix_tier_len");
            total = total.checked_add(len).ok_or_else(|| {
                crate::fuzz::cov::edge!("prefix_tier_overflow");
                anyhow!("tier table byte-length overflow")
            })?;
            tier_lens.push(len);
        }
        // the whole file must stay addressable on this platform
        if total > usize::MAX as u64 {
            crate::fuzz::cov::edge!("prefix_tier_overflow");
            bail!("tier table byte-length overflow");
        }
    }
    crate::fuzz::cov::edge!("prefix_ok");
    Ok(Parsed::Complete(
        ContainerPrefix { version, name, n_layers, parent_fp, tier_lens },
        cur.pos,
    ))
}

/// Parse one layer header (everything before the payload bytes) from a
/// byte prefix starting at the layer record.
pub fn parse_layer_header(buf: &[u8], version: u8) -> Result<Parsed<LayerHeader>> {
    let mut cur = Cur { buf, pos: 0 };
    if version == VERSION_DELTA {
        let skip = need!(cur.take(1))[0];
        match skip {
            0 => {
                crate::fuzz::cov::edge!("dlayer_coded");
            }
            1 => {
                crate::fuzz::cov::edge!("dlayer_skip");
                let name = need!(cur.string("layer name")?);
                return Ok(Parsed::Complete(
                    LayerHeader {
                        name,
                        dims: Vec::new(),
                        grid: QuantGrid { delta: 0.0, max_level: 0 },
                        s_param: 0,
                        cfg: CodecConfig::default(),
                        chunks: Vec::new(),
                        n_weights: 0,
                        payload_len: 0,
                        skipped: true,
                    },
                    cur.pos,
                ));
            }
            v => {
                crate::fuzz::cov::edge!("dlayer_bad_flag");
                bail!("bad delta skip flag {v}")
            }
        }
    }
    let name = need!(cur.string("layer name")?);
    let ndims = need!(cur.varint()?) as usize;
    if ndims > MAX_DIMS {
        crate::fuzz::cov::edge!("layer_bad_rank");
        bail!("layer claims rank {ndims} (hostile header?)");
    }
    let mut dims = Vec::with_capacity(ndims.min(1 << 8));
    for _ in 0..ndims {
        dims.push(need!(cur.varint()?) as usize);
    }
    let delta = f32::from_le_bytes(need!(cur.take(4)).try_into().unwrap());
    let max_level = need!(cur.varint()?) as i32;
    let s_param = need!(cur.varint()?) as u32;
    let params = need!(cur.take(4));
    let (n_abs_flags, rem_tag, rem_param, flags) =
        (params[0] as u32, params[1], params[2] as u32, params[3]);
    let remainder = RemainderMode::from_tag(rem_tag, rem_param).ok_or_else(|| {
        crate::fuzz::cov::edge!("layer_bad_remainder");
        anyhow!("bad remainder tag {rem_tag}")
    })?;
    let mut chunks = Vec::new();
    if version == VERSION_CHUNKED || version == VERSION_DELTA {
        let n_chunks = need!(cur.varint()?) as usize;
        if n_chunks == 0 || n_chunks > MAX_CHUNKS {
            crate::fuzz::cov::edge!("layer_bad_chunks");
            bail!("layer claims {n_chunks} chunks (hostile header?)");
        }
        chunks.reserve(n_chunks.min(1 << 10));
        for _ in 0..n_chunks {
            let cw = need!(cur.varint()?) as usize;
            let cb = need!(cur.varint()?) as usize;
            chunks.push(ChunkInfo { n_weights: cw, bytes: cb });
        }
        if n_chunks == 1 {
            crate::fuzz::cov::edge!("layer_chunk_canonical");
            chunks.clear(); // canonical monolithic representation
        }
    }
    let n_weights = need!(cur.varint()?) as usize;
    if n_weights > crate::baselines::MAX_DECODE_ELEMS {
        crate::fuzz::cov::edge!("layer_too_many_weights");
        bail!("layer claims {n_weights} weights (hostile header?)");
    }
    let payload_len = need!(cur.varint()?) as usize;
    // hostile-header guard: even fully adversarial CABAC output (every
    // bin mispredicted at the ~6 bits/bin worst case across sig, sign,
    // up to 255 gr flags and the EG chain) stays far below 512
    // bytes/weight, so anything bigger cannot be a real payload — and
    // without this cap a streaming decoder could be made to buffer an
    // arbitrarily large claimed payload
    if payload_len > n_weights.saturating_mul(512).saturating_add(4096) {
        crate::fuzz::cov::edge!("layer_payload_density");
        bail!("layer claims {payload_len} payload bytes for {n_weights} weights (hostile header?)");
    }
    // ...and the reverse direction: a level-density bound. The M-coder's
    // cheapest possible bin costs log2(512/507) ≈ 0.014 bits (rLPS ≥ 5 at
    // the most-confident state, range < 512), and every level spends at
    // least one sigflag bin, so a real stream codes < 600 levels per
    // payload byte. 2048/byte leaves > 3× headroom while stopping a
    // hostile header from claiming 2^28 weights against a tiny payload,
    // which would otherwise force a ~1 GiB allocation and 2^28 decode
    // steps out of a few dozen input bytes.
    if n_weights > payload_len.saturating_mul(2048).saturating_add(4096) {
        crate::fuzz::cov::edge!("layer_level_density");
        bail!("layer claims {n_weights} weights for {payload_len} payload bytes (hostile header?)");
    }
    // a chunk table must tile the payload and the weight count
    if !chunks.is_empty() {
        let (mut ws, mut bs) = (0usize, 0usize);
        for c in &chunks {
            ws = ws.checked_add(c.n_weights).ok_or_else(|| {
                crate::fuzz::cov::edge!("layer_chunk_overflow");
                anyhow!("chunk weight overflow")
            })?;
            bs = bs.checked_add(c.bytes).ok_or_else(|| {
                crate::fuzz::cov::edge!("layer_chunk_overflow");
                anyhow!("chunk byte overflow")
            })?;
        }
        if ws != n_weights || bs != payload_len {
            crate::fuzz::cov::edge!("layer_chunk_tile");
            bail!("chunk table inconsistent: {ws}/{n_weights} weights, {bs}/{payload_len} bytes");
        }
    }
    crate::fuzz::cov::edge!("layer_ok");
    Ok(Parsed::Complete(
        LayerHeader {
            name,
            dims,
            grid: QuantGrid { delta, max_level },
            s_param,
            cfg: CodecConfig {
                n_abs_flags,
                remainder,
                sig_ctx_neighbors: flags & FLAG_SIG_NEIGHBORS != 0,
            },
            chunks,
            n_weights,
            payload_len,
            skipped: false,
        },
        cur.pos,
    ))
}

/// Parse a bare varint (e.g. the bias length field) from a byte prefix.
pub fn parse_varint_prefix(buf: &[u8]) -> Result<Parsed<u64>> {
    let mut cur = Cur { buf, pos: 0 };
    let v = need!(cur.varint()?);
    Ok(Parsed::Complete(v, cur.pos))
}

fn write_str(out: &mut Vec<u8>, s: &str) {
    write_varint(out, s.len() as u64);
    out.extend_from_slice(s.as_bytes());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::encode_levels;
    use crate::util::ptest;

    fn sample_model() -> CompressedModel {
        let cfg = CodecConfig::default();
        let levels = vec![0, 1, -2, 0, 0, 7, 0, -1];
        CompressedModel {
            name: "tiny".into(),
            layers: vec![CompressedLayer {
                name: "fc1".into(),
                dims: vec![2, 4],
                grid: QuantGrid { delta: 0.125, max_level: 7 },
                s_param: 33,
                cfg,
                n_weights: levels.len(),
                payload: encode_levels(&levels, cfg),
                chunks: vec![],
                bias: vec![0.5, -0.25],
            }],
        }
    }

    fn chunked_layer(levels: &[i32], n_chunks: usize, cfg: CodecConfig) -> CompressedLayer {
        // encode each chunk independently (contexts reset), concatenate
        let n_chunks = n_chunks.max(1);
        let per = ((levels.len() + n_chunks - 1) / n_chunks).max(1);
        let mut payload = Vec::new();
        let mut chunks = Vec::new();
        for part in levels.chunks(per) {
            let bytes = encode_levels(part, cfg);
            chunks.push(ChunkInfo { n_weights: part.len(), bytes: bytes.len() });
            payload.extend_from_slice(&bytes);
        }
        CompressedLayer {
            name: "chunky".into(),
            dims: vec![levels.len().max(1)],
            grid: QuantGrid { delta: 0.1, max_level: 200 },
            s_param: 5,
            cfg,
            n_weights: levels.len(),
            payload,
            chunks,
            bias: vec![],
        }
    }

    #[test]
    fn serialize_roundtrip() {
        let m = sample_model();
        let bytes = m.serialize();
        let m2 = CompressedModel::deserialize(&bytes).unwrap();
        assert_eq!(m2.name, "tiny");
        assert_eq!(m2.layers.len(), 1);
        let l = &m2.layers[0];
        assert_eq!(l.dims, vec![2, 4]);
        assert_eq!(l.s_param, 33);
        assert_eq!(l.grid, m.layers[0].grid);
        assert_eq!(l.decode_levels(), vec![0, 1, -2, 0, 0, 7, 0, -1]);
        assert_eq!(l.bias, vec![0.5, -0.25]);
    }

    #[test]
    fn weights_reconstruct() {
        let m = sample_model();
        let w = m.layers[0].decode_weights();
        assert_eq!(w[1], 0.125);
        assert_eq!(w[2], -0.25);
        assert_eq!(w[5], 0.875);
    }

    #[test]
    fn unchunked_serialization_is_version_1() {
        // byte-compatibility: containers without chunked layers keep the
        // original format, version byte included
        let m = sample_model();
        assert!(!m.is_chunked());
        assert_eq!(m.serialize()[4], VERSION);
    }

    #[test]
    fn chunked_roundtrip_v2() {
        let cfg = CodecConfig::default();
        let mut rng = crate::util::SplitMix64::new(42);
        let levels: Vec<i32> = (0..5000)
            .map(|_| {
                if rng.next_f64() < 0.85 {
                    0
                } else {
                    (1 + rng.below(50) as i32) * if rng.next_u64() & 1 == 0 { 1 } else { -1 }
                }
            })
            .collect();
        for n_chunks in [2usize, 3, 8] {
            let layer = chunked_layer(&levels, n_chunks, cfg);
            assert_eq!(layer.n_chunks(), n_chunks);
            let m = CompressedModel { name: "c".into(), layers: vec![layer] };
            assert!(m.is_chunked());
            let bytes = m.serialize();
            assert_eq!(bytes[4], VERSION_CHUNKED);
            let m2 = CompressedModel::deserialize(&bytes).unwrap();
            // byte-stable re-serialization
            assert_eq!(m2.serialize(), bytes);
            // parallel and serial chunk decode agree with the source levels
            assert_eq!(m2.layers[0].decode_levels_with(1), levels, "serial n={n_chunks}");
            assert_eq!(m2.layers[0].decode_levels(), levels, "parallel n={n_chunks}");
        }
    }

    #[test]
    fn decode_levels_with_agrees_across_worker_counts() {
        // worker count must never change the decoded levels: 1 (inline),
        // 2 (fewer workers than chunks), n_chunks (one per chunk) and
        // more workers than chunks all agree with the source levels.
        let cfg = CodecConfig::default();
        let mut rng = crate::util::SplitMix64::new(7);
        let levels: Vec<i32> = (0..4096)
            .map(|_| {
                if rng.next_f64() < 0.8 {
                    0
                } else {
                    (1 + rng.below(30) as i32) * if rng.next_u64() & 1 == 0 { 1 } else { -1 }
                }
            })
            .collect();
        for n_chunks in [1usize, 3, 6] {
            let layer = chunked_layer(&levels, n_chunks, cfg);
            for workers in [1usize, 2, n_chunks, n_chunks + 5] {
                assert_eq!(
                    layer.decode_levels_with(workers),
                    levels,
                    "n_chunks={n_chunks} workers={workers}"
                );
            }
        }
    }

    #[test]
    fn chunk_spans_tile_payload() {
        let cfg = CodecConfig::default();
        let levels: Vec<i32> = (0..1000).map(|i| (i % 7 - 3) as i32).collect();
        // monolithic: one whole-payload span
        let mono = chunked_layer(&levels, 1, cfg);
        assert_eq!(
            mono.chunk_spans(),
            vec![ChunkSpan { offset: 0, bytes: mono.payload.len(), n_weights: 1000 }]
        );
        // chunked: spans are contiguous, ordered, and cover everything
        let layer = chunked_layer(&levels, 4, cfg);
        let spans = layer.chunk_spans();
        assert_eq!(spans.len(), 4);
        let mut off = 0usize;
        let mut nw = 0usize;
        for s in &spans {
            assert_eq!(s.offset, off);
            off += s.bytes;
            nw += s.n_weights;
        }
        assert_eq!(off, layer.payload.len());
        assert_eq!(nw, layer.n_weights);
    }

    #[test]
    fn incremental_prefix_parsers_match_batch() {
        // the shared prefix parsers must consume exactly the bytes the
        // serializer wrote, and report NeedMore (never Err) on every
        // strict prefix of a valid container
        let cfg = CodecConfig::default();
        let levels: Vec<i32> = (0..300).map(|i| (i % 11 - 5) as i32).collect();
        let m = CompressedModel {
            name: "px".into(),
            layers: vec![chunked_layer(&levels, 3, cfg)],
        };
        let bytes = m.serialize();
        let (prefix, used) = match parse_container_prefix(&bytes).unwrap() {
            Parsed::Complete(p, n) => (p, n),
            Parsed::NeedMore => panic!("full buffer must parse"),
        };
        assert_eq!(prefix.version, VERSION_CHUNKED);
        assert_eq!(prefix.name, "px");
        assert_eq!(prefix.n_layers, 1);
        let hdr = match parse_layer_header(&bytes[used..], prefix.version).unwrap() {
            Parsed::Complete(h, _) => h,
            Parsed::NeedMore => panic!("full buffer must parse"),
        };
        assert_eq!(hdr.name, "chunky");
        assert_eq!(hdr.n_weights, 300);
        assert_eq!(hdr.chunks.len(), 3);
        assert_eq!(
            hdr.payload_len,
            hdr.chunks.iter().map(|c| c.bytes).sum::<usize>()
        );
        // prefixes of the prelude: NeedMore, not Err
        for cut in 0..used {
            assert!(
                matches!(parse_container_prefix(&bytes[..cut]).unwrap(), Parsed::NeedMore),
                "cut={cut}"
            );
        }
    }

    #[test]
    fn rejects_inconsistent_chunk_table() {
        let cfg = CodecConfig::default();
        let levels: Vec<i32> = (0..200).map(|i| (i % 5 - 2) as i32).collect();
        let mut layer = chunked_layer(&levels, 3, cfg);
        layer.chunks[0].n_weights += 1;
        let m = CompressedModel { name: "bad".into(), layers: vec![layer] };
        assert!(CompressedModel::deserialize(&m.serialize()).is_err());
        let mut layer = chunked_layer(&levels, 3, cfg);
        layer.chunks[2].bytes -= 1;
        let m = CompressedModel { name: "bad".into(), layers: vec![layer] };
        assert!(CompressedModel::deserialize(&m.serialize()).is_err());
    }

    /// Hand-write a v2 layer header with arbitrary (unvalidated) chunk
    /// table and count fields — the public API canonicalizes, so hostile
    /// tables have to be authored at the byte level.
    fn raw_v2_container(
        chunks: &[(u64, u64)],
        n_weights: u64,
        payload_len: u64,
        payload: &[u8],
    ) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.push(VERSION_CHUNKED);
        write_str(&mut out, "raw");
        write_varint(&mut out, 1); // n_layers
        write_str(&mut out, "l0");
        write_varint(&mut out, 1); // ndims
        write_varint(&mut out, payload.len().max(1) as u64);
        out.extend_from_slice(&0.5f32.to_le_bytes());
        write_varint(&mut out, 3); // max_level
        write_varint(&mut out, 7); // s_param
        out.extend_from_slice(&[1, 1, 0, 0]); // n_abs_flags, EG(0), flags
        write_varint(&mut out, chunks.len() as u64);
        for &(w, b) in chunks {
            write_varint(&mut out, w);
            write_varint(&mut out, b);
        }
        write_varint(&mut out, n_weights);
        write_varint(&mut out, payload_len);
        out.extend_from_slice(payload);
        write_varint(&mut out, 0); // bias_len
        out
    }

    #[test]
    fn rejects_hostile_weight_density() {
        // a header claiming 2^28 weights against an 8-byte payload used
        // to force a ~1 GiB decode allocation; now it's a parse error
        let bytes = raw_v2_container(&[(1 << 28, 8)], 1 << 28, 8, &[0u8; 8]);
        let err = CompressedModel::deserialize(&bytes).unwrap_err().to_string();
        assert!(err.contains("hostile header"), "{err}");
        // the streaming decoder shares the guard
        let mut dec = crate::serve::stream::StreamDecoder::new();
        assert!(dec.feed(&bytes).is_err());
        // boundary: exactly payload_len * 2048 + 4096 weights is accepted
        // structurally (the decode itself is then payload-bounded)
        let n_ok = 8 * 2048 + 4096;
        let ok = raw_v2_container(&[(n_ok, 8)], n_ok, 8, &[0u8; 8]);
        let m = CompressedModel::deserialize(&ok).unwrap();
        assert_eq!(m.layers[0].n_weights, n_ok as usize);
        let over = raw_v2_container(&[(n_ok + 1, 8)], n_ok + 1, 8, &[0u8; 8]);
        assert!(CompressedModel::deserialize(&over).is_err());
    }

    #[test]
    fn rejects_overflowing_and_overlapping_chunk_tables() {
        // Σ chunk_bytes overflowing usize must hit the checked_add path,
        // not wrap around into a "consistent" table
        let huge = u64::MAX / 2 + 1;
        let bytes = raw_v2_container(&[(4, huge), (4, huge)], 8, 8, &[0u8; 8]);
        let err = CompressedModel::deserialize(&bytes).unwrap_err().to_string();
        assert!(
            err.contains("overflow") || err.contains("hostile") || err.contains("inconsistent"),
            "{err}"
        );
        // Σ chunk_n_weights overflow likewise
        let bytes = raw_v2_container(&[(huge, 4), (huge, 4)], 8, 8, &[0u8; 8]);
        assert!(CompressedModel::deserialize(&bytes).is_err());
        // out-of-order/overlapping spans can only be expressed as a table
        // whose sums disagree with the layer totals — both directions
        let bytes = raw_v2_container(&[(4, 6), (4, 6)], 8, 8, &[0u8; 8]);
        assert!(CompressedModel::deserialize(&bytes).is_err(), "byte sum must match");
        let bytes = raw_v2_container(&[(6, 4), (6, 4)], 8, 8, &[0u8; 8]);
        assert!(CompressedModel::deserialize(&bytes).is_err(), "weight sum must match");
        // zero chunks is malformed, as is a count past MAX_CHUNKS
        let bytes = raw_v2_container(&[], 8, 8, &[0u8; 8]);
        assert!(CompressedModel::deserialize(&bytes).is_err());
    }

    #[test]
    fn zero_weight_layer_mid_container_roundtrips() {
        // an empty tensor between two real ones: n_weights = 0,
        // payload_len = 0 — legal, decodes to nothing, byte-stable
        let cfg = CodecConfig::default();
        let levels: Vec<i32> = (0..64).map(|i| (i % 5 - 2) as i32).collect();
        let mk = |name: &str, lv: &[i32]| CompressedLayer {
            name: name.into(),
            dims: vec![lv.len().max(1)],
            grid: QuantGrid { delta: 0.25, max_level: 4 },
            s_param: 3,
            cfg,
            n_weights: lv.len(),
            payload: encode_levels(lv, cfg),
            chunks: vec![],
            bias: vec![],
        };
        let m = CompressedModel {
            name: "holes".into(),
            layers: vec![mk("a", &levels), mk("empty", &[]), mk("b", &levels)],
        };
        let bytes = m.serialize();
        let m2 = CompressedModel::deserialize(&bytes).unwrap();
        assert_eq!(m2.serialize(), bytes);
        assert_eq!(m2.layers[1].n_weights, 0);
        assert!(m2.layers[1].decode_levels().is_empty());
        assert_eq!(m2.layers[2].decode_levels(), levels);
        // and the streaming decoder delivers all three, empty included
        let streamed = crate::serve::stream::decode_all(&bytes).unwrap();
        assert_eq!(streamed.len(), 3);
        assert!(streamed[1].weights.is_empty());
    }

    #[test]
    fn rejects_corruption() {
        let bytes = sample_model().serialize();
        assert!(CompressedModel::deserialize(&bytes[1..]).is_err());
        assert!(CompressedModel::deserialize(&bytes[..bytes.len() - 3]).is_err());
        let mut bad = bytes.clone();
        bad[4] = 99; // version
        assert!(CompressedModel::deserialize(&bad).is_err());
    }

    fn sample_delta() -> DeltaModel {
        let cfg = CodecConfig::default();
        let residual = vec![0, 0, 1, 0, 0, 0, -2, 0];
        DeltaModel {
            parent_fp: 0xDEAD_BEEF_CAFE_F00D,
            name: "tiny".into(),
            layers: vec![
                DeltaLayer::Skipped("fc0".into()),
                DeltaLayer::Coded(CompressedLayer {
                    name: "fc1".into(),
                    dims: vec![2, 4],
                    grid: QuantGrid { delta: 0.125, max_level: 7 },
                    s_param: 33,
                    cfg,
                    n_weights: residual.len(),
                    payload: encode_levels(&residual, cfg),
                    chunks: vec![],
                    bias: vec![0.5],
                }),
            ],
        }
    }

    #[test]
    fn delta_roundtrip_v3_byte_stable() {
        let d = sample_delta();
        let bytes = d.serialize();
        assert_eq!(bytes[4], VERSION_DELTA);
        let d2 = DeltaModel::deserialize(&bytes).unwrap();
        assert_eq!(d2.parent_fp, d.parent_fp);
        assert_eq!(d2.name, "tiny");
        assert_eq!(d2.layers.len(), 2);
        assert!(matches!(&d2.layers[0], DeltaLayer::Skipped(n) if n == "fc0"));
        match &d2.layers[1] {
            DeltaLayer::Coded(l) => {
                assert_eq!(l.decode_levels(), vec![0, 0, 1, 0, 0, 0, -2, 0]);
                assert_eq!(l.bias, vec![0.5]);
            }
            other => panic!("expected coded layer, got {other:?}"),
        }
        // byte-stable re-serialization
        assert_eq!(d2.serialize(), bytes);
        // deserialize_any dispatches on the version byte
        assert!(matches!(deserialize_any(&bytes).unwrap(), Container::Delta(_)));
        assert!(matches!(
            deserialize_any(&sample_model().serialize()).unwrap(),
            Container::Full(_)
        ));
    }

    #[test]
    fn batch_reader_rejects_delta_with_structured_error() {
        let bytes = sample_delta().serialize();
        let err = CompressedModel::deserialize(&bytes).unwrap_err().to_string();
        assert!(err.contains("delta segment"), "{err}");
    }

    #[test]
    fn delta_prefixes_are_need_more_never_err() {
        // prefix monotonicity holds for v3 exactly as for v1/v2
        let bytes = sample_delta().serialize();
        for cut in 0..bytes.len() {
            assert!(
                DeltaModel::deserialize(&bytes[..cut]).is_err(),
                "strict prefix must not parse as complete (cut={cut})"
            );
            // the prelude parser itself must keep saying NeedMore
            if cut < 16 {
                assert!(
                    matches!(
                        parse_container_prefix(&bytes[..cut]).unwrap(),
                        Parsed::NeedMore
                    ),
                    "cut={cut}"
                );
            }
        }
        let (prefix, _) = match parse_container_prefix(&bytes).unwrap() {
            Parsed::Complete(p, n) => (p, n),
            Parsed::NeedMore => panic!("full buffer must parse"),
        };
        assert_eq!(prefix.version, VERSION_DELTA);
        assert_eq!(prefix.parent_fp, Some(0xDEAD_BEEF_CAFE_F00D));
    }

    #[test]
    fn delta_rejects_bad_skip_flag_and_trailing_bytes() {
        let d = sample_delta();
        let mut bytes = d.serialize();
        // locate the first dlayer's skip byte: prelude is
        // 4 magic + 1 version + 8 fp + str("tiny") + varint(2)
        let skip_at = 4 + 1 + 8 + (1 + 4) + 1;
        assert_eq!(bytes[skip_at], 1, "fixture layout changed");
        bytes[skip_at] = 2;
        let err = DeltaModel::deserialize(&bytes).unwrap_err().to_string();
        assert!(err.contains("skip flag"), "{err}");
        let mut bytes = d.serialize();
        bytes.push(0xFF);
        assert!(DeltaModel::deserialize(&bytes).is_err());
    }

    #[test]
    fn full_serialization_unchanged_by_delta_support() {
        // v1/v2 emission must be byte-for-byte what it was before v3
        // existed: version byte, no fingerprint, no skip bytes
        let m = sample_model();
        let bytes = m.serialize();
        assert_eq!(&bytes[..5], b"DCBC\x01");
        // name immediately follows the version byte
        assert_eq!(bytes[5] as usize, m.name.len());
        assert_eq!(&bytes[6..6 + m.name.len()], m.name.as_bytes());
    }

    fn sample_progressive() -> ProgressiveModel {
        let cfg = CodecConfig::default();
        let mk = |name: &str, levels: &[i32], delta: f32| CompressedLayer {
            name: name.into(),
            dims: vec![levels.len().max(1)],
            grid: QuantGrid {
                delta,
                max_level: levels.iter().map(|l| l.unsigned_abs()).max().unwrap_or(0) as i32,
            },
            s_param: 9,
            cfg,
            n_weights: levels.len(),
            payload: encode_levels(levels, cfg),
            chunks: vec![],
            bias: vec![0.25],
        };
        ProgressiveModel {
            name: "prog".into(),
            base: vec![mk("conv", &[0, 2, -1, 0], 0.25), mk("fc", &[1, 0], 0.25)],
            refinements: vec![
                vec![
                    DeltaLayer::Coded(mk("conv", &[0, 1, 0, -1], 0.125)),
                    DeltaLayer::Skipped("fc".into()),
                ],
                vec![
                    DeltaLayer::Skipped("conv".into()),
                    DeltaLayer::Coded(mk("fc", &[-1, 1], 0.0625)),
                ],
            ],
        }
    }

    /// Absolute byte offset where each tier body of `p` ends.
    fn tier_ends(p: &ProgressiveModel) -> Vec<usize> {
        let bytes = p.serialize();
        let lens = p.tier_body_lens();
        let prelude = bytes.len() - lens.iter().sum::<usize>();
        let mut ends = Vec::new();
        let mut pos = prelude;
        for l in lens {
            pos += l;
            ends.push(pos);
        }
        assert_eq!(pos, bytes.len());
        ends
    }

    #[test]
    fn progressive_roundtrip_v4_byte_stable() {
        let p = sample_progressive();
        let bytes = p.serialize();
        assert_eq!(bytes[4], VERSION_PROGRESSIVE);
        let p2 = ProgressiveModel::deserialize(&bytes).unwrap();
        assert_eq!(p2.name, "prog");
        assert_eq!(p2.n_tiers(), 3);
        assert_eq!(p2.base.len(), 2);
        assert_eq!(p2.serialize(), bytes);
        assert!(matches!(deserialize_any(&bytes).unwrap(), Container::Progressive(_)));
        match &p2.refinements[0][0] {
            DeltaLayer::Coded(l) => assert_eq!(l.decode_levels(), vec![0, 1, 0, -1]),
            other => panic!("expected coded layer, got {other:?}"),
        }
        assert!(matches!(&p2.refinements[0][1], DeltaLayer::Skipped(n) if n == "fc"));
    }

    #[test]
    fn progressive_truncation_rule() {
        let p = sample_progressive();
        let bytes = p.serialize();
        let ends = tier_ends(&p);
        assert_eq!(ends.len(), 3);
        // EOF exactly at each tier boundary: complete at that tier
        for (t, &end) in ends.iter().enumerate() {
            let trunc = ProgressiveModel::deserialize(&bytes[..end]).unwrap();
            assert_eq!(trunc.n_tiers(), t + 1, "boundary {t}");
            // canonicalization exception: the prefix re-serializes as a
            // *smaller complete container*, and that is a fixpoint
            let reser = trunc.serialize();
            let again = ProgressiveModel::deserialize(&reser).unwrap();
            assert_eq!(again.serialize(), reser, "boundary {t} not idempotent");
        }
        // EOF inside a tier body: truncated, never accepted
        for cut in [ends[0] - 1, ends[0] + 1, ends[1] - 2, ends[2] - 1] {
            assert!(
                ProgressiveModel::deserialize(&bytes[..cut]).is_err(),
                "mid-tier cut {cut} must not parse"
            );
        }
        // trailing bytes past the last declared tier: error
        let mut extra = bytes.clone();
        extra.push(0);
        assert!(ProgressiveModel::deserialize(&extra).is_err());
    }

    /// Hand-author a v4 prelude with arbitrary tier table; bodies appended raw.
    fn raw_v4_container(n_layers: u64, tier_lens: &[u64], bodies: &[u8]) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.push(VERSION_PROGRESSIVE);
        write_str(&mut out, "raw");
        write_varint(&mut out, n_layers);
        write_varint(&mut out, tier_lens.len() as u64);
        for &l in tier_lens {
            write_varint(&mut out, l);
        }
        out.extend_from_slice(bodies);
        out
    }

    #[test]
    fn progressive_rejects_hostile_tier_tables() {
        // zero tiers is malformed
        let err = ProgressiveModel::deserialize(&raw_v4_container(0, &[], &[]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("tiers"), "{err}");
        // tier count past MAX_TIERS
        let lens = vec![0u64; MAX_TIERS + 1];
        assert!(ProgressiveModel::deserialize(&raw_v4_container(0, &lens, &[])).is_err());
        // tier lengths whose sum overflows u64: checked, not wrapped
        let huge = u64::MAX / 2 + 1;
        let err = ProgressiveModel::deserialize(&raw_v4_container(0, &[huge, huge], &[]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("overflow"), "{err}");
        // a tier table lying about its body length
        let p = sample_progressive();
        let good = p.serialize();
        let lens = p.tier_body_lens();
        let bodies = &good[good.len() - lens.iter().sum::<usize>()..];
        let lie = [lens[0] as u64 + 1, lens[1] as u64, lens[2] as u64];
        let err = ProgressiveModel::deserialize(&raw_v4_container(2, &lie, bodies))
            .unwrap_err()
            .to_string();
        assert!(err.contains("tier"), "{err}");
        // zero-layer container with declared-but-absent refinement tiers
        // collapses to one tier under the truncation rule
        let empty = raw_v4_container(0, &[0, 0, 0], &[]);
        let m = ProgressiveModel::deserialize(&empty).unwrap();
        assert_eq!(m.n_tiers(), 1);
        assert!(m.base.is_empty());
    }

    #[test]
    fn progressive_prefix_monotonicity_of_prelude() {
        let bytes = sample_progressive().serialize();
        let (prefix, used) = match parse_container_prefix(&bytes).unwrap() {
            Parsed::Complete(p, n) => (p, n),
            Parsed::NeedMore => panic!("full buffer must parse"),
        };
        assert_eq!(prefix.version, VERSION_PROGRESSIVE);
        assert_eq!(prefix.n_layers, 2);
        assert_eq!(prefix.tier_lens.len(), 3);
        for cut in 0..used {
            assert!(
                matches!(parse_container_prefix(&bytes[..cut]).unwrap(), Parsed::NeedMore),
                "cut={cut}"
            );
        }
    }

    #[test]
    fn unknown_version_error_names_max_supported() {
        let mut bytes = sample_model().serialize();
        bytes[4] = MAX_SUPPORTED_VERSION + 1;
        let err = CompressedModel::deserialize(&bytes).unwrap_err().to_string();
        assert!(err.contains(&format!("{MAX_SUPPORTED_VERSION}")), "{err}");
        assert!(err.contains("unsupported"), "{err}");
    }

    #[test]
    fn batch_reader_rejects_progressive_with_structured_error() {
        let bytes = sample_progressive().serialize();
        let err = CompressedModel::deserialize(&bytes).unwrap_err().to_string();
        assert!(err.contains("progressive"), "{err}");
    }

    #[test]
    fn property_container_roundtrip() {
        ptest::check(
            ptest::Config { cases: 48, max_size: 800, ..Default::default() },
            "container-roundtrip",
            |g| {
                let n_layers = g.usize_in(0, 4);
                let mut layers = Vec::new();
                for li in 0..n_layers {
                    let levels = g.levels();
                    let max_abs =
                        levels.iter().map(|l| l.unsigned_abs()).max().unwrap_or(0);
                    let cfg = CodecConfig {
                        n_abs_flags: 1 + g.usize_in(0, 14) as u32,
                        remainder: RemainderMode::ExpGolomb(g.usize_in(0, 2) as u32),
                        sig_ctx_neighbors: g.bool(),
                    };
                    // mix monolithic and chunked layers in one container
                    let n_chunks = if g.bool() { 1 } else { 1 + g.usize_in(0, 5) };
                    let mut layer = if n_chunks > 1 && !levels.is_empty() {
                        chunked_layer(&levels, n_chunks, cfg)
                    } else {
                        CompressedLayer {
                            name: String::new(),
                            dims: vec![levels.len().max(1)],
                            grid: QuantGrid { delta: 0.0, max_level: 0 },
                            s_param: 0,
                            cfg,
                            n_weights: levels.len(),
                            payload: encode_levels(&levels, cfg),
                            chunks: vec![],
                            bias: vec![],
                        }
                    };
                    layer.name = format!("l{li}");
                    layer.grid =
                        QuantGrid { delta: 0.01 + g.rng.next_f32(), max_level: max_abs as i32 };
                    layer.s_param = g.usize_in(0, 256) as u32;
                    layer.bias = (0..g.usize_in(0, 16)).map(|_| g.f32_normal(1.0)).collect();
                    layers.push(layer);
                }
                let m = CompressedModel { name: "p".into(), layers };
                let bytes = m.serialize();
                let m2 = CompressedModel::deserialize(&bytes)
                    .map_err(|e| format!("deser: {e}"))?;
                if m2.serialize() != bytes {
                    return Err("re-serialization not byte-stable".into());
                }
                for (a, b) in m.layers.iter().zip(&m2.layers) {
                    if a.decode_levels() != b.decode_levels() {
                        return Err("level mismatch".into());
                    }
                    if a.chunks != b.chunks {
                        return Err("chunk table mismatch".into());
                    }
                    if a.bias != b.bias {
                        return Err("bias mismatch".into());
                    }
                }
                Ok(())
            },
        );
    }
}
