//! The `DCBC` compressed-model container format (DESIGN.md §6).
//!
//! ```text
//! file   := "DCBC" u8 version | str name | varint n_layers | layer*
//! layer  := str name | varint ndims, dims* | f32 delta | varint S
//!           | u8 n_abs_flags | u8 rem_tag | u8 rem_param | u8 flags
//!           | varint n_weights | varint payload_len | payload bytes
//!           | varint bias_len | raw f32 bias bytes
//! ```
//!
//! Biases (and any normalization parameters) are stored raw, as the
//! paper compresses weight tensors only.

use crate::bitstream::{read_varint, write_varint};
use crate::codec::{decode_levels, CodecConfig, RemainderMode};
use crate::quant::QuantGrid;
use anyhow::{anyhow, bail, Result};
use byteorder::{ByteOrder, LittleEndian};

pub const MAGIC: &[u8; 4] = b"DCBC";
pub const VERSION: u8 = 1;

const FLAG_SIG_NEIGHBORS: u8 = 1;

#[derive(Debug, Clone)]
pub struct CompressedLayer {
    pub name: String,
    pub dims: Vec<usize>,
    pub grid: QuantGrid,
    pub s_param: u32,
    pub cfg: CodecConfig,
    pub n_weights: usize,
    pub payload: Vec<u8>,
    pub bias: Vec<f32>,
}

impl CompressedLayer {
    /// Decode the CABAC payload back into integer levels.
    pub fn decode_levels(&self) -> Vec<i32> {
        decode_levels(&self.payload, self.n_weights, self.cfg)
    }

    /// Full reconstruction: levels × Δ.
    pub fn decode_weights(&self) -> Vec<f32> {
        self.grid.dequantize(&self.decode_levels())
    }

    /// On-disk footprint of this layer (payload + bias + header approx).
    pub fn stored_bytes(&self) -> usize {
        self.payload.len() + self.bias.len() * 4
    }
}

#[derive(Debug, Clone, Default)]
pub struct CompressedModel {
    pub name: String,
    pub layers: Vec<CompressedLayer>,
}

impl CompressedModel {
    pub fn total_bytes(&self) -> usize {
        // serialized size (exact): build lazily
        self.serialize().len()
    }

    pub fn payload_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.payload.len()).sum()
    }

    pub fn serialize(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.push(VERSION);
        write_str(&mut out, &self.name);
        write_varint(&mut out, self.layers.len() as u64);
        for l in &self.layers {
            write_str(&mut out, &l.name);
            write_varint(&mut out, l.dims.len() as u64);
            for &d in &l.dims {
                write_varint(&mut out, d as u64);
            }
            out.extend_from_slice(&l.grid.delta.to_le_bytes());
            write_varint(&mut out, l.grid.max_level as u64);
            write_varint(&mut out, l.s_param as u64);
            out.push(l.cfg.n_abs_flags as u8);
            out.push(l.cfg.remainder.tag());
            out.push(l.cfg.remainder.param() as u8);
            out.push(if l.cfg.sig_ctx_neighbors { FLAG_SIG_NEIGHBORS } else { 0 });
            write_varint(&mut out, l.n_weights as u64);
            write_varint(&mut out, l.payload.len() as u64);
            out.extend_from_slice(&l.payload);
            write_varint(&mut out, l.bias.len() as u64);
            let mut bias_bytes = vec![0u8; l.bias.len() * 4];
            LittleEndian::write_f32_into(&l.bias, &mut bias_bytes);
            out.extend_from_slice(&bias_bytes);
        }
        out
    }

    pub fn deserialize(buf: &[u8]) -> Result<Self> {
        let mut pos = 0usize;
        if buf.len() < 5 || &buf[..4] != MAGIC {
            bail!("not a DCBC container");
        }
        pos += 4;
        let version = buf[pos];
        pos += 1;
        if version != VERSION {
            bail!("unsupported DCBC version {version}");
        }
        let name = read_str(buf, &mut pos)?;
        let n_layers = read_vi(buf, &mut pos)? as usize;
        let mut layers = Vec::with_capacity(n_layers);
        for _ in 0..n_layers {
            let lname = read_str(buf, &mut pos)?;
            let ndims = read_vi(buf, &mut pos)? as usize;
            let mut dims = Vec::with_capacity(ndims);
            for _ in 0..ndims {
                dims.push(read_vi(buf, &mut pos)? as usize);
            }
            if pos + 4 > buf.len() {
                bail!("truncated delta");
            }
            let delta = f32::from_le_bytes(buf[pos..pos + 4].try_into().unwrap());
            pos += 4;
            let max_level = read_vi(buf, &mut pos)? as i32;
            let s_param = read_vi(buf, &mut pos)? as u32;
            if pos + 4 > buf.len() {
                bail!("truncated codec params");
            }
            let n_abs_flags = buf[pos] as u32;
            let rem_tag = buf[pos + 1];
            let rem_param = buf[pos + 2] as u32;
            let flags = buf[pos + 3];
            pos += 4;
            let remainder = RemainderMode::from_tag(rem_tag, rem_param)
                .ok_or_else(|| anyhow!("bad remainder tag {rem_tag}"))?;
            let n_weights = read_vi(buf, &mut pos)? as usize;
            if n_weights > crate::baselines::MAX_DECODE_ELEMS {
                bail!("layer claims {n_weights} weights (hostile header?)");
            }
            let plen = read_vi(buf, &mut pos)? as usize;
            if pos + plen > buf.len() {
                bail!("truncated payload");
            }
            let payload = buf[pos..pos + plen].to_vec();
            pos += plen;
            let blen = read_vi(buf, &mut pos)? as usize;
            if pos + blen * 4 > buf.len() {
                bail!("truncated bias");
            }
            let mut bias = vec![0f32; blen];
            LittleEndian::read_f32_into(&buf[pos..pos + blen * 4], &mut bias);
            pos += blen * 4;
            layers.push(CompressedLayer {
                name: lname,
                dims,
                grid: QuantGrid { delta, max_level },
                s_param,
                cfg: CodecConfig {
                    n_abs_flags,
                    remainder,
                    sig_ctx_neighbors: flags & FLAG_SIG_NEIGHBORS != 0,
                },
                n_weights,
                payload,
                bias,
            });
        }
        if pos != buf.len() {
            bail!("trailing bytes in container");
        }
        Ok(Self { name, layers })
    }
}

fn write_str(out: &mut Vec<u8>, s: &str) {
    write_varint(out, s.len() as u64);
    out.extend_from_slice(s.as_bytes());
}

fn read_str(buf: &[u8], pos: &mut usize) -> Result<String> {
    let len = read_vi(buf, pos)? as usize;
    if *pos + len > buf.len() {
        bail!("truncated string");
    }
    let s = std::str::from_utf8(&buf[*pos..*pos + len])?.to_string();
    *pos += len;
    Ok(s)
}

fn read_vi(buf: &[u8], pos: &mut usize) -> Result<u64> {
    let (v, n) =
        read_varint(&buf[*pos..]).ok_or_else(|| anyhow!("truncated varint"))?;
    *pos += n;
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::encode_levels;
    use crate::util::ptest;

    fn sample_model() -> CompressedModel {
        let cfg = CodecConfig::default();
        let levels = vec![0, 1, -2, 0, 0, 7, 0, -1];
        CompressedModel {
            name: "tiny".into(),
            layers: vec![CompressedLayer {
                name: "fc1".into(),
                dims: vec![2, 4],
                grid: QuantGrid { delta: 0.125, max_level: 7 },
                s_param: 33,
                cfg,
                n_weights: levels.len(),
                payload: encode_levels(&levels, cfg),
                bias: vec![0.5, -0.25],
            }],
        }
    }

    #[test]
    fn serialize_roundtrip() {
        let m = sample_model();
        let bytes = m.serialize();
        let m2 = CompressedModel::deserialize(&bytes).unwrap();
        assert_eq!(m2.name, "tiny");
        assert_eq!(m2.layers.len(), 1);
        let l = &m2.layers[0];
        assert_eq!(l.dims, vec![2, 4]);
        assert_eq!(l.s_param, 33);
        assert_eq!(l.grid, m.layers[0].grid);
        assert_eq!(l.decode_levels(), vec![0, 1, -2, 0, 0, 7, 0, -1]);
        assert_eq!(l.bias, vec![0.5, -0.25]);
    }

    #[test]
    fn weights_reconstruct() {
        let m = sample_model();
        let w = m.layers[0].decode_weights();
        assert_eq!(w[1], 0.125);
        assert_eq!(w[2], -0.25);
        assert_eq!(w[5], 0.875);
    }

    #[test]
    fn rejects_corruption() {
        let bytes = sample_model().serialize();
        assert!(CompressedModel::deserialize(&bytes[1..]).is_err());
        assert!(CompressedModel::deserialize(&bytes[..bytes.len() - 3]).is_err());
        let mut bad = bytes.clone();
        bad[4] = 99; // version
        assert!(CompressedModel::deserialize(&bad).is_err());
    }

    #[test]
    fn property_container_roundtrip() {
        ptest::check(
            ptest::Config { cases: 48, max_size: 800, ..Default::default() },
            "container-roundtrip",
            |g| {
                let n_layers = g.usize_in(0, 4);
                let mut layers = Vec::new();
                for li in 0..n_layers {
                    let levels = g.levels();
                    let max_abs =
                        levels.iter().map(|l| l.unsigned_abs()).max().unwrap_or(0);
                    let cfg = CodecConfig {
                        n_abs_flags: 1 + g.usize_in(0, 14) as u32,
                        remainder: RemainderMode::ExpGolomb(g.usize_in(0, 2) as u32),
                        sig_ctx_neighbors: g.bool(),
                    };
                    layers.push(CompressedLayer {
                        name: format!("l{li}"),
                        dims: vec![levels.len().max(1)],
                        grid: QuantGrid {
                            delta: 0.01 + g.rng.next_f32(),
                            max_level: max_abs as i32,
                        },
                        s_param: g.usize_in(0, 256) as u32,
                        cfg,
                        n_weights: levels.len(),
                        payload: encode_levels(&levels, cfg),
                        bias: (0..g.usize_in(0, 16)).map(|_| g.f32_normal(1.0)).collect(),
                    });
                }
                let m = CompressedModel { name: "p".into(), layers };
                let bytes = m.serialize();
                let m2 = CompressedModel::deserialize(&bytes)
                    .map_err(|e| format!("deser: {e}"))?;
                for (a, b) in m.layers.iter().zip(&m2.layers) {
                    if a.decode_levels() != b.decode_levels() {
                        return Err("level mismatch".into());
                    }
                    if a.bias != b.bias {
                        return Err("bias mismatch".into());
                    }
                }
                Ok(())
            },
        );
    }
}
