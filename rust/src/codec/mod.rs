//! DeepCABAC weight codec — the paper's §2.1 binarization wired to the
//! CABAC engine, plus the bit-cost estimator the RD quantizer queries.
//!
//! A quantized weight tensor is a flat row-major `&[i32]` of integer
//! levels. Each level is coded as (paper fig. 1):
//!
//! ```text
//! sigflag   (regular bin, ctx chosen from previous-2 significance)
//! signflag  (regular bin, own ctx)
//! AbsGr(i)  for i = 1..=n (regular bins, one ctx each)
//! remainder (bypass: fixed-length or exp-Golomb)
//! ```

pub mod binarize;
pub mod config;
pub mod estimator;

pub use binarize::{decode_levels, encode_levels, LevelDecoder, LevelEncoder};
pub use config::{CodecConfig, RemainderMode};
pub use estimator::RateEstimator;

use crate::cabac::ContextModel;

/// Number of sigflag contexts when neighbour conditioning is on
/// (selected by how many of the previous 2 weights were significant).
pub const SIG_CTXS: usize = 3;

/// Number of contexts for the exp-Golomb remainder's *prefix* bins.
/// Like the MPEG-NNR DeepCABAC, the unary prefix of the remainder is
/// context-coded (one model per prefix position, shared beyond); only
/// the suffix bits are bypass. On fine grids this is worth several bits
/// per significant weight.
pub const EG_PREFIX_CTXS: usize = 16;

/// The full set of adaptive contexts for one tensor.
#[derive(Debug, Clone)]
pub struct ContextSet {
    pub sig: [ContextModel; SIG_CTXS],
    pub sign: ContextModel,
    pub gr: Vec<ContextModel>, // n_abs_flags entries
    pub eg_prefix: [ContextModel; EG_PREFIX_CTXS],
}

impl ContextSet {
    pub fn new(cfg: &CodecConfig) -> Self {
        Self {
            sig: [ContextModel::default(); SIG_CTXS],
            sign: ContextModel::default(),
            gr: vec![ContextModel::default(); cfg.n_abs_flags as usize],
            eg_prefix: [ContextModel::default(); EG_PREFIX_CTXS],
        }
    }

    /// Index of the sigflag context for the current scan position.
    #[inline]
    pub fn sig_ctx_index(cfg: &CodecConfig, prev_sig: (bool, bool)) -> usize {
        if cfg.sig_ctx_neighbors {
            prev_sig.0 as usize + prev_sig.1 as usize
        } else {
            0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_count_follows_config() {
        let cfg = CodecConfig { n_abs_flags: 5, ..CodecConfig::default() };
        let set = ContextSet::new(&cfg);
        assert_eq!(set.gr.len(), 5);
    }

    #[test]
    fn sig_ctx_selection() {
        let on = CodecConfig { sig_ctx_neighbors: true, ..CodecConfig::default() };
        let off = CodecConfig { sig_ctx_neighbors: false, ..CodecConfig::default() };
        assert_eq!(ContextSet::sig_ctx_index(&on, (false, false)), 0);
        assert_eq!(ContextSet::sig_ctx_index(&on, (true, false)), 1);
        assert_eq!(ContextSet::sig_ctx_index(&on, (true, true)), 2);
        assert_eq!(ContextSet::sig_ctx_index(&off, (true, true)), 0);
    }
}
