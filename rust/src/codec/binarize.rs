//! Binarization of quantized weight levels (paper §2.1 / figure 1) and
//! the streaming encoder/decoder over a whole tensor.

use super::estimator::RateCache;
use super::{CodecConfig, ContextSet, RemainderMode};
use crate::cabac::{CabacDecoder, CabacEncoder};

/// Streaming level encoder: owns the CABAC engine + contexts and tracks
/// the previous-two significance for context selection. The RD quantizer
/// drives it weight by weight (estimate candidates → choose level →
/// `encode_level`). It also owns the memoized rate cache the estimator
/// uses, invalidated whenever a nonzero encode touches the gr/eg
/// contexts.
pub struct LevelEncoder {
    pub enc: CabacEncoder,
    pub ctxs: ContextSet,
    cfg: CodecConfig,
    prev_sig: (bool, bool), // (previous, one-before-previous)
    count: u64,
    rate_cache: RateCache,
}

impl LevelEncoder {
    pub fn new(cfg: CodecConfig) -> Self {
        Self {
            enc: CabacEncoder::new(),
            ctxs: ContextSet::new(&cfg),
            cfg,
            prev_sig: (false, false),
            count: 0,
            rate_cache: RateCache::new(),
        }
    }

    pub fn with_capacity(cfg: CodecConfig, bytes: usize) -> Self {
        Self { enc: CabacEncoder::with_capacity(bytes), ..Self::new(cfg) }
    }

    pub fn cfg(&self) -> &CodecConfig {
        &self.cfg
    }

    /// Current previous-two significance (feeds the rate estimator).
    pub fn prev_sig(&self) -> (bool, bool) {
        self.prev_sig
    }

    /// Fractional bits to code `level` at the current position — the
    /// memoized equivalent of [`super::RateEstimator::level_bits`]
    /// (bit-identical, but O(1) amortized per candidate: sig/sign costs
    /// are single RateTable loads and the gr/remainder tail comes from
    /// the per-magnitude cache).
    #[inline]
    pub fn estimate_level_bits(&mut self, level: i32) -> f32 {
        let sig_idx = ContextSet::sig_ctx_index(&self.cfg, self.prev_sig);
        if level == 0 {
            return self.ctxs.sig[sig_idx].bits(0);
        }
        self.ctxs.sig[sig_idx].bits(1)
            + self.ctxs.sign.bits((level < 0) as u8)
            + self.rate_cache.tail_bits(&self.cfg, &self.ctxs, level.unsigned_abs())
    }

    /// Encode one level and update all adaptive state.
    pub fn encode_level(&mut self, level: i32) {
        let cfg = self.cfg;
        let sig_idx = ContextSet::sig_ctx_index(&cfg, self.prev_sig);
        let sig = level != 0;
        self.enc.encode(&mut self.ctxs.sig[sig_idx], sig as u8);
        if sig {
            // gr/eg-prefix/sign contexts are about to change: memoized
            // tail costs are stale from here on.
            self.rate_cache.invalidate();
            let negative = level < 0;
            self.enc.encode(&mut self.ctxs.sign, negative as u8);
            let abs = level.unsigned_abs();
            // AbsGr(i): is |level| > i, for i = 1..=n
            let n = cfg.n_abs_flags;
            let mut i = 1;
            while i <= n {
                let greater = abs > i;
                self.enc.encode(&mut self.ctxs.gr[(i - 1) as usize], greater as u8);
                if !greater {
                    break;
                }
                i += 1;
            }
            if i > n {
                // remainder = |level| - n - 1
                let rem = abs - n - 1;
                match cfg.remainder {
                    RemainderMode::FixedLength(w) => self.enc.encode_bypass_bits(rem, w),
                    RemainderMode::ExpGolomb(k) => {
                        // context-coded EG prefix, bypass suffix (NNR-style);
                        // 64-bit thresholds: k reaches 32 for huge remainders
                        let mut v = rem as u64;
                        let mut k = k;
                        let mut p = 0usize;
                        loop {
                            if k < 63 && v >= (1u64 << k) {
                                let ctx = &mut self.ctxs.eg_prefix
                                    [p.min(super::EG_PREFIX_CTXS - 1)];
                                self.enc.encode(ctx, 1);
                                v -= 1u64 << k;
                                k += 1;
                                p += 1;
                            } else {
                                let ctx = &mut self.ctxs.eg_prefix
                                    [p.min(super::EG_PREFIX_CTXS - 1)];
                                self.enc.encode(ctx, 0);
                                // suffix: k bins of v, MSB first
                                let mut k = k;
                                while k > 32 {
                                    let take = (k - 32).min(16);
                                    self.enc.encode_bypass_bits(0, take);
                                    k -= take;
                                }
                                self.enc.encode_bypass_bits(v as u32, k);
                                break;
                            }
                        }
                    }
                }
            }
        }
        self.prev_sig = (sig, self.prev_sig.0);
        self.count += 1;
    }

    pub fn levels_encoded(&self) -> u64 {
        self.count
    }

    /// Payload bytes buffered so far — a **monotone lower bound** on the
    /// final [`Self::finish`] length (the arithmetic flush appends the
    /// last ~2–3 bytes, and up to a few bits plus deferred carry bytes
    /// are still latent in the engine). The sweep engine's early-abandon
    /// budget polls this: once the lower bound exceeds the budget, the
    /// finished payload necessarily would too.
    pub fn bytes_buffered(&self) -> usize {
        self.enc.bits_written() / 8
    }

    pub fn finish(self) -> Vec<u8> {
        self.enc.finish()
    }
}

/// Streaming level decoder (mirror of [`LevelEncoder`]).
pub struct LevelDecoder<'a> {
    dec: CabacDecoder<'a>,
    ctxs: ContextSet,
    cfg: CodecConfig,
    prev_sig: (bool, bool),
}

impl<'a> LevelDecoder<'a> {
    pub fn new(cfg: CodecConfig, payload: &'a [u8]) -> Self {
        Self {
            dec: CabacDecoder::new(payload),
            ctxs: ContextSet::new(&cfg),
            cfg,
            prev_sig: (false, false),
        }
    }

    pub fn decode_level(&mut self) -> i32 {
        let cfg = self.cfg;
        let sig_idx = ContextSet::sig_ctx_index(&cfg, self.prev_sig);
        let sig = self.dec.decode(&mut self.ctxs.sig[sig_idx]) != 0;
        let mut level = 0i32;
        if sig {
            let negative = self.dec.decode(&mut self.ctxs.sign) != 0;
            let n = cfg.n_abs_flags;
            let mut abs = 1u32;
            let mut i = 1;
            while i <= n {
                let greater = self.dec.decode(&mut self.ctxs.gr[(i - 1) as usize]) != 0;
                if !greater {
                    break;
                }
                abs += 1;
                i += 1;
            }
            if i > n {
                let rem = match cfg.remainder {
                    RemainderMode::FixedLength(w) => self.dec.decode_bypass_bits(w),
                    RemainderMode::ExpGolomb(k) => {
                        // 64-bit accumulation (encoder mirror); hostile
                        // payloads saturate instead of overflowing
                        let mut v = 0u64;
                        let mut k = k;
                        let mut p = 0usize;
                        loop {
                            let ctx = &mut self.ctxs.eg_prefix
                                [p.min(super::EG_PREFIX_CTXS - 1)];
                            if self.dec.decode(ctx) == 1 {
                                if k < 63 {
                                    v = v.saturating_add(1u64 << k);
                                }
                                k += 1;
                                p += 1;
                                if k > 96 {
                                    break; // corrupt stream guard
                                }
                            } else {
                                // suffix: k bins, MSB first (encoder pads
                                // bins above bit 31 with zeros)
                                let mut k = k;
                                while k > 32 {
                                    let take = (k - 32).min(16);
                                    self.dec.decode_bypass_bits(take);
                                    k -= take;
                                }
                                v = v.saturating_add(self.dec.decode_bypass_bits(k) as u64);
                                break;
                            }
                        }
                        v.min(u32::MAX as u64) as u32
                    }
                };
                abs = (n + 1).saturating_add(rem);
            }
            // |i32::MIN| is representable only when negative
            level = if negative {
                (-(abs.min(1u32 << 31) as i64)) as i32
            } else {
                abs.min(i32::MAX as u32) as i32
            };
        }
        self.prev_sig = (sig, self.prev_sig.0);
        level
    }
}

/// Encode a whole tensor of levels; returns the CABAC payload.
pub fn encode_levels(levels: &[i32], cfg: CodecConfig) -> Vec<u8> {
    let mut e = LevelEncoder::with_capacity(cfg, levels.len() / 4 + 16);
    for &l in levels {
        e.encode_level(l);
    }
    e.finish()
}

/// Decode `n` levels from a CABAC payload.
pub fn decode_levels(payload: &[u8], n: usize, cfg: CodecConfig) -> Vec<i32> {
    let mut d = LevelDecoder::new(cfg, payload);
    (0..n).map(|_| d.decode_level()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ptest;

    fn cfgs() -> Vec<CodecConfig> {
        vec![
            CodecConfig::default(),
            CodecConfig { n_abs_flags: 1, ..Default::default() },
            CodecConfig { sig_ctx_neighbors: false, ..Default::default() },
            CodecConfig {
                n_abs_flags: 4,
                remainder: RemainderMode::ExpGolomb(2),
                sig_ctx_neighbors: true,
            },
            CodecConfig::with_fixed_length_for(500, 6),
        ]
    }

    #[test]
    fn roundtrip_hand_cases() {
        for cfg in cfgs() {
            for levels in [
                vec![],
                vec![0],
                vec![1],
                vec![-1],
                vec![0, 0, 0, 0],
                vec![5, -5, 12, -300, 0, 0, 1],
                (-50..50).collect::<Vec<i32>>(),
            ] {
                if let RemainderMode::FixedLength(w) = cfg.remainder {
                    // skip cases whose remainder would overflow the width
                    let max_abs = levels.iter().map(|l| l.unsigned_abs()).max().unwrap_or(0);
                    if max_abs > cfg.n_abs_flags + (1 << w) {
                        continue;
                    }
                }
                let payload = encode_levels(&levels, cfg);
                let got = decode_levels(&payload, levels.len(), cfg);
                assert_eq!(got, levels, "cfg {cfg:?}");
            }
        }
    }

    #[test]
    fn property_roundtrip_random_levels() {
        ptest::quick("levels-roundtrip", |g| {
            let levels = g.levels();
            let n = 1 + g.usize_in(0, 12) as u32;
            let max_abs = levels.iter().map(|l| l.unsigned_abs()).max().unwrap_or(0);
            let cfg = if g.bool() {
                CodecConfig::with_fixed_length_for(max_abs.max(1), n)
            } else {
                CodecConfig {
                    n_abs_flags: n,
                    remainder: RemainderMode::ExpGolomb(g.usize_in(0, 3) as u32),
                    sig_ctx_neighbors: g.bool(),
                }
            };
            let payload = encode_levels(&levels, cfg);
            let got = decode_levels(&payload, levels.len(), cfg);
            if got != levels {
                return Err(format!("mismatch for {} levels (cfg {cfg:?})", levels.len()));
            }
            Ok(())
        });
    }

    #[test]
    fn sparse_tensor_codes_below_entropy_plus_slack() {
        // 95% zeros, levels in {-3..3}: CABAC with adaptive contexts must
        // beat 0.5 bits/weight comfortably.
        let mut rng = crate::util::SplitMix64::new(11);
        let levels: Vec<i32> = (0..100_000)
            .map(|_| {
                if rng.next_f64() < 0.95 {
                    0
                } else {
                    (1 + rng.below(3) as i32) * if rng.next_u64() & 1 == 0 { 1 } else { -1 }
                }
            })
            .collect();
        let payload = encode_levels(&levels, CodecConfig::default());
        let bpw = payload.len() as f64 * 8.0 / levels.len() as f64;
        assert!(bpw < 0.55, "bits/weight = {bpw}");
    }

    #[test]
    fn neighbor_contexts_help_clustered_data() {
        // Significance clustered in runs: neighbour-conditioned sigflag
        // contexts should not be worse than the single-context variant.
        let mut rng = crate::util::SplitMix64::new(5);
        let mut levels = Vec::with_capacity(50_000);
        let mut in_run = false;
        for _ in 0..50_000 {
            if rng.next_f64() < 0.02 {
                in_run = !in_run;
            }
            levels.push(if in_run && rng.next_f64() < 0.8 { 1 } else { 0 });
        }
        let with = encode_levels(&levels, CodecConfig::default()).len();
        let without = encode_levels(
            &levels,
            CodecConfig { sig_ctx_neighbors: false, ..Default::default() },
        )
        .len();
        assert!(
            (with as f64) < (without as f64) * 1.02,
            "with={with} without={without}"
        );
    }
}
