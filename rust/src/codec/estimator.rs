//! CABAC bit-cost estimation — the `R_ik` term of the paper's eq. 1.
//!
//! Given the *current* adaptive context states of a [`LevelEncoder`],
//! [`RateEstimator::level_bits`] returns the fractional number of bits
//! that coding a candidate level would consume right now. Because the
//! contexts adapt as the tensor is scanned, the same level has a
//! different cost at different positions — exactly the coupling the
//! paper exploits ("the bit-size R_ik now also depends on the index i").
//!
//! §Perf: the RD scan evaluates ~9–13 candidate levels per weight, and
//! the naive estimator re-walks the whole binarization (up to `n`
//! AbsGr bins plus the exp-Golomb prefix) for each. Two observations
//! make that O(1) amortized:
//!
//! 1. All per-bin costs come from the precomputed
//!    [`crate::cabac::tables::RateTable`] (H.264-style "fracBits"), so a
//!    single bin is one load.
//! 2. The cost of everything *after* the sign bin depends only on
//!    `|level|` and the gr/eg-prefix context states — which only change
//!    when a **nonzero** level is encoded. [`RateCache`] memoizes those
//!    tail costs per magnitude and is invalidated by a generation
//!    counter the encoder bumps on nonzero encodes; across the zero
//!    runs that dominate sparse tensors the cache stays hot.
//!
//! The memoized path is **bit-identical** to the naive one: both sum
//! the same f32 terms in the same order via the shared [`tail_bits`]
//! (verified by `property_cached_matches_naive_bitwise`).
//!
//! [`LevelEncoder`]: super::binarize::LevelEncoder

use super::{CodecConfig, ContextSet, RemainderMode};

pub struct RateEstimator;

impl RateEstimator {
    /// Fractional bits to code `level` under `ctxs` at a position whose
    /// previous-two significance is `prev_sig`. Pure — no state updates,
    /// no cache. The reference the memoized path is tested against.
    pub fn level_bits(
        cfg: &CodecConfig,
        ctxs: &ContextSet,
        prev_sig: (bool, bool),
        level: i32,
    ) -> f32 {
        let sig_idx = ContextSet::sig_ctx_index(cfg, prev_sig);
        if level == 0 {
            return ctxs.sig[sig_idx].bits(0);
        }
        ctxs.sig[sig_idx].bits(1)
            + ctxs.sign.bits((level < 0) as u8)
            + tail_bits(cfg, ctxs, level.unsigned_abs())
    }
}

/// Cost of everything after the sign bin — the AbsGr(i) chain plus the
/// remainder — for a magnitude `abs >= 1`.
///
/// Shared by the naive estimator and [`RateCache`] so both produce
/// bit-identical f32 sums (f32 addition is order-sensitive; one
/// accumulation order, one function).
pub fn tail_bits(cfg: &CodecConfig, ctxs: &ContextSet, abs: u32) -> f32 {
    debug_assert!(abs >= 1);
    let mut bits = 0.0f32;
    let n = cfg.n_abs_flags;
    let mut i = 1;
    while i <= n {
        let greater = abs > i;
        bits += ctxs.gr[(i - 1) as usize].bits(greater as u8);
        if !greater {
            return bits;
        }
        i += 1;
    }
    let rem = abs - n - 1;
    match cfg.remainder {
        RemainderMode::FixedLength(w) => bits += w as f32,
        RemainderMode::ExpGolomb(k) => {
            // context-coded prefix + bypass suffix (mirror of the coder);
            // 64-bit thresholds: k reaches 32 for u32-sized remainders
            let mut v = rem as u64;
            let mut k = k;
            let mut p = 0usize;
            loop {
                let ctx = &ctxs.eg_prefix[p.min(super::EG_PREFIX_CTXS - 1)];
                if k < 63 && v >= (1u64 << k) {
                    bits += ctx.bits(1);
                    v -= 1u64 << k;
                    k += 1;
                    p += 1;
                } else {
                    bits += ctx.bits(0) + k as f32;
                    break;
                }
            }
        }
    }
    bits
}

/// Largest |level| whose tail cost is memoized; beyond this the (rare)
/// candidate falls back to the direct walk. Grids in this codebase top
/// out at a few hundred levels.
const MAX_CACHED_ABS: usize = 4096;

/// Memoized tail costs per magnitude, invalidated by a generation
/// counter (bumped by the encoder whenever a nonzero level updates the
/// gr/eg-prefix contexts). Storage is allocated lazily on first use.
#[derive(Debug, Clone)]
pub struct RateCache {
    tail: Vec<f32>,
    tag: Vec<u64>,
    gen: u64,
}

impl Default for RateCache {
    fn default() -> Self {
        Self::new()
    }
}

impl RateCache {
    pub fn new() -> Self {
        // gen starts at 1 so zeroed tags read as stale
        Self { tail: Vec::new(), tag: Vec::new(), gen: 1 }
    }

    /// Drop all memoized tails (contexts feeding them changed).
    #[inline]
    pub fn invalidate(&mut self) {
        self.gen += 1;
    }

    /// Memoized [`tail_bits`]. Bit-identical to the direct call.
    #[inline]
    pub fn tail_bits(&mut self, cfg: &CodecConfig, ctxs: &ContextSet, abs: u32) -> f32 {
        let idx = (abs - 1) as usize;
        if idx >= MAX_CACHED_ABS {
            return tail_bits(cfg, ctxs, abs);
        }
        if idx >= self.tail.len() {
            self.tail.resize(MAX_CACHED_ABS, 0.0);
            self.tag.resize(MAX_CACHED_ABS, 0);
        }
        if self.tag[idx] != self.gen {
            self.tail[idx] = tail_bits(cfg, ctxs, abs);
            self.tag[idx] = self.gen;
        }
        self.tail[idx]
    }
}

/// Length in bins of an order-k exp-Golomb codeword for v.
///
/// 64-bit thresholds: for `v` near `u32::MAX` the running order reaches
/// 32, where `1u32 << k` would panic in debug builds.
pub fn eg_len(v: u32, k: u32) -> u32 {
    let mut v = v as u64;
    let mut k = k;
    let mut len = 0;
    loop {
        if k < 63 && v >= (1u64 << k) {
            len += 1;
            v -= 1u64 << k;
            k += 1;
        } else {
            return len + 1 + k;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::binarize::LevelEncoder;
    use super::*;
    use crate::util::ptest;

    #[test]
    fn eg_lengths() {
        // order 0: 0 -> "0" (1 bin); 1 -> "10 0"? order-0 EG as implemented:
        // v=0: stop bit + 0 suffix = 1 bin; v=1: 1, then k=1, v=0 -> stop +
        // 1 suffix = 3 bins; v in [1,2] -> 3 bins; v in [3,6] -> 5 bins.
        assert_eq!(eg_len(0, 0), 1);
        assert_eq!(eg_len(1, 0), 3);
        assert_eq!(eg_len(2, 0), 3);
        assert_eq!(eg_len(3, 0), 5);
        assert_eq!(eg_len(6, 0), 5);
        assert_eq!(eg_len(7, 0), 7);
        // order 2: v=0 -> 1 + 2 suffix bits
        assert_eq!(eg_len(0, 2), 3);
    }

    #[test]
    fn eg_len_u32_max_regression() {
        // u32::MAX: 32 prefix ones + stop + 32 suffix bits (the prefix
        // loop reaches k = 32, where `1u32 << k` used to panic)
        assert_eq!(eg_len(u32::MAX, 0), 65);
        // one less never reaches k = 32: 31 ones + stop + 31 suffix
        assert_eq!(eg_len(u32::MAX - 1, 0), 63);
        // large order start: one prefix one, stop, 32 suffix bits
        assert_eq!(eg_len(u32::MAX, 31), 34);
    }

    #[test]
    fn estimate_tracks_actual_bits() {
        // Encode a long random stream; the summed estimates (taken right
        // before each encode) must match the final payload size within a
        // small relative error — this validates the estimator the RD
        // quantizer relies on.
        let mut rng = crate::util::SplitMix64::new(23);
        let levels: Vec<i32> = (0..30_000)
            .map(|_| {
                if rng.next_f64() < 0.8 {
                    0
                } else {
                    let mag = 1 + rng.below(30) as i32;
                    if rng.next_u64() & 1 == 0 {
                        mag
                    } else {
                        -mag
                    }
                }
            })
            .collect();
        let cfg = CodecConfig::default();
        let mut enc = LevelEncoder::new(cfg);
        let mut est_total = 0.0f64;
        for &l in &levels {
            est_total +=
                RateEstimator::level_bits(&cfg, &enc.ctxs, enc.prev_sig(), l) as f64;
            enc.encode_level(l);
        }
        let actual = enc.finish().len() as f64 * 8.0;
        let rel = (est_total - actual).abs() / actual;
        assert!(rel < 0.02, "estimate {est_total:.0} vs actual {actual:.0} ({rel:.3})");
    }

    #[test]
    fn property_estimate_close_over_distributions() {
        ptest::quick("estimator-close", |g| {
            let levels = g.levels();
            if levels.len() < 500 {
                return Ok(()); // relative error meaningless on tiny payloads
            }
            let cfg = CodecConfig::default();
            let mut enc = LevelEncoder::new(cfg);
            let mut est = 0.0f64;
            for &l in &levels {
                est += RateEstimator::level_bits(&cfg, &enc.ctxs, enc.prev_sig(), l) as f64;
                enc.encode_level(l);
            }
            let actual = enc.finish().len() as f64 * 8.0;
            if actual < 1000.0 {
                // flush overhead (~2 bytes) dominates tiny payloads;
                // relative error is not meaningful there
                return Ok(());
            }
            let rel = (est - actual).abs() / actual;
            if rel > 0.08 {
                return Err(format!("estimator off by {rel:.3} on {} levels", levels.len()));
            }
            Ok(())
        });
    }

    #[test]
    fn property_cached_matches_naive_bitwise() {
        // The memoized RateTable/tail-cache path must return *bit-identical*
        // f32 costs to the naive walk, across random context states reached
        // by real encoding, all candidate magnitudes, and all configs.
        ptest::check(
            ptest::Config { cases: 64, max_size: 600, ..Default::default() },
            "cached-estimator-parity",
            |g| {
                let cfg = if g.bool() {
                    CodecConfig {
                        n_abs_flags: 1 + g.usize_in(0, 12) as u32,
                        remainder: RemainderMode::ExpGolomb(g.usize_in(0, 3) as u32),
                        sig_ctx_neighbors: g.bool(),
                    }
                } else {
                    CodecConfig::with_fixed_length_for(200, 1 + g.usize_in(0, 8) as u32)
                };
                let levels = g.levels();
                let mut enc = LevelEncoder::new(cfg);
                for (step, &l) in levels.iter().enumerate() {
                    // probe a spread of candidates at this context state
                    for cand in [-200, -37, -3, -1, 0, 1, 2, 5, 40, 4097] {
                        let naive =
                            RateEstimator::level_bits(&cfg, &enc.ctxs, enc.prev_sig(), cand);
                        let cached = enc.estimate_level_bits(cand);
                        if naive.to_bits() != cached.to_bits() {
                            return Err(format!(
                                "step {step} cand {cand}: naive {naive} != cached {cached}"
                            ));
                        }
                        // probe twice: the second hit comes from the cache
                        let cached2 = enc.estimate_level_bits(cand);
                        if cached2.to_bits() != naive.to_bits() {
                            return Err(format!("step {step} cand {cand}: cache hit differs"));
                        }
                    }
                    enc.encode_level(l);
                }
                Ok(())
            },
        );
    }

    #[test]
    fn zero_is_cheapest_in_fresh_context() {
        let cfg = CodecConfig::default();
        let ctxs = ContextSet::new(&cfg);
        let zero = RateEstimator::level_bits(&cfg, &ctxs, (false, false), 0);
        for l in [1, -1, 2, 7, -100] {
            assert!(RateEstimator::level_bits(&cfg, &ctxs, (false, false), l) > zero);
        }
    }

    #[test]
    fn larger_magnitude_never_cheaper() {
        let cfg = CodecConfig::default();
        let ctxs = ContextSet::new(&cfg);
        let mut prev = 0.0;
        for mag in 1..200 {
            let b = RateEstimator::level_bits(&cfg, &ctxs, (true, true), mag);
            assert!(b + 1e-4 >= prev, "mag {mag}: {b} < {prev}");
            prev = b;
        }
    }
}
