//! CABAC bit-cost estimation — the `R_ik` term of the paper's eq. 1.
//!
//! Given the *current* adaptive context states of a [`LevelEncoder`],
//! [`RateEstimator::level_bits`] returns the fractional number of bits
//! that coding a candidate level would consume right now. Because the
//! contexts adapt as the tensor is scanned, the same level has a
//! different cost at different positions — exactly the coupling the
//! paper exploits ("the bit-size R_ik now also depends on the index i").

use super::{CodecConfig, ContextSet, RemainderMode};

pub struct RateEstimator;

impl RateEstimator {
    /// Fractional bits to code `level` under `ctxs` at a position whose
    /// previous-two significance is `prev_sig`. Pure — no state updates.
    pub fn level_bits(
        cfg: &CodecConfig,
        ctxs: &ContextSet,
        prev_sig: (bool, bool),
        level: i32,
    ) -> f32 {
        let sig_idx = ContextSet::sig_ctx_index(cfg, prev_sig);
        if level == 0 {
            return ctxs.sig[sig_idx].bits(0);
        }
        let mut bits = ctxs.sig[sig_idx].bits(1);
        bits += ctxs.sign.bits((level < 0) as u8);
        let abs = level.unsigned_abs();
        let n = cfg.n_abs_flags;
        let mut i = 1;
        while i <= n {
            let greater = abs > i;
            bits += ctxs.gr[(i - 1) as usize].bits(greater as u8);
            if !greater {
                return bits;
            }
            i += 1;
        }
        let rem = abs - n - 1;
        match cfg.remainder {
            RemainderMode::FixedLength(w) => bits += w as f32,
            RemainderMode::ExpGolomb(k) => {
                // context-coded prefix + bypass suffix (mirror of the coder)
                let mut v = rem;
                let mut k = k;
                let mut p = 0usize;
                loop {
                    let ctx = &ctxs.eg_prefix[p.min(super::EG_PREFIX_CTXS - 1)];
                    if v >= (1 << k) {
                        bits += ctx.bits(1);
                        v -= 1 << k;
                        k += 1;
                        p += 1;
                    } else {
                        bits += ctx.bits(0) + k as f32;
                        break;
                    }
                }
            }
        }
        bits
    }
}

/// Length in bins of an order-k exp-Golomb codeword for v.
pub fn eg_len(v: u32, k: u32) -> u32 {
    let mut v = v;
    let mut k = k;
    let mut len = 0;
    loop {
        if v >= (1 << k) {
            len += 1;
            v -= 1 << k;
            k += 1;
        } else {
            return len + 1 + k;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::binarize::LevelEncoder;
    use super::*;
    use crate::util::ptest;

    #[test]
    fn eg_lengths() {
        // order 0: 0 -> "0" (1 bin); 1 -> "10 0"? order-0 EG as implemented:
        // v=0: stop bit + 0 suffix = 1 bin; v=1: 1, then k=1, v=0 -> stop +
        // 1 suffix = 3 bins; v in [1,2] -> 3 bins; v in [3,6] -> 5 bins.
        assert_eq!(eg_len(0, 0), 1);
        assert_eq!(eg_len(1, 0), 3);
        assert_eq!(eg_len(2, 0), 3);
        assert_eq!(eg_len(3, 0), 5);
        assert_eq!(eg_len(6, 0), 5);
        assert_eq!(eg_len(7, 0), 7);
        // order 2: v=0 -> 1 + 2 suffix bits
        assert_eq!(eg_len(0, 2), 3);
    }

    #[test]
    fn estimate_tracks_actual_bits() {
        // Encode a long random stream; the summed estimates (taken right
        // before each encode) must match the final payload size within a
        // small relative error — this validates the estimator the RD
        // quantizer relies on.
        let mut rng = crate::util::SplitMix64::new(23);
        let levels: Vec<i32> = (0..30_000)
            .map(|_| {
                if rng.next_f64() < 0.8 {
                    0
                } else {
                    let mag = 1 + rng.below(30) as i32;
                    if rng.next_u64() & 1 == 0 {
                        mag
                    } else {
                        -mag
                    }
                }
            })
            .collect();
        let cfg = CodecConfig::default();
        let mut enc = LevelEncoder::new(cfg);
        let mut est_total = 0.0f64;
        for &l in &levels {
            est_total +=
                RateEstimator::level_bits(&cfg, &enc.ctxs, enc.prev_sig(), l) as f64;
            enc.encode_level(l);
        }
        let actual = enc.finish().len() as f64 * 8.0;
        let rel = (est_total - actual).abs() / actual;
        assert!(rel < 0.02, "estimate {est_total:.0} vs actual {actual:.0} ({rel:.3})");
    }

    #[test]
    fn property_estimate_close_over_distributions() {
        ptest::quick("estimator-close", |g| {
            let levels = g.levels();
            if levels.len() < 500 {
                return Ok(()); // relative error meaningless on tiny payloads
            }
            let cfg = CodecConfig::default();
            let mut enc = LevelEncoder::new(cfg);
            let mut est = 0.0f64;
            for &l in &levels {
                est += RateEstimator::level_bits(&cfg, &enc.ctxs, enc.prev_sig(), l) as f64;
                enc.encode_level(l);
            }
            let actual = enc.finish().len() as f64 * 8.0;
            if actual < 1000.0 {
                // flush overhead (~2 bytes) dominates tiny payloads;
                // relative error is not meaningful there
                return Ok(());
            }
            let rel = (est - actual).abs() / actual;
            if rel > 0.08 {
                return Err(format!("estimator off by {rel:.3} on {} levels", levels.len()));
            }
            Ok(())
        });
    }

    #[test]
    fn zero_is_cheapest_in_fresh_context() {
        let cfg = CodecConfig::default();
        let ctxs = ContextSet::new(&cfg);
        let zero = RateEstimator::level_bits(&cfg, &ctxs, (false, false), 0);
        for l in [1, -1, 2, 7, -100] {
            assert!(RateEstimator::level_bits(&cfg, &ctxs, (false, false), l) > zero);
        }
    }

    #[test]
    fn larger_magnitude_never_cheaper() {
        let cfg = CodecConfig::default();
        let ctxs = ContextSet::new(&cfg);
        let mut prev = 0.0;
        for mag in 1..200 {
            let b = RateEstimator::level_bits(&cfg, &ctxs, (true, true), mag);
            assert!(b + 1e-4 >= prev, "mag {mag}: {b} < {prev}");
            prev = b;
        }
    }
}
