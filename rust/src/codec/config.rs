//! Codec configuration — the paper's encoder hyper-parameters.

/// How the remainder (|level| − n − 1 once all AbsGr flags fired) is
/// bypass-coded.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RemainderMode {
    /// Fixed-length code of the given bit width (the paper's choice; the
    /// width is derived from the tensor's max level and stored per layer).
    FixedLength(u32),
    /// Exp-Golomb of order k (an extension; self-delimiting, so no width
    /// needs to be signalled).
    ExpGolomb(u32),
}

impl RemainderMode {
    pub fn tag(&self) -> u8 {
        match self {
            RemainderMode::FixedLength(_) => 0,
            RemainderMode::ExpGolomb(_) => 1,
        }
    }

    pub fn param(&self) -> u32 {
        match self {
            RemainderMode::FixedLength(w) => *w,
            RemainderMode::ExpGolomb(k) => *k,
        }
    }

    pub fn from_tag(tag: u8, param: u32) -> Option<Self> {
        match tag {
            0 => Some(RemainderMode::FixedLength(param)),
            1 => Some(RemainderMode::ExpGolomb(param)),
            _ => None,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CodecConfig {
    /// The paper's hyper-parameter n: number of AbsGr(i) flags before
    /// falling through to the bypass remainder.
    pub n_abs_flags: u32,
    pub remainder: RemainderMode,
    /// Condition the sigflag context on the significance of the previous
    /// two weights in scan order (local-statistics adaptation).
    pub sig_ctx_neighbors: bool,
}

impl Default for CodecConfig {
    fn default() -> Self {
        Self {
            n_abs_flags: 10,
            remainder: RemainderMode::ExpGolomb(0),
            sig_ctx_neighbors: true,
        }
    }
}

impl CodecConfig {
    /// Derive the fixed-length remainder width for a tensor whose largest
    /// absolute level is `max_abs` (paper's fixed-length variant).
    pub fn with_fixed_length_for(max_abs: u32, n_abs_flags: u32) -> Self {
        let max_rem = max_abs.saturating_sub(n_abs_flags + 1);
        let width = 32 - max_rem.leading_zeros().min(31);
        let width = if max_rem == 0 { 0 } else { width };
        Self {
            n_abs_flags,
            remainder: RemainderMode::FixedLength(width),
            sig_ctx_neighbors: true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_length_width_derivation() {
        // max_abs = 12, n = 10 -> max remainder = 1 -> 1 bit
        let cfg = CodecConfig::with_fixed_length_for(12, 10);
        assert_eq!(cfg.remainder, RemainderMode::FixedLength(1));
        // max_abs <= n+1 -> no remainder bits needed
        let cfg = CodecConfig::with_fixed_length_for(11, 10);
        assert_eq!(cfg.remainder, RemainderMode::FixedLength(0));
        // max_abs = 300, n=10 -> rem 289 -> 9 bits
        let cfg = CodecConfig::with_fixed_length_for(300, 10);
        assert_eq!(cfg.remainder, RemainderMode::FixedLength(9));
    }

    #[test]
    fn tag_roundtrip() {
        for m in [RemainderMode::FixedLength(7), RemainderMode::ExpGolomb(2)] {
            assert_eq!(RemainderMode::from_tag(m.tag(), m.param()), Some(m));
        }
        assert_eq!(RemainderMode::from_tag(9, 0), None);
    }
}
