//! Compression pipeline metrics — the numbers Table 1 reports.

use crate::model::{CompressedModel, Model};

#[derive(Debug, Clone)]
pub struct LayerReport {
    pub name: String,
    pub n_weights: usize,
    pub nonzero: usize,
    pub payload_bytes: usize,
    /// Independently coded chunks (container v2 intra-layer parallelism);
    /// 1 = the monolithic v1 stream.
    pub n_chunks: usize,
    /// Σ η (w − q)² over the layer.
    pub distortion: f64,
    /// Estimated rate (bits) from the RD scan.
    pub est_bits: f64,
    /// Weights whose warm-start seed candidate was the chosen level
    /// (0 outside warm sweep probes — see `quant::ScanSeed`).
    pub seed_hits: usize,
    /// Weights scanned with a warm-start seed (0 for cold scans).
    pub seeded: usize,
    pub time_s: f64,
}

impl LayerReport {
    pub fn bits_per_weight(&self) -> f64 {
        self.payload_bytes as f64 * 8.0 / self.n_weights.max(1) as f64
    }

    pub fn density(&self) -> f64 {
        self.nonzero as f64 / self.n_weights.max(1) as f64
    }
}

#[derive(Debug, Clone)]
pub struct ModelReport {
    pub name: String,
    /// Raw f32 size of weights + biases (the "Org. size" column).
    pub raw_bytes: usize,
    /// Serialized DCBC container size.
    pub compressed_bytes: usize,
    /// Post-quantization density (levels ≠ 0).
    pub density: f64,
    pub layers: Vec<LayerReport>,
    pub total_time_s: f64,
}

impl ModelReport {
    pub fn from_layers(
        model: &Model,
        compressed: &CompressedModel,
        layers: Vec<LayerReport>,
    ) -> Self {
        Self::from_layers_sized(model, compressed.serialize().len(), layers)
    }

    /// [`Self::from_layers`] when the caller already serialized the
    /// container (the sweep engine hashes the bytes for its per-point
    /// identity fingerprint anyway — this avoids serializing twice).
    pub fn from_layers_sized(
        model: &Model,
        compressed_bytes: usize,
        layers: Vec<LayerReport>,
    ) -> Self {
        let nonzero: usize = layers.iter().map(|l| l.nonzero).sum();
        let total: usize = layers.iter().map(|l| l.n_weights).sum();
        Self {
            name: model.manifest.name.clone(),
            raw_bytes: model.raw_bytes(),
            compressed_bytes,
            density: nonzero as f64 / total.max(1) as f64,
            total_time_s: layers.iter().map(|l| l.time_s).sum(),
            layers,
        }
    }

    /// "Comp. ratio" column: compressed size as a % of the original.
    pub fn ratio_percent(&self) -> f64 {
        self.compressed_bytes as f64 / self.raw_bytes.max(1) as f64 * 100.0
    }

    /// Compression factor, e.g. 63.6 for the paper's VGG16 headline.
    pub fn factor(&self) -> f64 {
        self.raw_bytes as f64 / self.compressed_bytes.max(1) as f64
    }

    pub fn bits_per_weight(&self) -> f64 {
        let n: usize = self.layers.iter().map(|l| l.n_weights).sum();
        self.layers.iter().map(|l| l.payload_bytes).sum::<usize>() as f64 * 8.0
            / n.max(1) as f64
    }

    /// Total independently decodable streams across all layers (equals
    /// the layer count for monolithic containers).
    pub fn total_chunks(&self) -> usize {
        self.layers.iter().map(|l| l.n_chunks).sum()
    }
}

/// Aggregate statistics of one (S × λ) sweep run — the numbers
/// `BENCH_sweep.json` records next to the per-point frontier.
#[derive(Debug, Clone, Copy, Default)]
pub struct SweepStats {
    /// Grid points probed (each point = one (S, λ) cell over all layers).
    pub probes_total: usize,
    /// Points abandoned early under the active abandon mode (see
    /// `sweep::AbandonMode`): over their λ-column's payload budget and —
    /// in the frontier-preserving mode — provably Pareto-dominated.
    pub probes_abandoned: usize,
    /// Abandoned probes cut mid-scan by the in-layer 512-weight poll.
    pub abandoned_mid_layer: usize,
    /// Abandoned probes cut at a layer boundary by the coordinator.
    pub abandoned_boundary: usize,
    /// Scheduling rounds executed (1 for a flat sweep; coarse round +
    /// refinement rounds for the coarse-to-fine driver).
    pub rounds: usize,
    /// Distinct λ-columns of the swept surface.
    pub columns: usize,
    /// Weights scanned with a warm-start seed across all probes.
    pub seeded_weights: u64,
    /// Seeded weights whose seed candidate was the chosen level.
    pub seed_hits: u64,
    /// Wall clock of the whole sweep.
    pub wall_s: f64,
}

impl SweepStats {
    /// Fraction of seeded weights whose seed was the argmin (0 when the
    /// sweep ran cold).
    pub fn seed_hit_rate(&self) -> f64 {
        self.seed_hits as f64 / (self.seeded_weights.max(1)) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_report_derived_stats() {
        let r = LayerReport {
            name: "l".into(),
            n_weights: 1000,
            nonzero: 100,
            payload_bytes: 125,
            n_chunks: 1,
            distortion: 0.0,
            est_bits: 1000.0,
            seed_hits: 0,
            seeded: 0,
            time_s: 0.0,
        };
        assert!((r.bits_per_weight() - 1.0).abs() < 1e-12);
        assert!((r.density() - 0.1).abs() < 1e-12);
    }
}
