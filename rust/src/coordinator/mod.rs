//! Layer-3 coordinator: the compression pipeline (per-layer workers,
//! bounded queues), the parallel incremental (S × λ) sweep engine
//! (paper §4 probes S ∈ {0,…,256} and keeps the best; the journal
//! version sweeps the λ trade-off too; the engine fans (layer × S × λ)
//! probe tasks onto a worker pool, hoists per-tensor statistics across
//! the whole surface, warm-starts refinement probes from their
//! λ-column incumbents, early-abandons probes that are provably out of
//! the race under a selectable [`AbandonMode`], and emits the Pareto
//! size/distortion frontier), and pipeline metrics.

pub mod metrics;
pub mod pipeline;
pub mod sweep;

pub use metrics::{LayerReport, ModelReport, SweepStats};
pub use pipeline::{
    compress_model, compress_tensor, compress_tensor_chunked, CompressionSpec, LayerProbe,
    LayerStats,
};
pub use sweep::{
    sweep_delta, sweep_grid, sweep_per_layer, sweep_progressive, sweep_s, sweep_s_auto,
    sweep_s_per_layer, AbandonKind, AbandonMode, ColumnBest, GridPoint, ProgressiveSweep,
    SweepEngine, SweepOptions, SweepPoint, SweepResult,
};
