//! Layer-3 coordinator: the compression pipeline (per-layer workers,
//! bounded queues), the S-sweep scheduler (paper §4 probes
//! S ∈ {0,…,256} and keeps the best), and pipeline metrics.

pub mod metrics;
pub mod pipeline;
pub mod sweep;

pub use metrics::{LayerReport, ModelReport};
pub use pipeline::{
    compress_model, compress_tensor, compress_tensor_chunked, CompressionSpec,
};
pub use sweep::{sweep_s, SweepPoint, SweepResult};
