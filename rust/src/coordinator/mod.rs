//! Layer-3 coordinator: the compression pipeline (per-layer workers,
//! bounded queues), the parallel incremental S-sweep engine (paper §4
//! probes S ∈ {0,…,256} and keeps the best; the engine fans (layer × S)
//! probe tasks onto a worker pool, hoists per-tensor statistics across
//! probes, and early-abandons probes that can no longer win), and
//! pipeline metrics.

pub mod metrics;
pub mod pipeline;
pub mod sweep;

pub use metrics::{LayerReport, ModelReport, SweepStats};
pub use pipeline::{
    compress_model, compress_tensor, compress_tensor_chunked, CompressionSpec, LayerStats,
};
pub use sweep::{
    sweep_s, sweep_s_auto, sweep_s_per_layer, SweepEngine, SweepOptions, SweepPoint,
    SweepResult,
};
