//! The parallel, incremental S-sweep engine.
//!
//! The paper probes the grid coarseness S ∈ {0, …, 256} per model and
//! keeps the best-compressing setting ("Since the compression result can
//! be sensitive to the parameter S in (2), we probed the compression
//! performance for all S ∈ {0,...,256} and selected the best performing
//! model" — §4). Done naively that is ~257× the cost of one full
//! compression. This engine attacks the sweep on three axes:
//!
//! 1. **Parallel probes** — the sweep expands into (layer × S) probe
//!    tasks fanned onto a shared [`WorkerPool`]. A point's layer tasks
//!    are *chained* (layer ℓ+1 is dispatched when layer ℓ completes, by
//!    the coordinator thread — jobs never submit jobs, which would
//!    deadlock the pool's bounded queue), so parallelism comes from many
//!    S points in flight at once and every point's running payload total
//!    is deterministic.
//! 2. **Hoisted invariants** — w_max, σ_min, η, mean(η) do not depend on
//!    S, so they are computed once per layer ([`LayerStats`]) and shared
//!    by all of that layer's probes.
//! 3. **Early abandonment** — once some point has completed, any probe
//!    whose accumulated payload can no longer fit inside the best
//!    container is aborted mid-scan. The budget is
//!    `best_serialized − min_overhead` where `min_overhead` is a lower
//!    bound on a container's non-payload bytes, so an abandoned point
//!    provably serializes strictly larger than the incumbent:
//!    **abandonment never changes the winner**, and because budgets are
//!    fixed per round the set of abandoned points is a pure function of
//!    the schedule — identical across worker counts (the determinism
//!    tests pin both properties).
//!
//! On top of the engine, [`sweep_s_auto`] runs a coarse-to-fine driver:
//! probe a coarse grid, then repeatedly refine around the argmin until
//! every integer between its probed neighbours has been tried
//! (`exhaustive` forces all 257 points in one round instead).

use super::metrics::{LayerReport, ModelReport, SweepStats};
use super::pipeline::{self, CompressionSpec, LayerStats};
use crate::model::{CompressedLayer, CompressedModel, Model};
use crate::util::par::WorkerPool;
use crate::util::Timer;
use anyhow::{bail, Result};
use std::collections::BTreeSet;
use std::sync::{mpsc, Arc};

#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub s: u32,
    /// Serialized container size at this S. For abandoned probes this is
    /// the payload accumulated before the abort — a lower bound, recorded
    /// so the frontier report still shows *why* the point lost.
    pub compressed_bytes: usize,
    pub density: f64,
    pub distortion: f64,
    /// True if the probe was cut short by the early-abandon budget
    /// (density/distortion are then 0 — the point never completed).
    pub abandoned: bool,
    /// Summed wall clock of this point's probe tasks (reporting only —
    /// not deterministic, excluded from the determinism tests).
    pub wall_s: f64,
}

#[derive(Debug)]
pub struct SweepResult {
    /// Every probed point, in schedule order (deterministic).
    pub points: Vec<SweepPoint>,
    /// The best (smallest-container) probe; ties go to the earlier
    /// schedule position, exactly like the original serial sweep.
    pub best: (CompressedModel, ModelReport),
    pub stats: SweepStats,
}

/// Options for [`sweep_s_auto`].
#[derive(Debug, Clone, Copy)]
pub struct SweepOptions {
    /// Points per scheduling round (coarse grid size and refinement
    /// fan-out).
    pub points: usize,
    pub workers: usize,
    /// Probe all 257 values in one round instead of coarse-to-fine.
    pub exhaustive: bool,
    /// Early-abandon refinement probes that can no longer win.
    pub abandon: bool,
}

impl Default for SweepOptions {
    fn default() -> Self {
        Self { points: 17, workers: 1, exhaustive: false, abandon: true }
    }
}

/// Coarse-to-fine S grid covering {0..=256} with ~n points.
pub fn default_s_grid(n: usize) -> Vec<u32> {
    if n >= 257 {
        return (0..=256).collect();
    }
    let mut out: Vec<u32> = (0..n)
        .map(|i| ((i as f64 / (n - 1).max(1) as f64) * 256.0).round() as u32)
        .collect();
    out.dedup();
    out
}

/// Shared, immutable probe context — cloned out of the caller's model
/// once so probe tasks are `'static` for the worker pool.
struct ProbeCtx {
    model: Model,
    stats: Vec<LayerStats>,
    base: CompressionSpec,
    /// Lower bound on the serialized non-payload bytes of any container
    /// this model/spec can produce (see [`min_overhead`]).
    min_overhead: usize,
}

struct Best {
    s: u32,
    bytes: usize,
    model: CompressedModel,
    report: ModelReport,
}

/// LEB128 length of a varint (mirrors `bitstream::write_varint`).
fn varint_len(mut v: u64) -> usize {
    let mut n = 1;
    while v >= 0x80 {
        v >>= 7;
        n += 1;
    }
    n
}

/// Lower bound on the non-payload bytes of a serialized container for
/// `model`: every S-independent field is counted exactly, and each
/// S-dependent varint (max_level, s_param, payload_len) at its 1-byte
/// minimum; v2 chunk tables are omitted (they only add bytes). Used to
/// convert the best *serialized* size into a *payload* budget:
/// `payload(p) > best_bytes − min_overhead` implies
/// `serialized(p) > best_bytes`.
fn min_overhead(model: &Model) -> usize {
    let mut c = 4 + 1; // magic + version
    c += varint_len(model.manifest.name.len() as u64) + model.manifest.name.len();
    c += varint_len(model.weights.len() as u64);
    for i in 0..model.weights.len() {
        let name = &model.manifest.layers[i].name;
        let dims = &model.weights[i].shape;
        c += varint_len(name.len() as u64) + name.len();
        c += varint_len(dims.len() as u64);
        for &d in dims {
            c += varint_len(d as u64);
        }
        c += 4; // grid delta (f32)
        c += 1; // max_level varint, ≥ 1 byte
        c += 1; // s_param varint, ≥ 1 byte
        c += 4; // codec config bytes
        c += varint_len(model.weights[i].data.len() as u64); // n_weights
        c += 1; // payload_len varint, ≥ 1 byte
        let bl = model.biases[i].data.len();
        c += varint_len(bl as u64) + bl * 4;
    }
    c
}

/// The reusable sweep engine: create once, feed scheduling rounds, then
/// [`SweepEngine::finish`]. Rounds are barriers — the abandon budget is
/// fixed when a round starts, which is what makes the abandoned set
/// deterministic.
pub struct SweepEngine {
    ctx: Arc<ProbeCtx>,
    pool: WorkerPool,
    probed: BTreeSet<u32>,
    points: Vec<SweepPoint>,
    best: Option<Best>,
    rounds: usize,
    abandoned: usize,
    timer: Timer,
}

impl SweepEngine {
    /// Precomputes [`LayerStats`] for every layer (in parallel) and
    /// clones the model once so probe tasks can outlive the caller's
    /// borrow.
    pub fn new(model: &Model, base: &CompressionSpec, workers: usize) -> Self {
        let stats = crate::util::par::map_indexed(model.weights.len(), workers, |i| {
            LayerStats::compute(&model.weights[i].data, &model.sigmas[i].data, base.weighted)
        });
        let min_overhead = min_overhead(model);
        // Slim clone: σ tensors are already folded into LayerStats and
        // nothing downstream reads them, so don't hold a second
        // weights-sized copy for the engine's lifetime.
        let slim = Model {
            manifest: model.manifest.clone(),
            weights: model.weights.clone(),
            biases: model.biases.clone(),
            sigmas: model
                .weights
                .iter()
                .map(|_| crate::tensor::Tensor::new(vec![0], vec![]))
                .collect(),
        };
        Self {
            ctx: Arc::new(ProbeCtx {
                model: slim,
                stats,
                base: *base,
                min_overhead,
            }),
            pool: WorkerPool::new(workers),
            probed: BTreeSet::new(),
            points: Vec::new(),
            best: None,
            rounds: 0,
            abandoned: 0,
            timer: Timer::new(),
        }
    }

    /// S of the best completed probe so far.
    pub fn best_s(&self) -> Option<u32> {
        self.best.as_ref().map(|b| b.s)
    }

    /// Payload-byte budget derived from the incumbent (see the module
    /// docs); `usize::MAX` (never abandon) until a first point completes.
    fn budget(&self) -> usize {
        self.best
            .as_ref()
            .map(|b| b.bytes.saturating_sub(self.ctx.min_overhead))
            .unwrap_or(usize::MAX)
    }

    /// Probe every not-yet-probed S in `s_list` (duplicates and repeats
    /// are skipped), with early abandonment iff `abandon`. The budget is
    /// fixed on entry, so which probes get abandoned depends only on the
    /// schedule — not on worker count or timing.
    pub fn run_round(&mut self, s_list: &[u32], abandon: bool) {
        let s_list: Vec<u32> =
            s_list.iter().copied().filter(|s| self.probed.insert(*s)).collect();
        if s_list.is_empty() {
            return;
        }
        self.rounds += 1;
        let budget = if abandon { self.budget() } else { usize::MAX };
        let (points, round_best) = run_probes(&self.ctx, &self.pool, &s_list, budget);
        self.abandoned += points.iter().filter(|p| p.abandoned).count();
        self.points.extend(points);
        if let Some(rb) = round_best {
            // strict < : earlier rounds win ties, matching the serial
            // sweep's first-smallest selection
            let better = self.best.as_ref().map(|b| rb.bytes < b.bytes).unwrap_or(true);
            if better {
                self.best = Some(rb);
            }
        }
    }

    pub fn finish(self) -> Result<SweepResult> {
        let Some(best) = self.best else {
            bail!(
                "S sweep completed no probe points ({} scheduled) — \
                 the candidate grid must contain at least one S value",
                self.points.len()
            );
        };
        Ok(SweepResult {
            best: (best.model, best.report),
            stats: SweepStats {
                probes_total: self.points.len(),
                probes_abandoned: self.abandoned,
                rounds: self.rounds,
                wall_s: self.timer.elapsed_s(),
            },
            points: self.points,
        })
    }
}

/// One scheduling round: chained (layer × S) tasks on the pool, returning
/// the per-point records in `s_list` order plus the round's best
/// completed container (smallest bytes, ties to the earlier schedule
/// index — independent of completion order).
fn run_probes(
    ctx: &Arc<ProbeCtx>,
    pool: &WorkerPool,
    s_list: &[u32],
    budget: usize,
) -> (Vec<SweepPoint>, Option<Best>) {
    let n_layers = ctx.model.weights.len();
    let n_points = s_list.len();
    let mut points: Vec<Option<SweepPoint>> = (0..n_points).map(|_| None).collect();
    let mut best: Option<Best> = None;
    let mut best_idx = usize::MAX;

    // Degenerate zero-layer model: every probe is an empty container.
    if n_layers == 0 {
        for (p, &s) in s_list.iter().enumerate() {
            let compressed =
                CompressedModel { name: ctx.model.manifest.name.clone(), layers: vec![] };
            let report = ModelReport::from_layers(&ctx.model, &compressed, vec![]);
            points[p] = Some(SweepPoint {
                s,
                compressed_bytes: report.compressed_bytes,
                density: report.density,
                distortion: 0.0,
                abandoned: false,
                wall_s: 0.0,
            });
            if best.is_none() {
                best = Some(Best { s, bytes: report.compressed_bytes, model: compressed, report });
            }
        }
        return (points.into_iter().map(|p| p.unwrap()).collect(), best);
    }

    struct PState {
        layers: Vec<CompressedLayer>,
        reports: Vec<LayerReport>,
        bytes: usize,
        wall: f64,
    }
    let mut st: Vec<PState> = (0..n_points)
        .map(|_| PState {
            layers: Vec::with_capacity(n_layers),
            reports: Vec::with_capacity(n_layers),
            bytes: 0,
            wall: 0.0,
        })
        .collect();

    // Err(()) marks a panicked probe task: the pool catches worker
    // panics (and survives), so without this marker the coordinator
    // would wait on a Done message that never comes and hang forever.
    type Done = (usize, usize, f64, Result<Option<(CompressedLayer, LayerReport)>, ()>);
    let (tx, rx) = mpsc::channel::<Done>();
    let submit = |p: usize, l: usize, base_bytes: usize| {
        let ctx = Arc::clone(ctx);
        let tx = tx.clone();
        let s = s_list[p];
        pool.execute(move || {
            let t = Timer::new();
            let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let spec = CompressionSpec { s, ..ctx.base };
                pipeline::compress_tensor_budgeted(
                    &ctx.model.manifest.layers[l].name,
                    &ctx.model.weights[l].shape,
                    &ctx.model.weights[l].data,
                    &ctx.model.biases[l].data,
                    &spec,
                    &ctx.stats[l],
                    base_bytes,
                    budget,
                )
            }))
            .map_err(|_| ());
            let _ = tx.send((p, l, t.elapsed_s(), out));
        });
    };

    // At most one in-flight task per point; in-flight points are capped
    // at half the pool's queue capacity (= 2 × pool size), which keeps
    // the bounded queue from ever blocking the coordinator and bounds
    // the memory held by partially-built containers.
    let inflight_cap = (pool.queue_capacity() / 2).max(1);
    let mut seeded = 0usize;
    let mut completed = 0usize;
    while seeded < n_points && seeded < inflight_cap {
        submit(seeded, 0, 0);
        seeded += 1;
    }
    while completed < n_points {
        let (p, l, wall, out) = rx.recv().expect("sweep probe channel closed");
        // re-raise worker panics on the coordinator (like the scoped
        // threads the engine replaced) instead of hanging the sweep
        let out = out.unwrap_or_else(|()| {
            panic!("sweep probe task panicked (S={}, layer {l})", s_list[p])
        });
        st[p].wall += wall;
        // None => finished (abandoned or complete); Some(next) continues
        let finished: Option<bool> = match out {
            Some((cl, rep)) => {
                st[p].bytes += cl.payload.len();
                st[p].layers.push(cl);
                st[p].reports.push(rep);
                if l + 1 == n_layers {
                    Some(false)
                } else if st[p].bytes > budget {
                    Some(true) // boundary abandon: already over budget
                } else {
                    submit(p, l + 1, st[p].bytes);
                    None
                }
            }
            None => Some(true), // in-layer abandon
        };
        if let Some(abandoned) = finished {
            completed += 1;
            let ps = &mut st[p];
            let layers = std::mem::take(&mut ps.layers);
            let reports = std::mem::take(&mut ps.reports);
            if abandoned {
                points[p] = Some(SweepPoint {
                    s: s_list[p],
                    compressed_bytes: ps.bytes,
                    density: 0.0,
                    distortion: 0.0,
                    abandoned: true,
                    wall_s: ps.wall,
                });
            } else {
                let compressed =
                    CompressedModel { name: ctx.model.manifest.name.clone(), layers };
                let report = ModelReport::from_layers(&ctx.model, &compressed, reports);
                points[p] = Some(SweepPoint {
                    s: s_list[p],
                    compressed_bytes: report.compressed_bytes,
                    density: report.density,
                    distortion: report.layers.iter().map(|r| r.distortion).sum(),
                    abandoned: false,
                    wall_s: ps.wall,
                });
                let better = match &best {
                    None => true,
                    Some(b) => {
                        report.compressed_bytes < b.bytes
                            || (report.compressed_bytes == b.bytes && p < best_idx)
                    }
                };
                if better {
                    best_idx = p;
                    best = Some(Best {
                        s: s_list[p],
                        bytes: report.compressed_bytes,
                        model: compressed,
                        report,
                    });
                }
            }
            if seeded < n_points {
                submit(seeded, 0, 0);
                seeded += 1;
            }
        }
    }
    (points.into_iter().map(|p| p.expect("probe point resolved")).collect(), best)
}

/// Run a flat sweep over an explicit S list (single round, no
/// abandonment — every point completes with full stats). `workers`
/// parallelizes probe points across the pool. Errors on an empty list
/// instead of panicking.
pub fn sweep_s(
    model: &Model,
    s_values: &[u32],
    base: &CompressionSpec,
    workers: usize,
) -> Result<SweepResult> {
    if s_values.is_empty() {
        bail!(
            "S sweep needs at least one candidate value \
             (empty grid — was --sweep/--points set to 0?)"
        );
    }
    let mut eng = SweepEngine::new(model, base, workers);
    eng.run_round(s_values, false);
    eng.finish()
}

/// Coarse-to-fine sweep: probe `default_s_grid(opts.points)`, then
/// refine around the argmin until every integer between its probed
/// neighbours has been tried. Refinement rounds run with the
/// early-abandon budget when `opts.abandon` is set; the first (coarse)
/// round always completes fully so the frontier report covers the whole
/// range. `opts.exhaustive` probes all 257 values in one round instead.
pub fn sweep_s_auto(
    model: &Model,
    opts: &SweepOptions,
    base: &CompressionSpec,
) -> Result<SweepResult> {
    if opts.points == 0 {
        bail!("sweep --points must be >= 1");
    }
    let mut eng = SweepEngine::new(model, base, opts.workers);
    if opts.exhaustive {
        let all: Vec<u32> = (0..=256).collect();
        if opts.abandon {
            // seed a coarse incumbent first so the full 257-point round
            // runs with a budget: most far-from-optimal probes then die
            // within their first layers (still selection-neutral)
            eng.run_round(&default_s_grid(opts.points), false);
            eng.run_round(&all, true);
        } else {
            eng.run_round(&all, false);
        }
        return eng.finish();
    }
    // at least the two endpoints, or refinement has no bracket to close
    // in on (--points 1 would otherwise silently probe S=0 alone)
    eng.run_round(&default_s_grid(opts.points.max(2)), false);
    while let Some(best_s) = eng.best_s() {
        let next = refine_grid(&eng.probed, best_s, opts.points);
        if next.is_empty() {
            break;
        }
        eng.run_round(&next, opts.abandon);
    }
    eng.finish()
}

/// Up to `per_round` evenly spaced unprobed integers strictly between
/// the nearest probed neighbours of `best_s`. Empty when the bracket is
/// exhausted (refinement converged).
fn refine_grid(probed: &BTreeSet<u32>, best_s: u32, per_round: usize) -> Vec<u32> {
    let lo = probed.range(..best_s).next_back().copied().unwrap_or(best_s);
    let hi = probed.range(best_s + 1..).next().copied().unwrap_or(best_s);
    let cands: Vec<u32> = (lo..=hi).filter(|s| !probed.contains(s)).collect();
    if cands.len() <= per_round.max(1) {
        return cands;
    }
    (0..per_round)
        .map(|i| cands[((i as f64 + 0.5) / per_round as f64 * cands.len() as f64) as usize])
        .collect()
}

/// Per-layer S selection (an extension over the paper, which picks one S
/// per model): every layer independently keeps its smallest-payload S.
/// Never worse than the global sweep on total payload bytes, since the
/// global optimum is in each layer's candidate set. Per-layer stats are
/// hoisted across the S candidates, and a probe is abandoned as soon as
/// its payload exceeds the layer's incumbent (selection-neutral: equal
/// payloads never replace the incumbent either).
pub fn sweep_s_per_layer(
    model: &Model,
    s_values: &[u32],
    base: &CompressionSpec,
) -> Result<(CompressedModel, ModelReport, Vec<(String, u32)>)> {
    if s_values.is_empty() {
        bail!(
            "S sweep needs at least one candidate value \
             (empty grid — was --sweep/--points set to 0?)"
        );
    }
    let mut seen = BTreeSet::new();
    let s_values: Vec<u32> = s_values.iter().copied().filter(|s| seen.insert(*s)).collect();
    let n = model.weights.len();
    let mut layers = Vec::with_capacity(n);
    let mut reports = Vec::with_capacity(n);
    let mut chosen = Vec::with_capacity(n);
    for i in 0..n {
        let li = &model.manifest.layers[i];
        let stats =
            LayerStats::compute(&model.weights[i].data, &model.sigmas[i].data, base.weighted);
        let mut best: Option<(CompressedLayer, LayerReport)> = None;
        for &s in &s_values {
            let spec = CompressionSpec { s, ..*base };
            let budget =
                best.as_ref().map(|(b, _)| b.payload.len()).unwrap_or(usize::MAX);
            let Some((cl, rep)) = pipeline::compress_tensor_budgeted(
                &li.name,
                &model.weights[i].shape,
                &model.weights[i].data,
                &model.biases[i].data,
                &spec,
                &stats,
                0,
                budget,
            ) else {
                continue; // abandoned: payload already exceeded this layer's best
            };
            let better = best
                .as_ref()
                .map(|(b, _)| cl.payload.len() < b.payload.len())
                .unwrap_or(true);
            if better {
                best = Some((cl, rep));
            }
        }
        // the first S candidate runs with an unbounded budget, so a best
        // always exists by the time we get here
        let (cl, rep) = best.expect("first S candidate is never abandoned");
        chosen.push((cl.name.clone(), cl.s_param));
        layers.push(cl);
        reports.push(rep);
    }
    let compressed = CompressedModel { name: model.manifest.name.clone(), layers };
    let report = ModelReport::from_layers(model, &compressed, reports);
    Ok((compressed, report, chosen))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point_fields(p: &SweepPoint) -> (u32, usize, bool, f64, f64) {
        (p.s, p.compressed_bytes, p.abandoned, p.density, p.distortion)
    }

    #[test]
    fn per_layer_never_worse_than_global() {
        let model = super::super::pipeline::tests::toy_model_pub();
        let base = CompressionSpec::default();
        let s = [0u32, 64, 192, 256];
        let global = sweep_s(&model, &s, &base, 1).unwrap();
        let (_, per_layer, chosen) = sweep_s_per_layer(&model, &s, &base).unwrap();
        assert_eq!(chosen.len(), model.weights.len());
        let global_payload: usize =
            global.best.1.layers.iter().map(|l| l.payload_bytes).sum();
        let per_layer_payload: usize =
            per_layer.layers.iter().map(|l| l.payload_bytes).sum();
        assert!(per_layer_payload <= global_payload);
    }

    #[test]
    fn grid_shapes() {
        assert_eq!(default_s_grid(257).len(), 257);
        let g = default_s_grid(9);
        assert_eq!(g.first(), Some(&0));
        assert_eq!(g.last(), Some(&256));
        assert!(g.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn empty_grid_is_an_error_not_a_panic() {
        // regression: an empty S list used to hit assert!/unwrap panics
        let model = super::super::pipeline::tests::toy_model_pub();
        let base = CompressionSpec::default();
        let err = sweep_s(&model, &[], &base, 1).expect_err("empty grid must fail");
        assert!(err.to_string().contains("at least one candidate"), "{err}");
        let err =
            sweep_s_per_layer(&model, &[], &base).expect_err("empty grid must fail");
        assert!(err.to_string().contains("at least one candidate"), "{err}");
        assert!(default_s_grid(0).is_empty()); // …and this is why sweep_s checks
        let opts = SweepOptions { points: 0, ..Default::default() };
        assert!(sweep_s_auto(&model, &opts, &base).is_err());
    }

    #[test]
    fn sweep_picks_smallest() {
        let model = super::super::pipeline::tests::toy_model_pub();
        let res = sweep_s(
            &model,
            &[0, 32, 128, 256],
            &CompressionSpec::default(),
            1,
        )
        .unwrap();
        let best_bytes = res.best.1.compressed_bytes;
        assert!(res.points.iter().all(|p| p.compressed_bytes >= best_bytes));
        assert!(res.points.iter().all(|p| !p.abandoned));
        assert_eq!(res.stats.probes_total, 4);
        assert_eq!(res.stats.probes_abandoned, 0);
        assert_eq!(res.stats.rounds, 1);
        // coarser grids (smaller S) must not produce *larger* payloads than
        // the finest probe — sanity of the monotone trend
        let s0 = res.points.iter().find(|p| p.s == 0).unwrap();
        let s256 = res.points.iter().find(|p| p.s == 256).unwrap();
        assert!(s0.compressed_bytes <= s256.compressed_bytes);
    }

    #[test]
    fn parallel_sweep_matches_serial_byte_identical() {
        // tentpole invariant: the parallel engine is bit-for-bit the
        // serial sweep — same best container, same point list.
        let model = super::super::pipeline::tests::toy_model_pub();
        let base = CompressionSpec::default();
        let grid = [0u32, 16, 48, 96, 160, 224, 256];
        let serial = sweep_s(&model, &grid, &base, 1).unwrap();
        for workers in [2usize, 4, 8] {
            let par = sweep_s(&model, &grid, &base, workers).unwrap();
            assert_eq!(
                serial.best.0.serialize(),
                par.best.0.serialize(),
                "workers={workers}"
            );
            assert_eq!(serial.points.len(), par.points.len());
            for (a, b) in serial.points.iter().zip(&par.points) {
                assert_eq!(point_fields(a), point_fields(b), "workers={workers}");
            }
        }
    }

    #[test]
    fn refine_with_abandon_matches_serial_no_abandon() {
        // the kept winner must be byte-identical whether or not probes
        // are abandoned, at any worker count, and the probe schedule +
        // abandoned set must be deterministic.
        let model = super::super::pipeline::tests::toy_model_pub();
        let base = CompressionSpec::default();
        let reference = sweep_s_auto(
            &model,
            &SweepOptions { points: 5, workers: 1, exhaustive: false, abandon: false },
            &base,
        )
        .unwrap();
        let mut abandon_runs = Vec::new();
        for workers in [1usize, 2, 4, 8] {
            let res = sweep_s_auto(
                &model,
                &SweepOptions { points: 5, workers, exhaustive: false, abandon: true },
                &base,
            )
            .unwrap();
            assert_eq!(
                reference.best.0.serialize(),
                res.best.0.serialize(),
                "workers={workers}"
            );
            // identical probe schedule (abandonment never changes the
            // best-S trajectory, so refinement visits the same points)
            let sched: Vec<u32> = res.points.iter().map(|p| p.s).collect();
            let ref_sched: Vec<u32> = reference.points.iter().map(|p| p.s).collect();
            assert_eq!(sched, ref_sched, "workers={workers}");
            // completed points carry identical stats to the no-abandon run
            for (a, b) in reference.points.iter().zip(&res.points) {
                if !b.abandoned {
                    assert_eq!(point_fields(a), point_fields(b), "workers={workers}");
                }
            }
            abandon_runs.push(res);
        }
        // the abandoned set and partial byte counts are identical across
        // worker counts (round-fixed budgets + chained accounting)
        let first = &abandon_runs[0];
        for run in &abandon_runs[1..] {
            let a: Vec<_> = first.points.iter().map(point_fields).collect();
            let b: Vec<_> = run.points.iter().map(point_fields).collect();
            assert_eq!(a, b);
            assert_eq!(first.stats.probes_abandoned, run.stats.probes_abandoned);
        }
    }

    #[test]
    fn early_abandon_kills_oversized_probes_and_is_selection_neutral() {
        let model = super::super::pipeline::tests::toy_model_pub();
        let base = CompressionSpec::default();
        // reference: the same schedule, fully completed
        let full =
            sweep_s(&model, &[0, 8, 16, 224, 240, 256], &base, 1).unwrap();
        let mut eng = SweepEngine::new(&model, &base, 4);
        eng.run_round(&[0, 8, 16], false);
        // far-from-optimal probes in a budgeted round: S≈256 payloads are
        // well above the S≈0 incumbent, so they must be cut short
        eng.run_round(&[224, 240, 256], true);
        let res = eng.finish().unwrap();
        assert_eq!(res.best.0.serialize(), full.best.0.serialize());
        assert!(
            res.stats.probes_abandoned > 0,
            "oversized probes were not abandoned: {:?}",
            res.points
        );
        assert_eq!(res.stats.rounds, 2);
        // abandoned partials are lower bounds that already exceed the
        // payload budget story: they must never be the minimum
        let best_bytes = res.best.1.compressed_bytes;
        for p in res.points.iter().filter(|p| !p.abandoned) {
            assert!(p.compressed_bytes >= best_bytes);
        }
    }

    #[test]
    fn refinement_beats_or_matches_coarse_grid() {
        let model = super::super::pipeline::tests::toy_model_pub();
        let base = CompressionSpec::default();
        let coarse = sweep_s(&model, &default_s_grid(5), &base, 1).unwrap();
        let refined = sweep_s_auto(
            &model,
            &SweepOptions { points: 5, workers: 2, exhaustive: false, abandon: true },
            &base,
        )
        .unwrap();
        assert!(
            refined.best.1.compressed_bytes <= coarse.best.1.compressed_bytes,
            "refinement must never lose to its own coarse round"
        );
        assert!(refined.stats.rounds >= 1);
        assert!(refined.stats.probes_total >= coarse.stats.probes_total);
    }

    #[test]
    fn exhaustive_covers_all_257_points() {
        // tiny model keeps this cheap; exhaustive is the paper's exact
        // protocol and the refinement driver's ground truth
        let model = super::super::pipeline::tests::toy_model_pub();
        let base = CompressionSpec::default();
        let res = sweep_s_auto(
            &model,
            &SweepOptions { points: 9, workers: 8, exhaustive: true, abandon: false },
            &base,
        )
        .unwrap();
        assert_eq!(res.stats.probes_total, 257);
        assert_eq!(res.stats.rounds, 1);
        // exhaustive + abandon: same winner, same 257-point coverage,
        // via a seeded coarse round + one budgeted full round
        let ex_ab = sweep_s_auto(
            &model,
            &SweepOptions { points: 9, workers: 4, exhaustive: true, abandon: true },
            &base,
        )
        .unwrap();
        // same optimum size (the schedules differ, so on an exact byte
        // tie the winning S may differ — the minimum cannot)
        assert_eq!(ex_ab.best.1.compressed_bytes, res.best.1.compressed_bytes);
        assert_eq!(ex_ab.stats.probes_total, 257);
        assert_eq!(ex_ab.stats.rounds, 2);
        let refined = sweep_s_auto(
            &model,
            &SweepOptions { points: 9, workers: 8, exhaustive: false, abandon: true },
            &base,
        )
        .unwrap();
        // refinement can at best match the exhaustive protocol…
        assert!(
            refined.best.1.compressed_bytes >= res.best.1.compressed_bytes
        );
        // …and must converge to a probed local optimum: both integer
        // neighbours of its argmin were visited
        let best_s = refined.best.0.layers[0].s_param;
        for nb in [best_s.saturating_sub(1), (best_s + 1).min(256)] {
            if nb != best_s {
                assert!(
                    refined.points.iter().any(|p| p.s == nb),
                    "neighbour S={nb} of argmin S={best_s} never probed"
                );
            }
        }
    }

    #[test]
    fn min_overhead_is_a_lower_bound_on_real_serialized_overhead() {
        // the selection-neutrality proof rests on
        //   serialize().len() − Σ payload ≥ min_overhead
        // for every container this model can produce; pin the hand-mirrored
        // byte accounting to the real serializer across S and chunk configs
        // so layout drift in `serialize` is caught here.
        let model = super::super::pipeline::tests::toy_model_pub();
        let oh = min_overhead(&model);
        assert!(oh > 0);
        for s in [0u32, 7, 64, 200, 256] {
            for chunks in [1u32, 3] {
                let spec = CompressionSpec { s, chunks, ..Default::default() };
                let (c, _) = super::super::pipeline::compress_model(&model, &spec, 1);
                let payload: usize = c.layers.iter().map(|l| l.payload.len()).sum();
                let real_overhead = c.serialize().len() - payload;
                assert!(
                    oh <= real_overhead,
                    "S={s} chunks={chunks}: min_overhead {oh} > real {real_overhead}"
                );
            }
        }
    }

    #[test]
    fn single_point_sweep_still_brackets_the_range() {
        // regression: --points 1 used to probe S=0 alone and report it as
        // the sweep optimum; the driver must cover both endpoints and
        // refine between them
        let model = super::super::pipeline::tests::toy_model_pub();
        let res = sweep_s_auto(
            &model,
            &SweepOptions { points: 1, workers: 2, exhaustive: false, abandon: true },
            &CompressionSpec::default(),
        )
        .unwrap();
        assert!(res.points.iter().any(|p| p.s == 0));
        assert!(res.points.iter().any(|p| p.s == 256));
        assert!(res.stats.probes_total >= 3, "no refinement happened");
    }

    #[test]
    fn refine_grid_brackets() {
        let probed: BTreeSet<u32> = [0u32, 64, 128, 192, 256].into_iter().collect();
        let g = refine_grid(&probed, 64, 4);
        assert!(!g.is_empty() && g.len() <= 4);
        assert!(g.iter().all(|&s| s > 0 && s < 128 && s != 64));
        assert!(g.windows(2).all(|w| w[0] < w[1]));
        // exhausted bracket → empty
        let probed: BTreeSet<u32> = (10u32..=14).collect();
        assert!(refine_grid(&probed, 12, 4).is_empty());
        // edge argmin: bracket extends only inward
        let probed: BTreeSet<u32> = [0u32, 64].into_iter().collect();
        let g = refine_grid(&probed, 0, 3);
        assert!(g.iter().all(|&s| s > 0 && s < 64));
    }
}
