//! The parallel, incremental (S × λ) rate–distortion sweep engine — the
//! repo's single definition of "explore the RD surface".
//!
//! The paper probes the grid coarseness S ∈ {0, …, 256} per model and
//! keeps the best-compressing setting ("Since the compression result can
//! be sensitive to the parameter S in (2), we probed the compression
//! performance for all S ∈ {0,...,256} and selected the best performing
//! model" — §4). The journal version (arXiv 1907.11900) additionally
//! sweeps the rate–distortion trade-off λ, tracing the full
//! compression–accuracy frontier that beats Deep Compression. This
//! engine schedules the whole 2-D surface at once:
//!
//! 1. **Parallel probes** — every grid point (S, λ) expands into
//!    (layer × point) probe tasks fanned onto a shared [`WorkerPool`]. A
//!    point's layer tasks are *chained* (layer ℓ+1 is dispatched when
//!    layer ℓ completes, by the coordinator thread — jobs never submit
//!    jobs, which would deadlock the pool's bounded queue), so
//!    parallelism comes from many grid points in flight at once and
//!    every point's running payload total is deterministic.
//! 2. **Hoisted invariants** — w_max, σ_min, η, mean(η) depend on
//!    neither S nor λ, so they are computed once per layer
//!    ([`LayerStats`]) and shared by every probe of that layer across
//!    the entire surface.
//! 3. **Early abandonment** ([`AbandonMode`]) — each λ-column keeps its
//!    own incumbent (the smallest serialized container at that λ). Once
//!    a column has one, a probe whose accumulated payload can no longer
//!    fit inside the column's best container (`column_best_serialized −
//!    min_overhead`, a provable lower bound on container overhead) is a
//!    candidate for abortion mid-scan: it provably serializes strictly
//!    larger than its column's incumbent, so **abandonment never changes
//!    any column's argmin** (nor the overall winner, which is the min
//!    over column argmins). In the default
//!    [`AbandonMode::FrontierPreserving`] a second conjunct is required
//!    before the abort: some *completed* point must strictly dominate
//!    the probe's running (serialized bytes, distortion) lower bound on
//!    **both** axes ([`crate::quant::DominanceFrontier`]). Both partial
//!    sums are monotone, so the finished probe would provably have been
//!    strictly Pareto-dominated — abandonment then preserves the exact
//!    frontier too, and `--no-abandon` is no longer needed for frontier
//!    runs. [`AbandonMode::SelectionNeutral`] keeps the payload leg
//!    alone (faster, argmin-preserving only — a losing low-distortion
//!    probe may vanish from the frontier). Budgets *and* the dominance
//!    staircase are fixed per round, so the abandoned set is a pure
//!    function of the schedule — identical across worker counts (the
//!    determinism tests pin all of this).
//! 4. **Warm-start refinement probes** — a refinement-round probe seeds
//!    its candidate scan with the quantized levels of its λ-column's
//!    incumbent — the nearest already-probed grid point, since
//!    refinement grids bracket the incumbent's S and neighbouring Δ
//!    differs by < 1%, so most per-weight argmins are unchanged. Each
//!    seeded level is verified with one exact cost comparison and the
//!    outward scan continues from it ([`crate::quant::ScanSeed`]), which
//!    keeps every container **byte-identical** to the cold path; seeds
//!    are refreshed from column incumbents at round boundaries
//!    (deterministic), and per-probe hit rates are reported in
//!    [`SweepStats`].
//! 5. **Pareto frontier** — alongside the per-column argmins the engine
//!    emits the non-dominated set of completed points in the
//!    (serialized bytes, weighted distortion) plane. Abandoned probes
//!    never complete and are excluded from the frontier — which loses
//!    nothing in the frontier-preserving mode (each abandoned point is
//!    provably dominated by a completed one; removing dominated points
//!    never changes a Pareto set). The coarse round of [`sweep_s_auto`]
//!    always completes fully, so the frontier also covers the coarse
//!    grid at every λ in every mode.
//!
//! Every completed point records an FNV-1a fingerprint of its serialized
//! container, so byte-identity against the serial single-point pipeline
//! is checkable per grid point (`sweep --compare-serial`) without
//! retaining one container per probe.
//!
//! On top of the engine, [`sweep_s_auto`] runs a coarse-to-fine driver
//! *per λ-column*: probe a coarse S grid across every column, then
//! repeatedly refine each column around its own argmin until every
//! integer between its probed neighbours has been tried (`exhaustive`
//! forces all 257 S values per column instead).

use super::metrics::{LayerReport, ModelReport, SweepStats};
use super::pipeline::{self, CompressionSpec, LayerProbe, LayerStats};
use crate::delta::encode::{encode_with_ctx, ParentCtx};
use crate::delta::encode_progressive;
use crate::model::{CompressedLayer, CompressedModel, DeltaModel, Model, ProgressiveModel};
use crate::quant::{DominanceFrontier, ProbeBudget};
use crate::util::par::WorkerPool;
use crate::util::{fnv1a, Timer};
use anyhow::{bail, Result};
use std::collections::BTreeSet;
use std::sync::{mpsc, Arc};

/// One cell of the 2-D RD surface: grid coarseness S (eq. 2) × the
/// scale-free Lagrangian multiplier `lambda_scale`
/// (λ = lambda_scale · Δ² · mean(η), see [`CompressionSpec`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GridPoint {
    pub s: u32,
    pub lambda_scale: f32,
}

impl GridPoint {
    /// `-0.0` is normalized to `0.0` so the two bit patterns can never
    /// split one λ-column into two identical ones.
    pub fn new(s: u32, lambda_scale: f32) -> Self {
        let lambda_scale = if lambda_scale == 0.0 { 0.0 } else { lambda_scale };
        Self { s, lambda_scale }
    }

    /// Dedup/bracket key: λ-column first (exact bit pattern — columns
    /// are identity classes, not numerically ordered), then S.
    fn key(&self) -> (u32, u32) {
        (self.lambda_scale.to_bits(), self.s)
    }
}

/// Early-abandonment policy of a sweep run / scheduling round.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AbandonMode {
    /// Every probe completes — full per-point stats for the whole grid.
    Off,
    /// Legacy payload-only budget: a probe is cut the moment its
    /// accumulated payload can no longer beat its λ-column's incumbent.
    /// Preserves every column argmin and the overall winner, but a
    /// losing low-distortion probe never completes and so vanishes from
    /// the *frontier*. The fastest mode — use for argmin-only runs.
    SelectionNeutral,
    /// Payload budget **and** strict Pareto dominance by an
    /// already-completed point, on the probe's running (bytes,
    /// distortion) lower bounds. Preserves the argmins *and* the exact
    /// frontier (abandoned points are provably dominated), at the cost
    /// of completing every frontier candidate. The default.
    #[default]
    FrontierPreserving,
}

impl AbandonMode {
    /// Stable name used by `BENCH_sweep.json` and the CLI.
    pub fn name(self) -> &'static str {
        match self {
            AbandonMode::Off => "off",
            AbandonMode::SelectionNeutral => "argmin",
            AbandonMode::FrontierPreserving => "frontier",
        }
    }
}

/// Where an abandoned probe was cut.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AbandonKind {
    /// The in-scan 512-weight poll inside a layer fired.
    MidLayer,
    /// The coordinator's check between two layers fired.
    LayerBoundary,
}

impl AbandonKind {
    /// Stable name used by `BENCH_sweep.json`.
    pub fn name(self) -> &'static str {
        match self {
            AbandonKind::MidLayer => "mid-layer",
            AbandonKind::LayerBoundary => "layer-boundary",
        }
    }
}

#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub s: u32,
    pub lambda_scale: f32,
    /// Serialized container size at this point. For abandoned probes
    /// this is the payload accumulated before the abort — a lower bound,
    /// recorded so the frontier report still shows *why* the point lost.
    pub compressed_bytes: usize,
    pub density: f64,
    /// Weighted distortion. For abandoned probes this is the sum over
    /// the layers completed before the abort — a monotone lower bound on
    /// what the finished probe would have reported (density stays 0).
    pub distortion: f64,
    /// True if the probe was cut short by the round's abandon predicate
    /// (see [`AbandonMode`]; the point never completed).
    pub abandoned: bool,
    /// Which check cut an abandoned probe (`None` for completed points).
    pub abandon_kind: Option<AbandonKind>,
    /// FNV-1a fingerprint of the serialized container (0 for abandoned
    /// probes) — per-point byte-identity against the serial pipeline.
    pub container_hash: u64,
    /// Delta-sweep only: serialized size of the v3 delta segment diffing
    /// this point's container against the sweep's parent. `None` in a
    /// plain sweep, for abandoned probes, and for the rare point whose
    /// residuals cannot be delta-coded (level overflow) — such a point
    /// is recorded but never selected.
    pub delta_bytes: Option<usize>,
    /// Weights this probe scanned with a warm-start seed (0 when the
    /// round ran cold or its λ-column had no incumbent yet).
    pub seeded: usize,
    /// Seeded weights whose seed candidate was the chosen level.
    pub seed_hits: usize,
    /// Summed wall clock of this point's probe tasks (reporting only —
    /// not deterministic, excluded from the determinism tests).
    pub wall_s: f64,
}

/// A λ-column's argmin: the smallest-container probe at that λ.
#[derive(Debug)]
pub struct ColumnBest {
    pub lambda_scale: f32,
    pub s: u32,
    pub bytes: usize,
    pub model: CompressedModel,
    pub report: ModelReport,
    /// Probes scheduled / abandoned in this column (abandon-rate
    /// reporting per λ-column).
    pub probes: usize,
    pub abandoned: usize,
    /// Delta-sweep only: the incumbent's delta segment size (the metric
    /// this column's argmin was selected on).
    pub delta_bytes: Option<usize>,
}

#[derive(Debug)]
pub struct SweepResult {
    /// Every probed point, in schedule order (deterministic).
    pub points: Vec<SweepPoint>,
    /// The overall best (smallest-container) probe across all λ-columns;
    /// ties go to the earlier schedule position, exactly like the
    /// original serial sweep.
    pub best: (CompressedModel, ModelReport),
    /// The (S, λ) cell the overall best came from (the container itself
    /// records only S — λ shapes the levels but is not needed to decode).
    pub best_point: GridPoint,
    /// Per-λ-column argmin containers, in first-scheduled column order.
    pub columns: Vec<ColumnBest>,
    /// Indices into `points`: the Pareto frontier of completed probes in
    /// the (compressed_bytes, distortion) plane — (delta_bytes,
    /// distortion) in a delta sweep — sorted by bytes ascending
    /// (distortion is then non-increasing along it).
    pub frontier: Vec<usize>,
    /// Delta-sweep only: the winning point's delta segment + encoder
    /// report. `apply(parent, delta)` reproduces `best.0` byte-for-byte.
    pub best_delta: Option<(DeltaModel, crate::delta::DeltaReport)>,
    pub stats: SweepStats,
}

/// Options for [`sweep_s_auto`].
#[derive(Debug, Clone)]
pub struct SweepOptions {
    /// S points per scheduling round (coarse grid size and refinement
    /// fan-out, per λ-column).
    pub points: usize,
    pub workers: usize,
    /// Probe all 257 S values per λ-column in one round instead of
    /// coarse-to-fine.
    pub exhaustive: bool,
    /// Early-abandonment policy for refinement rounds (the coarse round
    /// always completes fully).
    pub abandon: AbandonMode,
    /// Seed refinement probes with their λ-column incumbent's levels
    /// (byte-identical to cold either way; `false` = the `--cold`
    /// reference path for identity checks).
    pub warm_start: bool,
    /// λ-columns (lambda_scale values) of the surface. Empty means
    /// "just the base spec's lambda_scale" — a pure S sweep.
    pub lambdas: Vec<f32>,
}

impl Default for SweepOptions {
    fn default() -> Self {
        Self {
            points: 17,
            workers: 1,
            exhaustive: false,
            abandon: AbandonMode::FrontierPreserving,
            warm_start: true,
            lambdas: Vec::new(),
        }
    }
}

/// Coarse-to-fine S grid covering {0..=256} with ~n points.
pub fn default_s_grid(n: usize) -> Vec<u32> {
    if n >= 257 {
        return (0..=256).collect();
    }
    let mut out: Vec<u32> = (0..n)
        .map(|i| ((i as f64 / (n - 1).max(1) as f64) * 256.0).round() as u32)
        .collect();
    out.dedup();
    out
}

/// λ (lambda_scale) grid for `sweep --lambda-sweep N`. N ≥ 3: λ = 0
/// (weighted nearest-neighbour, the min-distortion anchor of the
/// frontier) plus N−1 log-spaced columns over [0.01, 1.0] —
/// engine-native coverage of the set the legacy serial
/// `examples/rd_sweep.rs` swept ({0, 0.01, 0.05, 0.2, 1.0} ≈
/// `default_lambda_grid(5)`). Degenerate sizes are special-cased:
/// N = 2 pairs the λ=0 anchor with the 0.05 default, and N = 1 is just
/// the 0.05 default (no anchor — a single column can't trace a
/// frontier anyway).
pub fn default_lambda_grid(n: usize) -> Vec<f32> {
    match n {
        0 => Vec::new(),
        1 => vec![0.05],
        2 => vec![0.0, 0.05],
        _ => {
            let mut out = vec![0.0f32];
            for i in 0..(n - 1) {
                let t = i as f64 / (n - 2) as f64;
                out.push((0.01 * 100f64.powf(t)) as f32);
            }
            out
        }
    }
}

fn validate_lambda(l: f32) -> Result<()> {
    if !l.is_finite() || l < 0.0 {
        bail!("λ grid values must be finite and >= 0 (got {l})");
    }
    Ok(())
}

/// The λ-columns a driver run will cover: the caller's list (validated,
/// deduped by bit pattern, order preserved) or the base spec's single λ.
fn resolve_lambdas(lambdas: &[f32], base: &CompressionSpec) -> Result<Vec<f32>> {
    let raw: &[f32] = if lambdas.is_empty() {
        std::slice::from_ref(&base.lambda_scale)
    } else {
        lambdas
    };
    let mut seen = BTreeSet::new();
    let mut out = Vec::with_capacity(raw.len());
    for &l in raw {
        validate_lambda(l)?;
        let l = if l == 0.0 { 0.0 } else { l }; // -0.0 → 0.0: one column
        if seen.insert(l.to_bits()) {
            out.push(l);
        }
    }
    Ok(out)
}

/// Shared, immutable probe context — cloned out of the caller's model
/// once so probe tasks are `'static` for the worker pool.
struct ProbeCtx {
    model: Model,
    stats: Vec<LayerStats>,
    base: CompressionSpec,
    /// Lower bound on the serialized non-payload bytes of any container
    /// this model/spec can produce (see [`min_overhead`]).
    min_overhead: usize,
    /// Delta-sweep mode: the parent container every completed point is
    /// diffed against, with its reconstruction hoisted once — the
    /// delta-side analogue of [`LayerStats`].
    delta: Option<Arc<ParentCtx>>,
}

/// Precompute [`LayerStats`] for every layer (in parallel) and clone the
/// model once so probe tasks can outlive the caller's borrow. Shared by
/// the surface engine and the per-layer sweep.
fn probe_ctx(
    model: &Model,
    base: &CompressionSpec,
    workers: usize,
    delta: Option<Arc<ParentCtx>>,
) -> Arc<ProbeCtx> {
    let stats = crate::util::par::map_indexed(model.weights.len(), workers, |i| {
        LayerStats::compute(&model.weights[i].data, &model.sigmas[i].data, base.weighted)
    });
    let min_overhead = min_overhead(model);
    // Slim clone: σ tensors are already folded into LayerStats and
    // nothing downstream reads them, so don't hold a second
    // weights-sized copy for the engine's lifetime.
    let slim = Model {
        manifest: model.manifest.clone(),
        weights: model.weights.clone(),
        biases: model.biases.clone(),
        sigmas: model
            .weights
            .iter()
            .map(|_| crate::tensor::Tensor::new(vec![0], vec![]))
            .collect(),
    };
    Arc::new(ProbeCtx { model: slim, stats, base: *base, min_overhead, delta })
}

struct Best {
    /// Global schedule index of the winning probe (tie-breaker: earlier
    /// schedule position wins, independent of completion order).
    sched: usize,
    point: GridPoint,
    bytes: usize,
    /// The selection metric incumbents compete on: `bytes` in a plain
    /// sweep, the delta segment size in a delta sweep (a point whose
    /// residuals overflow gets `usize::MAX` and can never win).
    sel: usize,
    /// Delta-sweep only: the incumbent's delta segment.
    delta: Option<(DeltaModel, crate::delta::DeltaReport)>,
    model: CompressedModel,
    report: ModelReport,
}

/// Per-layer quantized levels of a column incumbent, decoded once from
/// its container and shared (`Arc`) by every warm probe it seeds — one
/// level-set per λ-column resident at a time, replaced when the
/// incumbent changes.
struct SeedLevels {
    /// The incumbent's S (each S is probed at most once per column, so
    /// this identifies the incumbent; the probe derives the grid-step
    /// rescale factor from it).
    s: u32,
    layers: Vec<Vec<i32>>,
}

/// One λ-column's scheduling state.
struct Column {
    lambda_bits: u32,
    lambda_scale: f32,
    best: Option<Best>,
    /// Warm-start seed: the incumbent's decoded levels (refreshed lazily
    /// at round boundaries, so it is a pure function of the schedule).
    seed: Option<Arc<SeedLevels>>,
}

/// LEB128 length of a varint (mirrors `bitstream::write_varint`).
fn varint_len(mut v: u64) -> usize {
    let mut n = 1;
    while v >= 0x80 {
        v >>= 7;
        n += 1;
    }
    n
}

/// Lower bound on the non-payload bytes of a serialized container for
/// `model`: every (S, λ)-independent field is counted exactly, and each
/// S-dependent varint (max_level, s_param, payload_len) at its 1-byte
/// minimum; v2 chunk tables are omitted (they only add bytes). Used to
/// convert a column's best *serialized* size into a *payload* budget:
/// `payload(p) > best_bytes − min_overhead` implies
/// `serialized(p) > best_bytes`.
fn min_overhead(model: &Model) -> usize {
    let mut c = 4 + 1; // magic + version
    c += varint_len(model.manifest.name.len() as u64) + model.manifest.name.len();
    c += varint_len(model.weights.len() as u64);
    for i in 0..model.weights.len() {
        let name = &model.manifest.layers[i].name;
        let dims = &model.weights[i].shape;
        c += varint_len(name.len() as u64) + name.len();
        c += varint_len(dims.len() as u64);
        for &d in dims {
            c += varint_len(d as u64);
        }
        c += 4; // grid delta (f32)
        c += 1; // max_level varint, ≥ 1 byte
        c += 1; // s_param varint, ≥ 1 byte
        c += 4; // codec config bytes
        c += varint_len(model.weights[i].data.len() as u64); // n_weights
        c += 1; // payload_len varint, ≥ 1 byte
        let bl = model.biases[i].data.len();
        c += varint_len(bl as u64) + bl * 4;
    }
    c
}

/// The reusable sweep engine: create once, feed scheduling rounds of
/// (S, λ) grid points, then [`SweepEngine::finish`]. Rounds are barriers
/// — every λ-column's abandon budget is fixed when a round starts, which
/// is what makes the abandoned set deterministic.
pub struct SweepEngine {
    ctx: Arc<ProbeCtx>,
    pool: WorkerPool,
    probed: BTreeSet<(u32, u32)>,
    points: Vec<SweepPoint>,
    columns: Vec<Column>,
    rounds: usize,
    abandoned: usize,
    timer: Timer,
}

impl SweepEngine {
    pub fn new(model: &Model, base: &CompressionSpec, workers: usize) -> Self {
        Self::with_delta(model, base, workers, None)
    }

    /// Delta-sweep engine: every completed point is additionally diffed
    /// against `parent` (reconstruction hoisted once, like
    /// [`LayerStats`]) and incumbents are selected on **delta segment
    /// bytes** instead of full-container bytes. Errors early if the
    /// parent's layer structure (count, names, weight counts) does not
    /// match `model` — a delta re-codes residuals, it does not
    /// re-architect.
    pub fn new_delta(
        model: &Model,
        base: &CompressionSpec,
        workers: usize,
        parent: CompressedModel,
    ) -> Result<Self> {
        if parent.layers.len() != model.weights.len() {
            bail!(
                "delta sweep: parent has {} layers, target model {}",
                parent.layers.len(),
                model.weights.len()
            );
        }
        for (pl, ml) in parent.layers.iter().zip(&model.manifest.layers) {
            if pl.name != ml.name {
                bail!(
                    "delta sweep: layer name mismatch ({:?} vs {:?})",
                    pl.name,
                    ml.name
                );
            }
        }
        for (i, pl) in parent.layers.iter().enumerate() {
            if pl.n_weights != model.weights[i].len() {
                bail!(
                    "delta sweep: layer {:?} weight count mismatch ({} vs {})",
                    pl.name,
                    pl.n_weights,
                    model.weights[i].len()
                );
            }
        }
        Ok(Self::with_delta(
            model,
            base,
            workers,
            Some(Arc::new(ParentCtx::new(parent, workers))),
        ))
    }

    fn with_delta(
        model: &Model,
        base: &CompressionSpec,
        workers: usize,
        delta: Option<Arc<ParentCtx>>,
    ) -> Self {
        Self {
            ctx: probe_ctx(model, base, workers, delta),
            pool: WorkerPool::new(workers),
            probed: BTreeSet::new(),
            points: Vec::new(),
            columns: Vec::new(),
            rounds: 0,
            abandoned: 0,
            timer: Timer::new(),
        }
    }

    fn col_index(&mut self, lambda_scale: f32) -> usize {
        let bits = lambda_scale.to_bits();
        if let Some(i) = self.columns.iter().position(|c| c.lambda_bits == bits) {
            return i;
        }
        self.columns.push(Column {
            lambda_bits: bits,
            lambda_scale,
            best: None,
            seed: None,
        });
        self.columns.len() - 1
    }

    /// Refresh column `c`'s warm-start seed from its incumbent (decode
    /// the incumbent's levels once; no-op while the seed is current).
    /// Called at round boundaries only, so seeds — like budgets — are a
    /// pure function of the schedule.
    fn refresh_seed(&mut self, c: usize) {
        let col = &mut self.columns[c];
        let Some(b) = &col.best else { return };
        if col.seed.as_ref().map(|s| s.s == b.point.s).unwrap_or(false) {
            return;
        }
        col.seed = Some(Arc::new(SeedLevels {
            s: b.point.s,
            layers: b.model.layers.iter().map(|l| l.decode_levels()).collect(),
        }));
    }

    /// (selection metric, sched, column index) of the overall winner so
    /// far — serialized bytes in a plain sweep, delta bytes in a delta
    /// sweep.
    fn overall(&self) -> Option<(usize, usize, usize)> {
        self.columns
            .iter()
            .enumerate()
            .filter_map(|(i, c)| c.best.as_ref().map(|b| (b.sel, b.sched, i)))
            .min()
    }

    /// The (S, λ) cell of the best completed probe so far.
    pub fn best_point(&self) -> Option<GridPoint> {
        self.overall().map(|(_, _, i)| {
            self.columns[i].best.as_ref().expect("overall() returned the column").point
        })
    }

    /// S of the best completed probe in the λ-column `lambda_scale`.
    pub fn best_s_in(&self, lambda_scale: f32) -> Option<u32> {
        let bits = lambda_scale.to_bits();
        self.columns
            .iter()
            .find(|c| c.lambda_bits == bits)?
            .best
            .as_ref()
            .map(|b| b.point.s)
    }

    /// The S values probed so far in the λ-column `lambda_scale`.
    fn probed_s_in(&self, lambda_scale: f32) -> BTreeSet<u32> {
        let bits = lambda_scale.to_bits();
        self.probed.range((bits, 0)..=(bits, u32::MAX)).map(|&(_, s)| s).collect()
    }

    /// Probe every not-yet-probed grid point in `grid` (duplicates and
    /// repeats are skipped) under the round's [`AbandonMode`], seeding
    /// probes from their λ-column incumbents when `warm`. Each λ-column's
    /// budget, the dominance staircase, and the seeds are all fixed on
    /// entry (∞/empty/none while a column has no completed probe — such
    /// a column can never abandon and has nothing to seed from), so
    /// which probes get abandoned — and every seeded-scan statistic —
    /// depends only on the schedule, not on worker count or timing.
    pub fn run_round(&mut self, grid: &[GridPoint], abandon: AbandonMode, warm: bool) {
        // Delta mode forces AbandonMode::Off: the abandon budgets are
        // derived from FULL-container incumbent sizes, and full bytes do
        // not order points the way delta bytes do (a probe that loses on
        // full bytes can still win on delta bytes, e.g. a grid close to
        // the parent's). Cutting probes on the full-byte predicate would
        // therefore not be selection-neutral for the delta objective.
        let abandon =
            if self.ctx.delta.is_some() { AbandonMode::Off } else { abandon };
        // re-normalize through GridPoint::new: the fields are pub, so a
        // literal-constructed -0.0 must still land in the +0.0 column
        let pts: Vec<GridPoint> = grid
            .iter()
            .map(|p| GridPoint::new(p.s, p.lambda_scale))
            .filter(|p| self.probed.insert(p.key()))
            .collect();
        if pts.is_empty() {
            return;
        }
        self.rounds += 1;
        let cols: Vec<usize> = pts.iter().map(|p| self.col_index(p.lambda_scale)).collect();
        let budgets: Vec<usize> = cols
            .iter()
            .map(|&c| {
                if abandon == AbandonMode::Off {
                    return usize::MAX;
                }
                self.columns[c]
                    .best
                    .as_ref()
                    .map(|b| b.bytes.saturating_sub(self.ctx.min_overhead))
                    .unwrap_or(usize::MAX)
            })
            .collect();
        // the dominance staircase over everything completed in EARLIER
        // rounds (round-fixed, like the budgets, for determinism)
        let dominance: Option<Arc<DominanceFrontier>> =
            if abandon == AbandonMode::FrontierPreserving {
                let f = DominanceFrontier::from_completed(
                    self.points
                        .iter()
                        .filter(|p| !p.abandoned)
                        .map(|p| (p.compressed_bytes, p.distortion)),
                    self.ctx.min_overhead,
                );
                if f.is_empty() {
                    None
                } else {
                    Some(Arc::new(f))
                }
            } else {
                None
            };
        let seeds: Vec<Option<Arc<SeedLevels>>> = if warm {
            for &c in &cols {
                self.refresh_seed(c);
            }
            cols.iter().map(|&c| self.columns[c].seed.clone()).collect()
        } else {
            cols.iter().map(|_| None).collect()
        };
        let sched_base = self.points.len();
        let (points, round_best) = run_probes(
            &self.ctx,
            &self.pool,
            &pts,
            &cols,
            &budgets,
            dominance,
            seeds,
            sched_base,
            self.columns.len(),
        );
        self.abandoned += points.iter().filter(|p| p.abandoned).count();
        self.points.extend(points);
        for (c, rb) in round_best.into_iter().enumerate() {
            if let Some(rb) = rb {
                // strict < : earlier rounds win ties, matching the serial
                // sweep's first-smallest selection (the incumbent always
                // has the smaller schedule index)
                let better =
                    self.columns[c].best.as_ref().map(|b| rb.sel < b.sel).unwrap_or(true);
                if better {
                    self.columns[c].best = Some(rb);
                }
            }
        }
    }

    pub fn finish(self) -> Result<SweepResult> {
        let Some((_, _, wi)) = self.overall() else {
            bail!(
                "sweep completed no probe points ({} scheduled) — \
                 the candidate grid must contain at least one (S, λ) value",
                self.points.len()
            );
        };
        // per-column probe/abandon counts for the column report
        let mut col_counts = vec![(0usize, 0usize); self.columns.len()];
        for p in &self.points {
            let bits = p.lambda_scale.to_bits();
            if let Some(i) = self.columns.iter().position(|c| c.lambda_bits == bits) {
                col_counts[i].0 += 1;
                if p.abandoned {
                    col_counts[i].1 += 1;
                }
            }
        }
        let delta_mode = self.ctx.delta.is_some();
        let frontier = pareto_frontier(&self.points, delta_mode);
        // the winner is cloned into `best` AND kept in its ColumnBest
        // (for --select-lambda): an accepted duplication — containers
        // are compressed artifacts, orders of magnitude below the model
        // the engine already holds
        let (best, best_point, best_delta) = {
            let b = self.columns[wi].best.as_ref().expect("overall() returned the column");
            if delta_mode && b.delta.is_none() {
                bail!(
                    "delta sweep: no grid point could be delta-coded against \
                     the parent (residual levels overflow) — the parent and \
                     target models are too far apart; ship a full container"
                );
            }
            ((b.model.clone(), b.report.clone()), b.point, b.delta.clone())
        };
        let n_columns = self.columns.len();
        let columns: Vec<ColumnBest> = self
            .columns
            .into_iter()
            .zip(col_counts)
            .filter_map(|(c, (probes, abandoned))| {
                c.best.map(|b| ColumnBest {
                    lambda_scale: c.lambda_scale,
                    s: b.point.s,
                    bytes: b.bytes,
                    model: b.model,
                    report: b.report,
                    probes,
                    abandoned,
                    delta_bytes: b.delta.as_ref().map(|(dm, _)| dm.total_bytes()),
                })
            })
            .collect();
        Ok(SweepResult {
            best,
            best_point,
            best_delta,
            columns,
            frontier,
            stats: SweepStats {
                probes_total: self.points.len(),
                probes_abandoned: self.abandoned,
                abandoned_mid_layer: self
                    .points
                    .iter()
                    .filter(|p| p.abandon_kind == Some(AbandonKind::MidLayer))
                    .count(),
                abandoned_boundary: self
                    .points
                    .iter()
                    .filter(|p| p.abandon_kind == Some(AbandonKind::LayerBoundary))
                    .count(),
                rounds: self.rounds,
                columns: n_columns,
                seeded_weights: self.points.iter().map(|p| p.seeded as u64).sum(),
                seed_hits: self.points.iter().map(|p| p.seed_hits as u64).sum(),
                wall_s: self.timer.elapsed_s(),
            },
            points: self.points,
        })
    }
}

/// Shared chained-dispatch scaffolding for the engine's task graphs
/// (surface probes: chains = grid points, steps = layers; per-layer
/// sweep: chains = layers, steps = candidates). Holds the no-deadlock
/// discipline in ONE place: at most one in-flight task per chain, total
/// in-flight capped below the pool's bounded queue capacity so the
/// coordinator never blocks on submission, and jobs never submit jobs —
/// `next` runs on the coordinator and decides each chain's
/// continuation. Worker panics are caught, marked, and re-raised on the
/// coordinator (the pool survives; the sweep fails loudly instead of
/// hanging on a Done message that never comes).
///
/// `step(chain, idx, arg)` runs on a worker; `next(chain, idx, out)`
/// runs on the coordinator and returns `Some(arg)` to dispatch step
/// `idx + 1` of that chain, or `None` to finish the chain (the freed
/// slot seeds the next unstarted chain).
fn chain_dispatch<A, T, S, N>(
    pool: &WorkerPool,
    label: &str,
    n_chains: usize,
    first: A,
    step: S,
    mut next: N,
) where
    A: Copy + Send + 'static,
    T: Send + 'static,
    S: Fn(usize, usize, A) -> T + Clone + Send + 'static,
    N: FnMut(usize, usize, T) -> Option<A>,
{
    if n_chains == 0 {
        return;
    }
    // Err(()) marks a panicked task (see the doc comment).
    let (tx, rx) = mpsc::channel::<(usize, usize, Result<T, ()>)>();
    let submit = |c: usize, k: usize, arg: A| {
        let tx = tx.clone();
        let step = step.clone();
        pool.execute(move || {
            let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                step(c, k, arg)
            }))
            .map_err(|_| ());
            let _ = tx.send((c, k, out));
        });
    };
    let inflight_cap = (pool.queue_capacity() / 2).max(1);
    let mut seeded = 0usize;
    let mut done = 0usize;
    while seeded < n_chains && seeded < inflight_cap {
        submit(seeded, 0, first);
        seeded += 1;
    }
    while done < n_chains {
        let (c, k, out) = rx.recv().expect("chain dispatch channel closed");
        let out = out
            .unwrap_or_else(|()| panic!("{label} task panicked (chain {c}, step {k})"));
        match next(c, k, out) {
            Some(arg) => submit(c, k + 1, arg),
            None => {
                done += 1;
                if seeded < n_chains {
                    submit(seeded, 0, first);
                    seeded += 1;
                }
            }
        }
    }
}

/// Indices of the completed points forming the Pareto frontier of
/// (bytes, distortion): a point is kept iff no other completed point is
/// at least as good on both axes and strictly better on one (exact
/// duplicates are all kept). Sorted by (bytes, distortion, schedule
/// index) — deterministic. In a delta sweep the byte axis is the delta
/// segment size and points that could not be delta-coded are excluded
/// (they are undeliverable under the delta objective).
fn pareto_frontier(points: &[SweepPoint], delta_mode: bool) -> Vec<usize> {
    let bytes_of = |p: &SweepPoint| -> Option<usize> {
        if delta_mode { p.delta_bytes } else { Some(p.compressed_bytes) }
    };
    let completed: Vec<usize> = (0..points.len())
        .filter(|&i| !points[i].abandoned && bytes_of(&points[i]).is_some())
        .collect();
    let mut out: Vec<usize> = completed
        .iter()
        .copied()
        .filter(|&i| {
            let p = &points[i];
            let pb = bytes_of(p).expect("completed points carry bytes");
            !completed.iter().any(|&j| {
                if j == i {
                    return false;
                }
                let q = &points[j];
                let qb = bytes_of(q).expect("completed points carry bytes");
                qb <= pb
                    && q.distortion <= p.distortion
                    && (qb < pb || q.distortion < p.distortion)
            })
        })
        .collect();
    out.sort_by(|&a, &b| {
        bytes_of(&points[a])
            .cmp(&bytes_of(&points[b]))
            .then(
                points[a]
                    .distortion
                    .partial_cmp(&points[b].distortion)
                    .unwrap_or(std::cmp::Ordering::Equal),
            )
            .then(a.cmp(&b))
    });
    out
}

/// Delta-encode a completed point's container against the sweep's
/// parent, on the coordinator thread (deterministic bookkeeping, like
/// the column-best updates). Outer `None`: plain sweep, no delta.
/// Inner `None`: the point's residuals cannot be delta-coded (level
/// overflow against this parent) — the point is recorded but can never
/// be selected.
#[allow(clippy::type_complexity)]
fn delta_for(
    ctx: &ProbeCtx,
    compressed: &CompressedModel,
) -> Option<Option<(usize, DeltaModel, crate::delta::DeltaReport)>> {
    let pc = ctx.delta.as_ref()?;
    Some(match encode_with_ctx(pc, compressed, 1) {
        Ok((dm, dr)) => Some((dm.total_bytes(), dm, dr)),
        Err(_) => None,
    })
}

/// The incumbent-selection metric: serialized container bytes in a
/// plain sweep, delta segment bytes in a delta sweep (`usize::MAX` for
/// an un-deltable point, so it never wins).
fn sel_metric(
    full_bytes: usize,
    delta: &Option<Option<(usize, DeltaModel, crate::delta::DeltaReport)>>,
) -> usize {
    match delta {
        None => full_bytes,
        Some(Some((b, ..))) => *b,
        Some(None) => usize::MAX,
    }
}

/// One scheduling round: chained (layer × point) tasks on the pool,
/// returning the per-point records in `pts` order plus each λ-column's
/// best completed container of the round (smallest bytes, ties to the
/// earlier schedule index — independent of completion order).
#[allow(clippy::too_many_arguments)]
fn run_probes(
    ctx: &Arc<ProbeCtx>,
    pool: &WorkerPool,
    pts: &[GridPoint],
    cols: &[usize],
    budgets: &[usize],
    dominance: Option<Arc<DominanceFrontier>>,
    seeds: Vec<Option<Arc<SeedLevels>>>,
    sched_base: usize,
    n_cols: usize,
) -> (Vec<SweepPoint>, Vec<Option<Best>>) {
    let n_layers = ctx.model.weights.len();
    let n_points = pts.len();
    let mut points: Vec<Option<SweepPoint>> = (0..n_points).map(|_| None).collect();
    let mut best: Vec<Option<Best>> = (0..n_cols).map(|_| None).collect();

    // Degenerate zero-layer model: every probe is an empty container.
    if n_layers == 0 {
        for (p, pt) in pts.iter().enumerate() {
            let compressed =
                CompressedModel { name: ctx.model.manifest.name.clone(), layers: vec![] };
            let ser = compressed.serialize();
            let report = ModelReport::from_layers_sized(&ctx.model, ser.len(), vec![]);
            let delta = delta_for(ctx, &compressed);
            points[p] = Some(SweepPoint {
                s: pt.s,
                lambda_scale: pt.lambda_scale,
                compressed_bytes: report.compressed_bytes,
                density: report.density,
                distortion: 0.0,
                abandoned: false,
                abandon_kind: None,
                container_hash: fnv1a(&ser),
                delta_bytes: delta.as_ref().and_then(|d| d.as_ref().map(|(b, ..)| *b)),
                seeded: 0,
                seed_hits: 0,
                wall_s: 0.0,
            });
            if best[cols[p]].is_none() {
                best[cols[p]] = Some(Best {
                    sched: sched_base + p,
                    point: *pt,
                    bytes: report.compressed_bytes,
                    sel: sel_metric(report.compressed_bytes, &delta),
                    delta: delta.flatten().map(|(_, dm, dr)| (dm, dr)),
                    model: compressed,
                    report,
                });
            }
        }
        return (points.into_iter().map(|p| p.unwrap()).collect(), best);
    }

    struct PState {
        layers: Vec<CompressedLayer>,
        reports: Vec<LayerReport>,
        bytes: usize,
        /// Running distortion in the exact per-layer summation order the
        /// completed report would use (a monotone lower bound).
        dist: f64,
        wall: f64,
    }
    let mut st: Vec<PState> = (0..n_points)
        .map(|_| PState {
            layers: Vec::with_capacity(n_layers),
            reports: Vec::with_capacity(n_layers),
            bytes: 0,
            dist: 0.0,
            wall: 0.0,
        })
        .collect();

    // worker side: one probed layer-compress per task (Arc'd captures
    // keep the step closure's clone O(1) per dispatch)
    let step = {
        let ctx = Arc::clone(ctx);
        let pts: Arc<Vec<GridPoint>> = Arc::new(pts.to_vec());
        let budgets: Arc<Vec<usize>> = Arc::new(budgets.to_vec());
        let dominance = dominance.clone();
        let seeds: Arc<Vec<Option<Arc<SeedLevels>>>> = Arc::new(seeds);
        move |p: usize, l: usize, (base_bytes, base_dist): (usize, f64)| {
            let t = Timer::new();
            let pt = pts[p];
            let spec = CompressionSpec { s: pt.s, lambda_scale: pt.lambda_scale, ..ctx.base };
            let probe = LayerProbe {
                base_bytes,
                base_distortion: base_dist,
                budget_bytes: budgets[p],
                dominance: dominance.as_deref(),
                seed: seeds[p].as_ref().map(|s| (&s.layers[l][..], s.s)),
            };
            let out = pipeline::compress_tensor_probe(
                &ctx.model.manifest.layers[l].name,
                &ctx.model.weights[l].shape,
                &ctx.model.weights[l].data,
                &ctx.model.biases[l].data,
                &spec,
                &ctx.stats[l],
                &probe,
            );
            (t.elapsed_s(), out)
        }
    };
    // coordinator side: chained per-point dispatch — layer ℓ+1 follows ℓ
    // with the accumulated (payload, distortion) as its base, or the
    // point finishes (complete or abandoned) and its record +
    // column-best update happen here, in deterministic bookkeeping
    // independent of completion order
    let boundary_budget = |p: usize, st: &PState| {
        // the same two-leg predicate the in-scan poll evaluates, applied
        // to the totals at a layer boundary
        ProbeBudget {
            base_bytes: 0,
            base_distortion: 0.0,
            budget_bytes: budgets[p],
            dominance: dominance.as_deref(),
        }
        .check(st.bytes, st.dist)
    };
    chain_dispatch(pool, "sweep probe", n_points, (0usize, 0.0f64), step, |p, l, (wall, out)| {
        st[p].wall += wall;
        let abandon_kind = match out {
            Ok((cl, rep)) => {
                st[p].bytes += cl.payload.len();
                st[p].dist += rep.distortion;
                st[p].layers.push(cl);
                st[p].reports.push(rep);
                if l + 1 < n_layers {
                    if boundary_budget(p, &st[p]).is_none() {
                        return Some((st[p].bytes, st[p].dist)); // chain continues
                    }
                    // boundary abandon: already provably out of the race
                    Some(AbandonKind::LayerBoundary)
                } else {
                    None // last layer done: completed (budget irrelevant)
                }
            }
            Err(cut) => {
                // record the exact totals the predicate fired at, so the
                // "provably dominated / over budget" claim is checkable
                // from the report alone
                st[p].bytes = cut.bytes;
                st[p].dist = cut.distortion;
                Some(AbandonKind::MidLayer) // in-layer abandon
            }
        };
        let ps = &mut st[p];
        let layers = std::mem::take(&mut ps.layers);
        let reports = std::mem::take(&mut ps.reports);
        if let Some(kind) = abandon_kind {
            points[p] = Some(SweepPoint {
                s: pts[p].s,
                lambda_scale: pts[p].lambda_scale,
                compressed_bytes: ps.bytes,
                density: 0.0,
                distortion: ps.dist,
                abandoned: true,
                abandon_kind: Some(kind),
                container_hash: 0,
                delta_bytes: None,
                seeded: reports.iter().map(|r| r.seeded).sum(),
                seed_hits: reports.iter().map(|r| r.seed_hits).sum(),
                wall_s: ps.wall,
            });
        } else {
            let compressed =
                CompressedModel { name: ctx.model.manifest.name.clone(), layers };
            let ser = compressed.serialize();
            let report = ModelReport::from_layers_sized(&ctx.model, ser.len(), reports);
            let delta = delta_for(ctx, &compressed);
            let sel = sel_metric(report.compressed_bytes, &delta);
            points[p] = Some(SweepPoint {
                s: pts[p].s,
                lambda_scale: pts[p].lambda_scale,
                compressed_bytes: report.compressed_bytes,
                density: report.density,
                distortion: report.layers.iter().map(|r| r.distortion).sum(),
                abandoned: false,
                abandon_kind: None,
                container_hash: fnv1a(&ser),
                delta_bytes: delta.as_ref().and_then(|d| d.as_ref().map(|(b, ..)| *b)),
                seeded: report.layers.iter().map(|r| r.seeded).sum(),
                seed_hits: report.layers.iter().map(|r| r.seed_hits).sum(),
                wall_s: ps.wall,
            });
            let c = cols[p];
            let sched = sched_base + p;
            let better = match &best[c] {
                None => true,
                Some(b) => sel < b.sel || (sel == b.sel && sched < b.sched),
            };
            if better {
                best[c] = Some(Best {
                    sched,
                    point: pts[p],
                    bytes: report.compressed_bytes,
                    sel,
                    delta: delta.flatten().map(|(_, dm, dr)| (dm, dr)),
                    model: compressed,
                    report,
                });
            }
        }
        None // chain finished
    });
    (points.into_iter().map(|p| p.expect("probe point resolved")).collect(), best)
}

/// Run a flat sweep over an explicit (S, λ) grid (single round, no
/// abandonment — every point completes with full stats, so the frontier
/// covers the whole grid). `workers` parallelizes probe points across
/// the pool. Errors on an empty list instead of panicking.
pub fn sweep_grid(
    model: &Model,
    grid: &[GridPoint],
    base: &CompressionSpec,
    workers: usize,
) -> Result<SweepResult> {
    if grid.is_empty() {
        bail!(
            "sweep needs at least one candidate value \
             (empty grid — was --sweep/--points or --lambdas empty?)"
        );
    }
    for p in grid {
        validate_lambda(p.lambda_scale)?;
    }
    let mut eng = SweepEngine::new(model, base, workers);
    eng.run_round(grid, AbandonMode::Off, false);
    eng.finish()
}

/// Run a flat S sweep at the base spec's λ (single round, no
/// abandonment). Errors on an empty list instead of panicking.
pub fn sweep_s(
    model: &Model,
    s_values: &[u32],
    base: &CompressionSpec,
    workers: usize,
) -> Result<SweepResult> {
    if s_values.is_empty() {
        bail!(
            "S sweep needs at least one candidate value \
             (empty grid — was --sweep/--points set to 0?)"
        );
    }
    let grid: Vec<GridPoint> =
        s_values.iter().map(|&s| GridPoint::new(s, base.lambda_scale)).collect();
    sweep_grid(model, &grid, base, workers)
}

/// Coarse-to-fine sweep over the (S × λ) surface: probe
/// `default_s_grid(opts.points)` across every λ-column, then refine each
/// column around its own argmin until every integer between its probed
/// neighbours has been tried. Refinement rounds run under
/// `opts.abandon` and — when `opts.warm_start` — seed their probes from
/// their λ-column incumbents (the coarse round always completes fully,
/// and runs cold since no incumbents exist yet, so the frontier report
/// covers the whole range at every λ). `opts.exhaustive` probes all 257
/// S values per column instead.
pub fn sweep_s_auto(
    model: &Model,
    opts: &SweepOptions,
    base: &CompressionSpec,
) -> Result<SweepResult> {
    if opts.points == 0 {
        bail!("sweep --points must be >= 1");
    }
    let lambdas = resolve_lambdas(&opts.lambdas, base)?;
    let cross = |ss: &[u32]| -> Vec<GridPoint> {
        lambdas
            .iter()
            .flat_map(|&l| ss.iter().map(move |&s| GridPoint::new(s, l)))
            .collect()
    };
    let mut eng = SweepEngine::new(model, base, opts.workers);
    if opts.exhaustive {
        let all: Vec<u32> = (0..=256).collect();
        if opts.abandon != AbandonMode::Off {
            // seed a coarse incumbent per column first so the full
            // 257-point round runs with budgets (and, when warm, with
            // coarse-incumbent seeds): in the argmin mode most
            // far-from-optimal probes then die within their first
            // layers; in the frontier mode only provably dominated ones
            // do
            eng.run_round(&cross(&default_s_grid(opts.points)), AbandonMode::Off, false);
            eng.run_round(&cross(&all), opts.abandon, opts.warm_start);
        } else {
            eng.run_round(&cross(&all), AbandonMode::Off, false);
        }
        return eng.finish();
    }
    // at least the two endpoints, or refinement has no bracket to close
    // in on (--points 1 would otherwise silently probe S=0 alone)
    eng.run_round(&cross(&default_s_grid(opts.points.max(2))), AbandonMode::Off, false);
    loop {
        let mut next: Vec<GridPoint> = Vec::new();
        for &l in &lambdas {
            if let Some(best_s) = eng.best_s_in(l) {
                let probed_s = eng.probed_s_in(l);
                next.extend(
                    refine_grid(&probed_s, best_s, opts.points)
                        .into_iter()
                        .map(|s| GridPoint::new(s, l)),
                );
            }
        }
        if next.is_empty() {
            break;
        }
        eng.run_round(&next, opts.abandon, opts.warm_start);
    }
    eng.finish()
}

/// Delta-aware (S × λ) sweep: the same coarse-to-fine surface search as
/// [`sweep_s_auto`], but every completed grid point is additionally
/// delta-encoded against `parent` (via a [`ParentCtx`] hoisted once —
/// one parent CABAC decode for the whole sweep) and selection minimizes
/// the **delta segment bytes** instead of the full container bytes. The
/// winner's container AND its delta segment come back together
/// (`SweepResult::best_delta`), so the caller ships whichever the
/// client's cache state calls for.
///
/// Warm-start seeding is unchanged (seeds never change a probe's
/// bytes); abandonment is forced off by the engine because full-byte
/// budgets do not order points the way delta bytes do. Grid points whose
/// residuals overflow against `parent` are recorded but never selected.
pub fn sweep_delta(
    parent: &CompressedModel,
    model: &Model,
    opts: &SweepOptions,
    base: &CompressionSpec,
) -> Result<SweepResult> {
    if opts.points == 0 {
        bail!("sweep --points must be >= 1");
    }
    let lambdas = resolve_lambdas(&opts.lambdas, base)?;
    let cross = |ss: &[u32]| -> Vec<GridPoint> {
        lambdas
            .iter()
            .flat_map(|&l| ss.iter().map(move |&s| GridPoint::new(s, l)))
            .collect()
    };
    let mut eng = SweepEngine::new_delta(model, base, opts.workers, parent.clone())?;
    if opts.exhaustive {
        let all: Vec<u32> = (0..=256).collect();
        eng.run_round(&cross(&all), AbandonMode::Off, false);
        return eng.finish();
    }
    eng.run_round(&cross(&default_s_grid(opts.points.max(2))), AbandonMode::Off, false);
    loop {
        let mut next: Vec<GridPoint> = Vec::new();
        for &l in &lambdas {
            if let Some(best_s) = eng.best_s_in(l) {
                let probed_s = eng.probed_s_in(l);
                next.extend(
                    refine_grid(&probed_s, best_s, opts.points)
                        .into_iter()
                        .map(|s| GridPoint::new(s, l)),
                );
            }
        }
        if next.is_empty() {
            break;
        }
        eng.run_round(&next, AbandonMode::Off, opts.warm_start);
    }
    eng.finish()
}

/// Everything `sweep --progressive` produces in one pass: the full
/// sweep record plus the chained v4 container and the per-tier
/// standalone containers it was chained from.
#[derive(Debug)]
pub struct ProgressiveSweep {
    /// The underlying (S × λ) sweep — points, frontier, stats.
    pub result: SweepResult,
    /// The chained `.dcbc` v4 container. `materialize(&progressive, t)`
    /// is byte-identical to `standalone[t]` for every tier t.
    pub progressive: ProgressiveModel,
    /// The standalone container at each tier, coarsest → finest
    /// (`standalone[0]` is the base tier re-encoded as v2).
    pub standalone: Vec<CompressedModel>,
    /// The frontier grid point each tier was recompressed at, in tier
    /// order.
    pub tier_points: Vec<GridPoint>,
    /// Per-refinement residual reports (`reports[t-1]` covers tier t).
    pub reports: Vec<crate::delta::DeltaReport>,
}

/// Progressive sweep driver: run the coarse-to-fine (S × λ) surface
/// search of [`sweep_s_auto`], pick up to `tiers` evenly spaced points
/// along the resulting Pareto frontier (coarsest → finest: the
/// smallest-container point anchors the base tier, the lowest-distortion
/// point the finest), recompress each deterministically through the
/// serial pipeline (verified against the sweep's per-point container
/// fingerprint), and chain-encode them into one `.dcbc` v4 progressive
/// container via the hoisted [`ParentCtx`] rescale path.
///
/// Duplicate frontier entries (identical container bytes) collapse to
/// one tier, so the chain can come back shorter than `tiers` — every
/// refinement tier is guaranteed to change the container. A frontier
/// with a single unique point yields a 1-tier container (base only).
pub fn sweep_progressive(
    model: &Model,
    opts: &SweepOptions,
    base: &CompressionSpec,
    tiers: usize,
) -> Result<ProgressiveSweep> {
    if tiers == 0 {
        bail!("--tiers must be >= 1");
    }
    if tiers > crate::model::MAX_TIERS {
        bail!(
            "--tiers {tiers} exceeds the format limit of {} tiers per container",
            crate::model::MAX_TIERS
        );
    }
    let result = sweep_s_auto(model, opts, base)?;
    // The frontier is sorted by bytes ascending (distortion then
    // non-increasing), i.e. already coarsest → finest. Exact duplicates
    // are all kept there; keep only the first of each container so no
    // refinement tier is a no-op.
    let mut picks: Vec<usize> = Vec::new();
    let mut seen_hashes = BTreeSet::new();
    for &i in &result.frontier {
        if seen_hashes.insert(result.points[i].container_hash) {
            picks.push(i);
        }
    }
    if picks.is_empty() {
        bail!("sweep produced no completed points to build tiers from");
    }
    // Up to `tiers` evenly spaced frontier points, always including the
    // coarsest and finest ends. `tiers == 1` keeps the finest point:
    // a single-tier container's only job is quality.
    let chosen: Vec<usize> = if picks.len() <= tiers {
        picks
    } else if tiers == 1 {
        vec![*picks.last().expect("picks is non-empty")]
    } else {
        let mut idxs: Vec<usize> = (0..tiers)
            .map(|k| (k as f64 / (tiers - 1) as f64 * (picks.len() - 1) as f64).round() as usize)
            .collect();
        idxs.dedup();
        idxs.into_iter().map(|k| picks[k]).collect()
    };
    let mut chain: Vec<CompressedModel> = Vec::with_capacity(chosen.len());
    let mut tier_points: Vec<GridPoint> = Vec::with_capacity(chosen.len());
    for &i in &chosen {
        let p = &result.points[i];
        let spec = CompressionSpec { s: p.s, lambda_scale: p.lambda_scale, ..*base };
        let (c, _) = pipeline::compress_model(model, &spec, opts.workers.max(1));
        let ser = c.serialize();
        if fnv1a(&ser) != p.container_hash {
            bail!(
                "internal error: tier recompress at S={} λ={} does not match the sweep's \
                 container fingerprint",
                p.s,
                p.lambda_scale
            );
        }
        chain.push(c);
        tier_points.push(GridPoint::new(p.s, p.lambda_scale));
    }
    let (progressive, reports) = encode_progressive(&chain, opts.workers.max(1))?;
    Ok(ProgressiveSweep { result, progressive, standalone: chain, tier_points, reports })
}

/// Up to `per_round` evenly spaced unprobed integers strictly between
/// the nearest probed neighbours of `best_s`. Empty when the bracket is
/// exhausted (refinement converged).
fn refine_grid(probed: &BTreeSet<u32>, best_s: u32, per_round: usize) -> Vec<u32> {
    let lo = probed.range(..best_s).next_back().copied().unwrap_or(best_s);
    let hi = probed.range(best_s + 1..).next().copied().unwrap_or(best_s);
    let cands: Vec<u32> = (lo..=hi).filter(|s| !probed.contains(s)).collect();
    if cands.len() <= per_round.max(1) {
        return cands;
    }
    (0..per_round)
        .map(|i| cands[((i as f64 + 0.5) / per_round as f64 * cands.len() as f64) as usize])
        .collect()
}

/// Per-layer grid-point selection (an extension over the paper, which
/// picks one S per model): every layer independently keeps its
/// smallest-payload (S, λ) candidate. Never worse than the global sweep
/// on total payload bytes, since the global optimum is in each layer's
/// candidate set.
///
/// Runs on the engine's (layer × point) task discipline: layers fan out
/// across the worker pool, and each layer's candidates are *chained*
/// (candidate k+1 is dispatched by the coordinator when k completes) so
/// its abandon budget — the layer's own incumbent payload — evolves in
/// exactly the serial candidate order. A probe is abandoned the moment
/// its payload exceeds the layer's incumbent (selection-neutral: equal
/// payloads never replace the incumbent either), and the result is
/// byte-identical at every worker count. Per-layer stats are hoisted
/// across all candidates of a layer.
pub fn sweep_per_layer(
    model: &Model,
    grid: &[GridPoint],
    base: &CompressionSpec,
    workers: usize,
) -> Result<(CompressedModel, ModelReport, Vec<(String, GridPoint)>)> {
    if grid.is_empty() {
        bail!(
            "S sweep needs at least one candidate value \
             (empty grid — was --sweep/--points set to 0?)"
        );
    }
    for p in grid {
        validate_lambda(p.lambda_scale)?;
    }
    let mut seen = BTreeSet::new();
    // re-normalize through GridPoint::new (pub fields — see run_round)
    let pts: Vec<GridPoint> = grid
        .iter()
        .map(|p| GridPoint::new(p.s, p.lambda_scale))
        .filter(|p| seen.insert(p.key()))
        .collect();
    let n = model.weights.len();
    let ctx = probe_ctx(model, base, workers, None);
    let mut best: Vec<Option<(usize, CompressedLayer, LayerReport)>> =
        (0..n).map(|_| None).collect();
    if n > 0 {
        let pool = WorkerPool::new(workers);
        // worker side: one budgeted candidate-compress per task
        let step = {
            let ctx = Arc::clone(&ctx);
            let pts: Arc<Vec<GridPoint>> = Arc::new(pts.clone());
            move |l: usize, k: usize, budget: usize| {
                let pt = pts[k];
                let spec =
                    CompressionSpec { s: pt.s, lambda_scale: pt.lambda_scale, ..ctx.base };
                pipeline::compress_tensor_budgeted(
                    &ctx.model.manifest.layers[l].name,
                    &ctx.model.weights[l].shape,
                    &ctx.model.weights[l].data,
                    &ctx.model.biases[l].data,
                    &spec,
                    &ctx.stats[l],
                    0,
                    budget,
                )
            }
        };
        // coordinator side: candidate k+1 of a layer follows k with the
        // layer's incumbent payload as its budget — exactly the serial
        // candidate order, so selection is worker-count independent
        chain_dispatch(&pool, "per-layer sweep", n, usize::MAX, step, |l, k, out| {
            if let Some((cl, rep)) = out {
                let better = best[l]
                    .as_ref()
                    .map(|(_, b, _)| cl.payload.len() < b.payload.len())
                    .unwrap_or(true);
                if better {
                    best[l] = Some((k, cl, rep));
                }
            }
            if k + 1 < pts.len() {
                Some(best[l].as_ref().map(|(_, b, _)| b.payload.len()).unwrap_or(usize::MAX))
            } else {
                None
            }
        });
    }
    let mut layers = Vec::with_capacity(n);
    let mut reports = Vec::with_capacity(n);
    let mut chosen = Vec::with_capacity(n);
    for slot in best {
        // the first candidate of every layer runs with an unbounded
        // budget, so a best always exists by the time we get here
        let (k, cl, rep) = slot.expect("first grid point is never abandoned");
        chosen.push((cl.name.clone(), pts[k]));
        layers.push(cl);
        reports.push(rep);
    }
    let compressed = CompressedModel { name: model.manifest.name.clone(), layers };
    let report = ModelReport::from_layers(model, &compressed, reports);
    Ok((compressed, report, chosen))
}

/// [`sweep_per_layer`] over an S-only grid at the base spec's λ — the
/// `compress --per-layer` entry point.
pub fn sweep_s_per_layer(
    model: &Model,
    s_values: &[u32],
    base: &CompressionSpec,
    workers: usize,
) -> Result<(CompressedModel, ModelReport, Vec<(String, u32)>)> {
    if s_values.is_empty() {
        bail!(
            "S sweep needs at least one candidate value \
             (empty grid — was --sweep/--points set to 0?)"
        );
    }
    let grid: Vec<GridPoint> =
        s_values.iter().map(|&s| GridPoint::new(s, base.lambda_scale)).collect();
    let (c, r, chosen) = sweep_per_layer(model, &grid, base, workers)?;
    Ok((c, r, chosen.into_iter().map(|(name, p)| (name, p.s)).collect()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point_fields(p: &SweepPoint) -> (u32, u32, usize, bool, f64, f64, u64) {
        (
            p.s,
            p.lambda_scale.to_bits(),
            p.compressed_bytes,
            p.abandoned,
            p.density,
            p.distortion,
            p.container_hash,
        )
    }

    fn s_points(ss: &[u32], lambda: f32) -> Vec<GridPoint> {
        ss.iter().map(|&s| GridPoint::new(s, lambda)).collect()
    }

    #[test]
    fn per_layer_never_worse_than_global() {
        let model = super::super::pipeline::tests::toy_model_pub();
        let base = CompressionSpec::default();
        let s = [0u32, 64, 192, 256];
        let global = sweep_s(&model, &s, &base, 1).unwrap();
        let (_, per_layer, chosen) = sweep_s_per_layer(&model, &s, &base, 1).unwrap();
        assert_eq!(chosen.len(), model.weights.len());
        let global_payload: usize =
            global.best.1.layers.iter().map(|l| l.payload_bytes).sum();
        let per_layer_payload: usize =
            per_layer.layers.iter().map(|l| l.payload_bytes).sum();
        assert!(per_layer_payload <= global_payload);
    }

    #[test]
    fn per_layer_parallel_matches_serial_reference_byte_identical() {
        // satellite: the per-layer sweep now runs on the engine's
        // (layer × point) tasks; it must stay byte-identical to the
        // serial unbudgeted per-layer payload argmin at every worker
        // count (the `parallel_sweep_matches_serial_byte_identical`
        // analogue for per-layer selection).
        let model = super::super::pipeline::tests::toy_model_pub();
        let base = CompressionSpec::default();
        let s = [0u32, 16, 64, 192, 256];
        let mut ref_layers = Vec::new();
        for i in 0..model.weights.len() {
            let stats = LayerStats::compute(
                &model.weights[i].data,
                &model.sigmas[i].data,
                base.weighted,
            );
            let mut layer_best: Option<CompressedLayer> = None;
            for &sv in &s {
                let spec = CompressionSpec { s: sv, ..base };
                let (cl, _) = pipeline::compress_tensor_with_stats(
                    &model.manifest.layers[i].name,
                    &model.weights[i].shape,
                    &model.weights[i].data,
                    &model.biases[i].data,
                    &spec,
                    &stats,
                    1,
                );
                let better = layer_best
                    .as_ref()
                    .map(|b| cl.payload.len() < b.payload.len())
                    .unwrap_or(true);
                if better {
                    layer_best = Some(cl);
                }
            }
            ref_layers.push(layer_best.unwrap());
        }
        let reference =
            CompressedModel { name: model.manifest.name.clone(), layers: ref_layers };
        for workers in [1usize, 2, 4, 8] {
            let (c, _, chosen) = sweep_s_per_layer(&model, &s, &base, workers).unwrap();
            assert_eq!(c.serialize(), reference.serialize(), "workers={workers}");
            assert_eq!(chosen.len(), model.weights.len());
            for ((_, cs), cl) in chosen.iter().zip(&c.layers) {
                assert_eq!(*cs, cl.s_param);
            }
        }
    }

    #[test]
    fn grid_shapes() {
        assert_eq!(default_s_grid(257).len(), 257);
        let g = default_s_grid(9);
        assert_eq!(g.first(), Some(&0));
        assert_eq!(g.last(), Some(&256));
        assert!(g.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn lambda_grid_shapes() {
        assert!(default_lambda_grid(0).is_empty());
        assert_eq!(default_lambda_grid(1), vec![0.05]);
        assert_eq!(default_lambda_grid(2), vec![0.0, 0.05]);
        // N ≥ 2 always includes the λ=0 anchor the legacy example swept,
        // then log-spaces [0.01, 1.0]
        let g = default_lambda_grid(5);
        assert_eq!(g.len(), 5);
        assert_eq!(g[0], 0.0);
        assert!((g[1] - 0.01).abs() < 1e-6);
        assert!((g[4] - 1.0).abs() < 1e-6);
        assert!(g.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn empty_grid_is_an_error_not_a_panic() {
        // regression: an empty candidate list used to hit assert!/unwrap
        // panics; the λ grid is validated the same way
        let model = super::super::pipeline::tests::toy_model_pub();
        let base = CompressionSpec::default();
        let err = sweep_s(&model, &[], &base, 1).expect_err("empty grid must fail");
        assert!(err.to_string().contains("at least one candidate"), "{err}");
        let err = sweep_grid(&model, &[], &base, 1).expect_err("empty grid must fail");
        assert!(err.to_string().contains("at least one candidate"), "{err}");
        let err =
            sweep_s_per_layer(&model, &[], &base, 1).expect_err("empty grid must fail");
        assert!(err.to_string().contains("at least one candidate"), "{err}");
        assert!(default_s_grid(0).is_empty()); // …and this is why sweep_s checks
        let opts = SweepOptions { points: 0, ..Default::default() };
        assert!(sweep_s_auto(&model, &opts, &base).is_err());
        // non-finite / negative λ values are errors, not silent clamps
        let opts = SweepOptions { lambdas: vec![f32::NAN], ..Default::default() };
        assert!(sweep_s_auto(&model, &opts, &base).is_err());
        let opts = SweepOptions { lambdas: vec![0.05, -0.1], ..Default::default() };
        assert!(sweep_s_auto(&model, &opts, &base).is_err());
        let bad = [GridPoint::new(64, -1.0)];
        assert!(sweep_grid(&model, &bad, &base, 1).is_err());
        assert!(sweep_per_layer(&model, &bad, &base, 1).is_err());
    }

    #[test]
    fn sweep_picks_smallest() {
        let model = super::super::pipeline::tests::toy_model_pub();
        let base = CompressionSpec::default();
        let res = sweep_s(&model, &[0, 32, 128, 256], &base, 1).unwrap();
        let best_bytes = res.best.1.compressed_bytes;
        assert!(res.points.iter().all(|p| p.compressed_bytes >= best_bytes));
        assert!(res.points.iter().all(|p| !p.abandoned));
        assert!(res.points.iter().all(|p| p.container_hash != 0));
        assert!(res
            .points
            .iter()
            .all(|p| p.lambda_scale.to_bits() == base.lambda_scale.to_bits()));
        assert_eq!(res.stats.probes_total, 4);
        assert_eq!(res.stats.probes_abandoned, 0);
        assert_eq!(res.stats.rounds, 1);
        assert_eq!(res.stats.columns, 1);
        assert_eq!(res.columns.len(), 1);
        assert_eq!(res.columns[0].bytes, best_bytes);
        assert_eq!(res.best_point.s, res.columns[0].s);
        // coarser grids (smaller S) must not produce *larger* payloads than
        // the finest probe — sanity of the monotone trend
        let s0 = res.points.iter().find(|p| p.s == 0).unwrap();
        let s256 = res.points.iter().find(|p| p.s == 256).unwrap();
        assert!(s0.compressed_bytes <= s256.compressed_bytes);
    }

    #[test]
    fn parallel_sweep_matches_serial_byte_identical() {
        // tentpole invariant: the parallel engine is bit-for-bit the
        // serial sweep — same best container, same point list (including
        // the per-point container fingerprints).
        let model = super::super::pipeline::tests::toy_model_pub();
        let base = CompressionSpec::default();
        let grid = [0u32, 16, 48, 96, 160, 224, 256];
        let serial = sweep_s(&model, &grid, &base, 1).unwrap();
        for workers in [2usize, 4, 8] {
            let par = sweep_s(&model, &grid, &base, workers).unwrap();
            assert_eq!(
                serial.best.0.serialize(),
                par.best.0.serialize(),
                "workers={workers}"
            );
            assert_eq!(serial.points.len(), par.points.len());
            for (a, b) in serial.points.iter().zip(&par.points) {
                assert_eq!(point_fields(a), point_fields(b), "workers={workers}");
            }
            assert_eq!(serial.frontier, par.frontier, "workers={workers}");
        }
    }

    #[test]
    fn lambda_column_matches_legacy_serial_rd_sweep() {
        // satellite (pre-deletion gate for examples/rd_sweep.rs): the
        // engine's λ-column at fixed S must be byte-identical to the
        // serial `compress_model` loop the example ran — checked per
        // grid point via size + FNV fingerprint, and on the winner via
        // full byte equality.
        let model = super::super::pipeline::tests::toy_model_pub();
        let base = CompressionSpec::default();
        let lambdas = [0.0f32, 0.05, 1.0];
        let ss = [0u32, 64, 256];
        let grid: Vec<GridPoint> = lambdas
            .iter()
            .flat_map(|&l| ss.iter().map(move |&s| GridPoint::new(s, l)))
            .collect();
        let res = sweep_grid(&model, &grid, &base, 4).unwrap();
        assert_eq!(res.points.len(), grid.len());
        assert_eq!(res.stats.columns, 3);
        assert_eq!(res.columns.len(), 3);
        for p in &res.points {
            assert!(!p.abandoned);
            let spec = CompressionSpec { s: p.s, lambda_scale: p.lambda_scale, ..base };
            let (c, rep) = super::super::pipeline::compress_model(&model, &spec, 1);
            let ser = c.serialize();
            assert_eq!(p.compressed_bytes, ser.len(), "S={} λ={}", p.s, p.lambda_scale);
            assert_eq!(
                p.container_hash,
                crate::util::fnv1a(&ser),
                "S={} λ={}",
                p.s,
                p.lambda_scale
            );
            assert_eq!(
                p.distortion,
                rep.layers.iter().map(|l| l.distortion).sum::<f64>(),
                "S={} λ={}",
                p.s,
                p.lambda_scale
            );
        }
        // the overall winner is byte-identical to its serial recompress
        let bp = res.best_point;
        let spec = CompressionSpec { s: bp.s, lambda_scale: bp.lambda_scale, ..base };
        let (c, _) = super::super::pipeline::compress_model(&model, &spec, 1);
        assert_eq!(res.best.0.serialize(), c.serialize());
        // each column's argmin is the min over that column's points
        for col in &res.columns {
            let col_min = res
                .points
                .iter()
                .filter(|p| p.lambda_scale.to_bits() == col.lambda_scale.to_bits())
                .map(|p| p.compressed_bytes)
                .min()
                .unwrap();
            assert_eq!(col.bytes, col_min);
            assert_eq!(col.probes, ss.len());
            assert_eq!(col.abandoned, 0);
        }
    }

    #[test]
    fn progressive_sweep_chains_frontier_points() {
        let model = super::super::pipeline::tests::toy_model_pub();
        let base = CompressionSpec::default();
        let opts = SweepOptions {
            points: 5,
            workers: 2,
            lambdas: vec![0.0, 0.05, 1.0],
            ..SweepOptions::default()
        };
        let ps = sweep_progressive(&model, &opts, &base, 3).unwrap();
        assert!(!ps.standalone.is_empty() && ps.standalone.len() <= 3);
        assert_eq!(ps.progressive.n_tiers(), ps.standalone.len());
        assert_eq!(ps.tier_points.len(), ps.standalone.len());
        assert_eq!(ps.reports.len(), ps.standalone.len() - 1);
        // tiers run coarsest → finest along the frontier's byte axis
        for w in ps.standalone.windows(2) {
            assert!(w[0].serialize().len() <= w[1].serialize().len());
        }
        // the chained container materializes byte-identically to every
        // standalone tier — the format's core invariant
        for (t, c) in ps.standalone.iter().enumerate() {
            let m = crate::delta::materialize(&ps.progressive, t, 1).unwrap();
            assert_eq!(m.serialize(), c.serialize(), "tier {t}");
        }
        // wire round-trip
        let bytes = ps.progressive.serialize();
        match crate::model::deserialize_any(&bytes).unwrap() {
            crate::model::Container::Progressive(p) => {
                assert_eq!(p.n_tiers(), ps.progressive.n_tiers());
            }
            other => panic!("expected a progressive container, got {other:?}"),
        }
        // byte-identical at every worker count
        let ps1 =
            sweep_progressive(&model, &SweepOptions { workers: 1, ..opts.clone() }, &base, 3)
                .unwrap();
        assert_eq!(ps1.progressive.serialize(), bytes);
        // --tiers 1 keeps the finest frontier point (quality anchor)
        let one = sweep_progressive(&model, &opts, &base, 1).unwrap();
        assert_eq!(one.progressive.n_tiers(), 1);
        assert_eq!(
            one.standalone[0].serialize(),
            ps.standalone.last().unwrap().serialize()
        );
        assert!(sweep_progressive(&model, &opts, &base, 0).is_err());
        assert!(sweep_progressive(&model, &opts, &base, 65).is_err());
    }

    #[test]
    fn bytes_near_monotone_along_lambda_at_fixed_s() {
        // the smoke script's frontier sanity: at fixed S, a larger λ
        // trades distortion for rate, so the container shrinks. The
        // per-weight argmin minimizes *estimated* cost under adaptive
        // contexts, which gives no strict pointwise guarantee on the
        // final arithmetic-coded payload — so allow a small slack
        // (0.5% + 2 bytes) instead of asserting exact monotonicity.
        let model = super::super::pipeline::tests::toy_model_pub();
        let base = CompressionSpec::default();
        let lambdas = [0.0f32, 0.05, 0.5, 2.0];
        for &s in &[32u32, 128] {
            let grid: Vec<GridPoint> =
                lambdas.iter().map(|&l| GridPoint::new(s, l)).collect();
            let res = sweep_grid(&model, &grid, &base, 2).unwrap();
            let bytes: Vec<usize> =
                res.points.iter().map(|p| p.compressed_bytes).collect();
            assert!(
                bytes.windows(2).all(|w| w[1] <= w[0] + w[0] / 200 + 2),
                "S={s}: {bytes:?}"
            );
            // ...and across the whole λ decade the shrink must be real
            assert!(bytes.last().unwrap() < bytes.first().unwrap(), "S={s}: {bytes:?}");
        }
    }

    #[test]
    fn frontier_is_nondominated_and_covers_extremes() {
        let model = super::super::pipeline::tests::toy_model_pub();
        let base = CompressionSpec::default();
        let grid: Vec<GridPoint> = [0.0f32, 0.05, 0.5]
            .iter()
            .flat_map(|&l| {
                [0u32, 32, 96, 160, 256].iter().map(move |&s| GridPoint::new(s, l))
            })
            .collect();
        let res = sweep_grid(&model, &grid, &base, 2).unwrap();
        let f = &res.frontier;
        assert!(!f.is_empty());
        // sorted by bytes; distortion non-increasing along the frontier
        for w in f.windows(2) {
            let (a, b) = (&res.points[w[0]], &res.points[w[1]]);
            assert!(a.compressed_bytes <= b.compressed_bytes);
            assert!(a.distortion >= b.distortion, "frontier not monotone");
        }
        // non-dominated against every completed point
        for &i in f {
            let p = &res.points[i];
            assert!(!p.abandoned);
            for q in res.points.iter().filter(|q| !q.abandoned) {
                let dominates = q.compressed_bytes <= p.compressed_bytes
                    && q.distortion <= p.distortion
                    && (q.compressed_bytes < p.compressed_bytes
                        || q.distortion < p.distortion);
                assert!(
                    !dominates,
                    "frontier point (S={}, λ={}) is dominated",
                    p.s, p.lambda_scale
                );
            }
        }
        // extreme points: the global min-bytes and min-distortion
        // completed probes are always on the frontier
        let min_bytes =
            res.points.iter().map(|p| p.compressed_bytes).min().unwrap();
        let min_dist = res
            .points
            .iter()
            .map(|p| p.distortion)
            .fold(f64::INFINITY, f64::min);
        assert!(f.iter().any(|&i| res.points[i].compressed_bytes == min_bytes));
        assert!(f.iter().any(|&i| res.points[i].distortion == min_dist));
        // and the overall best container is the min-bytes frontier point
        assert_eq!(res.best.1.compressed_bytes, min_bytes);
    }

    #[test]
    fn two_d_sweep_deterministic_across_worker_counts() {
        // the full 2-D driver (coarse round + per-column refinement with
        // per-column budgets) is a pure function of the schedule
        let model = super::super::pipeline::tests::toy_model_pub();
        let base = CompressionSpec::default();
        let opts = |workers| SweepOptions {
            points: 5,
            workers,
            lambdas: vec![0.01, 0.2],
            ..Default::default()
        };
        let reference = sweep_s_auto(&model, &opts(1), &base).unwrap();
        assert_eq!(reference.stats.columns, 2);
        assert_eq!(reference.columns.len(), 2);
        for workers in [2usize, 4, 8] {
            let res = sweep_s_auto(&model, &opts(workers), &base).unwrap();
            assert_eq!(
                res.best.0.serialize(),
                reference.best.0.serialize(),
                "workers={workers}"
            );
            let a: Vec<_> = reference.points.iter().map(point_fields).collect();
            let b: Vec<_> = res.points.iter().map(point_fields).collect();
            assert_eq!(a, b, "workers={workers}");
            assert_eq!(res.frontier, reference.frontier, "workers={workers}");
            for (x, y) in reference.columns.iter().zip(&res.columns) {
                assert_eq!(x.lambda_scale.to_bits(), y.lambda_scale.to_bits());
                assert_eq!((x.s, x.bytes, x.probes, x.abandoned), (y.s, y.bytes, y.probes, y.abandoned));
                assert_eq!(x.model.serialize(), y.model.serialize());
            }
        }
        // each column refined to a probed local optimum *in its own
        // column*: both integer neighbours of its argmin were visited
        for c in &reference.columns {
            let col_s: Vec<u32> = reference
                .points
                .iter()
                .filter(|p| p.lambda_scale.to_bits() == c.lambda_scale.to_bits())
                .map(|p| p.s)
                .collect();
            for nb in [c.s.saturating_sub(1), (c.s + 1).min(256)] {
                if nb != c.s {
                    assert!(
                        col_s.contains(&nb),
                        "λ={}: neighbour S={nb} of argmin S={} never probed",
                        c.lambda_scale,
                        c.s
                    );
                }
            }
        }
    }

    #[test]
    fn duplicate_lambdas_collapse_to_one_column() {
        let model = super::super::pipeline::tests::toy_model_pub();
        let base = CompressionSpec::default();
        let opts = SweepOptions {
            points: 3,
            workers: 2,
            lambdas: vec![0.05, 0.05],
            ..Default::default()
        };
        let res = sweep_s_auto(&model, &opts, &base).unwrap();
        assert_eq!(res.stats.columns, 1);
        assert_eq!(res.columns.len(), 1);
        // -0.0 and 0.0 have different bit patterns but are ONE column
        // (normalized in GridPoint::new / resolve_lambdas)
        let opts = SweepOptions {
            points: 3,
            workers: 2,
            lambdas: vec![0.0, -0.0],
            ..Default::default()
        };
        let res = sweep_s_auto(&model, &opts, &base).unwrap();
        assert_eq!(res.stats.columns, 1);
        assert_eq!(GridPoint::new(64, -0.0).lambda_scale.to_bits(), 0.0f32.to_bits());
    }

    #[test]
    fn refine_with_abandon_matches_serial_no_abandon() {
        // the kept winner must be byte-identical whether or not probes
        // are abandoned, at any worker count, and the probe schedule +
        // abandoned set must be deterministic.
        let model = super::super::pipeline::tests::toy_model_pub();
        let base = CompressionSpec::default();
        let reference = sweep_s_auto(
            &model,
            &SweepOptions {
                points: 5,
                workers: 1,
                abandon: AbandonMode::Off,
                ..Default::default()
            },
            &base,
        )
        .unwrap();
        let mut abandon_runs = Vec::new();
        for workers in [1usize, 2, 4, 8] {
            let res = sweep_s_auto(
                &model,
                &SweepOptions { points: 5, workers, ..Default::default() },
                &base,
            )
            .unwrap();
            assert_eq!(
                reference.best.0.serialize(),
                res.best.0.serialize(),
                "workers={workers}"
            );
            // identical probe schedule (abandonment never changes the
            // best-S trajectory, so refinement visits the same points)
            let sched: Vec<u32> = res.points.iter().map(|p| p.s).collect();
            let ref_sched: Vec<u32> = reference.points.iter().map(|p| p.s).collect();
            assert_eq!(sched, ref_sched, "workers={workers}");
            // completed points carry identical stats to the no-abandon run
            for (a, b) in reference.points.iter().zip(&res.points) {
                if !b.abandoned {
                    assert_eq!(point_fields(a), point_fields(b), "workers={workers}");
                }
            }
            abandon_runs.push(res);
        }
        // the abandoned set and partial byte counts are identical across
        // worker counts (round-fixed budgets + chained accounting)
        let first = &abandon_runs[0];
        for run in &abandon_runs[1..] {
            let a: Vec<_> = first.points.iter().map(point_fields).collect();
            let b: Vec<_> = run.points.iter().map(point_fields).collect();
            assert_eq!(a, b);
            assert_eq!(first.stats.probes_abandoned, run.stats.probes_abandoned);
        }
    }

    #[test]
    fn early_abandon_kills_oversized_probes_and_is_selection_neutral() {
        let model = super::super::pipeline::tests::toy_model_pub();
        let base = CompressionSpec::default();
        let lam = base.lambda_scale;
        // reference: the same schedule, fully completed
        let full = sweep_s(&model, &[0, 8, 16, 224, 240, 256], &base, 1).unwrap();
        let mut eng = SweepEngine::new(&model, &base, 4);
        eng.run_round(&s_points(&[0, 8, 16], lam), AbandonMode::Off, false);
        // far-from-optimal probes in a budgeted argmin-mode round: S≈256
        // payloads are well above the S≈0 incumbent, so they must be cut
        // short (this is the SelectionNeutral contract — the frontier
        // mode would keep them alive as min-distortion candidates, see
        // frontier_mode_keeps_low_distortion_probes_alive)
        eng.run_round(&s_points(&[224, 240, 256], lam), AbandonMode::SelectionNeutral, false);
        let res = eng.finish().unwrap();
        assert_eq!(res.best.0.serialize(), full.best.0.serialize());
        assert!(
            res.stats.probes_abandoned > 0,
            "oversized probes were not abandoned: {:?}",
            res.points
        );
        assert_eq!(res.stats.rounds, 2);
        assert_eq!(res.columns[0].abandoned, res.stats.probes_abandoned);
        assert_eq!(
            res.stats.abandoned_mid_layer + res.stats.abandoned_boundary,
            res.stats.probes_abandoned,
            "every abandoned probe records where it was cut"
        );
        for p in &res.points {
            assert_eq!(p.abandoned, p.abandon_kind.is_some());
        }
        // abandoned partials are lower bounds that already exceed the
        // payload budget story: they must never be the minimum
        let best_bytes = res.best.1.compressed_bytes;
        for p in res.points.iter().filter(|p| !p.abandoned) {
            assert!(p.compressed_bytes >= best_bytes);
        }
        // abandoned probes never enter the frontier
        for &i in &res.frontier {
            assert!(!res.points[i].abandoned);
        }
    }

    #[test]
    fn frontier_mode_keeps_low_distortion_probes_alive() {
        // the frontier-preserving conjunction: the very probes the
        // argmin mode kills (fine-grid, oversized, LOW distortion) are
        // frontier candidates — nothing completed dominates them on the
        // distortion axis, so they must run to completion and land on
        // the frontier.
        let model = super::super::pipeline::tests::toy_model_pub();
        let base = CompressionSpec::default();
        let lam = base.lambda_scale;
        let mut eng = SweepEngine::new(&model, &base, 4);
        eng.run_round(&s_points(&[0, 8, 16], lam), AbandonMode::Off, false);
        eng.run_round(&s_points(&[224, 240, 256], lam), AbandonMode::FrontierPreserving, false);
        let res = eng.finish().unwrap();
        for p in res.points.iter().filter(|p| p.s >= 224) {
            assert!(
                !p.abandoned,
                "S={}: a min-distortion frontier candidate was abandoned",
                p.s
            );
        }
        // ...and the min-distortion extreme sits on the frontier
        let min_dist =
            res.points.iter().map(|p| p.distortion).fold(f64::INFINITY, f64::min);
        assert!(res.frontier.iter().any(|&i| res.points[i].distortion == min_dist));
    }

    #[test]
    fn frontier_preserving_abandon_matches_no_abandon_frontier() {
        // the tentpole acceptance property: with dominance-based
        // abandonment the Pareto frontier — not just the argmins — is
        // identical to the no-abandon sweep, at every worker count, and
        // every abandoned probe's partial record really is strictly
        // dominated by some completed point.
        let model = super::super::pipeline::tests::toy_model_pub();
        let base = CompressionSpec::default();
        let lambdas = vec![0.0f32, 0.05, 0.5];
        let reference = sweep_s_auto(
            &model,
            &SweepOptions {
                points: 5,
                workers: 1,
                abandon: AbandonMode::Off,
                lambdas: lambdas.clone(),
                ..Default::default()
            },
            &base,
        )
        .unwrap();
        let ref_frontier: Vec<_> = reference
            .frontier
            .iter()
            .map(|&i| {
                let p = &reference.points[i];
                (p.s, p.lambda_scale.to_bits(), p.compressed_bytes, p.distortion.to_bits())
            })
            .collect();
        for workers in [1usize, 4] {
            let res = sweep_s_auto(
                &model,
                &SweepOptions {
                    points: 5,
                    workers,
                    abandon: AbandonMode::FrontierPreserving,
                    lambdas: lambdas.clone(),
                    ..Default::default()
                },
                &base,
            )
            .unwrap();
            let frontier: Vec<_> = res
                .frontier
                .iter()
                .map(|&i| {
                    let p = &res.points[i];
                    (p.s, p.lambda_scale.to_bits(), p.compressed_bytes, p.distortion.to_bits())
                })
                .collect();
            assert_eq!(frontier, ref_frontier, "workers={workers}");
            assert_eq!(res.best.0.serialize(), reference.best.0.serialize());
            // per-column argmins survive abandonment too
            assert_eq!(res.columns.len(), reference.columns.len());
            for (a, b) in res.columns.iter().zip(&reference.columns) {
                assert_eq!(a.lambda_scale.to_bits(), b.lambda_scale.to_bits());
                assert_eq!((a.s, a.bytes), (b.s, b.bytes), "workers={workers}");
            }
            // abandoned ⇒ strictly dominated partials (both axes)
            for p in res.points.iter().filter(|p| p.abandoned) {
                assert!(
                    res.points.iter().filter(|q| !q.abandoned).any(|q| {
                        q.compressed_bytes < p.compressed_bytes + min_overhead(&model)
                            && q.distortion < p.distortion
                    }),
                    "abandoned probe (S={}, λ={}) is not provably dominated",
                    p.s,
                    p.lambda_scale
                );
            }
        }
    }

    #[test]
    fn warm_start_sweep_is_byte_identical_to_cold() {
        // satellite: warm-started refinement sweeps must produce
        // byte-identical containers (FNV per-point fingerprints + the
        // winner's full bytes) to the cold sweep at worker counts
        // {1, 2, 8}, while actually seeding (the refinement rounds all
        // run with column incumbents available).
        let model = super::super::pipeline::tests::toy_model_pub();
        let base = CompressionSpec::default();
        let mk = |workers, warm| SweepOptions {
            points: 5,
            workers,
            warm_start: warm,
            lambdas: vec![0.01, 0.2],
            ..Default::default()
        };
        let cold = sweep_s_auto(&model, &mk(1, false), &base).unwrap();
        assert_eq!(cold.stats.seeded_weights, 0, "cold sweep must not seed");
        for workers in [1usize, 2, 8] {
            let warm = sweep_s_auto(&model, &mk(workers, true), &base).unwrap();
            assert!(
                warm.stats.seeded_weights > 0,
                "workers={workers}: warm sweep never seeded a probe"
            );
            assert!(warm.stats.seed_hits <= warm.stats.seeded_weights);
            // neighbouring-Δ seeds: the hit rate should be high; assert
            // a conservative floor so a silently broken rescale shows up
            assert!(
                warm.stats.seed_hit_rate() > 0.5,
                "workers={workers}: seed hit rate {:.3}",
                warm.stats.seed_hit_rate()
            );
            assert_eq!(warm.best.0.serialize(), cold.best.0.serialize());
            assert_eq!(warm.points.len(), cold.points.len());
            for (a, b) in cold.points.iter().zip(&warm.points) {
                // identical bytes, hashes, distortions — seed stats are
                // the only fields allowed to differ between warm/cold
                assert_eq!(point_fields(a), point_fields(b), "workers={workers}");
            }
            assert_eq!(warm.frontier, cold.frontier, "workers={workers}");
        }
    }

    #[test]
    fn refinement_beats_or_matches_coarse_grid() {
        let model = super::super::pipeline::tests::toy_model_pub();
        let base = CompressionSpec::default();
        let coarse = sweep_s(&model, &default_s_grid(5), &base, 1).unwrap();
        let refined = sweep_s_auto(
            &model,
            &SweepOptions { points: 5, workers: 2, ..Default::default() },
            &base,
        )
        .unwrap();
        assert!(
            refined.best.1.compressed_bytes <= coarse.best.1.compressed_bytes,
            "refinement must never lose to its own coarse round"
        );
        assert!(refined.stats.rounds >= 1);
        assert!(refined.stats.probes_total >= coarse.stats.probes_total);
    }

    #[test]
    fn exhaustive_covers_all_257_points() {
        // tiny model keeps this cheap; exhaustive is the paper's exact
        // protocol and the refinement driver's ground truth
        let model = super::super::pipeline::tests::toy_model_pub();
        let base = CompressionSpec::default();
        let res = sweep_s_auto(
            &model,
            &SweepOptions {
                points: 9,
                workers: 8,
                exhaustive: true,
                abandon: AbandonMode::Off,
                ..Default::default()
            },
            &base,
        )
        .unwrap();
        assert_eq!(res.stats.probes_total, 257);
        assert_eq!(res.stats.rounds, 1);
        // exhaustive + argmin-mode abandon: same winner, same 257-point
        // coverage, via a seeded coarse round + one budgeted full round
        let ex_ab = sweep_s_auto(
            &model,
            &SweepOptions {
                points: 9,
                workers: 4,
                exhaustive: true,
                abandon: AbandonMode::SelectionNeutral,
                ..Default::default()
            },
            &base,
        )
        .unwrap();
        // same optimum size (the schedules differ, so on an exact byte
        // tie the winning S may differ — the minimum cannot)
        assert_eq!(ex_ab.best.1.compressed_bytes, res.best.1.compressed_bytes);
        assert_eq!(ex_ab.stats.probes_total, 257);
        assert_eq!(ex_ab.stats.rounds, 2);
        let refined = sweep_s_auto(
            &model,
            &SweepOptions {
                points: 9,
                workers: 8,
                exhaustive: false,
                ..Default::default()
            },
            &base,
        )
        .unwrap();
        // refinement can at best match the exhaustive protocol…
        assert!(refined.best.1.compressed_bytes >= res.best.1.compressed_bytes);
        // …and must converge to a probed local optimum: both integer
        // neighbours of its argmin were visited
        let best_s = refined.best_point.s;
        for nb in [best_s.saturating_sub(1), (best_s + 1).min(256)] {
            if nb != best_s {
                assert!(
                    refined.points.iter().any(|p| p.s == nb),
                    "neighbour S={nb} of argmin S={best_s} never probed"
                );
            }
        }
    }

    #[test]
    fn min_overhead_is_a_lower_bound_on_real_serialized_overhead() {
        // the selection-neutrality proof rests on
        //   serialize().len() − Σ payload ≥ min_overhead
        // for every container this model can produce; pin the hand-mirrored
        // byte accounting to the real serializer across S and chunk configs
        // so layout drift in `serialize` is caught here.
        let model = super::super::pipeline::tests::toy_model_pub();
        let oh = min_overhead(&model);
        assert!(oh > 0);
        for s in [0u32, 7, 64, 200, 256] {
            for chunks in [1u32, 3] {
                let spec = CompressionSpec { s, chunks, ..Default::default() };
                let (c, _) = super::super::pipeline::compress_model(&model, &spec, 1);
                let payload: usize = c.layers.iter().map(|l| l.payload.len()).sum();
                let real_overhead = c.serialize().len() - payload;
                assert!(
                    oh <= real_overhead,
                    "S={s} chunks={chunks}: min_overhead {oh} > real {real_overhead}"
                );
            }
        }
    }

    #[test]
    fn single_point_sweep_still_brackets_the_range() {
        // regression: --points 1 used to probe S=0 alone and report it as
        // the sweep optimum; the driver must cover both endpoints and
        // refine between them
        let model = super::super::pipeline::tests::toy_model_pub();
        let res = sweep_s_auto(
            &model,
            &SweepOptions { points: 1, workers: 2, ..Default::default() },
            &CompressionSpec::default(),
        )
        .unwrap();
        assert!(res.points.iter().any(|p| p.s == 0));
        assert!(res.points.iter().any(|p| p.s == 256));
        assert!(res.stats.probes_total >= 3, "no refinement happened");
    }

    #[test]
    fn refine_grid_brackets() {
        let probed: BTreeSet<u32> = [0u32, 64, 128, 192, 256].into_iter().collect();
        let g = refine_grid(&probed, 64, 4);
        assert!(!g.is_empty() && g.len() <= 4);
        assert!(g.iter().all(|&s| s > 0 && s < 128 && s != 64));
        assert!(g.windows(2).all(|w| w[0] < w[1]));
        // exhausted bracket → empty
        let probed: BTreeSet<u32> = (10u32..=14).collect();
        assert!(refine_grid(&probed, 12, 4).is_empty());
        // edge argmin: bracket extends only inward
        let probed: BTreeSet<u32> = [0u32, 64].into_iter().collect();
        let g = refine_grid(&probed, 0, 3);
        assert!(g.iter().all(|&s| s > 0 && s < 64));
    }

    /// Parent container plus a sparsely perturbed target model (the
    /// incremental-update fixture: same architecture, ~2% of weights
    /// nudged).
    fn delta_fixture() -> (CompressedModel, Model) {
        let base_model = super::super::pipeline::tests::toy_model_pub();
        let (parent, _) =
            super::super::pipeline::compress_model(&base_model, &CompressionSpec::default(), 1);
        let mut target = base_model;
        let mut rng = crate::util::SplitMix64::new(0xDE17A);
        for t in &mut target.weights {
            let touched = (t.data.len() / 50).max(1);
            for _ in 0..touched {
                let i = rng.below(t.data.len() as u64) as usize;
                t.data[i] += 0.08 * (rng.next_f64() as f32 - 0.5);
            }
        }
        (parent, target)
    }

    #[test]
    fn delta_sweep_parallel_matches_serial_byte_identical() {
        // satellite: the delta-aware sweep must keep the engine's
        // determinism contract — same winner container, same winner
        // delta segment, same per-point records at every worker count.
        let (parent, target) = delta_fixture();
        let base = CompressionSpec::default();
        let mk = |workers: usize| {
            sweep_delta(
                &parent,
                &target,
                &SweepOptions { points: 5, workers, ..Default::default() },
                &base,
            )
            .unwrap()
        };
        let serial = mk(1);
        let (dm_s, _) = serial.best_delta.as_ref().expect("delta sweep returns a delta");
        for workers in [2usize, 4] {
            let par = mk(workers);
            assert_eq!(
                par.best.0.serialize(),
                serial.best.0.serialize(),
                "workers={workers}: winner container diverged"
            );
            let (dm_p, _) = par.best_delta.as_ref().unwrap();
            assert_eq!(
                dm_p.serialize(),
                dm_s.serialize(),
                "workers={workers}: winner delta diverged"
            );
            assert_eq!(par.best_point, serial.best_point);
            let a: Vec<_> = par.points.iter().map(point_fields).collect();
            let b: Vec<_> = serial.points.iter().map(point_fields).collect();
            assert_eq!(a, b, "workers={workers}: point records diverged");
            assert_eq!(
                par.points.iter().map(|p| p.delta_bytes).collect::<Vec<_>>(),
                serial.points.iter().map(|p| p.delta_bytes).collect::<Vec<_>>(),
            );
        }
    }

    #[test]
    fn delta_sweep_selects_on_delta_bytes_and_round_trips() {
        let (parent, target) = delta_fixture();
        let res = sweep_delta(
            &parent,
            &target,
            &SweepOptions { points: 5, workers: 2, ..Default::default() },
            &CompressionSpec::default(),
        )
        .unwrap();
        let (dm, report) = res.best_delta.as_ref().unwrap();
        // the winner minimizes delta bytes over all delta-codable points
        let min_delta =
            res.points.iter().filter_map(|p| p.delta_bytes).min().expect("codable points");
        assert_eq!(dm.total_bytes(), min_delta);
        // every completed point carries its delta size; abandonment is
        // forced off in delta mode so none are abandoned
        assert!(res.points.iter().all(|p| !p.abandoned));
        // frontier points are all delta-codable and sorted by delta bytes
        let fb: Vec<usize> =
            res.frontier.iter().map(|&i| res.points[i].delta_bytes.unwrap()).collect();
        assert!(fb.windows(2).all(|w| w[0] <= w[1]));
        // the delta applies back to the winner container byte-for-byte
        let applied = crate::delta::apply(&parent, dm, 2).unwrap();
        assert_eq!(applied.serialize(), res.best.0.serialize());
        assert!(report.residual_density() > 0.0);
    }

    #[test]
    fn delta_sweep_rejects_architecture_mismatch() {
        let (parent, target) = delta_fixture();
        let opts = SweepOptions { points: 3, workers: 1, ..Default::default() };
        let base = CompressionSpec::default();
        // layer count mismatch
        let mut short = parent.clone();
        short.layers.pop();
        let err = sweep_delta(&short, &target, &opts, &base).unwrap_err();
        assert!(err.to_string().contains("layers"), "{err}");
        // renamed layer
        let mut renamed = parent.clone();
        renamed.layers[0].name.push('X');
        let err = sweep_delta(&renamed, &target, &opts, &base).unwrap_err();
        assert!(err.to_string().contains("name mismatch"), "{err}");
        // weight count mismatch
        let mut resized = parent;
        resized.layers[0].n_weights += 1;
        let err = sweep_delta(&resized, &target, &opts, &base).unwrap_err();
        assert!(err.to_string().contains("weight count"), "{err}");
    }
}
