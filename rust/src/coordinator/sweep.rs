//! The S-sweep scheduler: the paper probes the grid coarseness
//! S ∈ {0, …, 256} per model and keeps the best-compressing setting
//! ("Since the compression result can be sensitive to the parameter S
//! in (2), we probed the compression performance for all S ∈ {0,...,256}
//! and selected the best performing model" — §4).
//!
//! A full 257-point sweep on a 100M-parameter model is expensive, so the
//! scheduler supports arbitrary S lists (coarse-to-fine refinement is
//! what `default_s_grid` returns) and fans candidates onto the worker
//! pool.

use super::pipeline::{compress_model, CompressionSpec};
use super::ModelReport;
use crate::model::{CompressedModel, Model};

#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub s: u32,
    pub compressed_bytes: usize,
    pub density: f64,
    pub distortion: f64,
}

#[derive(Debug)]
pub struct SweepResult {
    pub points: Vec<SweepPoint>,
    pub best: (CompressedModel, ModelReport),
}

/// Coarse-to-fine S grid covering {0..=256} with ~n points.
pub fn default_s_grid(n: usize) -> Vec<u32> {
    if n >= 257 {
        return (0..=256).collect();
    }
    let mut out: Vec<u32> = (0..n)
        .map(|i| ((i as f64 / (n - 1).max(1) as f64) * 256.0).round() as u32)
        .collect();
    out.dedup();
    out
}

/// Run the sweep; returns every probed point plus the best model
/// (smallest container). `workers` parallelizes layers within each probe.
pub fn sweep_s(
    model: &Model,
    s_values: &[u32],
    base: &CompressionSpec,
    workers: usize,
) -> SweepResult {
    assert!(!s_values.is_empty());
    let mut points = Vec::with_capacity(s_values.len());
    let mut best: Option<(CompressedModel, ModelReport)> = None;
    for &s in s_values {
        let spec = CompressionSpec { s, ..*base };
        let (compressed, report) = compress_model(model, &spec, workers);
        points.push(SweepPoint {
            s,
            compressed_bytes: report.compressed_bytes,
            density: report.density,
            distortion: report.layers.iter().map(|l| l.distortion).sum(),
        });
        let better = match &best {
            None => true,
            Some((_, b)) => report.compressed_bytes < b.compressed_bytes,
        };
        if better {
            best = Some((compressed, report));
        }
    }
    SweepResult { points, best: best.unwrap() }
}

/// Per-layer S selection (an extension over the paper, which picks one S
/// per model): every layer independently keeps its smallest-payload S.
/// Never worse than the global sweep on total payload bytes, since the
/// global optimum is in each layer's candidate set.
pub fn sweep_s_per_layer(
    model: &Model,
    s_values: &[u32],
    base: &CompressionSpec,
) -> (CompressedModel, ModelReport, Vec<(String, u32)>) {
    assert!(!s_values.is_empty());
    let n = model.weights.len();
    let mut best_layers: Vec<Option<(crate::model::CompressedLayer, super::LayerReport)>> =
        (0..n).map(|_| None).collect();
    for &s in s_values {
        let spec = CompressionSpec { s, ..*base };
        for i in 0..n {
            let layer = &model.manifest.layers[i];
            let (cl, rep) = super::pipeline::compress_tensor(
                &layer.name,
                &model.weights[i].shape,
                &model.weights[i].data,
                &model.sigmas[i].data,
                &model.biases[i].data,
                &spec,
            );
            let better = best_layers[i]
                .as_ref()
                .map(|(b, _)| cl.payload.len() < b.payload.len())
                .unwrap_or(true);
            if better {
                best_layers[i] = Some((cl, rep));
            }
        }
    }
    let mut layers = Vec::with_capacity(n);
    let mut reports = Vec::with_capacity(n);
    let mut chosen = Vec::with_capacity(n);
    for slot in best_layers {
        let (cl, rep) = slot.unwrap();
        chosen.push((cl.name.clone(), cl.s_param));
        layers.push(cl);
        reports.push(rep);
    }
    let compressed = CompressedModel { name: model.manifest.name.clone(), layers };
    let report = ModelReport::from_layers(model, &compressed, reports);
    (compressed, report, chosen)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_layer_never_worse_than_global() {
        let model = super::super::pipeline::tests::toy_model_pub();
        let base = CompressionSpec::default();
        let s = [0u32, 64, 192, 256];
        let global = sweep_s(&model, &s, &base, 1);
        let (_, per_layer, chosen) = sweep_s_per_layer(&model, &s, &base);
        assert_eq!(chosen.len(), model.weights.len());
        let global_payload: usize =
            global.best.1.layers.iter().map(|l| l.payload_bytes).sum();
        let per_layer_payload: usize =
            per_layer.layers.iter().map(|l| l.payload_bytes).sum();
        assert!(per_layer_payload <= global_payload);
    }

    #[test]
    fn grid_shapes() {
        assert_eq!(default_s_grid(257).len(), 257);
        let g = default_s_grid(9);
        assert_eq!(g.first(), Some(&0));
        assert_eq!(g.last(), Some(&256));
        assert!(g.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn sweep_picks_smallest() {
        let model = super::super::pipeline::tests::toy_model_pub();
        let res = sweep_s(
            &model,
            &[0, 32, 128, 256],
            &CompressionSpec::default(),
            1,
        );
        let best_bytes = res.best.1.compressed_bytes;
        assert!(res.points.iter().all(|p| p.compressed_bytes >= best_bytes));
        // coarser grids (smaller S) must not produce *larger* payloads than
        // the finest probe — sanity of the monotone trend
        let s0 = res.points.iter().find(|p| p.s == 0).unwrap();
        let s256 = res.points.iter().find(|p| p.s == 256).unwrap();
        assert!(s0.compressed_bytes <= s256.compressed_bytes);
    }
}
