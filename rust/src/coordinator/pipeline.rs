//! The per-layer compression pipeline.
//!
//! Every weight tensor is compressed independently ("we applied
//! DeepCABAC on to the weight parameters of each layer separately,
//! excluding biases and normalization parameters" — paper §4), so layers
//! fan out onto a worker pool; results are collected in manifest order.

use crate::bayes;
use crate::codec::CodecConfig;
use crate::model::{ChunkInfo, CompressedLayer, CompressedModel, Model};
use crate::quant::{QuantGrid, QuantResult, RdParams, RdQuantizer};
use crate::util::Timer;

use super::metrics::{LayerReport, ModelReport};

/// Everything that parameterizes one compression run.
#[derive(Debug, Clone, Copy)]
pub struct CompressionSpec {
    /// The grid coarseness hyper-parameter S of eq. 2.
    pub s: u32,
    /// λ = lambda_scale · Δ² · mean(η): scale-free Lagrangian so one knob
    /// works across layers with very different Δ and η magnitudes.
    /// (lambda_scale = 0 recovers weighted nearest-neighbour.)
    pub lambda_scale: f32,
    pub cfg: CodecConfig,
    /// η = 1/σ² (true) vs uniform η (ablation).
    pub weighted: bool,
    /// Candidate window for the RD scan.
    pub window: i32,
    /// Intra-layer chunk count (container-format v2). 1 = monolithic,
    /// bit-for-bit the original single-stream format. N > 1 splits each
    /// tensor into N independently coded streams (contexts reset per
    /// chunk) so one giant layer fans across the worker pool on encode
    /// *and* decode, at a small rate cost from the context restarts.
    pub chunks: u32,
}

impl Default for CompressionSpec {
    fn default() -> Self {
        Self {
            s: 64,
            lambda_scale: 0.05,
            cfg: CodecConfig::default(),
            weighted: true,
            window: 4,
            chunks: 1,
        }
    }
}

/// Compress one tensor on the current thread; honors `spec.chunks`.
pub fn compress_tensor(
    name: &str,
    dims: &[usize],
    weights: &[f32],
    sigmas: &[f32],
    bias: &[f32],
    spec: &CompressionSpec,
) -> (CompressedLayer, LayerReport) {
    compress_tensor_chunked(name, dims, weights, sigmas, bias, spec, 1)
}

/// Compress one tensor, fanning its chunks over up to `workers` threads.
///
/// Grid, η, and λ are derived from the **whole** tensor regardless of
/// chunking, so the only difference between chunk counts is where the
/// adaptive contexts restart — N=1 reproduces the monolithic payload
/// byte-for-byte.
pub fn compress_tensor_chunked(
    name: &str,
    dims: &[usize],
    weights: &[f32],
    sigmas: &[f32],
    bias: &[f32],
    spec: &CompressionSpec,
    workers: usize,
) -> (CompressedLayer, LayerReport) {
    let timer = Timer::new();
    let grid = QuantGrid::from_tensor(weights, sigmas, spec.s);
    let etas = if spec.weighted {
        bayes::etas_from_sigmas(sigmas, bayes::sigma_floor(sigmas))
    } else {
        bayes::etas_uniform(weights.len())
    };
    let mean_eta = etas.iter().map(|&e| e as f64).sum::<f64>() / etas.len().max(1) as f64;
    let lambda = spec.lambda_scale * grid.delta * grid.delta * mean_eta as f32;
    let params = RdParams { lambda, window: spec.window };
    let quantizer = RdQuantizer::new(spec.cfg);

    let n = weights.len();
    let n_chunks = (spec.chunks.max(1) as usize).min(n.max(1));
    let spans = chunk_spans(n, n_chunks);

    let results: Vec<QuantResult> = if spans.len() <= 1 {
        vec![quantizer.quantize_encode(weights, &etas, &grid, params)]
    } else {
        crate::util::par::map_indexed(spans.len(), workers, |i| {
            let (lo, hi) = spans[i];
            quantizer.quantize_encode(&weights[lo..hi], &etas[lo..hi], &grid, params)
        })
    };

    let mut levels = Vec::with_capacity(n);
    let mut payload = Vec::new();
    let mut chunks = Vec::with_capacity(results.len());
    let (mut distortion, mut est_bits) = (0.0f64, 0.0f64);
    for r in results {
        chunks.push(ChunkInfo { n_weights: r.levels.len(), bytes: r.payload.len() });
        levels.extend_from_slice(&r.levels);
        payload.extend_from_slice(&r.payload);
        distortion += r.distortion;
        est_bits += r.est_bits;
    }
    if chunks.len() <= 1 {
        chunks.clear(); // canonical monolithic representation (v1 format)
    }

    let nonzero = levels.iter().filter(|&&l| l != 0).count();
    let report = LayerReport {
        name: name.to_string(),
        n_weights: n,
        nonzero,
        payload_bytes: payload.len(),
        n_chunks: chunks.len().max(1),
        distortion,
        est_bits,
        time_s: timer.elapsed_s(),
    };
    let layer = CompressedLayer {
        name: name.to_string(),
        dims: dims.to_vec(),
        grid,
        s_param: spec.s,
        cfg: spec.cfg,
        n_weights: n,
        payload,
        chunks,
        bias: bias.to_vec(),
    };
    (layer, report)
}

/// Even contiguous split of `n` items into `k` spans (first `n % k`
/// spans get one extra item). Returns (lo, hi) pairs.
fn chunk_spans(n: usize, k: usize) -> Vec<(usize, usize)> {
    let k = k.max(1);
    if n == 0 {
        return vec![(0, 0)];
    }
    let (base, extra) = (n / k, n % k);
    let mut spans = Vec::with_capacity(k);
    let mut lo = 0;
    for i in 0..k {
        let len = base + usize::from(i < extra);
        if len == 0 {
            break;
        }
        spans.push((lo, lo + len));
        lo += len;
    }
    spans
}

/// Compress a whole model with `workers` threads. With `spec.chunks == 1`
/// layers fan out onto the pool (results re-assembled in manifest
/// order); with intra-layer chunking enabled, layers are processed in
/// order and each layer's chunks fan across the pool instead — the mode
/// for models whose runtime is dominated by one giant tensor.
pub fn compress_model(
    model: &Model,
    spec: &CompressionSpec,
    workers: usize,
) -> (CompressedModel, ModelReport) {
    let n = model.weights.len();
    let mut slots: Vec<Option<(CompressedLayer, LayerReport)>> = (0..n).map(|_| None).collect();

    if spec.chunks > 1 {
        for i in 0..n {
            let layer = &model.manifest.layers[i];
            slots[i] = Some(compress_tensor_chunked(
                &layer.name,
                &model.weights[i].shape,
                &model.weights[i].data,
                &model.sigmas[i].data,
                &model.biases[i].data,
                spec,
                workers,
            ));
        }
    } else if workers <= 1 || n <= 1 {
        for i in 0..n {
            slots[i] = Some(compress_layer_idx(model, i, spec));
        }
    } else {
        // Work-stealing over layer indices with scoped threads; a bounded
        // channel applies backpressure so huge layers don't pile up.
        let next = std::sync::atomic::AtomicUsize::new(0);
        let (tx, rx) = std::sync::mpsc::sync_channel::<(usize, (CompressedLayer, LayerReport))>(
            workers * 2,
        );
        std::thread::scope(|scope| {
            for _ in 0..workers.min(n) {
                let tx = tx.clone();
                let next = &next;
                scope.spawn(move || loop {
                    let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let out = compress_layer_idx(model, i, spec);
                    if tx.send((i, out)).is_err() {
                        break;
                    }
                });
            }
            drop(tx);
            for (i, out) in rx {
                slots[i] = Some(out);
            }
        });
    }

    let mut layers = Vec::with_capacity(n);
    let mut reports = Vec::with_capacity(n);
    for slot in slots {
        let (l, r) = slot.expect("layer not compressed");
        layers.push(l);
        reports.push(r);
    }
    let compressed = CompressedModel { name: model.manifest.name.clone(), layers };
    let report = ModelReport::from_layers(model, &compressed, reports);
    (compressed, report)
}

fn compress_layer_idx(
    model: &Model,
    i: usize,
    spec: &CompressionSpec,
) -> (CompressedLayer, LayerReport) {
    let layer = &model.manifest.layers[i];
    compress_tensor(
        &layer.name,
        &model.weights[i].shape,
        &model.weights[i].data,
        &model.sigmas[i].data,
        &model.biases[i].data,
        spec,
    )
}

/// Reconstruct all weight tensors from a compressed model.
pub fn decompress(compressed: &CompressedModel) -> Vec<crate::tensor::Tensor> {
    compressed
        .layers
        .iter()
        .map(|l| crate::tensor::Tensor::new(l.dims.clone(), l.decode_weights()))
        .collect()
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::util::SplitMix64;

    /// Shared fixture for sibling modules' tests.
    pub(crate) fn toy_model_pub() -> Model {
        toy_model()
    }

    fn toy_model() -> Model {
        use crate::model::manifest::*;
        let mut rng = SplitMix64::new(71);
        let mut weights = Vec::new();
        let mut sigmas = Vec::new();
        let mut biases = Vec::new();
        let mut layers = Vec::new();
        for (li, n) in [400usize, 900, 120].iter().enumerate() {
            let mut w = vec![0.0f32; *n];
            let mut s = vec![0.0f32; *n];
            for i in 0..*n {
                if rng.next_f64() > 0.8 {
                    w[i] = rng.laplace(0.05) as f32;
                }
                s[i] = 0.01 + 0.1 * rng.next_f32();
            }
            weights.push(crate::tensor::Tensor::new(vec![*n], w));
            sigmas.push(crate::tensor::Tensor::new(vec![*n], s));
            biases.push(crate::tensor::Tensor::new(vec![4], vec![0.1; 4]));
            layers.push(LayerInfo {
                name: format!("l{li}"),
                kind: LayerKind::Fc,
                shape: vec![*n],
                activation: None,
                stride: 1,
                padding: 0,
                nonzero: 0,
                size: *n,
            });
        }
        Model {
            manifest: ModelManifest {
                name: "toy".into(),
                task: "classify".into(),
                input_shape: vec![4],
                eval_batch: 2,
                n_classes: 2,
                param_count: 1420,
                density: 0.2,
                dense_metric: 1.0,
                sparse_metric: 1.0,
                layers,
                hlo: "none".into(),
                arg_order: vec![],
            },
            weights,
            biases,
            sigmas,
        }
    }

    #[test]
    fn compress_decompress_bounded_error() {
        let model = toy_model();
        let spec = CompressionSpec { lambda_scale: 0.0, ..Default::default() };
        let (compressed, report) = compress_model(&model, &spec, 1);
        assert_eq!(compressed.layers.len(), 3);
        assert!(report.compressed_bytes > 0);
        let recon = decompress(&compressed);
        for ((orig, rec), cl) in model.weights.iter().zip(&recon).zip(&compressed.layers) {
            assert_eq!(orig.shape, rec.shape);
            let bound = 0.51 * cl.grid.delta;
            let w_cap = cl.grid.value(cl.grid.max_level).abs();
            for (a, b) in orig.data.iter().zip(&rec.data) {
                if a.abs() <= w_cap {
                    // λ=0: nearest-neighbour ⇒ error ≤ Δ/2 for in-range weights
                    assert!((a - b).abs() <= bound, "a={a} b={b} Δ={}", cl.grid.delta);
                }
            }
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let model = toy_model();
        let spec = CompressionSpec::default();
        let (a, _) = compress_model(&model, &spec, 1);
        let (b, _) = compress_model(&model, &spec, 4);
        assert_eq!(a.serialize(), b.serialize());
    }

    fn sparse_fixture(n: usize, density: f64, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = SplitMix64::new(seed);
        let mut w = vec![0.0f32; n];
        let mut s = vec![0.0f32; n];
        for i in 0..n {
            if rng.next_f64() < density {
                w[i] = rng.laplace(0.08) as f32;
            }
            s[i] = 0.02 + 0.05 * rng.next_f32();
        }
        (w, s)
    }

    #[test]
    fn n1_chunk_reproduces_monolithic_payload() {
        // chunks = 1 must be byte-for-byte the single-stream encode the
        // format has always produced (and stays in the v1 container).
        let (w, s) = sparse_fixture(20_000, 0.1, 11);
        let spec = CompressionSpec::default();
        assert_eq!(spec.chunks, 1);
        let (layer, rep) = compress_tensor("t", &[w.len()], &w, &s, &[], &spec);
        assert!(layer.chunks.is_empty());
        assert_eq!(rep.n_chunks, 1);

        // the pre-chunking reference path: one QuantResult over the tensor
        let grid = QuantGrid::from_tensor(&w, &s, spec.s);
        let etas = bayes::etas_from_sigmas(&s, bayes::sigma_floor(&s));
        let mean_eta =
            etas.iter().map(|&e| e as f64).sum::<f64>() / etas.len().max(1) as f64;
        let lambda = spec.lambda_scale * grid.delta * grid.delta * mean_eta as f32;
        let reference = RdQuantizer::new(spec.cfg).quantize_encode(
            &w,
            &etas,
            &grid,
            RdParams { lambda, window: spec.window },
        );
        assert_eq!(layer.payload, reference.payload);
        assert_eq!(layer.decode_levels(), reference.levels);
    }

    #[test]
    fn chunked_encode_deterministic_and_roundtrips() {
        let (w, s) = sparse_fixture(30_000, 0.1, 23);
        for chunks in [2u32, 4, 7] {
            let spec = CompressionSpec { chunks, ..Default::default() };
            let (a, rep) =
                compress_tensor_chunked("t", &[w.len()], &w, &s, &[], &spec, 1);
            let (b, _) = compress_tensor_chunked("t", &[w.len()], &w, &s, &[], &spec, 4);
            assert_eq!(a.payload, b.payload, "chunks={chunks}");
            assert_eq!(a.chunks, b.chunks, "chunks={chunks}");
            assert_eq!(rep.n_chunks, chunks as usize);
            // decode (serial and chunk-parallel) equals per-span re-encode
            let grid = QuantGrid::from_tensor(&w, &s, spec.s);
            let etas = bayes::etas_from_sigmas(&s, bayes::sigma_floor(&s));
            let mean_eta =
                etas.iter().map(|&e| e as f64).sum::<f64>() / etas.len().max(1) as f64;
            let lambda = spec.lambda_scale * grid.delta * grid.delta * mean_eta as f32;
            let mut expected = Vec::new();
            let mut lo = 0usize;
            for c in &a.chunks {
                let hi = lo + c.n_weights;
                let r = RdQuantizer::new(spec.cfg).quantize_encode(
                    &w[lo..hi],
                    &etas[lo..hi],
                    &grid,
                    RdParams { lambda, window: spec.window },
                );
                expected.extend_from_slice(&r.levels);
                lo = hi;
            }
            assert_eq!(a.decode_levels_with(1), expected, "chunks={chunks}");
            assert_eq!(a.decode_levels(), expected, "chunks={chunks}");
        }
    }

    #[test]
    fn chunk_rate_overhead_is_small() {
        // context restarts cost a warmup per chunk; on a realistic
        // fixture the overhead must stay low (< 2% at the bench's scale,
        // checked there too — this is the fast guard)
        let (w, s) = sparse_fixture(120_000, 0.1, 31);
        let mono = compress_tensor(
            "t",
            &[w.len()],
            &w,
            &s,
            &[],
            &CompressionSpec::default(),
        )
        .0
        .payload
        .len() as f64;
        for (chunks, bound) in [(2u32, 1.02), (8, 1.05)] {
            let spec = CompressionSpec { chunks, ..Default::default() };
            let chunked = compress_tensor_chunked("t", &[w.len()], &w, &s, &[], &spec, 2)
                .0
                .payload
                .len() as f64;
            assert!(
                chunked <= mono * bound,
                "chunks={chunks}: {chunked} vs {mono} (bound {bound})"
            );
        }
    }

    #[test]
    fn chunked_model_parallel_matches_serial() {
        let model = toy_model();
        let spec = CompressionSpec { chunks: 3, ..Default::default() };
        let (a, ra) = compress_model(&model, &spec, 1);
        let (b, _) = compress_model(&model, &spec, 4);
        assert_eq!(a.serialize(), b.serialize());
        assert!(a.is_chunked());
        assert!(ra.layers.iter().all(|l| l.n_chunks == 3));
        // chunked container roundtrips through serialization
        let re = crate::model::CompressedModel::deserialize(&a.serialize()).unwrap();
        for (x, y) in a.layers.iter().zip(&re.layers) {
            assert_eq!(x.decode_levels(), y.decode_levels());
        }
    }

    #[test]
    fn weighted_vs_uniform_changes_output() {
        let model = toy_model();
        let (a, _) = compress_model(&model, &CompressionSpec::default(), 1);
        let (b, _) = compress_model(
            &model,
            &CompressionSpec { weighted: false, ..Default::default() },
            1,
        );
        assert_ne!(a.serialize(), b.serialize());
    }
}
