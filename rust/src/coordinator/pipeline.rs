//! The per-layer compression pipeline.
//!
//! Every weight tensor is compressed independently ("we applied
//! DeepCABAC on to the weight parameters of each layer separately,
//! excluding biases and normalization parameters" — paper §4), so layers
//! fan out onto a worker pool; results are collected in manifest order.
//!
//! The per-tensor invariants of eq. 1/eq. 2 — w_max, σ_min, the η
//! vector, mean(η) — depend on **neither** the grid coarseness S nor
//! the Lagrangian scale λ (λ = lambda_scale · Δ² · mean(η) is *derived
//! from* them per grid point), so they are hoisted into [`LayerStats`]:
//! the (S × λ) sweep engine computes them once per layer and shares
//! them across every probe of that layer over the whole surface instead
//! of recomputing them per (layer × S × λ) probe.

use crate::bayes;
use crate::codec::CodecConfig;
use crate::model::{ChunkInfo, CompressedLayer, CompressedModel, Model};
use crate::quant::{
    AbandonedAt, DominanceFrontier, ProbeBudget, QuantGrid, QuantResult, RdParams,
    RdQuantizer, ScanSeed,
};
use crate::util::Timer;

use super::metrics::{LayerReport, ModelReport};

/// Everything that parameterizes one compression run.
#[derive(Debug, Clone, Copy)]
pub struct CompressionSpec {
    /// The grid coarseness hyper-parameter S of eq. 2.
    pub s: u32,
    /// λ = lambda_scale · Δ² · mean(η): scale-free Lagrangian so one knob
    /// works across layers with very different Δ and η magnitudes.
    /// (lambda_scale = 0 recovers weighted nearest-neighbour.)
    pub lambda_scale: f32,
    pub cfg: CodecConfig,
    /// η = 1/σ² (true) vs uniform η (ablation).
    pub weighted: bool,
    /// Intra-layer chunk count (container-format v2). 1 = monolithic,
    /// bit-for-bit the original single-stream format. N > 1 splits each
    /// tensor into N independently coded streams (contexts reset per
    /// chunk) so one giant layer fans across the worker pool on encode
    /// *and* decode, at a small rate cost from the context restarts.
    pub chunks: u32,
}

impl Default for CompressionSpec {
    fn default() -> Self {
        Self {
            s: 64,
            lambda_scale: 0.05,
            cfg: CodecConfig::default(),
            weighted: true,
            chunks: 1,
        }
    }
}

/// Per-tensor invariants shared by every probe of an (S × λ) sweep.
/// Building the grid from these via [`LayerStats::grid`] is exactly
/// equivalent to [`QuantGrid::from_tensor`] on the raw tensors (same
/// folds, same fallbacks), and [`LayerStats::lambda`] reproduces the
/// inline λ expression bit for bit, so hoisting changes no bytes.
#[derive(Debug, Clone)]
pub struct LayerStats {
    /// max |w| over the tensor (the w_max of eq. 2).
    pub w_max: f32,
    /// Smallest positive σ (1.0 fallback for all-zero σ tensors),
    /// matching the [`QuantGrid::from_tensor`] convention.
    pub sigma_min: f32,
    /// η_i = 1/σ_i² (or all-ones for the unweighted ablation).
    pub etas: Vec<f32>,
    /// mean(η) in f64, the λ normalizer.
    pub mean_eta: f64,
}

impl LayerStats {
    pub fn compute(weights: &[f32], sigmas: &[f32], weighted: bool) -> Self {
        let w_max = weights.iter().fold(0.0f32, |m, &w| m.max(w.abs()));
        let sigma_min = sigmas
            .iter()
            .copied()
            .filter(|s| *s > 0.0)
            .fold(f32::INFINITY, f32::min);
        let sigma_min = if sigma_min.is_finite() { sigma_min } else { 1.0 };
        let etas = if weighted {
            bayes::etas_from_sigmas(sigmas, bayes::sigma_floor(sigmas))
        } else {
            bayes::etas_uniform(weights.len())
        };
        let mean_eta =
            etas.iter().map(|&e| e as f64).sum::<f64>() / etas.len().max(1) as f64;
        Self { w_max, sigma_min, etas, mean_eta }
    }

    /// Eq. 2 grid for coarseness `s` — identical to
    /// `QuantGrid::from_tensor(weights, sigmas, s)`.
    pub fn grid(&self, s: u32) -> QuantGrid {
        QuantGrid::from_stats(self.w_max, self.sigma_min, s)
    }

    /// λ = lambda_scale · Δ² · mean(η) (the same f32 expression, in the
    /// same order, as the pre-hoisting pipeline computed inline).
    pub fn lambda(&self, lambda_scale: f32, grid: &QuantGrid) -> f32 {
        lambda_scale * grid.delta * grid.delta * self.mean_eta as f32
    }
}

/// Compress one tensor on the current thread; honors `spec.chunks`.
pub fn compress_tensor(
    name: &str,
    dims: &[usize],
    weights: &[f32],
    sigmas: &[f32],
    bias: &[f32],
    spec: &CompressionSpec,
) -> (CompressedLayer, LayerReport) {
    compress_tensor_chunked(name, dims, weights, sigmas, bias, spec, 1)
}

/// Compress one tensor, fanning its chunks over up to `workers` threads.
///
/// Grid, η, and λ are derived from the **whole** tensor regardless of
/// chunking, so the only difference between chunk counts is where the
/// adaptive contexts restart — N=1 reproduces the monolithic payload
/// byte-for-byte.
pub fn compress_tensor_chunked(
    name: &str,
    dims: &[usize],
    weights: &[f32],
    sigmas: &[f32],
    bias: &[f32],
    spec: &CompressionSpec,
    workers: usize,
) -> (CompressedLayer, LayerReport) {
    let stats = LayerStats::compute(weights, sigmas, spec.weighted);
    compress_tensor_with_stats(name, dims, weights, bias, spec, &stats, workers)
}

/// [`compress_tensor_chunked`] with the per-tensor invariants supplied
/// by the caller (the sweep engine computes them once per layer).
pub fn compress_tensor_with_stats(
    name: &str,
    dims: &[usize],
    weights: &[f32],
    bias: &[f32],
    spec: &CompressionSpec,
    stats: &LayerStats,
    workers: usize,
) -> (CompressedLayer, LayerReport) {
    let timer = Timer::new();
    let grid = stats.grid(spec.s);
    let params = RdParams { lambda: stats.lambda(spec.lambda_scale, &grid) };
    let quantizer = RdQuantizer::new(spec.cfg);
    let etas = &stats.etas;

    let n = weights.len();
    let n_chunks = (spec.chunks.max(1) as usize).min(n.max(1));
    let spans = chunk_spans(n, n_chunks);

    let results: Vec<QuantResult> = if spans.len() <= 1 {
        vec![quantizer.quantize_encode(weights, etas, &grid, params)]
    } else {
        crate::util::par::map_indexed(spans.len(), workers, |i| {
            let (lo, hi) = spans[i];
            quantizer.quantize_encode(&weights[lo..hi], &etas[lo..hi], &grid, params)
        })
    };
    assemble_layer(name, dims, bias, spec, grid, n, results, &timer)
}

/// Everything a sweep-probe layer task carries besides the tensor
/// itself: running totals from the probe's earlier layers, the 2-D
/// abandon predicate's two legs, and the optional warm-start seed.
#[derive(Debug, Clone, Copy)]
pub struct LayerProbe<'a> {
    /// Payload bytes accumulated by this probe's earlier layers.
    pub base_bytes: usize,
    /// Distortion accumulated by this probe's earlier layers (summed in
    /// the same order the completed probe's report would sum it, so it
    /// is an exact monotone lower bound on the final distortion).
    pub base_distortion: f64,
    /// Payload budget (λ-column incumbent leg); `usize::MAX` = off.
    pub budget_bytes: usize,
    /// Completed-point staircase (dominance leg); `None` makes the
    /// payload leg decide alone (legacy selection-neutral budget).
    pub dominance: Option<&'a DominanceFrontier>,
    /// Warm-start seed: this layer's levels from an already-probed grid
    /// point, plus that point's S (the grid-step rescale factor is
    /// derived per layer from [`LayerStats`]).
    pub seed: Option<(&'a [i32], u32)>,
}

impl LayerProbe<'_> {
    /// A probe that never abandons and scans cold.
    pub const PLAIN: LayerProbe<'static> = LayerProbe {
        base_bytes: 0,
        base_distortion: 0.0,
        budget_bytes: usize::MAX,
        dominance: None,
        seed: None,
    };
}

impl Default for LayerProbe<'_> {
    fn default() -> Self {
        Self::PLAIN
    }
}

/// Budgeted variant for sweep probes: chunks run sequentially on the
/// calling worker, and the encode aborts — returning `None` — the moment
/// `base_bytes` (payload accumulated by earlier layers of the same
/// probe) plus the bytes produced so far exceed `budget_bytes`. Since
/// the byte counts only ever grow, an abandoned probe could not have
/// finished within budget, so abandonment never changes which probe
/// wins. A `Some` result is byte-identical to the unbudgeted path.
/// (The byte-leg-only special case of [`compress_tensor_probe`] — the
/// per-layer sweep's budget, and the legacy call shape.)
pub fn compress_tensor_budgeted(
    name: &str,
    dims: &[usize],
    weights: &[f32],
    bias: &[f32],
    spec: &CompressionSpec,
    stats: &LayerStats,
    base_bytes: usize,
    budget_bytes: usize,
) -> Option<(CompressedLayer, LayerReport)> {
    let probe = LayerProbe { base_bytes, budget_bytes, ..LayerProbe::PLAIN };
    compress_tensor_probe(name, dims, weights, bias, spec, stats, &probe).ok()
}

/// The (S × λ) engine's full probe task: [`compress_tensor_budgeted`]
/// extended with the dominance leg of the 2-D abandon predicate and the
/// warm-start seed (see [`LayerProbe`]). Chunks run sequentially; the
/// abandon predicate is polled inside each chunk scan with the exact
/// running (payload, distortion) lower bounds, and an `Err` carries the
/// probe-absolute totals the predicate fired at. An `Ok` result is
/// byte-identical to the plain unseeded, unbudgeted path.
pub fn compress_tensor_probe(
    name: &str,
    dims: &[usize],
    weights: &[f32],
    bias: &[f32],
    spec: &CompressionSpec,
    stats: &LayerStats,
    probe: &LayerProbe,
) -> Result<(CompressedLayer, LayerReport), AbandonedAt> {
    let timer = Timer::new();
    let grid = stats.grid(spec.s);
    let params = RdParams { lambda: stats.lambda(spec.lambda_scale, &grid) };
    let quantizer = RdQuantizer::new(spec.cfg);
    // Seed levels live on the seed point's grid; Δ_seed/Δ_probe maps
    // them onto this probe's grid (for neighbouring S the ratio is
    // within 1% of 1, so nearly every rescaled seed is the argmin).
    let seed = probe.seed.map(|(levels, seed_s)| {
        (levels, stats.grid(seed_s).delta as f64 / grid.delta as f64)
    });

    let n = weights.len();
    let n_chunks = (spec.chunks.max(1) as usize).min(n.max(1));
    let spans = chunk_spans(n, n_chunks);

    let mut results = Vec::with_capacity(spans.len());
    let mut acc = 0usize;
    let mut acc_dist = 0.0f64;
    for &(lo, hi) in &spans {
        let budget = ProbeBudget {
            base_bytes: probe.base_bytes.saturating_add(acc),
            base_distortion: probe.base_distortion + acc_dist,
            budget_bytes: probe.budget_bytes,
            dominance: probe.dominance,
        };
        let chunk_seed =
            seed.map(|(levels, scale)| ScanSeed { levels: &levels[lo..hi], scale });
        let r = quantizer.quantize_encode_probe(
            &weights[lo..hi],
            &stats.etas[lo..hi],
            &grid,
            params,
            &budget,
            chunk_seed,
        )?; // Err already carries probe-absolute totals (the budget's base)
        acc += r.payload.len();
        acc_dist += r.distortion;
        results.push(r);
    }
    Ok(assemble_layer(name, dims, bias, spec, grid, n, results, &timer))
}

/// Stitch chunk results into a [`CompressedLayer`] + [`LayerReport`]
/// (shared by the parallel-chunk and budgeted paths, so both produce the
/// same bytes for the same inputs).
#[allow(clippy::too_many_arguments)]
fn assemble_layer(
    name: &str,
    dims: &[usize],
    bias: &[f32],
    spec: &CompressionSpec,
    grid: QuantGrid,
    n: usize,
    results: Vec<QuantResult>,
    timer: &Timer,
) -> (CompressedLayer, LayerReport) {
    let mut levels = Vec::with_capacity(n);
    let mut payload = Vec::new();
    let mut chunks = Vec::with_capacity(results.len());
    let (mut distortion, mut est_bits) = (0.0f64, 0.0f64);
    let (mut seed_hits, mut seeded) = (0usize, 0usize);
    for r in results {
        chunks.push(ChunkInfo { n_weights: r.levels.len(), bytes: r.payload.len() });
        levels.extend_from_slice(&r.levels);
        payload.extend_from_slice(&r.payload);
        distortion += r.distortion;
        est_bits += r.est_bits;
        seed_hits += r.seed_hits;
        seeded += r.seeded;
    }
    if chunks.len() <= 1 {
        chunks.clear(); // canonical monolithic representation (v1 format)
    }

    let nonzero = levels.iter().filter(|&&l| l != 0).count();
    let report = LayerReport {
        name: name.to_string(),
        n_weights: n,
        nonzero,
        payload_bytes: payload.len(),
        n_chunks: chunks.len().max(1),
        distortion,
        est_bits,
        seed_hits,
        seeded,
        time_s: timer.elapsed_s(),
    };
    let layer = CompressedLayer {
        name: name.to_string(),
        dims: dims.to_vec(),
        grid,
        s_param: spec.s,
        cfg: spec.cfg,
        n_weights: n,
        payload,
        chunks,
        bias: bias.to_vec(),
    };
    (layer, report)
}

/// Even contiguous split of `n` items into `k` spans (first `n % k`
/// spans get one extra item). Returns (lo, hi) pairs.
fn chunk_spans(n: usize, k: usize) -> Vec<(usize, usize)> {
    let k = k.max(1);
    if n == 0 {
        return vec![(0, 0)];
    }
    let (base, extra) = (n / k, n % k);
    let mut spans = Vec::with_capacity(k);
    let mut lo = 0;
    for i in 0..k {
        let len = base + usize::from(i < extra);
        if len == 0 {
            break;
        }
        spans.push((lo, lo + len));
        lo += len;
    }
    spans
}

/// Compress a whole model with `workers` threads. With `spec.chunks == 1`
/// layers fan out via [`crate::util::par::map_indexed`] (results
/// re-assembled in manifest order); with intra-layer chunking enabled,
/// layers are processed in order and each layer's chunks fan across the
/// threads instead — the mode for models whose runtime is dominated by
/// one giant tensor.
pub fn compress_model(
    model: &Model,
    spec: &CompressionSpec,
    workers: usize,
) -> (CompressedModel, ModelReport) {
    let n = model.weights.len();
    let outs: Vec<(CompressedLayer, LayerReport)> = if spec.chunks > 1 {
        (0..n)
            .map(|i| {
                let layer = &model.manifest.layers[i];
                compress_tensor_chunked(
                    &layer.name,
                    &model.weights[i].shape,
                    &model.weights[i].data,
                    &model.sigmas[i].data,
                    &model.biases[i].data,
                    spec,
                    workers,
                )
            })
            .collect()
    } else {
        crate::util::par::map_indexed(n, workers, |i| compress_layer_idx(model, i, spec))
    };

    let mut layers = Vec::with_capacity(n);
    let mut reports = Vec::with_capacity(n);
    for (l, r) in outs {
        layers.push(l);
        reports.push(r);
    }
    let compressed = CompressedModel { name: model.manifest.name.clone(), layers };
    let report = ModelReport::from_layers(model, &compressed, reports);
    (compressed, report)
}

fn compress_layer_idx(
    model: &Model,
    i: usize,
    spec: &CompressionSpec,
) -> (CompressedLayer, LayerReport) {
    let layer = &model.manifest.layers[i];
    compress_tensor(
        &layer.name,
        &model.weights[i].shape,
        &model.weights[i].data,
        &model.sigmas[i].data,
        &model.biases[i].data,
        spec,
    )
}

/// Reconstruct all weight tensors from a compressed model.
pub fn decompress(compressed: &CompressedModel) -> Vec<crate::tensor::Tensor> {
    compressed
        .layers
        .iter()
        .map(|l| crate::tensor::Tensor::new(l.dims.clone(), l.decode_weights()))
        .collect()
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::util::SplitMix64;

    /// Shared fixture for sibling modules' tests.
    pub(crate) fn toy_model_pub() -> Model {
        toy_model()
    }

    fn toy_model() -> Model {
        use crate::model::manifest::*;
        let mut rng = SplitMix64::new(71);
        let mut weights = Vec::new();
        let mut sigmas = Vec::new();
        let mut biases = Vec::new();
        let mut layers = Vec::new();
        for (li, n) in [400usize, 900, 120].iter().enumerate() {
            let mut w = vec![0.0f32; *n];
            let mut s = vec![0.0f32; *n];
            for i in 0..*n {
                if rng.next_f64() > 0.8 {
                    w[i] = rng.laplace(0.05) as f32;
                }
                s[i] = 0.01 + 0.1 * rng.next_f32();
            }
            weights.push(crate::tensor::Tensor::new(vec![*n], w));
            sigmas.push(crate::tensor::Tensor::new(vec![*n], s));
            biases.push(crate::tensor::Tensor::new(vec![4], vec![0.1; 4]));
            layers.push(LayerInfo {
                name: format!("l{li}"),
                kind: LayerKind::Fc,
                shape: vec![*n],
                activation: None,
                stride: 1,
                padding: 0,
                nonzero: 0,
                size: *n,
            });
        }
        Model {
            manifest: ModelManifest {
                name: "toy".into(),
                task: "classify".into(),
                input_shape: vec![4],
                eval_batch: 2,
                n_classes: 2,
                param_count: 1420,
                density: 0.2,
                dense_metric: 1.0,
                sparse_metric: 1.0,
                layers,
                hlo: "none".into(),
                arg_order: vec![],
            },
            weights,
            biases,
            sigmas,
        }
    }

    #[test]
    fn compress_decompress_bounded_error() {
        let model = toy_model();
        let spec = CompressionSpec { lambda_scale: 0.0, ..Default::default() };
        let (compressed, report) = compress_model(&model, &spec, 1);
        assert_eq!(compressed.layers.len(), 3);
        assert!(report.compressed_bytes > 0);
        let recon = decompress(&compressed);
        for ((orig, rec), cl) in model.weights.iter().zip(&recon).zip(&compressed.layers) {
            assert_eq!(orig.shape, rec.shape);
            let bound = 0.51 * cl.grid.delta;
            let w_cap = cl.grid.value(cl.grid.max_level).abs();
            for (a, b) in orig.data.iter().zip(&rec.data) {
                if a.abs() <= w_cap {
                    // λ=0: nearest-neighbour ⇒ error ≤ Δ/2 for in-range weights
                    assert!((a - b).abs() <= bound, "a={a} b={b} Δ={}", cl.grid.delta);
                }
            }
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let model = toy_model();
        let spec = CompressionSpec::default();
        let (a, _) = compress_model(&model, &spec, 1);
        let (b, _) = compress_model(&model, &spec, 4);
        assert_eq!(a.serialize(), b.serialize());
    }

    fn sparse_fixture(n: usize, density: f64, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = SplitMix64::new(seed);
        let mut w = vec![0.0f32; n];
        let mut s = vec![0.0f32; n];
        for i in 0..n {
            if rng.next_f64() < density {
                w[i] = rng.laplace(0.08) as f32;
            }
            s[i] = 0.02 + 0.05 * rng.next_f32();
        }
        (w, s)
    }

    #[test]
    fn n1_chunk_reproduces_monolithic_payload() {
        // chunks = 1 must be byte-for-byte the single-stream encode the
        // format has always produced (and stays in the v1 container).
        let (w, s) = sparse_fixture(20_000, 0.1, 11);
        let spec = CompressionSpec::default();
        assert_eq!(spec.chunks, 1);
        let (layer, rep) = compress_tensor("t", &[w.len()], &w, &s, &[], &spec);
        assert!(layer.chunks.is_empty());
        assert_eq!(rep.n_chunks, 1);

        // the pre-chunking reference path: one QuantResult over the tensor
        let grid = QuantGrid::from_tensor(&w, &s, spec.s);
        let etas = bayes::etas_from_sigmas(&s, bayes::sigma_floor(&s));
        let mean_eta =
            etas.iter().map(|&e| e as f64).sum::<f64>() / etas.len().max(1) as f64;
        let lambda = spec.lambda_scale * grid.delta * grid.delta * mean_eta as f32;
        let reference = RdQuantizer::new(spec.cfg).quantize_encode(
            &w,
            &etas,
            &grid,
            RdParams { lambda },
        );
        assert_eq!(layer.payload, reference.payload);
        assert_eq!(layer.decode_levels(), reference.levels);
    }

    #[test]
    fn stats_hoisting_is_byte_identical() {
        // LayerStats::compute + compress_tensor_with_stats must reproduce
        // the from-raw-tensors path exactly (grid, λ, payload).
        let (w, s) = sparse_fixture(10_000, 0.15, 17);
        for weighted in [true, false] {
            for sv in [0u32, 40, 256] {
                let spec = CompressionSpec { s: sv, weighted, ..Default::default() };
                let (a, _) = compress_tensor("t", &[w.len()], &w, &s, &[], &spec);
                let stats = LayerStats::compute(&w, &s, weighted);
                assert_eq!(stats.grid(sv), QuantGrid::from_tensor(&w, &s, sv));
                let (b, _) =
                    compress_tensor_with_stats("t", &[w.len()], &w, &[], &spec, &stats, 1);
                assert_eq!(a.payload, b.payload, "S={sv} weighted={weighted}");
                assert_eq!(a.grid, b.grid, "S={sv} weighted={weighted}");
            }
        }
    }

    #[test]
    fn budgeted_tensor_compress_identical_or_none() {
        let (w, s) = sparse_fixture(30_000, 0.1, 29);
        let spec = CompressionSpec { chunks: 3, ..Default::default() };
        let stats = LayerStats::compute(&w, &s, spec.weighted);
        let (full, _) = compress_tensor_chunked("t", &[w.len()], &w, &s, &[], &spec, 2);
        let (b, _) = compress_tensor_budgeted(
            "t", &[w.len()], &w, &[], &spec, &stats, 0, usize::MAX,
        )
        .expect("unbounded budget");
        assert_eq!(full.payload, b.payload);
        assert_eq!(full.chunks, b.chunks);
        // a budget below the final size abandons (mid-chunk or at a
        // chunk boundary, both count)
        assert!(compress_tensor_budgeted(
            "t", &[w.len()], &w, &[], &spec, &stats, 0, full.payload.len() / 3,
        )
        .is_none());
        // base_bytes shifts the same budget
        assert!(compress_tensor_budgeted(
            "t", &[w.len()], &w, &[], &spec, &stats,
            full.payload.len(), full.payload.len() + full.payload.len() / 3,
        )
        .is_none());
    }

    #[test]
    fn probe_with_seed_is_byte_identical_and_reports_hits() {
        // the pipeline-level warm-start identity: a neighbour-S seed
        // (the engine's real plumbing, rescaled per layer and sliced per
        // chunk) and an adversarial all-wrong seed both reproduce the
        // cold payload byte for byte; only the hit counters differ.
        let (w, s) = sparse_fixture(20_000, 0.12, 41);
        let spec = CompressionSpec { chunks: 3, ..Default::default() };
        let stats = LayerStats::compute(&w, &s, spec.weighted);
        let (cold, cold_rep) =
            compress_tensor_budgeted("t", &[w.len()], &w, &[], &spec, &stats, 0, usize::MAX)
                .expect("unbounded");
        assert_eq!((cold_rep.seeded, cold_rep.seed_hits), (0, 0));

        // seed from the S=65 neighbour, exactly as the sweep engine does
        let nspec = CompressionSpec { s: 65, ..spec };
        let (nl, _) =
            compress_tensor_budgeted("t", &[w.len()], &w, &[], &nspec, &stats, 0, usize::MAX)
                .expect("unbounded");
        let seed_levels = nl.decode_levels();
        let probe = LayerProbe { seed: Some((&seed_levels, 65)), ..LayerProbe::PLAIN };
        let (warm, warm_rep) =
            compress_tensor_probe("t", &[w.len()], &w, &[], &spec, &stats, &probe)
                .expect("unbounded");
        assert_eq!(warm.payload, cold.payload);
        assert_eq!(warm.chunks, cold.chunks);
        assert_eq!(warm_rep.seeded, w.len());
        assert!(
            warm_rep.seed_hits * 5 >= warm_rep.seeded * 4,
            "neighbour-S seed hit rate {}/{}",
            warm_rep.seed_hits,
            warm_rep.seeded
        );

        // forced fallback: a seed that is wrong for every weight
        let bogus = vec![cold.grid.max_level; w.len()];
        let probe = LayerProbe { seed: Some((&bogus, spec.s + 1)), ..LayerProbe::PLAIN };
        let (warm, _) = compress_tensor_probe("t", &[w.len()], &w, &[], &spec, &stats, &probe)
            .expect("unbounded");
        assert_eq!(warm.payload, cold.payload);
    }

    #[test]
    fn dominance_leg_gates_the_byte_budget() {
        // frontier-preserving semantics at the pipeline level: over
        // budget alone no longer abandons — a completed point must also
        // strictly dominate the probe's running lower bounds.
        let (w, s) = sparse_fixture(20_000, 0.12, 43);
        let spec = CompressionSpec::default();
        let stats = LayerStats::compute(&w, &s, spec.weighted);
        let (full, rep) =
            compress_tensor_budgeted("t", &[w.len()], &w, &[], &spec, &stats, 0, usize::MAX)
                .expect("unbounded");
        let budget = full.payload.len() / 4;

        // dominating completed point (fewer bytes AND less distortion):
        // the probe must be cut, and the recorded totals satisfy the
        // predicate they were cut by
        let dom = DominanceFrontier::from_completed(
            [(full.payload.len() / 2, rep.distortion / 2.0)],
            0,
        );
        let probe = LayerProbe {
            budget_bytes: budget,
            dominance: Some(&dom),
            ..LayerProbe::PLAIN
        };
        let cut = compress_tensor_probe("t", &[w.len()], &w, &[], &spec, &stats, &probe)
            .expect_err("dominated probe must abandon");
        assert!(cut.bytes > budget);
        assert!(dom.dominates(cut.bytes, cut.distortion));

        // non-dominating completed point (fewer bytes but MORE
        // distortion): the probe is a frontier candidate and must
        // complete byte-identically despite being far over budget
        let nodom = DominanceFrontier::from_completed(
            [(full.payload.len() / 2, rep.distortion * 2.0)],
            0,
        );
        let probe = LayerProbe {
            budget_bytes: budget,
            dominance: Some(&nodom),
            ..LayerProbe::PLAIN
        };
        let (kept, _) = compress_tensor_probe("t", &[w.len()], &w, &[], &spec, &stats, &probe)
            .expect("frontier candidate must survive the byte budget");
        assert_eq!(kept.payload, full.payload);
    }

    #[test]
    fn chunked_encode_deterministic_and_roundtrips() {
        let (w, s) = sparse_fixture(30_000, 0.1, 23);
        for chunks in [2u32, 4, 7] {
            let spec = CompressionSpec { chunks, ..Default::default() };
            let (a, rep) =
                compress_tensor_chunked("t", &[w.len()], &w, &s, &[], &spec, 1);
            let (b, _) = compress_tensor_chunked("t", &[w.len()], &w, &s, &[], &spec, 4);
            assert_eq!(a.payload, b.payload, "chunks={chunks}");
            assert_eq!(a.chunks, b.chunks, "chunks={chunks}");
            assert_eq!(rep.n_chunks, chunks as usize);
            // decode (serial and chunk-parallel) equals per-span re-encode
            let grid = QuantGrid::from_tensor(&w, &s, spec.s);
            let etas = bayes::etas_from_sigmas(&s, bayes::sigma_floor(&s));
            let mean_eta =
                etas.iter().map(|&e| e as f64).sum::<f64>() / etas.len().max(1) as f64;
            let lambda = spec.lambda_scale * grid.delta * grid.delta * mean_eta as f32;
            let mut expected = Vec::new();
            let mut lo = 0usize;
            for c in &a.chunks {
                let hi = lo + c.n_weights;
                let r = RdQuantizer::new(spec.cfg).quantize_encode(
                    &w[lo..hi],
                    &etas[lo..hi],
                    &grid,
                    RdParams { lambda },
                );
                expected.extend_from_slice(&r.levels);
                lo = hi;
            }
            assert_eq!(a.decode_levels_with(1), expected, "chunks={chunks}");
            assert_eq!(a.decode_levels(), expected, "chunks={chunks}");
        }
    }

    #[test]
    fn chunk_rate_overhead_is_small() {
        // context restarts cost a warmup per chunk; on a realistic
        // fixture the overhead must stay low (< 2% at the bench's scale,
        // checked there too — this is the fast guard)
        let (w, s) = sparse_fixture(120_000, 0.1, 31);
        let mono = compress_tensor(
            "t",
            &[w.len()],
            &w,
            &s,
            &[],
            &CompressionSpec::default(),
        )
        .0
        .payload
        .len() as f64;
        for (chunks, bound) in [(2u32, 1.02), (8, 1.05)] {
            let spec = CompressionSpec { chunks, ..Default::default() };
            let chunked = compress_tensor_chunked("t", &[w.len()], &w, &s, &[], &spec, 2)
                .0
                .payload
                .len() as f64;
            assert!(
                chunked <= mono * bound,
                "chunks={chunks}: {chunked} vs {mono} (bound {bound})"
            );
        }
    }

    #[test]
    fn chunked_model_parallel_matches_serial() {
        let model = toy_model();
        let spec = CompressionSpec { chunks: 3, ..Default::default() };
        let (a, ra) = compress_model(&model, &spec, 1);
        let (b, _) = compress_model(&model, &spec, 4);
        assert_eq!(a.serialize(), b.serialize());
        assert!(a.is_chunked());
        assert!(ra.layers.iter().all(|l| l.n_chunks == 3));
        // chunked container roundtrips through serialization
        let re = crate::model::CompressedModel::deserialize(&a.serialize()).unwrap();
        for (x, y) in a.layers.iter().zip(&re.layers) {
            assert_eq!(x.decode_levels(), y.decode_levels());
        }
    }

    #[test]
    fn weighted_vs_uniform_changes_output() {
        let model = toy_model();
        let (a, _) = compress_model(&model, &CompressionSpec::default(), 1);
        let (b, _) = compress_model(
            &model,
            &CompressionSpec { weighted: false, ..Default::default() },
            1,
        );
        assert_ne!(a.serialize(), b.serialize());
    }
}
