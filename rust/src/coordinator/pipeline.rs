//! The per-layer compression pipeline.
//!
//! Every weight tensor is compressed independently ("we applied
//! DeepCABAC on to the weight parameters of each layer separately,
//! excluding biases and normalization parameters" — paper §4), so layers
//! fan out onto a worker pool; results are collected in manifest order.

use crate::bayes;
use crate::codec::CodecConfig;
use crate::model::{CompressedLayer, CompressedModel, Model};
use crate::quant::{QuantGrid, RdParams, RdQuantizer};
use crate::util::Timer;

use super::metrics::{LayerReport, ModelReport};

/// Everything that parameterizes one compression run.
#[derive(Debug, Clone, Copy)]
pub struct CompressionSpec {
    /// The grid coarseness hyper-parameter S of eq. 2.
    pub s: u32,
    /// λ = lambda_scale · Δ² · mean(η): scale-free Lagrangian so one knob
    /// works across layers with very different Δ and η magnitudes.
    /// (lambda_scale = 0 recovers weighted nearest-neighbour.)
    pub lambda_scale: f32,
    pub cfg: CodecConfig,
    /// η = 1/σ² (true) vs uniform η (ablation).
    pub weighted: bool,
    /// Candidate window for the RD scan.
    pub window: i32,
}

impl Default for CompressionSpec {
    fn default() -> Self {
        Self {
            s: 64,
            lambda_scale: 0.05,
            cfg: CodecConfig::default(),
            weighted: true,
            window: 4,
        }
    }
}

/// Compress one tensor; returns the layer record and its report.
pub fn compress_tensor(
    name: &str,
    dims: &[usize],
    weights: &[f32],
    sigmas: &[f32],
    bias: &[f32],
    spec: &CompressionSpec,
) -> (CompressedLayer, LayerReport) {
    let timer = Timer::new();
    let grid = QuantGrid::from_tensor(weights, sigmas, spec.s);
    let etas = if spec.weighted {
        bayes::etas_from_sigmas(sigmas, bayes::sigma_floor(sigmas))
    } else {
        bayes::etas_uniform(weights.len())
    };
    let mean_eta = etas.iter().map(|&e| e as f64).sum::<f64>() / etas.len().max(1) as f64;
    let lambda = spec.lambda_scale * grid.delta * grid.delta * mean_eta as f32;
    let quantizer = RdQuantizer::new(spec.cfg);
    let res = quantizer.quantize_encode(
        weights,
        &etas,
        &grid,
        RdParams { lambda, window: spec.window },
    );
    let nonzero = res.levels.iter().filter(|&&l| l != 0).count();
    let report = LayerReport {
        name: name.to_string(),
        n_weights: weights.len(),
        nonzero,
        payload_bytes: res.payload.len(),
        distortion: res.distortion,
        est_bits: res.est_bits,
        time_s: timer.elapsed_s(),
    };
    let layer = CompressedLayer {
        name: name.to_string(),
        dims: dims.to_vec(),
        grid,
        s_param: spec.s,
        cfg: spec.cfg,
        n_weights: weights.len(),
        payload: res.payload,
        bias: bias.to_vec(),
    };
    (layer, report)
}

/// Compress a whole model with `workers` threads (layers fan out; results
/// are re-assembled in manifest order).
pub fn compress_model(
    model: &Model,
    spec: &CompressionSpec,
    workers: usize,
) -> (CompressedModel, ModelReport) {
    let n = model.weights.len();
    let mut slots: Vec<Option<(CompressedLayer, LayerReport)>> = (0..n).map(|_| None).collect();

    if workers <= 1 || n <= 1 {
        for i in 0..n {
            slots[i] = Some(compress_layer_idx(model, i, spec));
        }
    } else {
        // Work-stealing over layer indices with scoped threads; a bounded
        // channel applies backpressure so huge layers don't pile up.
        let next = std::sync::atomic::AtomicUsize::new(0);
        let (tx, rx) = std::sync::mpsc::sync_channel::<(usize, (CompressedLayer, LayerReport))>(
            workers * 2,
        );
        std::thread::scope(|scope| {
            for _ in 0..workers.min(n) {
                let tx = tx.clone();
                let next = &next;
                scope.spawn(move || loop {
                    let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let out = compress_layer_idx(model, i, spec);
                    if tx.send((i, out)).is_err() {
                        break;
                    }
                });
            }
            drop(tx);
            for (i, out) in rx {
                slots[i] = Some(out);
            }
        });
    }

    let mut layers = Vec::with_capacity(n);
    let mut reports = Vec::with_capacity(n);
    for slot in slots {
        let (l, r) = slot.expect("layer not compressed");
        layers.push(l);
        reports.push(r);
    }
    let compressed = CompressedModel { name: model.manifest.name.clone(), layers };
    let report = ModelReport::from_layers(model, &compressed, reports);
    (compressed, report)
}

fn compress_layer_idx(
    model: &Model,
    i: usize,
    spec: &CompressionSpec,
) -> (CompressedLayer, LayerReport) {
    let layer = &model.manifest.layers[i];
    compress_tensor(
        &layer.name,
        &model.weights[i].shape,
        &model.weights[i].data,
        &model.sigmas[i].data,
        &model.biases[i].data,
        spec,
    )
}

/// Reconstruct all weight tensors from a compressed model.
pub fn decompress(compressed: &CompressedModel) -> Vec<crate::tensor::Tensor> {
    compressed
        .layers
        .iter()
        .map(|l| crate::tensor::Tensor::new(l.dims.clone(), l.decode_weights()))
        .collect()
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::util::SplitMix64;

    /// Shared fixture for sibling modules' tests.
    pub(crate) fn toy_model_pub() -> Model {
        toy_model()
    }

    fn toy_model() -> Model {
        use crate::model::manifest::*;
        let mut rng = SplitMix64::new(71);
        let mut weights = Vec::new();
        let mut sigmas = Vec::new();
        let mut biases = Vec::new();
        let mut layers = Vec::new();
        for (li, n) in [400usize, 900, 120].iter().enumerate() {
            let mut w = vec![0.0f32; *n];
            let mut s = vec![0.0f32; *n];
            for i in 0..*n {
                if rng.next_f64() > 0.8 {
                    w[i] = rng.laplace(0.05) as f32;
                }
                s[i] = 0.01 + 0.1 * rng.next_f32();
            }
            weights.push(crate::tensor::Tensor::new(vec![*n], w));
            sigmas.push(crate::tensor::Tensor::new(vec![*n], s));
            biases.push(crate::tensor::Tensor::new(vec![4], vec![0.1; 4]));
            layers.push(LayerInfo {
                name: format!("l{li}"),
                kind: LayerKind::Fc,
                shape: vec![*n],
                activation: None,
                stride: 1,
                padding: 0,
                nonzero: 0,
                size: *n,
            });
        }
        Model {
            manifest: ModelManifest {
                name: "toy".into(),
                task: "classify".into(),
                input_shape: vec![4],
                eval_batch: 2,
                n_classes: 2,
                param_count: 1420,
                density: 0.2,
                dense_metric: 1.0,
                sparse_metric: 1.0,
                layers,
                hlo: "none".into(),
                arg_order: vec![],
            },
            weights,
            biases,
            sigmas,
        }
    }

    #[test]
    fn compress_decompress_bounded_error() {
        let model = toy_model();
        let spec = CompressionSpec { lambda_scale: 0.0, ..Default::default() };
        let (compressed, report) = compress_model(&model, &spec, 1);
        assert_eq!(compressed.layers.len(), 3);
        assert!(report.compressed_bytes > 0);
        let recon = decompress(&compressed);
        for ((orig, rec), cl) in model.weights.iter().zip(&recon).zip(&compressed.layers) {
            assert_eq!(orig.shape, rec.shape);
            let bound = 0.51 * cl.grid.delta;
            let w_cap = cl.grid.value(cl.grid.max_level).abs();
            for (a, b) in orig.data.iter().zip(&rec.data) {
                if a.abs() <= w_cap {
                    // λ=0: nearest-neighbour ⇒ error ≤ Δ/2 for in-range weights
                    assert!((a - b).abs() <= bound, "a={a} b={b} Δ={}", cl.grid.delta);
                }
            }
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let model = toy_model();
        let spec = CompressionSpec::default();
        let (a, _) = compress_model(&model, &spec, 1);
        let (b, _) = compress_model(&model, &spec, 4);
        assert_eq!(a.serialize(), b.serialize());
    }

    #[test]
    fn weighted_vs_uniform_changes_output() {
        let model = toy_model();
        let (a, _) = compress_model(&model, &CompressionSpec::default(), 1);
        let (b, _) = compress_model(
            &model,
            &CompressionSpec { weighted: false, ..Default::default() },
            1,
        );
        assert_ne!(a.serialize(), b.serialize());
    }
}
