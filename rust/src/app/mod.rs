//! Application-level orchestration shared by the CLI, the examples and
//! the benches: load artifacts, run sweeps, evaluate via PJRT, and
//! format Table-1 rows.

use crate::coordinator::{sweep_s, CompressionSpec, ModelReport};
use crate::model::{CompressedModel, Model};
use crate::runtime::{eval, Runtime};
use crate::synth::{self, Arch};
use crate::tensor::{npy, Tensor};
use anyhow::{bail, Context, Result};
use std::path::PathBuf;

/// Locate the artifacts directory (env override for odd layouts).
pub fn artifacts_dir() -> PathBuf {
    std::env::var("DEEPCABAC_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

pub const SMALL_MODELS: [&str; 4] = ["lenet300", "lenet5", "smallvgg", "fcae"];

/// Load a trained model from `artifacts/models/<name>`.
pub fn load_model(name: &str) -> Result<Model> {
    let dir = artifacts_dir().join("models").join(name);
    if !dir.exists() {
        bail!(
            "{dir:?} missing — run `make artifacts` first (trains the model suite)"
        );
    }
    Model::load(&dir)
}

/// Load the eval set for a model.
pub fn load_eval_set(name: &str) -> Result<(Tensor, Option<Vec<i32>>)> {
    let dir = artifacts_dir().join("models").join(name);
    let (xs, xd) = npy::read_npy_f32(&dir.join("eval_x.npy"))?;
    let x = Tensor::new(xs, xd);
    let y_path = dir.join("eval_y.npy");
    let y = if y_path.exists() {
        Some(npy::read_npy_i32(&y_path)?.1)
    } else {
        None
    };
    Ok((x, y))
}

/// Evaluate weights (manifest arg order: w,b per layer) through the
/// model's PJRT executable. Weights can come from the original model or
/// a decompressed container.
pub fn evaluate_weights(
    rt: &Runtime,
    model: &Model,
    weights: &[Tensor],
    biases: &[Tensor],
) -> Result<eval::EvalResult> {
    let hlo = artifacts_dir().join(&model.manifest.hlo);
    let exe = rt
        .load_hlo_text(&hlo)
        .with_context(|| format!("loading {hlo:?}"))?;
    let (x, y) = load_eval_set(&model.manifest.name)?;
    let mut params = Vec::with_capacity(weights.len() * 2);
    for (w, b) in weights.iter().zip(biases) {
        params.push(w.clone());
        params.push(b.clone());
    }
    let batch = model.manifest.eval_batch;
    if model.manifest.task == "classify" {
        let y = y.context("classifier eval set missing labels")?;
        eval::eval_classifier(&exe, &params, &x, &y, batch)
    } else {
        eval::eval_autoencoder(&exe, &params, &x, batch)
    }
}

/// Evaluate the model's own (uncompressed) weights.
pub fn evaluate_original(rt: &Runtime, model: &Model) -> Result<eval::EvalResult> {
    evaluate_weights(rt, model, &model.weights, &model.biases)
}

/// Evaluate a compressed container (decompress → PJRT).
pub fn evaluate_compressed(
    rt: &Runtime,
    model: &Model,
    compressed: &CompressedModel,
) -> Result<eval::EvalResult> {
    let weights = crate::coordinator::pipeline::decompress(compressed);
    evaluate_weights(rt, model, &weights, &model.biases)
}

/// One Table-1 row for a trained small model: sweep S, compress, and
/// (optionally) evaluate pre/post accuracy via PJRT.
pub struct Table1Row {
    pub model: String,
    pub dataset: String,
    pub org_metric: f64,
    pub org_bytes: usize,
    pub sparsity_pct: f64,
    pub ratio_pct: f64,
    pub metric_after: Option<f64>,
    pub best_s: u32,
    pub report: ModelReport,
    pub compressed: CompressedModel,
}

pub fn dataset_of(name: &str) -> &'static str {
    match name {
        "lenet300" | "lenet5" => "synth-MNIST",
        "smallvgg" | "fcae" => "synth-CIFAR10",
        _ => "synthetic",
    }
}

/// Build a Table-1 row for a trained model.
pub fn table1_small_row(
    name: &str,
    s_grid: &[u32],
    spec: &CompressionSpec,
    workers: usize,
    with_eval: bool,
) -> Result<Table1Row> {
    let model = load_model(name)?;
    let sweep = sweep_s(&model, s_grid, spec, workers)?;
    let (compressed, report) = sweep.best;
    let best_s = compressed.layers.first().map(|l| l.s_param).unwrap_or(0);
    let (org_metric, metric_after) = if with_eval {
        let rt = Runtime::cpu()?;
        let orig = evaluate_original(&rt, &model)?;
        let after = evaluate_compressed(&rt, &model, &compressed)?;
        (orig.metric, Some(after.metric))
    } else {
        (model.manifest.sparse_metric, None)
    };
    Ok(Table1Row {
        model: name.to_string(),
        dataset: dataset_of(name).to_string(),
        org_metric,
        org_bytes: model.raw_bytes(),
        sparsity_pct: model.density() * 100.0,
        ratio_pct: report.ratio_percent(),
        metric_after,
        best_s,
        report,
        compressed,
    })
}

/// Build a Table-1 row for a synthetic ImageNet-scale model (ratio only;
/// accuracy N/A without ImageNet — DESIGN.md §5).
pub fn table1_large_row(
    arch: Arch,
    scale: usize,
    s_grid: &[u32],
    spec: &CompressionSpec,
    workers: usize,
    seed: u64,
) -> Result<Table1Row> {
    let synth = synth::generate(arch, scale, seed);
    // wrap into a Model-shaped compress call per layer
    let mut best: Option<(CompressedModel, usize, u32)> = None;
    for &s in s_grid {
        let spec = CompressionSpec { s, ..*spec };
        let mut layers = Vec::with_capacity(synth.layers.len());
        let mut payload = 0usize;
        for l in &synth.layers {
            let (cl, rep) = crate::coordinator::compress_tensor(
                &l.name, &l.dims, &l.weights, &l.sigmas, &[], &spec,
            );
            payload += rep.payload_bytes;
            layers.push(cl);
        }
        let cm = CompressedModel { name: arch.name().into(), layers };
        let better = best.as_ref().map(|&(_, b, _)| payload < b).unwrap_or(true);
        if better {
            best = Some((cm, payload, s));
        }
        let _ = workers;
    }
    let (compressed, _, best_s) = best.ok_or_else(|| {
        anyhow::anyhow!(
            "S sweep over {} candidate(s) produced no compressed model \
             (empty --sweep grid?)",
            s_grid.len()
        )
    })?;
    let compressed_bytes = compressed.serialize().len();
    let raw = synth.raw_bytes();
    let nz: usize = compressed
        .layers
        .iter()
        .map(|l| l.decode_levels().iter().filter(|&&v| v != 0).count())
        .sum();
    let report = ModelReport {
        name: arch.name().into(),
        raw_bytes: raw,
        compressed_bytes,
        density: nz as f64 / synth.weight_count() as f64,
        layers: vec![],
        total_time_s: 0.0,
    };
    Ok(Table1Row {
        model: arch.name().to_string(),
        dataset: "synthetic (ImageNet shapes)".to_string(),
        org_metric: f64::NAN,
        org_bytes: raw,
        sparsity_pct: synth.density() * 100.0,
        ratio_pct: compressed_bytes as f64 / raw as f64 * 100.0,
        metric_after: None,
        best_s,
        report,
        compressed,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CompressionSpec;

    #[test]
    fn empty_s_sweep_is_an_error_not_a_panic() {
        // regression: an empty candidate grid used to hit best.unwrap()
        let spec = CompressionSpec::default();
        let err = table1_large_row(Arch::MobileNetV1, 64, &[], &spec, 1, 7)
            .expect_err("empty sweep must fail");
        assert!(err.to_string().contains("no compressed model"), "{err}");
    }
}
