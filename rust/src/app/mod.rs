//! Application-level orchestration shared by the CLI, the examples and
//! the benches: load artifacts, run sweeps, evaluate via PJRT, and
//! format Table-1 rows.

use crate::coordinator::{sweep_s, CompressionSpec, ModelReport};
use crate::model::{CompressedModel, Model};
use crate::runtime::{eval, Runtime};
use crate::synth::{self, Arch};
use crate::tensor::{npy, Tensor};
use anyhow::{bail, Context, Result};
use std::path::PathBuf;

/// Locate the artifacts directory (env override for odd layouts).
pub fn artifacts_dir() -> PathBuf {
    std::env::var("DEEPCABAC_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

pub const SMALL_MODELS: [&str; 4] = ["lenet300", "lenet5", "smallvgg", "fcae"];

/// Load a trained model from `artifacts/models/<name>`.
pub fn load_model(name: &str) -> Result<Model> {
    let dir = artifacts_dir().join("models").join(name);
    if !dir.exists() {
        bail!(
            "{dir:?} missing — run `make artifacts` first (trains the model suite)"
        );
    }
    Model::load(&dir)
}

/// Load the eval set for a model.
pub fn load_eval_set(name: &str) -> Result<(Tensor, Option<Vec<i32>>)> {
    let dir = artifacts_dir().join("models").join(name);
    let (xs, xd) = npy::read_npy_f32(&dir.join("eval_x.npy"))?;
    let x = Tensor::new(xs, xd);
    let y_path = dir.join("eval_y.npy");
    let y = if y_path.exists() {
        Some(npy::read_npy_i32(&y_path)?.1)
    } else {
        None
    };
    Ok((x, y))
}

/// Evaluate weights (manifest arg order: w,b per layer) through the
/// model's PJRT executable. Weights can come from the original model or
/// a decompressed container.
pub fn evaluate_weights(
    rt: &Runtime,
    model: &Model,
    weights: &[Tensor],
    biases: &[Tensor],
) -> Result<eval::EvalResult> {
    let hlo = artifacts_dir().join(&model.manifest.hlo);
    let exe = rt
        .load_hlo_text(&hlo)
        .with_context(|| format!("loading {hlo:?}"))?;
    let (x, y) = load_eval_set(&model.manifest.name)?;
    let mut params = Vec::with_capacity(weights.len() * 2);
    for (w, b) in weights.iter().zip(biases) {
        params.push(w.clone());
        params.push(b.clone());
    }
    let batch = model.manifest.eval_batch;
    if model.manifest.task == "classify" {
        let y = y.context("classifier eval set missing labels")?;
        eval::eval_classifier(&exe, &params, &x, &y, batch)
    } else {
        eval::eval_autoencoder(&exe, &params, &x, batch)
    }
}

/// Evaluate the model's own (uncompressed) weights.
pub fn evaluate_original(rt: &Runtime, model: &Model) -> Result<eval::EvalResult> {
    evaluate_weights(rt, model, &model.weights, &model.biases)
}

/// Evaluate a compressed container (decompress → PJRT).
pub fn evaluate_compressed(
    rt: &Runtime,
    model: &Model,
    compressed: &CompressedModel,
) -> Result<eval::EvalResult> {
    let weights = crate::coordinator::pipeline::decompress(compressed);
    evaluate_weights(rt, model, &weights, &model.biases)
}

/// One Table-1 row for a trained small model: sweep S, compress, and
/// (optionally) evaluate pre/post accuracy via PJRT.
pub struct Table1Row {
    pub model: String,
    pub dataset: String,
    pub org_metric: f64,
    pub org_bytes: usize,
    pub sparsity_pct: f64,
    pub ratio_pct: f64,
    pub metric_after: Option<f64>,
    pub best_s: u32,
    pub report: ModelReport,
    pub compressed: CompressedModel,
}

pub fn dataset_of(name: &str) -> &'static str {
    match name {
        "lenet300" | "lenet5" => "synth-MNIST",
        "smallvgg" | "fcae" => "synth-CIFAR10",
        _ => "synthetic",
    }
}

/// Build a Table-1 row for a trained model.
pub fn table1_small_row(
    name: &str,
    s_grid: &[u32],
    spec: &CompressionSpec,
    workers: usize,
    with_eval: bool,
) -> Result<Table1Row> {
    let model = load_model(name)?;
    let sweep = sweep_s(&model, s_grid, spec, workers)?;
    let (compressed, report) = sweep.best;
    let best_s = compressed.layers.first().map(|l| l.s_param).unwrap_or(0);
    let (org_metric, metric_after) = if with_eval {
        let rt = Runtime::cpu()?;
        let orig = evaluate_original(&rt, &model)?;
        let after = evaluate_compressed(&rt, &model, &compressed)?;
        (orig.metric, Some(after.metric))
    } else {
        (model.manifest.sparse_metric, None)
    };
    Ok(Table1Row {
        model: name.to_string(),
        dataset: dataset_of(name).to_string(),
        org_metric,
        org_bytes: model.raw_bytes(),
        sparsity_pct: model.density() * 100.0,
        ratio_pct: report.ratio_percent(),
        metric_after,
        best_s,
        report,
        compressed,
    })
}

/// Build a Table-1 row for a synthetic ImageNet-scale model (ratio only;
/// accuracy N/A without ImageNet — DESIGN.md §5). Routed through
/// [`crate::synth::SynthModel::to_model`] + the sweep engine, so the
/// synthetic rows benefit from the same parallel probes / hoisted stats
/// as every other sweep caller. Selection note: the engine's argmin is
/// the **serialized container size** — the number the row actually
/// reports — where the old ad-hoc loop minimized summed payload bytes;
/// the two agree whenever payload gaps across the S grid exceed the few
/// bytes of S-dependent varint overhead
/// (`table1_large_row_matches_legacy_adhoc_loop` pins both argmins and
/// the reported numbers for a fixed config).
pub fn table1_large_row(
    arch: Arch,
    scale: usize,
    s_grid: &[u32],
    spec: &CompressionSpec,
    workers: usize,
    seed: u64,
) -> Result<Table1Row> {
    let synth = synth::generate(arch, scale, seed);
    let model = synth.to_model();
    let sweep = sweep_s(&model, s_grid, spec, workers).with_context(|| {
        format!(
            "S sweep over {} candidate(s) produced no compressed model \
             (empty --sweep grid?)",
            s_grid.len()
        )
    })?;
    let (compressed, report) = sweep.best;
    Ok(Table1Row {
        model: arch.name().to_string(),
        dataset: "synthetic (ImageNet shapes)".to_string(),
        org_metric: f64::NAN,
        org_bytes: report.raw_bytes,
        sparsity_pct: synth.density() * 100.0,
        ratio_pct: report.ratio_percent(),
        metric_after: None,
        best_s: sweep.best_point.s,
        report,
        compressed,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CompressionSpec;

    #[test]
    fn empty_s_sweep_is_an_error_not_a_panic() {
        // regression: an empty candidate grid used to hit best.unwrap()
        let spec = CompressionSpec::default();
        let err = table1_large_row(Arch::MobileNetV1, 64, &[], &spec, 1, 7)
            .expect_err("empty sweep must fail");
        assert!(err.to_string().contains("no compressed model"), "{err}");
    }

    #[test]
    fn table1_large_row_matches_legacy_adhoc_loop() {
        // satellite regression: `table1 --large` now routes through
        // SynthModel::to_model() + the sweep engine; the reported row
        // (size, ratio, best S, exact container bytes) must be unchanged
        // vs the old ad-hoc per-S compress loop, inlined here as the
        // reference (serial compress per S, payload argmin, earlier S
        // wins ties).
        let s_grid = [48u32, 128, 224];
        let spec = CompressionSpec::default();
        let (arch, scale, seed) = (Arch::MobileNetV1, 32, 7);
        let row = table1_large_row(arch, scale, &s_grid, &spec, 2, seed).unwrap();

        let synth = synth::generate(arch, scale, seed);
        let mut candidates: Vec<(u32, CompressedModel, usize)> = Vec::new();
        for &s in &s_grid {
            let spec = CompressionSpec { s, ..spec };
            let mut layers = Vec::with_capacity(synth.layers.len());
            let mut payload = 0usize;
            for l in &synth.layers {
                let (cl, rep) = crate::coordinator::compress_tensor(
                    &l.name, &l.dims, &l.weights, &l.sigmas, &[], &spec,
                );
                payload += rep.payload_bytes;
                layers.push(cl);
            }
            let cm = CompressedModel { name: arch.name().into(), layers };
            candidates.push((s, cm, payload));
        }
        // fixture guard: the legacy payload argmin and the engine's
        // serialized-size argmin must coincide here (payload gaps across
        // this S grid dwarf the few bytes of S-dependent varint
        // overhead); if this grid ever gets degenerate the guard points
        // at the fixture, not at a spurious engine regression
        let by_payload =
            candidates.iter().map(|(s, _, p)| (*p, *s)).min().unwrap().1;
        let by_serialized = candidates
            .iter()
            .map(|(s, cm, _)| (cm.serialize().len(), *s))
            .min()
            .unwrap()
            .1;
        assert_eq!(
            by_payload, by_serialized,
            "fixture has a payload/serialized argmin split — pick a wider S grid"
        );
        let (legacy_s, legacy, _) = candidates
            .into_iter()
            .find(|(s, _, _)| *s == by_payload)
            .unwrap();
        let legacy_ser = legacy.serialize();
        assert_eq!(row.best_s, legacy_s);
        assert_eq!(row.compressed.serialize(), legacy_ser);
        assert_eq!(row.report.compressed_bytes, legacy_ser.len());
        assert_eq!(row.org_bytes, synth.raw_bytes());
        let legacy_ratio =
            legacy_ser.len() as f64 / synth.raw_bytes() as f64 * 100.0;
        assert!((row.ratio_pct - legacy_ratio).abs() < 1e-9);
        assert!((row.sparsity_pct - synth.density() * 100.0).abs() < 1e-12);
    }
}
