//! Quantization — the paper's §3.
//!
//! * [`QuantGrid`] — the equidistant grid of eq. 2, parameterized by the
//!   coarseness hyper-parameter `S`.
//! * [`RdQuantizer`] — the coupled weighted rate–distortion quantizer of
//!   eq. 1: for every weight it queries the CABAC rate estimator under
//!   the *live* context states, picks the cost-minimizing level, and
//!   immediately encodes it (so the contexts adapt exactly as the
//!   decoder will see them).
//! * [`nearest`] — the decoupled nearest-neighbour baseline (what
//!   "quantize then compress" pipelines do; used in the ablations).

pub mod grid;
pub mod rd;

pub use grid::QuantGrid;
pub use rd::{
    AbandonedAt, DominanceFrontier, ProbeBudget, QuantResult, RdParams, RdQuantizer, ScanSeed,
};

/// Decoupled baseline: weighted nearest-neighbour quantization onto the
/// grid (λ = 0 in eq. 1 — distortion only).
pub fn nearest(weights: &[f32], grid: &QuantGrid) -> Vec<i32> {
    weights.iter().map(|&w| grid.nearest_level(w)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nearest_maps_onto_grid() {
        let grid = QuantGrid { delta: 0.5, max_level: 4 };
        let w = [0.0, 0.24, 0.26, -1.1, 7.0, -7.0];
        let lv = nearest(&w, &grid);
        assert_eq!(lv, vec![0, 0, 1, -2, 4, -4]); // clamped at ±max_level
    }
}
