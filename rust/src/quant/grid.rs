//! The equidistant quantization grid of the paper's eq. 2:
//!
//! ```text
//! q_k = Δ · I_k,   Δ = 2|w_max| / (2|w_max|/σ_min + S),   S, I_k ∈ Z
//! ```
//!
//! `S ≥ 0` controls coarseness: S = 0 gives Δ = σ_min (grid as fine as
//! the most sensitive weight warrants); larger S shrinks Δ, refining the
//! grid. The paper probes S ∈ {0, …, 256} per model and keeps the best.

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantGrid {
    /// Grid step Δ.
    pub delta: f32,
    /// Largest level index needed to cover max|w| (levels are clamped to
    /// [-max_level, max_level]).
    pub max_level: i32,
}

impl QuantGrid {
    /// Build the grid from tensor statistics per eq. 2.
    ///
    /// * `w_max`     — largest |w| in the tensor.
    /// * `sigma_min` — smallest posterior std among the weights (clamped
    ///   away from 0; an all-frozen tensor would otherwise degenerate).
    /// * `s`         — the coarseness hyper-parameter.
    pub fn from_stats(w_max: f32, sigma_min: f32, s: u32) -> Self {
        let w_max = w_max.abs();
        if w_max == 0.0 {
            return Self { delta: 1.0, max_level: 0 };
        }
        let sigma_min = sigma_min.max(1e-12);
        let denom = 2.0 * w_max / sigma_min + s as f32;
        let delta = 2.0 * w_max / denom;
        let max_level = (w_max / delta).round() as i32;
        Self { delta, max_level }
    }

    /// Convenience: scan a weight slice + sigma slice.
    pub fn from_tensor(weights: &[f32], sigmas: &[f32], s: u32) -> Self {
        let w_max = weights.iter().fold(0.0f32, |m, &w| m.max(w.abs()));
        // The paper's σ_min is over the network's weights; zero-valued
        // (pruned) entries keep their posterior σ so they participate too.
        let sigma_min = sigmas
            .iter()
            .copied()
            .filter(|s| *s > 0.0)
            .fold(f32::INFINITY, f32::min);
        let sigma_min = if sigma_min.is_finite() { sigma_min } else { 1.0 };
        Self::from_stats(w_max, sigma_min, s)
    }

    /// Reconstruction value of a level.
    #[inline]
    pub fn value(&self, level: i32) -> f32 {
        self.delta * level as f32
    }

    /// Closest level to `w` (clamped to the representable range).
    #[inline]
    pub fn nearest_level(&self, w: f32) -> i32 {
        let l = (w / self.delta).round() as i32;
        l.clamp(-self.max_level, self.max_level)
    }

    /// Dequantize a level slice into weights.
    pub fn dequantize(&self, levels: &[i32]) -> Vec<f32> {
        levels.iter().map(|&l| self.value(l)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq2_limits() {
        // S = 0  ⇒  Δ = σ_min
        let g = QuantGrid::from_stats(1.0, 0.01, 0);
        assert!((g.delta - 0.01).abs() < 1e-9);
        // S → large shrinks Δ monotonically
        let mut prev = g.delta;
        for s in [1u32, 4, 16, 64, 256] {
            let d = QuantGrid::from_stats(1.0, 0.01, s).delta;
            assert!(d < prev);
            prev = d;
        }
    }

    #[test]
    fn grid_covers_wmax() {
        for s in [0u32, 3, 77, 256] {
            let g = QuantGrid::from_stats(2.5, 0.1, s);
            // the nearest level of ±w_max must reconstruct within Δ/2
            let rec = g.value(g.nearest_level(2.5));
            assert!((rec - 2.5).abs() <= g.delta * 0.5 + 1e-6, "s={s}");
        }
    }

    #[test]
    fn delta_within_sigma_min_for_nonneg_s() {
        // the paper: "quantisation points lie within the range of the
        // standard deviation of each weight" for S >= 0, i.e. Δ <= σ_min.
        for s in 0..50u32 {
            let g = QuantGrid::from_stats(3.0, 0.05, s);
            assert!(g.delta <= 0.05 + 1e-9);
        }
    }

    #[test]
    fn degenerate_tensors() {
        let g = QuantGrid::from_stats(0.0, 0.1, 10);
        assert_eq!(g.max_level, 0);
        assert_eq!(g.nearest_level(0.0), 0);
        let g = QuantGrid::from_tensor(&[0.0, 0.0], &[0.0, 0.0], 5);
        assert!(g.delta > 0.0);
    }

    #[test]
    fn from_tensor_matches_from_stats() {
        let w = [0.3f32, -1.2, 0.0, 0.7];
        let s = [0.2f32, 0.05, 0.4, 0.1];
        let a = QuantGrid::from_tensor(&w, &s, 13);
        let b = QuantGrid::from_stats(1.2, 0.05, 13);
        assert_eq!(a, b);
    }
}
