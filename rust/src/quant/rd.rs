//! The coupled weighted rate–distortion quantizer (paper eq. 1):
//!
//! ```text
//! w_i → q_k* = argmin_k  η_i (w_i − q_k)² + λ R_ik
//! ```
//!
//! `R_ik` is the *live* CABAC bit cost of coding level k at position i —
//! the context models have been updated by every previously encoded
//! weight, so quantization and entropy coding are a single coupled scan
//! (the paper's central design point; decoupled pipelines lose this).
//!
//! Candidate pruning: candidates are visited **outward from the
//! distortion vertex** w/Δ (two frontiers, one descending and one
//! ascending, always expanding the one closer to the vertex). Along each
//! frontier the distortion term is monotone non-decreasing, and the rate
//! term satisfies λ·R ≥ 0, so the moment a frontier's distortion alone
//! exceeds the best total cost found so far, every remaining candidate
//! on that frontier is strictly worse and the frontier is closed. The
//! scan therefore evaluates exactly the candidates that could still win
//! and is **provably identical** to the exhaustive full-grid argmin
//! (ties broken toward the smaller level, matching the exhaustive scan
//! order), at a few rate queries per weight for realistic λ.
//!
//! The previous scheme (±window around the nearest level plus a halving
//! ladder toward 0) was *not* exact: levels in `1..=window` were never
//! evaluated when the nearest level sat far from 0, the region between
//! the window and `nearest/2` was only sampled at halving points, and
//! with adapted contexts the rate is not even monotone in |level| — at
//! large λ the pruned argmin diverged from the exhaustive one. The
//! property tests compare against the exhaustive scan across the full λ
//! range, including the `nearest ≫ old-window` regime.
//!
//! **Warm-start seeding.** A sweep-engine refinement probe differs from
//! an already-probed neighbour only in the grid step Δ (< 1% between
//! neighbouring S), so most per-weight argmins are unchanged. The
//! seeded scan ([`ScanSeed`]) rescales the neighbour's chosen level to
//! the probe's grid, evaluates it **first** (one exact cost query), and
//! installs it as the scan's incumbent. The outward scan then runs
//! unchanged; with a good seed both frontiers close almost immediately
//! (the incumbent already carries the final best cost), and with a bad
//! seed the scan simply proceeds as if unseeded. This is *exact*, not
//! heuristic: the true argmin has distortion ≤ its own cost ≤ the seed's
//! cost, so the frontier that contains it cannot close before reaching
//! it, and the tie-break (smaller level wins among equal costs) is a
//! total order independent of visit order — the chosen level, and
//! therefore every downstream context update and payload byte, is
//! **identical** to the unseeded scan. (A seed whose f32 cost overflows
//! to ∞ is discarded rather than installed, so even that degenerate
//! corner matches the cold path bit for bit.)
//!
//! **2-D dominance budget.** The budgeted scan's abandon predicate
//! ([`ProbeBudget`]) has two conjuncts: the payload leg (accumulated
//! payload exceeds the probe's byte budget — the λ-column-incumbent
//! bound that keeps abandonment argmin-neutral) and, when a
//! [`DominanceFrontier`] is supplied, the dominance leg: some completed
//! grid point must have **strictly** fewer serialized bytes *and*
//! strictly less distortion than the probe's running partial sums. Both
//! running sums are monotone lower bounds on the probe's final values
//! (payload bytes only grow; distortion terms are ≥ 0 and f64 addition
//! of non-negatives is monotone), so an abandoned probe's finished
//! point would provably have been strictly Pareto-dominated — the
//! frontier of completed points equals the frontier of the full
//! no-abandon surface. Without a staircase the payload leg alone
//! decides (the legacy selection-neutral budget, still used by the
//! per-layer sweep, which has no distortion frontier to preserve).

use super::grid::QuantGrid;
use crate::codec::{CodecConfig, LevelEncoder, RateEstimator};

#[derive(Debug, Clone, Copy)]
pub struct RdParams {
    /// Lagrangian λ (distortion units per bit). Negative values are
    /// clamped to 0 (a negative λ would reward spending bits and break
    /// the pruning invariants). The pipeline derives it per (S, λ) grid
    /// point as `lambda_scale · Δ² · mean(η)` (`LayerStats::lambda`), so
    /// the sweep engine's λ axis threads through here — including into
    /// the budgeted encode used by early-abandoned probes.
    pub lambda: f32,
}

impl Default for RdParams {
    fn default() -> Self {
        Self { lambda: 0.0 }
    }
}

/// How often (in weights) the budgeted scan polls the abandon condition.
pub const BUDGET_CHECK_EVERY: usize = 512;

/// Staircase of completed sweep points in the (serialized bytes,
/// distortion) plane, queried by the budgeted scan's dominance leg.
///
/// Entries are keyed by `serialized − min_overhead` so a probe can
/// compare its accumulated **payload** bytes directly: for any container
/// the probe could still produce, `final_serialized ≥ payload_so_far +
/// min_overhead`, hence `q.serialized − min_overhead < payload_so_far`
/// implies `q.serialized < final_serialized`. `min_dist[i]` is the
/// prefix-minimum distortion over all entries with key ≤ `bytes[i]`, so
/// one binary search answers "does any completed point beat these
/// partial sums on both axes, strictly?".
#[derive(Debug, Clone, Default)]
pub struct DominanceFrontier {
    /// `q.serialized − min_overhead`, ascending.
    bytes: Vec<usize>,
    /// Prefix-minimum of the entries' distortions.
    min_dist: Vec<f64>,
}

impl DominanceFrontier {
    /// Build from completed points' `(serialized_bytes, distortion)`
    /// pairs; `min_overhead` is the caller's provable lower bound on
    /// container overhead (see the sweep engine's `min_overhead`).
    pub fn from_completed(
        points: impl IntoIterator<Item = (usize, f64)>,
        min_overhead: usize,
    ) -> Self {
        let mut pts: Vec<(usize, f64)> = points
            .into_iter()
            .map(|(b, d)| (b.saturating_sub(min_overhead), d))
            .collect();
        pts.sort_by(|a, b| {
            a.0.cmp(&b.0)
                .then(a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal))
        });
        let mut bytes = Vec::with_capacity(pts.len());
        let mut min_dist = Vec::with_capacity(pts.len());
        let mut run = f64::INFINITY;
        for (b, d) in pts {
            run = run.min(d);
            bytes.push(b);
            min_dist.push(run);
        }
        Self { bytes, min_dist }
    }

    pub fn is_empty(&self) -> bool {
        self.bytes.is_empty()
    }

    /// True iff some completed point has strictly fewer serialized bytes
    /// than any container extending `payload_lb` payload bytes could
    /// have, **and** strictly less distortion than `dist_lb`. Both
    /// arguments are monotone lower bounds on the probe's final values,
    /// so `true` proves the finished probe would be strictly dominated.
    #[inline]
    pub fn dominates(&self, payload_lb: usize, dist_lb: f64) -> bool {
        let k = self.bytes.partition_point(|&b| b < payload_lb);
        k > 0 && self.min_dist[k - 1] < dist_lb
    }
}

/// The exact running totals an abandoned probe was cut at — the values
/// [`ProbeBudget::check`] evaluated, base sums included. Recorded on the
/// abandoned sweep point so "this partial is provably dominated / over
/// budget" stays checkable from the report alone.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AbandonedAt {
    /// Payload-byte lower bound at the cut (base + buffered).
    pub bytes: usize,
    /// Distortion lower bound at the cut (base + in-scan).
    pub distortion: f64,
}

/// The budgeted scan's abandon predicate (see the module docs): the
/// payload leg alone when `dominance` is `None` (legacy
/// selection-neutral budget), the conjunction of payload leg and strict
/// Pareto dominance when a staircase is supplied (frontier-preserving).
#[derive(Debug, Clone, Copy)]
pub struct ProbeBudget<'a> {
    /// Payload bytes accumulated by earlier layers/chunks of this probe.
    pub base_bytes: usize,
    /// Distortion accumulated by earlier layers/chunks of this probe.
    pub base_distortion: f64,
    /// Payload budget (λ-column incumbent bound); `usize::MAX` disables
    /// abandonment entirely.
    pub budget_bytes: usize,
    /// Completed-point staircase for the dominance leg.
    pub dominance: Option<&'a DominanceFrontier>,
}

impl ProbeBudget<'_> {
    /// Never abandons — the plain-encode configuration.
    pub const UNBOUNDED: ProbeBudget<'static> = ProbeBudget {
        base_bytes: 0,
        base_distortion: 0.0,
        budget_bytes: usize::MAX,
        dominance: None,
    };

    /// Abandon decision for the running totals `base + in-layer`;
    /// `Some` carries the exact evaluated totals. Shared by the in-scan
    /// poll and the sweep engine's layer-boundary check so both evaluate
    /// exactly the same predicate.
    #[inline]
    pub fn check(&self, bytes_in_layer: usize, dist_in_layer: f64) -> Option<AbandonedAt> {
        let bytes = self.base_bytes.saturating_add(bytes_in_layer);
        if bytes <= self.budget_bytes {
            return None;
        }
        let distortion = self.base_distortion + dist_in_layer;
        let cut = match self.dominance {
            None => true,
            Some(f) => f.dominates(bytes, distortion),
        };
        cut.then_some(AbandonedAt { bytes, distortion })
    }
}

/// Warm-start seed for one tensor scan: the levels an already-probed
/// neighbouring grid point chose, plus the grid-step ratio
/// `Δ_seed / Δ_probe` that maps them onto the probe's grid.
#[derive(Debug, Clone, Copy)]
pub struct ScanSeed<'a> {
    pub levels: &'a [i32],
    /// `Δ_seed / Δ_probe` — a seed level k lands near `k · scale` on the
    /// probe's grid.
    pub scale: f64,
}

#[derive(Debug)]
pub struct QuantResult {
    pub levels: Vec<i32>,
    pub payload: Vec<u8>,
    /// Weighted distortion Σ η_i (w_i − q_i)².
    pub distortion: f64,
    /// Estimated rate in bits (actual payload may differ by ≤ ~2%).
    pub est_bits: f64,
    /// Weights whose warm-start seed candidate was the chosen level
    /// (0 for unseeded scans).
    pub seed_hits: usize,
    /// Weights scanned with a warm-start seed (0 for unseeded scans).
    pub seeded: usize,
}

pub struct RdQuantizer {
    pub cfg: CodecConfig,
}

impl RdQuantizer {
    pub fn new(cfg: CodecConfig) -> Self {
        Self { cfg }
    }

    /// Quantize and entropy-code a tensor in one coupled scan.
    ///
    /// `etas[i] = 1/σ_i²` — the robustness weighting of eq. 1. Pass all
    /// ones for the unweighted ablation.
    pub fn quantize_encode(
        &self,
        weights: &[f32],
        etas: &[f32],
        grid: &QuantGrid,
        params: RdParams,
    ) -> QuantResult {
        self.quantize_encode_budgeted(weights, etas, grid, params, 0, usize::MAX)
            .expect("an unbounded budget never abandons")
    }

    /// [`Self::quantize_encode`] with the legacy byte-only abandon
    /// budget: every [`BUDGET_CHECK_EVERY`] weights the scan compares
    /// `base_bytes` (payload already accumulated by earlier layers or
    /// chunks of the same probe) plus the bytes buffered so far against
    /// `budget_bytes`, and returns `None` the moment the sum exceeds the
    /// budget. The buffered byte count is a monotone lower bound on the
    /// final payload size, so an abandoned probe could never have
    /// produced a payload within budget — abandonment is
    /// selection-neutral by construction. A non-abandoned result is
    /// byte-identical to the unbudgeted encode.
    pub fn quantize_encode_budgeted(
        &self,
        weights: &[f32],
        etas: &[f32],
        grid: &QuantGrid,
        params: RdParams,
        base_bytes: usize,
        budget_bytes: usize,
    ) -> Option<QuantResult> {
        let budget = ProbeBudget {
            base_bytes,
            base_distortion: 0.0,
            budget_bytes,
            dominance: None,
        };
        self.quantize_encode_probe(weights, etas, grid, params, &budget, None).ok()
    }

    /// The full sweep-probe scan: [`Self::quantize_encode`] with the 2-D
    /// abandon predicate of `budget` polled every [`BUDGET_CHECK_EVERY`]
    /// weights (`Err` on abandonment, carrying the exact cut totals) and
    /// an optional warm-start `seed` (see the module docs; the output is
    /// byte-identical to the unseeded scan either way — a seed only
    /// changes how fast the per-weight argmin certificate closes, plus
    /// the `seed_hits`/`seeded` counters in the result).
    pub fn quantize_encode_probe(
        &self,
        weights: &[f32],
        etas: &[f32],
        grid: &QuantGrid,
        params: RdParams,
        budget: &ProbeBudget,
        seed: Option<ScanSeed>,
    ) -> Result<QuantResult, AbandonedAt> {
        assert_eq!(weights.len(), etas.len());
        if let Some(s) = &seed {
            assert_eq!(s.levels.len(), weights.len(), "seed/weight length mismatch");
        }
        let cfg = self.cfg;
        let mut enc = LevelEncoder::with_capacity(cfg, weights.len() / 4 + 16);
        let mut levels = Vec::with_capacity(weights.len());
        let mut distortion = 0.0f64;
        let mut est_bits = 0.0f64;
        let (mut seed_hits, mut seeded) = (0usize, 0usize);

        for (i, (&w, &eta)) in weights.iter().zip(etas).enumerate() {
            if i % BUDGET_CHECK_EVERY == 0 {
                if let Some(cut) = budget.check(enc.bytes_buffered(), distortion) {
                    return Err(cut);
                }
            }
            let seed_cand = seed.as_ref().map(|s| {
                ((s.levels[i] as f64 * s.scale).round() as i64)
                    .clamp(-(grid.max_level as i64), grid.max_level as i64)
                    as i32
            });
            let (level, cost_d, cost_r) =
                self.pick_level(&mut enc, w, eta, grid, params, seed_cand);
            if let Some(c) = seed_cand {
                seeded += 1;
                seed_hits += usize::from(level == c);
            }
            distortion += cost_d as f64;
            est_bits += cost_r as f64;
            enc.encode_level(level);
            levels.push(level);
        }
        Ok(QuantResult {
            levels,
            payload: enc.finish(),
            distortion,
            est_bits,
            seed_hits,
            seeded,
        })
    }

    /// Choose the RD-optimal level for one weight under the encoder's
    /// current context states. Returns (level, distortion, rate_bits).
    ///
    /// Exact pruned argmin (see the module docs): candidates are visited
    /// in order of increasing distortion via two frontiers expanding
    /// outward from the real-valued vertex w/Δ. A frontier closes once
    /// its distortion term alone strictly exceeds the best cost so far
    /// (λ·R ≥ 0, and along one frontier the computed f32 distortion is
    /// monotone non-decreasing, so everything further out is strictly
    /// worse). Ties in cost keep the smaller level — the same winner the
    /// exhaustive ascending scan keeps.
    ///
    /// Rate queries go through the encoder's memoized estimator
    /// (bit-identical to `RateEstimator::level_bits`, O(1) amortized).
    ///
    /// `seed`: optional warm-start candidate evaluated first and
    /// installed as the incumbent (finite costs only) — provably
    /// outcome-neutral, see the module docs.
    #[inline]
    fn pick_level(
        &self,
        enc: &mut LevelEncoder,
        w: f32,
        eta: f32,
        grid: &QuantGrid,
        params: RdParams,
        seed: Option<i32>,
    ) -> (i32, f32, f32) {
        let lambda = params.lambda.max(0.0);
        let max_l = grid.max_level;
        // Real-valued vertex of the distortion parabola; the clamp keeps
        // the frontier arithmetic in i32 range for wild inputs.
        let x = (w as f64 / grid.delta as f64)
            .clamp(-(max_l as f64) - 1.0, max_l as f64 + 1.0);
        let mut down = x.floor() as i32; // first candidate at or below x
        if down > max_l {
            down = max_l; // whole grid sits below x: descend only
        }
        let mut up = down + 1; // first candidate above x
        if up < -max_l {
            up = -max_l; // whole grid sits above x: ascend only
        }
        let mut down_open = down >= -max_l;
        let mut up_open = up <= max_l;

        let mut best = (0i32, f32::INFINITY, 0.0f32, 0.0f32); // (level, cost, d, r)
        if let Some(s) = seed {
            let dq = w - grid.value(s);
            let d = eta * dq * dq;
            let r = enc.estimate_level_bits(s);
            let cost = d + lambda * r;
            // An ∞ seed cost would beat the cold path's ∞-cost tie-break
            // guard below; skip it so warm stays bit-identical to cold
            // even when every candidate's f32 cost overflows.
            if cost < f32::INFINITY {
                best = (s, cost, d, r);
            }
        }
        while down_open || up_open {
            // expand the frontier closer to the vertex (ties: down first,
            // so equidistant pairs are seen smaller-level first)
            let go_down = if down_open && up_open {
                (x - down as f64) <= (up as f64 - x)
            } else {
                down_open
            };
            let level = if go_down { down } else { up };
            let dq = w - grid.value(level);
            let d = eta * dq * dq;
            if d > best.1 {
                // every remaining candidate on this frontier has a
                // distortion ≥ d and a rate cost λ·R ≥ 0 ⇒ strictly
                // worse than the incumbent: close the frontier.
                if go_down {
                    down_open = false;
                } else {
                    up_open = false;
                }
                continue;
            }
            let r = enc.estimate_level_bits(level);
            let cost = d + lambda * r;
            if cost < best.1 || (cost == best.1 && best.1 < f32::INFINITY && level < best.0)
            {
                best = (level, cost, d, r);
            }
            if go_down {
                down -= 1;
                down_open = down >= -max_l;
            } else {
                up += 1;
                up_open = up <= max_l;
            }
        }
        (best.0, best.2, best.3)
    }

    /// Exhaustive variant (every level in the grid) — O(K) per weight,
    /// used by tests to validate the pruned scan.
    pub fn quantize_encode_exhaustive(
        &self,
        weights: &[f32],
        etas: &[f32],
        grid: &QuantGrid,
        lambda: f32,
    ) -> QuantResult {
        let lambda = lambda.max(0.0);
        let cfg = self.cfg;
        let mut enc = LevelEncoder::with_capacity(cfg, weights.len() / 4 + 16);
        let mut levels = Vec::with_capacity(weights.len());
        let mut distortion = 0.0f64;
        let mut est_bits = 0.0f64;
        for (&w, &eta) in weights.iter().zip(etas) {
            let prev = enc.prev_sig();
            let mut best = (0i32, f32::INFINITY, 0.0f32, 0.0f32);
            for level in -grid.max_level..=grid.max_level {
                let dq = w - grid.value(level);
                let d = eta * dq * dq;
                let r = RateEstimator::level_bits(&cfg, &enc.ctxs, prev, level);
                let cost = d + lambda * r;
                if cost < best.1 {
                    best = (level, cost, d, r);
                }
            }
            distortion += best.2 as f64;
            est_bits += best.3 as f64;
            enc.encode_level(best.0);
            levels.push(best.0);
        }
        QuantResult {
            levels,
            payload: enc.finish(),
            distortion,
            est_bits,
            seed_hits: 0,
            seeded: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::decode_levels;
    use crate::util::{ptest, SplitMix64};

    fn gen_tensor(rng: &mut SplitMix64, n: usize, sparsity: f64) -> (Vec<f32>, Vec<f32>) {
        let mut w = vec![0.0f32; n];
        let mut eta = vec![0.0f32; n];
        for i in 0..n {
            if rng.next_f64() >= sparsity {
                w[i] = rng.laplace(0.1) as f32;
            }
            let sigma = 0.01 + 0.2 * rng.next_f64() as f32;
            eta[i] = 1.0 / (sigma * sigma);
        }
        (w, eta)
    }

    #[test]
    fn lambda_zero_equals_weighted_nearest() {
        let mut rng = SplitMix64::new(2);
        let (w, eta) = gen_tensor(&mut rng, 4000, 0.8);
        let grid = QuantGrid::from_stats(1.0, 0.02, 40);
        let q = RdQuantizer::new(CodecConfig::default());
        let res = q.quantize_encode(&w, &eta, &grid, RdParams { lambda: 0.0 });
        let near = super::super::nearest(&w, &grid);
        assert_eq!(res.levels, near);
    }

    #[test]
    fn roundtrip_through_decoder() {
        let mut rng = SplitMix64::new(3);
        let (w, eta) = gen_tensor(&mut rng, 10_000, 0.9);
        let grid = QuantGrid::from_tensor(&w, &eta.iter().map(|e| 1.0 / e.sqrt()).collect::<Vec<_>>(), 30);
        let cfg = CodecConfig::default();
        let q = RdQuantizer::new(cfg);
        let res = q.quantize_encode(&w, &eta, &grid, RdParams { lambda: 0.002 });
        let dec = decode_levels(&res.payload, w.len(), cfg);
        assert_eq!(dec, res.levels);
    }

    #[test]
    fn higher_lambda_smaller_payload() {
        let mut rng = SplitMix64::new(5);
        let (w, eta) = gen_tensor(&mut rng, 20_000, 0.85);
        let grid = QuantGrid::from_stats(0.5, 0.01, 60);
        let q = RdQuantizer::new(CodecConfig::default());
        let mut prev_bytes = usize::MAX;
        let mut prev_dist = -1.0f64;
        for lambda in [0.0f32, 1e-4, 1e-3, 1e-2] {
            let res = q.quantize_encode(&w, &eta, &grid, RdParams { lambda });
            assert!(res.payload.len() <= prev_bytes, "λ={lambda}");
            assert!(res.distortion >= prev_dist, "λ={lambda}");
            prev_bytes = res.payload.len();
            prev_dist = res.distortion;
        }
    }

    #[test]
    fn pruned_matches_exhaustive() {
        // The outward-scan candidate set must reproduce the full-grid scan
        // exactly — levels AND payload bytes.
        let mut rng = SplitMix64::new(8);
        let (w, eta) = gen_tensor(&mut rng, 1500, 0.7);
        let grid = QuantGrid::from_stats(0.4, 0.02, 25);
        let q = RdQuantizer::new(CodecConfig::default());
        for lambda in [0.0f32, 5e-4, 5e-3] {
            let a = q.quantize_encode(&w, &eta, &grid, RdParams { lambda });
            let b = q.quantize_encode_exhaustive(&w, &eta, &grid, lambda);
            assert_eq!(a.levels, b.levels, "λ={lambda}");
            assert_eq!(a.payload, b.payload, "λ={lambda}");
        }
    }

    #[test]
    fn pruned_matches_exhaustive_large_lambda() {
        // Regression for the old ±window + halving-ladder scan: on a fine
        // grid (nearest level ≫ the old window of 4) at large λ the
        // optimum sits mid-range or near zero — exactly the levels the
        // ladder skipped. The outward scan must stay exhaustive-exact
        // across the whole λ sweep, through the regime where rate
        // dominates distortion.
        let mut rng = SplitMix64::new(13);
        let (w, eta) = gen_tensor(&mut rng, 400, 0.5);
        // σ_min far below the weight scale ⇒ Δ tiny ⇒ nearest ~ hundreds
        let grid = QuantGrid::from_tensor(
            &w,
            &vec![0.002f32; w.len()],
            64,
        );
        assert!(
            grid.max_level > 40,
            "fixture must put nearest levels far from 0 (max_level={})",
            grid.max_level
        );
        let q = RdQuantizer::new(CodecConfig::default());
        for lambda in [1e-3f32, 1e-2, 0.1, 1.0, 10.0, 100.0] {
            let a = q.quantize_encode(&w, &eta, &grid, RdParams { lambda });
            let b = q.quantize_encode_exhaustive(&w, &eta, &grid, lambda);
            assert_eq!(a.levels, b.levels, "λ={lambda}");
            assert_eq!(a.payload, b.payload, "λ={lambda}");
        }
        // sanity: the large-λ regime actually pulled levels off `nearest`
        let a = q.quantize_encode(&w, &eta, &grid, RdParams { lambda: 10.0 });
        let near = super::super::nearest(&w, &grid);
        assert_ne!(a.levels, near, "λ=10 should shrink levels toward 0");
    }

    #[test]
    fn property_pruned_matches_exhaustive_randomized() {
        // Random tensors × random grids × log-uniform λ: the pruned scan
        // is byte-identical to the exhaustive one everywhere, including
        // tie-breaks, clamped weights, and degenerate grids.
        ptest::check(
            ptest::Config { cases: 16, max_size: 300, ..Default::default() },
            "rd-pruned-exhaustive",
            |g| {
                let n = g.usize_in(1, g.size.max(1));
                let mut rng = SplitMix64::new(g.rng.next_u64());
                let sparsity = rng.next_f64();
                let (w, eta) = gen_tensor(&mut rng, n, sparsity);
                let s = rng.below(257) as u32;
                let sigma_min = 0.001 + 0.05 * rng.next_f32();
                let w_max = 0.2 + rng.next_f32();
                let grid = QuantGrid::from_stats(w_max, sigma_min, s);
                if grid.max_level > 600 {
                    return Ok(()); // keep the O(K)-per-weight oracle fast
                }
                // λ log-uniform over ~8 decades, plus exact zero
                let lambda = if rng.next_f64() < 0.1 {
                    0.0
                } else {
                    (10.0f64.powf(rng.next_f64() * 8.0 - 6.0)) as f32
                };
                let q = RdQuantizer::new(CodecConfig::default());
                let a = q.quantize_encode(&w, &eta, &grid, RdParams { lambda });
                let b = q.quantize_encode_exhaustive(&w, &eta, &grid, lambda);
                if a.levels != b.levels {
                    let i = a
                        .levels
                        .iter()
                        .zip(&b.levels)
                        .position(|(x, y)| x != y)
                        .unwrap();
                    return Err(format!(
                        "λ={lambda} S={s} Δ={} max_level={}: levels diverge at {i}: \
                         pruned {} vs exhaustive {} (w={})",
                        grid.delta, grid.max_level, a.levels[i], b.levels[i], w[i]
                    ));
                }
                if a.payload != b.payload {
                    return Err(format!("λ={lambda}: payload bytes diverge"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn seeded_scan_identical_even_with_adversarial_seed() {
        // satellite (warm-start forced fallback): a seed that is wrong
        // for EVERY weight must not change a single byte — the seeded
        // incumbent only tightens the scan's certificate, never its
        // answer. Exercised with the true levels (all hits), shifted
        // levels, saturated levels, and a rescaled-grid seed.
        let mut rng = SplitMix64::new(17);
        let (w, eta) = gen_tensor(&mut rng, 6_000, 0.8);
        let grid = QuantGrid::from_stats(0.6, 0.015, 48);
        let q = RdQuantizer::new(CodecConfig::default());
        for lambda in [0.0f32, 1e-3, 0.5] {
            let params = RdParams { lambda };
            let cold = q.quantize_encode(&w, &eta, &grid, params);
            assert_eq!((cold.seed_hits, cold.seeded), (0, 0));

            // perfect seed: everything hits, bytes identical
            let seed = ScanSeed { levels: &cold.levels, scale: 1.0 };
            let warm = q
                .quantize_encode_probe(&w, &eta, &grid, params, &ProbeBudget::UNBOUNDED, Some(seed))
                .unwrap();
            assert_eq!(warm.payload, cold.payload, "λ={lambda}");
            assert_eq!(warm.levels, cold.levels, "λ={lambda}");
            assert_eq!(warm.seeded, w.len());
            assert_eq!(warm.seed_hits, w.len(), "λ={lambda}: perfect seed must all-hit");

            // adversarial seeds: wrong for every weight, still identical
            let shifted: Vec<i32> = cold
                .levels
                .iter()
                .map(|&l| (l + 3).min(grid.max_level))
                .collect();
            let saturated = vec![grid.max_level; w.len()];
            for bad in [&shifted, &saturated] {
                let seed = ScanSeed { levels: bad, scale: 1.0 };
                let warm = q
                    .quantize_encode_probe(
                        &w, &eta, &grid, params, &ProbeBudget::UNBOUNDED, Some(seed),
                    )
                    .unwrap();
                assert_eq!(warm.payload, cold.payload, "λ={lambda}");
                assert_eq!(warm.levels, cold.levels, "λ={lambda}");
            }

            // neighbouring-grid seed: levels from S=47 rescaled onto S=48
            let near_grid = QuantGrid::from_stats(0.6, 0.015, 47);
            let near = q.quantize_encode(&w, &eta, &near_grid, params);
            let seed = ScanSeed {
                levels: &near.levels,
                scale: near_grid.delta as f64 / grid.delta as f64,
            };
            let warm = q
                .quantize_encode_probe(&w, &eta, &grid, params, &ProbeBudget::UNBOUNDED, Some(seed))
                .unwrap();
            assert_eq!(warm.payload, cold.payload, "λ={lambda}");
            // the whole point of warm starting: neighbouring Δ differs by
            // < 1%, so the vast majority of seeded argmins are unchanged
            // (conservative 80% floor — a broken rescale lands near 0%)
            assert!(
                warm.seed_hits * 5 >= warm.seeded * 4,
                "λ={lambda}: neighbour seed hit rate {}/{}",
                warm.seed_hits,
                warm.seeded
            );
        }
    }

    #[test]
    fn property_seeded_scan_matches_cold() {
        // random tensors × random grids × random (even garbage) seeds:
        // the seeded scan is byte-identical to the cold scan everywhere.
        ptest::check(
            ptest::Config { cases: 24, max_size: 400, ..Default::default() },
            "rd-seeded-cold-parity",
            |g| {
                let n = g.usize_in(1, g.size.max(1));
                let mut rng = SplitMix64::new(g.rng.next_u64());
                let (w, eta) = gen_tensor(&mut rng, n, rng.next_f64());
                let s = rng.below(257) as u32;
                let grid = QuantGrid::from_stats(0.2 + rng.next_f32(), 0.001 + 0.05 * rng.next_f32(), s);
                let lambda = if rng.next_f64() < 0.2 {
                    0.0
                } else {
                    (10.0f64.powf(rng.next_f64() * 6.0 - 4.0)) as f32
                };
                let params = RdParams { lambda };
                let q = RdQuantizer::new(CodecConfig::default());
                let cold = q.quantize_encode(&w, &eta, &grid, params);
                let seed_levels: Vec<i32> = match rng.below(3) {
                    0 => cold.levels.clone(), // perfect
                    1 => (0..n) // garbage
                        .map(|_| rng.below(2 * grid.max_level.max(1) as u64 + 1) as i32
                            - grid.max_level)
                        .collect(),
                    _ => {
                        // a neighbouring grid point's real levels
                        let ns = if s == 256 { 255 } else { s + 1 };
                        let ngrid = QuantGrid::from_stats(
                            0.2 + rng.next_f32(),
                            0.001 + 0.05 * rng.next_f32(),
                            ns,
                        );
                        q.quantize_encode(&w, &eta, &ngrid, params).levels
                    }
                };
                let scale = 0.5 + rng.next_f64(); // exercise rescale+clamp too
                let warm = q
                    .quantize_encode_probe(
                        &w,
                        &eta,
                        &grid,
                        params,
                        &ProbeBudget::UNBOUNDED,
                        Some(ScanSeed { levels: &seed_levels, scale }),
                    )
                    .expect("unbounded budget never abandons");
                if warm.levels != cold.levels {
                    let i = warm
                        .levels
                        .iter()
                        .zip(&cold.levels)
                        .position(|(a, b)| a != b)
                        .unwrap();
                    return Err(format!(
                        "λ={lambda} S={s}: seeded diverges at {i}: {} vs {} (seed {})",
                        warm.levels[i], cold.levels[i], seed_levels[i]
                    ));
                }
                if warm.payload != cold.payload {
                    return Err(format!("λ={lambda} S={s}: payload bytes diverge"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn dominance_frontier_staircase_queries() {
        // staircase vs the brute-force definition, including the strict
        // inequalities on both axes and the min_overhead shift
        let pts = [(100usize, 5.0f64), (80, 9.0), (120, 3.0), (80, 7.5)];
        let oh = 10;
        let f = DominanceFrontier::from_completed(pts.iter().copied(), oh);
        assert!(!f.is_empty());
        let brute = |payload_lb: usize, dist_lb: f64| {
            pts.iter().any(|&(b, d)| b - oh < payload_lb && d < dist_lb)
        };
        for payload in [0usize, 60, 69, 70, 71, 89, 90, 91, 109, 110, 111, 500] {
            for dist in [0.0f64, 2.9, 3.0, 3.1, 5.0, 7.4, 7.6, 9.0, 9.1, 50.0] {
                assert_eq!(
                    f.dominates(payload, dist),
                    brute(payload, dist),
                    "payload={payload} dist={dist}"
                );
            }
        }
        // empty staircase never dominates
        let empty = DominanceFrontier::from_completed(std::iter::empty(), 0);
        assert!(empty.is_empty());
        assert!(!empty.dominates(usize::MAX - 1, f64::INFINITY));
    }

    #[test]
    fn probe_budget_conjunction_semantics() {
        // byte leg alone (legacy) vs byte ∧ dominance (frontier-preserving)
        let f = DominanceFrontier::from_completed([(100usize, 5.0f64)], 0);
        let byte_only =
            ProbeBudget { base_bytes: 0, base_distortion: 0.0, budget_bytes: 50, dominance: None };
        assert_eq!(byte_only.check(51, 0.25), Some(AbandonedAt { bytes: 51, distortion: 0.25 }));
        assert_eq!(byte_only.check(50, 1e9), None);
        let guarded = ProbeBudget {
            base_bytes: 40,
            base_distortion: 2.0,
            budget_bytes: 50,
            dominance: Some(&f),
        };
        // over budget but NOT dominated (distortion lower bound below the
        // completed point's): a frontier candidate, must survive
        assert!(guarded.check(110, 2.0).is_none());
        // over budget AND strictly dominated on both axes (base 2.0 +
        // in-layer 4.0 = 6.0 > 5.0, bytes 150 > 100): abandoned, and the
        // cut record carries the exact evaluated totals
        assert_eq!(
            guarded.check(110, 4.0),
            Some(AbandonedAt { bytes: 150, distortion: 6.0 })
        );
        // under budget: never abandoned regardless of dominance
        assert!(guarded.check(5, 1e9).is_none());
        // equal distortion is NOT strict dominance (2.0 + 3.0 == 5.0)
        assert!(guarded.check(110, 3.0).is_none());
    }

    #[test]
    fn budgeted_encode_is_identical_or_abandons() {
        let mut rng = SplitMix64::new(21);
        let (w, eta) = gen_tensor(&mut rng, 8_000, 0.85);
        let grid = QuantGrid::from_stats(0.5, 0.01, 60);
        let q = RdQuantizer::new(CodecConfig::default());
        let params = RdParams { lambda: 1e-3 };
        let full = q.quantize_encode(&w, &eta, &grid, params);

        // generous budget: byte-identical to the unbudgeted encode
        let same = q
            .quantize_encode_budgeted(&w, &eta, &grid, params, 0, full.payload.len())
            .expect("budget == final size must not abandon");
        assert_eq!(same.payload, full.payload);
        assert_eq!(same.levels, full.levels);

        // budget strictly below the final size: must abandon...
        let aborted =
            q.quantize_encode_budgeted(&w, &eta, &grid, params, 0, full.payload.len() / 2);
        assert!(aborted.is_none());
        // ...and a nonzero base eats into the budget the same way
        let aborted = q.quantize_encode_budgeted(
            &w,
            &eta,
            &grid,
            params,
            full.payload.len(),
            full.payload.len() + 8,
        );
        assert!(aborted.is_none());
    }

    #[test]
    fn property_roundtrip_and_monotonicity() {
        ptest::check(
            ptest::Config { cases: 32, max_size: 3000, ..Default::default() },
            "rd-quant",
            |g| {
                let n = g.usize_in(1, g.size.max(1));
                let mut rng = SplitMix64::new(g.rng.next_u64());
                let sparsity = rng.next_f64();
                let (w, eta) = gen_tensor(&mut rng, n, sparsity);
                let s = rng.below(200) as u32;
                let sigmas: Vec<f32> = eta.iter().map(|e| 1.0 / e.sqrt()).collect();
                let grid = QuantGrid::from_tensor(&w, &sigmas, s);
                let cfg = CodecConfig::default();
                let qz = RdQuantizer::new(cfg);
                let lambda = (rng.next_f64() * 0.01) as f32;
                let res = qz.quantize_encode(&w, &eta, &grid, RdParams { lambda });
                let dec = decode_levels(&res.payload, n, cfg);
                if dec != res.levels {
                    return Err("decode mismatch".into());
                }
                // reconstruction error bounded by Δ/2 when λ=0-ish window
                if lambda == 0.0 {
                    for (i, (&wi, &li)) in w.iter().zip(&res.levels).enumerate() {
                        let rec = grid.value(li);
                        let bound = grid.delta * 0.5 + grid.delta * 1e-3;
                        let clamped = wi.abs() > grid.value(grid.max_level);
                        if !clamped && (wi - rec).abs() > bound {
                            return Err(format!("w[{i}]={wi} rec={rec} Δ={}", grid.delta));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
