//! The coupled weighted rate–distortion quantizer (paper eq. 1):
//!
//! ```text
//! w_i → q_k* = argmin_k  η_i (w_i − q_k)² + λ R_ik
//! ```
//!
//! `R_ik` is the *live* CABAC bit cost of coding level k at position i —
//! the context models have been updated by every previously encoded
//! weight, so quantization and entropy coding are a single coupled scan
//! (the paper's central design point; decoupled pipelines lose this).
//!
//! Candidate pruning: candidates are visited **outward from the
//! distortion vertex** w/Δ (two frontiers, one descending and one
//! ascending, always expanding the one closer to the vertex). Along each
//! frontier the distortion term is monotone non-decreasing, and the rate
//! term satisfies λ·R ≥ 0, so the moment a frontier's distortion alone
//! exceeds the best total cost found so far, every remaining candidate
//! on that frontier is strictly worse and the frontier is closed. The
//! scan therefore evaluates exactly the candidates that could still win
//! and is **provably identical** to the exhaustive full-grid argmin
//! (ties broken toward the smaller level, matching the exhaustive scan
//! order), at a few rate queries per weight for realistic λ.
//!
//! The previous scheme (±window around the nearest level plus a halving
//! ladder toward 0) was *not* exact: levels in `1..=window` were never
//! evaluated when the nearest level sat far from 0, the region between
//! the window and `nearest/2` was only sampled at halving points, and
//! with adapted contexts the rate is not even monotone in |level| — at
//! large λ the pruned argmin diverged from the exhaustive one. The
//! property tests compare against the exhaustive scan across the full λ
//! range, including the `nearest ≫ old-window` regime.

use super::grid::QuantGrid;
use crate::codec::{CodecConfig, LevelEncoder, RateEstimator};

#[derive(Debug, Clone, Copy)]
pub struct RdParams {
    /// Lagrangian λ (distortion units per bit). Negative values are
    /// clamped to 0 (a negative λ would reward spending bits and break
    /// the pruning invariants). The pipeline derives it per (S, λ) grid
    /// point as `lambda_scale · Δ² · mean(η)` (`LayerStats::lambda`), so
    /// the sweep engine's λ axis threads through here — including into
    /// the budgeted encode used by early-abandoned probes.
    pub lambda: f32,
}

impl Default for RdParams {
    fn default() -> Self {
        Self { lambda: 0.0 }
    }
}

/// How often (in weights) the budgeted scan polls the abandon condition.
const BUDGET_CHECK_EVERY: usize = 512;

#[derive(Debug)]
pub struct QuantResult {
    pub levels: Vec<i32>,
    pub payload: Vec<u8>,
    /// Weighted distortion Σ η_i (w_i − q_i)².
    pub distortion: f64,
    /// Estimated rate in bits (actual payload may differ by ≤ ~2%).
    pub est_bits: f64,
}

pub struct RdQuantizer {
    pub cfg: CodecConfig,
}

impl RdQuantizer {
    pub fn new(cfg: CodecConfig) -> Self {
        Self { cfg }
    }

    /// Quantize and entropy-code a tensor in one coupled scan.
    ///
    /// `etas[i] = 1/σ_i²` — the robustness weighting of eq. 1. Pass all
    /// ones for the unweighted ablation.
    pub fn quantize_encode(
        &self,
        weights: &[f32],
        etas: &[f32],
        grid: &QuantGrid,
        params: RdParams,
    ) -> QuantResult {
        self.quantize_encode_budgeted(weights, etas, grid, params, 0, usize::MAX)
            .expect("an unbounded budget never abandons")
    }

    /// [`Self::quantize_encode`] with the sweep engine's early-abandon
    /// budget threaded through: every [`BUDGET_CHECK_EVERY`] weights the
    /// scan compares `base_bytes` (payload already accumulated by earlier
    /// layers/chunks of the same probe) plus the bytes buffered so far
    /// against `budget_bytes`, and returns `None` the moment the sum
    /// exceeds the budget. The buffered byte count is a monotone lower
    /// bound on the final payload size, so an abandoned probe could never
    /// have produced a payload within budget — abandonment is
    /// selection-neutral by construction. A non-abandoned result is
    /// byte-identical to the unbudgeted encode.
    pub fn quantize_encode_budgeted(
        &self,
        weights: &[f32],
        etas: &[f32],
        grid: &QuantGrid,
        params: RdParams,
        base_bytes: usize,
        budget_bytes: usize,
    ) -> Option<QuantResult> {
        assert_eq!(weights.len(), etas.len());
        let cfg = self.cfg;
        let mut enc = LevelEncoder::with_capacity(cfg, weights.len() / 4 + 16);
        let mut levels = Vec::with_capacity(weights.len());
        let mut distortion = 0.0f64;
        let mut est_bits = 0.0f64;

        for (i, (&w, &eta)) in weights.iter().zip(etas).enumerate() {
            if i % BUDGET_CHECK_EVERY == 0
                && base_bytes.saturating_add(enc.bytes_buffered()) > budget_bytes
            {
                return None;
            }
            let (level, cost_d, cost_r) = self.pick_level(&mut enc, w, eta, grid, params);
            distortion += cost_d as f64;
            est_bits += cost_r as f64;
            enc.encode_level(level);
            levels.push(level);
        }
        Some(QuantResult { levels, payload: enc.finish(), distortion, est_bits })
    }

    /// Choose the RD-optimal level for one weight under the encoder's
    /// current context states. Returns (level, distortion, rate_bits).
    ///
    /// Exact pruned argmin (see the module docs): candidates are visited
    /// in order of increasing distortion via two frontiers expanding
    /// outward from the real-valued vertex w/Δ. A frontier closes once
    /// its distortion term alone strictly exceeds the best cost so far
    /// (λ·R ≥ 0, and along one frontier the computed f32 distortion is
    /// monotone non-decreasing, so everything further out is strictly
    /// worse). Ties in cost keep the smaller level — the same winner the
    /// exhaustive ascending scan keeps.
    ///
    /// Rate queries go through the encoder's memoized estimator
    /// (bit-identical to `RateEstimator::level_bits`, O(1) amortized).
    #[inline]
    fn pick_level(
        &self,
        enc: &mut LevelEncoder,
        w: f32,
        eta: f32,
        grid: &QuantGrid,
        params: RdParams,
    ) -> (i32, f32, f32) {
        let lambda = params.lambda.max(0.0);
        let max_l = grid.max_level;
        // Real-valued vertex of the distortion parabola; the clamp keeps
        // the frontier arithmetic in i32 range for wild inputs.
        let x = (w as f64 / grid.delta as f64)
            .clamp(-(max_l as f64) - 1.0, max_l as f64 + 1.0);
        let mut down = x.floor() as i32; // first candidate at or below x
        if down > max_l {
            down = max_l; // whole grid sits below x: descend only
        }
        let mut up = down + 1; // first candidate above x
        if up < -max_l {
            up = -max_l; // whole grid sits above x: ascend only
        }
        let mut down_open = down >= -max_l;
        let mut up_open = up <= max_l;

        let mut best = (0i32, f32::INFINITY, 0.0f32, 0.0f32); // (level, cost, d, r)
        while down_open || up_open {
            // expand the frontier closer to the vertex (ties: down first,
            // so equidistant pairs are seen smaller-level first)
            let go_down = if down_open && up_open {
                (x - down as f64) <= (up as f64 - x)
            } else {
                down_open
            };
            let level = if go_down { down } else { up };
            let dq = w - grid.value(level);
            let d = eta * dq * dq;
            if d > best.1 {
                // every remaining candidate on this frontier has a
                // distortion ≥ d and a rate cost λ·R ≥ 0 ⇒ strictly
                // worse than the incumbent: close the frontier.
                if go_down {
                    down_open = false;
                } else {
                    up_open = false;
                }
                continue;
            }
            let r = enc.estimate_level_bits(level);
            let cost = d + lambda * r;
            if cost < best.1 || (cost == best.1 && best.1 < f32::INFINITY && level < best.0)
            {
                best = (level, cost, d, r);
            }
            if go_down {
                down -= 1;
                down_open = down >= -max_l;
            } else {
                up += 1;
                up_open = up <= max_l;
            }
        }
        (best.0, best.2, best.3)
    }

    /// Exhaustive variant (every level in the grid) — O(K) per weight,
    /// used by tests to validate the pruned scan.
    pub fn quantize_encode_exhaustive(
        &self,
        weights: &[f32],
        etas: &[f32],
        grid: &QuantGrid,
        lambda: f32,
    ) -> QuantResult {
        let lambda = lambda.max(0.0);
        let cfg = self.cfg;
        let mut enc = LevelEncoder::with_capacity(cfg, weights.len() / 4 + 16);
        let mut levels = Vec::with_capacity(weights.len());
        let mut distortion = 0.0f64;
        let mut est_bits = 0.0f64;
        for (&w, &eta) in weights.iter().zip(etas) {
            let prev = enc.prev_sig();
            let mut best = (0i32, f32::INFINITY, 0.0f32, 0.0f32);
            for level in -grid.max_level..=grid.max_level {
                let dq = w - grid.value(level);
                let d = eta * dq * dq;
                let r = RateEstimator::level_bits(&cfg, &enc.ctxs, prev, level);
                let cost = d + lambda * r;
                if cost < best.1 {
                    best = (level, cost, d, r);
                }
            }
            distortion += best.2 as f64;
            est_bits += best.3 as f64;
            enc.encode_level(best.0);
            levels.push(best.0);
        }
        QuantResult { levels, payload: enc.finish(), distortion, est_bits }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::decode_levels;
    use crate::util::{ptest, SplitMix64};

    fn gen_tensor(rng: &mut SplitMix64, n: usize, sparsity: f64) -> (Vec<f32>, Vec<f32>) {
        let mut w = vec![0.0f32; n];
        let mut eta = vec![0.0f32; n];
        for i in 0..n {
            if rng.next_f64() >= sparsity {
                w[i] = rng.laplace(0.1) as f32;
            }
            let sigma = 0.01 + 0.2 * rng.next_f64() as f32;
            eta[i] = 1.0 / (sigma * sigma);
        }
        (w, eta)
    }

    #[test]
    fn lambda_zero_equals_weighted_nearest() {
        let mut rng = SplitMix64::new(2);
        let (w, eta) = gen_tensor(&mut rng, 4000, 0.8);
        let grid = QuantGrid::from_stats(1.0, 0.02, 40);
        let q = RdQuantizer::new(CodecConfig::default());
        let res = q.quantize_encode(&w, &eta, &grid, RdParams { lambda: 0.0 });
        let near = super::super::nearest(&w, &grid);
        assert_eq!(res.levels, near);
    }

    #[test]
    fn roundtrip_through_decoder() {
        let mut rng = SplitMix64::new(3);
        let (w, eta) = gen_tensor(&mut rng, 10_000, 0.9);
        let grid = QuantGrid::from_tensor(&w, &eta.iter().map(|e| 1.0 / e.sqrt()).collect::<Vec<_>>(), 30);
        let cfg = CodecConfig::default();
        let q = RdQuantizer::new(cfg);
        let res = q.quantize_encode(&w, &eta, &grid, RdParams { lambda: 0.002 });
        let dec = decode_levels(&res.payload, w.len(), cfg);
        assert_eq!(dec, res.levels);
    }

    #[test]
    fn higher_lambda_smaller_payload() {
        let mut rng = SplitMix64::new(5);
        let (w, eta) = gen_tensor(&mut rng, 20_000, 0.85);
        let grid = QuantGrid::from_stats(0.5, 0.01, 60);
        let q = RdQuantizer::new(CodecConfig::default());
        let mut prev_bytes = usize::MAX;
        let mut prev_dist = -1.0f64;
        for lambda in [0.0f32, 1e-4, 1e-3, 1e-2] {
            let res = q.quantize_encode(&w, &eta, &grid, RdParams { lambda });
            assert!(res.payload.len() <= prev_bytes, "λ={lambda}");
            assert!(res.distortion >= prev_dist, "λ={lambda}");
            prev_bytes = res.payload.len();
            prev_dist = res.distortion;
        }
    }

    #[test]
    fn pruned_matches_exhaustive() {
        // The outward-scan candidate set must reproduce the full-grid scan
        // exactly — levels AND payload bytes.
        let mut rng = SplitMix64::new(8);
        let (w, eta) = gen_tensor(&mut rng, 1500, 0.7);
        let grid = QuantGrid::from_stats(0.4, 0.02, 25);
        let q = RdQuantizer::new(CodecConfig::default());
        for lambda in [0.0f32, 5e-4, 5e-3] {
            let a = q.quantize_encode(&w, &eta, &grid, RdParams { lambda });
            let b = q.quantize_encode_exhaustive(&w, &eta, &grid, lambda);
            assert_eq!(a.levels, b.levels, "λ={lambda}");
            assert_eq!(a.payload, b.payload, "λ={lambda}");
        }
    }

    #[test]
    fn pruned_matches_exhaustive_large_lambda() {
        // Regression for the old ±window + halving-ladder scan: on a fine
        // grid (nearest level ≫ the old window of 4) at large λ the
        // optimum sits mid-range or near zero — exactly the levels the
        // ladder skipped. The outward scan must stay exhaustive-exact
        // across the whole λ sweep, through the regime where rate
        // dominates distortion.
        let mut rng = SplitMix64::new(13);
        let (w, eta) = gen_tensor(&mut rng, 400, 0.5);
        // σ_min far below the weight scale ⇒ Δ tiny ⇒ nearest ~ hundreds
        let grid = QuantGrid::from_tensor(
            &w,
            &vec![0.002f32; w.len()],
            64,
        );
        assert!(
            grid.max_level > 40,
            "fixture must put nearest levels far from 0 (max_level={})",
            grid.max_level
        );
        let q = RdQuantizer::new(CodecConfig::default());
        for lambda in [1e-3f32, 1e-2, 0.1, 1.0, 10.0, 100.0] {
            let a = q.quantize_encode(&w, &eta, &grid, RdParams { lambda });
            let b = q.quantize_encode_exhaustive(&w, &eta, &grid, lambda);
            assert_eq!(a.levels, b.levels, "λ={lambda}");
            assert_eq!(a.payload, b.payload, "λ={lambda}");
        }
        // sanity: the large-λ regime actually pulled levels off `nearest`
        let a = q.quantize_encode(&w, &eta, &grid, RdParams { lambda: 10.0 });
        let near = super::super::nearest(&w, &grid);
        assert_ne!(a.levels, near, "λ=10 should shrink levels toward 0");
    }

    #[test]
    fn property_pruned_matches_exhaustive_randomized() {
        // Random tensors × random grids × log-uniform λ: the pruned scan
        // is byte-identical to the exhaustive one everywhere, including
        // tie-breaks, clamped weights, and degenerate grids.
        ptest::check(
            ptest::Config { cases: 16, max_size: 300, ..Default::default() },
            "rd-pruned-exhaustive",
            |g| {
                let n = g.usize_in(1, g.size.max(1));
                let mut rng = SplitMix64::new(g.rng.next_u64());
                let sparsity = rng.next_f64();
                let (w, eta) = gen_tensor(&mut rng, n, sparsity);
                let s = rng.below(257) as u32;
                let sigma_min = 0.001 + 0.05 * rng.next_f32();
                let w_max = 0.2 + rng.next_f32();
                let grid = QuantGrid::from_stats(w_max, sigma_min, s);
                if grid.max_level > 600 {
                    return Ok(()); // keep the O(K)-per-weight oracle fast
                }
                // λ log-uniform over ~8 decades, plus exact zero
                let lambda = if rng.next_f64() < 0.1 {
                    0.0
                } else {
                    (10.0f64.powf(rng.next_f64() * 8.0 - 6.0)) as f32
                };
                let q = RdQuantizer::new(CodecConfig::default());
                let a = q.quantize_encode(&w, &eta, &grid, RdParams { lambda });
                let b = q.quantize_encode_exhaustive(&w, &eta, &grid, lambda);
                if a.levels != b.levels {
                    let i = a
                        .levels
                        .iter()
                        .zip(&b.levels)
                        .position(|(x, y)| x != y)
                        .unwrap();
                    return Err(format!(
                        "λ={lambda} S={s} Δ={} max_level={}: levels diverge at {i}: \
                         pruned {} vs exhaustive {} (w={})",
                        grid.delta, grid.max_level, a.levels[i], b.levels[i], w[i]
                    ));
                }
                if a.payload != b.payload {
                    return Err(format!("λ={lambda}: payload bytes diverge"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn budgeted_encode_is_identical_or_abandons() {
        let mut rng = SplitMix64::new(21);
        let (w, eta) = gen_tensor(&mut rng, 8_000, 0.85);
        let grid = QuantGrid::from_stats(0.5, 0.01, 60);
        let q = RdQuantizer::new(CodecConfig::default());
        let params = RdParams { lambda: 1e-3 };
        let full = q.quantize_encode(&w, &eta, &grid, params);

        // generous budget: byte-identical to the unbudgeted encode
        let same = q
            .quantize_encode_budgeted(&w, &eta, &grid, params, 0, full.payload.len())
            .expect("budget == final size must not abandon");
        assert_eq!(same.payload, full.payload);
        assert_eq!(same.levels, full.levels);

        // budget strictly below the final size: must abandon...
        let aborted =
            q.quantize_encode_budgeted(&w, &eta, &grid, params, 0, full.payload.len() / 2);
        assert!(aborted.is_none());
        // ...and a nonzero base eats into the budget the same way
        let aborted = q.quantize_encode_budgeted(
            &w,
            &eta,
            &grid,
            params,
            full.payload.len(),
            full.payload.len() + 8,
        );
        assert!(aborted.is_none());
    }

    #[test]
    fn property_roundtrip_and_monotonicity() {
        ptest::check(
            ptest::Config { cases: 32, max_size: 3000, ..Default::default() },
            "rd-quant",
            |g| {
                let n = g.usize_in(1, g.size.max(1));
                let mut rng = SplitMix64::new(g.rng.next_u64());
                let sparsity = rng.next_f64();
                let (w, eta) = gen_tensor(&mut rng, n, sparsity);
                let s = rng.below(200) as u32;
                let sigmas: Vec<f32> = eta.iter().map(|e| 1.0 / e.sqrt()).collect();
                let grid = QuantGrid::from_tensor(&w, &sigmas, s);
                let cfg = CodecConfig::default();
                let qz = RdQuantizer::new(cfg);
                let lambda = (rng.next_f64() * 0.01) as f32;
                let res = qz.quantize_encode(&w, &eta, &grid, RdParams { lambda });
                let dec = decode_levels(&res.payload, n, cfg);
                if dec != res.levels {
                    return Err("decode mismatch".into());
                }
                // reconstruction error bounded by Δ/2 when λ=0-ish window
                if lambda == 0.0 {
                    for (i, (&wi, &li)) in w.iter().zip(&res.levels).enumerate() {
                        let rec = grid.value(li);
                        let bound = grid.delta * 0.5 + grid.delta * 1e-3;
                        let clamped = wi.abs() > grid.value(grid.max_level);
                        if !clamped && (wi - rec).abs() > bound {
                            return Err(format!("w[{i}]={wi} rec={rec} Δ={}", grid.delta));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
