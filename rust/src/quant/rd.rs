//! The coupled weighted rate–distortion quantizer (paper eq. 1):
//!
//! ```text
//! w_i → q_k* = argmin_k  η_i (w_i − q_k)² + λ R_ik
//! ```
//!
//! `R_ik` is the *live* CABAC bit cost of coding level k at position i —
//! the context models have been updated by every previously encoded
//! weight, so quantization and entropy coding are a single coupled scan
//! (the paper's central design point; decoupled pipelines lose this).
//!
//! Candidate pruning: the cost is a parabola in the level with its
//! vertex at w/Δ, plus a rate term that grows monotonically with |level|
//! (sign-symmetric, piecewise). The argmin therefore lies between 0 and
//! the nearest level. We scan (a) a ±window around the nearest level,
//! (b) a halving ladder nearest/2, nearest/4, … toward 0 (catches the
//! mid-range optima that appear at large λ), and (c) level 0 itself.
//! The property tests compare against the exhaustive full-grid scan.

use super::grid::QuantGrid;
use crate::codec::{CodecConfig, LevelEncoder, RateEstimator};

#[derive(Debug, Clone, Copy)]
pub struct RdParams {
    /// Lagrangian λ (distortion units per bit).
    pub lambda: f32,
    /// Candidate half-window around the nearest level (4 is exhaustive in
    /// practice; the property tests compare against a full scan).
    pub window: i32,
}

impl Default for RdParams {
    fn default() -> Self {
        Self { lambda: 0.0, window: 4 }
    }
}

#[derive(Debug)]
pub struct QuantResult {
    pub levels: Vec<i32>,
    pub payload: Vec<u8>,
    /// Weighted distortion Σ η_i (w_i − q_i)².
    pub distortion: f64,
    /// Estimated rate in bits (actual payload may differ by ≤ ~2%).
    pub est_bits: f64,
}

pub struct RdQuantizer {
    pub cfg: CodecConfig,
}

impl RdQuantizer {
    pub fn new(cfg: CodecConfig) -> Self {
        Self { cfg }
    }

    /// Quantize and entropy-code a tensor in one coupled scan.
    ///
    /// `etas[i] = 1/σ_i²` — the robustness weighting of eq. 1. Pass all
    /// ones for the unweighted ablation.
    pub fn quantize_encode(
        &self,
        weights: &[f32],
        etas: &[f32],
        grid: &QuantGrid,
        params: RdParams,
    ) -> QuantResult {
        assert_eq!(weights.len(), etas.len());
        let cfg = self.cfg;
        let mut enc = LevelEncoder::with_capacity(cfg, weights.len() / 4 + 16);
        let mut levels = Vec::with_capacity(weights.len());
        let mut distortion = 0.0f64;
        let mut est_bits = 0.0f64;

        for (&w, &eta) in weights.iter().zip(etas) {
            let (level, cost_d, cost_r) =
                self.pick_level(&mut enc, w, eta, grid, params);
            distortion += cost_d as f64;
            est_bits += cost_r as f64;
            enc.encode_level(level);
            levels.push(level);
        }
        QuantResult { levels, payload: enc.finish(), distortion, est_bits }
    }

    /// Choose the RD-optimal level for one weight under the encoder's
    /// current context states. Returns (level, distortion, rate_bits).
    /// Rate queries go through the encoder's memoized estimator
    /// (bit-identical to `RateEstimator::level_bits`, O(1) amortized).
    #[inline]
    fn pick_level(
        &self,
        enc: &mut LevelEncoder,
        w: f32,
        eta: f32,
        grid: &QuantGrid,
        params: RdParams,
    ) -> (i32, f32, f32) {
        let nearest = grid.nearest_level(w);
        // Fast path for pruned weights (the majority in sparse tensors):
        // only level 0 and ±1 can win — any |level| ≥ 2 has both more
        // distortion and more rate than ±1. Cuts the candidate scan ~3x.
        if w == 0.0 {
            let r0 = enc.estimate_level_bits(0);
            let c0 = params.lambda * r0;
            let mut best = (0i32, c0, 0.0f32, r0);
            if grid.max_level >= 1 && params.lambda > 0.0 {
                let d1 = eta * grid.delta * grid.delta;
                for level in [-1i32, 1] {
                    let r = enc.estimate_level_bits(level);
                    let cost = d1 + params.lambda * r;
                    if cost < best.1 {
                        best = (level, cost, d1, r);
                    }
                }
            }
            return (best.0, best.2, best.3);
        }
        let lo = (nearest - params.window).clamp(-grid.max_level, grid.max_level);
        let hi = (nearest + params.window).clamp(-grid.max_level, grid.max_level);

        let mut best = (0i32, f32::INFINITY, 0.0f32, 0.0f32); // (level, cost, d, r)
        let mut eval = |level: i32| {
            let dq = w - grid.value(level);
            let d = eta * dq * dq;
            let r = enc.estimate_level_bits(level);
            let cost = d + params.lambda * r;
            if cost < best.1 {
                best = (level, cost, d, r);
            }
        };
        // Always consider 0 (the sigflag shortcut dominates sparse tensors).
        if lo > 0 || hi < 0 {
            eval(0);
        }
        for level in lo..=hi {
            eval(level);
        }
        // Halving ladder toward 0: at large λ the optimum can sit strictly
        // between 0 and the nearest level.
        let mut l = nearest / 2;
        while l.abs() > params.window {
            eval(l);
            l /= 2;
        }
        (best.0, best.2, best.3)
    }

    /// Exhaustive variant (every level in the grid) — O(K) per weight,
    /// used by tests to validate the pruned scan.
    pub fn quantize_encode_exhaustive(
        &self,
        weights: &[f32],
        etas: &[f32],
        grid: &QuantGrid,
        lambda: f32,
    ) -> QuantResult {
        let cfg = self.cfg;
        let mut enc = LevelEncoder::with_capacity(cfg, weights.len() / 4 + 16);
        let mut levels = Vec::with_capacity(weights.len());
        let mut distortion = 0.0f64;
        let mut est_bits = 0.0f64;
        for (&w, &eta) in weights.iter().zip(etas) {
            let prev = enc.prev_sig();
            let mut best = (0i32, f32::INFINITY, 0.0f32, 0.0f32);
            for level in -grid.max_level..=grid.max_level {
                let dq = w - grid.value(level);
                let d = eta * dq * dq;
                let r = RateEstimator::level_bits(&cfg, &enc.ctxs, prev, level);
                let cost = d + lambda * r;
                if cost < best.1 {
                    best = (level, cost, d, r);
                }
            }
            distortion += best.2 as f64;
            est_bits += best.3 as f64;
            enc.encode_level(best.0);
            levels.push(best.0);
        }
        QuantResult { levels, payload: enc.finish(), distortion, est_bits }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::decode_levels;
    use crate::util::{ptest, SplitMix64};

    fn gen_tensor(rng: &mut SplitMix64, n: usize, sparsity: f64) -> (Vec<f32>, Vec<f32>) {
        let mut w = vec![0.0f32; n];
        let mut eta = vec![0.0f32; n];
        for i in 0..n {
            if rng.next_f64() >= sparsity {
                w[i] = rng.laplace(0.1) as f32;
            }
            let sigma = 0.01 + 0.2 * rng.next_f64() as f32;
            eta[i] = 1.0 / (sigma * sigma);
        }
        (w, eta)
    }

    #[test]
    fn lambda_zero_equals_weighted_nearest() {
        let mut rng = SplitMix64::new(2);
        let (w, eta) = gen_tensor(&mut rng, 4000, 0.8);
        let grid = QuantGrid::from_stats(1.0, 0.02, 40);
        let q = RdQuantizer::new(CodecConfig::default());
        let res = q.quantize_encode(&w, &eta, &grid, RdParams { lambda: 0.0, window: 4 });
        let near = super::super::nearest(&w, &grid);
        assert_eq!(res.levels, near);
    }

    #[test]
    fn roundtrip_through_decoder() {
        let mut rng = SplitMix64::new(3);
        let (w, eta) = gen_tensor(&mut rng, 10_000, 0.9);
        let grid = QuantGrid::from_tensor(&w, &eta.iter().map(|e| 1.0 / e.sqrt()).collect::<Vec<_>>(), 30);
        let cfg = CodecConfig::default();
        let q = RdQuantizer::new(cfg);
        let res = q.quantize_encode(&w, &eta, &grid, RdParams { lambda: 0.002, window: 4 });
        let dec = decode_levels(&res.payload, w.len(), cfg);
        assert_eq!(dec, res.levels);
    }

    #[test]
    fn higher_lambda_smaller_payload() {
        let mut rng = SplitMix64::new(5);
        let (w, eta) = gen_tensor(&mut rng, 20_000, 0.85);
        let grid = QuantGrid::from_stats(0.5, 0.01, 60);
        let q = RdQuantizer::new(CodecConfig::default());
        let mut prev_bytes = usize::MAX;
        let mut prev_dist = -1.0f64;
        for lambda in [0.0f32, 1e-4, 1e-3, 1e-2] {
            let res = q.quantize_encode(&w, &eta, &grid, RdParams { lambda, window: 4 });
            assert!(res.payload.len() <= prev_bytes, "λ={lambda}");
            assert!(res.distortion >= prev_dist, "λ={lambda}");
            prev_bytes = res.payload.len();
            prev_dist = res.distortion;
        }
    }

    #[test]
    fn pruned_matches_exhaustive() {
        // The ±window + {0} candidate set must reproduce the full-grid scan.
        let mut rng = SplitMix64::new(8);
        let (w, eta) = gen_tensor(&mut rng, 1500, 0.7);
        let grid = QuantGrid::from_stats(0.4, 0.02, 25);
        let q = RdQuantizer::new(CodecConfig::default());
        for lambda in [0.0f32, 5e-4, 5e-3] {
            let a = q.quantize_encode(&w, &eta, &grid, RdParams { lambda, window: 4 });
            let b = q.quantize_encode_exhaustive(&w, &eta, &grid, lambda);
            assert_eq!(a.levels, b.levels, "λ={lambda}");
        }
    }

    #[test]
    fn property_roundtrip_and_monotonicity() {
        ptest::check(
            ptest::Config { cases: 32, max_size: 3000, ..Default::default() },
            "rd-quant",
            |g| {
                let n = g.usize_in(1, g.size.max(1));
                let mut rng = SplitMix64::new(g.rng.next_u64());
                let sparsity = rng.next_f64();
                let (w, eta) = gen_tensor(&mut rng, n, sparsity);
                let s = rng.below(200) as u32;
                let sigmas: Vec<f32> = eta.iter().map(|e| 1.0 / e.sqrt()).collect();
                let grid = QuantGrid::from_tensor(&w, &sigmas, s);
                let cfg = CodecConfig::default();
                let qz = RdQuantizer::new(cfg);
                let lambda = (rng.next_f64() * 0.01) as f32;
                let res = qz.quantize_encode(&w, &eta, &grid, RdParams { lambda, window: 4 });
                let dec = decode_levels(&res.payload, n, cfg);
                if dec != res.levels {
                    return Err("decode mismatch".into());
                }
                // reconstruction error bounded by Δ/2 when λ=0-ish window
                if lambda == 0.0 {
                    for (i, (&wi, &li)) in w.iter().zip(&res.levels).enumerate() {
                        let rec = grid.value(li);
                        let bound = grid.delta * 0.5 + grid.delta * 1e-3;
                        let clamped = wi.abs() > grid.value(grid.max_level);
                        if !clamped && (wi - rec).abs() > bound {
                            return Err(format!("w[{i}]={wi} rec={rec} Δ={}", grid.delta));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
