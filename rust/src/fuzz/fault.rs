//! Serve-path fault injection: a misbehaving-connection wrapper plus
//! canned hostile client sessions.
//!
//! [`FaultyConn`] wraps any `Read + Write` transport and injects the
//! classic client pathologies — byte-dribble writes, mid-stream
//! disconnects, stalled reads — at the `io` layer, so the code under
//! test sees exactly the errors a real flaky peer produces. The session
//! helpers ([`dribble_request`], [`slowloris`],
//! [`disconnect_mid_request`], [`stalled_reader`]) drive a *real* server
//! over TCP; `tests/fault_injection.rs` asserts the server keeps serving
//! healthy clients through a storm of them, and `loadgen --hostile N`
//! mixes them into load runs.

use anyhow::{Context, Result};
use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

/// What a [`FaultyConn`] does to the wrapped transport.
#[derive(Debug, Clone, Copy)]
pub enum FaultPlan {
    /// Pass-through (control case).
    None,
    /// Every `write` call transfers at most one byte.
    DribbleWrites,
    /// Writes fail with `BrokenPipe` after `after` bytes total.
    DisconnectAfterWrite { after: usize },
    /// Reads fail with `WouldBlock` after `after` bytes total (peer that
    /// stops sending but keeps the socket open).
    StallReadsAfter { after: usize },
}

/// A `Read + Write` wrapper that injects faults per a [`FaultPlan`].
pub struct FaultyConn<S> {
    inner: S,
    plan: FaultPlan,
    written: usize,
    read: usize,
}

impl<S> FaultyConn<S> {
    pub fn new(inner: S, plan: FaultPlan) -> Self {
        Self { inner, plan, written: 0, read: 0 }
    }

    pub fn into_inner(self) -> S {
        self.inner
    }
}

impl<S: Read> Read for FaultyConn<S> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        if let FaultPlan::StallReadsAfter { after } = self.plan {
            if self.read >= after {
                return Err(std::io::Error::new(ErrorKind::WouldBlock, "injected read stall"));
            }
            let cap = (after - self.read).min(buf.len()).max(1);
            let n = self.inner.read(&mut buf[..cap])?;
            self.read += n;
            return Ok(n);
        }
        let n = self.inner.read(buf)?;
        self.read += n;
        Ok(n)
    }
}

impl<S: Write> Write for FaultyConn<S> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self.plan {
            FaultPlan::DribbleWrites if !buf.is_empty() => {
                let n = self.inner.write(&buf[..1])?;
                self.written += n;
                Ok(n)
            }
            FaultPlan::DisconnectAfterWrite { after } => {
                if self.written >= after {
                    return Err(std::io::Error::new(
                        ErrorKind::BrokenPipe,
                        "injected disconnect",
                    ));
                }
                let cap = (after - self.written).min(buf.len());
                let n = self.inner.write(&buf[..cap])?;
                self.written += n;
                Ok(n)
            }
            _ => {
                let n = self.inner.write(buf)?;
                self.written += n;
                Ok(n)
            }
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

// ---------------------------------------------------------------------------
// Hostile sessions against a live server
// ---------------------------------------------------------------------------

/// How a hostile session ended, from the attacker's point of view.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FaultOutcome {
    /// The server answered with this HTTP status.
    Status(u16),
    /// The server closed the connection without a response.
    Closed,
    /// The socket errored (reset, timeout, refused…) — message attached.
    IoError(String),
}

/// Read just enough of a response to classify it.
fn read_status(stream: &mut TcpStream) -> FaultOutcome {
    let mut buf = [0u8; 512];
    let mut head = Vec::new();
    loop {
        match stream.read(&mut buf) {
            Ok(0) => {
                return if head.is_empty() { FaultOutcome::Closed } else { parse_status(&head) }
            }
            Ok(n) => {
                head.extend_from_slice(&buf[..n]);
                if head.windows(2).any(|w| w == b"\r\n") || head.len() >= buf.len() {
                    return parse_status(&head);
                }
            }
            Err(e) => return FaultOutcome::IoError(format!("{e} [kind={:?}]", e.kind())),
        }
    }
}

fn parse_status(head: &[u8]) -> FaultOutcome {
    let line = String::from_utf8_lossy(head);
    let mut parts = line.split_whitespace();
    match (parts.next(), parts.next().and_then(|s| s.parse::<u16>().ok())) {
        (Some(proto), Some(status)) if proto.starts_with("HTTP/1.") => {
            FaultOutcome::Status(status)
        }
        _ => FaultOutcome::IoError(format!("unparseable response head: {line:?}")),
    }
}

fn connect(addr: &str, deadline: Duration) -> Result<TcpStream> {
    let stream = TcpStream::connect(addr)
        .map_err(crate::serve::http::tag_io)
        .with_context(|| format!("connecting to {addr}"))?;
    let _ = stream.set_read_timeout(Some(deadline));
    let _ = stream.set_write_timeout(Some(deadline));
    Ok(stream)
}

/// Send a fully valid request one byte at a time with `delay` between
/// bytes. A robust server must still answer (the request is complete,
/// just slow) — callers expect `Status(200)`.
pub fn dribble_request(
    addr: &str,
    path: &str,
    delay: Duration,
    deadline: Duration,
) -> Result<FaultOutcome> {
    let mut stream = connect(addr, deadline)?;
    let req = format!("GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n");
    for b in req.as_bytes() {
        if let Err(e) = stream.write_all(std::slice::from_ref(b)) {
            return Ok(FaultOutcome::IoError(format!("{e} [kind={:?}]", e.kind())));
        }
        std::thread::sleep(delay);
    }
    let _ = stream.flush();
    Ok(read_status(&mut stream))
}

/// Classic slowloris: send a partial request head (no terminating blank
/// line) and then go quiet while keeping the socket open. A server with
/// read deadlines answers 408 or closes the connection — it must never
/// hold the worker slot forever. The call returns as soon as the server
/// reacts (or our own `deadline` fires).
pub fn slowloris(addr: &str, deadline: Duration) -> Result<FaultOutcome> {
    let mut stream = connect(addr, deadline)?;
    // a plausible, incomplete head — ends mid-header, no blank line
    let partial = b"GET /models HTTP/1.1\r\nHost: victim\r\nX-Slow: ";
    if let Err(e) = stream.write_all(partial) {
        return Ok(FaultOutcome::IoError(format!("{e} [kind={:?}]", e.kind())));
    }
    let _ = stream.flush();
    Ok(read_status(&mut stream))
}

/// Open a connection, send half a request line, and hang up.
pub fn disconnect_mid_request(addr: &str, deadline: Duration) -> Result<()> {
    let mut stream = connect(addr, deadline)?;
    let _ = stream.write_all(b"GET /mod");
    let _ = stream.flush();
    drop(stream); // RST/FIN mid-head
    Ok(())
}

/// Request a resource, then refuse to read the response for `hold`
/// before hanging up — pressure on the server's *write* path. With a
/// write deadline the handler unblocks and frees its slot no matter how
/// long the client sulks.
pub fn stalled_reader(addr: &str, path: &str, hold: Duration, deadline: Duration) -> Result<()> {
    let mut stream = connect(addr, deadline)?;
    let req = format!("GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n");
    if stream.write_all(req.as_bytes()).is_ok() {
        let _ = stream.flush();
        std::thread::sleep(hold);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// In-memory transport: reads from a script, collects writes.
    struct Mem {
        input: std::io::Cursor<Vec<u8>>,
        output: Vec<u8>,
    }

    impl Mem {
        fn new(input: &[u8]) -> Self {
            Self { input: std::io::Cursor::new(input.to_vec()), output: Vec::new() }
        }
    }

    impl Read for Mem {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            self.input.read(buf)
        }
    }

    impl Write for Mem {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.output.write(buf)
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn dribble_writes_one_byte_per_call() {
        let mut c = FaultyConn::new(Mem::new(b""), FaultPlan::DribbleWrites);
        assert_eq!(c.write(b"hello").unwrap(), 1);
        assert_eq!(c.write(b"ello").unwrap(), 1);
        // write_all still completes, one byte at a time
        c.write_all(b"llo").unwrap();
        assert_eq!(c.into_inner().output, b"hello");
    }

    #[test]
    fn disconnect_after_write_budget() {
        let mut c = FaultyConn::new(Mem::new(b""), FaultPlan::DisconnectAfterWrite { after: 4 });
        assert_eq!(c.write(b"abcdef").unwrap(), 4);
        let err = c.write(b"gh").unwrap_err();
        assert_eq!(err.kind(), ErrorKind::BrokenPipe);
        assert_eq!(c.into_inner().output, b"abcd");
    }

    #[test]
    fn stalled_reads_after_budget() {
        let mut c =
            FaultyConn::new(Mem::new(b"0123456789"), FaultPlan::StallReadsAfter { after: 3 });
        let mut buf = [0u8; 8];
        let mut got = 0usize;
        while got < 3 {
            got += c.read(&mut buf[got..]).unwrap();
        }
        assert_eq!(&buf[..3], b"012");
        let err = c.read(&mut buf).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::WouldBlock);
    }

    #[test]
    fn passthrough_counts_bytes() {
        let mut c = FaultyConn::new(Mem::new(b"xyz"), FaultPlan::None);
        let mut buf = [0u8; 8];
        assert_eq!(c.read(&mut buf).unwrap(), 3);
        c.write_all(b"ok").unwrap();
        assert_eq!(c.written, 2);
        assert_eq!(c.read, 3);
    }

    #[test]
    fn status_classifier() {
        assert_eq!(parse_status(b"HTTP/1.1 408 Request Timeout\r\n"), FaultOutcome::Status(408));
        assert_eq!(parse_status(b"HTTP/1.0 200 OK\r\n"), FaultOutcome::Status(200));
        assert!(matches!(parse_status(b"garbage"), FaultOutcome::IoError(_)));
    }
}
